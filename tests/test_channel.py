"""The channel layer: quantizer round-trip/unbiasedness properties and
the identity-channel invariants the certification harness leans on.

Two contracts are pinned here.  (1) The transforms themselves: casts
round-trip within their precision, int8 stochastic rounding is unbiased
given uniform offsets and lands on the scale grid, top-k keeps exactly k
survivors, and the wire-bit arithmetic is pure shape x dtype math.
(2) The identity channel is *invisible*: with ``channel="identity"``
every ledger stream — legacy tuple and typed tail alike — is
bit-identical to the default build across the {python, scan} x
{einsum, kernel} product, so nothing under ``docs/results/`` can depend
on the channel subsystem existing.

Property tests use hypothesis when installed; otherwise the
deterministic fallback shim in ``tests/_hypothesis_fallback.py`` replays
a fixed spread of examples.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.channel import (CHANNELS, Channel, parse_channel,
                                stochastic_round)
from repro.core.engine import ENGINES, run_program
from repro.core.runtime import ORACLE_BACKENDS, LocalDistERM
from repro.experiments.instances import build_instance
from repro.experiments.registry import get_algorithm


def _payload(n, seed, scale=1.0):
    rng = np.random.RandomState(seed)
    return jnp.asarray(rng.randn(n).astype(np.float32) * scale)


# --------------------------------------------------------------------------
# parse/registry
# --------------------------------------------------------------------------

def test_parse_channel_names_and_canonicalization():
    assert parse_channel(None).name == "identity"
    assert parse_channel("identity").lossless
    assert parse_channel("topk").name == "topk:0.1"
    assert parse_channel("topk:0.25").rho == 0.25
    ch = parse_channel("int8")
    assert parse_channel(ch) is ch              # Channel passes through
    for bad in ("zip", "fp8", "topk:0", "topk:1.5", "int8:7"):
        with pytest.raises(ValueError):
            parse_channel(bad)


def test_parse_channel_error_messages():
    """The error paths name the actual problem, not a generic list."""
    with pytest.raises(ValueError, match="empty topk keep fraction"):
        parse_channel("topk:")
    # a bare stage that lost its "sched:" prefix gets pointed at it
    with pytest.raises(ValueError, match="did you mean 'sched:int8@5'"):
        parse_channel("int8@5")
    with pytest.raises(ValueError, match="empty schedule"):
        parse_channel("sched:")
    with pytest.raises(ValueError, match="doubled or trailing comma"):
        parse_channel("sched:int8@0,,fp16@5")
    with pytest.raises(ValueError, match="missing"):
        parse_channel("sched:int8")


def test_resolve_channel_env_errors_name_the_env_var(monkeypatch):
    """A typo'd REPRO_CHANNEL must not surface as a caller error."""
    from repro.api import _resolve
    monkeypatch.setenv(_resolve.CHANNEL_ENV, "topk:")
    with pytest.raises(ValueError, match="REPRO_CHANNEL"):
        _resolve.resolve_channel(None)
    # an explicit argument wins over the env var and keeps the plain error
    monkeypatch.setenv(_resolve.CHANNEL_ENV, "int8")
    assert _resolve.resolve_channel("fp16") == "fp16"
    with pytest.raises(ValueError) as ei:
        _resolve.resolve_channel("nope")
    assert "REPRO_CHANNEL" not in str(ei.value)


def test_channel_lists_mirror_api_resolver():
    """core.channel owns the catalogue; the leaf resolver mirrors it."""
    from repro.api import _resolve
    assert _resolve.CHANNELS == CHANNELS
    assert _resolve.resolve_channel(None) == "identity"
    assert _resolve.resolve_channel("topk") == "topk:0.1"
    with pytest.raises(ValueError):
        _resolve.resolve_channel("nope")


def test_resolve_channel_env_var(monkeypatch):
    from repro.api import CHANNEL_ENV, _resolve
    monkeypatch.setenv(CHANNEL_ENV, "fp16")
    assert _resolve.resolve_channel(None) == "fp16"
    assert _resolve.resolve_channel("int8") == "int8"   # explicit wins
    monkeypatch.delenv(CHANNEL_ENV)
    assert _resolve.resolve_channel(None) == "identity"


# --------------------------------------------------------------------------
# transform properties
# --------------------------------------------------------------------------

@given(n=st.integers(4, 300), seed=st.integers(0, 99),
       scale=st.floats(1e-3, 1e3))
@settings(max_examples=6, deadline=None)
def test_half_precision_roundtrip_and_idempotence(n, seed, scale):
    x = _payload(n, seed, scale)
    for name, rel in (("fp16", 1e-3), ("bf16", 8e-3)):
        ch = parse_channel(name)
        y = ch.apply(x)
        np.testing.assert_allclose(y, x, rtol=rel, atol=rel * scale)
        np.testing.assert_array_equal(ch.apply(y), y)   # idempotent


def test_stochastic_round_unbiased_under_uniform_offsets():
    """E_u[floor(y + u)] == y for u ~ U[0,1): checked on a dense uniform
    grid, where the empirical mean converges at 1/N exactly."""
    N = 4096
    u = (jnp.arange(N, dtype=jnp.float32) + 0.5) / N
    for y in (0.0, 0.25, 2.37, -1.62, 100.499):
        mean = float(jnp.mean(stochastic_round(jnp.full((N,), y), u)))
        assert abs(mean - y) <= 1.5 / N + 1e-4, (y, mean)


@given(n=st.integers(4, 300), seed=st.integers(0, 99),
       scale=st.floats(1e-3, 1e3))
@settings(max_examples=6, deadline=None)
def test_int8_lands_on_grid_within_one_step(n, seed, scale):
    x = _payload(n, seed, scale)
    y = parse_channel("int8").apply(x)
    s = float(jnp.max(jnp.abs(x))) / 127.0
    # every output is an integer multiple of the per-message scale...
    np.testing.assert_allclose(np.asarray(y) / s,
                               np.round(np.asarray(y) / s),
                               atol=1e-3)
    # ...within one grid step of the input (stochastic rounding moves
    # at most one step), and the all-zero message is preserved exactly
    assert float(jnp.max(jnp.abs(y - x))) <= s * (1 + 1e-5)
    np.testing.assert_array_equal(
        parse_channel("int8").apply(jnp.zeros(8)), jnp.zeros(8))


@given(n=st.integers(4, 300), seed=st.integers(0, 99),
       rho=st.floats(0.05, 1.0))
@settings(max_examples=6, deadline=None)
def test_topk_keeps_exactly_k_largest(n, seed, rho):
    x = _payload(n, seed)
    ch = parse_channel(f"topk:{rho:g}")
    y = np.asarray(ch.apply(x))
    k = ch.topk_k(n)
    assert int(np.sum(y != 0)) == min(k, int(np.sum(np.asarray(x) != 0)))
    # the survivors are the k largest magnitudes, passed through exactly
    kept = np.nonzero(y)[0]
    thresh = np.sort(np.abs(np.asarray(x)))[-k]
    assert np.all(np.abs(np.asarray(x))[kept] >= thresh - 1e-7)
    np.testing.assert_array_equal(y[kept], np.asarray(x)[kept])


def test_all_to_all_broadcast_prices_per_machine_messages():
    """A local all-to-all broadcast is m per-machine messages: its wire
    bits are m x wire_bits(per-machine elems), not wire_bits(total) —
    the two differ for channels with per-message overhead (int8's scale,
    topk's per-message k)."""
    from repro.core.comm import LocalCommunicator
    m, per = 4, 8
    for name in ("identity", "fp16", "int8", "topk:0.25"):
        comm = LocalCommunicator(m, channel=name)
        comm.all_to_all_broadcast(jnp.ones((m, per)), tag="blocks")
        (rec,) = comm.ledger.records
        ch = parse_channel(name)
        assert rec.elems == m * per                      # legacy total
        assert rec.bits == m * ch.wire_bits(per, 4), name
        assert rec.direction == "worker->all"


def test_wire_bits_arithmetic():
    assert parse_channel("identity").wire_bits(100, 4) == 3200
    assert parse_channel("fp16").wire_bits(100, 4) == 1600
    assert parse_channel("bf16").wire_bits(100, 4) == 1600
    assert parse_channel("int8").wire_bits(100, 4) == 800 + 32
    assert parse_channel("topk:0.1").wire_bits(100, 4) == 10 * (32 + 32)
    assert parse_channel("topk:0.1").wire_bits(3, 4) == 1 * 64  # k >= 1


# --------------------------------------------------------------------------
# identity channel == channel-free build, across engines x backends
# --------------------------------------------------------------------------

def _typed_stream(dist):
    led = dist.comm.ledger
    return led.rounds, led.round_marks, led.typed_stream()


def _run(bundle, backend, engine, channel):
    algo = get_algorithm("dagd")
    dist = LocalDistERM(bundle.prob, bundle.part, backend=backend,
                        channel=channel)
    program = algo.program(dist, rounds=8, **algo.make_kwargs(bundle.ctx))
    run_program(dist, program, engine=engine)
    return _typed_stream(dist)


def test_identity_channel_streams_bit_identical_across_matrix():
    bundle = build_instance("random_ridge", n=24, d=32, m=4)
    ref = _run(bundle, "einsum", "python", None)
    for backend in ORACLE_BACKENDS:
        for engine in ENGINES:
            for channel in (None, "identity"):
                assert _run(bundle, backend, engine, channel) == ref, \
                    (backend, engine, channel)


def test_lossy_channel_changes_bits_not_legacy_stream():
    bundle = build_instance("random_ridge", n=24, d=32, m=4)
    _, _, ref = _run(bundle, "einsum", "scan", None)
    legacy_ref = [(r[0], r[1], r[2], r[4]) for r in ref]
    for channel in ("fp16", "bf16", "int8", "topk:0.25"):
        rounds, marks, recs = _run(bundle, "einsum", "scan", channel)
        assert [(r[0], r[1], r[2], r[4]) for r in recs] == legacy_ref
        # vector payloads got cheaper; the stream shape did not move
        assert sum(r[3] for r in recs) < sum(r[3] for r in ref), channel
        assert len(marks) == rounds == 8


# --------------------------------------------------------------------------
# the channel axis through the api facade
# --------------------------------------------------------------------------

TINY = dict(instance="thm2_chain",
            instance_params=dict(d=24, kappa=16.0, lam=0.5, m=4),
            algorithm="dagd", rounds=60, eps=(1e-3,))


def test_api_channel_resolution_and_serialization():
    from repro.api import PlanError, RunSpec, plan
    spec = RunSpec(**TINY, channel="topk")
    assert RunSpec.from_json(spec.to_json()) == spec
    pl = plan(spec)
    assert pl.channel == "topk:0.1"     # canonicalized at plan time
    assert plan(RunSpec(**TINY)).channel == "identity"
    with pytest.raises(PlanError, match="unknown channel"):
        plan(RunSpec(**TINY, channel="zip"))
    # a pre-channel (v1) spec dict still loads, defaulting to auto
    v1 = {**spec.to_dict(), "schema_version": 1}
    del v1["channel"]
    assert RunSpec.from_dict(v1).channel == "auto"


def test_api_run_meters_channel_bits():
    from repro.api import RunSpec, run
    ident = run(RunSpec(**TINY))
    int8 = run(RunSpec(**TINY, channel="int8"))
    assert int8.channel == "int8"
    assert ident.stream() == int8.stream()     # legacy stream invariant
    assert int8.ledger.total_bits() < ident.ledger.total_bits()
    assert ident.ledger.total_bits() == 8 * ident.ledger.total_bytes()


def test_execute_batch_groups_by_channel():
    """Same-channel cells group; mixed channels fall back (never merge),
    and the batched ledger — marks included — matches sequential."""
    from repro.api import RunSpec, execute_batch, plan
    k2 = {**TINY, "instance_params": dict(d=24, kappa=64.0, lam=0.5, m=4)}
    same = [plan(RunSpec(**TINY, channel="fp16")),
            plan(RunSpec(**k2, channel="fp16"))]
    res = execute_batch(same)
    assert all(r.batched for r in res)
    seq = plan(RunSpec(**TINY, channel="fp16")).execute()
    assert res[0].stream() == seq.stream()
    assert res[0].ledger.total_bits() == seq.ledger.total_bits()
    assert res[0].ledger.round_marks == seq.ledger.round_marks

    mixed = [plan(RunSpec(**TINY)), plan(RunSpec(**k2, channel="fp16"))]
    assert [r.batched for r in execute_batch(mixed)] == [False, False]


def test_sharded_placement_accepts_channel():
    from repro.api import RunSpec, run
    base = dict(instance="random_ridge",
                instance_params=dict(n=16, d=12, m=1),
                algorithm="dagd", rounds=6, measure="none")
    loc = run(RunSpec(**base, channel="fp16"))
    sh = run(RunSpec(**base, channel="fp16", placement="sharded"))
    assert sh.channel == "fp16"
    assert sh.ledger.total_bits() == loc.ledger.total_bits()
    assert len(sh.ledger.round_marks) == sh.ledger.rounds
    np.testing.assert_allclose(np.asarray(sh.w), np.asarray(loc.w),
                               atol=1e-5, rtol=1e-5)
