"""Mamba2/SSD: chunked scan vs naive recurrence, decode consistency."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.mamba2 import (Mamba2Config, _ssd_chunked, init_mamba2,
                                 init_mamba_cache, mamba2, mamba2_decode)
from repro.models.common import unbox


def _naive_ssd(xh, dt, A, Bm, Cm, rep):
    b, s, h, p = xh.shape
    n = Bm.shape[-1]
    Bh = jnp.repeat(Bm, rep, axis=2).astype(jnp.float32)
    Ch = jnp.repeat(Cm, rep, axis=2).astype(jnp.float32)
    state = jnp.zeros((b, h, n, p))
    ys = []
    for t in range(s):
        decay = jnp.exp(A[None, :] * dt[:, t])
        upd = jnp.einsum("bhn,bh,bhp->bhnp", Bh[:, t], dt[:, t],
                         xh[:, t].astype(jnp.float32))
        state = state * decay[..., None, None] + upd
        ys.append(jnp.einsum("bhn,bhnp->bhp", Ch[:, t], state))
    return jnp.stack(ys, 1), state


@pytest.mark.parametrize("chunk,s", [(8, 32), (16, 64), (64, 64)])
@pytest.mark.parametrize("groups", [1, 2])
def test_ssd_chunked_vs_naive(chunk, s, groups):
    b, h, p, n = 2, 4, 8, 16
    cfg = Mamba2Config(d_model=32, n_heads=h, head_dim=p, d_state=n,
                       chunk=chunk, n_groups=groups)
    k = jax.random.PRNGKey(0)
    xh = jax.random.normal(k, (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1), (b, s, h)))
    A = -jnp.exp(jax.random.normal(jax.random.PRNGKey(2), (h,)))
    Bm = jax.random.normal(jax.random.PRNGKey(3), (b, s, groups, n)) * 0.3
    Cm = jax.random.normal(jax.random.PRNGKey(4), (b, s, groups, n)) * 0.3
    y1, st1 = _ssd_chunked(xh, dt, A, Bm, Cm, cfg)
    y0, st0 = _naive_ssd(xh, dt, A, Bm, Cm, h // groups)
    np.testing.assert_allclose(y1, y0, atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(st1, st0, atol=1e-4, rtol=1e-3)


def test_full_layer_decode_matches_train():
    """Step-by-step recurrent decode == chunked train forward."""
    cfg = Mamba2Config(d_model=32, n_heads=4, head_dim=8, d_state=16,
                       chunk=8, n_groups=2)
    params, _ = unbox(init_mamba2(jax.random.PRNGKey(0), cfg, jnp.float32))
    S = 24
    x = jax.random.normal(jax.random.PRNGKey(1), (2, S, 32)) * 0.5
    y_train = mamba2(params, x, cfg)
    cache = init_mamba_cache(2, cfg, dtype=jnp.float32)
    outs = []
    for t in range(S):
        y_t, cache = mamba2_decode(params, x[:, t:t + 1], cfg, cache)
        outs.append(y_t)
    y_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(y_train, y_dec, atol=2e-3, rtol=2e-2)


@given(s=st.sampled_from([16, 32, 48]), seed=st.integers(0, 10))
@settings(max_examples=10, deadline=None)
def test_ssd_state_decay_property(s, seed):
    """With A -> -inf (decay ~ 0), SSD output reduces to the memoryless
    per-step term C_t . (dt_t B_t x_t)."""
    b, h, p, n = 1, 2, 4, 8
    cfg = Mamba2Config(d_model=16, n_heads=h, head_dim=p, d_state=n,
                       chunk=16, n_groups=1)
    k = jax.random.PRNGKey(seed)
    xh = jax.random.normal(k, (b, s, h, p))
    dt = jnp.ones((b, s, h)) * 0.5
    A = jnp.full((h,), -80.0)  # exp(A dt) ~ 0
    Bm = jax.random.normal(jax.random.PRNGKey(seed + 1), (b, s, 1, n))
    Cm = jax.random.normal(jax.random.PRNGKey(seed + 2), (b, s, 1, n))
    y, _ = _ssd_chunked(xh, dt, A, Bm, Cm, cfg)
    Bh = jnp.repeat(Bm, h, axis=2)
    Ch = jnp.repeat(Cm, h, axis=2)
    expect = jnp.einsum("bshn,bshn->bsh", Ch, Bh)[..., None] * \
        dt[..., None] * xh
    np.testing.assert_allclose(y, expect, atol=1e-4, rtol=1e-3)
