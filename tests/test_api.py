"""The unified run API: RunSpec serialization, plan-time resolution and
validation, execution, and batched execution vs the sequential path.

The facade is the repo's single front door — every runnable surface
(sweep CLI, dryrun CLI, benchmarks, examples) constructs a RunSpec and
resolves ``auto`` choices through ``repro.api.plan``, so this suite pins
the contracts everything else leans on: JSON round-trips, eager
validation, env-var resolution at plan time, ledger identity between
sequential and batched execution, and re-execution of embedded specs.
"""
import numpy as np
import pytest

from repro import api
from repro.api import (ENGINES, ORACLE_BACKENDS, PLACEMENTS, PlanError,
                       RunSpec, execute_batch, plan, run)


TINY = dict(instance="thm2_chain",
            instance_params=dict(d=24, kappa=16.0, lam=0.5, m=4),
            algorithm="dagd", rounds=120, eps=(1e-3,))


# --------------------------------------------------------------------------
# RunSpec serialization
# --------------------------------------------------------------------------

def test_runspec_json_roundtrip():
    spec = RunSpec(**TINY, eps_mode="abs", backend="einsum", tag="probe")
    assert RunSpec.from_json(spec.to_json()) == spec
    assert RunSpec.from_dict(spec.to_dict()) == spec
    # numpy scalars from grid machinery are coerced to JSON types
    spec_np = RunSpec(**{**TINY, "instance_params":
                         dict(d=np.int64(24), kappa=np.float64(16.0),
                              lam=0.5, m=4)},
                      algo_kwargs=dict(L=np.float64(3.0),
                                       nested=[np.int32(1), 2]))
    assert spec_np.instance_params == TINY["instance_params"]
    assert spec_np.algo_kwargs == dict(L=3.0, nested=[1, 2])
    assert RunSpec.from_json(spec_np.to_json()) == spec_np


def test_runspec_rejects_unknown_fields_and_bad_enums():
    with pytest.raises(ValueError):
        RunSpec.from_dict(dict(TINY, bogus_field=1))
    with pytest.raises(ValueError):
        RunSpec(**TINY, eps_mode="relative")
    with pytest.raises(ValueError):
        RunSpec(**TINY, measure="maybe")


# --------------------------------------------------------------------------
# plan(): resolution + validation
# --------------------------------------------------------------------------

def test_plan_resolves_auto_axes_on_cpu(monkeypatch):
    monkeypatch.delenv(api.BACKEND_ENV, raising=False)
    monkeypatch.delenv(api.ENGINE_ENV, raising=False)
    monkeypatch.delenv(api.CHANNEL_ENV, raising=False)
    pl = plan(RunSpec(**TINY))
    assert (pl.placement, pl.backend, pl.engine, pl.channel) == \
        ("local", "einsum", "scan", "identity")
    assert pl.measure == "gap"          # auto: eps requested


def test_env_vars_read_at_plan_time(monkeypatch):
    monkeypatch.setenv(api.BACKEND_ENV, "kernel")
    monkeypatch.setenv(api.ENGINE_ENV, "python")
    pl = plan(RunSpec(**TINY))
    assert (pl.backend, pl.engine) == ("kernel", "python")
    monkeypatch.delenv(api.BACKEND_ENV)
    monkeypatch.delenv(api.ENGINE_ENV)
    pl = plan(RunSpec(**TINY))
    assert (pl.backend, pl.engine) == ("einsum", "scan")


def test_core_resolvers_delegate_to_api():
    """core.runtime/core.engine keep their historical names as shims over
    the single repro.api resolver; the mirrored axis lists must agree."""
    from repro.core import engine as core_engine
    from repro.core import runtime as core_runtime
    assert core_runtime.ORACLE_BACKENDS == ORACLE_BACKENDS
    assert core_engine.ENGINES == ENGINES
    assert core_runtime.resolve_oracle_backend("auto") == \
        api.resolve_oracle_backend("auto")
    assert core_engine.resolve_engine(None) == api.resolve_engine(None)
    assert set(PLACEMENTS) == {"local", "sharded"}


@pytest.mark.parametrize("bad, match", [
    (dict(TINY, instance="nope"), "unknown instance"),
    (dict(TINY, algorithm="nope"), "unknown algorithm"),
    (dict(TINY, instance_params=dict(zz=1)), "does not accept"),
    (dict(TINY, measure="none"), "measure='none'"),
    (dict(TINY, rounds=0), "rounds"),
    (dict(TINY, algorithm="bcd", placement="sharded", eps=(),
          measure="none"), "machine-stacked"),
    (dict(TINY, placement="sharded"), "gap measurement"),
    (dict(TINY, algo_kwargs=dict(zz=1)), "hyper-parameter"),
    (dict(TINY, algo_kwargs=dict(rounds=5)), "hyper-parameter"),
    (dict(TINY, backend="blas"), "oracle backend"),
    (dict(TINY, channel="gzip"), "unknown channel"),
    (dict(TINY, instance=None), "BOTH instance and algorithm"),
])
def test_plan_rejects_invalid_specs(bad, match):
    with pytest.raises(PlanError, match=match):
        plan(RunSpec(**bad))


def test_plan_rejects_misaligned_bundle():
    """A pre-built bundle whose builder inputs differ from the spec's
    instance_params would execute a different problem than the embedded
    run_spec records — rejected on the stamped build_params."""
    from repro.experiments.instances import build_instance
    bundle = build_instance("thm2_chain", d=24, kappa=64.0, lam=0.5, m=4)
    with pytest.raises(PlanError, match="built with"):
        plan(RunSpec(**TINY), bundle=bundle)      # spec says kappa=16
    ok = build_instance("thm2_chain", **TINY["instance_params"])
    assert plan(RunSpec(**TINY), bundle=ok).bundle is ok


def test_resolution_only_plan():
    pl = plan(RunSpec(backend="einsum", engine="python"))
    assert pl.resolution_only
    assert (pl.backend, pl.engine) == ("einsum", "python")
    with pytest.raises(PlanError):
        pl.execute()


# --------------------------------------------------------------------------
# execution + re-execution from serialized specs
# --------------------------------------------------------------------------

def test_run_executes_and_reexecutes_verbatim():
    spec = RunSpec(**TINY)
    res = run(spec)
    assert res.rounds == res.ledger.rounds == spec.rounds
    assert res.gaps.shape == (spec.rounds,)
    assert res.budget_ok is True
    measured = res.measured_rounds(1e-3)
    assert measured is not None
    # the serialized spec re-executes to the identical measurement/meter
    res2 = run(RunSpec.from_json(spec.to_json()))
    assert res2.stream() == res.stream()
    assert res2.measured_rounds(1e-3) == measured
    np.testing.assert_array_equal(np.asarray(res2.w), np.asarray(res.w))


def test_plan_bound_matches_registry_theorem():
    pl = plan(RunSpec(**TINY))
    rep = pl.bound(1e-3)
    assert rep.theorem == "thm2"       # lam > 0, non-incremental
    assert rep.rounds > 0


def test_sharded_placement_matches_local():
    """placement='sharded' (1-device mesh on CPU) produces the same
    iterate and communication structure as the local reference."""
    base = dict(instance="random_ridge",
                instance_params=dict(n=16, d=12, m=1),
                algorithm="dagd", rounds=8, measure="none")
    loc = run(RunSpec(**base))
    sh = run(RunSpec(**base, placement="sharded"))
    np.testing.assert_allclose(np.asarray(sh.w), np.asarray(loc.w),
                               atol=1e-5, rtol=1e-5)
    assert sh.ledger.op_counts() == loc.ledger.op_counts()


# --------------------------------------------------------------------------
# execute_batch
# --------------------------------------------------------------------------

def _specs_grid():
    return [RunSpec(**{**TINY, "instance_params":
                       dict(d=24, kappa=k, lam=0.5, m=4),
                       "algorithm": a})
            for a in ("dagd", "dgd", "disco_f") for k in (16.0, 64.0)]


def test_execute_batch_groups_and_matches_sequential():
    specs = _specs_grid()
    seq = [plan(s).execute() for s in specs]
    bat = execute_batch([plan(s) for s in specs])
    assert all(r.batched for r in bat)   # every cell found a group
    for s, b in zip(seq, bat):
        assert b.stream() == s.stream()
        assert b.ledger.rounds == s.ledger.rounds
        assert b.measured_rounds(1e-3) == s.measured_rounds(1e-3)
        np.testing.assert_allclose(np.asarray(b.w), np.asarray(s.w),
                                   atol=1e-5, rtol=1e-5)


def test_execute_batch_falls_back_in_order():
    """Unbatchable plans (python engine, singleton shapes) still execute;
    results come back in input order."""
    specs = [RunSpec(**TINY),
             RunSpec(**TINY, engine="python"),
             RunSpec(**{**TINY, "rounds": 90}),       # singleton group
             RunSpec(**{**TINY, "instance_params":
                        dict(d=24, kappa=64.0, lam=0.5, m=4)})]
    results = execute_batch([plan(s) for s in specs])
    assert [r.spec for r in results] == specs
    assert results[1].batched is False and results[2].batched is False
    assert results[0].batched and results[3].batched   # group of two
    ref = plan(specs[1]).execute()
    assert results[1].stream() == ref.stream()


def test_sweep_batch_mode_matches_sequential():
    from repro.experiments.sweep import SweepSpec, run_sweep
    spec = SweepSpec(
        name="batch-probe", instance="thm2_chain",
        grid=dict(d=[24], kappa=[16.0, 64.0], lam=[0.5], m=[4]),
        algorithms=("dagd", "dgd"), eps=(1e-3,), max_rounds=120)
    seq = run_sweep(spec)
    bat = run_sweep(spec, execute="batch")
    assert [r.to_dict() for r in seq.records] == \
        [r.to_dict() for r in bat.records]
    assert seq.records[0].certified is True


def test_sweep_records_embed_reexecutable_spec():
    from repro.experiments.sweep import SweepSpec, run_sweep
    spec = SweepSpec(
        name="spec-probe", instance="thm2_chain",
        grid=dict(d=[16], kappa=[8.0], lam=[0.5], m=[2]),
        algorithms=("dagd",), eps=(1e-3,), max_rounds=100)
    rec = run_sweep(spec).records[0]
    assert rec.run_spec is not None
    res = run(RunSpec.from_dict(rec.run_spec))
    assert res.measured_rounds(rec.eps_abs) == rec.measured_rounds
    assert res.ledger.rounds == rec.ledger_rounds
    assert res.ledger.op_counts() == rec.op_counts


# --------------------------------------------------------------------------
# group_key composition (regression pin for the serving layer)
# --------------------------------------------------------------------------

def test_group_key_composition_partitions_the_axes():
    """Pin what ``Cell.group_key`` is made of.  The continuous-batching
    scheduler (``repro.serve``) pools submissions by this key, so a
    change in its composition silently changes which specs may share a
    compiled program: the leading components must stay
    (algorithm, backend, channel, rounds), placement/engine must never
    reach a key (unbatchable plans yield no cell), and a mixed batch
    must partition exactly as pinned here."""
    mixed = dict(
        k16=RunSpec(**TINY),
        k64=RunSpec(**{**TINY, "instance_params":
                       dict(d=24, kappa=64.0, lam=0.5, m=4)}),
        kernel=RunSpec(**TINY, backend="kernel"),
        fp16=RunSpec(**TINY, channel="fp16"),
        short=RunSpec(**{**TINY, "rounds": 90}),
        python=RunSpec(**TINY, engine="python"),
        sharded=RunSpec(instance="random_ridge",
                        instance_params=dict(n=16, d=12, m=1),
                        algorithm="dagd", rounds=8, measure="none",
                        placement="sharded"),
    )
    cells = {name: api.prepare_cell(plan(s)) for name, s in mixed.items()}

    # placement/engine never reach the pool: those plans are sequential
    assert cells["python"] is None and cells["sharded"] is None

    keys = {n: c.group_key() for n, c in cells.items() if c is not None}
    # same structure, different data -> same key (the whole point)
    assert keys["k16"] == keys["k64"]
    # each remaining axis, and the round budget, splits the key
    algo, backend, channel, rounds = keys["k16"][:4]
    assert (algo, backend, channel, rounds) == \
        ("dagd", "einsum", "identity", 120)
    assert keys["kernel"][:4] == ("dagd", "kernel", "identity", 120)
    assert keys["fp16"][:4] == ("dagd", "einsum", "fp16", 120)
    assert keys["short"][:4] == ("dagd", "einsum", "identity", 90)

    # the induced partition of the mixed batch, exactly
    groups = {}
    for name, cell in cells.items():
        if cell is not None:
            groups.setdefault(cell.group_key(), []).append(name)
    partition = sorted(sorted(g) for g in groups.values())
    assert partition == [["fp16"], ["k16", "k64"], ["kernel"], ["short"]]


# --------------------------------------------------------------------------
# adaptive channels: batching, grouping, and the frontier round trip
# --------------------------------------------------------------------------

def test_group_key_separates_schedules():
    """Scheduled channels reach the group key as their canonical wire
    channel: same schedule pools, different switch round never does, and
    a gap: spec pools with the sched: it resolves to."""
    k64 = {**TINY, "instance_params": dict(d=24, kappa=64.0, lam=0.5,
                                           m=4)}
    a = api.prepare_cell(plan(RunSpec(**TINY,
                                      channel="sched:int8@0,fp16@10")))
    b = api.prepare_cell(plan(RunSpec(**k64,
                                      channel="sched:int8@0,fp16@10")))
    c = api.prepare_cell(plan(RunSpec(**TINY,
                                      channel="sched:int8@0,fp16@20")))
    assert a.group_key() == b.group_key()
    assert a.group_key() != c.group_key()
    assert a.group_key()[2] == "sched:int8@0,fp16@10"


def test_execute_batch_matches_sequential_under_schedules():
    """The vmapped group threads the same global round indices the
    sequential scan does, so scheduled-channel ledgers — re-priced
    records, marks and all — stay bit-identical between the paths."""
    k64 = {**TINY, "instance_params": dict(d=24, kappa=64.0, lam=0.5,
                                           m=4)}
    specs = [RunSpec(**TINY, channel="sched:int8@0,fp16@10"),
             RunSpec(**k64, channel="sched:int8@0,fp16@10")]
    seq = [plan(s).execute() for s in specs]
    bat = execute_batch([plan(s) for s in specs])
    assert all(r.batched for r in bat)
    for s, b in zip(seq, bat):
        assert b.ledger.typed_stream() == s.ledger.typed_stream()
        assert b.ledger.round_marks == s.ledger.round_marks
        assert b.measured_rounds(1e-3) == s.measured_rounds(1e-3)
        np.testing.assert_allclose(np.asarray(b.w), np.asarray(s.w),
                                   atol=1e-5, rtol=1e-5)


def test_frontier_points_reexecute_bit_identically():
    """Differential gate for the bits-to-eps frontier: every point the
    search emits embeds a RunSpec, and re-executing that spec from its
    serialized form reproduces the verdicts, the measured rounds, and
    the total wire bits exactly — gap: points included (their schedule
    re-resolves from a fresh deterministic identity probe)."""
    from repro.experiments import frontier
    cell = dict(preset="thm2-small", instance="thm2_chain",
                instance_params=dict(d=24, kappa=16.0, lam=0.5, m=4),
                algorithm="dagd", rounds=120, eps=(1e-2, 1e-3),
                eps_mode="abs")
    record = frontier.run_cell(cell)
    assert any(p["adaptive"] for p in record["points"])
    assert any(p["channel"].startswith("gap:") for p in record["points"])
    for p in record["points"]:
        pl = plan(RunSpec.from_dict(p["run_spec"]))
        res = pl.execute()
        assert (res.wire_channel or res.channel) == p["wire_channel"]
        assert int(res.ledger.total_bits()) == p["total_bits"]
        for pe in p["per_eps"]:
            measured = res.measured_rounds(pl.eps_abs(pe["eps"]))
            assert measured == pe["measured_rounds"], p["channel"]
            assert pl.certify(res, pe["eps"]) == pe["certified"]
            if measured is not None:
                assert int(res.ledger.bits_through_round(measured)) == \
                    pe["bits_to_eps"], p["channel"]
        pl.release()
