"""Encoder-decoder (whisper family): decode == teacher forcing, stubs."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get
from repro.models import encdec as E
from repro.models.common import unbox
from repro.models import layers as L


@pytest.fixture(scope="module")
def setup():
    cfg = get("whisper-large-v3").smoke()
    params, _ = unbox(E.init_params(jax.random.PRNGKey(0), cfg))
    frames = jax.random.normal(jax.random.PRNGKey(1),
                               (2, cfg.n_frames, cfg.d_model),
                               cfg.dtype)
    return cfg, params, frames


def test_decode_matches_teacher_forcing(setup):
    """Greedy-free check: feeding the SAME tokens step-by-step through the
    cache must reproduce the teacher-forced decoder hiddens' logits."""
    cfg, params, frames = setup
    tokens = jax.random.randint(jax.random.PRNGKey(2), (2, 10), 0,
                                cfg.vocab)
    enc = E.encode(params, cfg, frames)
    hidden = E.decode_train(params, cfg, tokens, enc)
    logits_tf = L.logits(params["embed"], hidden)

    cache = E.init_cache(cfg, 2, max_seq=16)
    # prime cross-KV from the encoder states (per-layer projections)
    cks, cvs = [], []
    blocks = params["dec_blocks"]
    for i in range(cfg.n_dec_layers):
        blk = jax.tree_util.tree_map(lambda a: a[i], blocks)
        cks.append(jnp.einsum("btd,dhk->bthk", enc,
                              blk["cross_attn"]["wk"]))
        cvs.append(jnp.einsum("btd,dhk->bthk", enc,
                              blk["cross_attn"]["wv"]))
    cache["cross_k"] = jnp.stack(cks).astype(cache["cross_k"].dtype)
    cache["cross_v"] = jnp.stack(cvs).astype(cache["cross_v"].dtype)

    outs = []
    for t in range(10):
        lg, cache = E.decode_step(params, cfg, tokens[:, t:t + 1], cache)
        outs.append(lg)
    logits_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(logits_tf, np.float32),
                               np.asarray(logits_dec, np.float32),
                               atol=5e-2, rtol=5e-2)


def test_encoder_is_bidirectional(setup):
    """Changing late frames changes early encoder states (non-causal)."""
    cfg, params, frames = setup
    enc1 = E.encode(params, cfg, frames)
    frames2 = frames.at[:, -8:, :].add(1.0)
    enc2 = E.encode(params, cfg, frames2)
    assert not np.allclose(np.asarray(enc1[:, :8], np.float32),
                           np.asarray(enc2[:, :8], np.float32))


def test_loss_drops_with_training(setup):
    """A couple of AdamW steps on one batch reduce the enc-dec loss."""
    cfg, params, frames = setup
    from repro.launch.steps import make_train_step
    from repro.optim import adamw_init, OptConfig
    tokens = jax.random.randint(jax.random.PRNGKey(3), (2, 16), 0,
                                cfg.vocab)
    batch = {"frames": frames, "tokens": tokens,
             "labels": jnp.roll(tokens, -1, axis=1)}
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, OptConfig(lr=3e-3)))
    p = params
    losses = []
    for _ in range(8):
        p, opt, metrics = step(p, opt, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0]
