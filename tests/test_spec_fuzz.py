"""Property/fuzz tests for RunSpec deserialization and admission.

RunSpecs arrive over the wire now (``repro.serve`` accepts JSON
payloads; every ``docs/results`` record embeds one), so the
deserialization boundary must be total: any payload either loads into a
spec (v1 dicts migrate and round-trip) or raises a clear ``ValueError``
naming the problem — never a ``TypeError``/``AttributeError`` traceback
from a coercion or from deep inside ``plan()``.  Uses hypothesis when
installed, else the deterministic fallback shim replays a fixed spread
(``tests/_hypothesis_fallback.py``).
"""
import json

import pytest
from hypothesis import given, settings, strategies as st

from repro import api
from repro.api import PlanError, RunSpec
from repro.serve import SpecError, parse_runspec


VALID = dict(instance="thm2_chain",
             instance_params=dict(d=24, kappa=16.0, lam=0.5, m=4),
             algorithm="dagd", rounds=120, eps=[1e-3])


# --------------------------------------------------------------------------
# Malformed JSON text
# --------------------------------------------------------------------------

MALFORMED_JSON = [
    "", "{", "[1, 2", '{"instance": }', "{'instance': 'x'}",
    '{"instance": "thm2_chain",}', "not json at all", "\x00",
    '{"a": 1} trailing',
]

# syntactically valid JSON whose top level is not an object
NON_OBJECT_JSON = ["null", "[1, 2]", '"thm2_chain"', "3.14", "true"]


@given(text=st.sampled_from(MALFORMED_JSON + NON_OBJECT_JSON))
@settings(max_examples=len(MALFORMED_JSON) + len(NON_OBJECT_JSON),
          deadline=None)
def test_bad_json_text_is_a_clear_valueerror(text):
    with pytest.raises(ValueError):
        RunSpec.from_json(text)
    with pytest.raises(SpecError):
        parse_runspec(text)


def test_non_dict_payloads_rejected_not_crashed():
    for payload in (None, 3.14, True, [VALID], "nope", b"\xff\xfe"):
        with pytest.raises(ValueError):
            parse_runspec(payload)
    with pytest.raises(ValueError, match="JSON object"):
        RunSpec.from_dict([("instance", "thm2_chain")])


# --------------------------------------------------------------------------
# Wrong-typed fields: load or ValueError, never anything else
# --------------------------------------------------------------------------

_FIELDS = sorted(VALID) + ["eps_mode", "measure", "placement", "backend",
                           "engine", "channel", "algo_kwargs",
                           "check_budget", "tag"]
_BAD_VALUES = [None, 123, 1.5, True, [1, 2], {"zz": 1}, "bogus", ""]


@given(field=st.sampled_from(_FIELDS),
       value=st.sampled_from(_BAD_VALUES))
@settings(max_examples=60, deadline=None)
def test_wrong_typed_axes_load_or_raise_valueerror(field, value):
    """Fuzz one field at a time: the payload must either produce a spec
    that then planned cleanly or raises PlanError — or be rejected at
    load time with a ValueError.  No other exception type may escape
    either stage (that would be the deep-inside-plan traceback this
    suite exists to prevent)."""
    payload = dict(VALID, **{field: value})
    try:
        spec = RunSpec.from_dict(payload)
    except ValueError:
        return                         # clear load-time rejection
    try:
        api.plan(spec)
    except PlanError:
        return                         # clear plan-time rejection
    # some (field, value) pairs are legitimately fine (tag="bogus",
    # rounds=123, check_budget=True...) — loading + planning is success


@given(channel=st.sampled_from(
    ["gzip", "int4", "fp64", "topk:", "topk:0", "topk:2.0",
     "topk:-0.1", "identity ", "FP16", "topk:0.0.1",
     "sched", "sched:", "sched:fp16@5", "sched:int8@0,fp16@0",
     "sched:@0", "sched:int8@x", "sched:int8", "sched:int8@0,gzip@4",
     "gap", "gap:", "gap:int8", "gap:int8@0.1,fp16",
     "gap:int8,fp16@nope", "gap:int8,fp16@0",
     "gap:int8,fp16@0.1,identity@0.5"]))
@settings(max_examples=25, deadline=None)
def test_unknown_channel_strings_rejected_at_plan_time(channel):
    """The channel vocabulary lives in core.channel; a spec loads with
    any string but plan() must reject bad ones as PlanError (a
    ValueError) naming the channel — not crash inside the parser."""
    spec = RunSpec(**VALID, channel=channel)
    with pytest.raises(PlanError):
        api.plan(spec)


# every malformed schedule/gap string must name the offending SEGMENT
# (not just fail) — the strings arrive over the wire and via the
# REPRO_CHANNEL env var, where "ValueError: could not convert string"
# from a bare float() would be useless
_SEGMENT_ERRORS = [
    ("sched:@0", "missing channel name"),
    ("sched:int8@0,@5", "'@5'.*missing channel name"),
    ("sched:int8@x", "'int8@x'.*'x' is not an integer"),
    ("sched:int8", "'int8'.*missing '@"),
    ("sched:fp16@5", "must start at round 0"),
    ("sched:int8@0,fp16@0", "strictly increasing"),
    ("sched:int8@0,gzip@4", "'gzip@4'.*unknown channel"),
    ("gap:int8@0.1,fp16", "'int8@0.1'.*no threshold"),
    ("gap:int8,fp16@nope", "'fp16@nope'.*'nope' is not a number"),
    ("gap:int8,fp16@0", "'fp16@0'.*finite and > 0"),
    ("gap:int8,fp16@0.1,identity@0.5", "strictly decrease"),
    ("topk:0.0.1", "bad topk keep fraction '0.0.1'"),
]


@pytest.mark.parametrize("channel, match", _SEGMENT_ERRORS)
def test_malformed_schedule_errors_name_the_segment(channel, match):
    from repro.core.channel import parse_channel
    with pytest.raises(ValueError, match=match):
        parse_channel(channel)
    with pytest.raises(PlanError, match=match):
        api.plan(RunSpec(**VALID, channel=channel))


def test_malformed_channel_env_var_raises_named_error(monkeypatch):
    """resolve_channel(None) consults REPRO_CHANNEL: a malformed
    schedule there must surface the same segment-naming ValueError, not
    a bare parse failure."""
    from repro.api import CHANNEL_ENV, _resolve
    monkeypatch.setenv(CHANNEL_ENV, "sched:int8@x")
    with pytest.raises(ValueError, match="'x' is not an integer"):
        _resolve.resolve_channel(None)
    monkeypatch.setenv(CHANNEL_ENV, "sched:int8@0,fp16@4")
    assert _resolve.resolve_channel(None) == "sched:int8@0,fp16@4"


def test_unknown_fields_and_versions_rejected():
    with pytest.raises(ValueError, match="unknown RunSpec field"):
        RunSpec.from_dict(dict(VALID, bogus=1))
    with pytest.raises(ValueError, match="schema_version"):
        RunSpec.from_dict(dict(VALID, schema_version=99))


# --------------------------------------------------------------------------
# v1-schema migration
# --------------------------------------------------------------------------

def _v1_dict():
    d = RunSpec(**VALID).to_dict()
    del d["channel"]                  # the axis added in schema 2
    d["schema_version"] = 1
    return d


def test_v1_schema_loads_and_migration_round_trips():
    spec = RunSpec.from_dict(_v1_dict())
    assert spec.channel == "auto"     # v1 default: resolver decides
    migrated = spec.to_dict()
    assert migrated["schema_version"] == api.SPEC_SCHEMA_VERSION
    assert RunSpec.from_dict(migrated) == spec
    assert RunSpec.from_json(spec.to_json()) == spec


@given(rounds=st.integers(1, 5000),
       eps=st.floats(1e-9, 1.0),
       eps_mode=st.sampled_from(["abs", "rel"]),
       channel=st.sampled_from(["auto", "identity", "fp16", "bf16",
                                "int8", "topk:0.25",
                                "sched:int8@0,fp16@10",
                                "gap:int8,fp16@0.001"]),
       engine=st.sampled_from(["auto", "scan", "python"]))
@settings(max_examples=12, deadline=None)
def test_generated_valid_specs_round_trip(rounds, eps, eps_mode, channel,
                                          engine):
    spec = RunSpec(**{**VALID, "rounds": rounds, "eps": [eps]},
                   eps_mode=eps_mode, channel=channel, engine=engine)
    assert RunSpec.from_json(spec.to_json()) == spec
    wire = json.loads(spec.to_json())
    assert wire["schema_version"] == api.SPEC_SCHEMA_VERSION
    assert RunSpec.from_dict(wire) == spec
