"""End-to-end behaviour of the paper's system.

1. Paper validation: on the Theorem-2 hard instance, (a) no algorithm in
   the family beats the error floor within the Corollary-6 regime, and
   (b) the matching algorithm (DAGD) converges at the bound's rate.
2. Framework: a tiny LM actually learns (loss decreases) through the
   full train loop (data pipeline -> model -> AdamW -> checkpoint).
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import ChainInstance, ERMProblem, squared_loss
from repro.core.partition import even_partition
from repro.core.runtime import LocalDistERM
from repro.core.algorithms import ALGORITHMS


def _chain_erm(d, kappa, lam):
    ci = ChainInstance(d=d, kappa=kappa, lam=lam)
    B, y, lam_ = ci.as_erm_data()
    n = B.shape[0]
    prob = ERMProblem(A=jnp.asarray(B) * np.sqrt(n),
                      y=jnp.asarray(y) * np.sqrt(n),
                      loss=squared_loss(), lam=lam_)
    return ci, prob


@pytest.mark.parametrize("name", ["dgd", "dagd", "bcd", "disco_f"])
def test_no_family_member_beats_the_floor(name):
    """THE paper claim, measured: within k <= d rounds, every algorithm in
    F^{lam,L} sits above the Corollary-6 error floor."""
    d, kappa, lam = 64, 100.0, 0.5
    ci, prob = _chain_erm(d, kappa, lam)
    part = even_partition(d, 4)
    fstar = float(prob.value(jnp.asarray(ci.w_star())))
    L = prob.smoothness_bound()
    dist = LocalDistERM(prob, part)
    algo = ALGORITHMS[name]
    if name == "bcd":
        block_L = jnp.asarray(
            [[float(jnp.linalg.norm(Aj, 2)) ** 2 / prob.n + prob.lam]
             for Aj in part.split_columns(prob.A)])
        _, aux = algo(dist, rounds=d - 1, block_L=block_L, m=part.m,
                      history=True)
    else:
        _, aux = algo(dist, rounds=d - 1, L=L, lam=prob.lam, history=True)
    for k, w in enumerate(aux["iterates"], start=1):
        gap = float(prob.value(dist.gather_w(w))) - fstar
        floor = ci.error_floor(k)
        if floor < 5e-7:   # below f32 resolution of f-values: stop
            break
        assert gap >= floor * (1 - 1e-4), \
            f"{name} beat the floor at round {k}: {gap} < {floor}"


def test_dagd_rate_matches_bound_shape():
    """log(gap) decreases ~ linearly with slope of the same order as the
    bound's -4/(sqrt(kappa)+1) per round (tightness witness)."""
    d, kappa, lam = 96, 64.0, 0.5
    ci, prob = _chain_erm(d, kappa, lam)
    part = even_partition(d, 4)
    fstar = float(prob.value(jnp.asarray(ci.w_star())))
    L = prob.smoothness_bound()
    dist = LocalDistERM(prob, part)
    _, aux = ALGORITHMS["dagd"](dist, rounds=80, L=L, lam=prob.lam,
                                history=True)
    gaps = [max(float(prob.value(dist.gather_w(w))) - fstar, 1e-14)
            for w in aux["iterates"]]
    ks = np.arange(10, 70)
    slope = np.polyfit(ks, np.log([gaps[k] for k in ks]), 1)[0]
    bound_slope = -4.0 / (np.sqrt(kappa) + 1.0)
    assert slope < -0.2 / np.sqrt(kappa), slope     # converges fast
    assert slope > 6 * bound_slope, (slope, bound_slope)  # not faster than LB order


def test_tiny_lm_learns(tmp_path):
    """Full loop: synthetic bigram data -> train_step -> loss decreases,
    checkpoint save/restore preserves the params."""
    from repro.configs import get
    from repro.models import transformer as T
    from repro.models.common import unbox
    from repro.launch.steps import make_train_step
    from repro.optim import adamw_init, OptConfig
    from repro.data import TokenDataConfig, synthetic_lm_batches
    from repro.checkpoint import restore_checkpoint, save_checkpoint

    cfg = get("qwen1.5-32b").smoke()
    params, _ = unbox(T.init_params(jax.random.PRNGKey(0), cfg))
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, OptConfig(lr=3e-3)))
    data = synthetic_lm_batches(TokenDataConfig(vocab=cfg.vocab,
                                                seq_len=64, batch=8))
    losses = []
    for i in range(30):
        batch = next(data)
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses[::10]

    save_checkpoint(str(tmp_path), 30, params)
    like = jax.tree_util.tree_map(jnp.zeros_like, params)
    restored = restore_checkpoint(str(tmp_path), 30, like)
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
