"""Fault injection + self-healing recovery: determinism, detection,
pricing, and value transparency.

The fault model (``repro.core.faults``) is a seeded, data-independent
schedule injected at the communicator boundary.  These tests pin the
four contracts PR 8 claims:

  * **determinism** — every decision is a pure function of
    ``(seed, message index, attempt)`` or ``(seed, algorithm round)``,
    so python/scan/batch engines price the identical recovery stream;
  * **detection** — the XOR-fold checksum catches every single-bit
    corruption ``corrupt`` can inject;
  * **pricing** — recovery traffic is first-class in the ledger:
    ``total_bits == clean_bits + retransmit_bits`` exactly, the clean
    slice is bit-identical to the ``faults="none"`` run, and measured
    recovery rounds equal the declared (pre-computable) budget;
  * **transparency** — delivered payloads are always clean copies, so
    iterates and verdicts are bit-identical to the fault-free run; a
    crash replays from its snapshot to the identical state.
"""
import dataclasses

import numpy as np
import pytest

from repro import api
from repro.core.faults import (FaultRecoveryError, FaultSpec, NACK_BITS,
                               NO_FAULTS, checksum, corrupt, parse_faults)

CHAOS = "inject:seed=3,drop=0.15,flip=0.15,straggle=0.2x2,crash=8,snap=3"


def _spec(faults="none", engine="auto", rounds=12, **kw):
    base = dict(instance="thm2_chain",
                instance_params=dict(d=12, kappa=16.0, lam=0.5, m=2),
                algorithm="dagd", rounds=rounds, eps=(1e-2,),
                faults=faults, engine=engine)
    base.update(kw)
    return api.RunSpec(**base)


# --------------------------------------------------------------------------
# Grammar
# --------------------------------------------------------------------------

def test_parse_canonicalization_is_idempotent():
    f = parse_faults(CHAOS)
    assert f.name == CHAOS
    assert parse_faults(f.name) == f
    assert parse_faults(f) is f
    assert parse_faults(None) == NO_FAULTS == parse_faults("none")
    assert parse_faults("").name == "none"
    assert not NO_FAULTS.active and f.active


@pytest.mark.parametrize("bad", [
    "drop=0.5",                       # missing inject: prefix
    "inject:",                        # empty segment
    "inject:drop",                    # missing '='
    "inject:drop=2.0",                # probability out of range
    "inject:drop=x",                  # not a number
    "inject:drop=1.0",                # unrecoverable
    "inject:flip=1.0",                # unrecoverable
    "inject:snap=3",                  # snap= requires crash=
    "inject:crash=0",                 # crash round is 1-based
    "inject:drop=0.1,drop=0.2",       # duplicate key
    "inject:bogus=1",                 # unknown key
])
def test_parse_rejects_bad_grammar(bad):
    with pytest.raises(ValueError, match="faults"):
        parse_faults(bad)


# --------------------------------------------------------------------------
# Seeded determinism
# --------------------------------------------------------------------------

def test_fault_schedule_is_deterministic_and_seed_sensitive():
    f = parse_faults("inject:seed=1,drop=0.3,flip=0.2,resend=16")
    g = parse_faults("inject:seed=2,drop=0.3,flip=0.2,resend=16")
    sched_f = [f.attempts(m) for m in range(200)]
    assert sched_f == [f.attempts(m) for m in range(200)]
    assert sched_f != [g.attempts(m) for m in range(200)]
    assert any(sched_f), "rates this high must fault some message"
    assert all(k in ("drop", "flip") for ks in sched_f for k in ks)
    st = parse_faults("inject:seed=1,straggle=0.5x3")
    delays = [st.straggle_delay(r) for r in range(50)]
    assert delays == [st.straggle_delay(r) for r in range(50)]
    assert set(delays) == {0, 3}


def test_resend_budget_exhaustion_raises():
    f = FaultSpec(drop=0.9, max_resend=1)
    msgs_ok, failed = 0, 0
    for m in range(100):
        try:
            f.attempts(m)
            msgs_ok += 1
        except FaultRecoveryError:
            failed += 1
    assert failed > 0, "p=0.9 with 2 attempts must exhaust some budget"


def test_declared_recovery_budget_is_precomputable():
    f = parse_faults(CHAOS)
    total = 12
    s, k = f.crash_span(total)
    assert (s, k) == (6, 8)           # snap=3: last snapshot before 8 is 6
    declared = f.declared_recovery_rounds(total)
    assert declared == sum(f.straggle_delay(r) for r in range(total)) + 2
    # a crash beyond the budget never fires
    assert f.crash_span(4) == (0, 0)


# --------------------------------------------------------------------------
# Checksum detection
# --------------------------------------------------------------------------

def test_checksum_detects_every_injected_flip():
    rng = np.random.default_rng(0)
    for shape in [(7,), (3, 5), (16,), (2, 2, 2)]:
        a = rng.normal(size=shape).astype(np.float32)
        ref = checksum(a)
        for msg in range(20):
            for attempt in range(3):
                bad = corrupt(a, seed=3, msg=msg, attempt=attempt)
                assert bad.shape == a.shape
                assert np.asarray(bad).dtype == np.asarray(a).dtype
                assert checksum(bad) != ref, (shape, msg, attempt)
        # corruption is deterministic per (seed, msg, attempt)
        assert np.array_equal(corrupt(a, 3, 0, 0), corrupt(a, 3, 0, 0))


# --------------------------------------------------------------------------
# Engine identity: python == scan == batch under faults
# --------------------------------------------------------------------------

def test_faulted_stream_identical_across_engines():
    res_py = api.plan(_spec(CHAOS, engine="python")).execute()
    res_sc = api.plan(_spec(CHAOS, engine="scan")).execute()
    assert res_py.ledger.typed_stream() == res_sc.ledger.typed_stream()
    assert res_py.ledger.round_marks == res_sc.ledger.round_marks
    assert res_py.ledger.rounds == res_sc.ledger.rounds
    assert res_py.ledger.recovery_rounds == res_sc.ledger.recovery_rounds
    assert res_py.ledger.retransmissions() > 0
    np.testing.assert_allclose(res_py.w, res_sc.w, rtol=1e-5, atol=1e-5)


def test_faulted_stream_identical_across_batching():
    def _spec_k(k):
        return _spec(CHAOS,
                     instance_params=dict(d=12, kappa=k, lam=0.5, m=2))

    specs = [_spec_k(8.0), _spec_k(16.0)]
    seq = [api.plan(s).execute() for s in specs]
    bat = api.execute_batch([api.plan(s) for s in specs])
    for s, b in zip(seq, bat):
        assert b.ledger.typed_stream() == s.ledger.typed_stream()
        assert b.ledger.round_marks == s.ledger.round_marks
        assert b.ledger.recovery_rounds == s.ledger.recovery_rounds
        np.testing.assert_allclose(b.w, s.w, rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------------------
# Pricing: every recovered fault is in the ledger, exactly
# --------------------------------------------------------------------------

def test_retransmission_pricing_is_exact():
    res = api.plan(_spec(CHAOS)).execute()
    led = res.ledger
    assert led.retransmissions() > 0
    assert led.total_bits() == led.clean_bits() + led.retransmit_bits()
    # recovery stream structure: one 32-bit NACK per failed attempt,
    # followed by a resend priced identically to the original record
    stream = led.typed_stream()
    nacks = [r for r in stream if r[0] == "nack"]
    resends = [r for r in stream if r[-1] and r[0] != "nack"]
    assert nacks and all(r[3] == NACK_BITS and r[-1] for r in nacks)
    clean = {(r[0], r[4], r[5]): r for r in stream if not r[-1]}
    for r in resends:
        ref = clean.get((r[0], r[4], r[5]))
        if ref is not None:           # crash-replay rounds re-price whole
            assert r[1:4] == ref[1:4]  # rounds; per-message resends must
                                       # cost exactly the original


def test_clean_slice_is_bit_identical_to_fault_free_run():
    res_f = api.plan(_spec(CHAOS)).execute()
    res_0 = api.plan(_spec("none")).execute()
    led_f, led_0 = res_f.ledger, res_0.ledger
    assert led_f.clean_bits() == led_0.total_bits()
    # the non-retransmit sub-stream is the fault-free stream, verbatim
    clean_stream = [r for r in led_f.typed_stream() if not r[-1]]
    assert clean_stream == list(led_0.typed_stream())
    # value transparency: recovered values == fault-free values, bit-for-bit
    assert np.array_equal(np.asarray(res_f.w), np.asarray(res_0.w))
    assert res_f.measured_rounds(1e-2) == res_0.measured_rounds(1e-2)


def test_faults_none_is_bit_identical_to_default():
    """The faults axis at "none" leaves every stream byte-identical to a
    spec that never mentions it — the PR-8 invariance gate."""
    base = dict(instance="thm2_chain",
                instance_params=dict(d=12, kappa=16.0, lam=0.5, m=2),
                algorithm="dagd", rounds=12, eps=(1e-2,))
    res_default = api.plan(api.RunSpec(**base)).execute()
    res_none = api.plan(api.RunSpec(**base, faults="none")).execute()
    led_d, led_n = res_default.ledger, res_none.ledger
    assert led_n.typed_stream() == led_d.typed_stream()
    assert led_n.round_marks == led_d.round_marks
    assert led_n.recovery_rounds == 0 and led_n.retransmit_bits() == 0
    assert np.array_equal(np.asarray(res_none.w),
                          np.asarray(res_default.w))


def test_recovery_report_certifies_declared_budget():
    pl = api.plan(_spec(CHAOS))
    rep = pl.recovery_report(pl.execute())
    assert rep["faults"] == CHAOS
    assert rep["within_budget"]
    assert rep["recovery_rounds"] == rep["declared_recovery_rounds"]
    assert rep["wire_rounds"] == rep["algo_rounds"] + rep["recovery_rounds"]
    assert rep["total_bits"] == rep["clean_bits"] + rep["retransmit_bits"]
    assert rep["retransmissions"] > 0


# --------------------------------------------------------------------------
# Crash recovery
# --------------------------------------------------------------------------

def test_crash_recovery_replays_to_identical_state():
    crash = "inject:seed=1,crash=8,snap=3"
    res_c = api.plan(_spec(crash, engine="python")).execute()
    res_0 = api.plan(_spec("none", engine="python")).execute()
    assert np.array_equal(np.asarray(res_c.w), np.asarray(res_0.w))
    led = res_c.ledger
    assert led.recovery_rounds == 2   # snapshot at 6, replay 7..8
    assert led.algo_rounds == 12 and led.rounds == 14
    # the replayed rounds are priced as retransmission traffic
    assert led.retransmit_bits() > 0
    assert led.clean_bits() == res_0.ledger.total_bits()


def test_round_snapshotter_roundtrip_is_bit_exact():
    from repro.checkpoint import RoundSnapshotter
    rng = np.random.default_rng(1)
    tree = [rng.normal(size=(5, 3)).astype(np.float32),
            rng.normal(size=7).astype(np.float32)]
    with RoundSnapshotter() as snap:
        snap.save(4, tree)
        back = snap.restore(4, like=tree)
    for a, b in zip(tree, back):
        assert np.array_equal(np.asarray(a), np.asarray(b))


# --------------------------------------------------------------------------
# Plan-time validation
# --------------------------------------------------------------------------

def test_plan_rejects_faults_on_sharded_placement():
    with pytest.raises(api.PlanError, match="fault injection"):
        api.plan(_spec("inject:seed=1,drop=0.1", placement="sharded"))


def test_spec_roundtrip_carries_faults():
    s = _spec(CHAOS)
    assert api.RunSpec.from_json(s.to_json()).faults == CHAOS
    pl = api.plan(s)
    assert pl.faults == CHAOS
    res = pl.execute()
    assert res.faults == CHAOS
