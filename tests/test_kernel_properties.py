"""Property-based kernel-vs-reference parity for the feature kernels.

Sweeps ragged shapes (deliberately not multiples of the 128-lane MXU
tile), dtypes (f32 / bf16), and RHS batch widths including B > 128 (which
exercises the batch-axis grid tiling) through ``feature_matvec`` /
``feature_rmatvec`` / ``feature_hvp`` against the pure-jnp oracles in
``kernels/ref.py``. Uses hypothesis when installed; otherwise the
deterministic fallback shim in ``tests/_hypothesis_fallback.py`` replays
a fixed spread of examples (range endpoints + seeded fills), so CI runs
are reproducible either way.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

def _tol(dtype, k):
    """Tolerance for a length-k contraction: bf16 inputs carry ~2^-8
    relative noise per term, so absolute error grows like sqrt(k)."""
    if dtype == jnp.bfloat16:
        return dict(atol=6e-3 * max(1.0, k) ** 0.5, rtol=3e-2)
    return dict(atol=2e-4, rtol=2e-4)

# endpoints sit on ragged, off-tile sizes on purpose
N_RANGE = (3, 290)
D_RANGE = (2, 261)
BATCHES = (1, 2, 130)          # 130 > BLOCK_B exercises the batch grid


def _mats(n, d, b, dtype, seed):
    ka, kb, kh = jax.random.split(jax.random.PRNGKey(seed), 3)
    A = jax.random.normal(ka, (n, d)).astype(dtype)
    rhs_d = jax.random.normal(kb, (d, b)).astype(dtype)
    rhs_n = jax.random.normal(kb, (n, b)).astype(dtype)
    # h plays l''(z): positive and O(1), like a GLM curvature
    h = jax.nn.sigmoid(jax.random.normal(kh, (n,))).astype(dtype)
    if b == 1:
        rhs_d, rhs_n = rhs_d[:, 0], rhs_n[:, 0]
    return A, rhs_d, rhs_n, h


def _check(got, want, dtype, contraction):
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               **_tol(dtype, contraction))


@given(n=st.integers(*N_RANGE), d=st.integers(*D_RANGE),
       b=st.sampled_from(BATCHES),
       dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
       seed=st.integers(0, 99))
@settings(max_examples=6, deadline=None)
def test_feature_matvec_property(n, d, b, dtype, seed):
    A, w, _, _ = _mats(n, d, b, dtype, seed)
    got = ops.feature_matvec(A, w)
    want = ref.feature_matvec_ref(A, w) if b == 1 else A @ w
    assert got.shape == want.shape and got.dtype == A.dtype
    _check(got, want, dtype, contraction=d)


@given(n=st.integers(*N_RANGE), d=st.integers(*D_RANGE),
       b=st.sampled_from(BATCHES),
       dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
       seed=st.integers(0, 99))
@settings(max_examples=6, deadline=None)
def test_feature_rmatvec_property(n, d, b, dtype, seed):
    A, _, r, _ = _mats(n, d, b, dtype, seed)
    got = ops.feature_rmatvec(A, r)
    want = ref.feature_rmatvec_ref(A, r) if b == 1 else A.T @ r
    assert got.shape == want.shape and got.dtype == A.dtype
    _check(got, want, dtype, contraction=n)


@given(n=st.integers(*N_RANGE), d=st.integers(*D_RANGE),
       b=st.sampled_from(BATCHES),
       dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
       seed=st.integers(0, 99))
@settings(max_examples=6, deadline=None)
def test_feature_hvp_property(n, d, b, dtype, seed):
    A, _, av, h = _mats(n, d, b, dtype, seed)
    got = ops.feature_hvp(A, h, av)
    want = ref.feature_hvp_ref(A, h, av)
    assert got.shape == want.shape and got.dtype == A.dtype
    _check(got, want, dtype, contraction=n)
    # escape hatch returns the oracle itself
    np.testing.assert_allclose(
        np.asarray(ops.feature_hvp(A, h, av, use_kernel=False), np.float32),
        np.asarray(want, np.float32), atol=1e-5, rtol=1e-5)


def test_hvp_is_fused_rmatvec():
    """feature_hvp(A, h, av) == feature_rmatvec(A, h * av): the fusion
    must not change the math, only where the Hadamard happens."""
    k = jax.random.PRNGKey(0)
    A = jax.random.normal(k, (130, 67))
    h = jax.random.normal(jax.random.PRNGKey(1), (130,)) ** 2
    av = jax.random.normal(jax.random.PRNGKey(2), (130, 5))
    got = ops.feature_hvp(A, h, av)
    want = ops.feature_rmatvec(A, h[:, None] * av)
    np.testing.assert_allclose(got, want, atol=2e-4, rtol=2e-4)


def test_wide_batch_tiling_matches_column_slices():
    """B > BLOCK_B: each 128-wide batch tile must reproduce the per-column
    GEMV (regression for the formerly unused bb tiling)."""
    k = jax.random.PRNGKey(3)
    n, d, B = 96, 70, 200
    A = jax.random.normal(k, (n, d))
    W = jax.random.normal(jax.random.PRNGKey(4), (d, B))
    R = jax.random.normal(jax.random.PRNGKey(5), (n, B))
    zs = ops.feature_matvec(A, W)
    gs = ops.feature_rmatvec(A, R)
    assert zs.shape == (n, B) and gs.shape == (d, B)
    for i in (0, 127, 128, B - 1):    # straddle the batch-block boundary
        np.testing.assert_allclose(zs[:, i], A @ W[:, i],
                                   atol=2e-4, rtol=2e-4)
        np.testing.assert_allclose(gs[:, i], A.T @ R[:, i],
                                   atol=2e-4, rtol=2e-4)


@given(n=st.integers(*N_RANGE), d=st.integers(*D_RANGE),
       b=st.sampled_from(BATCHES),
       dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
       seed=st.integers(0, 99))
@settings(max_examples=6, deadline=None)
def test_fused_pgrad_property(n, d, b, dtype, seed):
    """fused_pgrad == (A^T r / n + lam w) * mask: one accumulation pass
    with the gradient epilogue applied on the last grid step, across
    ragged shapes, bf16, and B > BLOCK_B."""
    from repro.kernels.fused_round import fused_pgrad
    A, w, r, _ = _mats(n, d, b, dtype, seed)
    lam = 0.03
    mask = (jnp.arange(d) % 5 != 3).astype(jnp.float32)
    got = fused_pgrad(A, r, w, mask, n=n, lam=lam)
    rf, wf = [np.asarray(x, np.float32) for x in (r, w)]
    want = (np.asarray(A, np.float32).T @ rf / n + lam * wf) \
        * (np.asarray(mask)[:, None] if b > 1 else np.asarray(mask))
    assert got.shape == want.shape
    _check(got, want, dtype, contraction=n)


@given(n=st.integers(*N_RANGE), d=st.integers(*D_RANGE),
       b=st.sampled_from(BATCHES),
       dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
       seed=st.integers(0, 99))
@settings(max_examples=6, deadline=None)
def test_fused_phvp_property(n, d, b, dtype, seed):
    """fused_phvp == (A^T (h . av) / n + lam v) * mask: the Hadamard,
    the contraction, and the HVP epilogue in a single pass."""
    from repro.kernels.fused_round import fused_phvp
    A, v, av, h = _mats(n, d, b, dtype, seed)
    lam = 0.03
    mask = (jnp.arange(d) % 7 != 2).astype(jnp.float32)
    got = fused_phvp(A, h, av, v, mask, n=n, lam=lam)
    hf = np.asarray(h, np.float32)
    avf, vf = [np.asarray(x, np.float32) for x in (av, v)]
    had = hf[:, None] * avf if b > 1 else hf * avf
    want = (np.asarray(A, np.float32).T @ had / n + lam * vf) \
        * (np.asarray(mask)[:, None] if b > 1 else np.asarray(mask))
    assert got.shape == want.shape
    _check(got, want, dtype, contraction=n)


@pytest.mark.parametrize("block_b", [128, 256])
def test_explicit_batch_block_override(block_b):
    """block_b is a real tiling knob: any legal setting is exact."""
    from repro.kernels.feature_matvec import feature_matvec, feature_hvp
    k = jax.random.PRNGKey(6)
    A = jax.random.normal(k, (64, 48))
    W = jax.random.normal(jax.random.PRNGKey(7), (48, 300))
    got = feature_matvec(A, W, block_b=block_b)
    np.testing.assert_allclose(got, A @ W, atol=2e-4, rtol=2e-4)
    h = jax.random.normal(jax.random.PRNGKey(8), (64,)) ** 2
    R = jax.random.normal(jax.random.PRNGKey(9), (64, 300))
    got = feature_hvp(A, h, R, block_b=block_b)
    np.testing.assert_allclose(got, A.T @ (h[:, None] * R),
                               atol=2e-4, rtol=2e-4)
