"""Algorithm-family behaviour: convergence, rates, communication budget."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import (ChainInstance, CommLedger, ERMProblem,
                        make_random_erm, squared_loss,
                        thm2_strongly_convex)
from repro.core.partition import even_partition
from repro.core.runtime import LocalDistERM
from repro.core.algorithms import ALGORITHMS, bcd, dagd, dgd, disco_f, dsvrg


def _chain_erm(d=48, kappa=64.0, lam=0.5):
    ci = ChainInstance(d=d, kappa=kappa, lam=lam)
    B, y, lam_ = ci.as_erm_data()
    n = B.shape[0]
    # scale so the 1/n in the ERM cancels (f matches the chain function)
    prob = ERMProblem(A=jnp.asarray(B) * np.sqrt(n),
                      y=jnp.asarray(y) * np.sqrt(n),
                      loss=squared_loss(), lam=lam_)
    return ci, prob


@pytest.fixture(scope="module")
def chain_setup():
    ci, prob = _chain_erm()
    part = even_partition(prob.d, 4)
    fstar = float(prob.value(jnp.asarray(ci.w_star())))
    L = prob.smoothness_bound()
    return ci, prob, part, fstar, L


@pytest.mark.parametrize("name", ["dgd", "dagd", "bcd", "disco_f"])
def test_converges(chain_setup, name):
    ci, prob, part, fstar, L = chain_setup
    dist = LocalDistERM(prob, part)
    kw = {}
    algo = ALGORITHMS[name]
    if name == "bcd":
        block_L = jnp.asarray(
            [[float(jnp.linalg.norm(Aj, 2)) ** 2 / prob.n + prob.lam]
             for Aj in part.split_columns(prob.A)])
        w = algo(dist, rounds=2000, block_L=block_L, m=part.m)
    else:
        w = algo(dist, rounds=400, L=L, lam=prob.lam)
    gap = float(prob.value(dist.gather_w(w))) - fstar
    assert gap < 1e-4, f"{name}: gap {gap}"


def test_dagd_beats_dgd_at_high_kappa():
    ci, prob = _chain_erm(d=96, kappa=1024.0, lam=0.1)
    part = even_partition(prob.d, 4)
    fstar = float(prob.value(jnp.asarray(ci.w_star())))
    L = prob.smoothness_bound()
    gaps = {}
    for name, algo in [("dgd", dgd), ("dagd", dagd)]:
        dist = LocalDistERM(prob, part)
        w = algo(dist, rounds=120, L=L, lam=prob.lam)
        gaps[name] = float(prob.value(dist.gather_w(w))) - fstar
    assert gaps["dagd"] < 0.01 * gaps["dgd"], gaps


def test_round_accounting_and_budget(chain_setup):
    ci, prob, part, fstar, L = chain_setup
    dist = LocalDistERM(prob, part)
    dagd(dist, rounds=50, L=L, lam=prob.lam)
    led = dist.comm.ledger
    assert led.rounds == 50
    # DAGD: exactly one R^n ReduceAll per round
    assert led.op_counts() == {"reduce_all": 50}
    led.assert_budget(n=prob.n, d=prob.d)  # paper's O(n+d)/round budget


def test_disco_f_budget(chain_setup):
    ci, prob, part, fstar, L = chain_setup
    dist = LocalDistERM(prob, part)
    disco_f(dist, rounds=30, L=L, lam=prob.lam)
    dist.comm.ledger.assert_budget(n=prob.n, d=prob.d)


def test_dagd_rounds_track_lower_bound():
    """Tightness: DAGD's measured rounds-to-eps exceed the Thm-2 lower
    bound but only by a constant factor (<= ~8x across kappa)."""
    for kappa in [16.0, 64.0, 256.0]:
        ci, prob = _chain_erm(d=120, kappa=kappa, lam=0.2)
        part = even_partition(prob.d, 4)
        fstar = float(prob.value(jnp.asarray(ci.w_star())))
        L = prob.smoothness_bound()
        eps = 1e-5
        dist = LocalDistERM(prob, part)
        _, aux = dagd(dist, rounds=600, L=L, lam=prob.lam, history=True)
        rounds_needed = None
        for k, w in enumerate(aux["iterates"]):
            if float(prob.value(dist.gather_w(w))) - fstar <= eps:
                rounds_needed = k + 1
                break
        assert rounds_needed is not None, f"kappa={kappa} never converged"
        wstar = ci.w_star()
        lb = thm2_strongly_convex(kappa, prob.lam,
                                  float(jnp.linalg.norm(wstar)), eps).rounds
        assert rounds_needed >= lb * 0.9, (kappa, rounds_needed, lb)
        assert rounds_needed <= max(8.0 * lb, lb + 40), \
            (kappa, rounds_needed, lb)


def test_dsvrg_converges():
    prob = make_random_erm(n=24, d=16, loss="squared", lam=0.1, seed=0)
    part = even_partition(16, 4)
    dist = LocalDistERM(prob, part)
    row_norms = jnp.sum(prob.A ** 2, axis=1)
    L_max = float(jnp.max(row_norms)) + prob.lam
    w = dsvrg(dist, rounds=3000, L_max=L_max, lam=prob.lam, seed=1)
    wg = dist.gather_w(w)
    H = prob.A.T @ prob.A / prob.n + prob.lam * jnp.eye(16)
    wstar = jnp.linalg.solve(H, prob.A.T @ prob.y / prob.n)
    gap = float(prob.value(wg)) - float(prob.value(wstar))
    assert gap < 1e-3, gap
    # each stochastic step was one (cheap) round
    assert dist.comm.ledger.rounds == 3000


def test_incremental_rounds_exceed_thm4_bound():
    """DSVRG round count >= the Theorem-4 lower bound at matched eps."""
    from repro.core.bounds import thm4_incremental
    prob = make_random_erm(n=16, d=12, loss="squared", lam=0.5, seed=2)
    part = even_partition(12, 3)
    H = prob.A.T @ prob.A / prob.n + prob.lam * jnp.eye(12)
    wstar = jnp.linalg.solve(H, prob.A.T @ prob.y / prob.n)
    fstar = float(prob.value(wstar))
    L = prob.smoothness_bound()
    kappa = L / prob.lam
    eps = 1e-6
    row_norms = jnp.sum(prob.A ** 2, axis=1)
    L_max = float(jnp.max(row_norms)) + prob.lam
    dist = LocalDistERM(prob, part)
    w, aux = dsvrg(dist, rounds=5000, L_max=L_max, lam=prob.lam,
                   history=True, seed=3)
    rounds_needed = None
    for k, wk in enumerate(aux["iterates"]):
        if float(prob.value(dist.gather_w(wk))) - fstar <= eps:
            rounds_needed = k + 1
            break
    lb = thm4_incremental(prob.n, kappa, prob.lam,
                          float(jnp.linalg.norm(wstar)), eps).rounds
    if rounds_needed is not None:
        assert rounds_needed >= 0.5 * lb, (rounds_needed, lb)
