"""Property tests (hypothesis) for layer-level invariants."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.layers import (layernorm, rmsnorm, rope, init_rmsnorm,
                                 init_layernorm)
from repro.models.common import unbox


@given(seed=st.integers(0, 50), scale=st.floats(0.1, 10.0))
@settings(max_examples=20, deadline=None)
def test_rmsnorm_scale_invariance(seed, scale):
    """rmsnorm(c*x) == rmsnorm(x) for any c > 0."""
    p, _ = unbox(init_rmsnorm(32, jnp.float32))
    x = jax.random.normal(jax.random.PRNGKey(seed), (2, 4, 32))
    np.testing.assert_allclose(rmsnorm(p, x * scale), rmsnorm(p, x),
                               atol=1e-4, rtol=1e-3)


@given(seed=st.integers(0, 50), shift=st.floats(-5.0, 5.0))
@settings(max_examples=20, deadline=None)
def test_layernorm_shift_invariance(seed, shift):
    """layernorm(x + c) == layernorm(x) for any constant c."""
    p, _ = unbox(init_layernorm(32, jnp.float32))
    x = jax.random.normal(jax.random.PRNGKey(seed), (2, 4, 32))
    np.testing.assert_allclose(layernorm(p, x + shift), layernorm(p, x),
                               atol=1e-4, rtol=1e-3)


@given(seed=st.integers(0, 30), offset=st.integers(0, 100))
@settings(max_examples=15, deadline=None)
def test_rope_relative_position_property(seed, offset):
    """RoPE inner products depend only on RELATIVE position:
    <rope(q, i), rope(k, j)> == <rope(q, i+c), rope(k, j+c)>."""
    dh = 32
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    q = jax.random.normal(ks[0], (1, 1, 1, dh))
    k = jax.random.normal(ks[1], (1, 1, 1, dh))
    pos_q = jnp.array([[3]])
    pos_k = jnp.array([[11]])
    dot1 = jnp.vdot(rope(q, pos_q), rope(k, pos_k))
    dot2 = jnp.vdot(rope(q, pos_q + offset), rope(k, pos_k + offset))
    np.testing.assert_allclose(dot1, dot2, atol=1e-3, rtol=1e-3)


@given(seed=st.integers(0, 30))
@settings(max_examples=10, deadline=None)
def test_rope_norm_preservation(seed):
    """RoPE is a rotation: it preserves vector norms."""
    x = jax.random.normal(jax.random.PRNGKey(seed), (1, 5, 2, 64))
    pos = jnp.arange(5)[None, :]
    y = rope(x, pos)
    np.testing.assert_allclose(jnp.linalg.norm(y, axis=-1),
                               jnp.linalg.norm(x, axis=-1),
                               atol=1e-4, rtol=1e-4)


@given(m=st.integers(2, 6), kappa=st.floats(4.0, 256.0),
       seed=st.integers(0, 20))
@settings(max_examples=10, deadline=None)
def test_algorithm_budget_property(m, kappa, seed):
    """EVERY family algorithm respects the paper's O(n+d)/round budget,
    for random machine counts and condition numbers."""
    from repro.core import ChainInstance, ERMProblem, squared_loss
    from repro.core.partition import even_partition
    from repro.core.runtime import LocalDistERM
    from repro.core.algorithms import dagd, disco_f
    ci = ChainInstance(d=24, kappa=kappa, lam=0.5)
    B, y, lam = ci.as_erm_data()
    n = B.shape[0]
    prob = ERMProblem(A=jnp.asarray(B) * np.sqrt(n),
                      y=jnp.asarray(y) * np.sqrt(n),
                      loss=squared_loss(), lam=lam)
    L = prob.smoothness_bound()
    for algo in (dagd, disco_f):
        dist = LocalDistERM(prob, even_partition(prob.d, m))
        algo(dist, rounds=10, L=L, lam=lam)
        dist.comm.ledger.assert_budget(n=prob.n, d=prob.d)
