import os
import sys

# Tests run on the default single CPU device (the dry-run sets its own
# 512-device flag in its own process; see test_dryrun_smoke.py).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Property tests use hypothesis when available; otherwise install the
# deterministic fallback so the suite still collects and covers a fixed
# spread of examples (see tests/_hypothesis_fallback.py).
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    sys.path.insert(0, os.path.dirname(__file__))
    import _hypothesis_fallback

    sys.modules["hypothesis"] = _hypothesis_fallback
    sys.modules["hypothesis.strategies"] = _hypothesis_fallback.strategies
