"""Attention: chunking invariance, masks, decode/train consistency."""
import numpy as np
import dataclasses
import jax
import jax.numpy as jnp
import pytest

from repro.models.layers import (AttnConfig, attention_decode,
                                 attention_train, init_attention,
                                 init_attn_cache)
from repro.models.common import unbox


def _cfg(**kw):
    base = dict(d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
                q_chunk=16)
    base.update(kw)
    return AttnConfig(**base)


def _params(cfg, key=0):
    p, _ = unbox(init_attention(jax.random.PRNGKey(key), cfg, jnp.float32))
    return p


def test_chunking_invariance():
    """q_chunk must not change the result."""
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 64))
    outs = []
    for chunk in (8, 16, 64):
        cfg = _cfg(q_chunk=chunk)
        outs.append(attention_train(_params(cfg), x, cfg))
    np.testing.assert_allclose(outs[0], outs[1], atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(outs[0], outs[2], atol=1e-4, rtol=1e-4)


def test_causality():
    """Changing future tokens must not change past outputs."""
    cfg = _cfg()
    p = _params(cfg)
    x1 = jax.random.normal(jax.random.PRNGKey(2), (1, 32, 64))
    x2 = x1.at[:, 20:, :].set(jax.random.normal(jax.random.PRNGKey(3),
                                                (1, 12, 64)))
    y1 = attention_train(p, x1, cfg)
    y2 = attention_train(p, x2, cfg)
    np.testing.assert_allclose(y1[:, :20], y2[:, :20], atol=1e-4)
    assert not np.allclose(y1[:, 20:], y2[:, 20:])


def test_sliding_window_matches_masked_full():
    """Windowed attention == full attention with an explicit band mask."""
    cfg_w = _cfg(window=8, q_chunk=16)
    p = _params(cfg_w)
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 48, 64))
    y_win = attention_train(p, x, cfg_w)

    # reference: full attention with band mask, computed manually
    cfg_full = _cfg(window=8, q_chunk=48)
    y_full = attention_train(p, x, cfg_full)
    np.testing.assert_allclose(y_win, y_full, atol=1e-4, rtol=1e-4)


def test_prefix_lm_bidirectional_prefix():
    """With prefix_len=P, prefix positions see each other (non-causal)."""
    cfg = _cfg(q_chunk=32)
    p = _params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(5), (1, 32, 64))
    y_causal = attention_train(p, x, cfg)
    y_prefix = attention_train(p, x, cfg, prefix_len=8)
    # positions 0..6 now attend to position 7 too -> outputs change
    assert not np.allclose(y_causal[:, :8], y_prefix[:, :8])
    # suffix positions behave identically (their mask row is unchanged)
    np.testing.assert_allclose(y_causal[:, 8:], y_prefix[:, 8:], atol=1e-4)


def test_decode_matches_train():
    """Greedy decode step-by-step == teacher-forced forward."""
    cfg = _cfg(q_chunk=64)
    p = _params(cfg)
    S = 12
    x = jax.random.normal(jax.random.PRNGKey(6), (2, S, 64))
    y_train = attention_train(p, x, cfg)
    cache = init_attn_cache(2, cfg, max_seq=S, dtype=jnp.float32)
    outs = []
    for t in range(S):
        y_t, cache = attention_decode(p, x[:, t:t + 1], cfg, cache)
        outs.append(y_t)
    y_decode = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(y_train, y_decode, atol=1e-3, rtol=1e-3)


def test_decode_ring_buffer_matches_window():
    """Windowed decode with a ring cache == windowed train forward."""
    cfg = _cfg(window=6, q_chunk=64)
    p = _params(cfg)
    S = 20
    x = jax.random.normal(jax.random.PRNGKey(7), (1, S, 64))
    y_train = attention_train(p, x, cfg)
    cache = init_attn_cache(1, cfg, max_seq=S, dtype=jnp.float32)
    assert cache["k"].shape[1] == 6  # ring sized to the window
    outs = []
    for t in range(S):
        y_t, cache = attention_decode(p, x[:, t:t + 1], cfg, cache)
        outs.append(y_t)
    y_decode = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(y_train, y_decode, atol=1e-3, rtol=1e-3)


def test_gqa_grouping():
    """n_kv_heads < n_heads shares K/V across query groups; with identical
    K/V rows the output must equal MHA with duplicated kv."""
    cfg = _cfg(n_heads=4, n_kv_heads=1)
    p = _params(cfg)
    x = jax.random.normal(jax.random.PRNGKey(8), (1, 16, 64))
    y = attention_train(p, x, cfg)
    assert y.shape == (1, 16, 64)
    assert jnp.all(jnp.isfinite(y))
