"""Per-architecture smoke tests (reduced same-family configs, CPU).

For each of the 10 assigned archs: one forward/train step asserting
output shapes + finite values, and one decode step against a cache.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import canonical_ids, get
from repro.models.common import unbox
from repro.models import transformer as T
from repro.models import encdec as E
from repro.launch.steps import is_encdec, make_serve_step, make_train_step
from repro.optim import adamw_init

ARCHS = canonical_ids()
B, S = 2, 64


def _lm_batch(cfg, key):
    n_prefix = cfg.n_prefix if cfg.prefix_lm else 0
    tokens = jax.random.randint(key, (B, S - n_prefix), 0, cfg.vocab)
    batch = {"tokens": tokens, "labels": jnp.zeros_like(tokens)}
    if n_prefix:
        batch["prefix_embeds"] = jax.random.normal(
            key, (B, n_prefix, cfg.d_model)).astype(cfg.dtype)
    return batch


def _encdec_batch(cfg, key):
    tokens = jax.random.randint(key, (B, 32), 0, cfg.vocab)
    return {
        "frames": jax.random.normal(
            key, (B, cfg.n_frames, cfg.d_model)).astype(cfg.dtype),
        "tokens": tokens, "labels": jnp.zeros_like(tokens),
    }


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    mod = get(arch)
    cfg = mod.smoke()
    key = jax.random.PRNGKey(0)
    if is_encdec(cfg):
        params, _ = unbox(E.init_params(key, cfg))
        batch = _encdec_batch(cfg, key)
    else:
        params, _ = unbox(T.init_params(key, cfg))
        batch = _lm_batch(cfg, key)
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg))
    new_params, new_opt, metrics = step(params, opt, batch)
    assert jnp.isfinite(metrics["loss"]), arch
    assert jnp.isfinite(metrics["grad_norm"]), arch
    # params actually changed
    leaves_old = jax.tree_util.tree_leaves(params)
    leaves_new = jax.tree_util.tree_leaves(new_params)
    assert any(
        not jnp.array_equal(a, b) for a, b in zip(leaves_old, leaves_new))
    # no NaNs anywhere in the updated tree
    assert all(jnp.all(jnp.isfinite(l.astype(jnp.float32)))
               for l in leaves_new if l.dtype != jnp.int32)


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch):
    mod = get(arch)
    cfg = mod.smoke()
    key = jax.random.PRNGKey(1)
    if is_encdec(cfg):
        params, _ = unbox(E.init_params(key, cfg))
        cache = E.init_cache(cfg, B, 32)
    else:
        params, _ = unbox(T.init_params(key, cfg))
        cache = T.init_cache(cfg, B, 32)
    serve = jax.jit(make_serve_step(cfg))
    tok = jnp.zeros((B, 1), jnp.int32)
    for _ in range(3):
        tok, cache = serve(params, tok, cache)
    assert tok.shape == (B, 1)
    assert jnp.all((tok >= 0) & (tok < cfg.vocab))


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_matches_assignment(arch):
    """The full() configs carry the exact assigned hyperparameters."""
    spec = {
        "granite-moe-1b-a400m": dict(L=24, d=1024, H=16, kv=8, V=49155),
        "whisper-large-v3": dict(L=32, d=1280, H=20, kv=20, V=51866),
        "jamba-1-5-large-398b": dict(L=72, d=8192, H=64, kv=8, V=65536),
        "mamba2-780m": dict(L=48, d=1536, V=50280),
        "qwen1-5-32b": dict(L=64, d=5120, H=40, kv=40, V=152064),
        "stablelm-12b": dict(L=40, d=5120, H=32, kv=8, V=100352),
        "paligemma-3b": dict(L=18, d=2048, H=8, kv=1, V=257216),
        "gemma3-27b": dict(L=62, d=5376, H=32, kv=16, V=262144),
        "starcoder2-15b": dict(L=40, d=6144, H=48, kv=4, V=49152),
        "llama4-maverick-400b-a17b": dict(L=48, d=5120, H=40, kv=8,
                                          V=202048),
    }[arch]
    cfg = get(arch).full()
    assert cfg.vocab == spec["V"]
    assert cfg.d_model == spec["d"]
    if is_encdec(cfg):
        assert cfg.n_enc_layers == spec["L"]
        assert cfg.n_dec_layers == spec["L"]
        assert cfg.attn.n_heads == spec["H"]
    else:
        assert cfg.n_layers == spec["L"]
        if "H" in spec:
            assert cfg.attn.n_heads == spec["H"]
            assert cfg.attn.n_kv_heads == spec["kv"]
    assert cfg.citation


def test_moe_expert_counts():
    assert get("granite-moe-1b-a400m").full().moe.n_experts == 32
    assert get("granite-moe-1b-a400m").full().moe.top_k == 8
    assert get("jamba-1.5-large-398b").full().moe.n_experts == 16
    assert get("jamba-1.5-large-398b").full().moe.top_k == 2
    assert get("llama4-maverick-400b-a17b").full().moe.n_experts == 128
    assert get("llama4-maverick-400b-a17b").full().moe.top_k == 1


def test_pattern_structure():
    jamba = get("jamba-1.5-large-398b").full()
    assert len(jamba.pattern) == 8
    kinds = [s.kind for s in jamba.pattern]
    assert kinds.count("attn") == 1 and kinds.count("mamba") == 7
    gem = get("gemma3-27b").full()
    assert len(gem.pattern) == 6
    wins = [s.window for s in gem.pattern]
    assert wins.count(None) == 1  # 5 local : 1 global
    assert gem.repeats == 10 and len(gem.remainder) == 2
