"""Substrate: optimizer, schedules, data pipeline, checkpointing."""
import os
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.optim import (OptConfig, adamw_init, adamw_update,
                         cosine_schedule, linear_warmup)
from repro.data import TokenDataConfig, frame_stub, patch_stub, \
    synthetic_lm_batches
from repro.checkpoint import latest_step, restore_checkpoint, \
    save_checkpoint


def test_adamw_minimizes_quadratic():
    params = {"w": jnp.array([5.0, -3.0]), "b": jnp.array(2.0)}
    opt = adamw_init(params)
    cfg = OptConfig(lr=0.1, weight_decay=0.0, grad_clip=0.0)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + p["b"] ** 2

    for _ in range(200):
        grads = jax.grad(loss)(params)
        params, opt, gnorm = adamw_update(params, grads, opt, cfg)
    assert float(loss(params)) < 1e-3
    assert int(opt["step"]) == 200


def test_adamw_bf16_params_f32_moments():
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    opt = adamw_init(params)
    assert opt["m"]["w"].dtype == jnp.float32
    grads = {"w": jnp.ones((4,), jnp.bfloat16)}
    new_params, opt, _ = adamw_update(params, grads, opt, OptConfig())
    assert new_params["w"].dtype == jnp.bfloat16


def test_grad_clip():
    params = {"w": jnp.zeros((3,))}
    opt = adamw_init(params)
    big = {"w": jnp.full((3,), 1e6)}
    _, _, gnorm = adamw_update(params, big, opt,
                               OptConfig(grad_clip=1.0))
    assert float(gnorm) > 1e5  # reported raw norm


def test_schedules():
    assert float(linear_warmup(0, 10, 1.0)) == pytest.approx(0.1)
    assert float(linear_warmup(100, 10, 1.0)) == 1.0
    peak = cosine_schedule(10, 10, 110, 3e-4)
    end = cosine_schedule(110, 10, 110, 3e-4)
    assert float(peak) == pytest.approx(3e-4, rel=0.01)
    assert float(end) == pytest.approx(3e-5, rel=0.05)


def test_lm_pipeline_learnable_structure():
    cfg = TokenDataConfig(vocab=64, seq_len=32, batch=4, seed=0)
    it = synthetic_lm_batches(cfg)
    b1 = next(it)
    assert b1["tokens"].shape == (4, 32)
    assert b1["labels"].shape == (4, 32)
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])
    # bigram structure: successor repeats far above chance
    toks = np.asarray(b1["tokens"]).reshape(-1)
    labs = np.asarray(b1["labels"]).reshape(-1)
    # can't know succ table here; just check determinism across seeds
    b2 = next(synthetic_lm_batches(cfg))
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])


def test_stubs():
    f = frame_stub(2, 10, 16)
    p = patch_stub(3, 4, 8)
    assert f.shape == (2, 10, 16) and p.shape == (3, 4, 8)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "nested": {"b": jnp.ones((4,), jnp.bfloat16)}}
    d = str(tmp_path)
    save_checkpoint(d, 7, tree)
    assert latest_step(d) == 7
    like = jax.tree_util.tree_map(jnp.zeros_like, tree)
    restored = restore_checkpoint(d, 7, like)
    np.testing.assert_array_equal(restored["a"], tree["a"])
    assert restored["nested"]["b"].dtype == jnp.bfloat16
