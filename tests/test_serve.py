"""Certification service: unit tests + deterministic load/soak.

Everything runs on the injected clock — the service never reads wall
time — so the soak trace produces the identical batch sequence, cache
counters, and envelope stream on every run (CI replays it three times
back-to-back to enforce exactly that).
"""
import numpy as np
import pytest

from repro import api
from repro.serve import (
    Arrival, CertificationService, CoalescingScheduler, ProgramCache,
    QuarantinedError, QueueFullError, SpecError, SubmissionQueue,
    replay_trace, spec_pool, synthetic_trace,
)
from repro.serve.queue import PendingRun


SMALL = dict(instance="thm2_chain",
             instance_params=dict(d=6, kappa=8.0, lam=0.5, m=2),
             algorithm="dagd", rounds=5, eps=[1e-1])


def _fake_run(key, t=0.0, seq=0, client="c"):
    class _Cell:
        def group_key(self):
            return key
    return PendingRun(ticket=f"f{seq}", client_id=client, seq=seq,
                      spec=None, plan=None,
                      cell=None if key is None else _Cell(), arrival=t)


# --------------------------------------------------------------------------
# Scheduler
# --------------------------------------------------------------------------

def test_scheduler_count_flush_releases_full_batches():
    sched = CoalescingScheduler(max_batch=8, max_wait=10.0)
    for i in range(17):
        sched.add(_fake_run(("k",), t=0.0, seq=i))
    batches = sched.due(0.0)
    assert [b.width for b in batches] == [8, 8]
    # members in arrival order
    assert [r.seq for r in batches[0].runs] == list(range(8))
    assert [r.seq for r in batches[1].runs] == list(range(8, 16))
    assert sched.pending == 1
    # the straggler waits for its deadline...
    assert sched.due(5.0) == []
    # ...and is released once its wait exceeds max_wait
    (tail,) = sched.due(10.0)
    assert tail.width == 1 and tail.runs[0].seq == 16
    assert sched.pending == 0


def test_scheduler_deadline_and_flush():
    sched = CoalescingScheduler(max_batch=8, max_wait=0.25)
    for i in range(3):
        sched.add(_fake_run(("k",), t=0.0, seq=i))
    assert sched.due(0.2) == []
    (b,) = sched.due(0.25)
    assert b.width == 3 and b.grouped
    # flush releases partial groups regardless of age
    sched.add(_fake_run(("k",), t=1.0, seq=9))
    (b,) = sched.due(1.0, flush=True)
    assert b.width == 1


def test_scheduler_sequential_runs_bypass_the_pool():
    sched = CoalescingScheduler(max_batch=8, max_wait=10.0)
    sched.add(_fake_run(None, t=0.0, seq=0))
    sched.add(_fake_run(("k",), t=0.0, seq=1))
    batches = sched.due(0.0)          # no flush, nothing due but the
    assert len(batches) == 1          # unbatchable singleton
    assert not batches[0].grouped and batches[0].width == 1


def test_scheduler_release_order_is_pool_insertion_order():
    sched = CoalescingScheduler(max_batch=8, max_wait=0.1)
    sched.add(_fake_run(("b",), t=0.0, seq=0))
    sched.add(_fake_run(("a",), t=0.0, seq=1))
    sched.add(_fake_run(("b",), t=0.0, seq=2))
    keys = [b.key for b in sched.due(1.0)]
    assert keys == [("b",), ("a",)]


# --------------------------------------------------------------------------
# Program cache
# --------------------------------------------------------------------------

def test_cache_hit_requires_key_and_width():
    cache = ProgramCache(capacity=4)
    e1, hit = cache.lookup(("k",), 8)
    assert not hit                    # new key
    _, hit = cache.lookup(("k",), 1)
    assert not hit                    # known key, new width: jit respecializes
    e2, hit = cache.lookup(("k",), 8)
    assert hit and e2 is e1           # same runners dict survives
    st = cache.stats()
    assert (st.hits, st.misses, st.executions) == (1, 2, 3)


def test_cache_lru_eviction():
    cache = ProgramCache(capacity=2)
    cache.lookup(("a",), 1)
    cache.lookup(("b",), 1)
    cache.lookup(("a",), 1)           # touch a: b is now LRU
    cache.lookup(("c",), 1)           # evicts b
    assert cache.stats().evictions == 1 and len(cache) == 2
    _, hit = cache.lookup(("a",), 1)
    assert hit
    _, hit = cache.lookup(("b",), 1)  # evicted: pays the compile again
    assert not hit


# --------------------------------------------------------------------------
# Admission queue
# --------------------------------------------------------------------------

def test_queue_rejects_before_any_compute():
    q = SubmissionQueue(max_depth=4)
    with pytest.raises(SpecError):
        q.admit("{not json")
    with pytest.raises(SpecError):
        q.admit(dict(SMALL, bogus=1))
    with pytest.raises(api.PlanError):
        q.admit(dict(SMALL, algorithm="bogus"))
    with pytest.raises(SpecError, match="resolution-only"):
        q.admit(dict(instance_params=dict(d=6, kappa=8.0, m=2),
                     rounds=5))
    assert (q.admitted, q.rejected, q.outstanding) == (0, 4, 0)


def test_queue_admission_control_and_client_seq():
    q = SubmissionQueue(max_depth=2)
    r0 = q.admit(SMALL, client_id="a", now=1.0)
    with pytest.raises(SpecError):
        q.admit("{", client_id="a")   # rejection must not burn a seq
    r1 = q.admit(SMALL, client_id="a", now=2.0)
    assert (r0.seq, r1.seq) == (0, 1)
    assert (r0.ticket, r1.ticket) == ("t000001", "t000002")
    assert r0.arrival == 1.0 and r0.cell is not None
    with pytest.raises(QueueFullError):
        q.admit(SMALL, client_id="b")
    q.complete()
    r2 = q.admit(SMALL, client_id="b")
    assert r2.seq == 0                # seq is per-client


# --------------------------------------------------------------------------
# Service: sequential fallback + rejection accounting
# --------------------------------------------------------------------------

def test_service_sequential_fallback_matches_direct_execution():
    svc = CertificationService(max_batch=8, max_wait=10.0)
    spec = api.RunSpec(**SMALL, engine="python")   # unbatchable
    svc.submit(spec, client_id="c", now=0.0)
    (env,) = svc.step(0.0)            # immediately due, no coalescing
    assert not env.batched and not env.cache_hit and env.width == 1
    assert svc.stats()["fallbacks"] == 1 and svc.stats()["batches"] == 0
    pl = api.plan(spec)
    ref = pl.execute()
    assert env.result.ledger.typed_stream() == ref.ledger.typed_stream()
    assert env.verdicts == [dict(
        eps=e, measured_rounds=ref.measured_rounds(pl.eps_abs(e)),
        bound_rounds=pl.bound(pl.eps_abs(e)).rounds,
        certified=pl.certify(ref, e)) for e in spec.eps]


# --------------------------------------------------------------------------
# The deterministic soak
# --------------------------------------------------------------------------

def _soak_trace():
    """192 dense arrivals (3 structures x 64, shuffled, 5 clients,
    1ms apart) + 9 stragglers spaced 1s apart.  With max_batch=8 and
    max_wait=0.25 the dense phase (0.191s span) can only count-flush:
    8 full width-8 batches per structure; every straggler deadline-
    flushes alone at width 1.  Expected cache ledger, exactly:

        dense:      per structure 1 miss + 7 hits   -> 3 miss, 21 hit
        stragglers: per structure 1 miss + 2 hits   -> 3 miss,  6 hit
        total:      33 executions, 6 misses, hit rate 27/33 ~ 0.818
    """
    pools = spec_pool()
    dense = synthetic_trace(n_per_structure=64, seed=7, dt=1e-3,
                            clients=5, pools=pools)
    stragglers = [Arrival(t=5.0 + k, client_id="lone",
                          spec=pools[k % 3][k % 4]) for k in range(9)]
    return pools, dense + stragglers


def test_soak_deterministic_trace():
    pools, trace = _soak_trace()
    svc = CertificationService(max_batch=8, max_wait=0.25,
                               cache_capacity=32)
    envs = replay_trace(svc, trace)

    # -- no spec lost, duplicated, or reordered within a client --------
    assert len(envs) == len(trace) == 201
    assert len({e.ticket for e in envs}) == 201
    submitted, served = {}, {}
    for a in trace:
        submitted.setdefault(a.client_id, []).append(a.spec)
    for e in envs:
        served.setdefault(e.client_id, []).append(e)
    for cid, stream in served.items():
        assert [e.seq for e in stream] == list(range(len(stream)))
        assert [e.spec for e in stream] == submitted[cid]

    # -- cache counters: exact, and above the published floor ----------
    st = svc.cache.stats()
    assert (st.executions, st.misses, st.hits) == (33, 6, 27)
    assert st.hit_rate >= 0.80
    assert st.evictions == 0 and st.size == 3
    stats = svc.stats()
    assert stats["fallbacks"] == 0 and stats["rejected"] == 0
    assert stats["completed"] == 201 and stats["pending"] == 0
    assert stats["batches"] == 33

    # -- every served result identical to direct execution -------------
    refs = {}
    for pool in pools:
        for spec in pool:
            pl = api.plan(spec)
            res = pl.execute()
            refs[spec.to_json()] = (pl, res)
    for e in envs:
        pl, ref = refs[e.spec.to_json()]
        assert e.result.ledger.typed_stream() == ref.ledger.typed_stream()
        assert e.result.ledger.total_bits() == ref.ledger.total_bits()
        assert e.result.ledger.rounds == ref.ledger.rounds
        assert e.verdicts == [dict(
            eps=eps, measured_rounds=ref.measured_rounds(pl.eps_abs(eps)),
            bound_rounds=pl.bound(pl.eps_abs(eps)).rounds,
            certified=pl.certify(ref, eps)) for eps in e.spec.eps]
        np.testing.assert_allclose(e.result.w, ref.w,
                                   rtol=1e-5, atol=1e-5)

    # -- replaying the same trace on a fresh service is bit-identical --
    svc2 = CertificationService(max_batch=8, max_wait=0.25,
                                cache_capacity=32)
    envs2 = replay_trace(svc2, trace)
    assert svc2.stats() == stats
    assert [(e.ticket, e.client_id, e.seq, e.width, e.cache_hit,
             e.batched) for e in envs2] == \
           [(e.ticket, e.client_id, e.seq, e.width, e.cache_hit,
             e.batched) for e in envs]
    for a, b in zip(envs, envs2):
        assert a.result.ledger.typed_stream() == \
            b.result.ledger.typed_stream()
        assert a.verdicts == b.verdicts


# --------------------------------------------------------------------------
# scheduled channels through the service
# --------------------------------------------------------------------------

SCHED_STRUCTURES = (
    ("dagd", "identity"),
    ("dagd", "sched:int8@0,fp16@10"),
    ("dagd", "sched:int8@0,fp16@20"),
    ("dgd", "sched:int8@0,fp16@10"),
)


def test_soak_mixed_scheduled_channels():
    """Mixed fixed/scheduled structures under load: the group key
    separates schedules (same algorithm, different switch round never
    pools), the cache ledger stays exact, and every envelope — the
    re-priced scheduled records included — is bit-identical to direct
    execution of its spec.

    64 dense arrivals (4 structures x 16, shuffled, 3 clients, 1ms
    apart): with max_batch=8 the dense phase can only count-flush, two
    width-8 batches per structure -> per structure 1 miss + 1 hit."""
    pools = spec_pool(structures=SCHED_STRUCTURES)
    trace = synthetic_trace(n_per_structure=16, seed=11, dt=1e-3,
                            clients=3, pools=pools)
    svc = CertificationService(max_batch=8, max_wait=0.25,
                               cache_capacity=16)
    envs = replay_trace(svc, trace)

    assert len(envs) == len(trace) == 64
    st = svc.cache.stats()
    assert (st.executions, st.misses, st.hits) == (8, 4, 4)
    assert st.evictions == 0 and st.size == 4
    stats = svc.stats()
    assert stats["fallbacks"] == 0 and stats["rejected"] == 0
    assert stats["completed"] == 64 and stats["batches"] == 8

    # four distinct group keys; the wire channel is the separating axis
    keys = {}
    for pool, (algo, channel) in zip(pools, SCHED_STRUCTURES):
        cell = api.prepare_cell(api.plan(pool[0]))
        assert cell is not None, (algo, channel)
        keys[(algo, channel)] = cell.group_key()
    assert len(set(keys.values())) == len(SCHED_STRUCTURES)
    assert keys[("dagd", "sched:int8@0,fp16@10")][2] == \
        "sched:int8@0,fp16@10"
    assert keys[("dagd", "sched:int8@0,fp16@20")][2] == \
        "sched:int8@0,fp16@20"

    # every envelope bit-identical to direct execution of its spec
    refs = {}
    for pool in pools:
        for spec in pool:
            pl = api.plan(spec)
            refs[spec.to_json()] = (pl, pl.execute())
    for e in envs:
        pl, ref = refs[e.spec.to_json()]
        assert e.result.ledger.typed_stream() == ref.ledger.typed_stream()
        assert e.result.ledger.round_marks == ref.ledger.round_marks
        assert e.result.ledger.total_bits() == ref.ledger.total_bits()
        assert e.verdicts == [dict(
            eps=eps, measured_rounds=ref.measured_rounds(pl.eps_abs(eps)),
            bound_rounds=pl.bound(pl.eps_abs(eps)).rounds,
            certified=pl.certify(ref, eps)) for eps in e.spec.eps]
        np.testing.assert_allclose(e.result.w, ref.w,
                                   rtol=1e-5, atol=1e-5)

# --------------------------------------------------------------------------
# Resilience: degradation ladder, retries, dead letters, quarantine
# --------------------------------------------------------------------------

def test_queue_full_error_carries_backpressure_hints():
    q = SubmissionQueue(max_depth=1, retry_after=0.25)
    q.admit(SMALL, client_id="a")
    with pytest.raises(QueueFullError) as ei:
        q.admit(SMALL, client_id="b")
    assert ei.value.depth == 1 and ei.value.retry_after == 0.25
    assert q.rejected_full == 1 and q.rejected == 1


def test_cache_circuit_breaker_trips_and_resets():
    cache = ProgramCache(capacity=4, breaker_threshold=2)
    key = ("k",)
    cache.lookup(key, 8)
    cache.record_failure(key)
    assert not cache.tripped(key) and cache.breaker_open == 0
    assert len(cache) == 0            # failed entry dropped
    cache.record_failure(key)
    assert cache.tripped(key) and cache.breaker_open == 1
    assert cache.stats().breaker_open == 1
    cache.record_success(key)
    assert not cache.tripped(key) and cache.breaker_open == 0


def test_group_failure_degrades_sequentially_without_loss(monkeypatch):
    """A grouped batch that raises mid-execution must produce one ok
    envelope per run via the sequential ladder — no ticket lost, no
    duplicates, ordering preserved."""
    orig = api.execute_group
    calls = dict(n=0)

    def chaotic(cells, runner_cache=None):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("chaos: injected mid-batch failure")
        return orig(cells, runner_cache=runner_cache)

    monkeypatch.setattr(api, "execute_group", chaotic)
    svc = CertificationService(max_batch=4, max_wait=10.0)
    for i in range(4):
        svc.submit(SMALL, client_id="c", now=0.0)
    envs = svc.step(0.0)              # count-flush at width 4
    assert len(envs) == 4
    assert [e.seq for e in envs] == [0, 1, 2, 3]
    assert all(e.status == "ok" for e in envs)
    assert len({e.ticket for e in envs}) == 4
    stats = svc.stats()
    assert stats["group_failures"] == 1 and stats["dead_letters"] == 0
    assert stats["completed"] == 4 and stats["pending"] == 0
    # the sequential re-runs are still bit-identical to direct execution
    ref = api.plan(api.RunSpec(**SMALL)).execute()
    for e in envs:
        assert e.result.ledger.typed_stream() == ref.ledger.typed_stream()


def test_breaker_routes_batches_around_the_grouped_path(monkeypatch):
    def always_fail(cells, runner_cache=None):
        raise RuntimeError("chaos: grouped path down")

    monkeypatch.setattr(api, "execute_group", always_fail)
    svc = CertificationService(max_batch=2, max_wait=10.0,
                               breaker_threshold=1)
    svc.submit(SMALL, now=0.0)
    svc.submit(SMALL, now=0.0)
    envs = svc.step(0.0)
    assert len(envs) == 2 and all(e.status == "ok" for e in envs)
    assert svc.stats()["group_failures"] == 1
    # breaker now open: the next batch skips execute_group entirely
    svc.submit(SMALL, now=1.0)
    svc.submit(SMALL, now=1.0)
    envs = svc.step(1.0)
    assert len(envs) == 2 and all(e.status == "ok" for e in envs)
    stats = svc.stats()
    assert stats["group_failures"] == 1       # not called again
    assert stats["breaker_skips"] == 2
    assert stats["cache"]["breaker_open"] == 1


def test_retry_backoff_then_dead_letter_then_quarantine(monkeypatch):
    """A run whose execution always fails walks the whole ladder: retry
    with backoff, engine fallback, dead-letter envelope (still in the
    client stream), and quarantine of later submissions of that spec."""
    monkeypatch.setattr(api.ExecutionPlan, "execute",
                        lambda self: (_ for _ in ()).throw(
                            FloatingPointError("chaos: poisoned spec")))
    svc = CertificationService(max_batch=8, max_wait=10.0,
                               max_retries=1, retry_backoff=0.1)
    spec = api.RunSpec(**SMALL, engine="python")   # unbatchable
    svc.submit(spec, client_id="c", now=0.0)
    assert svc.step(0.0) == []        # first failure: retry scheduled
    assert svc.stats()["retries"] == 1 and svc.pending == 1
    assert svc.step(0.05) == []       # backoff not yet expired
    (env,) = svc.step(0.1)            # retry fails -> dead letter
    assert env.status == "error" and env.result is None
    assert "FloatingPointError" in env.error
    assert env.ticket == "t000001" and env.seq == 0
    d = env.to_dict()
    assert d["status"] == "error" and "chaos" in d["error"]
    stats = svc.stats()
    assert stats["dead_letters"] == 1 and stats["completed"] == 1
    assert stats["quarantined"] == 1 and stats["pending"] == 0
    # the poisoned spec is now rejected at the door
    with pytest.raises(QuarantinedError):
        svc.submit(spec, client_id="c", now=0.2)
    assert svc.stats()["rejected_quarantined"] == 1
    # a different spec is unaffected
    other = api.RunSpec(**dict(SMALL, rounds=4), engine="python")
    assert svc.submit(other, client_id="c", now=0.2) == "t000002"


def test_python_engine_fallback_rescues_scan_failures(monkeypatch):
    """When only the compiled path fails, the ladder lands on the python
    round engine and the envelope is still ok (engine invariance makes
    the verdicts identical)."""
    orig = api.ExecutionPlan.execute

    def scan_poison(self):
        if self.engine == "scan":
            raise RuntimeError("chaos: compiled path down")
        return orig(self)

    monkeypatch.setattr(api.ExecutionPlan, "execute", scan_poison)
    monkeypatch.setattr(api, "execute_group",
                        lambda cells, runner_cache=None: (_ for _ in ())
                        .throw(RuntimeError("chaos: grouped path down")))
    svc = CertificationService(max_batch=1, max_wait=10.0, max_retries=0)
    svc.submit(SMALL, client_id="c", now=0.0)
    (env,) = svc.step(0.0)
    assert env.status == "ok"
    stats = svc.stats()
    assert stats["engine_fallbacks"] == 1 and stats["dead_letters"] == 0
    ref = api.plan(api.RunSpec(**SMALL, engine="python")).execute()
    assert env.result.ledger.typed_stream() == ref.ledger.typed_stream()


def test_chaos_soak_no_loss_dup_reorder(monkeypatch):
    """The deterministic soak under executor chaos: every 3rd grouped
    call raises mid-batch.  Delivery invariants (one envelope per
    ticket, per-client order, all ok) must hold exactly as in the
    healthy soak."""
    orig = api.execute_group
    calls = dict(n=0)

    def chaotic(cells, runner_cache=None):
        calls["n"] += 1
        if calls["n"] % 3 == 0:
            raise RuntimeError("chaos: injected mid-batch failure")
        return orig(cells, runner_cache=runner_cache)

    monkeypatch.setattr(api, "execute_group", chaotic)
    pools = spec_pool()
    trace = synthetic_trace(n_per_structure=32, seed=13, dt=1e-3,
                            clients=4, pools=pools)
    svc = CertificationService(max_batch=8, max_wait=0.25)
    envs = replay_trace(svc, trace)

    assert len(envs) == len(trace) == 96
    assert len({e.ticket for e in envs}) == 96
    assert all(e.status == "ok" for e in envs)
    submitted, served = {}, {}
    for a in trace:
        submitted.setdefault(a.client_id, []).append(a.spec)
    for e in envs:
        served.setdefault(e.client_id, []).append(e)
    for cid, stream in served.items():
        assert [e.seq for e in stream] == list(range(len(stream)))
        assert [e.spec for e in stream] == submitted[cid]
    stats = svc.stats()
    assert stats["group_failures"] > 0, "chaos never fired"
    assert stats["dead_letters"] == 0 and stats["pending"] == 0
    assert stats["completed"] == 96

    # served results remain bit-identical to direct execution
    refs = {}
    for pool in pools:
        for spec in pool:
            refs[spec.to_json()] = api.plan(spec).execute()
    for e in envs:
        ref = refs[e.spec.to_json()]
        assert e.result.ledger.typed_stream() == ref.ledger.typed_stream()


def test_faulted_specs_serve_identically(monkeypatch):
    """RunSpecs with an active faults= axis flow through the service
    (grouped by the faults component of the key) and serve the same
    recovery-priced stream as direct execution."""
    faulted = dict(SMALL, rounds=10,
                   faults="inject:seed=2,drop=0.2,flip=0.2")
    clean = dict(SMALL, rounds=10)
    svc = CertificationService(max_batch=2, max_wait=10.0)
    svc.submit(faulted, client_id="c", now=0.0)
    svc.submit(clean, client_id="c", now=0.0)
    envs = svc.drain(0.0)
    assert len(envs) == 2 and all(e.status == "ok" for e in envs)
    # distinct group keys: the faulted spec never pools with the clean one
    assert svc.stats()["batches"] == 2
    ref_f = api.plan(api.RunSpec(**faulted)).execute()
    ref_c = api.plan(api.RunSpec(**clean)).execute()
    by_faults = {e.spec.faults: e for e in envs}
    env_f = by_faults["inject:seed=2,drop=0.2,flip=0.2"]
    env_c = by_faults["none"]
    assert env_f.result.ledger.typed_stream() == \
        ref_f.ledger.typed_stream()
    assert env_f.result.ledger.retransmissions() > 0
    assert env_c.result.ledger.typed_stream() == \
        ref_c.ledger.typed_stream()
    assert env_c.result.ledger.retransmissions() == 0
