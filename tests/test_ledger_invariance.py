"""The CommLedger must be bit-identical across oracle backends AND
round engines.

The paper's lower bounds meter communication rounds; how the per-machine
GEMVs are computed (einsum vs Pallas kernel) and how the rounds are
driven (per-call Python loop vs one scan-compiled XLA program whose
trace-once schedule is replayed) are both outside the model. If either
axis ever leaked into the meter — an extra reduce, a different payload
size, a changed tag, a mis-multiplied schedule — every certification
under docs/results/ would silently depend on it. These tests pin the
full record stream (kind, elems, bytes, tag) and the round counter, per
registered algorithm, across the {einsum, kernel, fused} x
{python, scan} product, the channel conformance matrix from the fused
round-step redesign, and the sweep-level measurement on a hard instance.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import make_random_erm
from repro.core.engine import ENGINES, run_program
from repro.core.partition import even_partition
from repro.core.runtime import ORACLE_BACKENDS, LocalDistERM
from repro.experiments.registry import ALGORITHM_REGISTRY, get_algorithm
from repro.experiments.instances import build_instance

ROUNDS = 6


def _ledger_stream(dist):
    led = dist.comm.ledger
    # the full typed stream: legacy tuple + the bit-accounting tail and
    # the round-boundary marks all must be engine/backend-invariant
    return led.rounds, led.round_marks, led.typed_stream()


def _run(algo_name: str, backend: str, engine: str = "python"):
    bundle = build_instance("random_ridge", n=24, d=32, m=4)
    algo = get_algorithm(algo_name)
    dist = LocalDistERM(bundle.prob, bundle.part, backend=backend)
    program = algo.program(dist, rounds=ROUNDS,
                           **algo.make_kwargs(bundle.ctx))
    run_program(dist, program, engine=engine)
    return _ledger_stream(dist)


@pytest.mark.parametrize("algo_name", sorted(ALGORITHM_REGISTRY))
def test_ledger_bit_identical_across_backends(algo_name):
    streams = {be: _run(algo_name, be) for be in ORACLE_BACKENDS}
    rounds0, marks0, records0 = streams["einsum"]
    assert rounds0 == ROUNDS == len(marks0)
    for be, (rounds, marks, records) in streams.items():
        assert rounds == rounds0, (algo_name, be)
        assert marks == marks0, (algo_name, be)
        assert records == records0, (algo_name, be)


@pytest.mark.parametrize("algo_name", sorted(ALGORITHM_REGISTRY))
def test_ledger_bit_identical_across_engines(algo_name):
    """{python, scan} x {einsum, kernel}: the scan engine's replayed
    trace-once schedule must reproduce the per-call stream exactly."""
    streams = {(be, eng): _run(algo_name, be, eng)
               for be in ORACLE_BACKENDS for eng in ENGINES}
    rounds0, marks0, records0 = streams[("einsum", "python")]
    assert rounds0 == ROUNDS
    for key, (rounds, marks, records) in streams.items():
        assert rounds == rounds0, (algo_name, key)
        assert marks == marks0, (algo_name, key)
        assert records == records0, (algo_name, key)


@pytest.mark.parametrize("algo_name", sorted(ALGORITHM_REGISTRY))
def test_byte_and_bit_totals_invariant_across_backends_and_engines(
        algo_name):
    """The aggregate accounting — total bytes, total wire bits, per-round
    prefix sums — is a pure function of the algorithm, never of how it
    executed."""
    totals = set()
    for be in ORACLE_BACKENDS:
        for eng in ENGINES:
            bundle = build_instance("random_ridge", n=24, d=32, m=4)
            algo = get_algorithm(algo_name)
            dist = LocalDistERM(bundle.prob, bundle.part, backend=be)
            program = algo.program(dist, rounds=ROUNDS,
                                   **algo.make_kwargs(bundle.ctx))
            run_program(dist, program, engine=eng)
            led = dist.comm.ledger
            totals.add((led.total_bytes(), led.total_bits(),
                        tuple(led.bits_through_round(k)
                              for k in range(ROUNDS + 1))))
    assert len(totals) == 1, (algo_name, totals)
    (total_bytes, total_bits, prefix), = totals
    assert total_bits == 8 * total_bytes      # identity channel wire
    assert prefix[0] == 0 and prefix[-1] == total_bits
    assert all(a <= b for a, b in zip(prefix, prefix[1:]))


def test_byte_totals_invariant_across_batching():
    """execute_batch replays the same trace-once schedules: every cell's
    byte/bit totals and round marks match its sequential run exactly."""
    from repro import api

    specs = [api.RunSpec(
        instance="thm2_chain",
        instance_params=dict(d=24, kappa=k, lam=0.5, m=4),
        algorithm=a, rounds=80, eps=(1e-3,))
        for a in ("dagd", "dgd") for k in (16.0, 64.0)]
    seq = [api.plan(s).execute() for s in specs]
    bat = api.execute_batch([api.plan(s) for s in specs])
    assert all(r.batched for r in bat)
    for s, b in zip(seq, bat):
        assert b.ledger.total_bytes() == s.ledger.total_bytes()
        assert b.ledger.total_bits() == s.ledger.total_bits()
        assert b.ledger.round_marks == s.ledger.round_marks
        assert b.stream() == s.stream()


def test_batched_fused_cells_keep_their_own_data():
    """execute_batch groups structurally identical cells and vmaps the
    shared jaxpr over per-cell hoisted consts. The fused round-step must
    expose its cell data (A block, labels, masks, step sizes) as jit
    ARGUMENTS — closure captures get baked inside the pjit equation,
    invisible to the const-hoisting split, and every grouped cell would
    silently replay the first cell's problem. Regression: batched
    iterates equal each cell's own sequential run bit-for-bit."""
    from repro import api

    for channel in ("identity", "sched:int8@0,fp16@5"):
        specs = [api.RunSpec(
            instance="thm2_chain",
            instance_params=dict(d=24, kappa=k, lam=0.5, m=4),
            algorithm="dagd", rounds=30, eps=(1e-3,),
            backend="fused", channel=channel)
            for k in (16.0, 64.0)]
        plans = [api.plan(s) for s in specs]
        batched = api.execute_batch(plans)
        assert all(r.batched for r in batched), channel
        for plan_i, bat in zip(plans, batched):
            seq = plan_i.execute()
            assert np.array_equal(np.asarray(bat.w), np.asarray(seq.w)), \
                (channel, plan_i.spec.instance_params)
            assert bat.ledger.typed_stream() == seq.ledger.typed_stream()
            assert bat.ledger.round_marks == seq.ledger.round_marks


def test_sweep_measurement_backend_invariant():
    """The certification pipeline's ledger fields and bound overlay agree
    record-by-record across backends on a hard instance. The ledger is
    invariant *by construction* (metering happens outside the compute
    path); measured rounds-to-eps additionally requires the iterates to
    agree, which is exact on CPU but may shift an eps-threshold crossing
    by a round on TPU where the MXU-tiled kernels reassociate float adds
    — hence the +/-1 tolerance on measured_rounds only."""
    from repro.experiments.sweep import SweepSpec, run_sweep

    spec = SweepSpec(
        name="ledger-invariance-probe", instance="thm2_chain",
        grid=dict(d=[24], kappa=[16.0], lam=[0.5], m=[4]),
        algorithms=("dagd",), eps=(1e-3,), max_rounds=120)
    results = {be: run_sweep(spec, backend=be) for be in ORACLE_BACKENDS}
    base = [r.to_dict() for r in results["einsum"].records]
    assert base and base[0]["measured_rounds"] is not None
    for be, result in results.items():
        got = [r.to_dict() for r in result.records]
        assert len(got) == len(base)
        for rec, ref in zip(got, base):
            rec, ref = dict(rec), dict(ref)
            assert rec.pop("oracle_backend") == be
            ref.pop("oracle_backend")
            # the embedded RunSpec names the backend it ran under by
            # construction; everything else in it must agree
            assert rec.pop("run_spec")["backend"] == be
            ref.pop("run_spec")
            assert abs(rec.pop("measured_rounds")
                       - ref.pop("measured_rounds")) <= 1, (be, rec)
            rec.pop("ratio"), ref.pop("ratio")   # follows measured_rounds
            assert rec == ref, (be, rec, ref)


def test_kernel_backend_oracle_values_match_reference():
    """Backend dispatch changes scheduling only: oracle outputs agree with
    the whole-vector ERM reference to float tolerance."""
    prob = make_random_erm(n=40, d=36, loss="logistic", lam=0.03, seed=2)
    part = even_partition(36, 3)
    w = jnp.linspace(-1.0, 1.0, 36)
    v = jnp.linspace(1.0, -1.0, 36)
    for backend in ORACLE_BACKENDS:
        dist = LocalDistERM(prob, part, backend=backend)
        w_stk, v_stk = dist.scatter_w(w), dist.scatter_w(v)
        z = dist.response(w_stk)
        np.testing.assert_allclose(z, prob.A @ w, atol=1e-5, rtol=1e-5)
        g = dist.gather_w(dist.pgrad(w_stk, z))
        np.testing.assert_allclose(g, prob.gradient(w), atol=1e-5,
                                   rtol=1e-5)
        av = dist.response(v_stk, tag="Av")
        hv = dist.gather_w(dist.phvp(v_stk, z, av))
        np.testing.assert_allclose(hv, prob.hvp(w, v), atol=1e-5,
                                   rtol=1e-5)


MATRIX_CHANNELS = ("identity", "int8", "sched:int8@0,fp16@5")


@pytest.mark.parametrize("channel", MATRIX_CHANNELS)
@pytest.mark.parametrize("algo_name", ["dgd", "dagd"])
def test_fused_conformance_matrix(algo_name, channel):
    """The fused round-step conformance matrix: {einsum, kernel, fused} x
    {python, scan} x {identity, int8, scheduled}.

    Contract (and what the fused backend is allowed to change):
      * the CommLedger stream and round marks are bit-identical in every
        cell — fusing the channel stage into the round kernel must not
        move a single metered byte;
      * measured rounds-to-eps agree within the +/-1 threshold-crossing
        tolerance the sweep invariance test already grants;
      * under the scan engine the fused iterates equal the kernel
        iterates bit-for-bit (same ops, same order, one jit boundary);
        under the python engine per-call jit boundaries already separate
        einsum from kernel by an ulp, so fused gets the same float
        tolerance those backends get against each other.
    """
    from repro import api

    eps = 1e-3
    runs = {}
    for be in ORACLE_BACKENDS:
        for eng in ENGINES:
            spec = api.RunSpec(
                instance="thm2_chain",
                instance_params=dict(d=16, kappa=16.0, lam=0.5, m=4),
                algorithm=algo_name, rounds=40, eps=(eps,),
                backend=be, engine=eng, channel=channel)
            runs[(be, eng)] = api.plan(spec).execute()

    ref = runs[("einsum", "python")]
    ref_stream = (ref.ledger.round_marks, ref.ledger.typed_stream())
    ref_rounds = ref.measured_rounds(eps)
    for key, res in runs.items():
        assert (res.ledger.round_marks,
                res.ledger.typed_stream()) == ref_stream, key
        got = res.measured_rounds(eps)
        if ref_rounds is None:
            assert got is None, key
        else:
            assert abs(got - ref_rounds) <= 1, (key, got, ref_rounds)

    assert np.array_equal(np.asarray(runs[("fused", "scan")].w),
                          np.asarray(runs[("kernel", "scan")].w))
    fused_py = np.asarray(runs[("fused", "python")].w)
    kernel_py = np.asarray(runs[("kernel", "python")].w)
    if channel == "identity":
        np.testing.assert_allclose(fused_py, kernel_py,
                                   atol=1e-4, rtol=1e-4)
    else:
        # Quantized channels: a 1-ulp pre-quantization difference (the
        # python engine's per-call jit boundaries) can flip a stochastic
        # rounding decision, so iterates agree only to the accumulated
        # quantization-noise envelope; convergence equivalence is pinned
        # by the measured-rounds check above.
        np.testing.assert_allclose(fused_py, kernel_py, atol=2e-2)


def test_faulted_ledger_bit_identical_across_backends_and_engines():
    """PR 8: the fault schedule is seeded and data-independent, so the
    recovery-priced stream — NACKs, resends, straggle idle rounds, the
    crash replay span — is bit-identical across the {einsum, kernel} x
    {python, scan} product, exactly like the clean stream."""
    from repro import api

    faults = "inject:seed=4,drop=0.2,flip=0.1,straggle=0.3x1,crash=4,snap=2"
    streams = {}
    for be in ORACLE_BACKENDS:
        for eng in ENGINES:
            spec = api.RunSpec(
                instance="thm2_chain",
                instance_params=dict(d=16, kappa=16.0, lam=0.5, m=4),
                algorithm="dagd", rounds=ROUNDS, eps=(1e-2,),
                backend=be, engine=eng, faults=faults)
            led = api.plan(spec).execute().ledger
            streams[(be, eng)] = (led.rounds, led.algo_rounds,
                                  led.recovery_rounds, led.round_marks,
                                  led.typed_stream())
    ref = streams[("einsum", "python")]
    assert ref[1] == ROUNDS                  # algo rounds unchanged
    assert ref[0] == ROUNDS + ref[2]         # wire = algo + recovery
    assert any(r[-1] for r in ref[4]), "no recovery traffic injected"
    for key, got in streams.items():
        assert got == ref, key


def test_faults_none_leaves_ledger_bit_identical():
    """The faults axis must be a no-op at "none": stream, marks, and
    totals match a spec that predates the axis entirely."""
    from repro import api

    base = dict(instance="thm2_chain",
                instance_params=dict(d=16, kappa=16.0, lam=0.5, m=4),
                algorithm="dagd", rounds=ROUNDS, eps=(1e-2,))
    led_default = api.plan(api.RunSpec(**base)).execute().ledger
    led_none = api.plan(api.RunSpec(**base, faults="none")).execute().ledger
    assert led_none.typed_stream() == led_default.typed_stream()
    assert led_none.round_marks == led_default.round_marks
    assert led_none.total_bits() == led_default.total_bits()
    assert led_none.recovery_rounds == 0
    assert led_none.retransmit_bits() == 0
    assert not any(r[-1] for r in led_none.typed_stream())
