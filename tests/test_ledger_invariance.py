"""The CommLedger must be bit-identical across oracle backends AND
round engines.

The paper's lower bounds meter communication rounds; how the per-machine
GEMVs are computed (einsum vs Pallas kernel) and how the rounds are
driven (per-call Python loop vs one scan-compiled XLA program whose
trace-once schedule is replayed) are both outside the model. If either
axis ever leaked into the meter — an extra reduce, a different payload
size, a changed tag, a mis-multiplied schedule — every certification
under docs/results/ would silently depend on it. These tests pin the
full record stream (kind, elems, bytes, tag) and the round counter, per
registered algorithm, across the {einsum, kernel} x {python, scan}
product, and the sweep-level measurement on a hard instance.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import make_random_erm
from repro.core.engine import ENGINES, run_program
from repro.core.partition import even_partition
from repro.core.runtime import ORACLE_BACKENDS, LocalDistERM
from repro.experiments.registry import ALGORITHM_REGISTRY, get_algorithm
from repro.experiments.instances import build_instance

ROUNDS = 6


def _ledger_stream(dist):
    led = dist.comm.ledger
    # the full typed stream: legacy tuple + the bit-accounting tail and
    # the round-boundary marks all must be engine/backend-invariant
    return led.rounds, led.round_marks, led.typed_stream()


def _run(algo_name: str, backend: str, engine: str = "python"):
    bundle = build_instance("random_ridge", n=24, d=32, m=4)
    algo = get_algorithm(algo_name)
    dist = LocalDistERM(bundle.prob, bundle.part, backend=backend)
    program = algo.program(dist, rounds=ROUNDS,
                           **algo.make_kwargs(bundle.ctx))
    run_program(dist, program, engine=engine)
    return _ledger_stream(dist)


@pytest.mark.parametrize("algo_name", sorted(ALGORITHM_REGISTRY))
def test_ledger_bit_identical_across_backends(algo_name):
    streams = {be: _run(algo_name, be) for be in ORACLE_BACKENDS}
    rounds0, marks0, records0 = streams["einsum"]
    assert rounds0 == ROUNDS == len(marks0)
    for be, (rounds, marks, records) in streams.items():
        assert rounds == rounds0, (algo_name, be)
        assert marks == marks0, (algo_name, be)
        assert records == records0, (algo_name, be)


@pytest.mark.parametrize("algo_name", sorted(ALGORITHM_REGISTRY))
def test_ledger_bit_identical_across_engines(algo_name):
    """{python, scan} x {einsum, kernel}: the scan engine's replayed
    trace-once schedule must reproduce the per-call stream exactly."""
    streams = {(be, eng): _run(algo_name, be, eng)
               for be in ORACLE_BACKENDS for eng in ENGINES}
    rounds0, marks0, records0 = streams[("einsum", "python")]
    assert rounds0 == ROUNDS
    for key, (rounds, marks, records) in streams.items():
        assert rounds == rounds0, (algo_name, key)
        assert marks == marks0, (algo_name, key)
        assert records == records0, (algo_name, key)


@pytest.mark.parametrize("algo_name", sorted(ALGORITHM_REGISTRY))
def test_byte_and_bit_totals_invariant_across_backends_and_engines(
        algo_name):
    """The aggregate accounting — total bytes, total wire bits, per-round
    prefix sums — is a pure function of the algorithm, never of how it
    executed."""
    totals = set()
    for be in ORACLE_BACKENDS:
        for eng in ENGINES:
            bundle = build_instance("random_ridge", n=24, d=32, m=4)
            algo = get_algorithm(algo_name)
            dist = LocalDistERM(bundle.prob, bundle.part, backend=be)
            program = algo.program(dist, rounds=ROUNDS,
                                   **algo.make_kwargs(bundle.ctx))
            run_program(dist, program, engine=eng)
            led = dist.comm.ledger
            totals.add((led.total_bytes(), led.total_bits(),
                        tuple(led.bits_through_round(k)
                              for k in range(ROUNDS + 1))))
    assert len(totals) == 1, (algo_name, totals)
    (total_bytes, total_bits, prefix), = totals
    assert total_bits == 8 * total_bytes      # identity channel wire
    assert prefix[0] == 0 and prefix[-1] == total_bits
    assert all(a <= b for a, b in zip(prefix, prefix[1:]))


def test_byte_totals_invariant_across_batching():
    """execute_batch replays the same trace-once schedules: every cell's
    byte/bit totals and round marks match its sequential run exactly."""
    from repro import api

    specs = [api.RunSpec(
        instance="thm2_chain",
        instance_params=dict(d=24, kappa=k, lam=0.5, m=4),
        algorithm=a, rounds=80, eps=(1e-3,))
        for a in ("dagd", "dgd") for k in (16.0, 64.0)]
    seq = [api.plan(s).execute() for s in specs]
    bat = api.execute_batch([api.plan(s) for s in specs])
    assert all(r.batched for r in bat)
    for s, b in zip(seq, bat):
        assert b.ledger.total_bytes() == s.ledger.total_bytes()
        assert b.ledger.total_bits() == s.ledger.total_bits()
        assert b.ledger.round_marks == s.ledger.round_marks
        assert b.stream() == s.stream()


def test_sweep_measurement_backend_invariant():
    """The certification pipeline's ledger fields and bound overlay agree
    record-by-record across backends on a hard instance. The ledger is
    invariant *by construction* (metering happens outside the compute
    path); measured rounds-to-eps additionally requires the iterates to
    agree, which is exact on CPU but may shift an eps-threshold crossing
    by a round on TPU where the MXU-tiled kernels reassociate float adds
    — hence the +/-1 tolerance on measured_rounds only."""
    from repro.experiments.sweep import SweepSpec, run_sweep

    spec = SweepSpec(
        name="ledger-invariance-probe", instance="thm2_chain",
        grid=dict(d=[24], kappa=[16.0], lam=[0.5], m=[4]),
        algorithms=("dagd",), eps=(1e-3,), max_rounds=120)
    results = {be: run_sweep(spec, backend=be) for be in ORACLE_BACKENDS}
    base = [r.to_dict() for r in results["einsum"].records]
    assert base and base[0]["measured_rounds"] is not None
    for be, result in results.items():
        got = [r.to_dict() for r in result.records]
        assert len(got) == len(base)
        for rec, ref in zip(got, base):
            rec, ref = dict(rec), dict(ref)
            assert rec.pop("oracle_backend") == be
            ref.pop("oracle_backend")
            # the embedded RunSpec names the backend it ran under by
            # construction; everything else in it must agree
            assert rec.pop("run_spec")["backend"] == be
            ref.pop("run_spec")
            assert abs(rec.pop("measured_rounds")
                       - ref.pop("measured_rounds")) <= 1, (be, rec)
            rec.pop("ratio"), ref.pop("ratio")   # follows measured_rounds
            assert rec == ref, (be, rec, ref)


def test_kernel_backend_oracle_values_match_reference():
    """Backend dispatch changes scheduling only: oracle outputs agree with
    the whole-vector ERM reference to float tolerance."""
    prob = make_random_erm(n=40, d=36, loss="logistic", lam=0.03, seed=2)
    part = even_partition(36, 3)
    w = jnp.linspace(-1.0, 1.0, 36)
    v = jnp.linspace(1.0, -1.0, 36)
    for backend in ORACLE_BACKENDS:
        dist = LocalDistERM(prob, part, backend=backend)
        w_stk, v_stk = dist.scatter_w(w), dist.scatter_w(v)
        z = dist.response(w_stk)
        np.testing.assert_allclose(z, prob.A @ w, atol=1e-5, rtol=1e-5)
        g = dist.gather_w(dist.pgrad(w_stk, z))
        np.testing.assert_allclose(g, prob.gradient(w), atol=1e-5,
                                   rtol=1e-5)
        av = dist.response(v_stk, tag="Av")
        hv = dist.gather_w(dist.phvp(v_stk, z, av))
        np.testing.assert_allclose(hv, prob.hvp(w, v), atol=1e-5,
                                   rtol=1e-5)


def test_faulted_ledger_bit_identical_across_backends_and_engines():
    """PR 8: the fault schedule is seeded and data-independent, so the
    recovery-priced stream — NACKs, resends, straggle idle rounds, the
    crash replay span — is bit-identical across the {einsum, kernel} x
    {python, scan} product, exactly like the clean stream."""
    from repro import api

    faults = "inject:seed=4,drop=0.2,flip=0.1,straggle=0.3x1,crash=4,snap=2"
    streams = {}
    for be in ORACLE_BACKENDS:
        for eng in ENGINES:
            spec = api.RunSpec(
                instance="thm2_chain",
                instance_params=dict(d=16, kappa=16.0, lam=0.5, m=4),
                algorithm="dagd", rounds=ROUNDS, eps=(1e-2,),
                backend=be, engine=eng, faults=faults)
            led = api.plan(spec).execute().ledger
            streams[(be, eng)] = (led.rounds, led.algo_rounds,
                                  led.recovery_rounds, led.round_marks,
                                  led.typed_stream())
    ref = streams[("einsum", "python")]
    assert ref[1] == ROUNDS                  # algo rounds unchanged
    assert ref[0] == ROUNDS + ref[2]         # wire = algo + recovery
    assert any(r[-1] for r in ref[4]), "no recovery traffic injected"
    for key, got in streams.items():
        assert got == ref, key


def test_faults_none_leaves_ledger_bit_identical():
    """The faults axis must be a no-op at "none": stream, marks, and
    totals match a spec that predates the axis entirely."""
    from repro import api

    base = dict(instance="thm2_chain",
                instance_params=dict(d=16, kappa=16.0, lam=0.5, m=4),
                algorithm="dagd", rounds=ROUNDS, eps=(1e-2,))
    led_default = api.plan(api.RunSpec(**base)).execute().ledger
    led_none = api.plan(api.RunSpec(**base, faults="none")).execute().ledger
    assert led_none.typed_stream() == led_default.typed_stream()
    assert led_none.round_marks == led_default.round_marks
    assert led_none.total_bits() == led_default.total_bits()
    assert led_none.recovery_rounds == 0
    assert led_none.retransmit_bits() == 0
    assert not any(r[-1] for r in led_none.typed_stream())
