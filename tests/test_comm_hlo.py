"""CommLedger accounting + HLO collective audit."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.comm import (CommLedger, LocalCommunicator,
                             collective_bytes_from_hlo)


def test_ledger_accounting():
    led = CommLedger()
    comm = LocalCommunicator(4, led)
    x = jnp.ones((4, 100))          # 4 machines, R^100 each
    z = comm.reduce_all(x)
    assert z.shape == (100,)
    comm.end_round()
    assert led.rounds == 1
    assert led.total_bytes() == 100 * 4  # one R^100 f32 payload
    led.assert_budget(n=100, d=10)
    with pytest.raises(AssertionError):
        led.assert_budget(n=2, d=2, const=1)


def test_ledger_bytes_per_round():
    led = CommLedger()
    comm = LocalCommunicator(2, led)
    for _ in range(5):
        comm.reduce_all(jnp.ones((2, 50)))
        comm.end_round()
    assert led.bytes_per_round() == 50 * 4
    assert led.op_counts() == {"reduce_all": 5}


HLO_FIXTURE = """
HloModule test
ENTRY %main {
  %p0 = f32[128,256]{1,0} parameter(0)
  %ar = f32[128,256]{1,0} all-reduce(%p0), replica_groups={{0,1,2,3}}
  %ag = bf16[64,512]{1,0} all-gather(%x), replica_groups=[4,8]<=[32]
  %rs = f32[32,64]{1,0} reduce-scatter(%y), replica_groups={{0,1,2,3,4,5,6,7}}
  %cp = f32[16]{0} collective-permute(%z)
  %a2a = f32[8,8]{1,0} all-to-all(%w)
  %ars = f32[10]{0} all-reduce-start(%q)
  %ard = f32[10]{0} all-reduce-done(%ars)
}
"""


def test_collective_audit_fixture():
    audit = collective_bytes_from_hlo(HLO_FIXTURE)
    assert audit.count_by_op == {"all-reduce": 2, "all-gather": 1,
                                 "reduce-scatter": 1,
                                 "collective-permute": 1, "all-to-all": 1}
    assert audit.bytes_by_op["all-reduce"] == 128 * 256 * 4 + 10 * 4
    assert audit.bytes_by_op["all-gather"] == 64 * 512 * 2
    # reduce-scatter: result x group size (8)
    assert audit.bytes_by_op["reduce-scatter"] == 32 * 64 * 4 * 8
    assert audit.bytes_by_op["collective-permute"] == 16 * 4
    assert audit.bytes_by_op["all-to-all"] == 64 * 4


SHARDED_AUDIT_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import json
import numpy as np
import jax, jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P
from repro.core import CommLedger, make_random_erm
from repro.core.comm import collective_bytes_from_lowered
from repro.core.runtime import _run_sharded
from repro.core.algorithms import PROGRAMS

out = {}

# (1) toy module: one all_gather, known payload
mesh = Mesh(np.array(jax.devices()), ("x",))
gather = shard_map(lambda a: jax.lax.all_gather(a, "x"), mesh=mesh,
                   in_specs=P("x"), out_specs=P(None, "x"),
                   check_rep=False)
audit = collective_bytes_from_lowered(
    jax.jit(gather).lower(jnp.ones((4,), jnp.float32)))
out["toy"] = {"counts": audit.count_by_op, "bytes": audit.bytes_by_op}

# (2) the real sharded driver, lowered without running: the compiled
# module must carry every collective the trace-once ledger metered
prob = make_random_erm(n=16, d=8, loss="squared", lam=0.05, seed=1)
L = prob.smoothness_bound()
lowered, led, spans = _run_sharded(
    prob, None, rounds=5, ledger=CommLedger(), engine="scan",
    program_builder=lambda d_, r: PROGRAMS["dgd"](d_, r, L=L,
                                                  lam=prob.lam),
    channel="identity", lower_only=True)
audit = collective_bytes_from_lowered(lowered)
out["dgd"] = {
    "counts": audit.count_by_op,
    "total_bytes": audit.total_bytes,
    "traced_records": len(led.records),
    "traced_bytes": sum(r.bytes for r in led.records),
}
print(json.dumps(out))
"""


def test_audit_on_real_module():
    """The parser finds the collectives of real lowered modules: a toy
    shard_map all_gather with a known payload, and the sharded driver's
    ``lower_only`` product, whose compiled HLO must carry at least the
    collective traffic the trace-once ledger metered."""
    import json
    import os
    import subprocess
    import sys

    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    proc = subprocess.run([sys.executable, "-c", SHARDED_AUDIT_SCRIPT],
                          capture_output=True, text=True, env=env,
                          timeout=600)
    assert proc.returncode == 0, proc.stderr[-2000:]
    out = json.loads(proc.stdout.strip().splitlines()[-1])

    # toy: one all-gather of the full f32[2,2] result
    assert out["toy"]["counts"].get("all-gather") == 1
    assert out["toy"]["bytes"]["all-gather"] == 2 * 2 * 4

    # driver: dgd's per-round ReduceAll (psum of f32[16]) compiles to at
    # least one all-reduce; the scanned module carries the traced
    # payload at least once (scan traces each step exactly once)
    dgd = out["dgd"]
    assert dgd["counts"].get("all-reduce", 0) >= 1
    assert dgd["traced_records"] >= 1
    assert dgd["total_bytes"] >= dgd["traced_bytes"]
