"""CommLedger accounting + HLO collective audit."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core.comm import (CommLedger, LocalCommunicator,
                             collective_bytes_from_hlo)


def test_ledger_accounting():
    led = CommLedger()
    comm = LocalCommunicator(4, led)
    x = jnp.ones((4, 100))          # 4 machines, R^100 each
    z = comm.reduce_all(x)
    assert z.shape == (100,)
    comm.end_round()
    assert led.rounds == 1
    assert led.total_bytes() == 100 * 4  # one R^100 f32 payload
    led.assert_budget(n=100, d=10)
    with pytest.raises(AssertionError):
        led.assert_budget(n=2, d=2, const=1)


def test_ledger_bytes_per_round():
    led = CommLedger()
    comm = LocalCommunicator(2, led)
    for _ in range(5):
        comm.reduce_all(jnp.ones((2, 50)))
        comm.end_round()
    assert led.bytes_per_round() == 50 * 4
    assert led.op_counts() == {"reduce_all": 5}


HLO_FIXTURE = """
HloModule test
ENTRY %main {
  %p0 = f32[128,256]{1,0} parameter(0)
  %ar = f32[128,256]{1,0} all-reduce(%p0), replica_groups={{0,1,2,3}}
  %ag = bf16[64,512]{1,0} all-gather(%x), replica_groups=[4,8]<=[32]
  %rs = f32[32,64]{1,0} reduce-scatter(%y), replica_groups={{0,1,2,3,4,5,6,7}}
  %cp = f32[16]{0} collective-permute(%z)
  %a2a = f32[8,8]{1,0} all-to-all(%w)
  %ars = f32[10]{0} all-reduce-start(%q)
  %ard = f32[10]{0} all-reduce-done(%ars)
}
"""


def test_collective_audit_fixture():
    audit = collective_bytes_from_hlo(HLO_FIXTURE)
    assert audit.count_by_op == {"all-reduce": 2, "all-gather": 1,
                                 "reduce-scatter": 1,
                                 "collective-permute": 1, "all-to-all": 1}
    assert audit.bytes_by_op["all-reduce"] == 128 * 256 * 4 + 10 * 4
    assert audit.bytes_by_op["all-gather"] == 64 * 512 * 2
    # reduce-scatter: result x group size (8)
    assert audit.bytes_by_op["reduce-scatter"] == 32 * 64 * 4 * 8
    assert audit.bytes_by_op["collective-permute"] == 16 * 4
    assert audit.bytes_by_op["all-to-all"] == 64 * 4


def test_audit_on_real_module():
    """all_gather in a real lowered module is found by the parser."""
    import jax
    if jax.device_count() < 2:
        pytest.skip("single device: no collectives emitted")
    # covered by the dry-run machinery tests on multi-device subprocesses
