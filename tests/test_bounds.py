"""Closed-form bounds (Theorems 2-4): shape properties the proofs imply,
plus an end-to-end smoke test of the bound-certification sweep runner."""
import math

import pytest

from repro.core.bounds import (agd_upper_bound, thm2_strongly_convex,
                               thm3_smooth_convex, thm4_incremental)


# --------------------------------------------------------------------------
# Theorem 2 — Omega(sqrt(kappa) log(lam |w*|^2 / eps))
# --------------------------------------------------------------------------

def test_thm2_monotone_in_kappa():
    rounds = [thm2_strongly_convex(k, lam=1.0, norm_w_star=1.0,
                                   eps=1e-6).rounds
              for k in (4.0, 16.0, 64.0, 256.0)]
    assert all(a < b for a, b in zip(rounds, rounds[1:]))


def test_thm2_monotone_in_accuracy():
    rounds = [thm2_strongly_convex(64.0, lam=1.0, norm_w_star=1.0,
                                   eps=e).rounds
              for e in (1e-2, 1e-4, 1e-6, 1e-8)]
    assert all(a < b for a, b in zip(rounds, rounds[1:]))


def test_thm2_zero_rounds_branch():
    # arg = lam |w*|^2 / ((sqrt(kappa)+1) eps) <= 1  =>  the bound is vacuous
    rep = thm2_strongly_convex(kappa=16.0, lam=1.0, norm_w_star=1.0,
                               eps=10.0)
    assert rep.rounds == 0.0
    assert rep.theorem == "thm2"
    # exactly at the threshold arg == 1 the log would be 0 anyway
    eps_thresh = 1.0 / (math.sqrt(16.0) + 1.0)
    assert thm2_strongly_convex(16.0, 1.0, 1.0, eps_thresh).rounds == 0.0


def test_thm2_below_agd_upper_bound():
    """Tightness sanity: the lower bound never exceeds AGD's upper bound."""
    for kappa in (4.0, 64.0, 1024.0):
        for eps in (1e-3, 1e-8):
            lb = thm2_strongly_convex(kappa, 1.0, 1.0, eps).rounds
            ub = agd_upper_bound(kappa, 1.0, 1.0, eps)
            assert lb <= ub


# --------------------------------------------------------------------------
# Theorem 3 — Omega(sqrt(L/eps) |w*|)
# --------------------------------------------------------------------------

def test_thm3_monotone_in_L_and_eps():
    r_L = [thm3_smooth_convex(L, 1.0, 1e-4).rounds
           for L in (1.0, 4.0, 16.0)]
    assert all(a < b for a, b in zip(r_L, r_L[1:]))
    r_eps = [thm3_smooth_convex(1.0, 1.0, e).rounds
             for e in (1e-2, 1e-4, 1e-6)]
    assert all(a < b for a, b in zip(r_eps, r_eps[1:]))


def test_thm3_never_negative():
    assert thm3_smooth_convex(1.0, 1.0, eps=100.0).rounds == 0.0


# --------------------------------------------------------------------------
# Theorem 4 — Omega((sqrt(n kappa) + n) log(lam |w*| / eps))
# --------------------------------------------------------------------------

def test_thm4_monotone_in_n_and_kappa():
    r_n = [thm4_incremental(n, 64.0, 1.0, 1.0, 1e-6).rounds
           for n in (8, 32, 128)]
    assert all(a < b for a, b in zip(r_n, r_n[1:]))
    r_k = [thm4_incremental(32, k, 1.0, 1.0, 1e-6).rounds
           for k in (4.0, 64.0, 1024.0)]
    assert all(a < b for a, b in zip(r_k, r_k[1:]))


def test_thm4_zero_rounds_branch():
    rep = thm4_incremental(n=16, kappa=64.0, lam=1.0, norm_w_star=1.0,
                           eps=1.0)
    assert rep.rounds == 0.0


def test_thm4_dominates_thm2():
    """The incremental bound is at least the non-incremental one
    (touching one component per round can only cost more rounds). With
    the proofs' explicit constants this holds from n = 2 upward; at n = 1
    the two constant factors are incomparable."""
    for n in (2, 8, 64):
        lb4 = thm4_incremental(n, 64.0, 1.0, 1.0, 1e-6).rounds
        lb2 = thm2_strongly_convex(64.0, 1.0, 1.0, 1e-6).rounds
        assert lb4 >= lb2


# --------------------------------------------------------------------------
# Sweep runner smoke test (tiny instance, one algorithm)
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiny_sweep_result():
    from repro.experiments import SweepSpec, run_sweep
    spec = SweepSpec(name="smoke", instance="thm2_chain",
                     grid=dict(d=[8], kappa=[4.0], lam=[0.5], m=[2]),
                     algorithms=("dagd",), eps=(1e-3,), max_rounds=200)
    return run_sweep(spec)


def test_sweep_produces_certified_record(tiny_sweep_result):
    recs = tiny_sweep_result.records
    assert len(recs) == 1
    r = recs[0]
    assert r.algorithm == "dagd" and r.hard
    assert r.measured_rounds is not None
    assert r.bound_theorem == "thm2"
    assert r.certified is True                  # measured >= lower bound
    assert r.budget_ok                          # O(n+d) bytes/round held
    assert r.bytes_per_round > 0


@pytest.mark.slow
def test_full_frontier_sweep_gates(tmp_path):
    """The full published bits-to-eps frontier (both hard families +
    both workloads) passes every gate: all hard points bit-certified
    against their schedule-aware floors, the Theorem-4 no-adaptive-win
    negative result present with a channel-invariant floor, and a >= 2x
    workload savings at unchanged verdict.  CI runs the --quick subset;
    this is the sweep behind docs/results/bits-frontier.{json,md}."""
    from benchmarks.bits_frontier import FULL_PRESETS
    from repro.experiments import frontier
    cells = frontier.preset_cells(FULL_PRESETS)
    doc = frontier.run_frontier(cells, verbose=False)
    assert frontier.gate_failures(doc) == []
    json_path, md_path = frontier.write_report(doc, tmp_path)
    assert json_path.exists() and md_path.exists()


def test_sweep_report_renders(tiny_sweep_result, tmp_path):
    from repro.experiments import write_report
    json_path, md_path = write_report(tiny_sweep_result, tmp_path)
    assert json_path.exists() and md_path.exists()
    assert (tmp_path / "README.md").exists()    # index refreshed
    doc = json_path.read_text()
    assert '"schema_version": 5' in doc       # 5: records carry error (PR 8)
    assert '"schema_version": 4' in doc       # embedded run_specs (4: faults)
    assert '"run_spec"' in doc
    assert '"wire_channel"' in doc
    md = md_path.read_text()
    assert "Measured rounds vs lower bound" in md
    assert "thm2" in md
