"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref


TOL = {jnp.float32: dict(atol=2e-4, rtol=2e-4),
       jnp.bfloat16: dict(atol=3e-2, rtol=3e-2)}


@pytest.mark.parametrize("n,d", [(8, 8), (48, 64), (300, 200), (513, 129),
                                 (1024, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_feature_matvec_sweep(n, d, dtype):
    k = jax.random.PRNGKey(n * 1000 + d)
    A = jax.random.normal(k, (n, d)).astype(dtype)
    w = jax.random.normal(jax.random.PRNGKey(1), (d,)).astype(dtype)
    got = ops.feature_matvec(A, w)
    want = ref.feature_matvec_ref(A, w)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **TOL[dtype])


@pytest.mark.parametrize("n,d", [(16, 16), (96, 48), (257, 130)])
@pytest.mark.parametrize("nrhs", [1, 3])
def test_feature_rmatvec_sweep(n, d, nrhs):
    k = jax.random.PRNGKey(7)
    A = jax.random.normal(k, (n, d))
    r = jax.random.normal(jax.random.PRNGKey(8), (n, nrhs))
    r = r[:, 0] if nrhs == 1 else r
    got = ops.feature_rmatvec(A, r)
    want = ref.feature_rmatvec_ref(A, r) if nrhs == 1 else A.T @ r
    np.testing.assert_allclose(got, want, atol=2e-4, rtol=2e-4)


def test_batched_rhs_matches_loop():
    k = jax.random.PRNGKey(3)
    A = jax.random.normal(k, (64, 40))
    W = jax.random.normal(jax.random.PRNGKey(4), (40, 5))
    got = ops.feature_matvec(A, W)
    for i in range(5):
        np.testing.assert_allclose(got[:, i], A @ W[:, i], atol=2e-4,
                                   rtol=2e-4)


@given(d=st.integers(2, 600), seed=st.integers(0, 50))
@settings(max_examples=25, deadline=None)
def test_tridiag_property(d, seed):
    k = jax.random.PRNGKey(seed)
    diag = jax.random.normal(k, (d,))
    off = jax.random.normal(jax.random.PRNGKey(seed + 1), (d - 1,))
    v = jax.random.normal(jax.random.PRNGKey(seed + 2), (d,))
    got = ops.tridiag_matvec(diag, off, v)
    want = ref.tridiag_matvec_ref(diag, off, v)
    np.testing.assert_allclose(got, want, atol=1e-4, rtol=1e-4)


def test_tridiag_identity_and_shift():
    d = 300
    v = jax.random.normal(jax.random.PRNGKey(0), (d,))
    # identity
    got = ops.tridiag_matvec(jnp.ones(d), jnp.zeros(d - 1), v)
    np.testing.assert_allclose(got, v, atol=1e-6)
    # pure shift structure: diag=0, off=1 -> out[k] = v[k-1] + v[k+1]
    got = ops.tridiag_matvec(jnp.zeros(d), jnp.ones(d - 1), v)
    want = jnp.zeros(d).at[:-1].add(v[1:]).at[1:].add(v[:-1])
    np.testing.assert_allclose(got, want, atol=1e-6)


@pytest.mark.parametrize("t,k,d", [(5, 1, 16), (37, 4, 96), (256, 8, 64)])
def test_moe_combine_sweep(t, k, d):
    key = jax.random.PRNGKey(t)
    x = jax.random.normal(key, (t, k, d))
    w = jax.random.normal(jax.random.PRNGKey(k), (t, k))
    got = ops.moe_combine(x, w)
    want = ref.moe_combine_ref(x, w)
    np.testing.assert_allclose(got, want, atol=2e-4, rtol=2e-4)


def test_kernels_used_by_erm_path():
    """ops wrappers compute the ERM round quantities correctly."""
    from repro.core import make_random_erm
    from repro.core.partition import even_partition
    prob = make_random_erm(n=40, d=32, seed=0)
    part = even_partition(32, 4)
    w = jax.random.normal(jax.random.PRNGKey(5), (32,))
    wjs = part.split_vector(w)
    Ajs = part.split_columns(prob.A)
    z = sum(ops.feature_matvec(Aj, wj) for Aj, wj in zip(Ajs, wjs))
    np.testing.assert_allclose(z, prob.A @ w, atol=1e-4, rtol=1e-4)
