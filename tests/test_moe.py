"""MoE dispatch: exactness vs dense mixture, capacity behaviour, groups."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.models.moe import MoEConfig, _capacity, init_moe, moe
from repro.models.common import unbox


def _dense_ref(params, x, cfg):
    """Every token through its top-k experts, no capacity limit."""
    xt = x.reshape(-1, x.shape[-1])
    logits = xt @ params["router"]
    probs = jax.nn.softmax(logits, -1)
    tw, te = jax.lax.top_k(probs, cfg.top_k)
    tw = tw / tw.sum(-1, keepdims=True)

    def expert(e, v):
        if cfg.activation == "swiglu":
            h = jax.nn.silu(v @ params["wi_gate"][e]) * \
                (v @ params["wi_up"][e])
        else:
            h = jax.nn.gelu(v @ params["wi"][e])
        return h @ params["wo"][e]

    out = jnp.zeros_like(xt)
    for i in range(xt.shape[0]):
        acc = jnp.zeros((x.shape[-1],))
        for j in range(cfg.top_k):
            acc += tw[i, j] * expert(int(te[i, j]), xt[i])
        out = out.at[i].set(acc)
    return out.reshape(x.shape)


@pytest.mark.parametrize("groups", [1, 2, 4])
@pytest.mark.parametrize("top_k", [1, 2])
def test_moe_matches_dense_when_capacity_ample(groups, top_k):
    cfg = MoEConfig(d_model=16, d_ff=8, n_experts=4, top_k=top_k,
                    capacity_factor=8.0, groups=groups)
    params, _ = unbox(init_moe(jax.random.PRNGKey(0), cfg, jnp.float32))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16))
    y, aux = moe(params, x, cfg)
    ref = _dense_ref(params, x, cfg)
    np.testing.assert_allclose(y, ref, atol=1e-4, rtol=1e-3)
    assert float(aux) >= 1.0 - 1e-5  # Switch aux >= 1 at optimum


def test_capacity_drops_dont_corrupt():
    """With capacity 0-ish, output collapses toward zero but stays finite
    and kept tokens are exact."""
    cfg = MoEConfig(d_model=16, d_ff=8, n_experts=4, top_k=2,
                    capacity_factor=0.01, groups=1)
    params, _ = unbox(init_moe(jax.random.PRNGKey(0), cfg, jnp.float32))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16))
    y, aux = moe(params, x, cfg)
    assert jnp.all(jnp.isfinite(y))
    # drops mean smaller norm than ample capacity
    cfg_full = MoEConfig(d_model=16, d_ff=8, n_experts=4, top_k=2,
                         capacity_factor=8.0, groups=1)
    y_full, _ = moe(params, x, cfg_full)
    assert float(jnp.linalg.norm(y)) < float(jnp.linalg.norm(y_full))


def test_groups_equivalence_with_ample_capacity():
    """Group count must not change results when nothing is dropped."""
    params, _ = unbox(init_moe(jax.random.PRNGKey(2), MoEConfig(
        16, 8, 4, 2), jnp.float32))
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 8, 16))
    outs = []
    for g in (1, 2, 4):
        cfg = MoEConfig(d_model=16, d_ff=8, n_experts=4, top_k=2,
                        capacity_factor=16.0, groups=g)
        y, _ = moe(params, x, cfg)
        outs.append(y)
    np.testing.assert_allclose(outs[0], outs[1], atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(outs[0], outs[2], atol=1e-4, rtol=1e-3)


def test_capacity_rounding():
    cfg = MoEConfig(d_model=8, d_ff=4, n_experts=4, top_k=2)
    cap = _capacity(1024, cfg)
    assert cap % 8 == 0
    assert cap >= 1024 * 2 * 1.25 / 4


def test_pallas_combine_path_matches():
    cfg = MoEConfig(d_model=16, d_ff=8, n_experts=4, top_k=2,
                    capacity_factor=8.0, groups=1)
    params, _ = unbox(init_moe(jax.random.PRNGKey(0), cfg, jnp.float32))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 16, 16))
    y0, _ = moe(params, x, cfg, use_pallas=False)
    y1, _ = moe(params, x, cfg, use_pallas=True)
    np.testing.assert_allclose(y0, y1, atol=1e-4, rtol=1e-3)
