"""flash_decode Pallas kernel: sweeps vs oracle + model integration."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref


def _rand(b, hk, g, dh, t, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, hk, g, dh), jnp.float32)
    k = jax.random.normal(ks[1], (b, t, hk, dh), jnp.float32)
    v = jax.random.normal(ks[2], (b, t, hk, dh), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("b,hk,g,dh,t", [
    (1, 1, 1, 16, 64), (2, 2, 2, 32, 128), (1, 4, 1, 64, 1000),
    (2, 1, 8, 32, 257),
])
def test_flash_decode_sweep(b, hk, g, dh, t):
    q, k, v = _rand(b, hk, g, dh, t)
    bias = jnp.zeros((b, t), jnp.float32)
    got = ops.flash_decode(q, k, v, bias)
    want = ref.flash_decode_ref(q, k, v, bias)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


@given(valid=st.integers(1, 63), seed=st.integers(0, 20))
@settings(max_examples=10, deadline=None)
def test_flash_decode_masking_property(valid, seed):
    """Masked-out cache positions must not influence the output."""
    b, hk, g, dh, t = 1, 2, 2, 16, 64
    q, k, v = _rand(b, hk, g, dh, t, seed)
    bias = jnp.where(jnp.arange(t)[None, :] < valid, 0.0, -1e30)
    got = ops.flash_decode(q, k, v, bias)
    # corrupt the invalid region: result must be identical
    k2 = k.at[:, valid:].set(999.0)
    v2 = v.at[:, valid:].set(-999.0)
    got2 = ops.flash_decode(q, k2, v2, bias)
    np.testing.assert_allclose(got, got2, atol=1e-5)
    want = ref.flash_decode_ref(q, k, v, bias)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_attention_decode_pallas_path_matches():
    """attention_decode(use_pallas=True) == the dense decode path."""
    from repro.models.layers import (AttnConfig, attention_decode,
                                     init_attention, init_attn_cache)
    from repro.models.common import unbox
    cfg = AttnConfig(d_model=64, n_heads=4, n_kv_heads=2, head_dim=16)
    p, _ = unbox(init_attention(jax.random.PRNGKey(0), cfg, jnp.float32))
    cache0 = init_attn_cache(2, cfg, max_seq=16, dtype=jnp.float32)
    cache1 = init_attn_cache(2, cfg, max_seq=16, dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 64))
    for s in range(8):
        y0, cache0 = attention_decode(p, x[:, s:s + 1], cfg, cache0,
                                      use_pallas=False)
        y1, cache1 = attention_decode(p, x[:, s:s + 1], cfg, cache1,
                                      use_pallas=True)
        np.testing.assert_allclose(y0, y1, atol=2e-4, rtol=2e-4)
