"""repro.analysis: schedule conformance, class certification, lints.

Four layers:

  * scope token round-trip — the ``named_scope`` encoding every
    communicator stamps on its graph ops parses back losslessly;
  * the conformance matrix — every registered algorithm, under both
    placements and the audited channel axis, statically verifies with
    zero error findings (and a sampled subset cross-checks against an
    executed run's ledger);
  * mutation fixtures — the deliberately out-of-class programs are
    rejected with the expected typed finding naming a jaxpr equation;
  * the report schema — ``AuditReport`` round-trips through JSON, and
    ``plan(verify="static")`` / ``ExecutionPlan.audit()`` gate on it.
"""
import jax.numpy as jnp
import pytest

from repro.analysis import (AUDIT_CHANNELS, AUDIT_INSTANCES, AuditReport,
                            CellAudit, Finding, audit_plan)
from repro.analysis.extract import extract_messages, trace_steps
from repro.analysis.fixtures import (fixture_chatty_dsvrg,
                                     fixture_leaky_dgd, fixture_oob_dgd,
                                     fixture_phantom_dgd)
from repro.analysis.lints import lint_group_stability
from repro.api import RunSpec
from repro.api.plan import ExecutionPlan, PlanError, plan
from repro.core.comm import (CommRecord, comm_scope_name,
                             parse_comm_scope)

ALGOS = ("dgd", "dagd", "prox_dagd", "bcd", "disco_f", "dsvrg")

_BUNDLES = {}


def _plan_for(algo, placement, channel, rounds=8):
    kind, params, _ = AUDIT_INSTANCES[algo]
    spec = RunSpec(instance=kind, instance_params=params, algorithm=algo,
                   rounds=rounds, placement=placement, engine="scan",
                   backend="einsum", channel=channel, measure="none")
    key = (kind, tuple(sorted(params.items())))
    pl = plan(spec, bundle=_BUNDLES.get(key))
    _BUNDLES.setdefault(key, pl.bundle)
    return pl


# --------------------------------------------------------------------------
# Scope tokens
# --------------------------------------------------------------------------

def test_scope_token_roundtrip():
    rec = CommRecord("reduce_all", 12, 48, tag="z=Aw",
                     direction="worker->center", shape=(12,),
                     dtype="float32", bits=96, wire=(12, 1))
    tok = comm_scope_name(rec, idx=3, rnd=2)
    meta = parse_comm_scope(tok)
    assert meta is not None
    assert meta["idx"] == 3 and meta["rnd"] == 2
    assert meta["kind"] == "reduce_all"
    assert meta["direction"] == "worker->center"
    assert meta["shape"] == (12,) and meta["dtype"] == "float32"
    assert meta["bits"] == 96 and meta["wire"] == (12, 1)


def test_scope_token_sanitizes_tags():
    rec = CommRecord("reduce_all", 1, 4, tag="|w|^2", shape=(),
                     dtype="float32", bits=32, wire=None)
    tok = comm_scope_name(rec, idx=0, rnd=0)
    assert "|" not in tok and "^" not in tok   # named_scope-safe
    meta = parse_comm_scope(tok)
    assert meta is not None and meta["shape"] == ()
    assert parse_comm_scope("comm[garbage]") is None
    assert parse_comm_scope("not-a-token") is None


def test_extract_messages_from_traced_step():
    pl = _plan_for("dgd", "local", "identity")
    dist, program, _ = pl._cell()
    steps = trace_steps(dist, program)
    assert len(steps) == 1
    msgs, problems = extract_messages(steps[0].closed.jaxpr)
    assert not problems
    assert len(msgs) == len(steps[0].records) == 1
    assert msgs[0].kind == "reduce_all"
    assert msgs[0].prims   # anchored by real equations


# --------------------------------------------------------------------------
# The conformance matrix (the acceptance-criteria grid)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("channel", AUDIT_CHANNELS)
@pytest.mark.parametrize("placement", ("local", "sharded"))
@pytest.mark.parametrize("algo", ALGOS)
def test_static_matrix(algo, placement, channel):
    if algo == "bcd" and placement == "sharded":
        with pytest.raises(PlanError):
            _plan_for(algo, placement, channel)
        return
    pl = _plan_for(algo, placement, channel)
    cell = audit_plan(pl, execute=False)
    errs = [f for f in cell.findings if f.severity == "error"]
    assert not errs, "\n".join(str(f) for f in errs)
    assert cell.messages > 0 and cell.rounds == 8
    assert cell.total_bits > 0


@pytest.mark.parametrize("placement", ("local", "sharded"))
def test_dynamic_crosscheck(placement):
    """The static schedule equals an actually executed run's ledger —
    sampled on the scheduled channel (the hardest pricing path)."""
    pl = _plan_for("dgd", placement, "sched:int8@0,fp16@5")
    cell = audit_plan(pl, execute=True)
    assert cell.executed
    errs = [f for f in cell.findings if f.severity == "error"]
    assert not errs, "\n".join(str(f) for f in errs)


def test_incremental_payload_certified():
    """dsvrg's inner segments really are scalar-only (Theorem 4)."""
    pl = _plan_for("dsvrg", "local", "identity")
    cell = audit_plan(pl)
    assert not any(f.code == "thm4-payload" for f in cell.findings)


# --------------------------------------------------------------------------
# Mutation fixtures: the verifier must reject
# --------------------------------------------------------------------------

@pytest.mark.parametrize("fixture,code", [
    (fixture_leaky_dgd, "class-leak"),
    (fixture_oob_dgd, "class-oob"),
    (fixture_chatty_dsvrg, "thm4-payload"),
    (fixture_phantom_dgd, "sched-count"),
])
def test_fixture_rejected(fixture, code):
    fx = fixture()
    assert fx.rejected, f"{fx.name} was NOT rejected"
    hits = [f for f in fx.findings
            if f.code == code and f.severity == "error"]
    assert hits
    if code.startswith("class-"):
        # lineage findings name the offending jaxpr equation
        assert hits[0].eqn and hits[0].path


# --------------------------------------------------------------------------
# Schema round-trip + plan gating
# --------------------------------------------------------------------------

def test_audit_report_roundtrip():
    pl = _plan_for("dgd", "local", "int8")
    cell = audit_plan(pl)
    report = AuditReport(cells=[cell], meta={"rounds": 8})
    report.cells[0].findings.append(Finding(
        "lint-weak-literal", "info", "synthetic", algorithm="dgd"))
    back = AuditReport.from_json(report.to_json())
    assert back.to_dict() == report.to_dict()
    assert isinstance(back.cells[0], CellAudit)
    assert back.cells[0].findings[-1].code == "lint-weak-literal"
    assert back.ok == report.ok
    md = report.to_markdown()
    assert "| dgd | local | `int8` |" in md


def test_plan_verify_static():
    kind, params, _ = AUDIT_INSTANCES["dgd"]
    spec = RunSpec(instance=kind, instance_params=params,
                   algorithm="dgd", rounds=4, placement="local",
                   channel="int8", measure="none")
    pl = plan(spec, verify="static")
    assert isinstance(pl, ExecutionPlan)
    cell = pl.audit()
    assert cell.ok
    with pytest.raises(PlanError, match="verify"):
        plan(spec, verify="dynamic-ish")
    with pytest.raises(PlanError, match="resolution-only"):
        plan(RunSpec(), verify="static")


# --------------------------------------------------------------------------
# Lints
# --------------------------------------------------------------------------

def test_lint_rng_fires():
    from repro.analysis.lints import lint_rng
    from repro.core.engine import RoundProgram, Segment
    from repro.analysis.fixtures import _fixture_dist
    import jax

    dist = _fixture_dist()

    def step(d_, w, x):
        key = jax.random.PRNGKey(0)
        noise = jax.random.normal(key, w.shape)
        z = d_.response(w + 0.0 * noise)
        g = d_.pgrad(w, z)
        d_.end_round()
        return w - jnp.float32(0.05) * g, w

    program = RoundProgram(init=dist.zeros_like_w(),
                           segments=[Segment(step, 2, name="gd")],
                           final=lambda w: w)
    steps = trace_steps(dist, program)
    findings = lint_rng(steps, algorithm="rng-fixture")
    assert findings and all(f.code == "lint-rng" for f in findings)
    assert all(f.severity == "error" for f in findings)


def test_lint_group_stability():
    same = ["a b c\nd e f"]
    assert lint_group_stability(same, ["a b c\nd e f"]) == []
    split = lint_group_stability(same, ["a b c\nd e g"],
                                 algorithm="dgd")
    assert len(split) == 1 and split[0].code == "lint-group-split"
    assert "line 2" in split[0].message
    segs = lint_group_stability(same, same + same)
    assert segs and segs[0].code == "lint-group-split"


def test_registered_algorithms_group_stable():
    """Hyper-value changes must not split execute_batch groups."""
    from repro.analysis import _group_stability_findings
    assert _group_stability_findings("dgd") == []
