"""Backend conformance: {Local, Sharded} execution x {einsum, kernel,
fused} oracle backends x {python, scan} round engines must agree.

Run in a subprocess so the 8-device XLA flag doesn't leak into other
tests. Two layers:

  * ``test_shard_map_parity`` — the original Local-vs-shard_map parity on
    the default oracle backend.
  * ``test_backend_conformance_matrix`` — EVERY registered algorithm run
    under all eight (execution, oracle, engine) combinations produces
    matching final iterates and the same communication structure (the
    Local pairs additionally pin bit-identical ledger record streams
    across engines). Iterating the registry is deliberate: registering a
    new algorithm without a step-form program, or without teaching this
    suite how to drive it, fails the test.
"""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
from repro.core import CommLedger, make_random_erm
from repro.core.partition import even_partition
from repro.core.runtime import LocalDistERM, _run_sharded
from repro.core.algorithms import dagd, dgd, disco_f

prob = make_random_erm(n=32, d=48, loss="squared", lam=0.05, seed=4)
L = prob.smoothness_bound()
part = even_partition(48, 8)
out = {}
for name, algo in [("dgd", dgd), ("dagd", dagd), ("disco_f", disco_f)]:
    w_sh, led = _run_sharded(prob, lambda d_, r: algo(d_, r, L=L,
                                                      lam=prob.lam),
                             rounds=25)
    dist = LocalDistERM(prob, part)
    w_lo = dist.gather_w(algo(dist, 25, L=L, lam=prob.lam))
    out[name] = {
        "max_diff": float(jnp.max(jnp.abs(w_sh - w_lo))),
        "sharded_ops": led.op_counts(),
        "local_ops": dist.comm.ledger.op_counts(),
    }
print(json.dumps(out))
"""


MATRIX_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import numpy as np
import jax, jax.numpy as jnp
from jax import lax
from repro.core import make_random_erm
from repro.core.engine import ENGINES, run_program
from repro.core.partition import even_partition
from repro.core.runtime import ORACLE_BACKENDS, LocalDistERM, _run_sharded
from repro.core.algorithms import ALGORITHMS, PROGRAMS
from repro.core.algorithms.prox_dagd import soft_threshold
from repro.experiments.registry import ALGORITHM_REGISTRY

M, D, N, R = 8, 48, 32, 12
prob = make_random_erm(n=N, d=D, loss="squared", lam=0.05, seed=4)
L = prob.smoothness_bound()
part = even_partition(D, M)
A = np.asarray(prob.A)
block_L = np.array(
    [np.linalg.norm(A[:, off:off + b], 2) ** 2 / N + prob.lam
     for off, b in zip(part.offsets, part.block_sizes)])
L_max = float(np.max(np.sum(A ** 2, axis=1)) + prob.lam)


def make_kwargs(name, sharded):
    # bcd needs its per-block constant in the stacked (m, 1) layout
    # locally vs a per-shard scalar under shard_map, so kwargs are built
    # lazily (axis_index only resolves inside the shard_map body)
    if name == "bcd":
        bl = jnp.asarray(block_L)
        if sharded:
            return lambda: dict(block_L=bl[lax.axis_index("model")], m=M)
        return lambda: dict(block_L=bl[:, None], m=M)
    if name == "dsvrg":
        return lambda: dict(L_max=L_max, lam=prob.lam, seed=7,
                            eta=1.0 / (4.0 * L_max))
    if name == "prox_dagd":
        return lambda: dict(L=L, lam=prob.lam, prox=soft_threshold(1e-3))
    return lambda: dict(L=L, lam=prob.lam)


def _stream(led):
    return [(r.kind, r.elems, r.bytes, r.tag) for r in led.records]


out = {}
for name in sorted(ALGORITHM_REGISTRY):
    iterates, op_counts, local_streams = {}, {}, {}
    for be in ORACLE_BACKENDS:
        for eng in ENGINES:
            dist = LocalDistERM(prob, part, backend=be)
            program = PROGRAMS[name](dist, R, **make_kwargs(name, False)())
            res = run_program(dist, program, engine=eng)
            iterates[f"local/{be}/{eng}"] = dist.gather_w(res.w)
            op_counts[f"local/{be}/{eng}"] = dist.comm.ledger.op_counts()
            local_streams[f"local/{be}/{eng}"] = _stream(dist.comm.ledger)

            kw = make_kwargs(name, True)
            if eng == "python":
                w_sh, led = _run_sharded(
                    prob, lambda d_, r: ALGORITHMS[name](d_, r, **kw()),
                    rounds=R, backend=be)
            else:
                w_sh, led = _run_sharded(
                    prob, None, rounds=R, backend=be, engine="scan",
                    program_builder=lambda d_, r: PROGRAMS[name](d_, r,
                                                                 **kw()))
            iterates[f"sharded/{be}/{eng}"] = w_sh
            op_counts[f"sharded/{be}/{eng}"] = led.op_counts()
    ref = iterates["local/einsum/python"]
    ref_ops = op_counts["local/einsum/python"]
    ref_stream = local_streams["local/einsum/python"]
    out[name] = {
        "combos": sorted(iterates),
        "max_diff": max(float(jnp.max(jnp.abs(w - ref)))
                        for w in iterates.values()),
        "ops_agree": all(ops == ref_ops for ops in op_counts.values()),
        "local_streams_identical": all(s == ref_stream
                                       for s in local_streams.values()),
    }
print(json.dumps(out))
"""


def _run_script(script: str) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    res = subprocess.run([sys.executable, "-c", script], env=env,
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, res.stderr[-3000:]
    return json.loads(res.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_shard_map_parity():
    out = _run_script(SCRIPT)
    for name, rec in out.items():
        assert rec["max_diff"] < 1e-4, (name, rec)
        # identical communication structure per round (trace-time count
        # for sharded == per-round python count for local)
        assert set(rec["sharded_ops"]) == set(rec["local_ops"]), name


@pytest.mark.slow
def test_backend_conformance_matrix():
    """Every registered algorithm x {Local, Sharded} x {einsum, kernel,
    fused} x {python, scan}: matching final iterates, identical per-run
    op counts, and (Local) bit-identical ledger record streams."""
    out = _run_script(MATRIX_SCRIPT)
    assert len(out) >= 6          # the six reference algorithms
    expected = sorted(f"{ex}/{be}/{eng}"
                      for ex in ("local", "sharded")
                      for be in ("einsum", "kernel", "fused")
                      for eng in ("python", "scan"))
    for name, rec in out.items():
        assert rec["combos"] == expected, name
        assert rec["max_diff"] < 1e-4, (name, rec)
        assert rec["ops_agree"], (name, rec)
        assert rec["local_streams_identical"], (name, rec)
