"""LocalDistERM vs ShardedDistERM (shard_map) parity — run in a
subprocess so the 8-device XLA flag doesn't leak into other tests."""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
from repro.core import CommLedger, make_random_erm
from repro.core.partition import even_partition
from repro.core.runtime import LocalDistERM, run_sharded
from repro.core.algorithms import dagd, dgd, disco_f

prob = make_random_erm(n=32, d=48, loss="squared", lam=0.05, seed=4)
L = prob.smoothness_bound()
part = even_partition(48, 8)
out = {}
for name, algo in [("dgd", dgd), ("dagd", dagd), ("disco_f", disco_f)]:
    w_sh, led = run_sharded(prob, lambda d_, r: algo(d_, r, L=L,
                                                     lam=prob.lam),
                            rounds=25)
    dist = LocalDistERM(prob, part)
    w_lo = dist.gather_w(algo(dist, 25, L=L, lam=prob.lam))
    out[name] = {
        "max_diff": float(jnp.max(jnp.abs(w_sh - w_lo))),
        "sharded_ops": led.op_counts(),
        "local_ops": dist.comm.ledger.op_counts(),
    }
print(json.dumps(out))
"""


@pytest.mark.slow
def test_shard_map_parity():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    res = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, res.stderr[-3000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    for name, rec in out.items():
        assert rec["max_diff"] < 1e-4, (name, rec)
        # identical communication structure per round (trace-time count
        # for sharded == per-round python count for local)
        assert set(rec["sharded_ops"]) == set(rec["local_ops"]), name
