"""Lemma 5 / Corollary 6 — numerical certification of the paper's
one-coordinate-per-round information propagation."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.feasible_set import SpanOracle
from repro.core.hard_instance import ChainInstance, chain_matrix
from repro.core.partition import even_partition


def _chain_oracle(d, kappa, lam, m):
    c = lam * (kappa - 1) / 4
    H = c * chain_matrix(d, kappa) + lam * np.eye(d)
    b = np.zeros(d)
    b[0] = c
    return SpanOracle(H=H, b=b, part=even_partition(d, m))


@pytest.mark.parametrize("m", [1, 2, 3, 4, 6])
def test_corollary6_certified(m):
    so = _chain_oracle(d=24, kappa=16.0, lam=1.0, m=m)
    assert so.certify_corollary6(23)


@given(m=st.integers(1, 5), kappa=st.floats(2.0, 400.0))
@settings(max_examples=15, deadline=None)
def test_corollary6_property(m, kappa):
    so = _chain_oracle(d=20, kappa=kappa, lam=1.0, m=m)
    assert so.certify_corollary6(19)


def test_propagation_is_tight():
    """The bound is achieved: support reaches coordinate k-1 at round k
    (the span rules DO advance one coordinate per round)."""
    so = _chain_oracle(d=16, kappa=25.0, lam=1.0, m=4)
    for k in range(1, 16):
        so.step()
        sup = so.union_support()
        assert sup.max() == k - 1, f"round {k}: support {sup}"


def test_error_floor_holds_for_best_feasible_point():
    """f(best point in W^(k)) - f* >= the paper's floor, for every k."""
    d, kappa, lam = 40, 49.0, 1.0
    ci = ChainInstance(d=d, kappa=kappa, lam=lam)
    so = _chain_oracle(d, kappa, lam, m=4)
    ws = np.asarray(ci.w_star())
    fstar = float(ci.f_star())
    import jax.numpy as jnp
    for k in range(1, 30):
        so.step()
        best = so.best_point(ws)
        gap = float(ci.value(jnp.asarray(best))) - fstar
        floor = ci.error_floor(k)
        if floor < 1e-5:      # below f32 resolution of f-values: stop
            break
        assert gap >= floor * (1 - 1e-5), (k, gap, floor)


def test_separable_function_stays_blocked():
    """On a block-diagonal H (Thm 4 instance), machine j's subspace stays
    inside its own block and coordinate 1 of each block never appears
    unless that block's linear term is nonzero."""
    d, m = 12, 3
    blk = np.array([[2.0, -1, 0, 0], [-1, 2, -1, 0], [0, -1, 2, -1],
                    [0, 0, -1, 1.5]])
    H = np.kron(np.eye(m), blk)
    b = np.zeros(d)
    b[0] = 1.0    # only machine 0's block is "seeded"
    so = SpanOracle(H=H, b=b, part=even_partition(d, m))
    for _ in range(8):
        so.step()
    sup = so.union_support()
    assert sup.size and sup.max() <= 3  # never leaves block 0
