"""Composite objectives under the feature partition (prox_dagd/FISTA)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import make_random_erm
from repro.core.partition import even_partition
from repro.core.runtime import LocalDistERM
from repro.core.algorithms import prox_dagd, soft_threshold, box_projection


def test_lasso_sparsity_and_optimality():
    """FISTA on 0.5|Aw-y|^2/n + tau|w|_1: KKT conditions hold and the
    solution is sparse; communication stays one ReduceAll per round."""
    prob = make_random_erm(n=40, d=60, loss="squared", lam=0.0, seed=1)
    part = even_partition(60, 4)
    L = prob.smoothness_bound()
    tau = 0.02
    dist = LocalDistERM(prob, part)
    w = prox_dagd(dist, rounds=800, L=L, prox=soft_threshold(tau))
    wg = dist.gather_w(w)
    # KKT: |grad_i f| <= tau on zeros, == -tau*sign(w_i) on support
    g = prob.gradient(wg)
    on = np.abs(np.asarray(wg)) > 1e-7
    assert on.sum() < 60                       # sparse
    assert on.sum() > 0
    np.testing.assert_allclose(np.asarray(g)[on],
                               -tau * np.sign(np.asarray(wg))[on],
                               atol=5e-4)
    assert np.all(np.abs(np.asarray(g)[~on]) <= tau + 5e-4)
    # comm model: exactly one R^n ReduceAll per round (prox is local)
    assert dist.comm.ledger.op_counts() == {"reduce_all": 800}
    dist.comm.ledger.assert_budget(n=prob.n, d=prob.d)


def test_box_constrained():
    """Projection onto [0, inf): solution is the nonnegative LS optimum."""
    prob = make_random_erm(n=30, d=20, loss="squared", lam=0.1, seed=2)
    part = even_partition(20, 4)
    L = prob.smoothness_bound()
    dist = LocalDistERM(prob, part)
    w = prox_dagd(dist, rounds=600, L=L, lam=prob.lam,
                  prox=box_projection(0.0, jnp.inf))
    wg = np.asarray(dist.gather_w(w))
    assert np.all(wg >= -1e-7)
    # KKT: gradient >= 0 where w == 0, ~ 0 where w > 0
    g = np.asarray(prob.gradient(jnp.asarray(wg)))
    active = wg > 1e-6
    np.testing.assert_allclose(g[active], 0.0, atol=1e-3)
    assert np.all(g[~active] >= -1e-3)
