"""Round-engine unit tests: python-vs-scan parity, trace-once ledger
schedules, in-scan gap measurement, and the runtime satellites (masked
``dot`` with shape assertion, per-round loss-term cache).

The heavier cross-product suites live in ``test_runtime_parity.py``
(engines x oracle backends x execution backends, slow-marked) and
``test_ledger_invariance.py``; this file is the fast tier-1 coverage.
"""
import dataclasses

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import CommLedger, GLMLoss, make_random_erm
from repro.core.engine import (ENGINES, EngineSession, RoundProgram,
                               Segment, resolve_engine, run_program)
from repro.core.partition import even_partition
from repro.core.runtime import LocalDistERM
from repro.core.algorithms import PROGRAMS
from repro.experiments.registry import ALGORITHM_REGISTRY, get_algorithm
from repro.experiments.instances import build_instance

ROUNDS = 40


def _stream(dist):
    led = dist.comm.ledger
    return led.rounds, [(r.kind, r.elems, r.bytes, r.tag)
                        for r in led.records]


def _setup(n=24, d=32, m=4, loss="squared"):
    bundle = build_instance("random_ridge", n=n, d=d, m=m)
    return bundle


def _run(bundle, algo_name, engine, rounds=ROUNDS, **overrides):
    algo = get_algorithm(algo_name)
    dist = LocalDistERM(bundle.prob, bundle.part)
    kwargs = dict(algo.make_kwargs(bundle.ctx), **overrides)
    program = algo.program(dist, rounds=rounds, **kwargs)
    res = run_program(dist, program, engine=engine, history=True)
    return dist, res


# --------------------------------------------------------------------------
# engine parity (fast, per registered algorithm)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("algo_name", sorted(ALGORITHM_REGISTRY))
def test_python_scan_parity(algo_name):
    """Same iterate history, same final w, bit-identical ledger stream."""
    bundle = _setup()
    dist_py, res_py = _run(bundle, algo_name, "python")
    dist_sc, res_sc = _run(bundle, algo_name, "scan")
    assert _stream(dist_py) == _stream(dist_sc)
    assert dist_py.comm.ledger.rounds == ROUNDS
    np.testing.assert_allclose(res_py.w, res_sc.w, atol=1e-5, rtol=1e-5)
    h_py = jnp.stack(res_py.iterates)
    h_sc = jnp.stack(res_sc.iterates)
    assert h_py.shape == h_sc.shape == (ROUNDS,) + res_py.w.shape
    np.testing.assert_allclose(h_py, h_sc, atol=1e-5, rtol=1e-5)


def test_disco_f_nonuniform_rounds_parity():
    """Multiple Newton segments (non-uniform round structure): stream and
    round count reproduce the historical loop's budget split."""
    bundle = _setup(loss="squared")
    newton_steps = 3
    rounds = 20
    inner = max(1, rounds // newton_steps - 1)
    dist_py, res_py = _run(bundle, "disco_f", "python", rounds=rounds,
                           newton_steps=newton_steps)
    dist_sc, res_sc = _run(bundle, "disco_f", "scan", rounds=rounds,
                           newton_steps=newton_steps)
    assert _stream(dist_py) == _stream(dist_sc)
    assert dist_py.comm.ledger.rounds == newton_steps * (1 + inner)
    np.testing.assert_allclose(res_py.w, res_sc.w, atol=1e-5, rtol=1e-5)


def test_dsvrg_truncated_epoch_parity():
    """A round budget that truncates the final epoch: the pre-drawn index
    sequence and segment split must still match the historical loop."""
    bundle = _setup()
    n = bundle.prob.n
    rounds = 2 * n + n // 2    # snapshot + full epoch + partial epoch
    dist_py, res_py = _run(bundle, "dsvrg", "python", rounds=rounds)
    dist_sc, res_sc = _run(bundle, "dsvrg", "scan", rounds=rounds)
    assert _stream(dist_py) == _stream(dist_sc)
    assert dist_py.comm.ledger.rounds == rounds
    np.testing.assert_allclose(jnp.stack(res_py.iterates),
                               jnp.stack(res_sc.iterates),
                               atol=1e-5, rtol=1e-5)


# --------------------------------------------------------------------------
# in-scan gap measurement
# --------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ENGINES)
def test_measure_matches_history_gaps(engine):
    """The (K,) in-scan gap series equals objective(iterate) - f* computed
    from an explicit history, and metering is untouched by measure."""
    bundle = build_instance("thm2_chain", d=24, kappa=16.0, lam=0.5, m=4)
    algo = get_algorithm("dagd")
    kwargs = algo.make_kwargs(bundle.ctx)

    dist_m = LocalDistERM(bundle.prob, bundle.part)
    program = algo.program(dist_m, rounds=ROUNDS, **kwargs)
    measure = lambda w: bundle.objective(dist_m.gather_w(w)) - bundle.fstar
    res_m = run_program(dist_m, program, engine=engine, measure=measure)
    assert res_m.gaps.shape == (ROUNDS,)

    dist_h = LocalDistERM(bundle.prob, bundle.part)
    program = algo.program(dist_h, rounds=ROUNDS, **kwargs)
    res_h = run_program(dist_h, program, engine=engine, history=True)
    ref = np.asarray([float(bundle.objective(dist_h.gather_w(w)))
                      - bundle.fstar for w in res_h.iterates])
    np.testing.assert_allclose(res_m.gaps, ref, atol=1e-6, rtol=1e-5)
    # measurement is not communication
    assert _stream(dist_m) == _stream(dist_h)


def test_measure_and_history_exclusive():
    bundle = _setup()
    dist = LocalDistERM(bundle.prob, bundle.part)
    program = PROGRAMS["dgd"](dist, 4, L=bundle.ctx.L, lam=bundle.ctx.lam)
    with pytest.raises(ValueError):
        run_program(dist, program, measure=lambda w: 0.0, history=True)


def test_session_reuse_skips_retrace():
    """A warm EngineSession reuses jitted runners and captured schedules;
    the ledger still grows by the full per-round stream each run."""
    bundle = _setup()
    dist = LocalDistERM(bundle.prob, bundle.part)
    program = PROGRAMS["dagd"](dist, ROUNDS, L=bundle.ctx.L,
                               lam=bundle.ctx.lam)
    session = EngineSession()
    run_program(dist, program, engine="scan", session=session)
    n_runners = len(session.runners)
    first = _stream(dist)
    dist.comm.ledger = CommLedger()
    run_program(dist, program, engine="scan", session=session)
    assert len(session.runners) == n_runners    # no new compilations
    assert _stream(dist) == first


def test_resolve_engine(monkeypatch):
    assert resolve_engine(None) == "scan"
    assert resolve_engine("python") == "python"
    monkeypatch.setenv("REPRO_ROUND_ENGINE", "python")
    assert resolve_engine("auto") == "python"
    with pytest.raises(ValueError):
        resolve_engine("jit")


def test_segment_validation():
    step = lambda dist, c, x: (c, c)
    with pytest.raises(ValueError):
        Segment(step, 0)
    with pytest.raises(ValueError):
        Segment(step, 3, xs=np.zeros(2))


# --------------------------------------------------------------------------
# runtime satellites
# --------------------------------------------------------------------------

def test_dot_rejects_wrong_rank():
    """A wrong-rank input used to silently reduce over the wrong axes."""
    bundle = _setup()
    dist = LocalDistERM(bundle.prob, bundle.part)
    w = dist.zeros_like_w()
    with pytest.raises(ValueError):
        dist.dot(w[None], w[None])          # (1, m, d_max)
    with pytest.raises(ValueError):
        dist.dot(w[0], w[0])                # (d_max,)
    with pytest.raises(ValueError):
        dist.dot(w, w[:, :-1])              # shape mismatch


def test_dot_masks_padding():
    """Values leaked into the pad region must not contribute."""
    prob = make_random_erm(n=8, d=10, loss="squared", lam=0.1, seed=0)
    part = even_partition(10, 3)            # ragged: blocks 4, 3, 3
    dist = LocalDistERM(prob, part)
    u = jnp.ones((part.m, part.d_max))      # garbage in the pad slots
    got = float(dist.dot(u, u))
    assert got == float(part.d)             # only the d valid coordinates


def test_loss_term_cache_within_round():
    """grad/hess evaluated once per (round, z); recomputed after
    end_round() and for a different z."""
    prob = make_random_erm(n=16, d=12, loss="logistic", lam=0.1, seed=3)
    part = even_partition(12, 3)
    dist = LocalDistERM(prob, part)
    calls = {"grad": 0, "hess": 0}
    base = prob.loss

    def counting(fn, key):
        def wrapped(z, y):
            calls[key] += 1
            return fn(z, y)
        return wrapped

    dist.loss = GLMLoss(name=base.name, value=base.value,
                        grad=counting(base.grad, "grad"),
                        hess=counting(base.hess, "hess"),
                        smoothness=base.smoothness)
    w = dist.scatter_w(jnp.linspace(-1, 1, 12))
    v = dist.scatter_w(jnp.linspace(1, -1, 12))
    z = dist.response(w)
    g1 = dist.pgrad(w, z)
    g2 = dist.pgrad(v, z)                   # same z: cached
    av = dist.response(v, tag="Av")
    h1 = dist.phvp(v, z, av)
    h2 = dist.phvp(w, z, av)                # same z: cached
    assert calls == {"grad": 1, "hess": 1}
    np.testing.assert_allclose(
        dist.gather_w(g1) - dist.gather_w(g2),
        prob.lam * (jnp.linspace(-1, 1, 12) - jnp.linspace(1, -1, 12)),
        atol=1e-6)
    dist.end_round()
    dist.pgrad(w, z)                        # new round: recomputed
    assert calls["grad"] == 2
    z2 = dist.response(v)
    dist.pgrad(w, z2)                       # different z: recomputed
    assert calls["grad"] == 3
    del h1, h2


def test_run_sharded_scan_requires_program():
    from repro.core.runtime import _run_sharded
    bundle = _setup()
    with pytest.raises(ValueError):
        _run_sharded(bundle.prob, lambda d_, r: None, rounds=2,
                     engine="scan")


# --------------------------------------------------------------------------
# sweep-level engine invariance (single small cell; the full matrix is in
# test_runtime_parity / test_ledger_invariance)
# --------------------------------------------------------------------------

def test_sweep_records_engine_invariant():
    from repro.experiments.sweep import SweepSpec, run_sweep

    spec = SweepSpec(
        name="engine-probe", instance="thm2_chain",
        grid=dict(d=[24], kappa=[16.0], lam=[0.5], m=[4]),
        algorithms=("dagd",), eps=(1e-3,), max_rounds=120)
    results = {eng: run_sweep(spec, engine=eng) for eng in ENGINES}
    base = [dataclasses.asdict(r) for r in results["python"].records]
    assert base and base[0]["measured_rounds"] is not None
    assert base[0]["certified"] is True
    for eng, result in results.items():
        got = [dataclasses.asdict(r) for r in result.records]
        for rec, ref in zip(got, base):
            rec, ref = dict(rec), dict(ref)
            assert rec.pop("engine") == eng
            ref.pop("engine")
            # the embedded RunSpec names the engine it ran under by
            # construction; everything else in it must agree
            assert rec.pop("run_spec")["engine"] == eng
            ref.pop("run_spec")
            assert rec == ref, (eng, rec, ref)
