"""The Theorem-2/3/4 hard instances: exact solutions and proof quantities."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.hard_instance import (ChainInstance, SeparableInstance,
                                      chain_matrix, tridiag_bands,
                                      tridiag_matvec)
from repro.core.bounds import (thm2_strongly_convex, thm3_smooth_convex,
                               thm4_incremental, agd_upper_bound)


def test_chain_matrix_structure():
    A = chain_matrix(6, 25.0)
    assert np.allclose(A, A.T)
    assert np.allclose(np.diag(A)[:-1], 2.0)
    assert A[5, 5] == pytest.approx((5 + 3) / (5 + 1))
    evals = np.linalg.eigvalsh(A)
    assert evals.min() > 0  # positive definite


def test_bands_match_dense():
    diag, off = tridiag_bands(8, 16.0)
    A = chain_matrix(8, 16.0)
    v = np.random.RandomState(0).randn(8)
    np.testing.assert_allclose(tridiag_matvec(jnp.asarray(diag),
                                              jnp.asarray(off),
                                              jnp.asarray(v)),
                               A @ v, atol=1e-5)


@pytest.mark.parametrize("kappa", [4.0, 16.0, 100.0])
def test_w_star_is_minimizer(kappa):
    ci = ChainInstance(d=80, kappa=kappa, lam=0.5)
    ws = ci.w_star()
    g = ci.gradient(ws)
    # gradient vanishes (up to the q^d boundary truncation the paper uses)
    assert float(jnp.linalg.norm(g)) < 1e-3 * max(1.0, float(
        jnp.linalg.norm(ws)))
    # and perturbations increase f
    f0 = float(ci.value(ws))
    for seed in range(3):
        dw = 0.01 * jax.random.normal(jax.random.PRNGKey(seed), (80,))
        assert float(ci.value(ws + dw)) > f0


def test_condition_number():
    kappa, lam = 36.0, 0.7
    ci = ChainInstance(d=40, kappa=kappa, lam=lam)
    H = lam * (kappa - 1) / 4 * chain_matrix(40, kappa) + lam * np.eye(40)
    evals = np.linalg.eigvalsh(H)
    # paper: f is lam-strongly convex with condition number kappa; the
    # chain construction approaches these as d grows (Nesterov bounds)
    assert evals.min() >= lam - 1e-6
    assert evals.max() <= kappa * lam + 1e-6


def test_error_floor_decreasing_and_positive():
    ci = ChainInstance(d=100, kappa=64.0, lam=1.0)
    floors = [ci.error_floor(k) for k in range(0, 50, 5)]
    assert all(f > 0 for f in floors)
    assert all(a > b for a, b in zip(floors, floors[1:]))


def test_lower_bound_rounds_scaling():
    # Omega(sqrt(kappa) log(1/eps)): quadrupling kappa ~doubles the bound
    r1 = thm2_strongly_convex(64.0, 1.0, 1.0, 1e-6).rounds
    r2 = thm2_strongly_convex(256.0, 1.0, 1.0, 1e-6).rounds
    assert 1.5 < r2 / r1 < 2.5
    # and log eps scaling
    r3 = thm2_strongly_convex(64.0, 1.0, 1.0, 1e-12).rounds
    assert 1.5 < r3 / r1 < 2.5


def test_thm3_scaling():
    r1 = thm3_smooth_convex(1.0, 1.0, 1e-4).rounds
    r2 = thm3_smooth_convex(1.0, 1.0, 1e-6).rounds
    assert 8 < r2 / r1 < 12  # sqrt(1/eps): x100 eps -> x10 rounds


def test_thm4_dominates_thm2():
    # incremental bound has the extra n term
    n, kappa = 64, 100.0
    r_inc = thm4_incremental(n, kappa, 1.0, 1.0, 1e-8).rounds
    r_non = thm2_strongly_convex(kappa, 1.0, 1.0, 1e-8).rounds
    assert r_inc > r_non


def test_upper_bounds_dominate_lower_bounds():
    for kappa in [9.0, 100.0, 2500.0]:
        lb = thm2_strongly_convex(kappa, 1.0, 1.0, 1e-8).rounds
        ub = agd_upper_bound(kappa, 1.0, 1.0, 1e-8)
        assert ub >= lb, (kappa, lb, ub)


def test_separable_instance():
    si = SeparableInstance(m=4, n=16, d_per_component=10, kappa=25.0)
    ws = si.w_star()
    assert ws.shape == (si.d,)
    g = si.gradient(ws)
    assert float(jnp.linalg.norm(g)) < 1e-3
    assert si.lower_bound_rounds(1e-6) > 0


def test_erm_embedding_matches_chain():
    ci = ChainInstance(d=24, kappa=16.0, lam=0.3)
    B, y, lam = ci.as_erm_data()
    w = np.random.RandomState(1).randn(24)
    f_erm = 0.5 * np.linalg.norm(B @ w - y) ** 2 + 0.5 * lam * w @ w
    f_chain = float(ci.value(jnp.asarray(w)))
    const = 0.5 * np.linalg.norm(y) ** 2
    assert f_erm - const == pytest.approx(f_chain, abs=1e-4)
