"""Metamorphic properties of the adaptive (scheduled / gap) channels.

Four families of relations, each checkable without knowing a "correct"
output, only how a *transformed* input must relate:

  * **bit monotonicity** — replacing any stage of a schedule with a
    coarser channel can only shrink the wire bits, per round and
    cumulatively through every round of a real metered run;
  * **identity at round 0** — a schedule whose first stage is fp32 is
    *invisible* before its first switch: payloads pass through exactly
    and the ledger prefix is bit-identical to the identity wire;
  * **schedule-vs-constant equivalence** — a one-entry schedule
    (``sched:<ch>@0``) is the fixed channel ``<ch>``: typed ledger
    streams, marks, and iterates agree bit-for-bit on both engines;
  * **prefix additivity** — ``bits_through_round(k)`` is an exact
    prefix sum over the round marks, on non-uniform round structures
    (DISCO-F's Newton+CG segments, DSVRG's snapshot+epoch), and each
    round's records price at the stage active at that round.

Property tests use hypothesis when installed; otherwise the
deterministic fallback shim in ``tests/_hypothesis_fallback.py``.
"""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.channel import make_schedule, parse_channel
from repro.core.engine import run_program
from repro.core.runtime import LocalDistERM
from repro.experiments.instances import build_instance
from repro.experiments.registry import get_algorithm


# fine -> coarse, by bits per element (overheads included for elems >= 4:
# int8's 32-bit scale amortizes below fp16 from 4 elements up)
PRECISION_ORDER = ("identity", "fp16", "int8")


def _payload(n, seed, scale=1.0):
    rng = np.random.RandomState(seed)
    return jnp.asarray(rng.randn(n).astype(np.float32) * scale)


def _run_ledger(channel, engine="scan", algorithm="dagd", rounds=10,
                n=24, d=32, m=4):
    bundle = build_instance("random_ridge", n=n, d=d, m=m)
    algo = get_algorithm(algorithm)
    dist = LocalDistERM(bundle.prob, bundle.part, backend="einsum",
                        channel=channel)
    program = algo.program(dist, rounds=rounds,
                           **algo.make_kwargs(bundle.ctx))
    result = run_program(dist, program, engine=engine)
    return dist.comm.ledger, result


# --------------------------------------------------------------------------
# bit monotonicity
# --------------------------------------------------------------------------

@given(elems=st.integers(4, 4096), itemsize=st.sampled_from([4, 8]),
       rnd=st.integers(0, 40))
@settings(max_examples=6, deadline=None)
def test_coarser_stage_never_costs_more_wire_bits(elems, itemsize, rnd):
    """Pointwise: at every round, coarsening any stage of a schedule can
    only shrink that round's message cost."""
    for i in range(len(PRECISION_ORDER) - 1):
        fine = parse_channel(PRECISION_ORDER[i])
        coarse = parse_channel(PRECISION_ORDER[i + 1])
        assert coarse.wire_bits(elems, itemsize) <= \
            fine.wire_bits(elems, itemsize)
    sched = parse_channel("sched:identity@0,fp16@5,int8@20")
    coarsened = parse_channel("sched:fp16@0,int8@5,int8@20")
    assert coarsened.wire_bits(elems, itemsize, rnd=rnd) <= \
        sched.wire_bits(elems, itemsize, rnd=rnd)


@given(switch=st.integers(1, 9), seed=st.integers(0, 99))
@settings(max_examples=4, deadline=None)
def test_coarsened_schedule_shrinks_every_ledger_prefix(switch, seed):
    """Cumulative, on a real metered run: the coarsened schedule's
    bits_through_round(k) is <= the original's at EVERY k, not just in
    total."""
    del seed    # the run is deterministic; seed only spreads examples
    fine = f"sched:identity@0,fp16@{switch}"
    coarse = f"sched:fp16@0,int8@{switch}"
    led_f, _ = _run_ledger(fine, rounds=12)
    led_c, _ = _run_ledger(coarse, rounds=12)
    assert led_f.rounds == led_c.rounds == 12
    for k in range(13):
        assert led_c.bits_through_round(k) <= led_f.bits_through_round(k)


# --------------------------------------------------------------------------
# identity at round 0
# --------------------------------------------------------------------------

@given(n=st.integers(4, 300), seed=st.integers(0, 99),
       switch=st.integers(1, 9))
@settings(max_examples=6, deadline=None)
def test_fp32_head_passes_payloads_through_exactly(n, seed, switch):
    ch = parse_channel(f"sched:fp32@0,int8@{switch}")
    x = _payload(n, seed)
    for r in (0, switch - 1):
        np.testing.assert_array_equal(np.asarray(ch.apply(x, r)),
                                      np.asarray(x))
        assert ch.wire_bits(n, 4, rnd=r) == 32 * n
    # ...and the switch round is no longer identity
    assert ch.wire_bits(n, 4, rnd=switch) == 8 * n + 32


@given(switch=st.integers(2, 8))
@settings(max_examples=4, deadline=None)
def test_fp32_head_ledger_prefix_matches_identity(switch):
    """Before the first switch the schedule's metered stream is
    bit-identical to the identity wire — per round, via the marks."""
    led_id, _ = _run_ledger("identity", rounds=10)
    led_s, _ = _run_ledger(f"sched:fp32@0,int8@{switch}", rounds=10)
    assert led_s.round_marks == led_id.round_marks
    for k in range(switch + 1):
        assert led_s.bits_through_round(k) == \
            led_id.bits_through_round(k), k
    assert led_s.total_bits() < led_id.total_bits()


# --------------------------------------------------------------------------
# schedule-vs-constant equivalence
# --------------------------------------------------------------------------

@pytest.mark.parametrize("fixed", ["identity", "fp16", "int8",
                                   "topk:0.25"])
@pytest.mark.parametrize("engine", ["python", "scan"])
def test_one_entry_schedule_is_the_fixed_channel(fixed, engine):
    led_fix, res_fix = _run_ledger(fixed, engine=engine)
    led_one, res_one = _run_ledger(f"sched:{fixed}@0", engine=engine)
    assert led_one.typed_stream() == led_fix.typed_stream()
    assert led_one.round_marks == led_fix.round_marks
    assert led_one.rounds == led_fix.rounds
    np.testing.assert_array_equal(np.asarray(res_one.w),
                                  np.asarray(res_fix.w))


def test_one_entry_schedule_is_not_scheduled():
    """The scan engines' fast path: a single-stage schedule needs no
    round threading (that is WHY the streams above are bit-identical)."""
    assert parse_channel("sched:int8@0").scheduled is False
    assert parse_channel("sched:fp32@0").lossless is True
    assert parse_channel("sched:int8@0,fp16@3").scheduled is True


# --------------------------------------------------------------------------
# prefix additivity on non-uniform round structures
# --------------------------------------------------------------------------

@pytest.mark.parametrize("algorithm,rounds", [("disco_f", 12),
                                              ("dsvrg", 30)])
@pytest.mark.parametrize("engine", ["python", "scan"])
def test_bits_through_round_is_an_exact_prefix_sum(algorithm, rounds,
                                                   engine):
    """On multi-segment programs (Newton+CG, snapshot+epochs) under a
    mid-run schedule switch: bits_through_round(k) == the literal sum of
    the records the marks assign to the first k rounds, and splitting at
    any j is additive."""
    channel = f"sched:identity@0,int8@{rounds // 2}"
    led, _ = _run_ledger(channel, engine=engine, algorithm=algorithm,
                         rounds=rounds)
    assert len(led.round_marks) == led.rounds
    marks = [0] + list(led.round_marks)
    for k in range(led.rounds + 1):
        expect = sum(r.bits for r in led.records[:marks[k]])
        assert led.bits_through_round(k) == expect, k
    for j in (1, led.rounds // 2, led.rounds - 1):
        head = led.bits_through_round(j)
        tail = sum(r.bits for r in led.records[marks[j]:])
        assert head + tail == led.total_bits(), j


def test_round_records_price_at_the_active_stage():
    """Every vector record in round r carries exactly the bits of the
    stage active at r — the ledger is a faithful replay of the schedule,
    not an average."""
    rounds, switch = 12, 5
    ch = parse_channel(f"sched:identity@0,int8@{switch}")
    led, _ = _run_ledger(str(ch.name), engine="scan", rounds=rounds)
    marks = [0] + list(led.round_marks)
    for r in range(led.rounds):
        stage = ch.stage_at(r)
        for rec in led.records[marks[r]:marks[r + 1]]:
            itemsize = np.dtype(rec.dtype).itemsize
            if tuple(rec.shape) == ():
                assert rec.bits == 32          # scalars bypass channels
            elif rec.direction == "worker->all" and len(rec.shape) >= 2:
                m = rec.shape[0]
                assert rec.bits == m * stage.wire_bits(rec.elems // m,
                                                       itemsize), r
            else:
                assert rec.bits == stage.wire_bits(rec.elems, itemsize), r


# --------------------------------------------------------------------------
# gap channels resolve to schedules deterministically
# --------------------------------------------------------------------------

def test_gap_channel_resolution_is_deterministic_and_monotone():
    from repro.core.channel import GapChannel
    gap = parse_channel("gap:int8,fp16@0.01,identity@0.0001")
    assert isinstance(gap, GapChannel)
    gaps = np.array([1.0, 0.5, 0.02, 0.009, 0.001, 5e-5, 1e-6])
    sched = gap.resolve(gaps)
    # threshold 0.01 first crossed at index 3 -> switch at round 4;
    # 1e-4 first crossed at index 5 -> switch at round 6
    assert sched.name == "sched:int8@0,fp16@4,identity@6"
    assert sched.name == make_schedule(
        [(0, parse_channel("int8")), (4, parse_channel("fp16")),
         (6, parse_channel("identity"))]).name
    # an unreached threshold drops its stage
    sched2 = gap.resolve(np.array([1.0, 0.5, 0.009]))
    assert sched2.name == "sched:int8@0,fp16@3"
    # a communicator refuses an unresolved gap channel
    from repro.core.comm import LocalCommunicator
    with pytest.raises(ValueError, match="resolve"):
        LocalCommunicator(2, channel=gap)
