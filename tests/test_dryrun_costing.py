"""Calibration of the dry-run costing methodology.

Two facts the roofline relies on, pinned by tests:
  1. cost_analysis() of an SPMD-partitioned module reports PER-DEVICE
     flops (a sharded matmul reports total/shards).
  2. a lax.scan body is counted ONCE regardless of trip count, and the
     two-point scan_unroll extrapolation recovers the full cost.
Run in a subprocess so the 4-device flag doesn't leak.
"""
import json
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

def cost(compiled):
    # newer jax returns a per-partition list of dicts (same guard as
    # repro.launch.dryrun)
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca

out = {}
mesh = Mesh(np.array(jax.devices()).reshape(4), ("model",))
M = N = K = 512
a = jax.ShapeDtypeStruct((M, K), jnp.float32)
b = jax.ShapeDtypeStruct((K, N), jnp.float32)
jt = jax.jit(lambda a, b: a @ b,
             in_shardings=(NamedSharding(mesh, P(None, None)),
                           NamedSharding(mesh, P(None, "model"))))
ca = cost(jt.lower(a, b).compile())
out["matmul_flops"] = float(ca["flops"])
out["matmul_expected_per_device"] = 2.0 * M * N * K / 4

def scanned(x, ws, unroll):
    def body(x, w):
        return jnp.tanh(x @ w), None
    y, _ = jax.lax.scan(body, x, ws, unroll=unroll)
    return y

x = jax.ShapeDtypeStruct((128, 256), jnp.float32)
ws = jax.ShapeDtypeStruct((8, 256, 256), jnp.float32)
fl = {}
for u in (1, 2):
    ca = cost(jax.jit(lambda x, ws, u=u: scanned(x, ws, u)).lower(
        x, ws).compile())
    fl[u] = float(ca["flops"])
R, k = 8, 2
out["scan_corrected"] = fl[1] + (R - 1) / (k - 1) * (fl[2] - fl[1])
out["scan_expected"] = 2.0 * 128 * 256 * 256 * 8
print(json.dumps(out))
"""


@pytest.mark.slow
def test_costing_calibration():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    res = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=300)
    assert res.returncode == 0, res.stderr[-2000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    # (1) per-device semantics
    assert out["matmul_flops"] == pytest.approx(
        out["matmul_expected_per_device"], rel=0.01)
    # (2) two-point scan correction recovers the full-trip cost (tanh
    # transcendentals add a small constant; 5% slack)
    assert out["scan_corrected"] == pytest.approx(out["scan_expected"],
                                                  rel=0.05)
