"""Deterministic stand-in for ``hypothesis`` when it is not installed.

Registered by ``conftest.py`` as the ``hypothesis`` module so property
tests still collect and run: ``@given`` replays each test over a small
fixed spread of examples (range endpoints + seeded pseudo-random fills)
instead of hypothesis' adaptive search. Only the API surface this suite
uses is provided: ``given(**kwargs)``, ``settings(max_examples=,
deadline=)``, and ``strategies.integers/floats/sampled_from/booleans``.
"""
from __future__ import annotations

import random
import types

_MAX_FALLBACK_EXAMPLES = 6


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, i: int, n: int, rng: random.Random):
        return self._draw(i, n, rng)


def _integers(min_value, max_value):
    def draw(i, n, rng):
        if i == 0:
            return min_value
        if i == 1 and max_value != min_value:
            return max_value
        return rng.randint(min_value, max_value)
    return _Strategy(draw)


def _floats(min_value, max_value, **_ignored):
    def draw(i, n, rng):
        if i == 0:
            return float(min_value)
        if i == 1:
            return float(max_value)
        return rng.uniform(min_value, max_value)
    return _Strategy(draw)


def _sampled_from(elements):
    seq = list(elements)

    def draw(i, n, rng):
        return seq[i % len(seq)]
    return _Strategy(draw)


def _booleans():
    return _sampled_from([False, True])


def settings(**kwargs):
    """Records max_examples on the function; everything else is ignored."""
    def deco(fn):
        fn._fallback_settings = kwargs
        return fn
    return deco


def given(*args, **strats):
    if args:
        raise TypeError("hypothesis fallback supports keyword strategies "
                        "only, e.g. @given(m=st.integers(1, 5))")

    def deco(fn):
        conf = getattr(fn, "_fallback_settings", {})
        n = min(int(conf.get("max_examples", _MAX_FALLBACK_EXAMPLES)),
                _MAX_FALLBACK_EXAMPLES)
        names = sorted(strats)
        rng = random.Random(f"{fn.__module__}.{fn.__qualname__}")
        cases = [{k: strats[k].example(i, n, rng) for k in names}
                 for i in range(n)]

        # Deliberately NOT functools.wraps: pytest must see the (*a, **kw)
        # signature, not the strategy parameters, or it would try to
        # resolve them as fixtures.
        def wrapper(*a, **kw):
            for case in cases:
                fn(*a, **{**kw, **case})
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        return wrapper
    return deco


strategies = types.SimpleNamespace(
    integers=_integers, floats=_floats, sampled_from=_sampled_from,
    booleans=_booleans)

__all__ = ["given", "settings", "strategies"]
