"""Prefill-then-decode == full forward (the production serving flow)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get
from repro.models import transformer as T
from repro.models.common import unbox

ARCHS = ["qwen1-5-32b", "gemma3-27b", "mamba2-780m",
         "jamba-1-5-large-398b", "granite-moe-1b-a400m", "paligemma-3b"]


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode_matches_forward(arch):
    import dataclasses
    cfg = get(arch).smoke()
    if cfg.moe is not None:
        # ample capacity: token-drop patterns depend on prompt length and
        # would (correctly) differ between the two paths under test
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=16.0))
    params, _ = unbox(T.init_params(jax.random.PRNGKey(0), cfg))
    B, S_pre, S_all = 2, 8, 12
    key = jax.random.PRNGKey(1)
    n_prefix = cfg.n_prefix if cfg.prefix_lm else 0
    tokens = jax.random.randint(key, (B, S_all), 0, cfg.vocab)
    prefix = jax.random.normal(key, (B, n_prefix, cfg.d_model),
                               cfg.dtype) if n_prefix else None

    # reference: one-shot prefill over the whole sequence
    lg_full, _ = T.prefill(params, cfg, tokens, prefix,
                           max_seq=S_all + n_prefix)

    # serving flow: prefill the first S_pre tokens, decode the rest
    lg, cache = T.prefill(params, cfg, tokens[:, :S_pre], prefix,
                          max_seq=S_all + n_prefix)
    for t in range(S_pre, S_all):
        lg, cache = T.decode_step(params, cfg, tokens[:, t:t + 1], cache)

    np.testing.assert_allclose(np.asarray(lg, np.float32),
                               np.asarray(lg_full, np.float32),
                               atol=2e-2, rtol=2e-2)


def test_windowed_ring_prime():
    """Prefill longer than the window still primes a correct ring."""
    import dataclasses
    cfg = get("gemma3-27b").smoke()
    # shrink the local window below the prompt length to exercise the roll
    local = dataclasses.replace(cfg.pattern[0], window=4)
    cfg = dataclasses.replace(cfg, pattern=(local, cfg.pattern[1]))
    params, _ = unbox(T.init_params(jax.random.PRNGKey(0), cfg))
    B, S_pre, S_all = 1, 9, 13
    tokens = jax.random.randint(jax.random.PRNGKey(2), (B, S_all), 0,
                                cfg.vocab)
    lg_full, _ = T.prefill(params, cfg, tokens, max_seq=S_all)
    lg, cache = T.prefill(params, cfg, tokens[:, :S_pre], max_seq=S_all)
    for t in range(S_pre, S_all):
        lg, cache = T.decode_step(params, cfg, tokens[:, t:t + 1], cache)
    np.testing.assert_allclose(np.asarray(lg, np.float32),
                               np.asarray(lg_full, np.float32),
                               atol=2e-2, rtol=2e-2)
