"""Metrics substrate: JSONL logging, EMA, timer percentiles."""
import json
import time

from repro.metrics import MetricsLogger, StepTimer


def test_jsonl_roundtrip(tmp_path):
    lg = MetricsLogger(str(tmp_path), tokens_per_step=100)
    for s in range(5):
        lg.log(s, {"loss": 2.0 - 0.1 * s})
    lg.close()
    lines = [json.loads(l) for l in
             open(tmp_path / "metrics.jsonl").read().splitlines()]
    assert len(lines) == 5
    assert lines[-1]["loss"] == 1.6
    assert lines[0]["step"] == 0


def test_ema_smoothing():
    lg = MetricsLogger(None, ema=0.5)
    lg.log(0, {"loss": 4.0})
    out = lg.log(1, {"loss": 0.0})
    assert out["loss"] == 2.0
    line = lg.line(1, 0.01)
    assert "loss 2.0000" in line


def test_timer_excludes_warmup():
    t = StepTimer(warmup=1)
    for _ in range(4):
        t.start()
        time.sleep(0.01)
        t.stop()
    s = t.summary()
    assert s["steps_timed"] == 3
    assert 0.005 < s["p50_s"] < 0.1
