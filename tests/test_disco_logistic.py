"""DISCO-F beyond quadratics: damped-Newton outer loop on logistic ERM,
plus DSVRG parity between the local and shard_map backends."""
import json
import os
import subprocess
import sys

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import make_random_erm
from repro.core.partition import even_partition
from repro.core.runtime import LocalDistERM
from repro.core.algorithms import dagd, disco_f


def test_disco_f_logistic_newton():
    """Multiple damped-Newton steps minimize a logistic ERM to high
    accuracy within the same round budget DAGD needs."""
    prob = make_random_erm(n=64, d=24, loss="logistic", lam=0.05, seed=5)
    part = even_partition(24, 4)
    L = prob.smoothness_bound()

    # reference optimum via many DAGD rounds
    dist_ref = LocalDistERM(prob, part)
    w_ref = dist_ref.gather_w(dagd(dist_ref, rounds=3000, L=L,
                                   lam=prob.lam))
    f_ref = float(prob.value(w_ref))

    dist = LocalDistERM(prob, part)
    w = disco_f(dist, rounds=60, L=L, lam=prob.lam, newton_steps=4)
    gap = float(prob.value(dist.gather_w(w))) - f_ref
    assert gap < 1e-6, gap
    # budget still respected on the non-quadratic path
    dist.comm.ledger.assert_budget(n=prob.n, d=prob.d)


SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import json
import jax.numpy as jnp
from repro.core import make_random_erm
from repro.core.partition import even_partition
from repro.core.runtime import LocalDistERM, _run_sharded
from repro.core.algorithms import dsvrg

prob = make_random_erm(n=16, d=16, loss="squared", lam=0.2, seed=9)
L_max = float(jnp.max(jnp.sum(prob.A ** 2, axis=1))) + prob.lam
kw = dict(L_max=L_max, lam=prob.lam, seed=3, epoch_len=8)
w_sh, led = _run_sharded(prob, lambda d_, r: dsvrg(d_, r, **kw), rounds=200)
dist = LocalDistERM(prob, even_partition(16, 4))
w_lo = dist.gather_w(dsvrg(dist, 200, **kw))
print(json.dumps({"max_diff": float(jnp.max(jnp.abs(w_sh - w_lo)))}))
"""


@pytest.mark.slow
def test_dsvrg_shard_map_parity():
    """The incremental family also runs identically under shard_map
    (same RNG seed -> same component sequence -> same iterates)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    res = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert res.returncode == 0, res.stderr[-3000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    assert out["max_diff"] < 1e-4, out
