"""Retired legacy entry points must fail loudly, naming the RunSpec
replacement.

PR 4 left deprecation shims over the repro.api facade (``run_sharded``'s
per-call kwargs, the sweep CLI's ``--backend``/``--engine`` flags, the
dryrun CLI's ``--oracle-backend``/``--round-engine``).  They are now
removed: each former entry point raises/errors with a message that spells
out the equivalent ``repro.api.RunSpec`` construction, so a stale script
dies with its migration instructions instead of a silent behavior change
or an anonymous TypeError.
"""
import warnings

import numpy as np
import pytest

from repro.api import RunSpec, run
from repro.experiments.instances import build_instance


def _stream(led):
    return led.rounds, [(r.kind, r.elems, r.bytes, r.tag)
                        for r in led.records]


# --------------------------------------------------------------------------
# run_sharded kwargs
# --------------------------------------------------------------------------

def test_run_sharded_removed_with_runspec_pointer():
    from repro.core.runtime import run_sharded

    bundle = build_instance("random_ridge", n=16, d=12, m=1)
    with pytest.raises(TypeError) as ei:
        run_sharded(bundle.prob, lambda d_, r: None, rounds=8)
    msg = str(ei.value)
    assert "removed" in msg
    assert "repro.api.RunSpec" in msg
    assert "placement='sharded'" in msg
    assert "_run_sharded" in msg        # the internal driver, for library code


@pytest.mark.parametrize("engine", ["python", "scan"])
def test_runspec_path_replaces_run_sharded(engine):
    """The replacement the error points at actually runs the old cell."""
    params = dict(n=16, d=12, m=1)
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        res = run(RunSpec(instance="random_ridge", instance_params=params,
                          algorithm="dagd", rounds=8, measure="none",
                          placement="sharded", engine=engine))
    assert res.placement == "sharded"
    assert res.ledger.rounds == 8
    assert np.all(np.isfinite(np.asarray(res.w)))


# --------------------------------------------------------------------------
# sweep CLI flags
# --------------------------------------------------------------------------

@pytest.mark.parametrize("flag, value, field", [
    ("--backend", "einsum", "backend"),
    ("--engine", "scan", "engine"),
])
def test_sweep_cli_flags_error_naming_runspec(capsys, flag, value, field):
    from repro.experiments import sweep

    with pytest.raises(SystemExit) as ei:
        sweep.main(["--preset", "thm2-small", flag, value,
                    "--no-report", "-q"])
    assert ei.value.code == 2
    err = capsys.readouterr().err
    assert "removed" in err
    assert "RunSpec" in err
    assert f"{field}={value!r}" in err


def test_sweep_cli_without_flags_still_works(monkeypatch):
    from repro.experiments import sweep

    monkeypatch.setattr(
        sweep, "run_sweep",
        lambda spec, **kw: sweep.SweepResult(spec=spec, records=[],
                                             command="probe"))
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        assert sweep.main(["--preset", "thm2-small", "--no-report",
                           "-q"]) == 0


def test_sweep_programmatic_kwargs_still_work():
    """Only the CLI flags were retired; run_sweep's programmatic axis
    kwargs remain the supported library surface."""
    from repro.experiments.sweep import SweepSpec, run_sweep

    spec = SweepSpec(
        name="shim-probe", instance="thm2_chain",
        grid=dict(d=[16], kappa=[8.0], lam=[0.5], m=[2]),
        algorithms=("dagd",), eps=(1e-3,), max_rounds=100)
    explicit = run_sweep(spec, backend="einsum", engine="scan")
    auto = run_sweep(spec)     # auto resolves to the same on CPU
    for a, b in zip(explicit.records, auto.records):
        da, db = a.to_dict(), b.to_dict()
        # the embedded spec records what was requested (explicit vs auto);
        # everything measured/metered must be identical
        assert da.pop("run_spec")["backend"] == "einsum"
        assert db.pop("run_spec")["backend"] == "auto"
        assert da == db


# --------------------------------------------------------------------------
# dryrun legacy axis kwargs / flags
# --------------------------------------------------------------------------

def test_dryrun_legacy_kwargs_error_naming_runspec():
    from repro.launch.dryrun import run_all

    with pytest.raises(TypeError) as ei:
        run_all("/tmp/dryrun-shim-probe", False,
                oracle_backend="einsum", round_engine="python")
    msg = str(ei.value)
    assert "removed" in msg
    assert "axes=RunSpec(backend='einsum', engine='python')" in msg


def test_dryrun_legacy_error_spells_defaults():
    from repro.launch.dryrun import _legacy_axes_error

    msg = str(_legacy_axes_error(None, "scan"))
    assert "axes=RunSpec(backend='auto', engine='scan')" in msg
    msg = str(_legacy_axes_error("kernel", None))
    assert "axes=RunSpec(backend='kernel', engine='auto')" in msg
