"""Deprecation shims over the repro.api facade.

Each legacy entry point — ``run_sharded``'s per-call kwargs, the sweep
CLI's ``--backend``/``--engine`` flags, the dryrun CLI's
``--oracle-backend``/``--round-engine`` — must (a) emit exactly one
``DeprecationWarning`` per invocation and (b) produce bit-identical
ledgers and iterates versus the equivalent ``RunSpec`` path, so existing
invocations keep working while the facade is the one canonical surface.
"""
import warnings

import numpy as np
import pytest

from repro.api import RunSpec, run
from repro.experiments.instances import build_instance


def _stream(led):
    return led.rounds, [(r.kind, r.elems, r.bytes, r.tag)
                        for r in led.records]


# --------------------------------------------------------------------------
# run_sharded kwargs
# --------------------------------------------------------------------------

@pytest.mark.parametrize("engine", ["python", "scan"])
def test_run_sharded_warns_once_and_matches_runspec_path(engine):
    from repro.core.runtime import run_sharded
    from repro.core.algorithms import dagd, dagd_program

    params = dict(n=16, d=12, m=1)
    bundle = build_instance("random_ridge", **params)
    L, lam = bundle.ctx.L, bundle.prob.lam

    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        if engine == "python":
            w, led = run_sharded(
                bundle.prob, lambda d_, r: dagd(d_, r, L=L, lam=lam),
                rounds=8)
        else:
            w, led = run_sharded(
                bundle.prob, None, rounds=8, engine="scan",
                program_builder=lambda d_, r: dagd_program(d_, r, L=L,
                                                           lam=lam))
    dep = [w_ for w_ in caught
           if issubclass(w_.category, DeprecationWarning)]
    assert len(dep) == 1
    assert "repro.api.RunSpec" in str(dep[0].message)

    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)   # none here
        res = run(RunSpec(instance="random_ridge", instance_params=params,
                          algorithm="dagd", rounds=8, measure="none",
                          placement="sharded", engine=engine))
    assert _stream(res.ledger) == _stream(led)
    np.testing.assert_array_equal(np.asarray(res.w), np.asarray(w))


# --------------------------------------------------------------------------
# sweep CLI flags
# --------------------------------------------------------------------------

@pytest.mark.parametrize("flag, value, kwarg", [
    ("--backend", "einsum", "backend"),
    ("--engine", "scan", "engine"),
])
def test_sweep_cli_flags_warn_and_feed_runspecs(monkeypatch, flag, value,
                                                kwarg):
    from repro.experiments import sweep

    captured = {}

    def fake_run_sweep(spec, **kwargs):
        captured.update(kwargs)
        return sweep.SweepResult(spec=spec, records=[], command="probe")

    monkeypatch.setattr(sweep, "run_sweep", fake_run_sweep)
    with pytest.warns(DeprecationWarning, match="legacy entry point"):
        rc = sweep.main(["--preset", "thm2-small", flag, value,
                         "--no-report", "-q"])
    assert rc == 0
    assert captured[kwarg] == value    # the flag feeds the RunSpec field


def test_sweep_cli_without_flags_is_warning_free(monkeypatch):
    from repro.experiments import sweep

    monkeypatch.setattr(
        sweep, "run_sweep",
        lambda spec, **kw: sweep.SweepResult(spec=spec, records=[],
                                             command="probe"))
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        assert sweep.main(["--preset", "thm2-small", "--no-report",
                           "-q"]) == 0


def test_sweep_flag_and_runspec_paths_produce_identical_records():
    from repro.experiments.sweep import SweepSpec, run_sweep

    spec = SweepSpec(
        name="shim-probe", instance="thm2_chain",
        grid=dict(d=[16], kappa=[8.0], lam=[0.5], m=[2]),
        algorithms=("dagd",), eps=(1e-3,), max_rounds=100)
    legacy = run_sweep(spec, backend="einsum", engine="scan")
    explicit = run_sweep(spec)     # auto resolves to the same on CPU
    for a, b in zip(legacy.records, explicit.records):
        da, db = a.to_dict(), b.to_dict()
        # the embedded spec records what was requested (explicit vs auto);
        # everything measured/metered must be identical
        assert da.pop("run_spec")["backend"] == "einsum"
        assert db.pop("run_spec")["backend"] == "auto"
        assert da == db


# --------------------------------------------------------------------------
# dryrun legacy axis kwargs
# --------------------------------------------------------------------------

def test_dryrun_legacy_axes_warn_and_resolve_through_api():
    from repro.api import plan
    from repro.launch.dryrun import _legacy_axes

    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        spec = _legacy_axes("einsum", "python")
    dep = [w for w in caught if issubclass(w.category, DeprecationWarning)]
    assert len(dep) == 1
    assert "repro.api.RunSpec" in str(dep[0].message)
    assert spec == RunSpec(backend="einsum", engine="python")
    pl = plan(spec)
    assert (pl.backend, pl.engine) == ("einsum", "python")
    # None means "not requested": the spec falls back to auto
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        assert _legacy_axes(None, "scan") == RunSpec(backend="auto",
                                                     engine="scan")
