"""ERM oracles: partial gradients/HVPs assemble to the full ones —
the identity that makes one R^n ReduceAll per round sufficient."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

# `hypothesis` may be absent: tests/conftest.py installs the deterministic
# fallback (tests/_hypothesis_fallback.py) before collection, so this
# import — and every other property-test module — collects cleanly.
from hypothesis import given, settings, strategies as st

from repro.core.erm import LOSSES, make_random_erm
from repro.core.partition import even_partition


@pytest.mark.parametrize("loss", ["squared", "logistic", "squared_hinge"])
def test_gradient_matches_autodiff(loss):
    prob = make_random_erm(n=20, d=15, loss=loss, lam=0.1, seed=0)
    w = jax.random.normal(jax.random.PRNGKey(1), (15,))
    g_manual = prob.gradient(w)
    g_auto = jax.grad(prob.value)(w)
    np.testing.assert_allclose(g_manual, g_auto, atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("loss", ["squared", "logistic"])
def test_hvp_matches_autodiff(loss):
    prob = make_random_erm(n=20, d=15, loss=loss, lam=0.1, seed=0)
    w = jax.random.normal(jax.random.PRNGKey(1), (15,))
    v = jax.random.normal(jax.random.PRNGKey(2), (15,))
    hvp_auto = jax.jvp(jax.grad(prob.value), (w,), (v,))[1]
    np.testing.assert_allclose(prob.hvp(w, v), hvp_auto, atol=1e-5,
                               rtol=1e-5)


@given(m=st.integers(1, 6), seed=st.integers(0, 20))
@settings(max_examples=20, deadline=None)
def test_partial_gradients_assemble(m, seed):
    prob = make_random_erm(n=12, d=18, loss="logistic", lam=0.05, seed=seed)
    part = even_partition(18, m)
    w = jax.random.normal(jax.random.PRNGKey(seed), (18,))
    wjs = part.split_vector(w)
    Ajs = part.split_columns(prob.A)
    # the single ReduceAll quantity
    z = sum(prob.local_response(Aj, wj) for Aj, wj in zip(Ajs, wjs))
    np.testing.assert_allclose(z, prob.A @ w, atol=1e-5, rtol=1e-5)
    g_parts = [prob.partial_gradient(Aj, wj, z)
               for Aj, wj in zip(Ajs, wjs)]
    np.testing.assert_allclose(part.concat_blocks(g_parts),
                               prob.gradient(w), atol=1e-5, rtol=1e-5)


def test_partial_hvp_assembles():
    prob = make_random_erm(n=14, d=10, loss="squared", lam=0.2, seed=3)
    part = even_partition(10, 3)
    w = jax.random.normal(jax.random.PRNGKey(0), (10,))
    v = jax.random.normal(jax.random.PRNGKey(1), (10,))
    Ajs = part.split_columns(prob.A)
    wjs, vjs = part.split_vector(w), part.split_vector(v)
    z = prob.A @ w
    av = prob.A @ v
    parts = [prob.partial_hvp(Aj, vj, z, av) for Aj, vj in zip(Ajs, vjs)]
    np.testing.assert_allclose(part.concat_blocks(parts), prob.hvp(w, v),
                               atol=1e-5, rtol=1e-5)


def test_smoothness_bound_is_upper_bound():
    prob = make_random_erm(n=30, d=20, loss="squared", lam=0.1, seed=0)
    H = np.asarray(prob.A.T @ prob.A) / prob.n + prob.lam * np.eye(20)
    lmax = float(np.linalg.eigvalsh(H).max())
    assert prob.smoothness_bound() >= lmax - 1e-6
