"""Feature-partition bookkeeping invariants (unit + property)."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.partition import FeaturePartition, even_partition


@given(d=st.integers(1, 200), m=st.integers(1, 16))
@settings(max_examples=50, deadline=None)
def test_even_partition_covers(d, m):
    if d < m:
        with pytest.raises(ValueError):
            even_partition(d, m)
        return
    part = even_partition(d, m)
    assert sum(part.block_sizes) == d
    assert part.m == m
    # blocks are contiguous, disjoint, complete
    seen = []
    for j in range(m):
        seen.extend(list(part.coords(j)))
    assert seen == list(range(d))


@given(d=st.integers(2, 100), m=st.integers(1, 8), seed=st.integers(0, 99))
@settings(max_examples=30, deadline=None)
def test_split_concat_roundtrip(d, m, seed):
    if d < m:
        return
    part = even_partition(d, m)
    rng = np.random.RandomState(seed)
    w = jnp.asarray(rng.randn(d))
    blocks = part.split_vector(w)
    assert np.allclose(part.concat_blocks(blocks), w)
    stacked = part.pad_blocks(blocks)
    assert stacked.shape == (m, part.d_max)
    unpadded = part.unpad_blocks(stacked)
    assert np.allclose(part.concat_blocks(unpadded), w)


def test_owner():
    part = FeaturePartition(d=10, block_sizes=(3, 3, 4))
    assert [part.owner(i) for i in range(10)] == \
        [0, 0, 0, 1, 1, 1, 2, 2, 2, 2]


def test_column_split_matches_matmul():
    rng = np.random.RandomState(0)
    A = jnp.asarray(rng.randn(7, 12))
    w = jnp.asarray(rng.randn(12))
    part = even_partition(12, 5)
    Ajs = part.split_columns(A)
    wjs = part.split_vector(w)
    z = sum(Aj @ wj for Aj, wj in zip(Ajs, wjs))
    assert np.allclose(z, A @ w, atol=1e-6)


def test_mask_marks_padding():
    part = FeaturePartition(d=7, block_sizes=(4, 3))
    m = np.asarray(part.mask())
    assert m.shape == (2, 4)
    assert m[0].tolist() == [1, 1, 1, 1]
    assert m[1].tolist() == [1, 1, 1, 0]
