"""Logical-axis rules engine: spec derivation, dedup, sanitization."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import Mesh, PartitionSpec as P
import numpy as np

from repro.models.common import (Boxed, box, logical_to_spec, make_rules,
                                 sanitize_spec_for_shape, unbox)
from repro.launch import sharding as shd


def test_default_rules_feature_partition():
    rules = make_rules(mesh_axes=("data", "model"))
    assert logical_to_spec(("embed", "mlp"), rules) == P(None, "model")
    assert logical_to_spec(("embed", "heads", "head_dim"), rules) == \
        P(None, "model", None)
    assert logical_to_spec(("batch", "seq"), rules) == P(("data",), None) \
        or logical_to_spec(("batch", "seq"), rules) == P("data", None)


def test_pod_axis_dropped_on_single_pod():
    rules = make_rules(mesh_axes=("data", "model"))
    spec = logical_to_spec(("batch",), rules)
    flat = spec[0]
    assert flat in ("data", ("data",))


def test_axis_dedup():
    """A mesh axis may appear once: batch takes (pod,data), embed loses it."""
    rules = make_rules(fsdp=True, mesh_axes=("pod", "data", "model"))
    spec = logical_to_spec(("batch", "seq", "embed"), rules)
    assert spec[0] == ("pod", "data")
    assert spec[2] is None  # deduped against batch


def test_fsdp_overlay_on_params():
    rules = make_rules(fsdp=True, mesh_axes=("pod", "data", "model"))
    spec = logical_to_spec(("embed", "mlp"), rules)
    assert spec == P(("pod", "data"), "model")


def test_sanitize_drops_nondivisible():
    mesh = Mesh(np.array(jax.devices() * 1).reshape(1, 1),
                ("data", "model"))
    # fake a 16-way model axis via explicit sizes by building mesh-like obj
    class FakeMesh:
        axis_names = ("data", "model")
        class devices:
            shape = (16, 16)
    spec = sanitize_spec_for_shape(P(None, None, "model", None),
                                   (24, 1024, 8, 64), FakeMesh)
    assert spec == P(None, None, None, None)
    spec2 = sanitize_spec_for_shape(P(None, "model"), (24, 1024), FakeMesh)
    assert spec2 == P(None, "model")
    # tuple assignment: trailing axes dropped until divisible
    spec3 = sanitize_spec_for_shape(P(("data", "model"),), (16,), FakeMesh)
    assert spec3 == P("data")


def test_boxed_roundtrip_and_specs():
    tree = {"w": box(jnp.zeros((4, 6)), "embed", "mlp"),
            "b": box(jnp.zeros((6,)), "mlp")}
    params, logical = unbox(tree)
    assert params["w"].shape == (4, 6)
    assert logical == {"w": ("embed", "mlp"), "b": ("mlp",)}
    rules = make_rules(mesh_axes=("data", "model"))
    specs = shd.param_specs(logical, rules)
    assert specs["w"] == P(None, "model")
    assert specs["b"] == P("model")


def test_cache_specs_by_name():
    rules = make_rules(mesh_axes=("data", "model"))
    cache = {
        "k": jax.ShapeDtypeStruct((4, 2, 128, 8, 64), jnp.bfloat16),
        "v": jax.ShapeDtypeStruct((4, 2, 128, 8, 64), jnp.bfloat16),
        "index": jax.ShapeDtypeStruct((4,), jnp.int32),
        "state": jax.ShapeDtypeStruct((2, 8, 16, 32), jnp.float32),
    }
    specs = shd.cache_specs(cache, rules)
    # stacked (layers) dim detected and replicated; kv_heads -> model
    assert specs["k"] == P(None, "data", None, "model", None)
    assert specs["state"] == P("data", "model", None, None)
    assert specs["index"] in (P(), P(None))  # replicated either way


def test_abstract_params_no_allocation():
    """eval_shape init path gives SDS leaves + logical axes."""
    from repro.configs import get
    from repro.models import transformer as T
    cfg = get("qwen1.5-32b").smoke()
    abs_params, logical = shd.abstract_params(
        lambda k: T.init_params(k, cfg), jax.random.PRNGKey(0))
    leaves = jax.tree_util.tree_leaves(abs_params)
    assert all(isinstance(l, jax.ShapeDtypeStruct) for l in leaves)
    n = sum(l.size for l in leaves)
    assert n > 0
