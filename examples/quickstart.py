"""Quickstart: feature-partitioned distributed optimization in 30 lines.

Solves a ridge-regression ERM with the paper's communication model:
4 "machines" each own a block of FEATURE columns; every round costs ONE
ReduceAll of an R^n vector; machine j only ever updates its own block.
The whole run is one declarative ``RunSpec`` — ``repro.api.run``
validates it, resolves the execution axes (scan engine, platform oracle
backend), and returns the iterate plus the metered communication bill.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp

from repro.api import RunSpec, run
from repro.core import thm2_strongly_convex
from repro.experiments.instances import build_instance

# 1. the run, declaratively: n=512 samples, d=1024 features (d > n: the
#    regime where the paper says feature partitioning wins on
#    communication), 4 machines, distributed accelerated gradient
#    descent (the algorithm that MATCHES the Theorem-2 lower bound)
params = dict(n=512, d=1024, m=4, lam=1e-2, seed=1)
bundle = build_instance("random_ridge", **params)
res = run(RunSpec(instance="random_ridge", instance_params=params,
                  algorithm="dagd", rounds=300, measure="none"),
          bundle=bundle)               # share the built instance

# 2. inspect solution + communication bill
prob = bundle.prob
H = prob.A.T @ prob.A / prob.n + prob.lam * jnp.eye(prob.d)
w_star = jnp.linalg.solve(H, prob.A.T @ prob.y / prob.n)
gap = float(prob.value(res.w)) - float(prob.value(w_star))
led = res.ledger
print(f"suboptimality f(w)-f*     : {gap:.3e}")
print(f"communication rounds      : {led.rounds}")
print(f"bytes per round           : {led.bytes_per_round():.0f} "
      f"(= one R^n ReduceAll; n={prob.n})")
print(f"total ReduceAll ops       : {led.op_counts()}")
lb = thm2_strongly_convex(prob.smoothness_bound() / prob.lam, prob.lam,
                          float(jnp.linalg.norm(w_star)), 1e-6)
print(f"Thm-2 lower bound (eps=1e-6): {lb.rounds:.0f} rounds")
print(f"paper's O(n+d)/round communication budget: "
      f"{'RESPECTED' if res.budget_ok else 'VIOLATED'}")
sys.exit(0 if res.budget_ok else 1)
