"""Quickstart: feature-partitioned distributed optimization in 40 lines.

Solves a ridge-regression ERM with the paper's communication model:
4 "machines" each own a block of FEATURE columns; every round costs ONE
ReduceAll of an R^n vector; machine j only ever updates its own block.

    PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp

from repro.core import make_random_erm, thm2_strongly_convex
from repro.core.partition import even_partition
from repro.core.runtime import LocalDistERM
from repro.core.algorithms import dagd

# 1. an ERM problem: n=512 samples, d=1024 features (d > n: the regime
#    where the paper says feature partitioning wins on communication)
prob = make_random_erm(n=512, d=1024, loss="squared", lam=1e-2, seed=0)

# 2. partition the FEATURES across 4 machines
part = even_partition(prob.d, m=4)
dist = LocalDistERM(prob, part)

# 3. run distributed accelerated gradient descent (the algorithm that
#    MATCHES the paper's Theorem-2 lower bound)
L = prob.smoothness_bound()
w_blocks = dagd(dist, rounds=300, L=L, lam=prob.lam)
w = dist.gather_w(w_blocks)

# 4. inspect solution + communication bill
H = prob.A.T @ prob.A / prob.n + prob.lam * jnp.eye(prob.d)
w_star = jnp.linalg.solve(H, prob.A.T @ prob.y / prob.n)
gap = float(prob.value(w)) - float(prob.value(w_star))
led = dist.comm.ledger
print(f"suboptimality f(w)-f*     : {gap:.3e}")
print(f"communication rounds      : {led.rounds}")
print(f"bytes per round           : {led.bytes_per_round():.0f} "
      f"(= one R^n ReduceAll; n={prob.n})")
print(f"total ReduceAll ops       : {led.op_counts()}")
lb = thm2_strongly_convex(L / prob.lam, prob.lam,
                          float(jnp.linalg.norm(w_star)), 1e-6)
print(f"Thm-2 lower bound (eps=1e-6): {lb.rounds:.0f} rounds")
led.assert_budget(n=prob.n, d=prob.d)
print("paper's O(n+d)/round communication budget: RESPECTED")
