"""End-to-end driver: train a ~100M-param decoder LM for a few hundred
steps on synthetic bigram data and watch the loss fall.

On CPU this uses a scaled-down (but same-family) model by default; pass
--full100m to run the actual ~100M config (slow on CPU, sized for a
single TPU host).

    PYTHONPATH=src python examples/train_lm.py --steps 200
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import time

import jax
import jax.numpy as jnp

from repro.models.layers import MLPConfig
from repro.models.transformer import LayerSpec, ModelConfig
from repro.models import transformer as T
from repro.models.common import unbox
from repro.configs._common import attn
from repro.launch.steps import make_train_step
from repro.optim import OptConfig, adamw_init
from repro.data import TokenDataConfig, synthetic_lm_batches
from repro.checkpoint import save_checkpoint


def model_100m():
    # ~100M params: 12L, d=768, 12H, ff=3072, vocab=32768
    return ModelConfig(
        name="repro-lm-100m", vocab=32768, d_model=768, n_layers=12,
        pattern=(LayerSpec("attn", "dense"),),
        attn=attn(768, 12, 12, 64, q_chunk=256),
        mlp=MLPConfig(d_model=768, d_ff=3072, activation="swiglu"),
        norm="rmsnorm", remat="none", dtype=jnp.float32)


def model_small():
    return ModelConfig(
        name="repro-lm-small", vocab=2048, d_model=256, n_layers=4,
        pattern=(LayerSpec("attn", "dense"),),
        attn=attn(256, 8, 4, 32, q_chunk=128),
        mlp=MLPConfig(d_model=256, d_ff=1024, activation="swiglu"),
        norm="rmsnorm", remat="none", dtype=jnp.float32)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full100m", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = model_100m() if args.full100m else model_small()
    params, _ = unbox(T.init_params(jax.random.PRNGKey(0), cfg))
    n_params = sum(l.size for l in jax.tree_util.tree_leaves(params))
    print(f"model {cfg.name}: {n_params/1e6:.1f}M params")
    opt = adamw_init(params)
    step_fn = jax.jit(make_train_step(cfg, OptConfig(lr=1e-3)))
    data = synthetic_lm_batches(TokenDataConfig(
        vocab=cfg.vocab, seq_len=args.seq, batch=args.batch))

    t0 = time.time()
    first = None
    for step in range(1, args.steps + 1):
        params, opt, metrics = step_fn(params, opt, next(data))
        loss = float(metrics["loss"])
        first = first if first is not None else loss
        if step % 20 == 0 or step == 1:
            print(f"step {step:4d}  loss {loss:.4f}  "
                  f"({(time.time()-t0)/step*1000:.0f} ms/step)", flush=True)
    print(f"\nloss: {first:.3f} -> {loss:.3f} "
          f"({'LEARNED' if loss < first - 0.5 else 'check hyperparams'})")
    if args.ckpt_dir:
        print("saved:", save_checkpoint(args.ckpt_dir, args.steps, params))


if __name__ == "__main__":
    main()
