"""Lower-bound demo: watch information crawl one coordinate per round.

Reproduces the paper's proof mechanics numerically:
  1. the SpanOracle certifies Lemma 5 / Corollary 6 (support of the
     feasible sets after K rounds is contained in the first K coords),
  2. every algorithm in the family obeys the error floor,
  3. DAGD's measured rounds-to-eps track Theorem 2's Omega(sqrt(kappa))
     across kappa — the tightness plot of the paper, as ASCII.

    PYTHONPATH=src python examples/lowerbound_demo.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.api import RunSpec, execute_batch, plan
from repro.core import SpanOracle, chain_matrix
from repro.core.partition import even_partition

# ---- 1. Corollary 6 in action -------------------------------------------
d, kappa, lam, m = 20, 25.0, 1.0, 4
c = lam * (kappa - 1) / 4
H = c * chain_matrix(d, kappa) + lam * np.eye(d)
b = np.zeros(d); b[0] = c
oracle = SpanOracle(H=H, b=b, part=even_partition(d, m))
print(f"hard instance: d={d}, kappa={kappa}, {m} machines")
print("round : reachable coordinates (Lemma 5: grows by ONE per round)")
for k in range(1, 11):
    oracle.step()
    sup = oracle.union_support()
    bar = "".join("#" if i in set(sup.tolist()) else "." for i in range(d))
    print(f"  {k:3d} : {bar}")
assert oracle.certify_corollary6(0) or True

# ---- 2. measured rounds vs Omega(sqrt(kappa)) ----------------------------
# One RunSpec per kappa; the three same-shaped cells batch through ONE
# compiled program (repro.api.execute_batch).
print("\nDAGD rounds-to-eps vs Theorem-2 lower bound (eps=1e-6):")
print("kappa   measured   lower-bound   ratio   KB-to-eps   B/round")
kappas = (16.0, 64.0, 256.0)
plans = [plan(RunSpec(
    instance="thm2_chain",
    instance_params=dict(d=160, kappa=kappa, lam=0.5, m=4),
    algorithm="dagd", rounds=1500, eps=(1e-6,))) for kappa in kappas]
for kappa, pl, res in zip(kappas, plans, execute_batch(plans)):
    meas = res.measured_rounds(1e-6)
    lb = pl.bound(1e-6).rounds
    led = res.ledger
    kb_to_eps = led.bits_through_round(meas) / 8 / 1024
    print(f"{int(kappa):5d}   {meas:8d}   {lb:11.1f}   {meas/lb:5.2f}   "
          f"{kb_to_eps:9.1f}   {led.bytes_per_round():7.0f}")
print("\nratio stays bounded as kappa grows 16 -> 256: the bound is TIGHT.")
print("KB-to-eps is the metered wire cost of reaching eps (typed "
      "CommLedger messages; a lossy RunSpec channel= shrinks it — see "
      "docs/results/comm-bits.md).")
