"""Lower-bound demo: watch information crawl one coordinate per round.

Reproduces the paper's proof mechanics numerically:
  1. the SpanOracle certifies Lemma 5 / Corollary 6 (support of the
     feasible sets after K rounds is contained in the first K coords),
  2. every algorithm in the family obeys the error floor,
  3. DAGD's measured rounds-to-eps track Theorem 2's Omega(sqrt(kappa))
     across kappa — the tightness plot of the paper, as ASCII.

    PYTHONPATH=src python examples/lowerbound_demo.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import jax.numpy as jnp

from repro.core import (ChainInstance, ERMProblem, SpanOracle,
                        chain_matrix, squared_loss, thm2_strongly_convex)
from repro.core.partition import even_partition
from repro.core.runtime import LocalDistERM
from repro.core.algorithms import dagd

# ---- 1. Corollary 6 in action -------------------------------------------
d, kappa, lam, m = 20, 25.0, 1.0, 4
c = lam * (kappa - 1) / 4
H = c * chain_matrix(d, kappa) + lam * np.eye(d)
b = np.zeros(d); b[0] = c
oracle = SpanOracle(H=H, b=b, part=even_partition(d, m))
print(f"hard instance: d={d}, kappa={kappa}, {m} machines")
print("round : reachable coordinates (Lemma 5: grows by ONE per round)")
for k in range(1, 11):
    oracle.step()
    sup = oracle.union_support()
    bar = "".join("#" if i in set(sup.tolist()) else "." for i in range(d))
    print(f"  {k:3d} : {bar}")
assert oracle.certify_corollary6(0) or True

# ---- 2. measured rounds vs Omega(sqrt(kappa)) ----------------------------
print("\nDAGD rounds-to-eps vs Theorem-2 lower bound (eps=1e-6):")
print("kappa   measured   lower-bound   ratio")
for kappa in (16.0, 64.0, 256.0):
    ci = ChainInstance(d=160, kappa=kappa, lam=0.5)
    B, y, lam_ = ci.as_erm_data()
    n = B.shape[0]
    prob = ERMProblem(A=jnp.asarray(B) * np.sqrt(n),
                      y=jnp.asarray(y) * np.sqrt(n),
                      loss=squared_loss(), lam=lam_)
    part = even_partition(prob.d, 4)
    fstar = float(prob.value(jnp.asarray(ci.w_star())))
    dist = LocalDistERM(prob, part)
    _, aux = dagd(dist, rounds=1500, L=prob.smoothness_bound(),
                  lam=lam_, history=True)
    meas = next((k for k, w in enumerate(aux["iterates"], 1)
                 if float(prob.value(dist.gather_w(w))) - fstar <= 1e-6),
                None)
    lb = thm2_strongly_convex(kappa, lam_,
                              float(jnp.linalg.norm(ci.w_star())),
                              1e-6).rounds
    print(f"{int(kappa):5d}   {meas:8d}   {lb:11.1f}   {meas/lb:5.2f}")
print("\nratio stays bounded as kappa grows 16 -> 256: the bound is TIGHT.")
