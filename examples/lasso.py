"""Composite objectives under the feature partition: distributed lasso.

The prox of a separable regularizer is BLOCK-LOCAL: machine j soft-
thresholds its own coordinates with zero extra communication, so FISTA
runs at the same one-ReduceAll-per-round budget as plain DAGD — the
paper's communication model extends beyond smooth objectives for free.

    PYTHONPATH=src python examples/lasso.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import jax.numpy as jnp

from repro.core import make_random_erm
from repro.core.partition import even_partition
from repro.core.runtime import LocalDistERM
from repro.core.algorithms import prox_dagd, soft_threshold

# sparse ground truth: 10 active features out of 256
rng = np.random.RandomState(0)
n, d, k_true = 128, 256, 10
A = rng.randn(n, d) / np.sqrt(d)
w_true = np.zeros(d)
idx = rng.choice(d, k_true, replace=False)
w_true[idx] = rng.randn(k_true) * 3
y = A @ w_true + 0.01 * rng.randn(n)

from repro.core.erm import ERMProblem, squared_loss
prob = ERMProblem(A=jnp.asarray(A), y=jnp.asarray(y),
                  loss=squared_loss(), lam=0.0)
part = even_partition(d, m=4)
dist = LocalDistERM(prob, part)

tau = 0.002
w = prox_dagd(dist, rounds=800, L=prob.smoothness_bound(),
              prox=soft_threshold(tau))
wg = np.asarray(dist.gather_w(w))
support = np.where(np.abs(wg) > 1e-6)[0]

print(f"true support    : {sorted(idx.tolist())}")
print(f"recovered       : {support.tolist()}")
print(f"support recall  : {len(set(support) & set(idx))}/{k_true}")
print(f"coef error (sup): "
      f"{np.abs(wg[idx] - w_true[idx]).max():.4f} (max abs, biased by tau)")
led = dist.comm.ledger
print(f"rounds={led.rounds}, ops={led.op_counts()} "
      f"(prox cost ZERO communication)")
led.assert_budget(n=n, d=d)
