"""Composite objectives under the feature partition: distributed lasso.

The prox of a separable regularizer is BLOCK-LOCAL: machine j soft-
thresholds its own coordinates with zero extra communication, so FISTA
runs at the same one-ReduceAll-per-round budget as plain DAGD — the
paper's communication model extends beyond smooth objectives for free.

    PYTHONPATH=src python examples/lasso.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.api import RunSpec, run

# the registered lasso instance plants a sparse ground truth: 10 active
# features out of 256, seed 0 (same RNG stream reproduced below)
n, d, k_true, tau = 128, 256, 10, 0.002
res = run(RunSpec(
    instance="lasso", instance_params=dict(n=n, d=d, m=4, tau=tau,
                                           k_true=k_true, seed=0),
    algorithm="prox_dagd", rounds=800, measure="none"))

rng = np.random.RandomState(0)           # the instance builder's stream
rng.randn(n, d)
idx = rng.choice(d, k_true, replace=False)
w_true = np.zeros(d)
w_true[idx] = rng.randn(k_true) * 3

wg = np.asarray(res.w)
support = np.where(np.abs(wg) > 1e-6)[0]

print(f"true support    : {sorted(idx.tolist())}")
print(f"recovered       : {support.tolist()}")
print(f"support recall  : {len(set(support) & set(idx))}/{k_true}")
print(f"coef error (sup): "
      f"{np.abs(wg[idx] - w_true[idx]).max():.4f} (max abs, biased by tau)")
led = res.ledger
print(f"rounds={led.rounds}, ops={led.op_counts()} "
      f"(prox cost ZERO communication)")
assert res.budget_ok
