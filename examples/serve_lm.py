"""LM serving example: batched greedy decode with three cache disciplines.

Shows the three serving regimes the input-shape matrix exercises:
  * full-attention KV cache (qwen-family smoke)
  * sliding-window ring cache (gemma3-family smoke, O(window) memory)
  * recurrent O(1) state (mamba2-family smoke)

    PYTHONPATH=src python examples/serve_lm.py

This drives ``repro.launch`` (token decoding from the model zoo).  For
serving *certification verdicts* — continuous batching of RunSpec
submissions — see ``repro.serve`` (``python -m repro.serve --demo 96``).
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import time

import jax
import jax.numpy as jnp

from repro.configs import get
from repro.models import transformer as T
from repro.models.common import unbox
from repro.launch.steps import make_serve_step

B, STEPS, MAX_SEQ = 4, 48, 128

for arch in ("qwen1.5-32b", "gemma3-27b", "mamba2-780m"):
    cfg = get(arch).smoke()
    params, _ = unbox(T.init_params(jax.random.PRNGKey(0), cfg))
    cache = T.init_cache(cfg, B, MAX_SEQ)
    serve = jax.jit(make_serve_step(cfg))
    tok = jnp.zeros((B, 1), jnp.int32)
    t0 = time.time()
    for _ in range(STEPS):
        tok, cache = serve(params, tok, cache)
    jax.block_until_ready(tok)
    dt = time.time() - t0
    # cache memory accounting
    leaves = jax.tree_util.tree_leaves(cache)
    cache_mb = sum(l.size * l.dtype.itemsize for l in leaves) / 1e6
    kind = {"qwen1.5-32b": "full KV", "gemma3-27b": "ring (window)",
            "mamba2-780m": "recurrent state"}[arch]
    print(f"{arch:16s} [{kind:16s}] {STEPS/dt*B:7.1f} tok/s total, "
          f"cache {cache_mb:6.2f} MB")
