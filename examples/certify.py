"""Certify an algorithm against the paper's lower bound in ~20 lines.

Builds the Theorem-2 hard chain instance, runs every registered
non-incremental algorithm through the metered runtime, and prints each
measured round count next to the closed-form bound — the same machinery
`python -m repro.experiments.sweep` uses to generate docs/results/.

    PYTHONPATH=src python examples/certify.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.experiments import SweepSpec, run_sweep

spec = SweepSpec(
    name="certify-demo", instance="thm2_chain",
    grid=dict(d=[64], kappa=[32.0], lam=[0.5], m=[4]),
    algorithms=("dagd", "dgd", "disco_f"), eps=(1e-6,), max_rounds=1500)

result = run_sweep(spec)

print(f"{'algorithm':>10} {'measured':>9} {'bound':>8} {'ratio':>6} "
      f"{'certified':>10}")
for r in result.records:
    measured = (str(r.measured_rounds) if r.measured_rounds is not None
                else f">{r.max_rounds}")
    ratio = f"{r.ratio:.2f}" if r.ratio is not None else "-"
    print(f"{r.algorithm:>10} {measured:>9} "
          f"{r.bound_rounds:>8.1f} {ratio:>6} "
          f"{str(r.certified):>10}")

summ = result.summary()
print(f"\n{summ['certified']}/{summ['certifiable']} certified "
      f"(measured rounds >= Theorem-2 bound on the hard instance)")
sys.exit(0 if not summ["failed"] else 1)
