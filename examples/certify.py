"""Certify algorithms against the paper's lower bound in ~30 lines.

Every run is a declarative ``repro.api.RunSpec``; ``plan`` validates it
and resolves the execution axes, ``execute_batch`` runs same-shaped
cells through ONE compiled program per group (here: each algorithm's
two-kappa column batches together).  The same machinery generates
``docs/results/`` via ``python -m repro.experiments.sweep``.

    PYTHONPATH=src python examples/certify.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.api import RunSpec, plan, execute_batch

EPS = 1e-6
specs = [
    RunSpec(instance="thm2_chain",
            instance_params=dict(d=64, kappa=kappa, lam=0.5, m=4),
            algorithm=algo, rounds=1500, eps=(EPS,), tag="certify-demo")
    for algo in ("dagd", "dgd", "disco_f") for kappa in (16.0, 32.0)]

plans = [plan(s) for s in specs]          # every "auto" resolved, cells
results = execute_batch(plans)            # vmapped per same-shaped group

print(f"{'algorithm':>10} {'kappa':>6} {'measured':>9} {'bound':>8} "
      f"{'ratio':>6} {'certified':>10} {'batched':>8} {'KB sent':>8} "
      f"{'B/round':>8}")
failed = 0
for spec, pl, res in zip(specs, plans, results):
    bound = pl.bound(pl.eps_abs(EPS))
    measured = res.measured_rounds(pl.eps_abs(EPS))
    certified = pl.certify(res, EPS)   # three-valued, sweep semantics
    failed += certified is False       # inconclusive (None) is not failure
    ratio = f"{measured / bound.rounds:.2f}" if measured else "-"
    led = res.ledger                   # typed messages: bytes AND wire bits
    print(f"{spec.algorithm:>10} {spec.instance_params['kappa']:>6g} "
          f"{measured if measured is not None else f'>{spec.rounds}':>9} "
          f"{bound.rounds:>8.1f} {ratio:>6} "
          f"{'n/a' if certified is None else str(certified):>10} "
          f"{str(res.batched):>8} {led.total_bytes() / 1024:>8.1f} "
          f"{led.bytes_per_round():>8.0f}")

print(f"\n{len(specs) - failed}/{len(specs)} certified (measured rounds "
      f">= Theorem-2 bound on the hard instance)")
print("`KB sent` / `B/round` are metered off the upgraded CommLedger "
      "(per-machine uploads; wire bits also available via "
      "res.ledger.total_bits() — rerun with RunSpec(channel='int8') to "
      "shrink them)")
sys.exit(1 if failed else 0)
