"""Theorem 4 (incremental family): DSVRG measured rounds vs the lower
bound Omega((sqrt(n kappa) + n) log(1/eps)), on the HARD chain instance
embedded as ERM — the bound is worst-case over functions, so the
comparison is only meaningful on a hard f. Each stochastic step = one
communication round, per the paper's Definition 3.2 model.

Thin CLI wrapper over the ``repro.experiments`` sweep subsystem (preset
``thm4``). Full JSON + Markdown reports: ``python -m
repro.experiments.sweep --preset thm4``.
"""
from __future__ import annotations

from repro.experiments import PRESETS, run_sweep

from .common import emit


def run():
    result = run_sweep(PRESETS["thm4"])
    for r in result.records:
        n = int(r.instance_params["n"])
        kappa = r.instance_params["kappa"]
        k = r.measured_rounds if r.measured_rounds is not None else -1
        ratio = r.ratio if r.ratio is not None else float("nan")
        emit(f"thm4/n{n}/{r.algorithm}/rounds_to_eps", k,
             f"lb={r.bound_rounds:.0f};ratio={ratio:.2f};"
             f"kappa={kappa:.1f}")
    return result


if __name__ == "__main__":
    run()
