"""Theorem 4 (incremental family): DSVRG measured rounds vs the lower
bound Omega((sqrt(n kappa) + n) log(1/eps)), on the HARD chain instance
embedded as ERM — the bound is worst-case over functions, so the
comparison is only meaningful on a hard f. Each stochastic step = one
communication round, per the paper's Definition 3.2 model."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.bounds import thm4_incremental
from repro.core.partition import even_partition
from repro.core.runtime import LocalDistERM
from repro.core.algorithms import dsvrg
from .common import chain_erm, emit


def run(m: int = 4, eps: float = 1e-4, kappa: float = 64.0):
    for n in (16, 32, 64):
        # chain hard function on d = n coords: the ERM has n samples
        ci, prob = chain_erm(d=n, kappa=kappa, lam=0.5)
        wstar = jnp.asarray(ci.w_star())
        fstar = float(prob.value(wstar))
        kap = prob.smoothness_bound() / prob.lam
        L_max = float(jnp.max(jnp.sum(prob.A ** 2, axis=1))) + prob.lam
        part = even_partition(prob.d, m)
        dist = LocalDistERM(prob, part)
        _, aux = dsvrg(dist, rounds=30000, L_max=L_max, lam=prob.lam,
                       history=True, seed=7, eta=1.0 / (4.0 * L_max))
        k = None
        for i, w in enumerate(aux["iterates"], start=1):
            if float(prob.value(dist.gather_w(w))) - fstar <= eps:
                k = i
                break
        lb = thm4_incremental(n, kap, prob.lam,
                              float(jnp.linalg.norm(wstar)), eps).rounds
        ratio = (k / lb) if (k and lb) else float("nan")
        emit(f"thm4/n{n}/dsvrg/rounds_to_eps", k if k else -1,
             f"lb={lb:.0f};ratio={ratio:.2f};kappa={kap:.1f}")


if __name__ == "__main__":
    run()
