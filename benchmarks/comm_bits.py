"""Bit-level communication accounting: identity-channel equivalence
gates + the bits-vs-rounds tradeoff across lossy channels.

The paper meters *rounds*; the ledger now also meters the *wire bits*
each round spends (``core.comm`` typed messages, ``core.channel``
transforms).  This benchmark is the accounting subsystem's gatekeeper:

  * **identity gates** — every ``thm2-small`` cell executed with the
    default (``auto``) channel and with an explicit ``channel="identity"``
    must produce identical certification verdicts, identical measured
    rounds, and bit-identical ``CommLedger`` streams; and every record's
    byte/bit fields must match shape x dtype arithmetic exactly
    (``bytes == prod(shape) * itemsize``, ``bits == 8 * bytes``), with
    the round-boundary marks consistent (``len(round_marks) == rounds``,
    prefix bit sums telescoping to the total).  These run in ``--quick``
    (the CI smoke) and full mode alike.
  * **tradeoff table** — one Theorem-2 cell run under every channel
    (identity / fp16 / bf16 / int8 stochastic rounding / top-k):
    rounds-to-eps, bits-to-eps, and the bit savings vs identity, per eps
    threshold.  Quantized channels must spend strictly fewer bits than
    identity to the coarsest threshold (the savings gate); where a
    channel's noise floor keeps it from a tighter threshold the table
    says so — that *is* the tradeoff.

CLI:
    PYTHONPATH=src python -m benchmarks.comm_bits
    PYTHONPATH=src python -m benchmarks.comm_bits --quick --no-report   # CI

Writes ``docs/results/comm-bits.json`` + ``.md`` and refreshes the
results index.  Exit status is non-zero on any identity/accounting gate
violation, and on a missed savings gate.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
from typing import Dict, List, Optional, Sequence

import numpy as np
import jax

from repro import api
from repro.core.channel import parse_channel
from repro.experiments.instances import build_instance
from repro.experiments.sweep import PRESETS

COMMAND = "PYTHONPATH=src python -m benchmarks.comm_bits"

PRESET = "thm2-small"
CHANNEL_SET = ("identity", "fp16", "bf16", "int8", "topk:0.25")

# the tradeoff cell: one Thm-2 hard instance, DAGD (the tightness
# witness), eps thresholds spanning the channels' noise floors
TRADEOFF = dict(instance="thm2_chain",
                instance_params=dict(d=96, kappa=64.0, lam=0.5, m=4),
                algorithm="dagd", rounds=2500, eps=(1e-2, 1e-4, 1e-6))
TRADEOFF_QUICK = dict(instance="thm2_chain",
                      instance_params=dict(d=48, kappa=16.0, lam=0.5, m=4),
                      algorithm="dagd", rounds=400, eps=(1e-2, 1e-4))


# --------------------------------------------------------------------------
# Identity-channel equivalence + accounting gates
# --------------------------------------------------------------------------

def _accounting_ok(led) -> List[str]:
    """Byte/bit fields must be pure shape x dtype arithmetic; round marks
    must tile the stream."""
    problems = []
    for i, r in enumerate(led.records):
        elems = int(np.prod(r.shape, dtype=np.int64)) if r.shape else 1
        itemsize = np.dtype(r.dtype).itemsize
        if r.elems != elems:
            problems.append(f"record {i}: elems {r.elems} != prod(shape) "
                            f"{elems}")
        if r.bytes != elems * itemsize:
            problems.append(f"record {i}: bytes {r.bytes} != "
                            f"{elems} x {itemsize}")
        if r.bits != r.bytes * 8:
            problems.append(f"record {i}: identity bits {r.bits} != "
                            f"8 x {r.bytes}")
    if len(led.round_marks) != led.rounds:
        problems.append(f"round_marks {len(led.round_marks)} != rounds "
                        f"{led.rounds}")
    if led.bits_through_round(led.rounds) != led.total_bits():
        problems.append("prefix bit sum does not telescope to total_bits")
    return problems


def run_identity(rounds: Optional[int] = None,
                 algorithms: Optional[Sequence[str]] = None) -> List[dict]:
    """Every thm2-small cell, auto channel vs explicit identity."""
    spec = PRESETS[PRESET]
    rounds = rounds or spec.max_rounds
    algorithms = tuple(algorithms or spec.algorithms)
    records = []
    for point in spec.grid_points():
        bundle = build_instance(spec.instance, **point)
        for name in algorithms:
            cell = spec.cell_spec(point, name, max_rounds=rounds)
            pl_auto = api.plan(cell, bundle=bundle)
            res_auto = pl_auto.execute()
            pl_id = api.plan(cell.replace(channel="identity"),
                             bundle=bundle)
            res_id = pl_id.execute()
            verdicts_auto = [pl_auto.certify(res_auto, e) for e in spec.eps]
            verdicts_id = [pl_id.certify(res_id, e) for e in spec.eps]
            measured_auto = [res_auto.measured_rounds(pl_auto.eps_abs(e))
                             for e in spec.eps]
            measured_id = [res_id.measured_rounds(pl_id.eps_abs(e))
                           for e in spec.eps]
            problems = _accounting_ok(res_id.ledger)
            records.append(dict(
                instance_label=bundle.label, instance_params=dict(point),
                algorithm=name, rounds=rounds,
                verdicts=verdicts_id,
                verdict_identical=verdicts_auto == verdicts_id,
                measured_rounds_identical=measured_auto == measured_id,
                ledger_identical=(
                    res_auto.ledger.typed_stream()
                    == res_id.ledger.typed_stream()
                    and res_auto.ledger.rounds == res_id.ledger.rounds
                    and res_auto.ledger.round_marks
                    == res_id.ledger.round_marks),
                total_bytes=int(res_id.ledger.total_bytes()),
                total_bits=int(res_id.ledger.total_bits()),
                bits_are_8x_bytes=(res_id.ledger.total_bits()
                                   == 8 * res_id.ledger.total_bytes()),
                accounting_problems=problems,
            ))
    return records


# --------------------------------------------------------------------------
# Bits-vs-rounds tradeoff
# --------------------------------------------------------------------------

def run_tradeoff(cell: Dict, channels: Sequence[str] = CHANNEL_SET) -> dict:
    """One certification cell under every channel: rounds-to-eps and
    bits-to-eps per threshold, savings vs the identity wire."""
    eps = tuple(cell["eps"])
    rows = []
    for ch in channels:
        pl = api.plan(api.RunSpec(**cell, channel=ch, tag="comm-bits"))
        res = pl.execute()
        led = res.ledger
        per_eps = []
        for e in eps:
            measured = res.measured_rounds(pl.eps_abs(e))
            per_eps.append(dict(
                eps=e, measured_rounds=measured,
                bits_to_eps=(int(led.bits_through_round(measured))
                             if measured is not None else None),
                bound_rounds=pl.bound(pl.eps_abs(e)).rounds))
        rows.append(dict(
            channel=res.channel,
            bits_per_round=float(led.bits_per_round()),
            bytes_per_round=float(led.bytes_per_round()),
            total_bits=int(led.total_bits()),
            per_eps=per_eps,
            # a record-level arithmetic check: every vector upload must
            # price at exactly wire_bits(elems); scalars stay 32-bit
            wire_arithmetic_ok=_wire_arithmetic_ok(led, res.channel),
        ))
    ident = {r["channel"]: r for r in rows}["identity"]
    for row in rows:
        row["savings_vs_identity"] = [
            (round(i_e["bits_to_eps"] / c_e["bits_to_eps"], 2)
             if c_e["bits_to_eps"] and i_e["bits_to_eps"] else None)
            for c_e, i_e in zip(row["per_eps"], ident["per_eps"])]
    return dict(cell={k: v for k, v in cell.items() if k != "eps"},
                eps=list(eps), channels=rows)


def _wire_arithmetic_ok(led, channel_name: str) -> bool:
    ch = parse_channel(channel_name)
    for r in led.records:
        itemsize = np.dtype(r.dtype).itemsize
        if tuple(r.shape) == ():   # scalar reductions bypass the channel
            expect = 32
        elif r.direction == "worker->all" and len(r.shape) >= 2:
            # local all-to-all broadcast: the stacked (m, ...) payload is
            # m per-machine messages, each priced through the channel
            m = r.shape[0]
            expect = m * ch.wire_bits(r.elems // m, itemsize)
        else:
            expect = ch.wire_bits(r.elems, itemsize)
        if r.bits != expect:
            return False
    return True


# --------------------------------------------------------------------------
# Reporting
# --------------------------------------------------------------------------

def render_markdown(doc: dict) -> str:
    lines = [
        "# Bit-level communication accounting — `comm-bits`",
        "",
        f"<!-- Generated by `{doc['command']}`. Do not edit by hand. -->",
        f"*Generated by* `{doc['command']}` *— regenerate instead of "
        "editing.*",
        "",
        f"- **Platform:** `{doc['platform']}`",
        f"- **Identity gates:** {doc['summary']['certified']}/"
        f"{doc['summary']['certifiable']} `{doc['spec']['preset']}` cells "
        "with identical verdicts, measured rounds, and bit-identical "
        "typed ledger streams between the `auto` and explicit "
        "`identity` channels, byte/bit totals matching shape×dtype "
        "arithmetic exactly",
        "- **Wire model:** per-machine uploads priced by the channel "
        "(`core.channel`); scalar reductions always exact (32 bits)",
        "",
        "## Identity-channel equivalence per certification cell",
        "",
        "| instance | algorithm | verdicts identical | measured rounds "
        "identical | ledger identical | bytes (shape×dtype) | "
        "bits = 8×bytes |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in doc["identity"]:
        lines.append(
            f"| {r['instance_label']} | {r['algorithm']} | "
            f"{'yes' if r['verdict_identical'] else '**NO**'} | "
            f"{'yes' if r['measured_rounds_identical'] else '**NO**'} | "
            f"{'yes' if r['ledger_identical'] else '**NO**'} | "
            f"{'exact' if not r['accounting_problems'] else '**DRIFT**'} | "
            f"{'yes' if r['bits_are_8x_bytes'] else '**NO**'} |")
    t = doc.get("tradeoff")
    if t:
        cell = t["cell"]
        lines += [
            "",
            "## Bits-vs-rounds tradeoff",
            "",
            f"`{cell['algorithm']}` on `{cell['instance']}`"
            f"({', '.join(f'{k}={v:g}' for k, v in cell['instance_params'].items())}), "
            f"round budget {cell['rounds']}:",
            "",
            "| channel | bits/round | "
            + " | ".join(f"rounds @ {e:g} | bits @ {e:g} | ×fewer bits"
                         for e in t["eps"]) + " |",
            "|---|---|" + "---|" * (3 * len(t["eps"])),
        ]
        for row in t["channels"]:
            cells = []
            for pe, sv in zip(row["per_eps"], row["savings_vs_identity"]):
                if pe["measured_rounds"] is None:
                    cells += ["not reached (noise floor)", "—", "—"]
                else:
                    cells += [str(pe["measured_rounds"]),
                              f"{pe['bits_to_eps']:,}",
                              f"{sv:.2f}×" if sv else "—"]
            lines.append(f"| `{row['channel']}` | "
                         f"{row['bits_per_round']:.0f} | "
                         + " | ".join(cells) + " |")
        lines += [
            "",
            "Reading the table: `fp16`/`bf16` halve every message at no "
            "round cost at these thresholds; `int8` (stochastic "
            "rounding, per-message scale) reaches the coarse threshold "
            "with ~4× fewer bits at the price of a round or two, but its "
            "quantization noise floors the achievable gap; `topk` keeps "
            "a fraction of coordinates per message (value + 32-bit "
            "index each). A channel that cannot reach a threshold spends "
            "infinite bits on it — *that* is the tradeoff the bit "
            "accounting makes visible next to the round bounds.",
        ]
    lines += [
        "",
        "Under the identity channel the typed ledger is pure accounting: "
        "the legacy `(kind, elems, bytes, tag)` stream, the certification "
        "verdicts, and the measured rounds are bit-identical to a "
        "channel-free build, so every existing report under "
        "`docs/results/` is unchanged by this subsystem.",
        "",
    ]
    return "\n".join(lines)


def write_reports(identity: List[dict], tradeoff: Optional[dict],
                  out_dir, rounds: int) -> pathlib.Path:
    from repro.experiments.report import refresh_index

    out = pathlib.Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    ok = sum(1 for r in identity if _cell_ok(r))
    doc = dict(
        schema_version=1,
        command=COMMAND,
        spec=dict(name="comm-bits", preset=PRESET,
                  instance=PRESETS[PRESET].instance,
                  algorithms=sorted({r["algorithm"] for r in identity}),
                  rounds=rounds, channels=list(CHANNEL_SET)),
        platform=jax.default_backend(),
        summary=dict(records=len(identity), certifiable=len(identity),
                     certified=ok, failed=len(identity) - ok),
        identity=identity,
        tradeoff=tradeoff,
    )
    (out / "comm-bits.json").write_text(json.dumps(doc, indent=2) + "\n")
    (out / "comm-bits.md").write_text(render_markdown(doc))
    refresh_index(out)
    return out / "comm-bits.json"


def _cell_ok(r: dict) -> bool:
    return bool(r["verdict_identical"] and r["measured_rounds_identical"]
                and r["ledger_identical"] and r["bits_are_8x_bytes"]
                and not r["accounting_problems"])


def run():
    """CSV rows for the legacy benchmarks/run.py surface."""
    from .common import emit
    t = run_tradeoff(TRADEOFF_QUICK, channels=("identity", "int8"))
    for row in t["channels"]:
        pe = row["per_eps"][0]
        emit(f"comm_bits/dagd/{row['channel']}",
             f"{row['bits_per_round']:.0f}",
             f"rounds_to_{pe['eps']:g}={pe['measured_rounds']};"
             f"bits_to_eps={pe['bits_to_eps']}")


def main(argv: Optional[Sequence[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m benchmarks.comm_bits", description=__doc__)
    parser.add_argument("--out", default=None,
                        help="output directory (default: docs/results)")
    parser.add_argument("--rounds", type=int, default=None,
                        help="override the preset round budget for the "
                             "identity gates")
    parser.add_argument("--quick", action="store_true",
                        help="CI smoke: fewer rounds / algorithms, small "
                             "tradeoff cell; every gate still enforced")
    parser.add_argument("--no-report", action="store_true")
    args = parser.parse_args(argv)

    if args.quick:
        identity = run_identity(rounds=args.rounds or 300,
                                algorithms=("dagd", "dgd"))
        tradeoff = run_tradeoff(TRADEOFF_QUICK)
    else:
        identity = run_identity(rounds=args.rounds)
        tradeoff = run_tradeoff(TRADEOFF)
    rounds = identity[0]["rounds"] if identity else 0

    for r in identity:
        print(f"[comm-bits] {r['instance_label']} {r['algorithm']:>8}: "
              f"verdicts "
              f"{'identical' if r['verdict_identical'] else 'DIFFER'}, "
              f"measured "
              f"{'identical' if r['measured_rounds_identical'] else 'DIFFER'}"
              f", ledger "
              f"{'identical' if r['ledger_identical'] else 'DIFFERS'}, "
              f"accounting "
              f"{'exact' if not r['accounting_problems'] else 'DRIFT'}",
              file=sys.stderr)
    for row in tradeoff["channels"]:
        pe0 = row["per_eps"][0]
        print(f"[comm-bits] {row['channel']:>10}: "
              f"{row['bits_per_round']:.0f} bits/round, "
              f"rounds@{pe0['eps']:g}={pe0['measured_rounds']}, "
              f"bits@{pe0['eps']:g}={pe0['bits_to_eps']}",
              file=sys.stderr)

    if not args.no_report:
        from repro.experiments.report import default_results_dir
        out = args.out or default_results_dir()
        path = write_reports(identity, tradeoff, out, rounds)
        print(f"[comm-bits] report -> {path}")

    bad = [r for r in identity if not _cell_ok(r)]
    if bad:
        print(f"[comm-bits] IDENTITY/ACCOUNTING GATE FAILED in "
              f"{len(bad)} cell(s): the identity channel must be "
              f"invisible and byte totals must match dtype arithmetic",
              file=sys.stderr)
        for r in bad:
            for p in r["accounting_problems"]:
                print(f"[comm-bits]   {r['algorithm']}: {p}",
                      file=sys.stderr)
        return 1
    wire_bad = [row["channel"] for row in tradeoff["channels"]
                if not row["wire_arithmetic_ok"]]
    if wire_bad:
        print(f"[comm-bits] WIRE ARITHMETIC DRIFT for {wire_bad}",
              file=sys.stderr)
        return 1
    coarse = tradeoff["eps"][0]
    ident_bits = tradeoff["channels"][0]["per_eps"][0]["bits_to_eps"]
    missed = []
    for row in tradeoff["channels"][1:]:
        b = row["per_eps"][0]["bits_to_eps"]
        if b is None or (ident_bits is not None and b >= ident_bits):
            missed.append(row["channel"])
    if missed:
        print(f"[comm-bits] SAVINGS GATE MISSED: {missed} spent >= "
              f"identity bits to eps={coarse:g}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
