"""Bits-to-eps frontier benchmark: adaptive channels vs the bit bounds.

Thin CLI over ``repro.experiments.frontier`` (the search engine lives in
the package so ``python -m repro.experiments.sweep --frontier`` and the
tests share it).  Re-executes certification cells under fixed, scheduled
(``sched:``) and gap-adaptive (``gap:``) channels, publishes the
(rounds, bits) frontier, and enforces the subsystem's gates:

  * **bit certification** — every hard point must measure at or above
    its schedule-aware bit floor (the certifying round bound priced at
    the stage active in each bounded round);
  * **negative result** — at least one hard cell (the Theorem-4
    incremental family) where NO adaptive candidate beats the best
    fixed channel and the certified floor is channel-invariant
    (``bound_rounds x 32`` exact scalar bits — channels never touch
    scalars);
  * **savings** — at least one real workload (lasso / logistic) with a
    >= 2x total-bit reduction vs the identity wire at unchanged
    verdict.

CLI:
    PYTHONPATH=src python -m benchmarks.bits_frontier
    PYTHONPATH=src python -m benchmarks.bits_frontier --quick --no-report  # CI

Writes ``docs/results/bits-frontier.json`` + ``.md`` and refreshes the
results index.  Exit status is non-zero on any missed gate.
"""
from __future__ import annotations

import argparse
import sys
from typing import Optional, Sequence

from repro.experiments import frontier

# the published full sweep: both hard families + both workloads
FULL_PRESETS = ("thm2-small", "thm4-small", "lasso", "logistic")


def run():
    """CSV rows for the legacy benchmarks/run.py surface."""
    from .common import emit
    doc = frontier.run_frontier(frontier.QUICK_CELLS[:1])
    for p in doc["cells"][0]["points"]:
        pe = p["per_eps"][0]
        emit(f"bits_frontier/dagd/{p['channel']}",
             f"{p['bits_per_round']:.0f}",
             f"rounds_to_{pe['eps']:g}={pe['measured_rounds']};"
             f"bits_to_eps={pe['bits_to_eps']};"
             f"pareto={pe.get('pareto')}")


def main(argv: Optional[Sequence[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m benchmarks.bits_frontier", description=__doc__)
    parser.add_argument("--out", default=None,
                        help="output directory (default: docs/results)")
    parser.add_argument("--quick", action="store_true",
                        help="CI smoke: one small Theorem-2 cell + the "
                             "Theorem-4 incremental cell + the lasso "
                             "workload; every gate still enforced")
    parser.add_argument("--max-rounds", type=int, default=None,
                        help="override the per-preset round budgets "
                             "(full mode only)")
    parser.add_argument("--no-report", action="store_true",
                        help="run and gate, but write nothing")
    parser.add_argument("-q", "--quiet", action="store_true")
    args = parser.parse_args(argv)

    if args.quick:
        cells = frontier.QUICK_CELLS
    else:
        cells = frontier.preset_cells(FULL_PRESETS,
                                      max_rounds=args.max_rounds)
    doc = frontier.run_frontier(cells, verbose=not args.quiet)

    summ = doc["summary"]
    print(f"[bits-frontier] {len(doc['cells'])} cells, "
          f"{summ['records']} points; bit-certified "
          f"{summ['certified']}/{summ['certifiable']}; "
          f"adaptive wins on {summ['hard_adaptive_wins']}, "
          f"cannot help on {summ['hard_no_adaptive_win']}; "
          f"workload savings {summ['workload_best_savings']}")

    if not args.no_report:
        json_path, md_path = frontier.write_report(doc, args.out)
        print(f"[bits-frontier] report -> {json_path}, {md_path}")

    fails = frontier.gate_failures(doc)
    for f in fails:
        print(f"[bits-frontier] GATE FAILED: {f}", file=sys.stderr)
    return 1 if fails else 0


if __name__ == "__main__":
    sys.exit(main())
