"""Shared benchmark utilities.

Rounds-to-eps measurement and the hard-instance ERM embeddings moved to
``repro.experiments`` (sweep/_run_cell and instances.chain_erm); the
theorem benchmarks are thin wrappers over that subsystem now.
"""
from __future__ import annotations

import time
from typing import Callable

import numpy as np
import jax


def timeit(fn: Callable, n_iter: int = 20, warmup: int = 3) -> float:
    """Median wall-time per call in microseconds."""
    for _ in range(warmup):
        r = fn()
        jax.block_until_ready(r)
    ts = []
    for _ in range(n_iter):
        t0 = time.perf_counter()
        r = fn()
        jax.block_until_ready(r)
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def emit(name: str, us_per_call, derived):
    print(f"{name},{us_per_call},{derived}")
