"""Shared benchmark utilities."""
from __future__ import annotations

import time
from typing import Callable, List

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import ChainInstance, ERMProblem, squared_loss
from repro.core.partition import even_partition
from repro.core.runtime import LocalDistERM


def chain_erm(d: int, kappa: float, lam: float):
    """Hard instance as an ERM problem (exact embedding)."""
    ci = ChainInstance(d=d, kappa=kappa, lam=lam)
    B, y, lam_ = ci.as_erm_data()
    n = B.shape[0]
    prob = ERMProblem(A=jnp.asarray(B) * np.sqrt(n),
                      y=jnp.asarray(y) * np.sqrt(n),
                      loss=squared_loss(), lam=lam_)
    return ci, prob


def rounds_to_eps(prob, part, algo, eps: float, fstar: float,
                  max_rounds: int, **algo_kw):
    """Measured communication rounds to reach f - f* <= eps."""
    dist = LocalDistERM(prob, part)
    _, aux = algo(dist, rounds=max_rounds, history=True, **algo_kw)
    for k, w in enumerate(aux["iterates"], start=1):
        if float(prob.value(dist.gather_w(w))) - fstar <= eps:
            return k, dist.comm.ledger
    return None, dist.comm.ledger


def timeit(fn: Callable, n_iter: int = 20, warmup: int = 3) -> float:
    """Median wall-time per call in microseconds."""
    for _ in range(warmup):
        r = fn()
        jax.block_until_ready(r)
    ts = []
    for _ in range(n_iter):
        t0 = time.perf_counter()
        r = fn()
        jax.block_until_ready(r)
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def emit(name: str, us_per_call, derived):
    print(f"{name},{us_per_call},{derived}")
