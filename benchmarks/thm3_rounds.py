"""Theorem 3 (smooth convex, lam = 0): rounds-to-eps vs the lower bound.

Hard instance: Nesterov's smooth chain f(w) = L/4 (1/2 w^T A w - <e1, w>)
with plain tridiagonal A — embedded as an un-regularized least-squares ERM
so the same feature-partitioned algorithms run unchanged.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core import ERMProblem, squared_loss
from repro.core.bounds import thm3_smooth_convex
from repro.core.partition import even_partition
from repro.core.algorithms import dagd, dgd
from .common import emit, rounds_to_eps


def _smooth_chain_erm(d: int, L: float):
    A = np.zeros((d, d))
    idx = np.arange(d)
    A[idx, idx] = 2.0
    A[idx[:-1], idx[:-1] + 1] = -1.0
    A[idx[:-1] + 1, idx[:-1]] = -1.0
    c = L / 4.0
    evals, evecs = np.linalg.eigh(A)
    B = (evecs * np.sqrt(np.clip(c * evals, 0, None))) @ evecs.T
    rhs = np.zeros(d)
    rhs[0] = c
    y = np.linalg.lstsq(B.T, rhs, rcond=None)[0]
    n = d
    prob = ERMProblem(A=jnp.asarray(B) * np.sqrt(n),
                      y=jnp.asarray(y) * np.sqrt(n),
                      loss=squared_loss(), lam=0.0)
    # w*(i) = 1 - i/(d+1)  (Nesterov 2.1.2 boundary solution)
    wstar = 1.0 - np.arange(1, d + 1) / (d + 1.0)
    return prob, jnp.asarray(wstar)


def run(d: int = 128, L: float = 1.0, m: int = 4):
    prob, wstar = _smooth_chain_erm(d, L)
    part = even_partition(d, m)
    fstar = float(prob.value(wstar))
    Lb = prob.smoothness_bound()
    for eps_frac in (1e-2, 1e-3):
        # eps relative to the f(0) - f* scale, as Thm 3 is sublinear
        gap0 = float(prob.value(jnp.zeros(d))) - fstar
        eps = eps_frac * gap0
        lb = thm3_smooth_convex(L, float(jnp.linalg.norm(wstar)),
                                eps).rounds
        for name, algo in (("dagd", dagd), ("dgd", dgd)):
            k, _ = rounds_to_eps(prob, part, algo, eps, fstar,
                                 max_rounds=4000, L=Lb, lam=0.0)
            ratio = (k / lb) if (k and lb) else float("nan")
            emit(f"thm3/eps{eps_frac:g}/{name}/rounds_to_eps",
                 k if k else -1, f"lb={lb:.1f};ratio={ratio:.2f}")


if __name__ == "__main__":
    run()
