"""Theorem 3 (smooth convex, lam = 0): rounds-to-eps vs the lower bound.

Thin CLI wrapper over the ``repro.experiments`` sweep subsystem (preset
``thm3``). The hard instance — Nesterov's smooth chain embedded as an
un-regularized least-squares ERM — now lives in
``repro.experiments.instances.smooth_chain_erm``; eps is relative to the
initial gap f(0) - f* (the sublinear regime).

Full JSON + Markdown reports: ``python -m repro.experiments.sweep
--preset thm3``.
"""
from __future__ import annotations

from repro.experiments import PRESETS, run_sweep

from .common import emit


def run():
    result = run_sweep(PRESETS["thm3"])
    for r in result.records:
        k = r.measured_rounds if r.measured_rounds is not None else -1
        ratio = r.ratio if r.ratio is not None else float("nan")
        emit(f"thm3/eps{r.eps:g}/{r.algorithm}/rounds_to_eps", k,
             f"lb={r.bound_rounds:.1f};ratio={ratio:.2f}")
    return result


if __name__ == "__main__":
    run()
