"""Benchmark harness — one function per paper claim/table.

Prints ``name,us_per_call,derived`` CSV rows:
  thm2_rounds      — Theorem 2 tightness (rounds vs lower bound, x kappa)
  thm3_rounds      — Theorem 3 (smooth convex)
  thm4_incremental — Theorem 4 (incremental family, x n)
  comm_cost        — feature- vs sample-partition per-round bytes
  kernel_bench     — Pallas/jnp hot-loop microbenchmarks
  roofline         — dry-run roofline terms per (arch x shape x mesh)
"""
from __future__ import annotations


def main() -> None:
    print("name,us_per_call,derived")
    from . import (comm_cost, kernel_bench, m_invariance,
                   moe_dispatch_ablation, roofline, thm2_rounds,
                   thm3_rounds, thm4_incremental)
    thm2_rounds.run()
    thm3_rounds.run()
    thm4_incremental.run()
    m_invariance.run()
    comm_cost.run()
    kernel_bench.run()
    moe_dispatch_ablation.run()
    roofline.run()


if __name__ == "__main__":
    main()
