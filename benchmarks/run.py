"""Benchmark harness — one function per paper claim/table.

Default mode prints ``name,us_per_call,derived`` CSV rows:
  thm2_rounds      — Theorem 2 tightness (rounds vs lower bound, x kappa)
  thm3_rounds      — Theorem 3 (smooth convex)
  thm4_incremental — Theorem 4 (incremental family, x n)
  m_invariance     — round counts constant across machine counts
  comm_cost        — feature- vs sample-partition per-round bytes
  kernel_bench     — Pallas/jnp hot-loop microbenchmarks
  oracle_backends  — einsum vs Pallas-kernel per-round wall-clock
  round_engine     — python-loop vs scan-compiled per-cell wall-clock
  api_batch        — execute_batch vs sequential per-cell wall-clock
  comm_bits        — wire bits/round + bits-to-eps per lossy channel
  serve_throughput — certification-service specs/s + cache hit rate
  roofline         — fused vs composed HBM bytes/round + achieved fraction

The theorem rows are thin wrappers over ``repro.experiments`` (which
drives every cell through the ``repro.api`` facade); pass ``--sweeps``
to additionally write the full JSON + Markdown reports to
``docs/results/`` (equivalent to ``python -m repro.experiments.sweep
--preset all`` followed by the round-engine and api-batch ablation
reports), or ``--sweep NAME`` for a single preset.
"""
from __future__ import annotations

import argparse
import sys
from typing import Optional, Sequence


def main(argv: Optional[Sequence[str]] = None) -> int:
    parser = argparse.ArgumentParser(prog="python -m benchmarks.run")
    parser.add_argument("--sweeps", action="store_true",
                        help="run every sweep preset and write reports "
                             "under docs/results/")
    parser.add_argument("--sweep", action="append", default=[],
                        help="run one named sweep preset (repeatable)")
    parser.add_argument("--out", default=None,
                        help="report directory (default docs/results)")
    args = parser.parse_args(argv)

    if args.sweeps or args.sweep:
        from repro.experiments.sweep import main as sweep_main
        presets = ["all"] if args.sweeps else args.sweep
        sweep_argv = []
        for p in presets:
            sweep_argv += ["--preset", p]
        if args.out:
            sweep_argv += ["--out", args.out]
        rc = sweep_main(sweep_argv)
        if args.sweeps:
            # the round-engine, api-batch, and comm-bits ablations
            # publish to the same results tree; --sweeps is the
            # "regenerate docs/results" entry point
            from .api_batch import main as api_batch_main
            from .comm_bits import main as comm_bits_main
            from .round_engine import main as round_engine_main
            from .serve_throughput import main as serve_main
            re_argv = ["--out", args.out] if args.out else []
            rc = rc or round_engine_main(re_argv)
            rc = rc or api_batch_main(re_argv)
            rc = rc or comm_bits_main(re_argv)
            rc = rc or serve_main(re_argv)
        return rc

    print("name,us_per_call,derived")
    from . import (api_batch, comm_bits, comm_cost, kernel_bench,
                   m_invariance, moe_dispatch_ablation, oracle_backends,
                   round_engine, roofline, serve_throughput, thm2_rounds,
                   thm3_rounds, thm4_incremental)
    thm2_rounds.run()
    thm3_rounds.run()
    thm4_incremental.run()
    m_invariance.run()
    comm_cost.run()
    kernel_bench.run()
    oracle_backends.run()
    round_engine.run()
    api_batch.run()
    comm_bits.run()
    serve_throughput.run()
    moe_dispatch_ablation.run()
    roofline.run()
    return 0


if __name__ == "__main__":
    sys.exit(main())
