"""Theorem 2 (strongly convex): measured rounds-to-eps vs the lower bound.

Thin CLI wrapper over the ``repro.experiments`` sweep subsystem (preset
``thm2``): one row per (kappa, algorithm) — the tightness table of the
paper's main result. derived column = measured_rounds / lower_bound
(constant factor; tight iff bounded as kappa grows).

Full JSON + Markdown reports: ``python -m repro.experiments.sweep
--preset thm2``.
"""
from __future__ import annotations

from repro.experiments import PRESETS, run_sweep

from .common import emit


def run():
    result = run_sweep(PRESETS["thm2"])
    for r in result.records:
        kappa = int(r.instance_params["kappa"])
        k = r.measured_rounds if r.measured_rounds is not None else -1
        lb = r.bound_rounds
        ratio = r.ratio if r.ratio is not None else float("nan")
        emit(f"thm2/kappa{kappa}/{r.algorithm}/rounds_to_eps", k,
             f"lb={lb:.1f};ratio={ratio:.2f}")
    return result


if __name__ == "__main__":
    run()
