"""Theorem 2 (strongly convex): measured rounds-to-eps vs the lower bound.

One row per (kappa, algorithm): the tightness table of the paper's main
result. derived column = measured_rounds / lower_bound (constant factor;
tight iff bounded as kappa grows).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.core.bounds import thm2_strongly_convex
from repro.core.partition import even_partition
from repro.core.algorithms import dagd, dgd, disco_f
from .common import chain_erm, emit, rounds_to_eps, timeit


def run(eps: float = 1e-6, d: int = 160, lam: float = 0.5, m: int = 4):
    for kappa in (16.0, 64.0, 256.0):
        ci, prob = chain_erm(d, kappa, lam)
        part = even_partition(prob.d, m)
        fstar = float(prob.value(jnp.asarray(ci.w_star())))
        L = prob.smoothness_bound()
        wstar_norm = float(jnp.linalg.norm(ci.w_star()))
        lb = thm2_strongly_convex(kappa, lam, wstar_norm, eps).rounds
        for name, algo in (("dagd", dagd), ("dgd", dgd),
                           ("disco_f", disco_f)):
            k, led = rounds_to_eps(prob, part, algo, eps, fstar,
                                   max_rounds=3000, L=L, lam=lam)
            ratio = (k / lb) if (k and lb) else float("nan")
            emit(f"thm2/kappa{int(kappa)}/{name}/rounds_to_eps",
                 k if k else -1, f"lb={lb:.1f};ratio={ratio:.2f}")


if __name__ == "__main__":
    run()
