"""Oracle-backend ablation: einsum vs Pallas-kernel per-round wall-clock.

The paper meters communication rounds; the compute inside a round is free
to get as fast as the hardware allows. This benchmark drives metered
``LocalDistERM`` runs of the same algorithms under every oracle backend
("einsum" — plain jnp contractions; "kernel" — the MXU-tiled Pallas
kernels; "fused" — the whole-round kernels of
``kernels/fused_round.py`` with composed fused-epilogue fallbacks) and
reports:

  * wall-clock per communication round for each backend, and
  * the CommLedger (round count, op counts, bytes), which MUST be
    bit-identical across backends — the lower-bound certifications in
    ``docs/results/`` may not depend on how local FLOPs are computed.

On a TPU the kernel column is the production number. On CPU the Pallas
kernels execute in interpret mode, so the kernel column there proves the
dispatch path end-to-end (and the ledger invariance) rather than speed;
the report records the platform it ran on.

CLI:
    PYTHONPATH=src python -m benchmarks.oracle_backends
    PYTHONPATH=src python -m benchmarks.oracle_backends --out docs/results

Writes ``docs/results/oracle-backends.json`` + ``.md`` and refreshes the
results index. Exit status is non-zero if any ledger differs across
backends.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import sys
import time
from typing import List, Optional, Sequence

import jax

from repro import api
from repro.api import ORACLE_BACKENDS
from repro.core.comm import CommLedger
from repro.experiments.instances import build_instance

COMMAND = "PYTHONPATH=src python -m benchmarks.oracle_backends"


@dataclasses.dataclass(frozen=True)
class Preset:
    label: str
    n: int
    d: int
    m: int
    lam: float = 0.05
    rounds: int = 10


# Shapes on both sides of the paper's n-vs-d tradeoff: tall (n >> d),
# wide (d >> n, the feature-partition regime), and square.
PRESETS = (
    Preset("tall n=512 d=96 m=4", n=512, d=96, m=4),
    Preset("wide n=96 d=512 m=4", n=96, d=512, m=4),
    Preset("square n=256 d=256 m=8", n=256, d=256, m=8),
)

# dagd exercises feature_matvec + feature_rmatvec; disco_f additionally
# exercises the fused feature_hvp inside its CG loop. Both are driven
# through the experiments registry, so their hyper-parameters come from
# the same AlgoContext the certification sweeps use.
ALGORITHMS = ("dagd", "disco_f")


def _ledger_snapshot(ledger: CommLedger) -> dict:
    return dict(rounds=ledger.rounds, op_counts=ledger.op_counts(),
                total_bytes=ledger.total_bytes(),
                records=[(r.kind, r.elems, r.bytes, r.tag)
                         for r in ledger.records])


def _timed_run(preset: Preset, algo_name: str, backend: str,
               repeats: int) -> dict:
    bundle = build_instance("random_ridge", n=preset.n, d=preset.d,
                            m=preset.m, lam=preset.lam, seed=11)
    # engine="python" keeps the historical per-call oracle dispatch this
    # ablation times (the scan engine's per-round cost is measured by
    # benchmarks/round_engine.py instead)
    spec = api.RunSpec(instance="random_ridge",
                       instance_params=dict(n=preset.n, d=preset.d,
                                            m=preset.m, lam=preset.lam,
                                            seed=11),
                       algorithm=algo_name, rounds=preset.rounds,
                       measure="none", backend=backend, engine="python")
    pl = api.plan(spec, bundle=bundle)

    # warmup: compile every jitted oracle shape once
    result = pl.execute()
    jax.block_until_ready(result.w)
    ledger = _ledger_snapshot(result.ledger)

    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(pl.execute().w)
        times.append(time.perf_counter() - t0)
    us_per_round = min(times) / preset.rounds * 1e6
    return dict(backend=backend, us_per_round=round(us_per_round, 1),
                **{k: v for k, v in ledger.items() if k != "records"},
                _records=ledger["records"])


def run_ablation(repeats: int = 3,
                 presets: Sequence[Preset] = PRESETS) -> List[dict]:
    """One record per (preset, algorithm): both backends timed + the
    ledger-identity verdict."""
    records = []
    for preset in presets:
        for algo_name in ALGORITHMS:
            by_backend = {be: _timed_run(preset, algo_name, be, repeats)
                          for be in ORACLE_BACKENDS}
            base = by_backend["einsum"]
            identical = all(b["_records"] == base["_records"]
                            and b["rounds"] == base["rounds"]
                            for b in by_backend.values())
            rec = dict(
                instance_label=preset.label,
                instance_params=dict(n=preset.n, d=preset.d, m=preset.m,
                                     lam=preset.lam),
                algorithm=algo_name, rounds=preset.rounds,
                backends={be: {k: v for k, v in b.items()
                               if not k.startswith("_")}
                          for be, b in by_backend.items()},
                speedup_kernel_vs_einsum=round(
                    base["us_per_round"]
                    / by_backend["kernel"]["us_per_round"], 3),
                ledger_identical=identical,
            )
            records.append(rec)
    return records


# --------------------------------------------------------------------------
# Reporting
# --------------------------------------------------------------------------

def render_markdown(doc: dict) -> str:
    lines = [
        "# Oracle-backend ablation — `oracle-backends`",
        "",
        f"<!-- Generated by `{doc['command']}`. Do not edit by hand. -->",
        f"*Generated by* `{doc['command']}` *— regenerate instead of "
        "editing.*",
        "",
        f"- **Platform:** `{doc['platform']}`"
        + (" (Pallas kernels in **interpret mode** — the kernel column "
           "proves the dispatch path, not speed)"
           if doc["platform"] != "tpu" else " (compiled Pallas kernels)"),
        f"- **Backends:** {', '.join(f'`{b}`' for b in ORACLE_BACKENDS)}",
        f"- **Ledger invariance:** {doc['summary']['certified']}/"
        f"{doc['summary']['certifiable']} records with bit-identical "
        "CommLedgers across backends",
        "",
        "## Per-round wall-clock",
        "",
        "| instance | algorithm | einsum µs/round | kernel µs/round | "
        "fused µs/round | kernel/einsum speedup | ledger rounds | "
        "ledger identical |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in doc["records"]:
        ein, ker = r["backends"]["einsum"], r["backends"]["kernel"]
        fus = r["backends"].get("fused")
        lines.append(
            f"| {r['instance_label']} | {r['algorithm']} | "
            f"{ein['us_per_round']:.1f} | {ker['us_per_round']:.1f} | "
            f"{fus['us_per_round']:.1f} | " if fus else
            f"| {r['instance_label']} | {r['algorithm']} | "
            f"{ein['us_per_round']:.1f} | {ker['us_per_round']:.1f} | "
            "- | ")
        lines[-1] += (
            f"{r['speedup_kernel_vs_einsum']:.2f}x | "
            f"{ein['rounds']} | "
            f"{'yes' if r['ledger_identical'] else '**NO**'} |")
    lines += [
        "",
        "Reading the table: the two columns compute identical oracle "
        "values (`tests/test_runtime_parity.py` pins the iterates to "
        "match); the CommLedger — rounds, op kinds/sizes/tags, bytes — "
        "is asserted bit-identical per row, so every lower-bound "
        "certification under `docs/results/` is invariant to the compute "
        "backend. Run this on a TPU to see the MXU-tiled kernels ahead; "
        "on CPU the kernel path runs the Pallas interpreter and the "
        "einsum column is the production number.",
        "",
    ]
    return "\n".join(lines)


def write_reports(records: List[dict], out_dir) -> pathlib.Path:
    from repro.experiments.report import refresh_index

    out = pathlib.Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    ok = sum(1 for r in records if r["ledger_identical"])
    doc = dict(
        schema_version=1,
        command=COMMAND,
        spec=dict(name="oracle-backends", instance="random_ridge",
                  algorithms=sorted(ALGORITHMS),
                  backends=list(ORACLE_BACKENDS)),
        platform=jax.default_backend(),
        summary=dict(records=len(records), certifiable=len(records),
                     certified=ok, failed=len(records) - ok),
        records=records,
    )
    (out / "oracle-backends.json").write_text(
        json.dumps(doc, indent=2) + "\n")
    (out / "oracle-backends.md").write_text(render_markdown(doc))
    refresh_index(out)
    return out / "oracle-backends.json"


def run():
    """CSV rows for the legacy benchmarks/run.py surface."""
    from .common import emit
    for rec in run_ablation(repeats=1, presets=PRESETS[:1]):
        for be, b in rec["backends"].items():
            emit(f"oracle_backend/{rec['algorithm']}/{be}",
                 f"{b['us_per_round']:.1f}",
                 f"rounds={b['rounds']};ledger_identical="
                 f"{rec['ledger_identical']}")


def main(argv: Optional[Sequence[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m benchmarks.oracle_backends", description=__doc__)
    parser.add_argument("--out", default=None,
                        help="output directory (default: docs/results)")
    parser.add_argument("--repeats", type=int, default=3)
    parser.add_argument("--no-report", action="store_true")
    args = parser.parse_args(argv)

    records = run_ablation(repeats=args.repeats)
    for r in records:
        ein, ker = r["backends"]["einsum"], r["backends"]["kernel"]
        print(f"[oracle-backends] {r['instance_label']} "
              f"{r['algorithm']:>8}: einsum {ein['us_per_round']:.0f} "
              f"us/round, kernel {ker['us_per_round']:.0f} us/round, "
              f"ledger {'identical' if r['ledger_identical'] else 'DIFFERS'}",
              file=sys.stderr)
    if not args.no_report:
        from repro.experiments.report import default_results_dir
        out = args.out or default_results_dir()
        path = write_reports(records, out)
        print(f"[oracle-backends] report -> {path}")
    bad = [r for r in records if not r["ledger_identical"]]
    if bad:
        print(f"[oracle-backends] LEDGER DRIFT in {len(bad)} record(s): "
              "the communication meter depends on the compute backend",
              file=sys.stderr)
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
