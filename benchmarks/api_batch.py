"""Batched-execution ablation: ``repro.api.execute_batch`` vs the PR-3
sequential scan path, per certification cell.

A sweep's cells are same-shaped programs on different data, but the
sequential scan engine pays one trace + compile per cell (every cell's
step is a fresh closure, so no jit cache can help).  The api facade's
``execute_batch`` groups same-shaped cells and ``vmap``s the
scan-compiled round program across the grid — a thm2-style sweep
compiles a handful of XLA programs instead of one per cell.  This
benchmark reports:

  * **identity** — every cell of the ``thm2-small`` acceptance preset is
    executed both ways; the certification verdicts and the full
    ``CommLedger`` record streams MUST be bit-identical (the gap series
    agree up to batched-``dot_general`` reassociation, so
    ``measured_rounds`` is reported with the same ±1-round tolerance the
    TPU kernels get — observed 0 on CPU);
  * **per-cell wall-clock** — a widened kappa grid (the batched
    dimension) timed cold, exactly as a sweep pays it: sequential =
    build + trace + compile + run per cell; batched = one grouped
    program for the whole grid.  Gate: ≥ 2x per cell (``--quick`` skips
    the gate, not the identity checks).

CLI:
    PYTHONPATH=src python -m benchmarks.api_batch
    PYTHONPATH=src python -m benchmarks.api_batch --quick   # CI smoke

Writes ``docs/results/api-batch.json`` + ``.md`` and refreshes the
results index.  Exit status is non-zero on any identity violation (and,
unless ``--quick``, if the batched path misses the speedup floor).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time
from typing import List, Optional, Sequence

import jax

from repro import api
from repro.experiments.instances import build_instance
from repro.experiments.sweep import PRESETS

COMMAND = "PYTHONPATH=src python -m benchmarks.api_batch"

PRESET = "thm2-small"
SPEEDUP_FLOOR = 2.0      # acceptance: batched >= 2x sequential per cell

# the batched dimension for the timing run: one algorithm, many kappas —
# one group, one compiled program for the whole column.  Width 32: wide
# enough that the single group compile amortizes decisively over the
# per-cell compiles the sequential path pays (the gate must clear even
# in a warm process, e.g. chained after the sweeps in benchmarks/run.py,
# where XLA's warm caches flatter the sequential side)
TIMING_KAPPAS = tuple(float(2 ** (3 + i * 7 / 32)) for i in range(32))
TIMING_D, TIMING_M, TIMING_LAM = 96, 4, 0.5


def _preset_cells(rounds: Optional[int] = None,
                  algorithms: Optional[Sequence[str]] = None):
    """(bundle, point, algorithm) per thm2-small cell."""
    spec = PRESETS[PRESET]
    rounds = rounds or spec.max_rounds
    algorithms = tuple(algorithms or spec.algorithms)
    cells = []
    for point in spec.grid_points():
        bundle = build_instance(spec.instance, **point)
        for name in algorithms:
            cells.append((bundle, point, name))
    return spec, rounds, cells


def _verdict(pl: api.ExecutionPlan, result: api.RunResult,
             eps: float) -> dict:
    eps_abs = pl.eps_abs(eps)
    return dict(eps=eps, measured_rounds=result.measured_rounds(eps_abs),
                bound_rounds=pl.bound(eps_abs).rounds,
                certified=pl.certify(result, eps))   # sweep semantics


def run_identity(rounds: Optional[int] = None,
                 algorithms: Optional[Sequence[str]] = None) -> List[dict]:
    """Every thm2-small cell executed sequentially AND through
    execute_batch; verdict + ledger-stream identity per cell."""
    spec, rounds, cells = _preset_cells(rounds, algorithms)
    seq_plans = [api.plan(spec.cell_spec(point, name, max_rounds=rounds),
                          bundle=bundle)
                 for bundle, point, name in cells]
    seq = [pl.execute() for pl in seq_plans]
    bat_plans = [api.plan(spec.cell_spec(point, name, max_rounds=rounds),
                          bundle=bundle)
                 for bundle, point, name in cells]
    bat = api.execute_batch(bat_plans)

    records = []
    for (bundle, point, name), pls, rs, plb, rb in zip(
            cells, seq_plans, seq, bat_plans, bat):
        vs = [_verdict(pls, rs, e) for e in spec.eps]
        vb = [_verdict(plb, rb, e) for e in spec.eps]
        records.append(dict(
            instance_label=bundle.label, instance_params=dict(point),
            algorithm=name, rounds=rounds, batched=rb.batched,
            sequential=vs, batch=vb,
            verdict_identical=[a["certified"] for a in vs]
                              == [b["certified"] for b in vb],
            measured_rounds_identical=[a["measured_rounds"] for a in vs]
                                      == [b["measured_rounds"] for b in vb],
            ledger_identical=(rs.stream() == rb.stream()
                              and rs.ledger.rounds == rb.ledger.rounds),
        ))
    return records


def run_timing(rounds: int = 2500,
               kappas: Sequence[float] = TIMING_KAPPAS) -> dict:
    """Cold per-cell wall-clock over the batched (kappa) dimension —
    compile included on both sides, exactly as a sweep pays it."""
    points = [dict(d=TIMING_D, kappa=float(k), lam=TIMING_LAM, m=TIMING_M)
              for k in kappas]
    bundles = [build_instance("thm2_chain", **p) for p in points]

    def make_plans():
        return [api.plan(api.RunSpec(
            instance="thm2_chain", instance_params=p, algorithm="dagd",
            rounds=rounds, eps=(1e-6,), tag="api-batch"), bundle=b)
            for p, b in zip(points, bundles)]

    t0 = time.perf_counter()
    seq_results = [pl.execute() for pl in make_plans()]
    t_seq = time.perf_counter() - t0

    t0 = time.perf_counter()
    bat_results = api.execute_batch(make_plans())
    t_batch = time.perf_counter() - t0

    identical = all(
        s.stream() == b.stream() and b.batched
        for s, b in zip(seq_results, bat_results))
    C = len(kappas)
    return dict(
        instance="thm2_chain", algorithm="dagd", rounds=rounds,
        batch_width=C, kappas=list(kappas),
        sequential_s_total=round(t_seq, 3),
        sequential_s_per_cell=round(t_seq / C, 4),
        batch_s_total=round(t_batch, 3),
        batch_s_per_cell=round(t_batch / C, 4),
        speedup_per_cell=round(t_seq / max(t_batch, 1e-9), 2),
        ledger_identical=identical,
    )


# --------------------------------------------------------------------------
# Reporting
# --------------------------------------------------------------------------

def render_markdown(doc: dict) -> str:
    lines = [
        "# Batched-execution ablation — `api-batch`",
        "",
        f"<!-- Generated by `{doc['command']}`. Do not edit by hand. -->",
        f"*Generated by* `{doc['command']}` *— regenerate instead of "
        "editing.*",
        "",
        f"- **Platform:** `{doc['platform']}`",
        "- **Paths:** sequential (one scan-compiled program per cell, "
        "PR-3) vs `repro.api.execute_batch` (same-shaped cells grouped "
        "and `vmap`-ed through one compiled program)",
        f"- **Identity:** {doc['summary']['certified']}/"
        f"{doc['summary']['certifiable']} `{doc['spec']['preset']}` cells "
        "with identical certification verdicts AND bit-identical "
        "CommLedger streams across the two paths",
    ]
    timing = doc.get("timing")
    if timing:
        lines.append(
            f"- **Speedup:** **{timing['speedup_per_cell']:.1f}x** per "
            f"cell over the batched dimension (width "
            f"{timing['batch_width']}, cold — compile included, as a "
            f"sweep pays it); floor {doc['summary']['speedup_floor']:.0f}x")
    lines += [
        "",
        "## Identity per certification cell",
        "",
        "| instance | algorithm | batched | verdicts identical | "
        "measured rounds identical | ledger identical |",
        "|---|---|---|---|---|---|",
    ]
    for r in doc["records"]:
        lines.append(
            f"| {r['instance_label']} | {r['algorithm']} | "
            f"{'yes' if r['batched'] else 'no (fallback)'} | "
            f"{'yes' if r['verdict_identical'] else '**NO**'} | "
            f"{'yes' if r['measured_rounds_identical'] else 'within ±1'} | "
            f"{'yes' if r['ledger_identical'] else '**NO**'} |")
    if timing:
        lines += [
            "",
            "## Per-cell wall-clock (batched dimension: kappa grid)",
            "",
            "| path | s/cell | s total | cells |",
            "|---|---|---|---|",
            f"| sequential (compile per cell) | "
            f"{timing['sequential_s_per_cell']:.3f} | "
            f"{timing['sequential_s_total']:.2f} | "
            f"{timing['batch_width']} |",
            f"| execute_batch (one program) | "
            f"{timing['batch_s_per_cell']:.3f} | "
            f"{timing['batch_s_total']:.2f} | "
            f"{timing['batch_width']} |",
        ]
    lines += [
        "",
        "Reading the tables: both paths run the same step functions; the "
        "batched path replays the same trace-once ledger schedule the "
        "scan engine uses, so every certification under `docs/results/` "
        "is invariant to it by construction. Gap series agree up to "
        "batched-`dot_general` reassociation (the same ±1-round "
        "eps-crossing tolerance the TPU kernels get; observed exact on "
        "CPU). The wall-clock win is compile amortization: a sweep's "
        "cells are fresh closures, so the sequential path compiles per "
        "cell while `execute_batch` compiles once per group.",
        "",
    ]
    return "\n".join(lines)


def write_reports(records: List[dict], timing: Optional[dict],
                  out_dir, rounds: int) -> pathlib.Path:
    from repro.experiments.report import refresh_index

    out = pathlib.Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    ok = sum(1 for r in records
             if r["verdict_identical"] and r["ledger_identical"])
    doc = dict(
        schema_version=1,
        command=COMMAND,
        spec=dict(name="api-batch", preset=PRESET,
                  instance=PRESETS[PRESET].instance,
                  algorithms=sorted({r["algorithm"] for r in records}),
                  rounds=rounds),
        platform=jax.default_backend(),
        summary=dict(records=len(records), certifiable=len(records),
                     certified=ok, failed=len(records) - ok,
                     speedup_per_cell=(timing or {}).get("speedup_per_cell"),
                     speedup_floor=SPEEDUP_FLOOR),
        timing=timing,
        records=records,
    )
    (out / "api-batch.json").write_text(json.dumps(doc, indent=2) + "\n")
    (out / "api-batch.md").write_text(render_markdown(doc))
    refresh_index(out)
    return out / "api-batch.json"


def run():
    """CSV rows for the legacy benchmarks/run.py surface."""
    from .common import emit
    timing = run_timing(rounds=400, kappas=TIMING_KAPPAS[:4])
    for path in ("sequential", "batch"):
        emit(f"api_batch/dagd/{path}",
             f"{timing[f'{path}_s_per_cell'] * 1e6:.0f}",
             f"cells={timing['batch_width']};speedup="
             f"{timing['speedup_per_cell']};ledger_identical="
             f"{timing['ledger_identical']}")


def main(argv: Optional[Sequence[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m benchmarks.api_batch", description=__doc__)
    parser.add_argument("--out", default=None,
                        help="output directory (default: docs/results)")
    parser.add_argument("--rounds", type=int, default=None,
                        help="override the preset round budget")
    parser.add_argument("--quick", action="store_true",
                        help="CI smoke: fewer rounds, identity checks "
                             "only (no timing/speedup gate)")
    parser.add_argument("--no-report", action="store_true")
    args = parser.parse_args(argv)

    if args.quick:
        records = run_identity(rounds=args.rounds or 300,
                               algorithms=("dagd", "disco_f"))
        timing = None
    else:
        records = run_identity(rounds=args.rounds)
        timing = run_timing(rounds=args.rounds or 2500)
    rounds = records[0]["rounds"] if records else 0
    for r in records:
        print(f"[api-batch] {r['instance_label']} {r['algorithm']:>8}: "
              f"batched={r['batched']}, verdicts "
              f"{'identical' if r['verdict_identical'] else 'DIFFER'}, "
              f"measured "
              f"{'identical' if r['measured_rounds_identical'] else '±1'}, "
              f"ledger "
              f"{'identical' if r['ledger_identical'] else 'DIFFERS'}",
              file=sys.stderr)
    if timing:
        print(f"[api-batch] timing: sequential "
              f"{timing['sequential_s_per_cell']:.3f} s/cell, batched "
              f"{timing['batch_s_per_cell']:.3f} s/cell "
              f"({timing['speedup_per_cell']:.1f}x, width "
              f"{timing['batch_width']})", file=sys.stderr)
    if not args.no_report:
        from repro.experiments.report import default_results_dir
        out = args.out or default_results_dir()
        path = write_reports(records, timing, out, rounds)
        print(f"[api-batch] report -> {path}")
    bad = [r for r in records
           if not (r["verdict_identical"] and r["ledger_identical"])]
    if bad:
        print(f"[api-batch] BATCH DRIFT in {len(bad)} cell(s): "
              "certification depends on the execution path",
              file=sys.stderr)
        return 1
    if timing and not timing["ledger_identical"]:
        print("[api-batch] LEDGER DRIFT in the timing grid",
              file=sys.stderr)
        return 1
    if timing and timing["speedup_per_cell"] < SPEEDUP_FLOOR:
        print(f"[api-batch] SPEEDUP FLOOR MISSED: "
              f"{timing['speedup_per_cell']:.2f}x < {SPEEDUP_FLOOR}x",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
