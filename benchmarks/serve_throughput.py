"""Certification-service load benchmark: sustained throughput + latency.

Drives ``repro.serve.CertificationService`` through a seeded synthetic
trace (``repro.serve.workload``) in saturation mode — the coalescing
deadline is disabled (``max_wait=inf``) so batches release only at full
``max_batch`` width (plus the final drain), which makes the batch
sequence and therefore the compiled-cache ledger deterministic while the
*latencies* are measured on the real clock.  Reports:

  * **throughput** — sustained specs/second over the whole trace, and
    p50/p99 submit→verdict latency (coalescing wait + execution);
  * **compiled-cache hit rate** — fraction of batch executions that paid
    no XLA compile (key AND width seen before).  Gate: ≥ 80%.  Under
    continuous batching at a fixed width this is the steady-state
    regime; missing the floor means the scheduler stopped reusing
    compiled programs;
  * **identity** — every served envelope's certification verdicts and
    typed ``CommLedger`` stream MUST be bit-identical to executing its
    RunSpec directly via ``repro.api.plan(spec).execute()``.  The
    serving layer may change when and with whom a spec is compiled,
    never what it computes.

CLI:
    PYTHONPATH=src python -m benchmarks.serve_throughput
    PYTHONPATH=src python -m benchmarks.serve_throughput --quick  # CI

Writes ``docs/results/serve-throughput.json`` + ``.md`` and refreshes
the results index.  Exit status is non-zero on any identity violation
or a missed hit-rate floor (both gates apply to ``--quick`` too).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time
from typing import List, Optional, Sequence

import jax
import numpy as np

from repro import api
from repro.serve import (CertificationService, DEFAULT_STRUCTURES,
                         spec_pool, synthetic_trace)

COMMAND = "PYTHONPATH=src python -m benchmarks.serve_throughput"

HIT_RATE_FLOOR = 0.80
MAX_BATCH = 8

# trace sizes are multiples of MAX_BATCH so saturation mode yields only
# full-width batches: per structure n/MAX_BATCH executions, 1 miss
FULL_PER_STRUCTURE = 96       # 3 structures -> 36 exec, 33 hits (0.917)
QUICK_PER_STRUCTURE = 48      # 2 structures -> 12 exec, 10 hits (0.833)


def run_load(n_per_structure: int, structures=DEFAULT_STRUCTURES,
             seed: int = 0) -> dict:
    """Serve the trace in saturation mode; return measurements plus the
    raw envelopes and pools for the identity pass."""
    pools = spec_pool(structures)
    trace = synthetic_trace(n_per_structure=n_per_structure, seed=seed,
                            pools=pools)
    service = CertificationService(max_batch=MAX_BATCH,
                                   max_wait=float("inf"),
                                   cache_capacity=32,
                                   max_depth=len(trace) + 1)
    envelopes = []
    t0 = time.perf_counter()
    for a in trace:
        envelopes.extend(service.step(time.perf_counter() - t0))
        service.submit(a.spec, client_id=a.client_id,
                       now=time.perf_counter() - t0)
    envelopes.extend(service.drain(time.perf_counter() - t0))
    wall = time.perf_counter() - t0

    lat = sorted(e.latency for e in envelopes)
    cache = service.cache.stats()
    return dict(
        pools=pools, envelopes=envelopes,
        measurements=dict(
            n_specs=len(trace), wall_s=round(wall, 3),
            specs_per_s=round(len(trace) / wall, 2),
            p50_latency_s=round(lat[len(lat) // 2], 4),
            p99_latency_s=round(lat[min(len(lat) - 1,
                                        int(len(lat) * 0.99))], 4),
            max_batch=MAX_BATCH, batches=service.batches,
            fallbacks=service.fallbacks,
            structures=[f"{a}/{c}" for a, c in structures],
            cache=cache.to_dict()))


def run_identity(pools, envelopes) -> List[dict]:
    """Direct-execute each distinct pool spec once; check every served
    envelope of that spec against it."""
    records = []
    for pool in pools:
        for spec in pool:
            pl = api.plan(spec)
            ref = pl.execute()
            ref_verdicts = [dict(
                eps=e, measured_rounds=ref.measured_rounds(pl.eps_abs(e)),
                bound_rounds=pl.bound(pl.eps_abs(e)).rounds,
                certified=pl.certify(ref, e)) for e in spec.eps]
            mine = [env for env in envelopes if env.spec == spec]
            records.append(dict(
                algorithm=spec.algorithm, channel=spec.channel,
                kappa=spec.instance_params["kappa"],
                n_served=len(mine),
                verdict_identical=all(env.verdicts == ref_verdicts
                                      for env in mine),
                ledger_identical=all(
                    env.result.ledger.typed_stream()
                    == ref.ledger.typed_stream()
                    and env.result.ledger.rounds == ref.ledger.rounds
                    for env in mine),
                iterate_identical=all(
                    np.allclose(env.result.w, ref.w,
                                atol=1e-5, rtol=1e-5) for env in mine),
            ))
    return records


# --------------------------------------------------------------------------
# Reporting
# --------------------------------------------------------------------------

def render_markdown(doc: dict) -> str:
    m = doc["measurements"]
    cache = m["cache"]
    lines = [
        "# Certification-service load benchmark — `serve-throughput`",
        "",
        f"<!-- Generated by `{doc['command']}`. Do not edit by hand. -->",
        f"*Generated by* `{doc['command']}` *— regenerate instead of "
        "editing.*",
        "",
        f"- **Platform:** `{doc['platform']}`",
        "- **Path:** `repro.serve` continuous batching (saturation mode: "
        f"count-flush at width {m['max_batch']}, deadline disabled) over "
        f"a seeded trace of {m['n_specs']} RunSpecs, "
        f"{len(m['structures'])} structures: "
        + ", ".join(f"`{s}`" for s in m["structures"]),
        f"- **Throughput:** **{m['specs_per_s']:.1f} specs/s** sustained "
        f"({m['wall_s']:.1f} s wall); latency p50 "
        f"{m['p50_latency_s'] * 1e3:.0f} ms / p99 "
        f"{m['p99_latency_s'] * 1e3:.0f} ms (submit -> verdict, "
        "coalescing wait included)",
        f"- **Compiled cache:** {cache['hits']}/{cache['hits'] + cache['misses']} "
        f"batch executions compile-free (hit rate "
        f"{cache['hit_rate']:.3f}, floor {doc['summary']['hit_rate_floor']}"
        f"; {cache['evictions']} evictions)",
        f"- **Identity:** {doc['summary']['certified']}/"
        f"{doc['summary']['certifiable']} distinct specs with verdicts, "
        "typed ledger streams, and iterates identical to direct "
        "`plan(spec).execute()` across every served envelope",
        "",
        "## Identity per distinct RunSpec",
        "",
        "| algorithm | channel | kappa | served | verdicts | ledger | "
        "iterate |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in doc["records"]:
        lines.append(
            f"| {r['algorithm']} | {r['channel']} | {r['kappa']:g} | "
            f"{r['n_served']} | "
            f"{'identical' if r['verdict_identical'] else '**DIFFER**'} | "
            f"{'identical' if r['ledger_identical'] else '**DIFFER**'} | "
            f"{'identical' if r['iterate_identical'] else '**DIFFER**'} |")
    lines += [
        "",
        "Reading the table: the service coalesces same-`group_key` "
        "submissions into vmapped batches and reuses the jitted group "
        "runners across batches (the LRU program cache), so the compile "
        "is paid once per (structure, width). A hit rate at/above the "
        "floor is the steady-state continuous-batching regime; identity "
        "means serving is invisible to certification — the same "
        "trace-once ledger schedule and verdicts as the PR-4 direct "
        "path.",
        "",
    ]
    return "\n".join(lines)


def write_reports(measurements: dict, records: List[dict],
                  out_dir) -> pathlib.Path:
    from repro.experiments.report import refresh_index

    out = pathlib.Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    ok = sum(1 for r in records
             if r["verdict_identical"] and r["ledger_identical"]
             and r["iterate_identical"])
    doc = dict(
        schema_version=1,
        command=COMMAND,
        spec=dict(name="serve-throughput", instance="thm2_chain",
                  structures=measurements["structures"],
                  n_specs=measurements["n_specs"],
                  max_batch=measurements["max_batch"]),
        platform=jax.default_backend(),
        summary=dict(records=len(records), certifiable=len(records),
                     certified=ok, failed=len(records) - ok,
                     specs_per_s=measurements["specs_per_s"],
                     hit_rate=measurements["cache"]["hit_rate"],
                     hit_rate_floor=HIT_RATE_FLOOR),
        measurements=measurements,
        records=records,
    )
    (out / "serve-throughput.json").write_text(
        json.dumps(doc, indent=2) + "\n")
    (out / "serve-throughput.md").write_text(render_markdown(doc))
    refresh_index(out)
    return out / "serve-throughput.json"


def run():
    """CSV rows for the legacy benchmarks/run.py surface."""
    from .common import emit
    load = run_load(n_per_structure=16,
                    structures=DEFAULT_STRUCTURES[:2])
    m = load["measurements"]
    emit("serve/throughput",
         f"{1e6 / max(m['specs_per_s'], 1e-9):.0f}",
         f"specs={m['n_specs']};specs_per_s={m['specs_per_s']};"
         f"hit_rate={m['cache']['hit_rate']}")


def main(argv: Optional[Sequence[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m benchmarks.serve_throughput", description=__doc__)
    parser.add_argument("--out", default=None,
                        help="output directory (default: docs/results)")
    parser.add_argument("--quick", action="store_true",
                        help="CI smoke: smaller trace, same gates")
    parser.add_argument("--no-report", action="store_true")
    args = parser.parse_args(argv)

    if args.quick:
        load = run_load(QUICK_PER_STRUCTURE,
                        structures=DEFAULT_STRUCTURES[:2])
    else:
        load = run_load(FULL_PER_STRUCTURE)
    m = load["measurements"]
    print(f"[serve-throughput] {m['n_specs']} specs in {m['wall_s']:.1f} s "
          f"= {m['specs_per_s']:.1f} specs/s; latency p50 "
          f"{m['p50_latency_s'] * 1e3:.0f} ms, p99 "
          f"{m['p99_latency_s'] * 1e3:.0f} ms; cache hit rate "
          f"{m['cache']['hit_rate']:.3f} "
          f"({m['cache']['hits']}/{m['cache']['hits'] + m['cache']['misses']})",
          file=sys.stderr)
    records = run_identity(load["pools"], load["envelopes"])
    for r in records:
        status = ("identical" if r["verdict_identical"]
                  and r["ledger_identical"] and r["iterate_identical"]
                  else "DIFFERS")
        print(f"[serve-throughput] {r['algorithm']:>6}/{r['channel']} "
              f"kappa={r['kappa']:g}: {r['n_served']} served, {status}",
              file=sys.stderr)
    if not args.no_report:
        from repro.experiments.report import default_results_dir
        out = args.out or default_results_dir()
        path = write_reports(m, records, out)
        print(f"[serve-throughput] report -> {path}")
    bad = [r for r in records
           if not (r["verdict_identical"] and r["ledger_identical"]
                   and r["iterate_identical"])]
    if bad:
        print(f"[serve-throughput] SERVING DRIFT in {len(bad)} spec(s): "
              "certification depends on the serving path", file=sys.stderr)
        return 1
    if m["cache"]["hit_rate"] < HIT_RATE_FLOOR:
        print(f"[serve-throughput] HIT-RATE FLOOR MISSED: "
              f"{m['cache']['hit_rate']:.3f} < {HIT_RATE_FLOOR}",
              file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
