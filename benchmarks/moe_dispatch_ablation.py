"""Ablation: sort-based MoE dispatch (shipped) vs cumulative-one-hot
dispatch (the naive formulation).

The naive position computation — `cumsum(one_hot(expert_ids))` over
(T*k, E) — lowers to a reduce-window whose HLO cost model is quadratic
in T*k, which both bloats real traffic and poisoned the roofline before
the fix (DESIGN.md §5.5). This benchmark compiles both dispatch builds
and reports HLO FLOPs, demonstrating the blowup.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import emit


def _positions_sort(flat_e, e):
    order = jnp.argsort(flat_e, stable=True)
    counts = jnp.zeros((e,), jnp.int32).at[flat_e].add(1)
    starts = jnp.cumsum(counts) - counts
    n = flat_e.shape[0]
    pos_sorted = jnp.arange(n, dtype=jnp.int32) - starts[flat_e[order]]
    return jnp.zeros((n,), jnp.int32).at[order].set(pos_sorted)


def _positions_cumsum(flat_e, e):
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)
    pos_in_e = jnp.cumsum(onehot, axis=0) - onehot
    return jnp.take_along_axis(pos_in_e, flat_e[:, None], axis=1)[:, 0]


def run(t: int = 32768, k: int = 2, e: int = 16):
    ids = jax.ShapeDtypeStruct((t * k,), jnp.int32)
    flops = {}
    for name, fn in (("sort", _positions_sort),
                     ("cumsum", _positions_cumsum)):
        compiled = jax.jit(lambda x, fn=fn: fn(x, e)).lower(ids).compile()
        ca = compiled.cost_analysis()
        flops[name] = float(ca.get("flops", 0.0)) + \
            float(ca.get("transcendentals", 0.0))
        emit(f"moe_dispatch/{name}/hlo_flops", f"{flops[name]:.3e}",
             f"T*k={t*k};E={e}")
    blowup = flops["cumsum"] / max(flops["sort"], 1.0)
    emit("moe_dispatch/cumsum_vs_sort_blowup", f"{blowup:.1f}",
         "reduce-window quadratic cost vs O(T log T) sort")


if __name__ == "__main__":
    run()
