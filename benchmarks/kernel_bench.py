"""Kernel microbenchmarks.

On this CPU container the Pallas kernels execute in interpret mode
(correctness target only, not speed); the wall-time numbers that matter
for the CPU runs are the jnp reference paths, which we also use as the
oracle. Both are reported; the interpret-mode column exists to prove the
kernels run end-to-end.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from .common import emit, timeit


def run(small: bool = True):
    n, d = (2048, 1024) if small else (16384, 8192)
    k = jax.random.PRNGKey(0)
    A = jax.random.normal(k, (n, d), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (d,), jnp.float32)
    r = jax.random.normal(jax.random.PRNGKey(2), (n,), jnp.float32)

    jref_mv = jax.jit(ref.feature_matvec_ref)
    us = timeit(lambda: jref_mv(A, w))
    emit("kernel/feature_matvec/jnp_ref", f"{us:.1f}",
         f"gflops={2*n*d/us/1e3:.2f}")
    jref_rmv = jax.jit(ref.feature_rmatvec_ref)
    us = timeit(lambda: jref_rmv(A, r))
    emit("kernel/feature_rmatvec/jnp_ref", f"{us:.1f}",
         f"gflops={2*n*d/us/1e3:.2f}")
    h = jax.random.normal(jax.random.PRNGKey(4), (n,), jnp.float32) ** 2
    jref_hvp = jax.jit(ref.feature_hvp_ref)
    us = timeit(lambda: jref_hvp(A, h, r))
    emit("kernel/feature_hvp/jnp_ref", f"{us:.1f}",
         f"gflops={2*n*d/us/1e3:.2f}")

    dd = 65536
    diag = jax.random.normal(k, (dd,))
    off = jax.random.normal(k, (dd - 1,))
    v = jax.random.normal(jax.random.PRNGKey(3), (dd,))
    jref_td = jax.jit(ref.tridiag_matvec_ref)
    us = timeit(lambda: jref_td(diag, off, v))
    emit("kernel/tridiag_matvec/jnp_ref", f"{us:.1f}",
         f"gbytes_s={5*dd*4/us/1e3:.2f}")

    # interpret-mode Pallas (correctness path; slow on CPU by design)
    us = timeit(lambda: ops.feature_matvec(A[:256, :256], w[:256]),
                n_iter=3, warmup=1)
    emit("kernel/feature_matvec/pallas_interpret_256", f"{us:.1f}",
         "correctness-path")

    # flash-decode: streaming KV attention (jnp oracle timing on CPU)
    b, hk, g, dh, T = 2, 4, 2, 64, 8192
    import jax as _jax
    q = jax.random.normal(k, (b, hk, g, dh))
    kc = jax.random.normal(k, (b, T, hk, dh))
    vc = jax.random.normal(k, (b, T, hk, dh))
    bias = jnp.zeros((b, T))
    jref_fd = jax.jit(ref.flash_decode_ref)
    us = timeit(lambda: jref_fd(q, kc, vc, bias))
    kv_bytes = 2 * b * T * hk * dh * 4
    emit("kernel/flash_decode/jnp_ref", f"{us:.1f}",
         f"kv_gbytes_s={kv_bytes/us/1e3:.2f}")
    us = timeit(lambda: ops.flash_decode(q, kc[:, :512], vc[:, :512],
                                         bias[:, :512]), n_iter=3, warmup=1)
    emit("kernel/flash_decode/pallas_interpret_512", f"{us:.1f}",
         "correctness-path")

    t, kk, dmod = 4096, 8, 512
    x = jax.random.normal(k, (t, kk, dmod))
    cw = jax.random.normal(k, (t, kk))
    jref_moe = jax.jit(ref.moe_combine_ref)
    us = timeit(lambda: jref_moe(x, cw))
    emit("kernel/moe_combine/jnp_ref", f"{us:.1f}",
         f"gbytes_s={(t*kk*dmod+t*dmod)*4/us/1e3:.2f}")


if __name__ == "__main__":
    run()
