"""Round-engine ablation: python-loop vs scan-compiled wall-clock per
certification cell.

The paper's certification workload is thousands of communication rounds
per (algorithm x instance) cell. The python engine dispatches every op of
every round from the host; the scan engine traces each step once, wraps
it in ``lax.scan``, and runs one XLA program per segment. This benchmark
drives the ``thm2-small`` sweep preset's cells (the acceptance preset:
2500-round DAGD/DGD/DISCO-F runs on the Theorem-2 chain) under both
engines — full certification measurement included, i.e. the in-scan
per-round gap series — and reports:

  * steady-state wall-clock per cell and per round for each engine (the
    scan engine is warmed once so repeats hit the jit cache, mirroring
    how a long certification sweep amortizes its single trace);
  * the certification outcome (measured rounds-to-eps), which MUST be
    identical across engines; and
  * the CommLedger record stream, which MUST be bit-identical across
    engines — the lower-bound certifications in ``docs/results/`` may
    not depend on how rounds are driven.

These are the first entries in the repo's performance trajectory for the
round path; regenerate after any engine change and compare the JSON.

CLI:
    PYTHONPATH=src python -m benchmarks.round_engine
    PYTHONPATH=src python -m benchmarks.round_engine --quick   # CI smoke

Writes ``docs/results/round-engine.json`` + ``.md`` and refreshes the
results index. Exit status is non-zero if any cell's certification
outcome or ledger stream differs across engines (and, unless ``--quick``,
if the scan engine fails the >= 10x speedup floor on any cell).
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys
import time
from typing import List, Optional, Sequence

import numpy as np
import jax

from repro import api
from repro.core.engine import ENGINES, EngineSession
from repro.experiments.instances import build_instance
from repro.experiments.sweep import PRESETS

COMMAND = "PYTHONPATH=src python -m benchmarks.round_engine"

PRESET = "thm2-small"
SPEEDUP_FLOOR = 10.0     # acceptance: scan >= 10x python on these cells


def _measured_rounds(gaps: np.ndarray, eps: float) -> Optional[int]:
    hits = np.nonzero(gaps <= eps)[0]
    return int(hits[0]) + 1 if hits.size else None


def _timed_cell(bundle, point: dict, algo_name: str, engine: str,
                rounds: int, eps: Sequence[float], repeats: int) -> dict:
    """One engine's steady-state timing of a full certification cell:
    metered run + in-scan gap measurement, exactly what the sweep does —
    driven through the repro.api facade."""
    spec = PRESETS[PRESET].cell_spec(point, algo_name, max_rounds=rounds,
                                     engine=engine)
    pl = api.plan(spec, bundle=bundle)
    session = EngineSession()
    # warmup: the scan engine traces + compiles here; repeats below hit
    # the session's jit cache (how a sweep's round budget amortizes it)
    result = pl.execute(session=session)
    stream = result.stream()
    ledger_rounds = result.ledger.rounds

    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        res = pl.execute(session=session)
        np.asarray(res.gaps)        # gaps are host-materialized already
        times.append(time.perf_counter() - t0)
    secs = min(times)
    return dict(engine=engine,
                s_per_cell=round(secs, 4),
                us_per_round=round(secs / rounds * 1e6, 2),
                rounds=rounds, ledger_rounds=ledger_rounds,
                measured_rounds={f"{e:g}": _measured_rounds(result.gaps, e)
                                 for e in eps},
                _stream=stream)


def run_ablation(repeats: int = 3, rounds: Optional[int] = None,
                 algorithms: Optional[Sequence[str]] = None) -> List[dict]:
    """One record per thm2-small (instance, algorithm) cell: both engines
    timed, certification-outcome and ledger-identity verdicts attached."""
    spec = PRESETS[PRESET]
    rounds = rounds or spec.max_rounds
    algorithms = tuple(algorithms or spec.algorithms)
    records = []
    for point in spec.grid_points():
        bundle = build_instance(spec.instance, **point)
        for name in algorithms:
            by_engine = {eng: _timed_cell(bundle, point, name, eng,
                                          rounds, spec.eps, repeats)
                         for eng in ENGINES}
            py, sc = by_engine["python"], by_engine["scan"]
            records.append(dict(
                instance_label=bundle.label,
                instance_params=dict(bundle.params),
                algorithm=name, rounds=rounds,
                engines={eng: {k: v for k, v in rec.items()
                               if not k.startswith("_")}
                         for eng, rec in by_engine.items()},
                speedup_scan_vs_python=round(
                    py["s_per_cell"] / max(sc["s_per_cell"], 1e-9), 2),
                outcome_identical=(py["measured_rounds"]
                                   == sc["measured_rounds"]),
                ledger_identical=(py["_stream"] == sc["_stream"]
                                  and py["ledger_rounds"]
                                  == sc["ledger_rounds"]),
            ))
    return records


# --------------------------------------------------------------------------
# Reporting
# --------------------------------------------------------------------------

def render_markdown(doc: dict) -> str:
    lines = [
        "# Round-engine ablation — `round-engine`",
        "",
        f"<!-- Generated by `{doc['command']}`. Do not edit by hand. -->",
        f"*Generated by* `{doc['command']}` *— regenerate instead of "
        "editing.*",
        "",
        f"- **Platform:** `{doc['platform']}`",
        f"- **Engines:** {', '.join(f'`{e}`' for e in ENGINES)} "
        "(python: per-call loop; scan: one `lax.scan`-compiled XLA "
        "program per segment, trace-once ledger schedule)",
        f"- **Workload:** the `{doc['spec']['preset']}` certification "
        f"cells at {doc['spec']['rounds']} rounds, in-scan gap "
        "measurement included",
        f"- **Invariance:** {doc['summary']['certified']}/"
        f"{doc['summary']['certifiable']} cells with identical "
        "certification outcomes AND bit-identical CommLedger streams "
        "across engines",
        "",
        "## Wall-clock per certification cell",
        "",
        "| instance | algorithm | python s/cell | scan s/cell | "
        "python µs/round | scan µs/round | scan/python speedup | "
        "outcome identical | ledger identical |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in doc["records"]:
        py, sc = r["engines"]["python"], r["engines"]["scan"]
        lines.append(
            f"| {r['instance_label']} | {r['algorithm']} | "
            f"{py['s_per_cell']:.3f} | {sc['s_per_cell']:.3f} | "
            f"{py['us_per_round']:.1f} | {sc['us_per_round']:.1f} | "
            f"**{r['speedup_scan_vs_python']:.1f}x** | "
            f"{'yes' if r['outcome_identical'] else '**NO**'} | "
            f"{'yes' if r['ledger_identical'] else '**NO**'} |")
    lines += [
        "",
        "Reading the table: both engines run the same step functions and "
        "meter the same communication — the certification pipeline is "
        "invariant to the engine by construction "
        "(`tests/test_ledger_invariance.py`, `tests/test_engine.py`). "
        "The scan column is the production path (`--engine scan`, the "
        "default); the python column is the per-call debugging path the "
        "original Python loops correspond to. Steady-state timing: the "
        "scan engine's one-time trace+compile is excluded by a warmup "
        "run, as a multi-thousand-round sweep amortizes it.",
        "",
    ]
    return "\n".join(lines)


def write_reports(records: List[dict], out_dir, rounds: int) -> pathlib.Path:
    from repro.experiments.report import refresh_index

    out = pathlib.Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    ok = sum(1 for r in records
             if r["outcome_identical"] and r["ledger_identical"])
    doc = dict(
        schema_version=1,
        command=COMMAND,
        spec=dict(name="round-engine", preset=PRESET,
                  instance=PRESETS[PRESET].instance,
                  algorithms=sorted({r["algorithm"] for r in records}),
                  engines=list(ENGINES), rounds=rounds),
        platform=jax.default_backend(),
        summary=dict(records=len(records), certifiable=len(records),
                     certified=ok, failed=len(records) - ok,
                     min_speedup=min((r["speedup_scan_vs_python"]
                                      for r in records), default=None),
                     speedup_floor=SPEEDUP_FLOOR),
        records=records,
    )
    (out / "round-engine.json").write_text(json.dumps(doc, indent=2) + "\n")
    (out / "round-engine.md").write_text(render_markdown(doc))
    refresh_index(out)
    return out / "round-engine.json"


def run():
    """CSV rows for the legacy benchmarks/run.py surface."""
    from .common import emit
    for rec in run_ablation(repeats=1, rounds=400, algorithms=("dagd",)):
        for eng, b in rec["engines"].items():
            emit(f"round_engine/{rec['algorithm']}/{eng}",
                 f"{b['us_per_round']:.1f}",
                 f"rounds={b['rounds']};speedup="
                 f"{rec['speedup_scan_vs_python']};outcome_identical="
                 f"{rec['outcome_identical']}")


def main(argv: Optional[Sequence[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m benchmarks.round_engine", description=__doc__)
    parser.add_argument("--out", default=None,
                        help="output directory (default: docs/results)")
    parser.add_argument("--repeats", type=int, default=3)
    parser.add_argument("--rounds", type=int, default=None,
                        help="override the preset round budget")
    parser.add_argument("--quick", action="store_true",
                        help="CI smoke: one cell, few rounds, identity "
                             "checks only (no speedup gate)")
    parser.add_argument("--no-report", action="store_true")
    args = parser.parse_args(argv)

    if args.quick:
        records = run_ablation(repeats=1, rounds=args.rounds or 300,
                               algorithms=("dagd", "disco_f"))
    else:
        records = run_ablation(repeats=args.repeats, rounds=args.rounds)
    rounds = records[0]["rounds"] if records else 0
    for r in records:
        py, sc = r["engines"]["python"], r["engines"]["scan"]
        print(f"[round-engine] {r['instance_label']} "
              f"{r['algorithm']:>8}: python {py['s_per_cell']:.3f} s, "
              f"scan {sc['s_per_cell']:.3f} s "
              f"({r['speedup_scan_vs_python']:.1f}x), outcome "
              f"{'identical' if r['outcome_identical'] else 'DIFFERS'}, "
              f"ledger "
              f"{'identical' if r['ledger_identical'] else 'DIFFERS'}",
              file=sys.stderr)
    if not args.no_report:
        from repro.experiments.report import default_results_dir
        out = args.out or default_results_dir()
        path = write_reports(records, out, rounds)
        print(f"[round-engine] report -> {path}")
    bad = [r for r in records
           if not (r["outcome_identical"] and r["ledger_identical"])]
    if bad:
        print(f"[round-engine] ENGINE DRIFT in {len(bad)} cell(s): "
              "certification depends on the round engine", file=sys.stderr)
        return 1
    if not args.quick:
        slow = [r for r in records
                if r["speedup_scan_vs_python"] < SPEEDUP_FLOOR]
        if slow:
            print(f"[round-engine] SPEEDUP FLOOR MISSED in {len(slow)} "
                  f"cell(s): scan < {SPEEDUP_FLOOR}x python",
                  file=sys.stderr)
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
