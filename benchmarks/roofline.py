"""Fused-round roofline: HBM bytes/round, arithmetic intensity, and
achieved compute fraction — fused vs composed oracle backends.

The fused round-step kernel (``src/repro/kernels/fused_round.py``)
exists to cut HBM traffic: the composed path streams machine j's A_j
block from HBM twice per round (response + pgrad) and round-trips every
intermediate vector (wire-channel pass, raw-gradient epilogue) through
HBM, while the whole-round kernel holds A_j VMEM-resident, reads it
exactly once, and emits the channel-transformed upload in the same
pass. This benchmark makes that claim auditable per cell:

* **HBM bytes/round** — an analytic, deterministic byte model over the
  padded single-tile block shapes actually dispatched (f32), fused vs
  composed, with the per-term breakdown in the JSON;
* **arithmetic intensity** — FLOPs/round / HBM bytes/round (FLOPs are
  backend-invariant: fusion moves bytes, not math);
* **achieved fraction** — measured FLOP/s over a matmul ceiling
  calibrated on the same device at the same padded shape.

Gates (identical under ``--quick``; exit status 1 on any failure):

1. *bytes* — fused HBM bytes/round STRICTLY fewer than composed on
   every cell: whole-round cells save an entire A-pass plus the channel
   and epilogue round-trips; fallback cells (topk wire, oversized
   blocks) still save the raw-gradient epilogue round-trip via the
   fused-epilogue oracles.
2. *ledger* — the CommLedger stream is bit-identical fused vs composed
   per cell: the communication meter may not notice the fusion.
3. *achieved fraction* — on compiled-kernel platforms (TPU) the fused
   backend's achieved fraction must be at least ``FRACTION_SLACK`` of
   the composed kernel backend's. On CPU the Pallas kernels run in
   interpret mode, so measured fractions are recorded as informational
   and this gate auto-passes (gates 1-2 are platform-free).

CLI:
    PYTHONPATH=src python -m benchmarks.roofline
    PYTHONPATH=src python -m benchmarks.roofline --quick --out docs/results

Writes ``docs/results/roofline.json`` + ``.md`` and refreshes the
results index.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import sys
import time
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro import api
from repro.core.channel import parse_channel
from repro.kernels.fused_round import (channel_stages, round_step_fits,
                                       _rup)

COMMAND = "PYTHONPATH=src python -m benchmarks.roofline"
ITEMSIZE = 4                    # f32 wire + accumulators
FRACTION_SLACK = 0.9            # fused may lose <=10% vs composed (TPU)


@dataclasses.dataclass(frozen=True)
class Cell:
    label: str
    n: int
    d: int
    m: int
    channel: str = "identity"
    algorithm: str = "dagd"
    rounds: int = 8
    lam: float = 0.05


# Shapes on both sides of the paper's n-vs-d tradeoff, channels from the
# conformance matrix, and one deliberate fallback cell (topk needs
# lax.top_k over the full message, so the whole-round kernel refuses it
# and the fused backend drops to the fused-epilogue composed oracles).
CELLS = (
    Cell("tall n=512 d=96 m=4", n=512, d=96, m=4),
    Cell("tall int8", n=512, d=96, m=4, channel="int8"),
    Cell("wide n=96 d=512 m=4", n=96, d=512, m=4),
    Cell("wide int8", n=96, d=512, m=4, channel="int8"),
    Cell("square sched", n=256, d=256, m=8,
         channel="sched:int8@0,fp16@4"),
    Cell("square topk (fallback)", n=256, d=256, m=8, channel="topk:0.25"),
)
QUICK_CELLS = (CELLS[0], CELLS[1], CELLS[5])


def whole_round_engages(cell: Cell) -> bool:
    """Mirrors the runtime support gate: single-tile padded block inside
    the VMEM budget AND every channel stage reproducible in-kernel."""
    d_max = -(-cell.d // cell.m)
    return (round_step_fits(cell.n, d_max)
            and channel_stages(parse_channel(cell.channel)) is not None)


# --------------------------------------------------------------------------
# Analytic byte / FLOP model
# --------------------------------------------------------------------------

def hbm_bytes_per_round(cell: Cell, backend: str) -> dict:
    """Per-round HBM traffic of one machine's round-step, summed over
    machines. Counts every stream of the padded single-tile block and
    every materialized intermediate vector; registers/VMEM reuse inside
    one kernel pass is free. ``backend`` is "kernel" (composed) or
    "fused"."""
    n_pad = _rup(cell.n)
    d_pad = _rup(-(-cell.d // cell.m))
    a_pass = n_pad * d_pad * ITEMSIZE
    nvec, dvec = n_pad * ITEMSIZE, d_pad * ITEMSIZE
    if backend == "fused" and whole_round_engages(cell):
        terms = dict(
            # ONE streaming pass over A_j for the whole round
            A_block=1 * a_pass,
            # in: z, y_data, nmask; out: channel-transformed zloc
            n_vectors=4 * nvec,
            # in: x, y, mask; out: x, y  (consts are O(1) rows)
            d_vectors=5 * dvec,
        )
    elif backend == "fused":
        # composed dispatch with fused-epilogue oracles: still two
        # A-passes, but pgrad writes the finished gradient (the
        # /n + lam*w + mask epilogue folds into the last contraction
        # block), saving the raw-gradient HBM round-trip
        terms = dict(
            A_block=2 * a_pass,
            n_vectors=7 * nvec,
            d_vectors=9 * dvec,
        )
    else:
        terms = dict(
            # response + pgrad each stream the block
            A_block=2 * a_pass,
            # response out; channel in/out; z + y_data in; lgrad out + in
            n_vectors=7 * nvec,
            # w in (response); g_raw out + in, w + mask in, g out
            # (epilogue); x/y/g in, x/y out (update)
            d_vectors=11 * dvec,
        )
    return dict(per_machine=terms, machines=cell.m,
                total=sum(terms.values()) * cell.m)


def flops_per_round(cell: Cell) -> int:
    """Backend-invariant: one matvec + one rmatvec over the padded block
    per machine, plus elementwise epilogues."""
    n_pad = _rup(cell.n)
    d_pad = _rup(-(-cell.d // cell.m))
    return cell.m * (4 * n_pad * d_pad + 6 * (n_pad + d_pad))


# --------------------------------------------------------------------------
# Measurement
# --------------------------------------------------------------------------

def _matmul_ceiling_flops(cell: Cell, repeats: int) -> float:
    """Attainable FLOP/s at this cell's padded shape: time the bare
    stacked GEMV pair the round is made of."""
    n_pad = _rup(cell.n)
    d_pad = _rup(-(-cell.d // cell.m))
    key = jax.random.PRNGKey(0)
    A = jax.random.normal(key, (cell.m, n_pad, d_pad))
    w = jax.random.normal(key, (cell.m, d_pad))

    @jax.jit
    def pair(A, w):
        z = jnp.einsum("mnd,md->mn", A, w)
        return jnp.einsum("mnd,mn->md", A, z)

    jax.block_until_ready(pair(A, w))           # compile
    times = []
    for _ in range(max(repeats, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(pair(A, w))
        times.append(time.perf_counter() - t0)
    return cell.m * 4 * n_pad * d_pad / min(times)


def _timed_run(cell: Cell, backend: str, repeats: int) -> dict:
    spec = api.RunSpec(
        instance="random_ridge",
        instance_params=dict(n=cell.n, d=cell.d, m=cell.m, lam=cell.lam,
                             seed=11),
        algorithm=cell.algorithm, rounds=cell.rounds, measure="none",
        backend=backend, engine="scan", channel=cell.channel)
    plan = api.plan(spec)
    result = plan.execute()                     # warmup + compile
    jax.block_until_ready(result.w)
    led = result.ledger
    stream = (led.round_marks, led.typed_stream())
    times = []
    for _ in range(max(repeats, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(plan.execute().w)
        times.append(time.perf_counter() - t0)
    return dict(us_per_round=min(times) / cell.rounds * 1e6,
                _stream=stream)


def run_cells(cells: Sequence[Cell] = CELLS, repeats: int = 3) -> List[dict]:
    platform = jax.default_backend()
    records = []
    for cell in cells:
        fused_model = hbm_bytes_per_round(cell, "fused")
        composed_model = hbm_bytes_per_round(cell, "kernel")
        flops = flops_per_round(cell)
        ceiling = _matmul_ceiling_flops(cell, repeats)
        timed = {be: _timed_run(cell, be, repeats)
                 for be in ("kernel", "fused")}
        fractions = {
            be: (flops / (t["us_per_round"] * 1e-6)) / ceiling
            for be, t in timed.items()}
        rec = dict(
            label=cell.label,
            params=dict(n=cell.n, d=cell.d, m=cell.m,
                        channel=cell.channel, algorithm=cell.algorithm,
                        rounds=cell.rounds),
            whole_round=whole_round_engages(cell),
            flops_per_round=flops,
            hbm_bytes_per_round=dict(fused=fused_model,
                                     composed=composed_model),
            arithmetic_intensity=dict(
                fused=round(flops / fused_model["total"], 3),
                composed=round(flops / composed_model["total"], 3)),
            bytes_saved_fraction=round(
                1.0 - fused_model["total"] / composed_model["total"], 3),
            us_per_round={be: round(t["us_per_round"], 1)
                          for be, t in timed.items()},
            achieved_fraction={be: round(f, 4)
                               for be, f in fractions.items()},
            gates=dict(
                bytes=fused_model["total"] < composed_model["total"],
                ledger=timed["fused"]["_stream"]
                == timed["kernel"]["_stream"],
                fraction=(platform != "tpu"
                          or fractions["fused"]
                          >= FRACTION_SLACK * fractions["kernel"]),
            ),
        )
        rec["ok"] = all(rec["gates"].values())
        records.append(rec)
    return records


# --------------------------------------------------------------------------
# Reporting
# --------------------------------------------------------------------------

def render_markdown(doc: dict) -> str:
    interp = doc["platform"] != "tpu"
    lines = [
        "# Fused-round roofline — `roofline`",
        "",
        f"<!-- Generated by `{doc['command']}`. Do not edit by hand. -->",
        f"*Generated by* `{doc['command']}` *— regenerate instead of "
        "editing.*",
        "",
        f"- **Platform:** `{doc['platform']}`"
        + (" (Pallas kernels in **interpret mode** — achieved fractions "
           "are informational; the bytes and ledger gates are "
           "platform-free)" if interp else " (compiled Pallas kernels)"),
        f"- **Gates:** {doc['summary']['passed']}/"
        f"{doc['summary']['cells']} cells pass "
        "(bytes strictly fewer + ledger bit-identical"
        + ("" if interp else
           f" + achieved fraction >= {FRACTION_SLACK:.0%} of composed")
        + ")",
        "",
        "| cell | channel | whole-round kernel | HBM KiB/round fused | "
        "composed | saved | arith. intensity fused | composed | "
        "fused µs/round | composed | gates |",
        "|---|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in doc["records"]:
        fb = r["hbm_bytes_per_round"]["fused"]["total"] / 1024
        cb = r["hbm_bytes_per_round"]["composed"]["total"] / 1024
        lines.append(
            f"| {r['label']} | `{r['params']['channel']}` | "
            f"{'yes' if r['whole_round'] else 'fallback'} | "
            f"{fb:.1f} | {cb:.1f} | {r['bytes_saved_fraction']:.0%} | "
            f"{r['arithmetic_intensity']['fused']:.2f} | "
            f"{r['arithmetic_intensity']['composed']:.2f} | "
            f"{r['us_per_round']['fused']:.0f} | "
            f"{r['us_per_round']['kernel']:.0f} | "
            f"{'ok' if r['ok'] else '**FAIL**'} |")
    lines += [
        "",
        "Reading the table: HBM bytes/round is the analytic single-tile "
        "byte model over the padded blocks actually dispatched (term "
        "breakdown in `roofline.json`); whole-round cells read A_j once "
        "per round instead of twice, fallback cells keep two A-passes "
        "but fold the gradient epilogue into the contraction. "
        "Arithmetic intensity is FLOPs/round over those bytes — the "
        "fused column is strictly higher everywhere, which is the whole "
        "point of the redesign. The ledger gate pins that none of this "
        "moves a single metered byte.",
        "",
    ]
    return "\n".join(lines)


def write_reports(records: List[dict], out_dir, quick: bool) -> pathlib.Path:
    from repro.experiments.report import refresh_index

    out = pathlib.Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    ok = sum(1 for r in records if r["ok"])
    doc = dict(
        schema_version=1,
        command=COMMAND + (" --quick" if quick else ""),
        spec=dict(name="roofline", quick=quick,
                  fraction_slack=FRACTION_SLACK,
                  backends=["kernel", "fused"]),
        platform=jax.default_backend(),
        summary=dict(cells=len(records), passed=ok,
                     failed=len(records) - ok),
        records=records,
    )
    (out / "roofline.json").write_text(json.dumps(doc, indent=2) + "\n")
    (out / "roofline.md").write_text(render_markdown(doc))
    refresh_index(out)
    return out / "roofline.json"


def run():
    """CSV rows for the legacy benchmarks/run.py surface."""
    from .common import emit
    for rec in run_cells(QUICK_CELLS, repeats=1):
        for be in ("fused", "kernel"):
            emit(f"roofline/{rec['label'].replace(' ', '_')}/{be}",
                 f"{rec['us_per_round'][be]:.1f}",
                 f"hbm_bytes={rec['hbm_bytes_per_round']['fused' if be == 'fused' else 'composed']['total']}"
                 f";ai={rec['arithmetic_intensity']['fused' if be == 'fused' else 'composed']}"
                 f";ok={rec['ok']}")


def main(argv: Optional[Sequence[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m benchmarks.roofline", description=__doc__)
    parser.add_argument("--quick", action="store_true",
                        help="subset of cells, 1 timing repeat — same "
                             "gates as the full run")
    parser.add_argument("--out", default=None,
                        help="output directory (default: docs/results)")
    parser.add_argument("--repeats", type=int, default=3)
    parser.add_argument("--no-report", action="store_true")
    args = parser.parse_args(argv)

    cells = QUICK_CELLS if args.quick else CELLS
    repeats = 1 if args.quick else args.repeats
    records = run_cells(cells, repeats=repeats)
    for r in records:
        fused = r["hbm_bytes_per_round"]["fused"]["total"]
        comp = r["hbm_bytes_per_round"]["composed"]["total"]
        print(f"[roofline] {r['label']:>26}: fused {fused / 1024:.1f} KiB/"
              f"round vs composed {comp / 1024:.1f} "
              f"({r['bytes_saved_fraction']:.0%} saved), "
              f"AI {r['arithmetic_intensity']['fused']:.2f} vs "
              f"{r['arithmetic_intensity']['composed']:.2f}, "
              f"{'ok' if r['ok'] else 'GATE FAILURE ' + str(r['gates'])}",
              file=sys.stderr)
    if not args.no_report:
        from repro.experiments.report import default_results_dir
        out = args.out or default_results_dir()
        path = write_reports(records, out, quick=args.quick)
        print(f"[roofline] report -> {path}")
    bad = [r for r in records if not r["ok"]]
    if bad:
        print(f"[roofline] {len(bad)} cell(s) failed a gate: the fused "
              "round-step must strictly reduce HBM traffic with a "
              "bit-identical ledger", file=sys.stderr)
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
