"""Roofline table builder: reads reports/dryrun/*.json into the
EXPERIMENTS.md §Roofline table (also emits CSV rows to stdout)."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

from .common import emit

REPORT_DIR = os.path.join(os.path.dirname(__file__), "..", "reports",
                          "dryrun")


def load_records(report_dir: str = REPORT_DIR) -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(report_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def run(report_dir: str = REPORT_DIR):
    recs = [r for r in load_records(report_dir)
            if not r.get("skipped") and not r.get("failed")]
    for r in recs:
        rf = r["roofline"]
        total = rf["compute_s"] + rf["memory_s"] + rf["collective_s"]
        emit(
            f"roofline/{r['arch']}/{r['shape']}/"
            f"{'pod2' if '2x16' in r['mesh'] else 'pod1'}/{r['variant']}",
            f"{total*1e6:.0f}",
            f"dom={rf['dominant']};c={rf['compute_s']:.3f}"
            f";m={rf['memory_s']:.3f};coll={rf['collective_s']:.3f}"
            f";useful={r.get('useful_flops_ratio') or 0:.3f}")


def markdown_table(report_dir: str = REPORT_DIR,
                   variant: str = "baseline") -> str:
    recs = [r for r in load_records(report_dir)
            if not r.get("failed") and r.get("variant", "baseline")
            == variant]
    lines = [
        "| arch | shape | mesh | compute s | memory s | collective s |"
        " dominant | useful FLOPs ratio | HBM temp GB |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"],
                                         r.get("mesh", ""))):
        if r.get("skipped"):
            lines.append(f"| {r['arch']} | {r['shape']} | - | - | - | - |"
                         f" SKIP ({r['reason'][:40]}) | - | - |")
            continue
        rf = r["roofline"]
        temp_gb = r["memory"].get("temp_bytes", 0) / 1e9
        ratio = r.get("useful_flops_ratio")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {rf['compute_s']:.4f} | {rf['memory_s']:.4f} "
            f"| {rf['collective_s']:.4f} | **{rf['dominant']}** "
            f"| {ratio:.3f} | {temp_gb:.1f} |" if ratio is not None else
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | - | - | - | - |"
            f" - | - |")
    return "\n".join(lines)


if __name__ == "__main__":
    run()
