"""Chaos soak: fault-injected serving must lose nothing and price
everything.

Drives ``repro.serve.CertificationService`` through a seeded trace that
mixes clean RunSpecs with fault-injected ones (the PR-8 ``faults`` axis:
seeded drops, bit flips, stragglers, a crash + snapshot-replay), while a
chaos wrapper around ``repro.api.execute_group`` makes every Nth grouped
execution raise mid-batch — exercising the service's degradation ladder
(failed group -> sequential re-run) under load.  The gates:

  * **no loss / dup / reorder** — exactly one envelope per admitted
    ticket, every envelope ``status="ok"`` (the ladder recovered every
    injected executor crash), and within each client the sequence
    numbers released are ``0..k-1`` in order;
  * **unfaulted specs bit-identical** — every envelope of a
    ``faults="none"`` spec carries the same certification verdicts and
    the same typed ``CommLedger`` stream as direct
    ``plan(spec).execute()``: chaos in the serving layer and faulted
    neighbors in the same soak never perturb a clean run;
  * **every fault priced, exactly** — for each faulted spec, the served
    stream equals the direct faulted run (seeded faults are
    deterministic), its clean-traffic slice equals the fault-free run's
    total (``clean_bits == total_bits(faults="none")``), total splits
    exactly into clean + retransmission bits, recovered values are
    bit-identical to the fault-free iterate, and the measured recovery
    rounds equal the declared budget (``ExecutionPlan.recovery_report``).

CLI:
    PYTHONPATH=src python -m benchmarks.chaos_soak
    PYTHONPATH=src python -m benchmarks.chaos_soak --quick   # CI

Writes ``docs/results/chaos-soak.json`` + ``.md`` and refreshes the
results index.  Exit status is non-zero if any gate fails.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import random
import sys
import time
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro import api
from repro.serve import CertificationService
from repro.serve.workload import Arrival

COMMAND = "PYTHONPATH=src python -m benchmarks.chaos_soak"

# one grouped execution in CHAOS_EVERY raises mid-batch (seeded choice
# of which, via the call counter): the degradation ladder must re-run
# every run of that batch sequentially with zero envelope loss
CHAOS_EVERY = 4

# (algorithm, channel, faults): the faulted structures inject every
# fault kind the model knows — drops, flips, a straggler pattern, and a
# crash with snapshot replay — at rates that guarantee multiple
# retransmissions over a 30-round run
STRUCTURES: Tuple[Tuple[str, str, str], ...] = (
    ("dagd", "identity", "none"),
    ("dgd", "identity",
     "inject:seed=5,drop=0.2,flip=0.2,straggle=0.25x2,crash=20,snap=5"),
    ("dagd", "fp16", "inject:seed=9,drop=0.3,flip=0.1"),
)


def spec_pool(structures: Sequence[Tuple[str, str, str]] = STRUCTURES,
              kappas: Sequence[float] = (8.0, 16.0, 32.0, 64.0),
              d: int = 12, m: int = 2,
              rounds: int = 30) -> List[List[api.RunSpec]]:
    """One list of distinct specs per structure (same group key,
    different kappa), mirroring ``repro.serve.workload.spec_pool`` plus
    the faults axis."""
    return [[api.RunSpec(
        instance="thm2_chain",
        instance_params=dict(d=d, kappa=float(k), lam=0.5, m=m),
        algorithm=algo, rounds=rounds, eps=(1e-2,), channel=channel,
        faults=faults, tag=f"chaos-{algo}-{channel}")
        for k in kappas]
        for algo, channel, faults in structures]


def chaos_trace(n_per_structure: int, seed: int = 0, dt: float = 1e-3,
                clients: int = 4,
                pools: Sequence[Sequence[api.RunSpec]] = None
                ) -> List[Arrival]:
    if pools is None:
        pools = spec_pool()
    specs: List[api.RunSpec] = []
    for pool in pools:
        specs.extend(pool[i % len(pool)] for i in range(n_per_structure))
    rng = random.Random(seed)
    rng.shuffle(specs)
    return [Arrival(t=i * dt, client_id=f"c{i % clients}", spec=spec)
            for i, spec in enumerate(specs)]


class GroupChaos:
    """Wraps ``api.execute_group`` so every ``CHAOS_EVERY``-th grouped
    call raises once (the retry of the same batch goes through — here
    the service's ladder re-runs per-run, sequentially).  Install/
    remove with ``with GroupChaos(): ...``."""

    def __init__(self, every: int = CHAOS_EVERY):
        self.every = int(every)
        self.calls = 0
        self.raised = 0
        self._orig = None

    def __enter__(self):
        self._orig = api.execute_group

        def chaotic(cells, runner_cache=None):
            self.calls += 1
            if self.every and self.calls % self.every == 0:
                self.raised += 1
                raise RuntimeError(
                    f"chaos: injected executor failure "
                    f"(grouped call #{self.calls})")
            return self._orig(cells, runner_cache=runner_cache)

        api.execute_group = chaotic
        return self

    def __exit__(self, *exc):
        api.execute_group = self._orig
        return False


def run_soak(n_per_structure: int, seed: int = 0,
             chaos_every: int = CHAOS_EVERY) -> dict:
    """Serve the mixed clean/faulted trace under executor chaos; the
    trace clock is synthetic (deterministic scheduling), wall time is
    measured for context only."""
    pools = spec_pool()
    trace = chaos_trace(n_per_structure, seed=seed, pools=pools)
    service = CertificationService(max_batch=8, max_wait=0.05,
                                   cache_capacity=32,
                                   max_depth=len(trace) + 1)
    envelopes = []
    t0 = time.perf_counter()
    with GroupChaos(every=chaos_every) as chaos:
        for a in trace:
            envelopes.extend(service.step(a.t))
            service.submit(a.spec, client_id=a.client_id, now=a.t)
        envelopes.extend(service.drain(trace[-1].t))
    wall = time.perf_counter() - t0
    return dict(pools=pools, trace=trace, envelopes=envelopes,
                service=service, chaos=chaos, wall_s=round(wall, 3))


# --------------------------------------------------------------------------
# Gates
# --------------------------------------------------------------------------

def gate_delivery(trace, envelopes, stats) -> List[str]:
    """Zero lost, duplicated, or reordered envelopes; chaos actually
    fired and the ladder absorbed all of it."""
    fails = []
    if len(envelopes) != len(trace):
        fails.append(f"envelope count {len(envelopes)} != "
                     f"{len(trace)} submissions (lost or duplicated)")
    tickets = [e.ticket for e in envelopes]
    if len(set(tickets)) != len(tickets):
        fails.append("duplicate tickets in the served stream")
    seqs: Dict[str, List[int]] = {}
    for e in envelopes:
        seqs.setdefault(e.client_id, []).append(e.seq)
    for cid, ss in sorted(seqs.items()):
        if ss != list(range(len(ss))):
            fails.append(f"client {cid} stream reordered or gapped: {ss}")
    bad = [e.ticket for e in envelopes if e.status != "ok"]
    if bad:
        fails.append(f"{len(bad)} envelope(s) dead-lettered under "
                     f"recoverable chaos: {bad[:5]}")
    if stats["group_failures"] == 0:
        fails.append("chaos never fired (group_failures == 0): the soak "
                     "exercised nothing")
    return fails


def clean_identity_records(pools, envelopes) -> List[dict]:
    """Every served envelope of a ``faults='none'`` spec vs its direct
    execution: verdicts, typed stream, rounds, iterate."""
    records = []
    for pool in pools:
        for spec in pool:
            if spec.faults != "none":
                continue
            pl = api.plan(spec)
            ref = pl.execute()
            ref_verdicts = [dict(
                eps=e, measured_rounds=ref.measured_rounds(pl.eps_abs(e)),
                bound_rounds=pl.bound(pl.eps_abs(e)).rounds,
                certified=pl.certify(ref, e)) for e in spec.eps]
            mine = [env for env in envelopes if env.spec == spec]
            records.append(dict(
                algorithm=spec.algorithm, channel=spec.channel,
                kappa=spec.instance_params["kappa"], n_served=len(mine),
                verdict_identical=all(env.verdicts == ref_verdicts
                                      for env in mine),
                stream_identical=all(
                    env.result.ledger.typed_stream()
                    == ref.ledger.typed_stream()
                    and env.result.ledger.rounds == ref.ledger.rounds
                    for env in mine),
                iterate_identical=all(
                    np.array_equal(np.asarray(env.result.w),
                                   np.asarray(ref.w)) for env in mine)))
            pl.release()
    return records


def fault_pricing_records(pools, envelopes) -> List[dict]:
    """Per faulted spec: served == direct faulted run; clean slice ==
    fault-free total; total == clean + retransmit; recovered values
    bit-identical to fault-free; recovery rounds == declared budget."""
    records = []
    for pool in pools:
        for spec in pool:
            if spec.faults == "none":
                continue
            pl = api.plan(spec)
            res = pl.execute()
            rep = pl.recovery_report(res)
            clean_spec = dataclasses.replace(spec, faults="none")
            pl0 = api.plan(clean_spec)
            res0 = pl0.execute()
            mine = [env for env in envelopes if env.spec == spec]
            records.append(dict(
                algorithm=spec.algorithm, channel=spec.channel,
                kappa=spec.instance_params["kappa"],
                faults=spec.faults, n_served=len(mine),
                recovery=rep,
                served_identical=all(
                    env.result.ledger.typed_stream()
                    == res.ledger.typed_stream() for env in mine),
                faults_injected=rep["retransmissions"] > 0
                or rep["recovery_rounds"] > 0,
                clean_slice_exact=(rep["clean_bits"]
                                   == res0.ledger.total_bits()),
                pricing_exact=(rep["total_bits"]
                               == rep["clean_bits"]
                               + rep["retransmit_bits"]),
                values_recovered=np.array_equal(np.asarray(res.w),
                                                np.asarray(res0.w)),
                budget_exact=(rep["within_budget"]
                              and rep["recovery_rounds"]
                              == rep["declared_recovery_rounds"])))
            pl.release()
            pl0.release()
    return records


def gate_identity(clean_records, fault_records) -> List[str]:
    fails = []
    for r in clean_records:
        for k in ("verdict_identical", "stream_identical",
                  "iterate_identical"):
            if not r[k]:
                fails.append(f"clean {r['algorithm']}/{r['channel']} "
                             f"kappa={r['kappa']:g}: {k} is False")
    for r in fault_records:
        for k in ("served_identical", "faults_injected",
                  "clean_slice_exact", "pricing_exact",
                  "values_recovered", "budget_exact"):
            if not r[k]:
                fails.append(f"faulted {r['algorithm']}/{r['channel']} "
                             f"kappa={r['kappa']:g}: {k} is False")
    return fails


# --------------------------------------------------------------------------
# Reporting
# --------------------------------------------------------------------------

def render_markdown(doc: dict) -> str:
    m = doc["measurements"]
    lines = [
        "# Chaos soak — `chaos-soak`",
        "",
        f"<!-- Generated by `{doc['command']}`. Do not edit by hand. -->",
        f"*Generated by* `{doc['command']}` *— regenerate instead of "
        "editing.*",
        "",
        f"- **Platform:** `{doc['platform']}`",
        f"- **Trace:** {m['n_specs']} RunSpecs ({m['n_faulted']} fault-"
        f"injected), {len(m['structures'])} structures: "
        + ", ".join(f"`{s}`" for s in m["structures"]),
        f"- **Chaos:** every {m['chaos_every']}th grouped execution "
        f"raised mid-batch ({m['chaos_raised']} injected failures; the "
        "service degraded each to sequential re-runs)",
        f"- **Delivery:** {m['n_envelopes']}/{m['n_specs']} envelopes, "
        "zero lost / duplicated / reordered"
        if not doc["summary"]["delivery_failures"] else
        f"- **Delivery:** **{len(doc['summary']['delivery_failures'])} "
        "FAILURE(S)** (see gates)",
        f"- **Identity + pricing:** {doc['summary']['certified']}/"
        f"{doc['summary']['certifiable']} spec gates passed"
        + (f", **{doc['summary']['failed']} FAILED**"
           if doc["summary"]["failed"] else ""),
        "",
        "## Clean specs: serving + chaos are invisible",
        "",
        "| algorithm | channel | kappa | served | verdicts | typed "
        "stream | iterate |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in doc["clean_records"]:
        lines.append(
            f"| {r['algorithm']} | `{r['channel']}` | {r['kappa']:g} | "
            f"{r['n_served']} | "
            + " | ".join("identical" if r[k] else "**DIFFER**"
                         for k in ("verdict_identical", "stream_identical",
                                   "iterate_identical")) + " |")
    lines += [
        "",
        "## Faulted specs: every injected fault recovered and priced",
        "",
        "| algorithm | channel | kappa | faults | resends | recovery "
        "rounds (measured = declared) | retransmit bits | clean slice | "
        "total = clean + resend | values |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in doc["fault_records"]:
        rep = r["recovery"]
        lines.append(
            f"| {r['algorithm']} | `{r['channel']}` | {r['kappa']:g} | "
            f"`{r['faults']}` | {rep['retransmissions']} | "
            f"{rep['recovery_rounds']} = "
            f"{rep['declared_recovery_rounds']}"
            f"{' ✓' if r['budget_exact'] else ' **✗**'} | "
            f"{rep['retransmit_bits']} | "
            f"{'exact' if r['clean_slice_exact'] else '**DRIFT**'} | "
            f"{'exact' if r['pricing_exact'] else '**DRIFT**'} | "
            f"{'bit-identical' if r['values_recovered'] else '**DIFFER**'}"
            " |")
    lines += [
        "",
        "Reading the tables: faults are injected at the communicator "
        "boundary from a seeded, data-independent schedule; detection is "
        "checksum + NACK, recovery is bounded resend (priced as typed "
        "`retransmit` ledger entries) and snapshot replay for crashes. "
        "`clean slice` checks that the non-retransmission traffic of a "
        "faulted run is bit-identical to the same spec with "
        "`faults=\"none\"` — recovery adds traffic, it never perturbs "
        "the algorithm's own stream. The declared recovery budget is "
        "computable before the run (the schedule is data-independent), "
        "and a healthy run measures exactly it.",
        "",
    ]
    return "\n".join(lines)


def write_reports(doc: dict, out_dir) -> pathlib.Path:
    from repro.experiments.report import refresh_index

    out = pathlib.Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    (out / "chaos-soak.json").write_text(json.dumps(doc, indent=2) + "\n")
    (out / "chaos-soak.md").write_text(render_markdown(doc))
    refresh_index(out)
    return out / "chaos-soak.json"


def build_doc(soak: dict, clean_records, fault_records,
              delivery_fails, identity_fails) -> dict:
    stats = soak["service"].stats()
    trace = soak["trace"]
    per_spec = len(clean_records) + len(fault_records)
    failed_specs = len({f.split(":")[0] for f in identity_fails})
    return dict(
        schema_version=1,
        command=COMMAND,
        spec=dict(name="chaos-soak", instance="thm2_chain",
                  structures=[f"{a}/{c}/{f}" for a, c, f in STRUCTURES],
                  n_specs=len(trace), chaos_every=soak["chaos"].every),
        platform=jax.default_backend(),
        summary=dict(records=per_spec, certifiable=per_spec,
                     certified=per_spec - failed_specs,
                     failed=failed_specs,
                     delivery_failures=delivery_fails,
                     identity_failures=identity_fails),
        measurements=dict(
            n_specs=len(trace),
            n_faulted=sum(1 for a in trace if a.spec.faults != "none"),
            n_envelopes=len(soak["envelopes"]),
            wall_s=soak["wall_s"],
            chaos_every=soak["chaos"].every,
            chaos_raised=soak["chaos"].raised,
            structures=[f"{a}/{c}/{f}" for a, c, f in STRUCTURES],
            stats=stats),
        clean_records=clean_records,
        fault_records=fault_records)


def main(argv: Optional[Sequence[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m benchmarks.chaos_soak", description=__doc__)
    parser.add_argument("--out", default=None,
                        help="output directory (default: docs/results)")
    parser.add_argument("--quick", action="store_true",
                        help="CI smoke: smaller trace, same gates")
    parser.add_argument("--no-report", action="store_true")
    parser.add_argument("--seed", type=int, default=0)
    args = parser.parse_args(argv)

    # quick mode has only ~3 grouped calls, so chaos must fire sooner
    n, every = (8, 2) if args.quick else (24, CHAOS_EVERY)
    soak = run_soak(n_per_structure=n, seed=args.seed, chaos_every=every)
    stats = soak["service"].stats()
    print(f"[chaos-soak] {len(soak['trace'])} specs served in "
          f"{soak['wall_s']:.1f} s; {soak['chaos'].raised} injected "
          f"executor failures over {soak['chaos'].calls} grouped calls; "
          f"stats: batches={stats['batches']} "
          f"group_failures={stats['group_failures']} "
          f"dead_letters={stats['dead_letters']}", file=sys.stderr)

    delivery_fails = gate_delivery(soak["trace"], soak["envelopes"], stats)
    clean_records = clean_identity_records(soak["pools"],
                                           soak["envelopes"])
    fault_records = fault_pricing_records(soak["pools"],
                                          soak["envelopes"])
    identity_fails = gate_identity(clean_records, fault_records)

    doc = build_doc(soak, clean_records, fault_records,
                    delivery_fails, identity_fails)
    if not args.no_report:
        from repro.experiments.report import default_results_dir
        out = args.out or default_results_dir()
        path = write_reports(doc, out)
        print(f"[chaos-soak] report -> {path}")
    fails = delivery_fails + identity_fails
    for f in fails:
        print(f"[chaos-soak] GATE FAILED: {f}", file=sys.stderr)
    return 1 if fails else 0


if __name__ == "__main__":
    sys.exit(main())
