"""Communication-cost comparison: partition-on-feature (this paper) vs
partition-on-sample (Arjevani-Shamir [1]) per-round budgets.

Thin CLI wrapper over the ``repro.experiments`` sweep subsystem (preset
``comm-cost``, fixed-rounds mode). Feature-partition rounds are MEASURED
from the CommLedger of a real DAGD run; the sample-partition figure is
the model O(m d) bits/round that [1] allows (each machine broadcasts an
R^d iterate). The derived column shows the ratio — the paper's motivating
observation that feature partition wins when d >> n.

Full JSON + Markdown reports: ``python -m repro.experiments.sweep
--preset comm-cost``.
"""
from __future__ import annotations

from repro.experiments import PRESETS, run_sweep

from .common import emit


def run():
    result = run_sweep(PRESETS["comm-cost"])
    for r in result.records:
        n = int(r.instance_params["n"])
        d = int(r.instance_params["d"])
        feature = r.bytes_per_round
        sample = r.sample_model_bytes_per_round
        emit(f"comm_cost/n{n}_d{d}/feature_bytes_per_round",
             f"{feature:.0f}",
             f"sample_model={sample:.0f};"
             f"ratio={sample / max(feature, 1):.1f}x")
    return result


if __name__ == "__main__":
    run()
