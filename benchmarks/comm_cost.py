"""Communication-cost comparison: partition-on-feature (this paper) vs
partition-on-sample (Arjevani-Shamir [1]) per-round budgets.

Feature partition rounds are MEASURED from the CommLedger of a real DAGD
run; the sample-partition figure is the model O(m d) bits/round that [1]
allows (each machine broadcasts an R^d iterate). The derived column shows
the ratio — the paper's motivating observation that feature partition
wins when d >> n."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import make_random_erm
from repro.core.partition import even_partition
from repro.core.runtime import LocalDistERM
from repro.core.algorithms import dagd
from .common import emit


def run(m: int = 8):
    for (n, d) in ((256, 64), (64, 256), (64, 4096)):
        prob = make_random_erm(n=n, d=d, seed=1)
        part = even_partition(d, m)
        dist = LocalDistERM(prob, part)
        L = prob.smoothness_bound()
        dagd(dist, rounds=20, L=L, lam=prob.lam)
        led = dist.comm.ledger
        feature_bytes = led.bytes_per_round()
        sample_bytes = m * d * 4        # [1]'s per-round broadcast budget
        emit(f"comm_cost/n{n}_d{d}/feature_bytes_per_round",
             f"{feature_bytes:.0f}",
             f"sample_model={sample_bytes};ratio={sample_bytes/max(feature_bytes,1):.1f}x")


if __name__ == "__main__":
    run()
