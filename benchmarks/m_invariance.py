"""Machine-count invariance — a distinctive feature of the paper's
bounds: Theorems 2-4 hold FOR ANY m, and the matching algorithms' round
counts are m-independent (communication rounds don't degrade as the
feature partition spreads wider). Measured: DAGD rounds-to-eps across
m in {1, 2, 4, 8} at fixed kappa must be constant."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.partition import even_partition
from repro.core.algorithms import dagd
from .common import chain_erm, emit, rounds_to_eps


def run(kappa: float = 64.0, d: int = 128, eps: float = 1e-6):
    ci, prob = chain_erm(d, kappa, lam=0.5)
    fstar = float(prob.value(jnp.asarray(ci.w_star())))
    L = prob.smoothness_bound()
    base = None
    for m in (1, 2, 4, 8):
        part = even_partition(prob.d, m)
        k, led = rounds_to_eps(prob, part, dagd, eps, fstar,
                               max_rounds=1500, L=L, lam=prob.lam)
        base = base or k
        emit(f"m_invariance/m{m}/dagd/rounds_to_eps", k,
             f"vs_m1={k/base:.3f};bytes_per_round={led.bytes_per_round():.0f}")


if __name__ == "__main__":
    run()
