"""Machine-count invariance — a distinctive feature of the paper's
bounds: Theorems 2-4 hold FOR ANY m, and the matching algorithms' round
counts are m-independent (communication rounds don't degrade as the
feature partition spreads wider). Measured: DAGD rounds-to-eps across
m in {1, 2, 4, 8} at fixed kappa must agree to within one round — the
iterate sequences across m differ only by the summation order of the
ReduceAll, so the sole legitimate divergence is an eps-threshold
crossing quantized one round earlier or later. A wider spread means the
algorithm's communication pattern actually depends on m, and this
benchmark raises.

Thin CLI wrapper over the ``repro.experiments`` sweep subsystem (preset
``m-invariance``)."""
from __future__ import annotations

from repro.experiments import PRESETS, run_sweep

from .common import emit

# eps-threshold quantization only: measured rounds across m may differ
# by at most this many rounds (float reassociation moving one crossing)
MAX_SPREAD = 1


def run():
    result = run_sweep(PRESETS["m-invariance"])
    base = None
    measured = []
    for r in result.records:
        m = int(r.instance_params["m"])
        k = r.measured_rounds if r.measured_rounds is not None else -1
        if base is None and k > 0:
            base = k
        if k > 0:
            measured.append(k)
        ratio = k / base if (k > 0 and base) else float("nan")
        emit(f"m_invariance/m{m}/{r.algorithm}/rounds_to_eps", k,
             f"vs_m1={ratio:.3f};bytes_per_round={r.bytes_per_round:.0f}")
    spread = max(measured) - min(measured) if measured else 0
    emit("m_invariance/rounds_spread", spread,
         f"max_allowed={MAX_SPREAD}")
    if spread > MAX_SPREAD:
        raise AssertionError(
            f"m-invariance violated: rounds-to-eps spread {spread} across "
            f"m grid exceeds the +/-{MAX_SPREAD} eps-quantization allowance "
            f"(measured {sorted(measured)})")
    return result


if __name__ == "__main__":
    run()
