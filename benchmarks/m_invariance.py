"""Machine-count invariance — a distinctive feature of the paper's
bounds: Theorems 2-4 hold FOR ANY m, and the matching algorithms' round
counts are m-independent (communication rounds don't degrade as the
feature partition spreads wider). Measured: DAGD rounds-to-eps across
m in {1, 2, 4, 8} at fixed kappa must be constant.

Thin CLI wrapper over the ``repro.experiments`` sweep subsystem (preset
``m-invariance``)."""
from __future__ import annotations

from repro.experiments import PRESETS, run_sweep

from .common import emit


def run():
    result = run_sweep(PRESETS["m-invariance"])
    base = None
    for r in result.records:
        m = int(r.instance_params["m"])
        k = r.measured_rounds if r.measured_rounds is not None else -1
        if base is None and k > 0:
            base = k
        ratio = k / base if (k > 0 and base) else float("nan")
        emit(f"m_invariance/m{m}/{r.algorithm}/rounds_to_eps", k,
             f"vs_m1={ratio:.3f};bytes_per_round={r.bytes_per_round:.0f}")
    return result


if __name__ == "__main__":
    run()
