"""AdamW on pytrees — mixed-precision aware.

Moments are kept in f32 regardless of param dtype; the update is computed
in f32 and cast back to the param dtype (bf16 training convention; no
separate f32 master copy — documented memory tradeoff in DESIGN.md).
Optimizer-state leaves inherit the parameter's sharding (same logical
axes), which the launch layer exploits to build opt-state PartitionSpecs.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def adamw_init(params):
    zeros = lambda p: jnp.zeros(p.shape, F32)
    return {
        "m": jax.tree_util.tree_map(zeros, params),
        "v": jax.tree_util.tree_map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(l.astype(F32) ** 2) for l in leaves))


def adamw_update(params, grads, state, cfg: OptConfig, lr=None):
    lr = cfg.lr if lr is None else lr
    step = state["step"] + 1
    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip else jnp.ones(())

    def upd(p, g, m, v):
        g = g.astype(F32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / (1 - cfg.b1 ** step.astype(F32))
        vhat = v / (1 - cfg.b2 ** step.astype(F32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + \
            cfg.weight_decay * p.astype(F32)
        return (p.astype(F32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree_util.tree_map(upd, params, grads, state["m"], state["v"])
    new_params = jax.tree_util.tree_map(lambda t: t[0], out,
                                        is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree_util.tree_map(lambda t: t[1], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree_util.tree_map(lambda t: t[2], out,
                                   is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"m": new_m, "v": new_v, "step": step}, gnorm
