from .adamw import adamw_init, adamw_update, OptConfig
from .schedules import cosine_schedule, linear_warmup

__all__ = ["adamw_init", "adamw_update", "OptConfig", "cosine_schedule",
           "linear_warmup"]
