"""The five RunSpec execution axes, as one declarative table.

Placement, oracle backend, round engine, channel and faults used to
each hand-roll their own resolution path (env lookup, default rule,
validation, error wording) across ``api/_resolve.py`` — and ``channel``
/``faults`` differed gratuitously from the closed-vocabulary axes.  One
``Axis`` row now states everything that distinguishes an axis:

  * ``options``   — the canonical vocabulary.  For the grammar axes
    (channel, faults) these are the grammar *kinds* — validation runs
    through the core parser instead of membership;
  * ``env``       — the ``REPRO_*`` override variable, consulted when
    the spec says ``"auto"`` (and, when ``env_on_none``, when the value
    is omitted entirely — faults opts out so a stray ``REPRO_FAULTS``
    can never perturb a spec that didn't ask);
  * ``default``   — the resolved fallback: a literal, or a callable of
    the ``capabilities()`` dict for platform-dependent axes;
  * ``parser``    — for grammar axes, a thunk returning the core parser
    (imported at call time to keep this module a leaf);
  * ``auto_values`` — the inputs that mean "use the default".

``resolve`` is the one shared algorithm; env-sourced parse failures are
re-labelled with the variable name on every axis, so a typo'd env var
never surfaces as if the caller had passed the bad value explicitly.

This module must stay a leaf (stdlib only at load time): it is imported
by ``api/spec.py`` and ``api/_resolve.py``, both of which are reachable
from ``repro.core``'s call-time shims — any load-time import of
``repro.core`` from here would recreate the cycle those shims avoid.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Callable, Optional, Tuple, Union


def _channel_parser():
    from ..core.channel import parse_channel
    return parse_channel


def _faults_parser():
    from ..core.faults import parse_faults
    return parse_faults


@dataclasses.dataclass(frozen=True)
class Axis:
    """One execution axis: its vocabulary, env hook and default rule."""

    name: str                      # RunSpec field name
    label: str                     # error wording ("oracle backend", ...)
    options: Tuple[str, ...]       # canonical vocabulary / grammar kinds
    default: Union[str, Callable]  # literal, or callable(caps) -> str
    env: Optional[str] = None      # REPRO_* override variable
    env_on_none: bool = True       # consult env for None, not just "auto"
    parser: Optional[Callable] = None      # grammar axes: parser thunk
    auto_values: Tuple = (None, "auto")    # inputs meaning "default"


AXES: Tuple[Axis, ...] = (
    Axis(name="placement", label="placement",
         options=("local", "sharded"), default="local"),
    Axis(name="backend", label="oracle backend",
         options=("einsum", "kernel", "fused"),
         # fused == kernel plus whole-round fusion where a cell supports
         # it (falling back to the composed kernels otherwise), so it is
         # the strictly-better default wherever the kernels compile.
         default=lambda caps: "fused" if caps["kernel_compiled"]
         else "einsum",
         env="REPRO_ORACLE_BACKEND"),
    Axis(name="engine", label="round engine",
         options=("python", "scan"), default="scan",
         env="REPRO_ROUND_ENGINE"),
    Axis(name="channel", label="channel",
         options=("identity", "fp16", "bf16", "int8", "topk", "sched",
                  "gap"),
         default="identity", env="REPRO_CHANNEL",
         parser=_channel_parser),
    Axis(name="faults", label="faults",
         options=("none", "inject"), default="none", env="REPRO_FAULTS",
         env_on_none=False, parser=_faults_parser,
         auto_values=(None, "auto", "", "none")),
)

AXES_BY_NAME = {axis.name: axis for axis in AXES}

# The axis fields of a RunSpec, in declaration order — api/spec.py pins
# its string-typed axis fields to this, so adding an axis here is the
# single source of truth for serialization too.
AXIS_FIELDS = tuple(axis.name for axis in AXES)


def check(value: str, axis: Axis) -> str:
    """Membership check with the uniform error wording every axis uses."""
    if value not in axis.options:
        raise ValueError(f"unknown {axis.label} {value!r}; expected one "
                         f"of {tuple(axis.options) + ('auto',)}")
    return value


def resolve(axis: Axis, value: Optional[str],
            caps: Union[dict, Callable, None] = None) -> str:
    """Resolve ``value`` on ``axis``: env override, then default, then
    validation (vocabulary membership, or the core grammar parser).

    ``caps`` — the ``capabilities()`` dict, or a zero-arg callable
    producing it; only consulted (lazily) by platform-dependent
    defaults, so cheap resolutions never probe the backend.
    """
    from_env = False
    if axis.env is not None and (value == "auto"
                                 or (value is None and axis.env_on_none)):
        env_value = os.environ.get(axis.env, "").strip() or None
        if env_value is not None:
            value, from_env = env_value, True
    if value in axis.auto_values:
        if callable(axis.default):
            caps = caps() if callable(caps) else caps
            return axis.default(caps)
        return axis.default
    if axis.parser is None:
        return check(value, axis)
    try:
        return axis.parser()(value).name
    except ValueError as e:
        if from_env:
            # without this, a typo'd REPRO_* value surfaces as if the
            # caller had passed the bad name explicitly — on a spec
            # that never mentioned this axis at all.
            raise ValueError(
                f"{axis.env} environment variable: {e}") from None
        raise
