"""``RunSpec`` — every run in the repo as one explicit, serializable value.

A ``RunSpec`` captures the full coordinates of a certification cell:
the problem (a registered instance family plus its parameters), the
algorithm, the round/accuracy budget, and the four execution axes
(placement, oracle backend, round engine, channel — ``"auto"`` until
``repro.api.plan`` resolves them).  Nothing about a run lives anywhere
else: a spec embedded in a ``docs/results/*.json`` record is enough to
re-execute that row verbatim (``RunSpec.from_dict(rec["run_spec"])``).

Specs are frozen and JSON-round-trippable; ``plan(spec)`` validates one
eagerly before any compute is paid for.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, Optional, Tuple

import numpy as np


SPEC_SCHEMA_VERSION = 2       # 2: channel axis (PR 5)
# Older spec dicts still load: every field added since a compat version
# has a default, so from_dict accepts the whole range.
_SPEC_COMPAT_VERSIONS = (1, SPEC_SCHEMA_VERSION)

_EPS_MODES = ("abs", "rel")
_MEASURES = ("auto", "gap", "none")


def _plain(value):
    """Recursively coerce numpy scalars/arrays (grid machinery leaks
    them) to JSON types, so every constructible spec round-trips."""
    if isinstance(value, np.bool_):
        return bool(value)
    if isinstance(value, np.integer):
        return int(value)
    if isinstance(value, np.floating):
        return float(value)
    if isinstance(value, np.ndarray):
        return [_plain(v) for v in value.tolist()]
    if isinstance(value, dict):
        return {str(k): _plain(v) for k, v in value.items()}
    if isinstance(value, (list, tuple)):
        return [_plain(v) for v in value]
    return value


@dataclasses.dataclass(frozen=True)
class RunSpec:
    """One run, declaratively.

    ``instance``/``instance_params`` name a builder in
    ``repro.experiments.instances.INSTANCE_BUILDERS``; ``algorithm`` an
    entry of ``repro.experiments.registry``.  Both may be ``None`` for a
    *resolution-only* spec (used e.g. by the dry-run tooling, which only
    needs the axes resolved).

    ``eps`` thresholds are read off the measured gap series after the
    run — they never change what executes, so one metered run serves a
    whole eps grid.  ``measure="auto"`` folds gap measurement into the
    run iff thresholds were requested.

    ``algo_kwargs`` overrides entries of the hyper-context the registry
    derives from the instance (``AlgorithmSpec.make_kwargs``).
    """

    instance: Optional[str] = None
    instance_params: Dict[str, object] = dataclasses.field(
        default_factory=dict)
    algorithm: Optional[str] = None
    rounds: int = 0
    eps: Tuple[float, ...] = ()
    eps_mode: str = "abs"            # "abs" | "rel" (x (f(0) - f*))
    measure: str = "auto"            # "auto" | "gap" | "none"
    placement: str = "auto"          # "auto" | "local" | "sharded"
    backend: str = "auto"            # "auto" | "einsum" | "kernel"
    engine: str = "auto"             # "auto" | "scan" | "python"
    channel: str = "auto"            # "auto" | "identity" | "fp16" | "bf16"
                                     # | "int8" | "topk[:rho]"
    algo_kwargs: Dict[str, object] = dataclasses.field(default_factory=dict)
    check_budget: bool = True        # assert the O(n+d)/round budget
    tag: str = ""

    def __post_init__(self):
        object.__setattr__(self, "instance_params",
                           _plain(dict(self.instance_params)))
        object.__setattr__(self, "algo_kwargs",
                           _plain(dict(self.algo_kwargs)))
        object.__setattr__(self, "eps",
                           tuple(float(e) for e in self.eps))
        object.__setattr__(self, "rounds", int(self.rounds))
        if self.eps_mode not in _EPS_MODES:
            raise ValueError(f"eps_mode {self.eps_mode!r}; expected one of "
                             f"{_EPS_MODES}")
        if self.measure not in _MEASURES:
            raise ValueError(f"measure {self.measure!r}; expected one of "
                             f"{_MEASURES}")

    # ---- serialization ---------------------------------------------------
    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["eps"] = list(self.eps)
        d["schema_version"] = SPEC_SCHEMA_VERSION
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "RunSpec":
        d = dict(d)
        version = d.pop("schema_version", SPEC_SCHEMA_VERSION)
        if version not in _SPEC_COMPAT_VERSIONS:
            raise ValueError(f"RunSpec schema_version {version} not "
                             f"supported (this build speaks "
                             f"{_SPEC_COMPAT_VERSIONS})")
        fields = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - fields
        if unknown:
            raise ValueError(f"unknown RunSpec field(s) {sorted(unknown)}; "
                             f"known: {sorted(fields)}")
        return cls(**d)

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "RunSpec":
        return cls.from_dict(json.loads(text))

    def replace(self, **changes) -> "RunSpec":
        return dataclasses.replace(self, **changes)
