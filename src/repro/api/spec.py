"""``RunSpec`` — every run in the repo as one explicit, serializable value.

A ``RunSpec`` captures the full coordinates of a certification cell:
the problem (a registered instance family plus its parameters), the
algorithm, the round/accuracy budget, and the four execution axes
(placement, oracle backend, round engine, channel — ``"auto"`` until
``repro.api.plan`` resolves them).  Nothing about a run lives anywhere
else: a spec embedded in a ``docs/results/*.json`` record is enough to
re-execute that row verbatim (``RunSpec.from_dict(rec["run_spec"])``).

Specs are frozen and JSON-round-trippable; ``plan(spec)`` validates one
eagerly before any compute is paid for.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, Optional, Tuple

import numpy as np

from ._axes import AXIS_FIELDS


SPEC_SCHEMA_VERSION = 4       # 2: channel axis (PR 5); 3: adaptive
                              # channels — sched:/gap: channel grammar;
                              # 4: faults axis (seeded fault injection)
# Older spec dicts still load: every field added since a compat version
# has a default, so from_dict accepts the whole range.
_SPEC_COMPAT_VERSIONS = (1, 2, 3, SPEC_SCHEMA_VERSION)

_EPS_MODES = ("abs", "rel")
_MEASURES = ("auto", "gap", "none")

# Fields that name a point on an execution/selection axis.  The axis
# VALUES are validated later (``plan`` owns the vocabularies — the axis
# table in api/_axes.py and the grammars in core.channel/core.faults),
# but the TYPE is pinned here so a wrong-typed payload dies with a clear
# ValueError at load time, never a TypeError from deep inside the
# resolvers.  The execution-axis fields come straight from the table, so
# adding an axis there extends serialization type-pinning automatically.
_STR_FIELDS = ("instance", "algorithm", "eps_mode",
               "measure") + AXIS_FIELDS + ("tag",)


def _type_error(name: str, value, expected: str) -> ValueError:
    return ValueError(f"RunSpec field {name!r} must be {expected}; got "
                      f"{type(value).__name__} ({value!r})")


def _plain(value):
    """Recursively coerce numpy scalars/arrays (grid machinery leaks
    them) to JSON types, so every constructible spec round-trips."""
    if isinstance(value, np.bool_):
        return bool(value)
    if isinstance(value, np.integer):
        return int(value)
    if isinstance(value, np.floating):
        return float(value)
    if isinstance(value, np.ndarray):
        return [_plain(v) for v in value.tolist()]
    if isinstance(value, dict):
        return {str(k): _plain(v) for k, v in value.items()}
    if isinstance(value, (list, tuple)):
        return [_plain(v) for v in value]
    return value


@dataclasses.dataclass(frozen=True)
class RunSpec:
    """One run, declaratively.

    ``instance``/``instance_params`` name a builder in
    ``repro.experiments.instances.INSTANCE_BUILDERS``; ``algorithm`` an
    entry of ``repro.experiments.registry``.  Both may be ``None`` for a
    *resolution-only* spec (used e.g. by the dry-run tooling, which only
    needs the axes resolved).

    ``eps`` thresholds are read off the measured gap series after the
    run — they never change what executes, so one metered run serves a
    whole eps grid.  ``measure="auto"`` folds gap measurement into the
    run iff thresholds were requested.

    ``algo_kwargs`` overrides entries of the hyper-context the registry
    derives from the instance (``AlgorithmSpec.make_kwargs``).
    """

    instance: Optional[str] = None
    instance_params: Dict[str, object] = dataclasses.field(
        default_factory=dict)
    algorithm: Optional[str] = None
    rounds: int = 0
    eps: Tuple[float, ...] = ()
    eps_mode: str = "abs"            # "abs" | "rel" (x (f(0) - f*))
    measure: str = "auto"            # "auto" | "gap" | "none"
    placement: str = "auto"          # "auto" | "local" | "sharded"
    backend: str = "auto"            # "auto" | "einsum" | "kernel"
                                     # | "fused"
    engine: str = "auto"             # "auto" | "scan" | "python"
    channel: str = "auto"            # "auto" | "identity" | "fp16" | "bf16"
                                     # | "int8" | "topk[:rho]"
                                     # | "sched:<ch>@<round>,..."
                                     # | "gap:<ch0>,<ch>@<thr>,..."
    faults: str = "none"             # "auto" | "none" |
                                     # "inject:seed=..,drop=..,flip=..,
                                     #  straggle=<p>x<r>,crash=<k>,snap=<s>"
                                     # (core.faults grammar; "none" keeps
                                     # streams bit-identical to pre-fault
                                     # builds)
    algo_kwargs: Dict[str, object] = dataclasses.field(default_factory=dict)
    check_budget: bool = True        # assert the O(n+d)/round budget
    tag: str = ""

    def __post_init__(self):
        # Every coercion failure below is a ValueError naming the field:
        # specs arrive over the wire (repro.serve, embedded run_spec
        # records), so a wrong-typed payload must be a clear rejection,
        # never a TypeError traceback from inside a coercion.
        for name in _STR_FIELDS:
            value = getattr(self, name)
            if value is not None and not isinstance(value, str):
                raise _type_error(name, value, "a string"
                                  + (" or null" if name in
                                     ("instance", "algorithm") else ""))
        for name in ("instance_params", "algo_kwargs"):
            value = getattr(self, name)
            if not isinstance(value, dict):
                raise _type_error(name, value, "an object/dict")
            object.__setattr__(self, name, _plain(dict(value)))
        if isinstance(self.eps, (str, bytes)) or not hasattr(self.eps,
                                                             "__iter__"):
            raise _type_error("eps", self.eps, "a list of numbers")
        try:
            object.__setattr__(self, "eps",
                               tuple(float(e) for e in self.eps))
        except (TypeError, ValueError):
            raise _type_error("eps", self.eps, "a list of numbers") \
                from None
        try:
            object.__setattr__(self, "rounds", int(self.rounds))
        except (TypeError, ValueError):
            raise _type_error("rounds", self.rounds, "an integer") \
                from None
        if not isinstance(self.check_budget, (bool, int, np.bool_)):
            raise _type_error("check_budget", self.check_budget,
                              "a boolean")
        if self.eps_mode not in _EPS_MODES:
            raise ValueError(f"eps_mode {self.eps_mode!r}; expected one of "
                             f"{_EPS_MODES}")
        if self.measure not in _MEASURES:
            raise ValueError(f"measure {self.measure!r}; expected one of "
                             f"{_MEASURES}")

    # ---- serialization ---------------------------------------------------
    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["eps"] = list(self.eps)
        d["schema_version"] = SPEC_SCHEMA_VERSION
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "RunSpec":
        if not isinstance(d, dict):
            raise ValueError(f"a RunSpec payload must be a JSON object/"
                             f"dict; got {type(d).__name__}")
        d = dict(d)
        version = d.pop("schema_version", SPEC_SCHEMA_VERSION)
        if version not in _SPEC_COMPAT_VERSIONS:
            raise ValueError(f"RunSpec schema_version {version} not "
                             f"supported (this build speaks "
                             f"{_SPEC_COMPAT_VERSIONS})")
        fields = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - fields
        if unknown:
            raise ValueError(f"unknown RunSpec field(s) {sorted(unknown)}; "
                             f"known: {sorted(fields)}")
        return cls(**d)

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "RunSpec":
        try:
            payload = json.loads(text)
        except json.JSONDecodeError as e:
            raise ValueError(f"malformed RunSpec JSON: {e}") from None
        return cls.from_dict(payload)

    def replace(self, **changes) -> "RunSpec":
        return dataclasses.replace(self, **changes)
