"""Unified declarative run API — the repo's one front door.

Every run is an explicit, serializable value:

    from repro.api import RunSpec, run

    res = run(RunSpec(
        instance="thm2_chain",
        instance_params=dict(d=64, kappa=32.0, lam=0.5, m=4),
        algorithm="dagd", rounds=1500, eps=(1e-6,)))
    res.measured_rounds(1e-6), res.ledger.rounds, res.stream()

``RunSpec -> plan -> execute``: ``plan(spec)`` resolves every ``"auto"``
axis (placement, oracle backend, round engine) through the single
capability resolver and validates the combination eagerly;
``ExecutionPlan.execute()`` drives the existing metered runtime;
``execute_batch(plans)`` groups same-shaped cells and ``vmap``s the
scan-compiled round program across the grid — a sweep compiles a
handful of XLA programs instead of one per cell.

Specs round-trip through JSON (``to_json``/``from_json``) and are
embedded in every sweep record under ``docs/results/``, so any published
row can be re-executed verbatim.
"""
from ._resolve import (BACKEND_ENV, CHANNEL_ENV, CHANNELS, ENGINE_ENV,
                       ENGINES, FAULTS_ENV, ORACLE_BACKENDS, PLACEMENTS,
                       capabilities, resolve_channel, resolve_engine,
                       resolve_faults, resolve_oracle_backend,
                       resolve_placement)
from .spec import SPEC_SCHEMA_VERSION, RunSpec
from .plan import (VERIFY_ANALYSES, ExecutionPlan, PlanError, RunResult,
                   bound_for, plan, run)
from .batch import Cell, execute_batch, execute_group, prepare_cell

__all__ = [
    "BACKEND_ENV", "CHANNEL_ENV", "CHANNELS", "ENGINE_ENV", "ENGINES",
    "FAULTS_ENV", "ORACLE_BACKENDS", "PLACEMENTS",
    "capabilities", "resolve_channel", "resolve_engine", "resolve_faults",
    "resolve_oracle_backend", "resolve_placement",
    "SPEC_SCHEMA_VERSION", "RunSpec", "VERIFY_ANALYSES",
    "ExecutionPlan", "PlanError", "RunResult", "bound_for", "plan", "run",
    "Cell", "execute_batch", "execute_group", "prepare_cell",
]
