"""``plan(spec) -> ExecutionPlan`` — validate a run before paying for it.

Planning is where every ``"auto"`` in a ``RunSpec`` becomes a concrete
choice (one resolver, ``repro.api._resolve``, consulted at plan time)
and where incompatible combinations are rejected eagerly: unknown
algorithm or instance names, instance parameters the builder does not
accept, eps thresholds without measurement, gap measurement under the
sharded placement (whose driver has no measurement channel), hyper-
parameter overrides the algorithm's program does not take.  A failed
plan costs microseconds; a failed run costs a compile.

An ``ExecutionPlan`` then drives the existing machinery:

  * ``execute()`` — one metered run through ``LocalDistERM`` +
    ``run_program`` (or ``shard_map`` via the ``core.runtime`` driver for
    the sharded placement), returning a ``RunResult`` with the final
    iterate, the per-round gap series, and a fresh ``CommLedger``.
  * ``bound(eps_abs)`` — the closed-form theorem report certifying this
    (instance, algorithm) pair: Thm 2 (λ>0) / Thm 3 (λ=0) for the
    non-incremental family, Thm 4 for the incremental one.
  * ``execute_batch`` (``repro.api.batch``) — many plans per compiled
    XLA program.

The instance is built lazily (``plan`` itself stays cheap); sweeps that
share one instance across algorithms pass ``bundle=`` to avoid
rebuilding reference solutions.
"""
from __future__ import annotations

import dataclasses
import inspect
from typing import List, Optional, Tuple

import numpy as np
import jax.numpy as jnp

from ..core.bounds import (BoundReport, thm2_strongly_convex,
                           thm3_smooth_convex, thm4_incremental)
from ..core.comm import CommLedger
from ..core.engine import EngineSession, run_program
from ..experiments.instances import INSTANCE_BUILDERS, InstanceBundle, \
    build_instance
from ..experiments.registry import ALGORITHM_REGISTRY, AlgorithmSpec, \
    get_algorithm
from . import _resolve
from .spec import RunSpec


class PlanError(ValueError):
    """A RunSpec that cannot execute, rejected before any compute."""


def bound_for(bundle: InstanceBundle, algo: AlgorithmSpec,
              eps_abs: float) -> Optional[BoundReport]:
    """The theorem bound certifying this (instance, algorithm) pair, as
    declared by the algorithm's registry entry."""
    p, ctx = bundle.params, bundle.ctx
    if bundle.wstar_norm is None:
        return None
    sc_theorem, smooth_theorem = algo.certifying_theorem
    theorem = sc_theorem if ctx.lam > 0 else smooth_theorem
    if theorem == "thm4":
        n_comp = int(p.get("n", bundle.prob.n))
        kappa = float(p.get("kappa", ctx.L / max(ctx.lam, 1e-30)))
        return thm4_incremental(n_comp, kappa, ctx.lam, bundle.wstar_norm,
                                eps_abs)
    if theorem == "thm2":
        kappa = float(p.get("kappa", ctx.L / ctx.lam))
        return thm2_strongly_convex(kappa, ctx.lam, bundle.wstar_norm,
                                    eps_abs)
    return thm3_smooth_convex(float(p.get("L", ctx.L)), bundle.wstar_norm,
                              eps_abs)


@dataclasses.dataclass
class RunResult:
    """One executed run: final iterate, measurements, and the meter."""

    spec: RunSpec
    placement: str
    backend: str
    engine: str
    w: jnp.ndarray                    # assembled global iterate (d,)
    rounds: int
    ledger: CommLedger
    gaps: Optional[np.ndarray] = None     # (K,) when measure == "gap"
    budget_ok: Optional[bool] = None      # None: budget check disabled
    batched: bool = False                 # executed via execute_batch group
    channel: str = "identity"             # resolved wire model (canonical)
    wire_channel: str = ""                # channel actually driven on the
                                          # wire: == channel except for
                                          # gap: specs, which resolve to a
                                          # concrete sched: before running
    faults: str = "none"                  # resolved fault schedule
                                          # (canonical core.faults name)

    def measured_rounds(self, eps_abs: float) -> Optional[int]:
        """First round k with f(w_k) - f* <= eps_abs (1-based), or None
        if the budget never reached eps."""
        if self.gaps is None:
            raise PlanError("run was executed without gap measurement "
                            "(measure='none'); no rounds-to-eps to read")
        hits = np.nonzero(self.gaps <= eps_abs)[0]
        return int(hits[0]) + 1 if hits.size else None

    def stream(self) -> List[Tuple[str, int, int, str]]:
        """The full (kind, elems, bytes, tag) CommLedger record stream —
        the quantity the conformance suites pin bit-identical across
        backends, engines, and batching."""
        return [(r.kind, r.elems, r.bytes, r.tag)
                for r in self.ledger.records]


@dataclasses.dataclass
class ExecutionPlan:
    """A validated RunSpec with every ``auto`` resolved."""

    spec: RunSpec
    placement: str
    backend: str
    engine: str
    channel: str                      # canonical name, e.g. "topk:0.1"
    measure: str                      # "gap" | "none"
    algo: Optional[AlgorithmSpec]
    faults: str = "none"              # canonical core.faults name
    _bundle: Optional[InstanceBundle] = None
    _cell_cache: Optional[tuple] = None
    _gap0: Optional[float] = None
    _wire: Optional[str] = None       # gap: spec resolved to sched: (lazy)

    # ---- lazy problem construction --------------------------------------
    @property
    def resolution_only(self) -> bool:
        return self.spec.instance is None

    @property
    def bundle(self) -> InstanceBundle:
        if self.resolution_only:
            raise PlanError("resolution-only plan (no instance); nothing "
                            "to build")
        if self._bundle is None:
            self._bundle = build_instance(self.spec.instance,
                                          **self.spec.instance_params)
        return self._bundle

    def algo_kwargs(self) -> dict:
        return dict(self.algo.make_kwargs(self.bundle.ctx),
                    **self.spec.algo_kwargs)

    def gap0(self) -> float:
        """f(0) - f*, the denominator of relative eps thresholds."""
        if self._gap0 is None:
            b = self.bundle
            if b.fstar is None:
                raise PlanError(f"instance {b.kind!r} has no reference "
                                f"optimum (fstar); relative eps and gap "
                                f"measurement are unavailable")
            self._gap0 = float(b.objective(jnp.zeros((b.prob.d,)))
                               - b.fstar)
        return self._gap0

    def eps_abs(self, eps: float) -> float:
        return eps * self.gap0() if self.spec.eps_mode == "rel" else eps

    def bound(self, eps_abs: float) -> Optional[BoundReport]:
        return bound_for(self.bundle, self.algo, eps_abs)

    # ---- gap-adaptive channel resolution ---------------------------------
    def wire_channel(self) -> str:
        """The canonical channel actually driven on the wire.

        For fixed and ``sched:`` channels this is ``self.channel``.  A
        ``gap:`` spec is resolved here — once, lazily — into a concrete
        ``sched:`` channel by probing the cell under the identity
        channel, measuring its gap series, and pinning each stage's
        switch round where the trajectory crosses the stage threshold
        (``core.channel.GapChannel.resolve``).  The probe is a
        deterministic identity run of the same cell, so re-executing a
        recorded gap-channel spec reproduces the schedule — and the wire
        bits — exactly."""
        if not self.channel.startswith("gap:"):
            return self.channel
        if self._wire is None:
            from ..core.channel import parse_channel
            gap = parse_channel(self.channel)
            probe_spec = self.spec.replace(
                channel="identity", measure="gap", placement="local",
                backend=self.backend, engine=self.engine, faults="none")
            try:
                probe = plan(probe_spec, bundle=self._bundle)
                res = probe.execute()
            except PlanError as e:
                raise PlanError(
                    f"channel {self.channel!r} needs a measurable gap "
                    f"series to resolve its schedule: {e}") from None
            self._wire = gap.resolve(res.gaps).name
        return self._wire

    def certify(self, result: "RunResult", eps: float) -> Optional[bool]:
        """The certification verdict for one eps threshold, three-valued
        exactly as the sweep reports it: ``True``/``False`` when the
        inequality measured >= bound is conclusive, ``None`` when it is
        not applicable (instance not hard, no bound) or inconclusive
        (eps unreached within a round budget still below the bound).
        When eps goes unreached but budget >= bound, the run certifies:
        rounds-to-eps > budget >= bound."""
        eps_abs = self.eps_abs(eps)
        bound = self.bound(eps_abs)
        if not self.bundle.hard or bound is None:
            return None
        measured = result.measured_rounds(eps_abs)
        if measured is not None:
            return bool(measured >= bound.rounds)
        return True if self.spec.rounds >= bound.rounds else None

    def recovery_report(self, result: "RunResult") -> dict:
        """Measured rounds-with-faults against the bound's currency plus
        the *declared* recovery budget.  The fault schedule is seeded and
        data-independent, so its recovery cost (straggler idle rounds +
        the crash replay span) is computable before the run; a healthy
        recovery layer measures exactly the declared budget — no silent
        extra traffic, no unpriced recovery."""
        from ..core.faults import parse_faults
        led = result.ledger
        f = parse_faults(self.faults)
        declared = f.declared_recovery_rounds(led.algo_rounds)
        return dict(
            faults=self.faults,
            algo_rounds=led.algo_rounds,
            wire_rounds=led.rounds,
            recovery_rounds=led.recovery_rounds,
            declared_recovery_rounds=declared,
            within_budget=led.recovery_rounds <= declared,
            retransmissions=led.retransmissions(),
            retransmit_bits=led.retransmit_bits(),
            clean_bits=led.clean_bits(),
            total_bits=led.total_bits(),
        )

    # ---- execution -------------------------------------------------------
    def _cell(self):
        """(dist, program, measure_fn) — built once, reused across
        ``execute`` calls (each call meters into a fresh ledger)."""
        if self._cell_cache is None:
            from ..core.runtime import LocalDistERM
            b = self.bundle
            dist = LocalDistERM(b.prob, b.part, backend=self.backend,
                                channel=self.wire_channel(),
                                faults=self.faults)
            program = self.algo.program(dist, rounds=self.spec.rounds,
                                        **self.algo_kwargs())
            measure_fn = None
            if self.measure == "gap":
                objective = b.objective
                if b.fstar is None:
                    raise PlanError(f"instance {b.kind!r} has no fstar; "
                                    f"run with measure='none'")
                # f32-wrapped so fstar is a hoistable const, not a
                # per-cell literal (same f32 value the weak-typed float
                # subtraction produced; see execute_batch grouping)
                fstar = jnp.float32(b.fstar)

                def measure_fn(w_stk):
                    return objective(dist.gather_w(w_stk)) - fstar

            self._cell_cache = (dist, program, measure_fn)
        return self._cell_cache

    def _budget_ok(self, ledger: CommLedger) -> Optional[bool]:
        if not self.spec.check_budget:
            return None
        try:
            ledger.assert_budget(n=self.bundle.prob.n, d=self.bundle.prob.d)
            return True
        except AssertionError:
            return False

    def audit(self, execute: bool = False):
        """Statically audit this plan's cell (``repro.analysis``):
        schedule conformance against the trace-once ledger capture and
        its replay, algorithm-class certification, and the compile-
        hazard lints.  ``execute=True`` additionally cross-checks the
        static schedule against an executed run's ledger.  Returns the
        ``CellAudit``; ``plan(spec, verify="static")`` is the raising
        front door."""
        from ..analysis import audit_plan
        return audit_plan(self, execute=execute)

    def audit_hlo_bytes(self):
        """Lower this plan's sharded cell without running it and audit
        the compiled module's collectives
        (``core.comm.collective_bytes_from_lowered``): the module must
        carry at least the collective traffic the trace-once ledger
        metered, or the wire meter is lying about the compiled program.
        Returns the ``CollectiveAudit``; ``plan(spec,
        verify=("hlo-bytes",))`` is the raising front door.  Lowering
        always happens through the scan driver (the python driver has no
        whole-program module to audit)."""
        if self.placement != "sharded":
            raise PlanError(
                "verify analysis 'hlo-bytes' audits the compiled XLA "
                "module's collectives; only the sharded placement lowers "
                "to collective HLO (the local placement simulates "
                "machines on one device, so its module has none) — use "
                "placement='sharded', or verify='static' for local cells")
        from ..core.comm import collective_bytes_from_lowered
        from ..core.runtime import _run_sharded
        b = self.bundle
        kwargs = self.algo_kwargs()
        lowered, led, _ = _run_sharded(
            b.prob, None, rounds=self.spec.rounds, ledger=CommLedger(),
            backend=self.backend, engine="scan",
            program_builder=lambda d_, r: self.algo.program(d_, r,
                                                            **kwargs),
            channel=self.wire_channel(), lower_only=True)
        audit = collective_bytes_from_lowered(lowered)
        traced = sum(r.bytes for r in led.records)
        if led.records and audit.total_bytes < traced:
            raise PlanError(
                f"hlo-bytes audit rejected "
                f"{self.spec.algorithm}/{self.channel}: the lowered "
                f"module carries {audit.total_bytes} collective bytes "
                f"but the trace-once ledger metered {traced}")
        return audit

    def release(self) -> None:
        """Drop the cached cell (dist's padded data copy, compiled-step
        closures) and bundle.  A long sweep calls this after harvesting a
        cell's records so peak memory stays one grid point, not the whole
        grid; the plan can still re-execute (everything rebuilds)."""
        self._cell_cache = None
        self._bundle = None

    def execute(self, session: Optional[EngineSession] = None) -> RunResult:
        if self.resolution_only:
            raise PlanError("resolution-only plan; give the RunSpec an "
                            "instance and algorithm to execute it")
        if self.placement == "sharded":
            return self._execute_sharded()
        dist, program, measure_fn = self._cell()
        dist.comm.ledger = ledger = CommLedger()
        res = run_program(dist, program, engine=self.engine,
                          measure=measure_fn, session=session)
        return RunResult(
            spec=self.spec, placement=self.placement, backend=self.backend,
            engine=self.engine, channel=self.channel,
            wire_channel=self.wire_channel(), faults=self.faults,
            w=dist.gather_w(res.w), rounds=res.rounds,
            ledger=ledger, gaps=res.gaps, budget_ok=self._budget_ok(ledger))

    def _execute_sharded(self) -> RunResult:
        from ..core.runtime import _run_sharded
        b = self.bundle
        kwargs = self.algo_kwargs()
        ledger = CommLedger()
        if self.engine == "python":
            w, led = _run_sharded(
                b.prob, lambda d_, r: self.algo.fn(d_, r, **kwargs),
                rounds=self.spec.rounds, ledger=ledger,
                backend=self.backend, engine="python",
                channel=self.wire_channel())
        else:
            w, led = _run_sharded(
                b.prob, None, rounds=self.spec.rounds, ledger=ledger,
                backend=self.backend, engine="scan",
                program_builder=lambda d_, r: self.algo.program(d_, r,
                                                                **kwargs),
                channel=self.wire_channel())
        return RunResult(
            spec=self.spec, placement=self.placement, backend=self.backend,
            engine=self.engine, channel=self.channel,
            wire_channel=self.wire_channel(),
            w=w, rounds=led.rounds, ledger=led,
            gaps=None, budget_ok=self._budget_ok(led))


# --------------------------------------------------------------------------
# The validator
# --------------------------------------------------------------------------

def _validate_instance(spec: RunSpec) -> None:
    if spec.instance not in INSTANCE_BUILDERS:
        raise PlanError(f"unknown instance {spec.instance!r}; known: "
                        f"{sorted(INSTANCE_BUILDERS)}")
    sig = inspect.signature(INSTANCE_BUILDERS[spec.instance])
    unknown = set(spec.instance_params) - set(sig.parameters)
    if unknown:
        raise PlanError(
            f"instance {spec.instance!r} does not accept parameter(s) "
            f"{sorted(unknown)}; accepted: {sorted(sig.parameters)}")


def _validate_algorithm(spec: RunSpec) -> AlgorithmSpec:
    if spec.algorithm not in ALGORITHM_REGISTRY:
        raise PlanError(f"unknown algorithm {spec.algorithm!r}; "
                        f"registered: {sorted(ALGORITHM_REGISTRY)}")
    algo = get_algorithm(spec.algorithm)
    if spec.algo_kwargs:
        sig = inspect.signature(algo.program)
        # 'dist' and 'rounds' are positions the plan itself fills — a
        # spec supplying them would pass the signature check here only to
        # die with a duplicate-argument TypeError at execute time
        reserved = {"dist", "rounds"}
        accepted = set(sig.parameters) - reserved
        unknown = set(spec.algo_kwargs) - accepted
        if unknown:
            raise PlanError(
                f"algorithm {spec.algorithm!r} takes no hyper-parameter(s) "
                f"{sorted(unknown)}; its program accepts "
                f"{sorted(accepted)}")
    return algo


VERIFY_ANALYSES = ("static", "hlo-bytes")


def _verify_analyses(verify) -> Tuple[str, ...]:
    """Normalize ``plan``'s ``verify=`` argument — ``"none"``/``None``,
    one analysis name, or an iterable of names — to a tuple of known
    analyses, rejecting anything else eagerly."""
    if verify is None or verify == "none":
        return ()
    if isinstance(verify, str):
        verify = (verify,)
    try:
        analyses = tuple(verify)
    except TypeError:
        raise PlanError(f"verify must be an analysis name or an iterable "
                        f"of names; got {type(verify).__name__} "
                        f"({verify!r})") from None
    for a in analyses:
        if a not in VERIFY_ANALYSES:
            raise PlanError(f"unknown verify mode {a!r}; expected 'none' "
                            f"or a subset of {VERIFY_ANALYSES}")
    return analyses


def plan(spec: RunSpec,
         bundle: Optional[InstanceBundle] = None,
         verify="none") -> ExecutionPlan:
    """Resolve + validate a RunSpec.  ``bundle`` optionally supplies a
    pre-built instance (sweeps share one across algorithms); it must
    match ``spec.instance``.

    ``verify=`` names the pre-flight analyses to run over the plan
    before returning it — one name or an iterable of names from
    ``VERIFY_ANALYSES`` (e.g. ``verify=("static", "hlo-bytes")``):

      * ``"static"`` — the ``repro.analysis`` audit over the traced
        cell: the plan is rejected unless its wire schedule is provably
        the ledger's, its oracles provably read only their own feature
        partition, and no compile-hazard lint fires at error severity.
        Costs one trace per distinct segment step (no rounds execute).
      * ``"hlo-bytes"`` — the collective-bytes audit of the lowered XLA
        module (sharded placement only): the compiled program must
        carry at least the collective traffic the trace-once ledger
        metered (``ExecutionPlan.audit_hlo_bytes``)."""
    analyses = _verify_analyses(verify)
    caps = _resolve.capabilities()
    try:
        placement = _resolve.resolve_placement(spec.placement)
        backend = _resolve.resolve_oracle_backend(spec.backend, caps=caps)
        engine = _resolve.resolve_engine(spec.engine)
        channel = _resolve.resolve_channel(spec.channel)
        faults = _resolve.resolve_faults(spec.faults)
    except ValueError as e:
        raise PlanError(str(e)) from None

    if faults != "none" and placement == "sharded":
        raise PlanError(
            "fault injection needs the local placement (the "
            "detect/retransmit recovery dance runs on concrete host "
            "arrays; the shard_map driver meters at trace time); run "
            "faulted specs with placement='local'")

    if spec.instance is None and spec.algorithm is None:
        # resolution-only: the axes are the whole request (dry-run tools)
        if analyses:
            raise PlanError(f"verify={analyses!r} needs a runnable spec; "
                            f"a resolution-only plan traces nothing to "
                            f"audit")
        return ExecutionPlan(spec=spec, placement=placement,
                             backend=backend, engine=engine,
                             channel=channel, measure="none", algo=None,
                             faults=faults)
    if spec.instance is None or spec.algorithm is None:
        raise PlanError("a runnable RunSpec needs BOTH instance and "
                        "algorithm (leave both None for a resolution-only "
                        "plan)")

    _validate_instance(spec)
    algo = _validate_algorithm(spec)
    if spec.rounds < 1:
        raise PlanError(f"rounds must be >= 1 to execute; got "
                        f"{spec.rounds}")

    measure = spec.measure
    if measure == "auto":
        measure = "gap" if spec.eps else "none"
    if spec.eps and measure == "none":
        raise PlanError("eps thresholds were requested but measure='none'; "
                        "rounds-to-eps needs the in-run gap series")
    if channel.startswith("gap:") and placement == "sharded":
        raise PlanError(
            "gap-adaptive channels need the local placement (the "
            "schedule is resolved from an identity probe's measured gap "
            "series, and the sharded driver has no measurement channel); "
            "pin an explicit sched: channel for sharded runs")
    if placement == "sharded":
        if measure == "gap":
            raise PlanError(
                "gap measurement is not supported under the sharded "
                "placement (the shard_map driver has no measurement "
                "channel); use placement='local' for certification cells")
        if algo.local_only_kwargs:
            raise PlanError(
                f"algorithm {algo.name!r} derives machine-stacked hyper-"
                f"parameters (registry local_only_kwargs); its registry "
                f"adapter only supports placement='local'")
    if bundle is not None:
        if bundle.kind != spec.instance:
            raise PlanError(f"supplied bundle is {bundle.kind!r} but the "
                            f"spec names instance {spec.instance!r}")
        # a misaligned bundle would execute a different problem than the
        # embedded run_spec records, silently breaking the "re-execute any
        # row verbatim" guarantee — reject on the stamped builder inputs
        if bundle.build_params is not None and \
                bundle.build_params != spec.instance_params:
            raise PlanError(
                f"supplied bundle was built with {bundle.build_params} "
                f"but the spec says instance_params="
                f"{spec.instance_params}; the executed problem would not "
                f"match the recorded run_spec")

    pl = ExecutionPlan(spec=spec, placement=placement, backend=backend,
                       engine=engine, channel=channel, measure=measure,
                       algo=algo, faults=faults, _bundle=bundle)
    if "static" in analyses:
        from ..analysis import summarize
        cell = pl.audit()
        if cell.skipped:
            raise PlanError(f"verify='static' cannot audit this plan: "
                            f"{cell.skipped}")
        errors = [f for f in cell.findings if f.severity == "error"]
        if errors:
            raise PlanError(
                f"static verification rejected "
                f"{spec.algorithm}/{placement}/{channel}: "
                f"{summarize(cell.findings)}")
    if "hlo-bytes" in analyses:
        pl.audit_hlo_bytes()
    return pl


def run(spec: RunSpec, bundle: Optional[InstanceBundle] = None) -> RunResult:
    """The one-call front door: ``plan`` then ``execute``."""
    return plan(spec, bundle=bundle).execute()
