"""``execute_batch(plans)`` — many certification cells per compiled program.

The PR-3 scan engine compiles one XLA program per (cell, segment); a
sweep over an instance grid therefore pays one trace + compile per cell
even though every cell of the same algorithm runs the *same* round
recurrence on different data.  This module groups same-shaped cells and
``vmap``s the scan-compiled round program across the grid, so a
thm2-style sweep compiles a handful of XLA programs instead of one per
cell.

**How a cell becomes batchable.**  A cell's step function closes over
its own data (``A_stk``, masks, hyper-parameter scalars).  For each
distinct step we trace it once with ``jax.make_jaxpr`` and split the
result into

  * the *structure* — the jaxpr with its constants abstracted out, and
  * the *consts* — the closed-over arrays, in trace order.

Two cells group iff their structures are string-identical (same
algorithm, same shapes, every cell-varying value hoisted into consts —
the algorithm builders wrap their scalar hypers in ``jnp.float32`` for
exactly this reason) and their consts line up shape-for-shape.  The
group then runs as ONE jitted ``lax.scan`` whose body ``vmap``s the
shared structure over the stacked consts/carries.  Anything that fails
the structural check — a python-float literal that differs per cell, a
different round budget, the python engine — falls back to the sequential
``ExecutionPlan.execute`` path.  Grouping is checked, never assumed:
a structural mismatch can only cause a fallback, not a wrong result.

**Ledger contract.**  The batched run meters nothing from compiled code;
like the scan engine it replays each step's trace-once schedule
``count`` times per segment into each cell's own fresh ``CommLedger``.
Because the schedule comes from the same step functions the sequential
engines run, every cell's record stream is **bit-identical** to its
sequential stream (``benchmarks/api_batch.py`` gates this, along with
certification-verdict identity).  Gap series agree with the sequential
scan path up to batched-``dot_general`` reassociation (same ±1-round
eps-crossing tolerance the TPU kernels get).

**Reusable pieces.**  The splitting and the group runner are public —
``prepare_cell(plan) -> Cell | None``, ``Cell.group_key()``, and
``execute_group(cells, runner_cache=...)`` — so long-lived callers
(``repro.serve``, the continuous-batching certification service) can
coalesce cells by the same key and keep the jitted group runners alive
across calls.  A ``runner_cache`` entry is sound to reuse for any batch
sharing the group key: the key covers the jaxpr structure text and every
const's shape/dtype, so evaluating a later batch's consts through the
first-seen structure performs the identical computation.
``execute_batch`` below stays the one-shot front door built from the
same pieces.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax

from ..core.comm import CommLedger, inject_crash_recovery
from ..core.engine import Segment
from .plan import ExecutionPlan, PlanError, RunResult


# --------------------------------------------------------------------------
# Structure/consts splitting
# --------------------------------------------------------------------------

@dataclasses.dataclass
class _Converted:
    """One closure, split into pure structure + hoisted consts."""

    pure: Callable                    # pure(consts, *args) -> outputs
    consts: List[jnp.ndarray]
    structure: str                    # jaxpr text, consts abstracted
    schedule: Tuple[list, int, list]  # (ledger records, rounds,
                                      #  round-boundary marks) per call
    closed: object = None             # the traced ClosedJaxpr itself —
                                      # repro.analysis walks its
                                      # equations (structure text is for
                                      # grouping, not for analysis)


def _convert(fn: Callable, *example_args) -> _Converted:
    closed, out_shape = jax.make_jaxpr(fn, return_shape=True)(*example_args)
    out_tree = jax.tree.structure(out_shape)

    def pure(consts, *args):
        flat, _ = jax.tree.flatten(args)
        out = jax.core.eval_jaxpr(closed.jaxpr, consts, *flat)
        return jax.tree.unflatten(out_tree, out)

    return _Converted(pure=pure, consts=list(closed.consts),
                      structure=str(closed.jaxpr), schedule=([], 0, []),
                      closed=closed)


def _segment_xs(seg: Segment) -> np.ndarray:
    if seg.xs is not None:
        return np.asarray(seg.xs)
    return np.arange(seg.count, dtype=np.int32)


@dataclasses.dataclass
class Cell:
    """One batchable certification cell: a plan traced into pure
    structure + hoisted consts, ready to group and ``vmap``."""

    plan: ExecutionPlan
    dist: object
    program: object
    steps: List[_Converted]           # one per segment (shared by identity)
    meas: Optional[_Converted]

    def group_key(self) -> tuple:
        """The grouping axis: cells batch iff their keys are equal.

        Composition (pinned by ``tests/test_api.py``): the leading
        components are the explicit axes — algorithm name, oracle
        backend, channel, round budget — followed by the per-segment
        (jaxpr structure text, scan length, xs shape/dtype, const
        shapes/dtypes) and the measurement structure.  The placement and
        engine axes never appear because only local/scan plans produce a
        Cell at all (``prepare_cell`` returns None otherwise).  A future
        execution axis MUST land here, or incompatible cells would
        silently merge."""
        segs = tuple(
            (conv.structure, seg.count, _segment_xs(seg).shape,
             _segment_xs(seg).dtype.str,
             tuple((tuple(c.shape), jnp.asarray(c).dtype.str)
                   for c in conv.consts))
            for seg, conv in zip(self.program.segments, self.steps))
        meas = (self.meas.structure,
                tuple((tuple(c.shape), jnp.asarray(c).dtype.str)
                      for c in self.meas.consts)) if self.meas else None
        # The channel component is the WIRE channel (the canonical sched:
        # a gap spec resolved to): two specs whose wires differ — even
        # only in a stage switch round — must not merge, while a gap spec
        # may batch with the sched: it resolved to (identical transform,
        # identical pricing; each cell still replays its own schedule).
        # The faults axis is appended LAST (the channel stays component
        # 2, which tests/test_serve.py pins): two cells under different
        # fault schedules compute identical values but replay different
        # recovery streams, so they must not merge either.
        return (self.plan.algo.name, self.plan.backend,
                self.plan.wire_channel(), self.plan.spec.rounds, segs, meas,
                self.plan.faults)


def prepare_cell(plan: ExecutionPlan) -> Optional[Cell]:
    """Trace a plan's cell into structure + consts; None if unbatchable."""
    if plan.resolution_only or plan.placement != "local" \
            or plan.engine != "scan":
        return None
    dist, program, measure_fn = plan._cell()
    scheduled = getattr(getattr(dist.comm, "channel", None),
                        "scheduled", False)
    real = dist.comm.ledger
    dist.comm.ledger = scratch = CommLedger()
    dist.comm._tracing = True   # captured schedules stay fault-free; the
    try:                        # per-cell ledger replay injects faults
        carry = program.init
        by_step = {}
        steps = []
        for seg in program.segments:
            xs = _segment_xs(seg)
            key = (id(seg.step), xs.dtype.str, xs.shape[1:])
            if key not in by_step:
                n0, r0 = len(scratch.records), scratch.rounds
                m0 = len(scratch.round_marks)
                if scheduled:
                    # scheduled channel: the round index rides along as
                    # part of xs so the compiled group runner can switch
                    # stages mid-scan; trace with a symbolic index (the
                    # example int32 is abstracted by make_jaxpr) and pin
                    # it for the step's channel transforms.
                    def traced(c, rx, _step=seg.step):
                        rk, x = rx
                        dist.comm.begin_round(rk)
                        try:
                            return _step(dist, c, x)
                        finally:
                            dist.comm.reset_round()
                    conv = _convert(traced, carry,
                                    (jnp.int32(0), jnp.asarray(xs[0])))
                else:
                    conv = _convert(lambda c, x: seg.step(dist, c, x),
                                    carry, jnp.asarray(xs[0]))
                conv.schedule = (scratch.records[n0:], scratch.rounds - r0,
                                 [m - n0 for m in scratch.round_marks[m0:]])
                by_step[key] = conv
            steps.append(by_step[key])
        meas = None
        if measure_fn is not None:
            n0 = len(scratch.records)
            # every registered program emits the round iterate in stacked
            # block form (m, d_max) — the same shape zeros_like_w builds
            meas = _convert(measure_fn, dist.zeros_like_w())
            if len(scratch.records) != n0:
                raise PlanError("measure performed metered communication; "
                                "measurement must stay oracle-free")
    finally:
        dist.comm.ledger = real
        dist.comm._tracing = False
    return Cell(plan=plan, dist=dist, program=program, steps=steps,
                meas=meas)


# --------------------------------------------------------------------------
# Group execution
# --------------------------------------------------------------------------

def _stack_consts(cells: Sequence[Cell], pick) -> list:
    convs = [pick(c) for c in cells]
    n = len(convs[0].consts)
    return [jnp.stack([jnp.asarray(conv.consts[k]) for conv in convs])
            for k in range(n)]


def execute_group(cells: List[Cell],
                  runner_cache: Optional[dict] = None) -> List[RunResult]:
    """Run a group of cells sharing one ``group_key`` as one ``vmap``-ed
    scan program per distinct segment structure.

    ``runner_cache`` (mutable mapping, owned by the caller) keeps the
    jitted group runners alive across calls: keys are
    ``(segment jaxpr structure, shared_xs)`` — stable across batches,
    unlike the per-call trace objects — so a long-lived service can hand
    in the same dict for every batch with this group key and pay the
    trace + compile once per (structure, batch width).  Per-cell consts
    are stacked fresh per call (they carry the data); a cached runner is
    pure structure.  Safe to share only between batches with EQUAL group
    keys — the key pins structure text and const shapes/dtypes."""
    C = len(cells)
    progs = [c.program for c in cells]
    carry = jax.tree.map(lambda *xs: jnp.stack(xs),
                         *[p.init for p in progs])
    meas0 = cells[0].meas
    # all cells in a group share the wire channel (group_key pins it)
    chan0 = getattr(cells[0].dist.comm, "channel", None)
    sched_chan = chan0 if getattr(chan0, "scheduled", False) else None
    runners = runner_cache if runner_cache is not None else {}
    consts_cache, outs = {}, []
    mconsts = _stack_consts(cells, lambda c: c.meas) if meas0 else []
    round_base = 0     # global round index of the next segment's start
    for s, seg0 in enumerate(progs[0].segments):
        conv0 = cells[0].steps[s]
        cell_xs = [_segment_xs(c.program.segments[s]) for c in cells]
        # the common case (index aranges, shared momentum/RNG schedules):
        # every cell scans the same xs — share one copy and broadcast it
        # across the vmap instead of scanning a (count, C) stack
        shared_xs = all(np.array_equal(x, cell_xs[0]) for x in cell_xs[1:])
        # consts are per-call values, keyed by trace identity (two steps
        # with identical structure may hoist different const VALUES);
        # runners are pure structure, keyed by the structure text so they
        # survive across calls through runner_cache
        ckey = (id(conv0), shared_xs)
        if ckey not in consts_cache:
            consts_cache[ckey] = _stack_consts(cells, lambda c: c.steps[s])
        consts = consts_cache[ckey]
        rkey = (conv0.structure, shared_xs, sched_chan is not None)
        if rkey not in runners:
            pure_step = conv0.pure
            pure_meas = meas0.pure if meas0 else None

            def runner_fn(consts, mconsts, carry, xs,
                          _step=pure_step, _meas=pure_meas,
                          _shared=shared_xs,
                          _sched=sched_chan is not None):
                # scheduled channels scan (round index, per-round input)
                # pairs; the round index is identical across the batch,
                # so it broadcasts (in_axes None) like shared xs
                x_axes = ((None, None) if _shared else (None, 0)) \
                    if _sched else (None if _shared else 0)

                def body(c, x):
                    c, w = jax.vmap(_step, in_axes=(0, 0, x_axes)
                                    )(consts, c, x)
                    out = jax.vmap(_meas)(mconsts, w) if _meas else None
                    return c, out

                return lax.scan(body, carry, xs)

            runners[rkey] = jax.jit(runner_fn)
        xs = cell_xs[0] if shared_xs else np.stack(cell_xs, axis=1)
        xs_arg = jnp.asarray(xs)
        rounds_per_step = conv0.schedule[1]
        if sched_chan is not None:
            rid = round_base + np.arange(seg0.count,
                                         dtype=np.int32) * rounds_per_step
            xs_arg = (jnp.asarray(rid), xs_arg)
        round_base += rounds_per_step * seg0.count
        carry, out = runners[rkey](consts, mconsts, carry, xs_arg)
        if meas0 is not None:
            outs.append(out)                        # (count, C)
    gaps_all = np.asarray(jnp.concatenate(outs, axis=0)) if outs else None

    # all cells in a group share the fault schedule (group_key pins it);
    # each cell's replay draws its own fault stream into its own ledger
    faults0 = getattr(cells[0].dist.comm, "faults", None)
    if faults0 is not None and not faults0.active:
        faults0 = None
    results = []
    for i, cell in enumerate(cells):
        ledger = CommLedger()
        for s, seg in enumerate(cell.program.segments):
            records, rounds_per_step, marks = cell.steps[s].schedule
            ledger.replay_schedule(records, rounds_per_step, marks,
                                   seg.count, channel=sched_chan,
                                   faults=faults0)
        if faults0 is not None:
            inject_crash_recovery(ledger, faults0)
        carry_i = jax.tree.map(lambda a: a[i], carry)
        w = cell.dist.gather_w(cell.program.final(carry_i))
        pl = cell.plan
        results.append(RunResult(
            spec=pl.spec, placement=pl.placement, backend=pl.backend,
            engine=pl.engine, channel=pl.channel,
            wire_channel=pl.wire_channel(), faults=pl.faults, w=w,
            rounds=cell.program.rounds, ledger=ledger,
            gaps=gaps_all[:, i] if gaps_all is not None else None,
            budget_ok=pl._budget_ok(ledger), batched=True))
    return results


# --------------------------------------------------------------------------
# Entry point
# --------------------------------------------------------------------------

def execute_batch(plans: Sequence[ExecutionPlan]) -> List[RunResult]:
    """Execute many plans, vmapping groups of same-shaped cells through
    one compiled program each.  Results come back in input order; plans
    that cannot batch (python engine, sharded placement, structural
    mismatch, singleton groups) execute sequentially — batching is a
    performance optimization, never a semantic one."""
    cells: List[Optional[Cell]] = [prepare_cell(pl) for pl in plans]
    groups: dict = {}
    for i, cell in enumerate(cells):
        if cell is not None:
            groups.setdefault(cell.group_key(), []).append(i)

    results: List[Optional[RunResult]] = [None] * len(plans)
    for key, idxs in groups.items():
        if len(idxs) < 2:
            continue
        for i, res in zip(idxs, execute_group([cells[i] for i in idxs])):
            results[i] = res
    for i, res in enumerate(results):
        if res is None:
            results[i] = plans[i].execute()
    return results
