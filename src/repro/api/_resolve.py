"""The single capability resolver for the five execution axes.

Every run in the repo is positioned on five orthogonal axes:

  * **placement** — where the machines live: ``local`` (m simulated
    machines, blocks stacked on a leading axis) or ``sharded`` (machine j
    = mesh slice j inside ``shard_map``);
  * **oracle backend** — how the per-machine work inside
    ``response``/``pgrad``/``phvp`` is computed: ``einsum`` (plain jnp
    contractions), ``kernel`` (the MXU-tiled Pallas kernels) or
    ``fused`` (the kernels plus the whole-round fused step of
    ``kernels/fused_round.py`` where a cell supports it);
  * **round engine** — how rounds are driven: ``python`` (per-call loop)
    or ``scan`` (one ``lax.scan``-compiled XLA program per segment);
  * **channel** — what the per-machine uploads cost on the wire:
    ``identity`` (exact f32) or a lossy transform (``fp16``/``bf16``/
    ``int8``/``topk[:rho]``), a round-indexed schedule of those
    (``sched:<ch>@<round>,...``) or a gap-adaptive spec
    (``gap:<ch0>,<ch>@<thr>,...``) — see ``core.channel``;
  * **faults** — seeded fault injection (``core.faults`` grammar), off
    by default.

Axis *policy* (vocabulary, env var, default rule, error wording) lives
in one declarative table, ``api/_axes.py``; this module binds the table
to ``capabilities()`` and keeps the historical ``resolve_*`` names.
``repro.api.plan`` calls these at *plan time*, so environment variables
are consulted when a run is planned, never at import time, and a
resolved ``ExecutionPlan`` carries concrete choices from then on.
``core.runtime``/``core.engine`` keep their historical ``resolve_*``
names as delegating shims.

This module must stay a leaf (stdlib + jax only): ``repro.core``'s shims
reach it at call time through the ``repro.api`` package (which imports
the whole facade), so any load-time dependency from here back into
``repro.core`` or ``repro.experiments`` would recreate the import cycle
the call-time indirection avoids.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax

from . import _axes


ORACLE_BACKENDS = _axes.AXES_BY_NAME["backend"].options
ENGINES = _axes.AXES_BY_NAME["engine"].options
PLACEMENTS = _axes.AXES_BY_NAME["placement"].options
# Canonical list lives in repro.core.channel (the transform
# implementations); mirrored in the axis table so the resolver stays a
# leaf at load time. tests/test_channel.py pins equality.
CHANNELS = _axes.AXES_BY_NAME["channel"].options

BACKEND_ENV = _axes.AXES_BY_NAME["backend"].env
ENGINE_ENV = _axes.AXES_BY_NAME["engine"].env
CHANNEL_ENV = _axes.AXES_BY_NAME["channel"].env
FAULTS_ENV = _axes.AXES_BY_NAME["faults"].env


def capabilities() -> Dict[str, object]:
    """What the current process can actually execute.

    ``kernel_compiled`` — the Pallas kernels compile for TPU; everywhere
    else they run in interpret mode (correct but slow), which is why
    ``auto`` only picks ``fused`` on TPU.  ``devices`` bounds the mesh a
    ``sharded`` placement can build.
    """
    platform = jax.default_backend()
    return dict(platform=platform,
                devices=jax.device_count(),
                kernel_compiled=(platform == "tpu"))


def resolve_oracle_backend(backend: Optional[str] = None, *,
                           caps: Optional[dict] = None) -> str:
    """``None``/``"auto"`` -> the ``REPRO_ORACLE_BACKEND`` env var, then
    the platform default (``fused`` on TPU, ``einsum`` elsewhere)."""
    return _axes.resolve(_axes.AXES_BY_NAME["backend"], backend,
                         caps=caps if caps is not None else capabilities)


def resolve_engine(engine: Optional[str] = None) -> str:
    """``None``/``"auto"`` -> the ``REPRO_ROUND_ENGINE`` env var, then
    ``scan`` — the compiled engine is the production default on every
    platform; the python engine exists for debugging and parity."""
    return _axes.resolve(_axes.AXES_BY_NAME["engine"], engine)


def resolve_channel(channel: Optional[str] = None) -> str:
    """``None``/``"auto"`` -> the ``REPRO_CHANNEL`` env var, then
    ``identity`` — lossy channels are an explicit opt-in because they
    change the optimization trajectory, not just its cost.  Returns the
    *canonical name* (e.g. ``"topk:0.1"``); raises ``ValueError`` on an
    unknown channel (labelled with the env var when it came from one)."""
    return _axes.resolve(_axes.AXES_BY_NAME["channel"], channel)


def resolve_faults(faults: Optional[str] = None) -> str:
    """``"none"``/``None`` -> no faults (the default: fault injection is
    an explicit opt-in; unlike the other axes, the env var is consulted
    only for ``"auto"``, so a stray ``REPRO_FAULTS`` can never perturb a
    spec that didn't ask).  Returns the *canonical name* (idempotent
    under re-parse); raises ``ValueError`` on a malformed spec."""
    return _axes.resolve(_axes.AXES_BY_NAME["faults"], faults)


def resolve_placement(placement: Optional[str] = None) -> str:
    """``None``/``"auto"`` -> ``local``.  The sharded placement is an
    explicit opt-in: it needs a mesh and its ledger records at trace
    time, so silently switching on device count would change metering
    conventions under the caller."""
    return _axes.resolve(_axes.AXES_BY_NAME["placement"], placement)
