"""The single capability resolver for the four execution axes.

Every run in the repo is positioned on four orthogonal axes:

  * **placement** — where the machines live: ``local`` (m simulated
    machines, blocks stacked on a leading axis) or ``sharded`` (machine j
    = mesh slice j inside ``shard_map``);
  * **oracle backend** — how the per-machine GEMVs inside
    ``response``/``pgrad``/``phvp`` are computed: ``einsum`` (plain jnp
    contractions) or ``kernel`` (the MXU-tiled Pallas kernels);
  * **round engine** — how rounds are driven: ``python`` (per-call loop)
    or ``scan`` (one ``lax.scan``-compiled XLA program per segment);
  * **channel** — what the per-machine uploads cost on the wire:
    ``identity`` (exact f32) or a lossy transform (``fp16``/``bf16``/
    ``int8``/``topk[:rho]``), a round-indexed schedule of those
    (``sched:<ch>@<round>,...``) or a gap-adaptive spec
    (``gap:<ch0>,<ch>@<thr>,...``) — see ``core.channel``.

Historically the ``auto`` choices were resolved in three places
(``core/runtime.py``, ``experiments/sweep.py``, ``launch/dryrun.py``);
this module is now the only implementation.  ``repro.api.plan`` calls it
at *plan time*, so environment variables are consulted when a run is
planned, never at import time, and a resolved ``ExecutionPlan`` carries
concrete choices from then on.  ``core.runtime``/``core.engine`` keep
their historical ``resolve_*`` names as delegating shims.

This module must stay a leaf (stdlib + jax only): ``repro.core``'s shims
reach it at call time through the ``repro.api`` package (which imports
the whole facade), so any load-time dependency from here back into
``repro.core`` or ``repro.experiments`` would recreate the import cycle
the call-time indirection avoids.
"""
from __future__ import annotations

import os
from typing import Dict, Optional

import jax


ORACLE_BACKENDS = ("einsum", "kernel")
ENGINES = ("python", "scan")
PLACEMENTS = ("local", "sharded")
# Canonical list lives in repro.core.channel (the transform
# implementations); mirrored here so the resolver module stays a leaf at
# load time. tests/test_channel.py pins equality.
CHANNELS = ("identity", "fp16", "bf16", "int8", "topk", "sched", "gap")

BACKEND_ENV = "REPRO_ORACLE_BACKEND"
ENGINE_ENV = "REPRO_ROUND_ENGINE"
CHANNEL_ENV = "REPRO_CHANNEL"
FAULTS_ENV = "REPRO_FAULTS"


def capabilities() -> Dict[str, object]:
    """What the current process can actually execute.

    ``kernel_compiled`` — the Pallas kernels compile for TPU; everywhere
    else they run in interpret mode (correct but slow), which is why
    ``auto`` only picks ``kernel`` on TPU.  ``devices`` bounds the mesh a
    ``sharded`` placement can build.
    """
    platform = jax.default_backend()
    return dict(platform=platform,
                devices=jax.device_count(),
                kernel_compiled=(platform == "tpu"))


def _check(value: str, axis: str, options) -> str:
    if value not in options:
        raise ValueError(f"unknown {axis} {value!r}; expected one of "
                         f"{tuple(options) + ('auto',)}")
    return value


def resolve_oracle_backend(backend: Optional[str] = None, *,
                           caps: Optional[dict] = None) -> str:
    """``None``/``"auto"`` -> the ``REPRO_ORACLE_BACKEND`` env var, then
    the platform default (``kernel`` on TPU, ``einsum`` elsewhere)."""
    if backend in (None, "auto"):
        backend = os.environ.get(BACKEND_ENV, "").strip() or None
    if backend in (None, "auto"):
        caps = caps if caps is not None else capabilities()
        backend = "kernel" if caps["kernel_compiled"] else "einsum"
    return _check(backend, "oracle backend", ORACLE_BACKENDS)


def resolve_engine(engine: Optional[str] = None) -> str:
    """``None``/``"auto"`` -> the ``REPRO_ROUND_ENGINE`` env var, then
    ``scan`` — the compiled engine is the production default on every
    platform; the python engine exists for debugging and parity."""
    if engine in (None, "auto"):
        engine = os.environ.get(ENGINE_ENV, "").strip() or None
    if engine in (None, "auto"):
        engine = "scan"
    return _check(engine, "round engine", ENGINES)


def resolve_channel(channel: Optional[str] = None) -> str:
    """``None``/``"auto"`` -> the ``REPRO_CHANNEL`` env var, then
    ``identity`` — lossy channels are an explicit opt-in because they
    change the optimization trajectory, not just its cost.  Returns the
    *canonical name* (e.g. ``"topk:0.1"``); raises ``ValueError`` on an
    unknown channel."""
    from_env = False
    if channel in (None, "auto"):
        channel = os.environ.get(CHANNEL_ENV, "").strip() or None
        from_env = channel is not None
    if channel in (None, "auto"):
        return "identity"
    # call-time import (same pattern as the core shims in the other
    # direction): the transform catalogue lives with its implementations
    # in repro.core.channel, and importing repro.core at module-load
    # time would violate this module's leaf constraint.
    from ..core.channel import parse_channel
    try:
        return parse_channel(channel).name
    except ValueError as e:
        if from_env:
            # without this, a typo'd REPRO_CHANNEL surfaces as if the
            # caller had passed the bad name explicitly — on a spec that
            # never mentioned a channel at all.
            raise ValueError(
                f"{CHANNEL_ENV} environment variable: {e}") from None
        raise


def resolve_faults(faults: Optional[str] = None) -> str:
    """``"none"``/``None`` -> no faults (the default: fault injection is
    an explicit opt-in; unlike the other axes, the env var is consulted
    only for ``"auto"``, so a stray ``REPRO_FAULTS`` can never perturb a
    spec that didn't ask).  Returns the *canonical name* (idempotent
    under re-parse); raises ``ValueError`` on a malformed spec."""
    if faults == "auto":
        faults = os.environ.get(FAULTS_ENV, "").strip() or None
    if faults in (None, "auto", "", "none"):
        return "none"
    # call-time import for the same leaf-constraint reason as channels.
    from ..core.faults import parse_faults
    return parse_faults(faults).name


def resolve_placement(placement: Optional[str] = None) -> str:
    """``None``/``"auto"`` -> ``local``.  The sharded placement is an
    explicit opt-in: it needs a mesh and its ledger records at trace
    time, so silently switching on device count would change metering
    conventions under the caller."""
    if placement in (None, "auto"):
        placement = "local"
    return _check(placement, "placement", PLACEMENTS)
