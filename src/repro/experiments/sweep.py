"""Declarative sweep runner: instance grids x registered algorithms x eps.

A ``SweepSpec`` names an instance family, a parameter grid, the algorithms
to run, and the accuracy targets. ``run_sweep`` turns every grid cell
into a ``repro.api.RunSpec``, validates it through ``repro.api.plan``
(the single place ``auto`` backends/engines/placements resolve), executes
it through the ``CommLedger``-metered runtime — sequentially, or with
``execute="batch"`` through ``repro.api.execute_batch``, which ``vmap``s
same-shaped cells through one compiled program — measures rounds-to-eps
from the in-run per-round gap series f(w_k) - f*, and pairs each
measurement with the closed-form ``BoundReport`` the algorithm's registry
entry says must lower-bound it:

    non-incremental (F^{lam,L}), lam > 0   ->  Theorem 2
    non-incremental (F^{lam,L}), lam = 0   ->  Theorem 3
    incremental     (I^{lam,L})            ->  Theorem 4

On hard instances the record carries ``certified``: measured >= bound.
If eps was not reached within the round budget, the run still certifies
whenever budget >= bound (rounds-to-eps > budget >= bound).

Every record embeds its ``run_spec`` (the serialized RunSpec), so any
row of a ``docs/results/*.json`` report can be re-executed verbatim:

    repro.api.run(repro.api.RunSpec.from_dict(record["run_spec"]))

CLI:
    PYTHONPATH=src python -m repro.experiments.sweep --preset thm2-small
    PYTHONPATH=src python -m repro.experiments.sweep --preset all --out docs/results

Each preset writes ``docs/results/<preset>.json`` + ``<preset>.md`` and
refreshes ``docs/results/README.md``. Exit status is non-zero if any
certification fails — the harness is self-checking.
"""
from __future__ import annotations

import argparse
import dataclasses
import itertools
import sys
from typing import Dict, List, Optional, Sequence, Tuple, Union

from repro import api

from .instances import build_instance


# --------------------------------------------------------------------------
# Spec / record / result
# --------------------------------------------------------------------------

SCHEMA_VERSION = 5      # 5: per-record error field (graceful degradation)
                        # 4: wire_channel (adaptive sched:/gap: channels)
                        # 3: bit-level accounting + channel axis (PR 5)
                        # 2: records embed their run_spec (PR 4)

# Bits one exact f32 scalar occupies: the per-round wire floor of the
# incremental family (one scalar ReduceAll per stochastic round; scalars
# bypass the channel — see core.channel).
_SCALAR_BITS = 32

Grid = Union[Dict[str, Sequence], Sequence[Dict[str, object]]]


@dataclasses.dataclass(frozen=True)
class SweepSpec:
    name: str
    instance: str                     # key into INSTANCE_BUILDERS
    grid: Grid                        # dict of lists (product) or list of dicts
    algorithms: Tuple[str, ...]
    eps: Tuple[float, ...] = (1e-6,)
    eps_mode: str = "abs"             # "abs" | "rel" (x (f(0) - f*))
    max_rounds: int = 3000
    mode: str = "to_eps"              # "to_eps" | "fixed_rounds"
    fixed_rounds: int = 20
    note: str = ""

    def grid_points(self) -> List[Dict[str, object]]:
        if isinstance(self.grid, dict):
            keys = list(self.grid)
            return [dict(zip(keys, vals))
                    for vals in itertools.product(*(self.grid[k]
                                                    for k in keys))]
        return [dict(pt) for pt in self.grid]

    def cell_spec(self, point: Dict[str, object], algorithm: str,
                  max_rounds: Optional[int] = None,
                  backend: Optional[str] = None,
                  engine: Optional[str] = None,
                  channel: Optional[str] = None) -> api.RunSpec:
        """The RunSpec for one (grid point, algorithm) cell."""
        fixed = self.mode == "fixed_rounds"
        return api.RunSpec(
            instance=self.instance, instance_params=point,
            algorithm=algorithm,
            rounds=(self.fixed_rounds if fixed
                    else (max_rounds or self.max_rounds)),
            eps=(() if fixed else self.eps), eps_mode=self.eps_mode,
            measure=("none" if fixed else "gap"),
            backend=backend or "auto", engine=engine or "auto",
            channel=channel or "auto",
            tag=self.name)


@dataclasses.dataclass
class SweepRecord:
    instance_kind: str
    instance_label: str
    instance_params: Dict[str, float]
    hard: bool
    algorithm: str
    family: str
    incremental: bool
    accelerated: bool
    eps: Optional[float]              # as specified (rel or abs)
    eps_abs: Optional[float]
    measured_rounds: Optional[int]
    max_rounds: int
    bound_theorem: Optional[str]
    bound_rounds: Optional[float]
    ratio: Optional[float]            # measured / bound
    certified: Optional[bool]         # only meaningful on hard instances
    ledger_rounds: int
    bytes_per_round: float
    total_bytes: int
    op_counts: Dict[str, int]
    budget_ok: bool
    sample_model_bytes_per_round: float   # Arjevani-Shamir O(m d)/round
    oracle_backend: str = "einsum"        # compute path; never affects rounds
    engine: str = "scan"                  # round engine; never affects rounds
    run_spec: Optional[dict] = None       # the serialized RunSpec: any row
                                          # re-executes verbatim via
                                          # api.RunSpec.from_dict(...)
    # ---- bit-level accounting (schema 3) --------------------------------
    channel: str = "identity"             # wire model; identity leaves the
                                          # legacy stream bit-identical
    wire_channel: str = ""                # the channel actually driven on
                                          # the wire: == channel except for
                                          # gap: specs, which resolve to the
                                          # sched: schedule recorded here
                                          # (schema 4)
    bits_per_round: float = 0.0           # mean wire bits/round
    total_bits: int = 0                   # wire bits over the full budget
    bits_to_eps: Optional[int] = None     # wire bits of the first
                                          # measured_rounds rounds (exact,
                                          # via the ledger's round marks)
    bound_bits: Optional[float] = None    # the round bound x the per-round
                                          # payload floor at this channel's
                                          # precision (d elems for F^{lam,L},
                                          # one exact scalar for I^{lam,L})
    bits_certified: Optional[bool] = None # bits_to_eps >= bound_bits on
                                          # hard instances
    # ---- graceful degradation (schema 5) --------------------------------
    error: Optional[str] = None           # execution failure cause; an
                                          # errored cell still lands in the
                                          # report (partial results beat a
                                          # lost sweep) and fails the gate

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class SweepResult:
    spec: SweepSpec
    records: List[SweepRecord]
    command: str

    def summary(self) -> Dict[str, int]:
        applicable = [r for r in self.records if r.certified is not None]
        bits_app = [r for r in self.records if r.bits_certified is not None]
        return dict(
            records=len(self.records),
            certifiable=len(applicable),
            certified=sum(1 for r in applicable if r.certified),
            failed=sum(1 for r in applicable if not r.certified),
            bits_certifiable=len(bits_app),
            bits_certified=sum(1 for r in bits_app if r.bits_certified),
            bits_failed=sum(1 for r in bits_app if not r.bits_certified),
            errors=sum(1 for r in self.records if r.error is not None),
            # union, not sum: one record can fail several ways
            failed_records=sum(1 for r in self.records
                               if r.certified is False
                               or r.bits_certified is False
                               or r.error is not None),
        )

    def to_dict(self) -> dict:
        spec = dataclasses.asdict(self.spec)
        spec["grid"] = (self.spec.grid if isinstance(self.spec.grid, list)
                        else {k: list(v) for k, v in self.spec.grid.items()})
        return dict(schema_version=SCHEMA_VERSION, command=self.command,
                    spec=spec, summary=self.summary(),
                    records=[r.to_dict() for r in self.records])


# --------------------------------------------------------------------------
# Records from executed plans
# --------------------------------------------------------------------------

def _ledger_fields(result: api.RunResult, bundle) -> dict:
    led = result.ledger
    return dict(ledger_rounds=led.rounds,
                bytes_per_round=float(led.bytes_per_round()),
                total_bytes=int(led.total_bytes()),
                op_counts=led.op_counts(),
                budget_ok=bool(result.budget_ok),
                sample_model_bytes_per_round=float(
                    bundle.ctx.m * bundle.prob.d * 4),
                channel=result.channel,
                wire_channel=result.wire_channel or result.channel,
                bits_per_round=float(led.bits_per_round()),
                total_bits=int(led.total_bits()))


def _bound_bits(bound_rounds: Optional[float], channel: str,
                incremental: bool, d: int) -> Optional[float]:
    """The round bound scaled to wire bits: Theorem K rounds, each
    carrying at least the family's per-round payload floor at this
    channel's precision.  Non-incremental F^{lam,L} algorithms upload a
    full R^n / R^d vector per round (n >= d on every hard instance), so
    the floor is one d-element message through the channel — the
    ``d x precision`` scaling; incremental rounds carry one exact scalar
    (channels never touch scalar reductions), so the floor is 32 bits —
    a floor NO schedule can lower (the incremental bound is therefore
    invariant to every adaptive channel).

    For a round-scheduled channel the non-incremental floor is summed
    round by round — round k's payload floor is the stage active at k —
    which reduces exactly to ``bound_rounds * unit`` whenever the wire
    cost is round-invariant (fixed channels, one-entry schedules)."""
    if bound_rounds is None:
        return None
    from repro.core.channel import parse_channel
    if incremental:
        return float(bound_rounds) * _SCALAR_BITS
    ch = parse_channel(channel)
    if not getattr(ch, "scheduled", False):
        return float(bound_rounds) * ch.wire_bits(d, 4)
    whole = int(bound_rounds)
    total = float(sum(ch.wire_bits(d, 4, rnd=k) for k in range(whole)))
    frac = float(bound_rounds) - whole
    if frac > 0:
        total += frac * ch.wire_bits(d, 4, rnd=whole)
    return total


def _cell_records(spec: SweepSpec, pl: api.ExecutionPlan,
                  result: api.RunResult) -> List[SweepRecord]:
    """One record per eps threshold, all read off the cell's single
    metered run."""
    bundle, algo = pl.bundle, pl.algo
    base = dict(instance_kind=bundle.kind, instance_label=bundle.label,
                instance_params=dict(bundle.params), hard=bundle.hard,
                algorithm=algo.name, family=algo.family,
                incremental=algo.incremental, accelerated=algo.accelerated,
                oracle_backend=result.backend, engine=result.engine,
                max_rounds=pl.spec.rounds,
                run_spec=pl.spec.to_dict(),
                **_ledger_fields(result, bundle))

    if spec.mode == "fixed_rounds":
        return [SweepRecord(**base, eps=None, eps_abs=None,
                            measured_rounds=None, bound_theorem=None,
                            bound_rounds=None, ratio=None, certified=None)]

    records = []
    for eps in spec.eps:
        eps_abs = pl.eps_abs(eps)
        measured = result.measured_rounds(eps_abs)
        bound = pl.bound(eps_abs)
        bound_rounds = bound.rounds if bound else None
        ratio = (measured / bound_rounds
                 if measured and bound_rounds else None)
        bits_to_eps = (int(result.ledger.bits_through_round(measured))
                       if measured is not None else None)
        # bound against the channel actually driven on the wire (a gap:
        # spec prices as the sched: schedule it resolved to)
        bound_bits = _bound_bits(bound_rounds,
                                 result.wire_channel or result.channel,
                                 algo.incremental, bundle.prob.d)
        if not bundle.hard or bound_bits is None:
            bits_certified = None
        elif bits_to_eps is not None:
            bits_certified = bool(bits_to_eps >= bound_bits)
        else:
            # eps unreached: the run still certifies in bits whenever the
            # whole metered budget already exceeds the bound
            bits_certified = (True if base["total_bits"] >= bound_bits
                              else None)
        records.append(SweepRecord(
            **base, eps=eps, eps_abs=eps_abs, measured_rounds=measured,
            bound_theorem=bound.theorem if bound else None,
            bound_rounds=bound_rounds, ratio=ratio,
            certified=pl.certify(result, eps),
            bits_to_eps=bits_to_eps, bound_bits=bound_bits,
            bits_certified=bits_certified))
    return records


def _error_record(spec: SweepSpec, pl: api.ExecutionPlan,
                  exc: BaseException) -> SweepRecord:
    """A placeholder record for a cell whose execution failed: identity
    fields from the (already validated) plan, zeroed measurements, the
    failure cause in ``error``.  Lands in the report like any other
    record and trips the certification gate."""
    bundle, algo = pl.bundle, pl.algo
    return SweepRecord(
        instance_kind=bundle.kind, instance_label=bundle.label,
        instance_params=dict(bundle.params), hard=bundle.hard,
        algorithm=algo.name, family=algo.family,
        incremental=algo.incremental, accelerated=algo.accelerated,
        oracle_backend=pl.backend, engine=pl.engine,
        max_rounds=pl.spec.rounds, run_spec=pl.spec.to_dict(),
        eps=None, eps_abs=None, measured_rounds=None, bound_theorem=None,
        bound_rounds=None, ratio=None, certified=None,
        ledger_rounds=0, bytes_per_round=0.0, total_bytes=0,
        op_counts={}, budget_ok=False,
        sample_model_bytes_per_round=float(
            bundle.ctx.m * bundle.prob.d * 4),
        channel=pl.channel, error=f"{type(exc).__name__}: {exc}")


def run_sweep(spec: SweepSpec, max_rounds: Optional[int] = None,
              verbose: bool = False,
              backend: Optional[str] = None,
              engine: Optional[str] = None,
              channel: Optional[str] = None,
              execute: str = "sequential") -> SweepResult:
    """``backend``/``engine`` feed every cell's RunSpec ("auto" resolves
    through ``repro.api.plan`` — kernel on TPU / einsum elsewhere, scan
    by default). Both change local scheduling only; the CommLedger is
    bit-invariant to them (tests/test_ledger_invariance.py) and
    certification outcomes must agree (benchmarks/round_engine.py).

    ``channel`` feeds the fourth RunSpec axis: the wire model for
    per-machine uploads ("auto" resolves to identity).  Unlike the other
    axes it is *allowed* to change measurements — a lossy channel spends
    fewer bits per round and possibly more rounds — which is exactly the
    tradeoff ``benchmarks/comm_bits.py`` publishes; under the identity
    channel every legacy field is unchanged record-for-record.

    ``execute``: ``"sequential"`` runs one compiled program per cell;
    ``"batch"`` routes all cells through ``repro.api.execute_batch``,
    which groups same-shaped cells and ``vmap``s each group through ONE
    compiled program (``benchmarks/api_batch.py`` gates ledger/verdict
    identity between the two and publishes the speedup)."""
    if execute not in ("sequential", "batch"):
        raise ValueError(f"execute {execute!r}; expected 'sequential' or "
                         f"'batch'")

    def _plans():
        for point in spec.grid_points():
            bundle = build_instance(spec.instance, **point)
            for name in spec.algorithms:
                cell = spec.cell_spec(point, name, max_rounds=max_rounds,
                                      backend=backend, engine=engine,
                                      channel=channel)
                yield api.plan(cell, bundle=bundle)

    def _execute_one(pl):
        # graceful degradation: a failing cell yields its exception (turned
        # into an error record below) instead of losing the whole sweep
        try:
            return pl.execute()
        except Exception as e:        # noqa: BLE001 — recorded per-cell
            return e

    if execute == "batch":
        # grouping needs every cell up front — one compiled program per
        # same-shaped group is the whole point
        plans = list(_plans())
        try:
            executed = list(zip(plans, api.execute_batch(plans)))
        except Exception as e:        # noqa: BLE001 — degrade to per-cell
            print(f"[sweep] batch execution failed "
                  f"({type(e).__name__}: {e}); degrading to sequential "
                  f"per-cell execution", file=sys.stderr)
            executed = ((pl, _execute_one(pl)) for pl in plans)
    else:
        # one cell in memory at a time: execute as plans materialize
        executed = ((pl, _execute_one(pl)) for pl in _plans())

    records: List[SweepRecord] = []
    for pl, result in executed:
        if isinstance(result, BaseException):
            err = _error_record(spec, pl, result)
            pl.release()
            records.append(err)
            if verbose:
                print(f"  {err.instance_label} {err.algorithm:>9} "
                      f"ERROR {err.error}", file=sys.stderr)
            continue
        cell = _cell_records(spec, pl, result)
        pl.release()      # drop the cell's data copies before the next one
        records.extend(cell)
        if verbose:
            for r in cell:
                meas = (str(r.measured_rounds)
                        if r.measured_rounds is not None
                        else f">{r.max_rounds}")
                bnd = (f"{r.bound_rounds:.1f}" if r.bound_rounds
                       is not None else "-")
                cert = {True: "ok", False: "FAIL", None: "n/a"}[
                    r.certified]
                print(f"  {r.instance_label} {r.algorithm:>9} "
                      f"eps={r.eps} rounds={meas} bound={bnd} "
                      f"certified={cert}", file=sys.stderr)
    if spec.name in PRESETS:
        command = (f"PYTHONPATH=src python -m repro.experiments.sweep "
                   f"--preset {spec.name}")
    else:
        command = (f"repro.experiments.run_sweep(<ad-hoc SweepSpec "
                   f"{spec.name!r}>)")
    return SweepResult(spec=spec, records=records, command=command)


# --------------------------------------------------------------------------
# Presets
# --------------------------------------------------------------------------

PRESETS: Dict[str, SweepSpec] = {s.name: s for s in [
    SweepSpec(
        name="thm2-small", instance="thm2_chain",
        grid=dict(d=[96], kappa=[16.0, 64.0], lam=[0.5], m=[4]),
        algorithms=("dagd", "dgd", "disco_f"), eps=(1e-6,),
        max_rounds=2500,
        note="CPU-minutes Theorem-2 certification (acceptance preset)."),
    SweepSpec(
        name="thm2", instance="thm2_chain",
        grid=dict(d=[160], kappa=[16.0, 64.0, 256.0], lam=[0.5], m=[4]),
        algorithms=("dagd", "dgd", "disco_f"), eps=(1e-6,),
        max_rounds=3000,
        note="Theorem-2 tightness table (mirrors benchmarks/thm2_rounds)."),
    SweepSpec(
        name="thm3", instance="thm3_chain",
        grid=dict(d=[128], L=[1.0], m=[4]),
        algorithms=("dagd", "dgd", "prox_dagd"), eps=(1e-2, 1e-3),
        eps_mode="rel", max_rounds=4000,
        note="Theorem-3 smooth-convex certification; eps relative to "
             "f(0) - f* (sublinear regime)."),
    SweepSpec(
        name="thm4-small", instance="thm4_separable",
        grid=dict(n=[16], kappa=[64.0], lam=[0.5], m=[4]),
        algorithms=("dsvrg",), eps=(1e-4,), max_rounds=12000,
        note="Incremental-family certification, smallest n."),
    SweepSpec(
        name="thm4", instance="thm4_separable",
        grid=dict(n=[16, 32, 64], kappa=[64.0], lam=[0.5], m=[4]),
        algorithms=("dsvrg",), eps=(1e-4,), max_rounds=30000,
        note="Theorem-4 incremental family vs n (mirrors "
             "benchmarks/thm4_incremental)."),
    SweepSpec(
        name="m-invariance", instance="thm2_chain",
        grid=dict(d=[128], kappa=[64.0], lam=[0.5], m=[1, 2, 4, 8]),
        algorithms=("dagd",), eps=(1e-6,), max_rounds=1500,
        note="Round counts must be m-independent (the bounds hold for "
             "ANY m); across m the iterates differ only by ReduceAll "
             "summation order, so measured rounds may disagree by at "
             "most one eps-threshold quantization round "
             "(benchmarks/m_invariance.py gates the spread)."),
    SweepSpec(
        name="lasso", instance="lasso",
        grid=dict(n=[128], d=[256], m=[4], tau=[2e-3]),
        algorithms=("prox_dagd",), eps=(1e-4, 1e-6), max_rounds=2500,
        note="Composite workload: block-local prox, Thm-3 overlay as "
             "context (instance is not hard)."),
    SweepSpec(
        name="logistic", instance="logistic",
        grid=dict(n=[256], d=[96], m=[4], lam=[1e-2]),
        algorithms=("dagd", "dgd", "disco_f", "bcd"),
        eps=(1e-4, 1e-6), eps_mode="rel", max_rounds=2000,
        note="GLM workload; Thm-2 overlay as context (instance is not "
             "hard)."),
    SweepSpec(
        name="comm-cost", instance="random_ridge",
        grid=[dict(n=256, d=64, m=8), dict(n=64, d=256, m=8),
              dict(n=64, d=4096, m=8)],
        algorithms=("dagd",), mode="fixed_rounds", fixed_rounds=20,
        note="Feature-partition bytes/round (measured) vs the sample-"
             "partition O(m d)/round model of Arjevani-Shamir."),
]}


def main(argv: Optional[Sequence[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.experiments.sweep",
        description="Run a bound-certification sweep and write JSON + "
                    "Markdown reports.")
    parser.add_argument("--preset", action="append", required=True,
                        choices=sorted(PRESETS) + ["all"],
                        help="preset name (repeatable), or 'all'")
    parser.add_argument("--out", default=None,
                        help="output directory (default: docs/results at "
                             "the repo root)")
    parser.add_argument("--max-rounds", type=int, default=None,
                        help="override the preset round budget")
    parser.add_argument("--batch", action="store_true",
                        help="execute cells through repro.api."
                             "execute_batch (same-shaped cells vmap'd "
                             "through one compiled program)")
    parser.add_argument("--backend", default=None,
                        help="REMOVED: set repro.api.RunSpec(backend=...) "
                             "— e.g. run_sweep(spec, backend='kernel') — "
                             "instead; this flag now only errors")
    parser.add_argument("--engine", default=None,
                        help="REMOVED: set repro.api.RunSpec(engine=...) "
                             "— e.g. run_sweep(spec, engine='python') — "
                             "instead; this flag now only errors")
    parser.add_argument("--channel", default=None,
                        help="wire model for per-machine uploads "
                             "(identity/fp16/bf16/int8/topk[:rho], a "
                             "round schedule 'sched:<ch>@0,<ch>@<k>,...' "
                             "or a gap-adaptive 'gap:<ch>,<ch>@<thr>,"
                             "...'); feeds RunSpec(channel=...) for "
                             "every cell. Presets are published under "
                             "identity; a lossy channel legitimately "
                             "changes measured rounds and bits")
    parser.add_argument("--frontier", action="store_true",
                        help="run the bits-to-eps frontier search "
                             "(repro.experiments.frontier) over the "
                             "named presets instead of the plain sweep: "
                             "every cell is re-run under a candidate "
                             "set of fixed + scheduled + gap-adaptive "
                             "channels and the rounds-vs-bits frontier "
                             "is published to docs/results/"
                             "bits-frontier.{json,md}")
    parser.add_argument("--no-report", action="store_true",
                        help="run and print, but write nothing")
    parser.add_argument("-q", "--quiet", action="store_true")
    args = parser.parse_args(argv)

    for flag, field, value in (("--backend", "backend", args.backend),
                               ("--engine", "engine", args.engine)):
        if value is not None:
            parser.error(
                f"the {flag} flag was removed: set the axis on the "
                f"repro.api.RunSpec every sweep cell embeds — "
                f"RunSpec({field}={value!r}) — or pass "
                f"run_sweep(spec, {field}={value!r}) programmatically")

    from .report import default_results_dir, write_report

    names = sorted(PRESETS) if "all" in args.preset else args.preset
    out_dir = args.out or default_results_dir()

    if args.frontier:
        from . import frontier
        if "all" in args.preset:
            names = sorted(frontier.FRONTIER_EPS)
        try:
            cells = frontier.preset_cells(names,
                                          max_rounds=args.max_rounds)
        except ValueError as e:
            print(f"[frontier] {e}", file=sys.stderr)
            return 2
        doc = frontier.run_frontier(cells, backend=args.backend,
                                    engine=args.engine,
                                    verbose=not args.quiet)
        line = (f"[frontier] {len(doc['cells'])} cells, "
                f"{doc['summary']['certified']}/"
                f"{doc['summary']['certifiable']} points bit-certified")
        if not args.no_report:
            json_path, md_path = frontier.write_report(doc, out_dir)
            line += f" -> {json_path}, {md_path}"
        print(line)
        fails = frontier.gate_failures(doc)
        for f in fails:
            print(f"[frontier] GATE FAILED: {f}", file=sys.stderr)
        return 1 if fails else 0

    failed = 0
    for name in names:
        spec = PRESETS[name]
        if not args.quiet:
            print(f"[sweep] {name}: instance={spec.instance} "
                  f"algorithms={','.join(spec.algorithms)}",
                  file=sys.stderr)
        result = run_sweep(spec, max_rounds=args.max_rounds,
                           verbose=not args.quiet, backend=args.backend,
                           engine=args.engine, channel=args.channel,
                           execute="batch" if args.batch else "sequential")
        summ = result.summary()
        failed += summ["failed_records"]
        line = (f"[sweep] {name}: {summ['records']} records, "
                f"{summ['certified']}/{summ['certifiable']} certified, "
                f"{summ['bits_certified']}/{summ['bits_certifiable']} "
                f"bit-certified")
        if summ["errors"]:
            line += f", {summ['errors']} ERRORED"
        if not args.no_report:
            # the (possibly partial) report is written BEFORE the gate
            # exits non-zero — an errored cell never loses its siblings
            json_path, md_path = write_report(result, out_dir)
            line += f" -> {json_path}, {md_path}"
        print(line)
    if failed:
        print(f"[sweep] CERTIFICATION FAILED for {failed} record(s): a "
              f"measured round count or bit total fell below its lower "
              f"bound, or the cell errored (see per-record 'error')",
              file=sys.stderr)
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
