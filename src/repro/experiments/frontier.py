"""Bits-to-eps frontier: rounds-vs-wire-bits search across channels.

PR 5 made the ledger meter wire bits per round; adaptive channels
(``core.channel``'s ``sched:``/``gap:`` grammars) make the per-round
precision a *policy*.  This module searches the resulting frontier: for
each certification cell (instance x algorithm) it re-executes the run
under a candidate set of channels —

  * the fixed channels (identity / fp16 / int8 / topk:0.25),
  * round schedules derived from the identity run's measured
    rounds-to-eps (coarse-early, fine-late switch points),
  * gap-adaptive channels whose thresholds sit at the geometric midpoint
    of the identity run's start gap and the eps target

— and records, per eps threshold, the measured rounds, the exact wire
bits through that round (``CommLedger.bits_through_round``), the
schedule-aware bit lower bound (the certifying round bound priced at the
stage active in each bounded round), and the certification verdicts.
Points are Pareto-marked on the (rounds, bits) plane.

Two findings the published report must carry (``benchmarks/
bits_frontier`` gates both):

  * **adaptive helps** where the per-round payload is a compressible
    vector: on the Theorem-2 hard chain a coarse-early schedule reaches
    the same eps in the same rounds as the identity wire at a fraction
    of the bits — strictly beating the best *fixed* channel, whose
    precision must be paid in every round;
  * **adaptive cannot help** where the wire floor is scalar-dominated:
    the incremental family (Theorem 4, DSVRG) spends one exact 32-bit
    scalar per stochastic round — channels never touch scalars — so the
    certified bit floor ``bound_rounds x 32`` is *invariant to every
    candidate*, and no schedule beats the best fixed channel on
    measured bits either.  That negative result is the frontier-level
    echo of the paper's lower bound.

Every point embeds its ``RunSpec``; any row re-executes verbatim via
``repro.api.run(RunSpec.from_dict(point["run_spec"]))`` — the
differential test in ``tests/test_api.py`` pins this round trip
bit-identically.

Entry points: ``python -m repro.experiments.sweep --frontier --preset
<name>`` and ``python -m benchmarks.bits_frontier`` (report + gates).
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Sequence, Tuple

from repro import api

from .sweep import PRESETS, _bound_bits

FRONTIER_SCHEMA_VERSION = 1

COMMAND = "PYTHONPATH=src python -m benchmarks.bits_frontier"

# the fixed baselines every cell runs; adaptive candidates are derived
# per cell from the identity run (see _adaptive_candidates)
FIXED_CANDIDATES = ("identity", "fp16", "int8", "topk:0.25")

# presets the frontier knows how to sweep, with the eps grid the search
# uses (coarser thresholds than the certification presets: the frontier
# is about *where* each channel's noise floor bites, so the grid must
# straddle the floors)
FRONTIER_EPS: Dict[str, Tuple[float, ...]] = {
    "thm2-small": (1e-4, 1e-6),
    "thm4-small": (1e-4,),
    "lasso": (1e-4, 1e-6),
    "logistic": (1e-4, 1e-6),
}


# --------------------------------------------------------------------------
# Cells
# --------------------------------------------------------------------------

def preset_cells(names: Sequence[str],
                 max_rounds: Optional[int] = None) -> List[dict]:
    """One frontier cell per (grid point, algorithm) of each named
    preset.  Only the presets in ``FRONTIER_EPS`` are sweepable."""
    cells = []
    for name in names:
        if name not in FRONTIER_EPS:
            raise ValueError(
                f"preset {name!r} has no frontier configuration; "
                f"sweepable: {sorted(FRONTIER_EPS)}")
        spec = PRESETS[name]
        for point in spec.grid_points():
            for algo in spec.algorithms:
                cells.append(dict(
                    preset=name, instance=spec.instance,
                    instance_params=dict(point), algorithm=algo,
                    rounds=max_rounds or spec.max_rounds,
                    eps=FRONTIER_EPS[name], eps_mode=spec.eps_mode))
    return cells


# the CI smoke set: one Theorem-2 hard cell small enough for seconds
# (the adaptive-win row), the full Theorem-4 incremental cell (the
# no-adaptive-win row — it is already CPU-seconds), and the lasso
# workload (the >= 2x savings row).  Every report gate still applies.
QUICK_CELLS: List[dict] = [
    dict(preset="thm2-small", instance="thm2_chain",
         instance_params=dict(d=48, kappa=16.0, lam=0.5, m=4),
         algorithm="dagd", rounds=400, eps=(1e-2, 1e-4),
         eps_mode="abs"),
    dict(preset="thm4-small", instance="thm4_separable",
         instance_params=dict(n=16, kappa=64.0, lam=0.5, m=4),
         algorithm="dsvrg", rounds=12000, eps=(1e-4,), eps_mode="abs"),
    dict(preset="lasso", instance="lasso",
         instance_params=dict(n=128, d=256, m=4, tau=2e-3),
         algorithm="prox_dagd", rounds=2500, eps=(1e-4,),
         eps_mode="abs"),
]


# --------------------------------------------------------------------------
# Candidate derivation
# --------------------------------------------------------------------------

def _adaptive_candidates(identity_result, eps_abs_targets) -> List[str]:
    """Schedules and gap channels derived from the identity run.

    Switch points come from the finest eps the identity wire reached
    (coarse stage over the first half / three quarters of that run);
    gap thresholds sit at the geometric midpoint between the start gap
    and the eps target, so the channel refines roughly when half the
    log-scale progress is made.  Deterministic given the identity run —
    and every emitted point embeds its RunSpec, so the derivation never
    needs to be repeated to re-execute a row.
    """
    reached = [(e, identity_result.measured_rounds(e))
               for e in eps_abs_targets]
    reached = [(e, k) for e, k in reached if k is not None]
    if not reached:
        return []
    eps_target, rounds_to_eps = reached[-1]        # finest reached
    half = max(1, rounds_to_eps // 2)
    three_q = max(1, (3 * rounds_to_eps) // 4)
    cands = [f"sched:int8@0,fp16@{half}",
             f"sched:int8@0,identity@{half}",
             f"sched:fp16@0,identity@{three_q}"]
    gaps = identity_result.gaps
    if gaps is not None and len(gaps):
        g0 = max(float(gaps[0]), 1e-30)
        thr = math.sqrt(g0 * max(float(eps_target), 1e-30))
        if thr > 0 and math.isfinite(thr):
            cands += [f"gap:int8,fp16@{thr:g}",
                      f"gap:int8,identity@{thr:g}"]
    return cands


# --------------------------------------------------------------------------
# One cell
# --------------------------------------------------------------------------

def _run_point(cell: dict, channel: str, backend, engine) -> dict:
    spec = api.RunSpec(
        instance=cell["instance"],
        instance_params=cell["instance_params"],
        algorithm=cell["algorithm"], rounds=cell["rounds"],
        eps=cell["eps"], eps_mode=cell["eps_mode"], measure="gap",
        backend=backend or "auto", engine=engine or "auto",
        channel=channel, tag="bits-frontier")
    pl = api.plan(spec)
    res = pl.execute()
    wire = res.wire_channel or res.channel
    incremental = pl.algo.incremental
    hard = pl.bundle.hard
    d = pl.bundle.prob.d
    per_eps = []
    for e in cell["eps"]:
        eps_abs = pl.eps_abs(e)
        measured = res.measured_rounds(eps_abs)
        bound = pl.bound(eps_abs)
        bound_rounds = bound.rounds if bound else None
        bits = (int(res.ledger.bits_through_round(measured))
                if measured is not None else None)
        bound_bits = _bound_bits(bound_rounds, wire, incremental, d)
        if not hard or bound_bits is None:
            bits_certified = None
        elif bits is not None:
            bits_certified = bool(bits >= bound_bits)
        else:
            bits_certified = (True if res.ledger.total_bits() >= bound_bits
                              else None)
        per_eps.append(dict(
            eps=e, eps_abs=float(eps_abs), measured_rounds=measured,
            bits_to_eps=bits, bound_rounds=bound_rounds,
            bound_theorem=bound.theorem if bound else None,
            bound_bits=bound_bits, bits_certified=bits_certified,
            certified=pl.certify(res, e)))
    point = dict(
        channel=res.channel, wire_channel=wire,
        adaptive=res.channel.startswith(("sched:", "gap:")),
        bits_per_round=float(res.ledger.bits_per_round()),
        total_bits=int(res.ledger.total_bits()),
        per_eps=per_eps, run_spec=pl.spec.to_dict())
    point["_result"] = res          # stripped before serialization
    point["_hard"] = hard
    point["_incremental"] = incremental
    pl.release()
    return point


def _pareto_mark(points: List[dict], eps_index: int) -> None:
    """Non-dominated points on the (rounds, bits) plane at one eps."""
    coords = []
    for p in points:
        pe = p["per_eps"][eps_index]
        if pe["measured_rounds"] is not None and pe["bits_to_eps"]:
            coords.append((p, pe["measured_rounds"], pe["bits_to_eps"]))
    for p, r, b in coords:
        dominated = any(
            (r2 <= r and b2 <= b and (r2 < r or b2 < b))
            for _, r2, b2 in coords)
        p["per_eps"][eps_index]["pareto"] = not dominated
    for p in points:
        p["per_eps"][eps_index].setdefault("pareto", False)


def _error_point(cell: dict, channel: str, exc: BaseException) -> dict:
    """A placeholder point for a candidate whose run failed: no
    measurements, the cause in ``error``.  It renders as "not reached"
    in the tables and trips the error gate — a crashing candidate never
    loses the cell's other points."""
    return dict(
        channel=channel, wire_channel=channel,
        adaptive=channel.startswith(("sched:", "gap:")),
        bits_per_round=0.0, total_bits=0,
        per_eps=[dict(eps=e, eps_abs=None, measured_rounds=None,
                      bits_to_eps=None, bound_rounds=None,
                      bound_theorem=None, bound_bits=None,
                      bits_certified=None, certified=None)
                 for e in cell["eps"]],
        run_spec=None, error=f"{type(exc).__name__}: {exc}")


def run_cell(cell: dict, backend=None, engine=None,
             verbose: bool = False) -> dict:
    """Run one cell under the full candidate set; returns the cell
    record (points + per-eps summary).  A failing candidate degrades to
    an error point; only a failing *identity* run (no baseline to derive
    candidates from) raises — the caller records the whole cell as
    errored."""
    import sys

    identity = _run_point(cell, "identity", backend, engine)
    eps_abs = [pe["eps_abs"] for pe in identity["per_eps"]]
    candidates = [c for c in FIXED_CANDIDATES if c != "identity"]
    candidates += _adaptive_candidates(identity["_result"], eps_abs)
    points = [identity]
    for ch in candidates:
        try:
            points.append(_run_point(cell, ch, backend, engine))
        except Exception as e:        # noqa: BLE001 — degrade per-point
            points.append(_error_point(cell, ch, e))
    hard = identity.pop("_hard")
    incremental = identity.pop("_incremental")
    for p in points:
        p.pop("_result", None)
        p.pop("_hard", None)
        p.pop("_incremental", None)

    # savings vs the identity wire, per eps
    for p in points:
        for pe, ipe in zip(p["per_eps"], identity["per_eps"]):
            pe["savings_vs_identity"] = (
                round(ipe["bits_to_eps"] / pe["bits_to_eps"], 2)
                if pe["bits_to_eps"] and ipe["bits_to_eps"] else None)

    summary = []
    for i, e in enumerate(cell["eps"]):
        _pareto_mark(points, i)
        summary.append(_eps_summary(points, i, e, hard))
    record = dict(
        preset=cell["preset"], instance=cell["instance"],
        instance_params=dict(cell["instance_params"]),
        algorithm=cell["algorithm"], rounds=cell["rounds"],
        eps=list(cell["eps"]), eps_mode=cell["eps_mode"],
        hard=hard, incremental=incremental,
        points=points, per_eps_summary=summary)
    if verbose:
        for s in summary:
            print(f"[frontier] {cell['instance']} {cell['algorithm']:>9} "
                  f"eps={s['eps']:g}: best fixed "
                  f"{s['best_fixed'] or '-'} ({s['best_fixed_bits'] or '-'}"
                  f" bits), best adaptive {s['best_adaptive'] or '-'} "
                  f"({s['best_adaptive_bits'] or '-'} bits), "
                  f"adaptive_win={s['adaptive_win']}", file=sys.stderr)
    return record


def _usable(p: dict, i: int, hard: bool) -> Optional[int]:
    """bits_to_eps iff the point reached this eps (and, on a hard
    instance, kept both certifications)."""
    pe = p["per_eps"][i]
    if pe["bits_to_eps"] is None:
        return None
    if hard and (pe["certified"] is False or pe["bits_certified"] is False):
        return None
    return pe["bits_to_eps"]


def _eps_summary(points: List[dict], i: int, eps: float,
                 hard: bool) -> dict:
    fixed = [(p["channel"], _usable(p, i, hard))
             for p in points if not p["adaptive"]]
    adaptive = [(p["channel"], _usable(p, i, hard))
                for p in points if p["adaptive"]]
    fixed = [(c, b) for c, b in fixed if b is not None]
    adaptive = [(c, b) for c, b in adaptive if b is not None]
    best_fixed = min(fixed, key=lambda cb: cb[1]) if fixed else (None, None)
    best_adaptive = (min(adaptive, key=lambda cb: cb[1])
                     if adaptive else (None, None))
    bounds = {pe["bound_bits"] for p in points
              for pe in [p["per_eps"][i]] if pe["bound_bits"] is not None}
    return dict(
        eps=eps,
        best_fixed=best_fixed[0], best_fixed_bits=best_fixed[1],
        best_adaptive=best_adaptive[0],
        best_adaptive_bits=best_adaptive[1],
        adaptive_win=bool(best_adaptive[1] is not None
                          and best_fixed[1] is not None
                          and best_adaptive[1] < best_fixed[1]),
        # the certified floor is channel-invariant iff every candidate
        # prices the bound identically (always true for the scalar-
        # dominated incremental family)
        bound_bits_invariant=(len(bounds) <= 1))


# --------------------------------------------------------------------------
# The sweep
# --------------------------------------------------------------------------

def run_frontier(cells: List[dict], backend=None, engine=None,
                 verbose: bool = False) -> dict:
    """Run every cell and assemble the report document (the
    ``spec``/``summary``/``command`` envelope the results index
    expects).  A cell whose identity baseline fails is recorded under
    ``summary.errors`` — the (partial) report is still assembled and
    written; the error gate then fails it."""
    import sys

    import jax

    records, errors = [], []
    for c in cells:
        try:
            records.append(run_cell(c, backend=backend, engine=engine,
                                    verbose=verbose))
        except Exception as e:        # noqa: BLE001 — degrade per-cell
            cause = f"{type(e).__name__}: {e}"
            errors.append(dict(
                preset=c.get("preset"), instance=c["instance"],
                instance_params=dict(c["instance_params"]),
                algorithm=c["algorithm"], error=cause))
            print(f"[frontier] cell {c['instance']}/{c['algorithm']} "
                  f"FAILED ({cause}); continuing with remaining cells",
                  file=sys.stderr)
    errors += [dict(preset=r["preset"], instance=r["instance"],
                    instance_params=r["instance_params"],
                    algorithm=r["algorithm"], channel=p["channel"],
                    error=p["error"])
               for r in records for p in r["points"] if p.get("error")]
    all_pe = [pe for r in records for p in r["points"]
              for pe in p["per_eps"]]
    certifiable = [pe for pe in all_pe if pe["bits_certified"] is not None]
    hard_no_win = list(dict.fromkeys(
        f"{r['instance']}/{r['algorithm']}" for r in records
        if r["hard"] and not any(s["adaptive_win"]
                                 for s in r["per_eps_summary"])))
    hard_wins = list(dict.fromkeys(
        f"{r['instance']}/{r['algorithm']}" for r in records
        if r["hard"] and any(s["adaptive_win"]
                             for s in r["per_eps_summary"])))
    workload_best = {}
    for r in records:
        if r["hard"]:
            continue
        best = _workload_best_savings(r)
        if best is not None:
            workload_best[f"{r['instance']}/{r['algorithm']}"] = best
    return dict(
        schema_version=FRONTIER_SCHEMA_VERSION,
        command=COMMAND,
        spec=dict(name="bits-frontier",
                  instance=",".join(sorted({r["instance"]
                                            for r in records})),
                  presets=sorted({r["preset"] for r in records}),
                  fixed_candidates=list(FIXED_CANDIDATES)),
        platform=jax.default_backend(),
        summary=dict(
            records=len(all_pe),
            certifiable=len(certifiable),
            certified=sum(1 for pe in certifiable if pe["bits_certified"]),
            failed=sum(1 for pe in certifiable
                       if pe["bits_certified"] is False),
            errors=errors,
            hard_no_adaptive_win=hard_no_win,
            hard_adaptive_wins=hard_wins,
            workload_best_savings=workload_best),
        cells=records)


def _workload_best_savings(record: dict) -> Optional[float]:
    """Best bits savings vs identity among points whose *reach* matches
    the identity wire at every eps (the unchanged-verdict condition for
    workloads, where certification does not apply)."""
    identity = next(p for p in record["points"]
                    if p["channel"] == "identity")
    ident_reach = [pe["measured_rounds"] is not None
                   for pe in identity["per_eps"]]
    best = None
    for p in record["points"]:
        if [pe["measured_rounds"] is not None
                for pe in p["per_eps"]] != ident_reach:
            continue
        for pe in p["per_eps"]:
            s = pe["savings_vs_identity"]
            if s is not None and (best is None or s > best):
                best = s
    return best


# --------------------------------------------------------------------------
# Gates (shared by benchmarks/bits_frontier and the sweep CLI)
# --------------------------------------------------------------------------

def gate_failures(doc: dict) -> List[str]:
    """The acceptance gates: every point bit-certified against its
    (schedule-aware) floor; at least one hard cell where adaptivity
    provably cannot help; at least one workload with >= 2x total-bit
    reduction at unchanged verdict."""
    fails = []
    for err in doc["summary"].get("errors", []):
        where = f"{err['instance']}/{err['algorithm']}"
        if err.get("channel"):
            where += f" [{err['channel']}]"
        fails.append(f"cell ERRORED at {where}: {err['error']}")
    if doc["summary"]["failed"]:
        bad = [(r["instance"], r["algorithm"], p["channel"], pe["eps"])
               for r in doc["cells"] for p in r["points"]
               for pe in p["per_eps"] if pe["bits_certified"] is False]
        fails.append(f"bit-certification BELOW BOUND at {bad}")
    no_win = doc["summary"]["hard_no_adaptive_win"]
    if not no_win:
        fails.append("no hard instance exhibits the no-adaptive-win "
                     "negative result (expected the incremental family)")
    else:
        # the negative result must be floor-level, not just measured:
        # on those cells the certified bound must be channel-invariant
        for r in doc["cells"]:
            label = f"{r['instance']}/{r['algorithm']}"
            if label in no_win and r["incremental"]:
                if not all(s["bound_bits_invariant"]
                           for s in r["per_eps_summary"]):
                    fails.append(f"{label}: certified floor varies "
                                 f"across candidates")
    best = doc["summary"]["workload_best_savings"]
    if not any(v is not None and v >= 2.0 for v in best.values()):
        fails.append(f"no workload reached a 2x bit reduction at "
                     f"unchanged verdict (best: {best})")
    return fails


# --------------------------------------------------------------------------
# Reporting
# --------------------------------------------------------------------------

def render_markdown(doc: dict) -> str:
    lines = [
        "# Bits-to-eps frontier — `bits-frontier`",
        "",
        f"<!-- Generated by `{doc['command']}`. Do not edit by hand. -->",
        f"*Generated by* `{doc['command']}` *— regenerate instead of "
        "editing.*",
        "",
        f"- **Platform:** `{doc['platform']}`",
        f"- **Fixed candidates:** "
        + ", ".join(f"`{c}`" for c in doc["spec"]["fixed_candidates"])
        + "; adaptive `sched:`/`gap:` candidates derived per cell from "
        "the identity run",
        f"- **Bit certification:** {doc['summary']['certified']}/"
        f"{doc['summary']['certifiable']} hard points at or above their "
        "schedule-aware bit floor"
        + (f", **{doc['summary']['failed']} FAILED**"
           if doc['summary']['failed'] else ""),
        f"- **Adaptive wins (hard):** "
        + (", ".join(f"`{c}`"
                     for c in doc["summary"]["hard_adaptive_wins"])
           or "none"),
        f"- **Adaptive cannot help (hard):** "
        + (", ".join(f"`{c}`"
                     for c in doc["summary"]["hard_no_adaptive_win"])
           or "none"),
        "",
    ]
    errors = doc["summary"].get("errors", [])
    if errors:
        lines += [f"- **ERRORS ({len(errors)}):** this is a PARTIAL "
                  "report — the listed runs failed to execute", ""]
        for err in errors:
            where = f"{err['instance']}/{err['algorithm']}"
            if err.get("channel"):
                where += f" [{err['channel']}]"
            lines.append(f"  - `{where}`: {err['error']}")
        lines.append("")
    for r in doc["cells"]:
        params = ", ".join(f"{k}={v:g}"
                           for k, v in r["instance_params"].items())
        lines += [
            f"## `{r['algorithm']}` on `{r['instance']}` ({params})"
            + (" — hard" if r["hard"] else " — workload"),
            "",
            "| channel | wire channel | "
            + " | ".join(f"rounds @ {e:g} | bits @ {e:g} | ×fewer | "
                         f"frontier" for e in r["eps"]) + " |",
            "|---|---|" + "---|" * (4 * len(r["eps"])),
        ]
        for p in r["points"]:
            cells = []
            for pe in p["per_eps"]:
                if pe["measured_rounds"] is None:
                    cells += ["not reached", "—", "—", ""]
                else:
                    cells += [
                        str(pe["measured_rounds"]),
                        f"{pe['bits_to_eps']:,}",
                        (f"{pe['savings_vs_identity']:.2f}×"
                         if pe["savings_vs_identity"] else "—"),
                        "◆" if pe.get("pareto") else ""]
            wire = (f"`{p['wire_channel']}`"
                    if p["wire_channel"] != p["channel"] else "=")
            lines.append(f"| `{p['channel']}` | {wire} | "
                         + " | ".join(cells) + " |")
        for s in r["per_eps_summary"]:
            if s["best_fixed"] is None:
                continue
            verdict = ("**adaptive wins**" if s["adaptive_win"]
                       else "adaptive does not beat the best fixed "
                            "channel")
            lines.append("")
            lines.append(
                f"At eps={s['eps']:g}: best fixed `{s['best_fixed']}` "
                f"({s['best_fixed_bits']:,} bits), best adaptive "
                f"{'`' + s['best_adaptive'] + '`' if s['best_adaptive'] else '—'}"
                + (f" ({s['best_adaptive_bits']:,} bits)"
                   if s["best_adaptive_bits"] else "")
                + f" — {verdict}."
                + (" The certified bit floor is channel-invariant "
                   "across every candidate."
                   if s["bound_bits_invariant"] and r["incremental"]
                   else ""))
        lines.append("")
    lines += [
        "## Reading the frontier",
        "",
        "Each table re-executes one certification cell under every "
        "candidate channel. `×fewer` is the identity wire's bits-to-eps "
        "over the candidate's; `◆` marks the (rounds, bits) Pareto "
        "frontier at that eps. A `gap:` channel resolves to the "
        "`sched:` schedule in its *wire channel* column before "
        "executing (deterministic identity probe; see "
        "`docs/architecture.md`).",
        "",
        "The negative result is structural: incremental (Theorem-4) "
        "rounds carry one exact 32-bit scalar — channels never touch "
        "scalar reductions — so the certified floor "
        "`bound_rounds × 32` cannot be lowered by *any* schedule, and "
        "the measured frontier confirms no adaptive candidate beats "
        "the best fixed channel there. On vector-payload cells "
        "(Theorem 2, lasso, logistic) coarse-early schedules beat "
        "every fixed channel: the early rounds don't need the "
        "precision the late rounds do.",
        "",
        "Every point embeds its `run_spec`: re-execute any row "
        "verbatim with "
        "`repro.api.run(RunSpec.from_dict(point['run_spec']))`.",
        "",
    ]
    return "\n".join(lines)


def write_report(doc: dict, out_dir=None):
    """Write bits-frontier.{json,md} and refresh the results index."""
    import json
    import pathlib

    from .report import default_results_dir, refresh_index

    out = pathlib.Path(out_dir) if out_dir else default_results_dir()
    out.mkdir(parents=True, exist_ok=True)
    json_path = out / "bits-frontier.json"
    md_path = out / "bits-frontier.md"
    json_path.write_text(json.dumps(doc, indent=2) + "\n")
    md_path.write_text(render_markdown(doc))
    refresh_index(out)
    return json_path, md_path
