"""Instance builders: the paper's hard functions and real workloads,
packaged uniformly so the sweep runner can treat them interchangeably.

An ``InstanceBundle`` carries a concrete ERM problem, its feature
partition, the objective to measure suboptimality against (which may
include a separable regularizer psi), the reference optimum, and the
parameters a certifying bound needs (kappa, L, n, |w*|).

``hard=True`` marks the Theorem-2/3/4 constructions: on those, every
algorithm's measured rounds-to-eps is REQUIRED to sit above the closed-
form bound (the certification inequality). Real workloads (lasso,
logistic, random ridge) set ``hard=False``: the bounds are worst-case
over function classes, so on an easy instance measured < bound is
legitimate — the overlay is reported as context, not as a certificate.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax

from repro.core import (ChainInstance, ERMProblem, make_random_erm,
                        squared_loss)
from repro.core.algorithms import soft_threshold
from repro.core.partition import FeaturePartition, even_partition

from .registry import AlgoContext


@dataclasses.dataclass(frozen=True)
class InstanceBundle:
    kind: str
    hard: bool                      # certification inequality applies
    prob: ERMProblem
    part: FeaturePartition
    ctx: AlgoContext
    objective: Callable             # w (d,) -> scalar; includes psi if any
    fstar: Optional[float]          # None => fixed-rounds use only
    wstar_norm: Optional[float]
    params: Dict[str, float]        # what the bounds + report tables need
                                    # (may hold DERIVED values, e.g. the
                                    # thm4 kappa is the embedded ERM's own)
    build_params: Optional[Dict[str, object]] = None
                                    # the verbatim builder inputs, stamped
                                    # by build_instance; repro.api.plan
                                    # checks a supplied bundle against the
                                    # spec's instance_params with these

    @property
    def label(self) -> str:
        inner = ", ".join(f"{k}={v:g}" if isinstance(v, float) else f"{k}={v}"
                          for k, v in self.params.items())
        return f"{self.kind}({inner})"


# --------------------------------------------------------------------------
# Shared construction helpers
# --------------------------------------------------------------------------

def _make_context(prob: ERMProblem, part: FeaturePartition,
                  prox: Optional[Callable] = None) -> AlgoContext:
    """Derive every constant the registered adapters may ask for."""
    L = prob.smoothness_bound()
    sm = prob.loss.smoothness
    A = np.asarray(prob.A)
    block_L = np.array(
        [sm * np.linalg.norm(A[:, off:off + b], 2) ** 2 / prob.n + prob.lam
         for off, b in zip(part.offsets, part.block_sizes)]).reshape(-1, 1)
    L_max = float(np.max(np.sum(A ** 2, axis=1)) * sm + prob.lam)
    return AlgoContext(L=L, lam=prob.lam, L_max=L_max, block_L=block_L,
                       m=part.m, n=prob.n, d=prob.d,
                       loss_name=prob.loss.name, prox=prox)


def chain_erm(d: int, kappa: float, lam: float):
    """The Theorem-2 hard chain function embedded exactly as a ridge
    least-squares ERM (so the generic feature-partitioned algorithms run
    on it unchanged)."""
    ci = ChainInstance(d=d, kappa=kappa, lam=lam)
    B, y, lam_ = ci.as_erm_data()
    n = B.shape[0]
    prob = ERMProblem(A=jnp.asarray(B) * np.sqrt(n),
                      y=jnp.asarray(y) * np.sqrt(n),
                      loss=squared_loss(), lam=lam_)
    return ci, prob


def smooth_chain_erm(d: int, L: float):
    """The Theorem-3 hard function (Nesterov's smooth chain, lam = 0)
    embedded as an un-regularized least-squares ERM. Returns the problem
    and the closed-form minimizer w*(i) = 1 - i/(d+1)."""
    A = np.zeros((d, d))
    idx = np.arange(d)
    A[idx, idx] = 2.0
    A[idx[:-1], idx[:-1] + 1] = -1.0
    A[idx[:-1] + 1, idx[:-1]] = -1.0
    c = L / 4.0
    evals, evecs = np.linalg.eigh(A)
    B = (evecs * np.sqrt(np.clip(c * evals, 0, None))) @ evecs.T
    rhs = np.zeros(d)
    rhs[0] = c
    y = np.linalg.lstsq(B.T, rhs, rcond=None)[0]
    n = d
    prob = ERMProblem(A=jnp.asarray(B) * np.sqrt(n),
                      y=jnp.asarray(y) * np.sqrt(n),
                      loss=squared_loss(), lam=0.0)
    wstar = 1.0 - np.arange(1, d + 1) / (d + 1.0)
    return prob, jnp.asarray(wstar)


def _reference_solution(prob: ERMProblem, iters: int,
                        prox: Optional[Callable] = None) -> jnp.ndarray:
    """High-accuracy reference minimizer for workloads with no closed form:
    full-vector (non-distributed) FISTA / accelerated gradient, jitted."""
    L = prob.smoothness_bound()
    lam = prob.lam
    grad = jax.grad(prob.value) if prox is None else prob.gradient
    px = prox if prox is not None else (lambda w, s: w)
    if lam > 0:
        kap = L / lam
        beta = (math.sqrt(kap) - 1.0) / (math.sqrt(kap) + 1.0)

        def body(_, carry):
            x, y = carry
            x_new = px(y - grad(y) / L, 1.0 / L)
            return x_new, x_new + beta * (x_new - x)

        x0 = jnp.zeros((prob.d,))
        x, _ = jax.jit(lambda c: lax.fori_loop(0, iters, body, c))((x0, x0))
        return x

    def body(_, carry):
        x, y, t = carry
        x_new = px(y - grad(y) / L, 1.0 / L)
        t_new = 0.5 * (1.0 + jnp.sqrt(1.0 + 4.0 * t * t))
        y_new = x_new + ((t - 1.0) / t_new) * (x_new - x)
        return x_new, y_new, t_new

    x0 = jnp.zeros((prob.d,))
    x, _, _ = jax.jit(lambda c: lax.fori_loop(0, iters, body, c))(
        (x0, x0, jnp.asarray(1.0)))
    return x


# --------------------------------------------------------------------------
# Hard instances (certification applies)
# --------------------------------------------------------------------------

def build_thm2_chain(d: int = 160, kappa: float = 64.0, lam: float = 0.5,
                     m: int = 4) -> InstanceBundle:
    """Theorem-2 hard instance: lam-strongly-convex chain with condition
    number kappa; exact minimizer w*(i) = q^i."""
    ci, prob = chain_erm(d, kappa, lam)
    part = even_partition(prob.d, m)
    wstar = jnp.asarray(ci.w_star())
    fstar = float(prob.value(wstar))
    return InstanceBundle(
        kind="thm2_chain", hard=True, prob=prob, part=part,
        ctx=_make_context(prob, part), objective=prob.value,
        fstar=fstar, wstar_norm=float(jnp.linalg.norm(wstar)),
        params=dict(d=d, kappa=kappa, lam=lam, m=m, n=prob.n))


def build_thm3_chain(d: int = 128, L: float = 1.0, m: int = 4
                     ) -> InstanceBundle:
    """Theorem-3 hard instance: smooth convex chain, lam = 0."""
    prob, wstar = smooth_chain_erm(d, L)
    part = even_partition(d, m)
    fstar = float(prob.value(wstar))
    return InstanceBundle(
        kind="thm3_chain", hard=True, prob=prob, part=part,
        ctx=_make_context(prob, part), objective=prob.value,
        fstar=fstar, wstar_norm=float(jnp.linalg.norm(wstar)),
        params=dict(d=d, L=L, m=m, n=prob.n))


def build_thm4_separable(n: int = 32, kappa: float = 64.0, lam: float = 0.5,
                         m: int = 4) -> InstanceBundle:
    """Theorem-4 hard instance for the incremental family: the chain
    function on d = n coordinates, so the ERM has n components and each
    stochastic step touches one (Definition 3.2's model). The certifying
    kappa is the ERM's own condition number L/lam."""
    ci, prob = chain_erm(d=n, kappa=kappa, lam=lam)
    part = even_partition(prob.d, m)
    wstar = jnp.asarray(ci.w_star())
    fstar = float(prob.value(wstar))
    kappa_erm = prob.smoothness_bound() / prob.lam
    return InstanceBundle(
        kind="thm4_separable", hard=True, prob=prob, part=part,
        ctx=_make_context(prob, part), objective=prob.value,
        fstar=fstar, wstar_norm=float(jnp.linalg.norm(wstar)),
        params=dict(n=n, kappa=kappa_erm, lam=lam, m=m, d=prob.d))


# --------------------------------------------------------------------------
# Real workloads (bounds overlaid as context; hard=False)
# --------------------------------------------------------------------------

def build_lasso(n: int = 128, d: int = 256, m: int = 4, tau: float = 2e-3,
                k_true: int = 10, seed: int = 0,
                ref_iters: int = 20000) -> InstanceBundle:
    """Sparse-recovery lasso: F(w) = 1/2n |Aw - y|^2 + tau |w|_1. The prox
    is block-local, so the round budget stays one R^n ReduceAll."""
    rng = np.random.RandomState(seed)
    A = rng.randn(n, d) / np.sqrt(d)
    w_true = np.zeros(d)
    idx = rng.choice(d, k_true, replace=False)
    w_true[idx] = rng.randn(k_true) * 3
    y = A @ w_true + 0.01 * rng.randn(n)
    prob = ERMProblem(A=jnp.asarray(A), y=jnp.asarray(y),
                      loss=squared_loss(), lam=0.0)
    part = even_partition(d, m)
    prox = soft_threshold(tau)

    def objective(w):
        return prob.value(w) + tau * jnp.sum(jnp.abs(w))

    wref = _reference_solution(prob, ref_iters, prox=prox)
    return InstanceBundle(
        kind="lasso", hard=False, prob=prob, part=part,
        ctx=_make_context(prob, part, prox=prox), objective=objective,
        fstar=float(objective(wref)),
        wstar_norm=float(jnp.linalg.norm(wref)),
        params=dict(n=n, d=d, m=m, tau=tau, L=prob.smoothness_bound()))


def build_logistic(n: int = 256, d: int = 96, m: int = 4, lam: float = 1e-2,
                   seed: int = 0, ref_iters: int = 20000) -> InstanceBundle:
    """Ridge-regularized logistic regression on synthetic separable-ish
    data — the paper's motivating GLM workload."""
    prob = make_random_erm(n=n, d=d, loss="logistic", lam=lam, seed=seed)
    part = even_partition(d, m)
    wref = _reference_solution(prob, ref_iters)
    kappa = prob.smoothness_bound() / lam
    return InstanceBundle(
        kind="logistic", hard=False, prob=prob, part=part,
        ctx=_make_context(prob, part), objective=prob.value,
        fstar=float(prob.value(wref)),
        wstar_norm=float(jnp.linalg.norm(wref)),
        params=dict(n=n, d=d, m=m, lam=lam, kappa=kappa))


def build_random_ridge(n: int = 256, d: int = 64, m: int = 8,
                       lam: float = 1e-2, seed: int = 1) -> InstanceBundle:
    """Random ridge ERM for fixed-round communication costing (no fstar:
    used by the comm-cost sweeps, which never measure rounds-to-eps)."""
    prob = make_random_erm(n=n, d=d, loss="squared", lam=lam, seed=seed)
    part = even_partition(d, m)
    return InstanceBundle(
        kind="random_ridge", hard=False, prob=prob, part=part,
        ctx=_make_context(prob, part), objective=prob.value,
        fstar=None, wstar_norm=None,
        params=dict(n=n, d=d, m=m, lam=lam))


INSTANCE_BUILDERS: Dict[str, Callable[..., InstanceBundle]] = {
    "thm2_chain": build_thm2_chain,
    "thm3_chain": build_thm3_chain,
    "thm4_separable": build_thm4_separable,
    "lasso": build_lasso,
    "logistic": build_logistic,
    "random_ridge": build_random_ridge,
}


def build_instance(kind: str, **params) -> InstanceBundle:
    try:
        builder = INSTANCE_BUILDERS[kind]
    except KeyError:
        raise KeyError(f"unknown instance kind {kind!r}; known: "
                       f"{sorted(INSTANCE_BUILDERS)}") from None
    return dataclasses.replace(builder(**params), build_params=dict(params))
