"""Bound-certification experiment harness.

Ties the paper's three artifact layers into one reproducible story:

  * hard instances + closed-form lower bounds (``core.hard_instance``,
    ``core.bounds``) — the theory,
  * the metered Definition-1 communication model (``core.comm``,
    ``core.runtime``) — the measurement apparatus,
  * the algorithm family F^{lam,L} / I^{lam,L} (``core.algorithms``) —
    the subjects.

``registry``  — algorithms self-describe (family membership, incremental
                or not, how to derive their hyper-parameters from a
                problem); anything registered is certified automatically.
``instances`` — builders for the Theorem-2/3/4 hard instances and for
                real workloads (lasso, logistic) as ``InstanceBundle``s.
``sweep``     — declarative grid runner: instance grid x algorithms x eps,
                measured rounds/bytes against the matching BoundReport.
``report``    — renders a sweep into machine-readable JSON + generated
                Markdown under ``docs/results/``.

CLI:  PYTHONPATH=src python -m repro.experiments.sweep --preset thm2-small
"""
import importlib

from .registry import (ALGORITHM_REGISTRY, AlgoContext, AlgorithmSpec,
                       get_algorithm, register_algorithm)
from .instances import INSTANCE_BUILDERS, InstanceBundle, build_instance

# sweep/report exports are lazy (PEP 562) so `python -m
# repro.experiments.sweep` does not import the module twice (runpy warns).
_LAZY = {
    "PRESETS": ".sweep", "SweepRecord": ".sweep", "SweepResult": ".sweep",
    "SweepSpec": ".sweep", "run_sweep": ".sweep",
    "write_report": ".report", "default_results_dir": ".report",
}

__all__ = [
    "ALGORITHM_REGISTRY", "AlgoContext", "AlgorithmSpec",
    "get_algorithm", "register_algorithm",
    "INSTANCE_BUILDERS", "InstanceBundle", "build_instance",
    *sorted(_LAZY),
]


def __getattr__(name):
    if name in _LAZY:
        return getattr(importlib.import_module(_LAZY[name], __name__), name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
