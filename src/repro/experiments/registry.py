"""Algorithm registry: every optimizer self-describes its position in the
paper's taxonomy so the sweep runner can certify it automatically.

An ``AlgorithmSpec`` records what the theory needs to know:

  * ``family``       — "F^{lam,L}" (Definition 1's non-incremental family,
                       subject to Theorems 2/3) or "I^{lam,L}" (the
                       incremental family of Sec. 3.2, subject to Thm 4);
  * ``incremental``  — selects which lower bound certifies the algorithm;
  * ``accelerated``  — whether its known rate matches the bound order-wise
                       (the tightness witnesses: DAGD, DISCO-F);
  * ``make_kwargs``  — derives the algorithm's hyper-parameters from an
                       ``AlgoContext`` (smoothness constants, partition
                       shape, optional prox) so a sweep can run it on any
                       instance without per-algorithm glue;
  * ``program``      — the step-form registration: a
                       ``RoundProgram`` factory (``core.engine``) taking
                       the same kwargs as ``fn``, which is what the
                       scan-compiled round engine executes.  Registering
                       an algorithm without a step form is an error —
                       every sweep cell must be runnable under both
                       engines.

Registering a new algorithm here is all that is needed for it to appear in
every future sweep report with its measured rounds overlaid against the
correct theorem bound.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import numpy as np

from repro.core.algorithms import (bcd, bcd_program, dagd, dagd_program,
                                   dgd, dgd_program, disco_f,
                                   disco_f_program, dsvrg, dsvrg_program,
                                   prox_dagd, prox_dagd_program)

FAMILY_F = "F^{lam,L}"
FAMILY_I = "I^{lam,L}"


@dataclasses.dataclass(frozen=True)
class AlgoContext:
    """Everything an adapter may need to instantiate an algorithm on a
    concrete (problem, partition) pair. Built once per instance by
    ``instances.build_instance``."""

    L: float                      # global smoothness bound of f
    lam: float                    # ridge / strong-convexity modulus
    L_max: float                  # max per-component smoothness (Thm 4)
    block_L: np.ndarray           # (m, 1) per-block Lipschitz bounds (BCD)
    m: int
    n: int
    d: int
    loss_name: str
    prox: Optional[Callable] = None   # separable prox for composite runs


def _identity_prox(w, step):
    return w


@dataclasses.dataclass(frozen=True)
class AlgorithmSpec:
    name: str
    fn: Callable                  # fn(dist, rounds, history=True, **kwargs)
    family: str                   # FAMILY_F | FAMILY_I
    incremental: bool
    accelerated: bool
    description: str
    make_kwargs: Callable[[AlgoContext], dict]
    program: Callable             # program(dist, rounds, **kwargs)
                                  #   -> core.engine.RoundProgram
    local_only_kwargs: bool = False   # make_kwargs emits machine-stacked
                                      # arrays; repro.api.plan rejects
                                      # placement="sharded" for these

    @property
    def certifying_theorem(self) -> Tuple[str, str]:
        """(strongly-convex theorem, smooth-convex theorem) that lower-bound
        this algorithm's rounds. Incremental algorithms fall under Thm 4;
        everything in F^{lam,L} under Thm 2 (lam > 0) / Thm 3 (lam = 0)."""
        if self.incremental:
            return ("thm4", "thm4")
        return ("thm2", "thm3")


ALGORITHM_REGISTRY: Dict[str, AlgorithmSpec] = {}


def register_algorithm(spec: AlgorithmSpec) -> AlgorithmSpec:
    if spec.name in ALGORITHM_REGISTRY:
        raise ValueError(f"algorithm {spec.name!r} already registered")
    ALGORITHM_REGISTRY[spec.name] = spec
    return spec


def get_algorithm(name: str) -> AlgorithmSpec:
    try:
        return ALGORITHM_REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown algorithm {name!r}; registered: "
            f"{sorted(ALGORITHM_REGISTRY)}") from None


# --------------------------------------------------------------------------
# The six reference algorithms
# --------------------------------------------------------------------------

register_algorithm(AlgorithmSpec(
    name="dgd", fn=dgd, program=dgd_program,
    family=FAMILY_F, incremental=False,
    accelerated=False,
    description="Distributed gradient descent; O(kappa log(1/eps)) — the "
                "unaccelerated baseline the bound separates from.",
    make_kwargs=lambda ctx: dict(L=ctx.L, lam=ctx.lam),
))

register_algorithm(AlgorithmSpec(
    name="dagd", fn=dagd, program=dagd_program,
    family=FAMILY_F, incremental=False,
    accelerated=True,
    description="Distributed Nesterov AGD; O(sqrt(kappa) log(1/eps)) — "
                "matches Theorem 2 (and Theorem 3 when lam = 0).",
    make_kwargs=lambda ctx: dict(L=ctx.L, lam=ctx.lam),
))

register_algorithm(AlgorithmSpec(
    name="prox_dagd", fn=prox_dagd, program=prox_dagd_program,
    family=FAMILY_F, incremental=False,
    accelerated=True,
    description="FISTA with a block-local separable prox; same one-"
                "ReduceAll round budget as DAGD (identity prox when the "
                "instance declares none).",
    make_kwargs=lambda ctx: dict(L=ctx.L, lam=ctx.lam,
                                 prox=ctx.prox or _identity_prox),
))

register_algorithm(AlgorithmSpec(
    name="bcd", fn=bcd, program=bcd_program,
    family=FAMILY_F, incremental=False,
    accelerated=False,
    description="Synchronous parallel block coordinate descent "
                "(Richtarik-Takac ESO step); practitioner's baseline.",
    make_kwargs=lambda ctx: dict(block_L=ctx.block_L, m=ctx.m),
    local_only_kwargs=True,       # block_L comes back stacked (m, 1)
))

register_algorithm(AlgorithmSpec(
    name="disco_f", fn=disco_f, program=disco_f_program,
    family=FAMILY_F, incremental=False,
    accelerated=True,
    description="DISCO-F damped Newton via distributed CG; matches "
                "Theorem 2 on quadratics (second-order information does "
                "not beat the bound).",
    make_kwargs=lambda ctx: dict(
        L=ctx.L, lam=ctx.lam,
        newton_steps=1 if ctx.loss_name == "squared" else 4),
))

register_algorithm(AlgorithmSpec(
    name="dsvrg", fn=dsvrg, program=dsvrg_program,
    family=FAMILY_I, incremental=True,
    accelerated=False,
    description="Feature-partitioned SVRG (incremental family); each "
                "stochastic step is one scalar-ReduceAll round. Tightness "
                "vs Theorem 4 is open.",
    make_kwargs=lambda ctx: dict(L_max=ctx.L_max, lam=ctx.lam, seed=7,
                                 eta=1.0 / (4.0 * ctx.L_max)),
))
