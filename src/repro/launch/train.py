"""Training driver: ``python -m repro.launch.train --arch <id> [--smoke]``.

Runs the full loop on whatever devices exist (CPU for local runs; the
same code path drives a TPU slice when one is attached): data pipeline ->
sharded train_step -> metrics -> periodic checkpoint.
"""
from __future__ import annotations

import argparse

import jax

from ..checkpoint import save_checkpoint
from ..configs import get as get_arch
from ..data import TokenDataConfig, frame_stub, patch_stub, \
    synthetic_lm_batches
from ..metrics import MetricsLogger
from ..models import encdec as E
from ..models import transformer as T
from ..models.common import make_rules, sharding_ctx, unbox
from ..optim import OptConfig, adamw_init
from .mesh import make_host_mesh
from .steps import is_encdec, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--log-dir", default=None,
                    help="JSONL metrics directory")
    ap.add_argument("--microbatch", type=int, default=1)
    args = ap.parse_args()

    mod = get_arch(args.arch)
    cfg = mod.smoke() if args.smoke else mod.full()
    mesh = make_host_mesh()
    rules = make_rules(mesh_axes=mesh.axis_names)
    key = jax.random.PRNGKey(0)

    with mesh, sharding_ctx(mesh, rules):
        if is_encdec(cfg):
            params, _ = unbox(E.init_params(key, cfg))
        else:
            params, _ = unbox(T.init_params(key, cfg))
        opt = adamw_init(params)
        step_fn = jax.jit(make_train_step(cfg, OptConfig(lr=args.lr),
                                          microbatch=args.microbatch))
        data = synthetic_lm_batches(TokenDataConfig(
            vocab=cfg.vocab, seq_len=args.seq, batch=args.batch))
        logger = MetricsLogger(args.log_dir,
                               tokens_per_step=args.batch * args.seq)

        for step in range(1, args.steps + 1):
            batch = next(data)
            if is_encdec(cfg):
                batch = {"frames": frame_stub(args.batch, cfg.n_frames,
                                              cfg.d_model, seed=step,
                                              dtype=cfg.dtype),
                         "tokens": batch["tokens"],
                         "labels": batch["labels"]}
            elif cfg.prefix_lm:
                batch["prefix_embeds"] = patch_stub(
                    args.batch, cfg.n_prefix, cfg.d_model, seed=step,
                    dtype=cfg.dtype)
            logger.timer.start()
            params, opt, metrics = step_fn(params, opt, batch)
            jax.block_until_ready(metrics["loss"])
            dt = logger.timer.stop()
            logger.log(step, {"loss": metrics["loss"],
                              "grad_norm": metrics["grad_norm"]})
            if step % args.log_every == 0:
                print(logger.line(step, dt), flush=True)
            if args.ckpt_dir and step % args.ckpt_every == 0:
                path = save_checkpoint(args.ckpt_dir, step, params)
                print(f"  saved {path}")
        summ = logger.timer.summary()
        if summ:
            print(f"timing: mean {summ['mean_s']*1e3:.0f} ms/step, "
                  f"p95 {summ['p95_s']*1e3:.0f} ms "
                  f"({summ['steps_timed']} steps)")
        logger.close()


if __name__ == "__main__":
    main()
