"""Sharding spec derivation: logical axes -> PartitionSpec trees.

This is the launch-side companion of models/common.py. Everything the
step functions take or return gets a spec here:

  * params     — from the Boxed init tree's logical axes (via eval_shape,
                 so no memory is allocated to learn the shapes)
  * opt state  — m/v mirror the param specs; step is replicated
  * batches    — tokens/labels sharded on batch; stub embeddings likewise
  * caches     — by leaf name: k/v -> (batch, cache_seq, kv_heads, head_dim),
                 mamba state -> (batch, heads, state, none), stacked layer
                 dims replicated

The rules table (models.common.make_rules) is the experiment surface: the
baseline is the paper-faithful feature partition (model axis carries every
feature dim), FSDP overlays add data-axis parameter sharding for the
>=27B archs, and §Perf variants override individual entries.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.common import logical_to_spec, unbox


def abstract_params(init_fn, *args):
    """eval_shape an init function returning a Boxed tree ->
    (abstract param tree (SDS leaves), logical tree)."""
    boxed = jax.eval_shape(init_fn, *args)
    return unbox(boxed)


def param_specs(logical_tree, rules) -> Any:
    return jax.tree_util.tree_map(
        lambda names: logical_to_spec(names, rules), logical_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            n is None or isinstance(n, str) for n in x))


def opt_specs(pspecs) -> Dict[str, Any]:
    return {"m": pspecs, "v": pspecs, "step": P()}


def abstract_opt_state(params_abstract) -> Dict[str, Any]:
    f32 = lambda l: jax.ShapeDtypeStruct(l.shape, jnp.float32)
    return {
        "m": jax.tree_util.tree_map(f32, params_abstract),
        "v": jax.tree_util.tree_map(f32, params_abstract),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def batch_specs(batch_abstract, rules) -> Any:
    """tokens/labels (B,S) -> (batch, None); (B,S,D) stubs -> + embed."""
    def one(path, leaf):
        if leaf.ndim == 2:
            return logical_to_spec(("batch", "seq"), rules)
        if leaf.ndim == 3:
            return logical_to_spec(("batch", "seq", "embed"), rules)
        return P()
    return jax.tree_util.tree_map_with_path(one, batch_abstract)


_CACHE_LOGICAL = {
    # name -> logical axes for the UNSTACKED leaf; a leading stacked
    # "layers" dim is detected by ndim and prepended.
    "k": ("batch", "cache_seq", "kv_heads", "head_dim"),
    "v": ("batch", "cache_seq", "kv_heads", "head_dim"),
    "cross_k": ("batch", "cache_seq", "kv_heads", "head_dim"),
    "cross_v": ("batch", "cache_seq", "kv_heads", "head_dim"),
    "state": ("batch", "heads", "state", "head_dim"),
    "conv_x": ("batch", "conv", "heads"),
    "conv_B": ("batch", "conv", "state"),
    "conv_C": ("batch", "conv", "state"),
    "index": (),
}


def cache_specs(cache_abstract, rules) -> Any:
    def one(path, leaf):
        name = None
        for entry in reversed(path):
            if isinstance(entry, jax.tree_util.DictKey):
                name = str(entry.key)
                break
        logical = _CACHE_LOGICAL.get(name)
        if logical is None:
            return P()
        extra = leaf.ndim - len(logical)
        logical = ("layers",) * extra + logical
        return logical_to_spec(logical, rules)
    return jax.tree_util.tree_map_with_path(one, cache_abstract)


def sanitize_specs(abstract_tree, specs_tree, mesh: Mesh) -> Any:
    """Drop mesh-axis assignments whose size does not divide the dim.

    E.g. kv_heads=8 cannot shard over model=16 -> that dim falls back to
    replication (the faithful-but-wasteful baseline; §Perf explores
    alternatives like head-dim sharding / kv padding). For tuple
    assignments (("pod","data")) trailing axes are dropped one at a time
    until the product divides.
    """
    from ..models.common import sanitize_spec_for_shape

    def fix(leaf, spec):
        if not isinstance(spec, P):
            return spec
        return sanitize_spec_for_shape(spec, leaf.shape, mesh)

    return jax.tree_util.tree_map(fix, abstract_tree, specs_tree,
                                  is_leaf=lambda x: isinstance(x, P))


def shardings_from_specs(mesh: Mesh, specs) -> Any:
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))
