"""Step functions: train_step (AdamW) and serve_step (one-token decode).

Built per-architecture: decoder-only LMs (transformer.py) and enc-dec
(encdec.py) differ in their batch structure but expose the same step
signatures to the launcher/dry-run:

    train_step(params, opt_state, batch) -> (params, opt_state, metrics)
    serve_step(params, token, cache)     -> (next_token, cache)
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from ..models import transformer as T
from ..models import encdec as E
from ..optim import OptConfig, adamw_update

F32 = jnp.float32


def is_encdec(cfg) -> bool:
    return cfg.__class__.__name__ == "EncDecConfig"


def make_train_step(cfg, opt_cfg: OptConfig = OptConfig(),
                    microbatch: int = 1) -> Callable:
    """AdamW train step; ``microbatch`` > 1 enables gradient accumulation
    (splits the global batch into `microbatch` sequential micro-steps,
    dividing peak activation memory by ~microbatch at the cost of weight
    re-reads — a §Perf lever). The micro loop is FULLY UNROLLED so dry-run
    cost analysis counts every micro-step."""
    if is_encdec(cfg):
        def loss_fn(params, batch):
            loss, aux = E.loss(params, cfg, batch["frames"],
                               batch["tokens"], batch["labels"])
            return loss, aux
    else:
        def loss_fn(params, batch):
            loss, aux = T.lm_loss(params, cfg, batch["tokens"],
                                  batch["labels"],
                                  batch.get("prefix_embeds"))
            return loss, aux

    def train_step(params, opt_state, batch):
        if microbatch <= 1:
            (loss, aux), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            def micro(i):
                mb = jax.tree_util.tree_map(
                    lambda x: x.reshape((microbatch,
                                         x.shape[0] // microbatch)
                                        + x.shape[1:])[i], batch)
                return jax.value_and_grad(loss_fn, has_aux=True)(params,
                                                                 mb)
            (loss, aux), grads = micro(0)
            for i in range(1, microbatch):     # static unroll
                (l_i, a_i), g_i = micro(i)
                loss = loss + l_i
                aux = jax.tree_util.tree_map(lambda a, b: a + b, aux, a_i)
                grads = jax.tree_util.tree_map(lambda a, b: a + b, grads,
                                               g_i)
            inv = 1.0 / microbatch
            loss = loss * inv
            aux = jax.tree_util.tree_map(lambda a: a * inv, aux)
            grads = jax.tree_util.tree_map(lambda g: g * inv, grads)
        params, opt_state, gnorm = adamw_update(params, grads, opt_state,
                                                opt_cfg)
        metrics = {"loss": loss.astype(F32), "grad_norm": gnorm, **aux}
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg) -> Callable:
    """Forward-only pass (inference prefill) returning last-token logits."""
    if is_encdec(cfg):
        def prefill(params, batch):
            enc = E.encode(params, cfg, batch["frames"])
            hidden = E.decode_train(params, cfg, batch["tokens"], enc)
            from ..models import layers as L
            return L.logits(params["embed"], hidden[:, -1:, :])
    else:
        def prefill(params, batch):
            hidden, _ = T.forward(params, cfg, batch["tokens"],
                                  batch.get("prefix_embeds"))
            from ..models import layers as L
            return L.logits(params["embed"], hidden[:, -1:, :])
    return prefill


def make_serve_step(cfg, sample: str = "greedy") -> Callable:
    decode = E.decode_step if is_encdec(cfg) else T.decode_step

    def serve_step(params, token, cache):
        logits, cache = decode(params, cfg, token, cache)
        nxt = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)
        return nxt[:, None], cache

    return serve_step
