"""Production mesh builders.

Functions, not module-level constants: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS *before* first jax
init; tests see the default single device).

Production target: TPU v5e, 256 chips/pod.
  single-pod : (16, 16)    -> ("data", "model")
  multi-pod  : (2, 16, 16) -> ("pod", "data", "model")
"""
from __future__ import annotations

from typing import Optional, Tuple

import numpy as np
import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_parallel: Optional[int] = None) -> Mesh:
    """Small mesh over whatever devices exist (tests / examples)."""
    devs = jax.devices()
    n = len(devs)
    mp = model_parallel or n
    dp = n // mp
    return Mesh(np.array(devs).reshape(dp, mp), ("data", "model"))


def mesh_axis_names(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(mesh.axis_names)
