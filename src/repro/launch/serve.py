"""LM serving driver: batched greedy decoding against a KV/state cache.

``python -m repro.launch.serve --arch mamba2-780m --smoke --tokens 32``

Not to be confused with ``repro.serve`` (``python -m repro.serve``) —
the *certification* service, which continuously batches RunSpec
submissions into grouped certification runs.  This module serves tokens
from one model of the zoo; that one serves communication-bound verdicts
for many specs.  See ``examples/serve_lm.py`` vs
``docs/architecture.md#certification-service``.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import get as get_arch
from ..models import encdec as E
from ..models import transformer as T
from ..models.common import make_rules, sharding_ctx, unbox
from .mesh import make_host_mesh
from .steps import is_encdec, make_serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--prompt", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=256)
    args = ap.parse_args()

    mod = get_arch(args.arch)
    cfg = mod.smoke() if args.smoke else mod.full()
    mesh = make_host_mesh()
    rules = make_rules(mesh_axes=mesh.axis_names)
    key = jax.random.PRNGKey(0)

    with mesh, sharding_ctx(mesh, rules):
        if is_encdec(cfg):
            params, _ = unbox(E.init_params(key, cfg))
            cache = E.init_cache(cfg, args.batch, args.max_seq)
            tok = jnp.zeros((args.batch, 1), jnp.int32)
        else:
            params, _ = unbox(T.init_params(key, cfg))
            # production flow: prefill the prompt, then decode
            prompt = jax.random.randint(key, (args.batch, args.prompt),
                                        0, cfg.vocab)
            t0 = time.time()
            lg, cache = jax.jit(
                lambda p, t: T.prefill(p, cfg, t, max_seq=args.max_seq)
            )(params, prompt)
            jax.block_until_ready(lg)
            print(f"prefill({args.prompt} tokens) in "
                  f"{time.time()-t0:.2f}s")
            tok = jnp.argmax(lg[:, -1, :], -1).astype(jnp.int32)[:, None]
        serve = jax.jit(make_serve_step(cfg))
        seqs = [tok]
        t0 = time.time()
        for _ in range(args.tokens):
            tok, cache = serve(params, tok, cache)
            seqs.append(tok)
        jax.block_until_ready(tok)
        dt = time.time() - t0
        out = jnp.concatenate(seqs, axis=1)
        print(f"decoded {args.tokens} tokens x {args.batch} seqs in "
              f"{dt:.2f}s ({args.tokens/dt:.1f} tok/s/seq)")
        print("sequences:\n", out)


if __name__ == "__main__":
    main()
