"""Launch layer: production mesh, sharding spec derivation, train/serve
steps, the multi-pod dry-run driver, and runnable drivers."""
