"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) combo.

MUST set the placeholder device count before ANY other import (jax locks
the device count on first init).
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ruff: noqa: E402
import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from ..api import RunSpec, plan as api_plan
from ..configs import get as get_arch, canonical_ids
from ..configs import shapes as S
from ..core.comm import collective_bytes_from_hlo
from ..models import transformer as T
from ..models import encdec as E
from ..models.common import make_rules, sharding_ctx
from .mesh import make_production_mesh
from . import sharding as shd
from .steps import is_encdec, make_prefill_step, make_serve_step, \
    make_train_step

# TPU v5e hardware constants (per chip) — see DESIGN.md §Roofline.
PEAK_FLOPS = 197e12       # bf16
HBM_BW = 819e9            # bytes/s
ICI_BW = 50e9             # bytes/s per link (conservative 1-link figure)


def _mesh_devices(multi_pod: bool) -> int:
    return 512 if multi_pod else 256


def _legacy_axes_error(oracle_backend: Optional[str],
                       round_engine: Optional[str]) -> TypeError:
    """The removal error for the PR-4 legacy axis kwargs/flags, spelling
    out the exact RunSpec replacement for what was passed."""
    return TypeError(
        f"the oracle_backend/round_engine kwargs (and the matching "
        f"--oracle-backend/--round-engine flags) were removed: pass a "
        f"repro.api.RunSpec via axes= instead — "
        f"axes=RunSpec(backend={oracle_backend or 'auto'!r}, "
        f"engine={round_engine or 'auto'!r})")


def _abstract_state(cfg, shape_name: str, rules, mesh):
    """(abstract args, in_shardings specs) for the step that this input
    shape exercises."""
    arch_mod = _MOD_CACHE[cfg.name]
    key = jax.random.PRNGKey(0)
    if is_encdec(cfg):
        init = lambda k: E.init_params(k, cfg)
    else:
        init = lambda k: T.init_params(k, cfg)
    params_abs, logical = shd.abstract_params(init, key)
    pspecs = shd.sanitize_specs(params_abs,
                                shd.param_specs(logical, rules), mesh)
    shape = S.SHAPES[shape_name]
    specs_in = arch_mod.input_specs(shape_name, cfg)
    if shape.kind == "train":
        opt_abs = shd.abstract_opt_state(params_abs)
        ospecs = shd.opt_specs(pspecs)
        bspecs = shd.sanitize_specs(specs_in,
                                    shd.batch_specs(specs_in, rules), mesh)
        return ((params_abs, opt_abs, specs_in),
                (pspecs, ospecs, bspecs), "train")
    if shape.kind == "prefill":
        bspecs = shd.sanitize_specs(specs_in,
                                    shd.batch_specs(specs_in, rules), mesh)
        return ((params_abs, specs_in), (pspecs, bspecs), "prefill")
    # decode
    token = specs_in["token"]
    cache = specs_in["cache"]
    cspecs = shd.sanitize_specs(cache, shd.cache_specs(cache, rules), mesh)
    tspec = shd.sanitize_specs({"t": token},
                               shd.batch_specs({"t": token}, rules),
                               mesh)["t"]
    return ((params_abs, token, cache), (pspecs, tspec, cspecs), "decode")


_MOD_CACHE: Dict[str, Any] = {}


def _n_params(params_abs) -> int:
    return sum(int(l.size) for l in jax.tree_util.tree_leaves(params_abs))


def _active_params(cfg, n_total: int) -> int:
    """Rough active-parameter count for MoE FLOPs (6*N_active*D)."""
    if getattr(cfg, "moe", None) is None:
        return n_total
    moe_cfg = cfg.moe
    # expert params counted at top_k/n_experts utilization
    n_moe_layers = sum(1 for s in cfg.pattern if s.ffn == "moe") \
        * cfg.repeats + sum(1 for s in cfg.remainder if s.ffn == "moe")
    per_expert = 3 * moe_cfg.d_model * moe_cfg.d_ff \
        if moe_cfg.activation == "swiglu" else 2 * moe_cfg.d_model * moe_cfg.d_ff
    total_expert = n_moe_layers * moe_cfg.n_experts * per_expert
    active_expert = n_moe_layers * moe_cfg.top_k * per_expert
    return n_total - total_expert + active_expert


def dryrun_one(arch_id: str, shape_name: str, *, multi_pod: bool = False,
               rules_overrides: Optional[Dict[str, Any]] = None,
               variant: str = "baseline",
               cfg_overrides: Optional[Dict[str, Any]] = None,
               microbatch: int = 1,
               donate: bool = True,
               oracle_backend: Optional[str] = None,
               round_engine: Optional[str] = None,
               axes: Optional[RunSpec] = None,
               _apply_backend: Optional[bool] = None) -> Dict[str, Any]:
    """Lower + compile one combo on the production mesh; return the record.

    ``cfg_overrides``: dataclasses.replace kwargs applied to the arch
    config (e.g. {"remat": "dots", "cache_dtype": "f8"}); "moe.<field>"
    keys address the nested MoE config. ``microbatch``: gradient-
    accumulation factor for train shapes (peak-memory lever).

    ``axes``: a (typically resolution-only) ``repro.api.RunSpec`` naming
    the oracle backend / round engine this dry-run should cost.  The
    backend is resolved through ``repro.api.plan`` — the single
    capability resolver — and routed into the model zoo's
    ``cfg.use_pallas`` (kernel=True); the engine is stamped into the
    record so dry-run artifacts name the engine their companion sweeps
    executed under.  An explicit ``use_pallas`` in ``cfg_overrides``
    wins.  ``axes=None`` — or an engine-only spec (``backend="auto"``) —
    leaves the arch config untouched and stamps the plan-time engine.

    ``oracle_backend``/``round_engine`` are the removed PR-4 legacy
    kwargs: passing either raises a ``TypeError`` naming the equivalent
    ``axes=RunSpec(...)`` replacement.
    """
    if oracle_backend is not None or round_engine is not None:
        raise _legacy_axes_error(oracle_backend, round_engine)
    # canonical axes surface: an engine-only spec (backend="auto") leaves
    # the arch config untouched; name the backend to route it into
    # cfg.use_pallas
    apply_backend = (_apply_backend if _apply_backend is not None
                     else axes is not None and axes.backend != "auto")
    resolved = api_plan(axes if axes is not None else RunSpec())

    t0 = time.time()
    mod = get_arch(arch_id)
    if shape_name not in mod.SUPPORTED_SHAPES:
        return {"arch": arch_id, "shape": shape_name, "skipped": True,
                "reason": "unsupported shape (see DESIGN.md long_500k policy)"}
    cfg = mod.full()
    if cfg_overrides:
        moe_kw = {k.split(".", 1)[1]: v for k, v in cfg_overrides.items()
                  if k.startswith("moe.")}
        plain = {k: v for k, v in cfg_overrides.items()
                 if not k.startswith("moe.")}
        if "cache_dtype" in plain and isinstance(plain["cache_dtype"], str):
            plain["cache_dtype"] = {
                "f8": jnp.float8_e4m3fn, "int8": jnp.int8,
                "bf16": jnp.bfloat16}[plain["cache_dtype"]]
        if moe_kw and getattr(cfg, "moe", None) is not None:
            plain["moe"] = dataclasses.replace(cfg.moe, **moe_kw)
        cfg = dataclasses.replace(cfg, **plain)
    if apply_backend and \
            not (cfg_overrides and "use_pallas" in cfg_overrides):
        cfg = dataclasses.replace(
            cfg, use_pallas=resolved.backend in ("kernel", "fused"))
    mesh = make_production_mesh(multi_pod=multi_pod)
    if getattr(cfg, "moe", None) is not None and \
            not (cfg_overrides and "moe.groups" in cfg_overrides):
        # dispatch groups = data-parallel degree (routing stays shard-local)
        data_deg = 1
        for ax in ("pod", "data"):
            if ax in mesh.axis_names:
                data_deg *= mesh.shape[ax]
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, groups=data_deg))
    _MOD_CACHE[cfg.name] = mod
    fsdp = bool(getattr(mod, "FSDP", False))
    rules = make_rules(fsdp=fsdp, extra=rules_overrides,
                       mesh_axes=mesh.axis_names)

    def compile_variant(cfg_v):
        _MOD_CACHE[cfg_v.name] = mod
        with mesh, sharding_ctx(mesh, rules):
            args, in_specs, kind = _abstract_state(cfg_v, shape_name,
                                                   rules, mesh)
            in_sh = shd.shardings_from_specs(mesh, in_specs)
            if kind == "train":
                step = make_train_step(cfg_v, microbatch=microbatch)
                dn = (0, 1) if donate else ()
            elif kind == "prefill":
                step = make_prefill_step(cfg_v)
                dn = ()
            else:
                step = make_serve_step(cfg_v)
                dn = (2,) if donate else ()
            jitted = jax.jit(step, in_shardings=in_sh, donate_argnums=dn)
            t_l0 = time.time()
            lowered = jitted.lower(*args)
            t_lower = time.time() - t_l0
            t_c0 = time.time()
            compiled = lowered.compile()
            t_compile = time.time() - t_c0
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0] if cost else {}
        flops = float(cost.get("flops", 0.0))
        nbytes = float(cost.get("bytes accessed", 0.0))
        audit = collective_bytes_from_hlo(compiled.as_text())
        return dict(args=args, kind=kind, compiled=compiled, flops=flops,
                    bytes=nbytes, audit=audit, t_lower=t_lower,
                    t_compile=t_compile)

    # ---- two-point scan-cost correction ---------------------------------
    # XLA cost_analysis counts a while-loop (lax.scan) body ONCE, not
    # trip-count times. Compiling at scan_unroll=1 and scan_unroll=k gives
    # body cost (B - A)/(k - 1); the linear extrapolation
    #   corrected = A + (R - 1)/(k - 1) * (B - A)
    # recovers the full-R cost exactly for flops / bytes / collectives.
    R = cfg.repeats if not is_encdec(cfg) else cfg.n_enc_layers
    k = next((kk for kk in (2, 3, 4, 5) if R % kk == 0), None)
    va = compile_variant(cfg)
    if R > 1 and k:
        vb = compile_variant(dataclasses.replace(cfg, scan_unroll=k))
        scale = (R - 1) / (k - 1)
        flops = va["flops"] + scale * (vb["flops"] - va["flops"])
        bytes_accessed = va["bytes"] + scale * (vb["bytes"] - va["bytes"])
        coll_a, coll_b = va["audit"], vb["audit"]
        collective_total = coll_a.total_bytes + scale * (
            coll_b.total_bytes - coll_a.total_bytes)
        collective_by_op = {
            op: coll_a.bytes_by_op.get(op, 0) + scale * (
                coll_b.bytes_by_op.get(op, 0) - coll_a.bytes_by_op.get(op, 0))
            for op in set(coll_a.bytes_by_op) | set(coll_b.bytes_by_op)}
        corrected = True
    else:
        flops, bytes_accessed = va["flops"], va["bytes"]
        collective_total = va["audit"].total_bytes
        collective_by_op = va["audit"].bytes_by_op
        corrected = False
    kind = va["kind"]
    compiled = va["compiled"]
    audit = va["audit"]
    t_lower, t_compile = va["t_lower"], va["t_compile"]

    # ---- analyses -------------------------------------------------------
    try:
        mem = compiled.memory_analysis()
        mem_rec = {
            "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
            "generated_code_bytes": int(
                getattr(mem, "generated_code_size_in_bytes", 0)),
        }
    except Exception as e:  # pragma: no cover
        mem_rec = {"error": repr(e)}

    n_chips = _mesh_devices(multi_pod)
    # cost_analysis of the SPMD-partitioned module is PER-DEVICE
    # (calibrated in tests/test_dryrun_costing.py): no /n_chips here.
    compute_s = flops / PEAK_FLOPS
    memory_s = bytes_accessed / HBM_BW
    collective_s = collective_total / ICI_BW

    params_abs = va["args"][0]
    n_total = _n_params(params_abs)
    n_active = _active_params(cfg, n_total)
    shape = S.SHAPES[shape_name]
    if kind == "train":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6 * n_active * tokens
    elif kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2 * n_active * tokens
    else:
        tokens = shape.global_batch
        model_flops = 2 * n_active * tokens

    record = {
        "arch": arch_id, "shape": shape_name, "kind": kind,
        "variant": variant,
        "mesh": "2x16x16(pod,data,model)" if multi_pod
                else "16x16(data,model)",
        "n_chips": n_chips,
        "fsdp": fsdp,
        "use_pallas": bool(getattr(cfg, "use_pallas", False)),
        "round_engine": resolved.engine,
        "rules_overrides": rules_overrides or {},
        "n_params": n_total, "n_params_active": n_active,
        "hlo_flops": flops, "hlo_bytes": bytes_accessed,
        "scan_corrected": corrected,
        "raw_uncorrected": {"flops": va["flops"], "bytes": va["bytes"],
                            "collective_bytes": va["audit"].total_bytes},
        "collective_bytes": collective_total,
        "collective_by_op": collective_by_op,
        "collective_counts": audit.count_by_op,
        "memory": mem_rec,
        "roofline": {
            "compute_s": compute_s, "memory_s": memory_s,
            "collective_s": collective_s,
            "dominant": max(
                [("compute", compute_s), ("memory", memory_s),
                 ("collective", collective_s)], key=lambda kv: kv[1])[0],
        },
        "model_flops": model_flops,
        # model_flops is global; hlo flops are per-device
        "useful_flops_ratio": (model_flops / (flops * n_chips))
                              if flops else None,
        "t_lower_s": t_lower, "t_compile_s": t_compile,
        "t_total_s": time.time() - t0,
    }
    return record


def run_all(out_dir: str, multi_pod: bool, archs=None, shapes=None,
            force: bool = False, variant: str = "baseline",
            rules_overrides=None, cfg_overrides=None, microbatch: int = 1,
            oracle_backend: Optional[str] = None,
            round_engine: Optional[str] = None,
            axes: Optional[RunSpec] = None):
    os.makedirs(out_dir, exist_ok=True)
    if oracle_backend is not None or round_engine is not None:
        raise _legacy_axes_error(oracle_backend, round_engine)
    apply_backend = axes is not None and axes.backend != "auto"
    resolved = api_plan(axes if axes is not None else RunSpec())
    archs = archs or canonical_ids()
    shapes = shapes or list(S.SHAPES)
    results = []
    for arch in archs:
        for shape in shapes:
            tag = f"{arch}__{shape}__{'pod2' if multi_pod else 'pod1'}" \
                  f"__{variant}"
            if apply_backend:
                # the backend changes the compiled HLO like a variant
                # does; tag with the RESOLVED choice ("auto" is
                # platform-dependent and must not alias cache entries)
                tag += f"__ob-{resolved.backend}"
            path = os.path.join(out_dir, tag + ".json")
            if os.path.exists(path) and not force:
                print(f"[skip cached] {tag}")
                continue
            print(f"[dryrun] {tag} ...", flush=True)
            try:
                rec = dryrun_one(arch, shape, multi_pod=multi_pod,
                                 variant=variant,
                                 rules_overrides=rules_overrides,
                                 cfg_overrides=cfg_overrides,
                                 microbatch=microbatch,
                                 axes=axes,
                                 _apply_backend=apply_backend)
            except Exception:
                rec = {"arch": arch, "shape": shape, "failed": True,
                       "traceback": traceback.format_exc()}
                print(rec["traceback"])
            with open(path, "w") as f:
                json.dump(rec, f, indent=2)
            if rec.get("skipped"):
                print(f"  -> skipped ({rec['reason']})")
            elif rec.get("failed"):
                print("  -> FAILED")
            else:
                r = rec["roofline"]
                print(f"  -> ok: compute={r['compute_s']:.4f}s "
                      f"memory={r['memory_s']:.4f}s "
                      f"collective={r['collective_s']:.4f}s "
                      f"dominant={r['dominant']} "
                      f"(compile {rec['t_compile_s']:.0f}s)")
            results.append(rec)
    return results


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None,
                    help="single arch id (default: all)")
    ap.add_argument("--shape", default=None,
                    help="single input shape (default: all)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="reports/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--rules", default=None,
                    help="JSON dict of logical-axis rule overrides")
    ap.add_argument("--cfg", default=None,
                    help="JSON dict of config overrides (moe.* nested)")
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--oracle-backend", default=None,
                    help="REMOVED: build a repro.api.RunSpec and use "
                         "dryrun_one(axes=RunSpec(backend=...)); this "
                         "flag now only errors")
    ap.add_argument("--round-engine", default=None,
                    help="REMOVED: build a repro.api.RunSpec and use "
                         "dryrun_one(axes=RunSpec(engine=...)); this "
                         "flag now only errors")
    args = ap.parse_args()
    if args.oracle_backend is not None or args.round_engine is not None:
        ap.error(str(_legacy_axes_error(args.oracle_backend,
                                        args.round_engine)))
    overrides = json.loads(args.rules) if args.rules else None
    cfg_over = json.loads(args.cfg) if args.cfg else None
    archs = [args.arch] if args.arch else None
    shapes = [args.shape] if args.shape else None
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for mp in meshes:
        run_all(args.out, mp, archs, shapes, force=args.force,
                variant=args.variant, rules_overrides=overrides,
                cfg_overrides=cfg_over, microbatch=args.microbatch)


if __name__ == "__main__":
    main()
