"""Model-stack substrate: boxed params with logical axes, sharding rules.

The paper's partition-on-feature idea, made first-class: every parameter
and major activation is annotated with *logical* axis names; a rules table
maps logical axes onto mesh axes. "Feature" axes (embed/heads/mlp/experts/
vocab) map to the `model` mesh axis — that IS the paper's column partition
of the data/weight matrices; "sample" axes (batch) map to `data`/`pod`.
Changing the rules table is how the §Perf hillclimb re-shards the system
without touching model code.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


# --------------------------------------------------------------------------
# Logical axis rules
# --------------------------------------------------------------------------

# default rules: classic TP ("feature partition") + DP on batch
DEFAULT_RULES: Dict[str, Any] = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "experts": "model",
    "expert_mlp": None,
    "vocab": "model",
    "layers": None,
    "conv": None,
    "state": None,          # mamba dstate
    "cache_seq": None,
    "frames": None,
    "patches": None,
}

# FSDP overlay for models too big to replicate: param "embed"/"layers"
# dims additionally sharded over the data axes.
FSDP_OVERLAY: Dict[str, Any] = {
    "embed": ("pod", "data"),
}


def make_rules(fsdp: bool = False, extra: Optional[Dict[str, Any]] = None,
               mesh_axes: Sequence[str] = ("pod", "data", "model")):
    rules = dict(DEFAULT_RULES)
    if fsdp:
        rules.update(FSDP_OVERLAY)
    if extra:
        rules.update(extra)
    # drop mesh axes that don't exist on this mesh (e.g. "pod" single-pod)
    def _filter(v):
        if v is None:
            return None
        if isinstance(v, str):
            return v if v in mesh_axes else None
        vv = tuple(a for a in v if a in mesh_axes)
        return vv if vv else None
    return {k: _filter(v) for k, v in rules.items()}


class _Ctx(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: Optional[Dict[str, Any]] = None


_CTX = _Ctx()


@contextlib.contextmanager
def sharding_ctx(mesh: Optional[Mesh], rules: Optional[Dict[str, Any]]):
    """Activate (mesh, rules) for logical_constraint / make_specs."""
    prev = (_CTX.mesh, _CTX.rules)
    _CTX.mesh, _CTX.rules = mesh, rules
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = prev


def logical_to_spec(logical: Sequence[Optional[str]],
                    rules: Optional[Dict[str, Any]] = None) -> P:
    rules = rules if rules is not None else (_CTX.rules or {})
    used = set()
    parts = []
    for name in logical:
        axis = rules.get(name) if name is not None else None
        # a mesh axis may appear at most once in a PartitionSpec
        if axis is not None:
            flat = (axis,) if isinstance(axis, str) else tuple(axis)
            flat = tuple(a for a in flat if a not in used)
            used.update(flat)
            axis = (flat[0] if len(flat) == 1 else flat) if flat else None
        parts.append(axis)
    return P(*parts)


def sanitize_spec_for_shape(spec: P, shape: Sequence[int], mesh: Mesh) -> P:
    """Drop mesh-axis assignments whose size does not divide the dim
    (replication fallback — e.g. kv_heads=8 over model=16). For tuple
    assignments, trailing axes are dropped until the product divides."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    parts = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, entry in zip(shape, parts):
        if entry is None:
            out.append(None)
            continue
        axes = [entry] if isinstance(entry, str) else list(entry)
        while axes:
            prod = 1
            for a in axes:
                prod *= sizes[a]
            if dim % prod == 0:
                break
            axes.pop()
        out.append(None if not axes else
                   (axes[0] if len(axes) == 1 else tuple(axes)))
    return P(*out)


def logical_constraint(x, logical: Sequence[Optional[str]]):
    """with_sharding_constraint by logical names (no-op outside a ctx).
    Non-divisible assignments fall back to replication on that dim."""
    if _CTX.mesh is None or _CTX.rules is None:
        return x
    spec = logical_to_spec(logical, _CTX.rules)
    spec = sanitize_spec_for_shape(spec, x.shape, _CTX.mesh)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_CTX.mesh, spec))


# --------------------------------------------------------------------------
# Boxed params
# --------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass
class Boxed:
    """A parameter value together with its logical axis names."""
    value: Any
    logical: Tuple[Optional[str], ...]

    def tree_flatten(self):
        return (self.value,), self.logical

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux)


def box(value, *logical):
    assert value.ndim == len(logical), (value.shape, logical)
    return Boxed(value, tuple(logical))


def unbox(tree):
    """Boxed tree -> (params, logical tree)."""
    params = jax.tree_util.tree_map(
        lambda b: b.value, tree, is_leaf=lambda x: isinstance(x, Boxed))
    logical = jax.tree_util.tree_map(
        lambda b: b.logical, tree, is_leaf=lambda x: isinstance(x, Boxed))
    return params, logical


def specs_from_logical(logical_tree, rules) -> Any:
    """Logical-axes tree -> PartitionSpec tree (for in_shardings)."""
    return jax.tree_util.tree_map(
        lambda names: logical_to_spec(names, rules), logical_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            n is None or isinstance(n, str) for n in x))


# --------------------------------------------------------------------------
# Initializers
# --------------------------------------------------------------------------

def _normal(key, shape, dtype, scale):
    return (scale * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def dense_init(key, shape, logical, dtype=jnp.bfloat16, scale=None):
    """Fan-in scaled init, boxed with logical axes."""
    fan_in = shape[0] if len(shape) > 1 else shape[0]
    scale = scale if scale is not None else fan_in ** -0.5
    return box(_normal(key, shape, dtype, scale), *logical)


def zeros_init(shape, logical, dtype=jnp.bfloat16):
    return box(jnp.zeros(shape, dtype), *logical)


def ones_init(shape, logical, dtype=jnp.bfloat16):
    return box(jnp.ones(shape, dtype), *logical)


def abstract_like(boxed_tree):
    """Boxed tree -> boxed ShapeDtypeStructs (for eval_shape dry-runs)."""
    return jax.tree_util.tree_map(
        lambda b: Boxed(jax.ShapeDtypeStruct(b.value.shape, b.value.dtype),
                        b.logical),
        boxed_tree, is_leaf=lambda x: isinstance(x, Boxed))
