"""Mamba-2 (SSD — state-space duality) layer  [arXiv:2405.21060].

Chunked SSD algorithm (Dao & Gu 2024, Listing 1), TPU-adapted:
  * the sequence is split into chunks of Q tokens; within a chunk the
    quadratic "attention-like" form runs on the MXU (Q x Q matmuls),
    across chunks a linear recurrence carries the (H, P, N) state —
    implemented with an associative scan over chunk summaries, so the
    cross-chunk depth is log(S/Q) rather than S/Q.
  * heads H carry the logical axis "heads" -> `model` mesh axis: the
    feature partition of the paper applied to SSD state heads (states
    never cross heads, so the scan needs NO collectives — noted in
    DESIGN.md §Arch-applicability).

Decode is the O(1) recurrent update: state <- state * exp(a dt) + dt B x.

Simplifications vs the reference CUDA impl (documented): depthwise causal
conv width 4 on (x,B,C) as in the paper; no chunk-local Z normalization
beyond the final RMSNorm-gate; real-valued scalar A per head (Mamba-2's
choice).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from .common import box, dense_init, logical_constraint, ones_init, zeros_init
from .layers import init_rmsnorm, rmsnorm

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class Mamba2Config:
    d_model: int
    n_heads: int             # value heads (d_inner = n_heads * head_dim)
    head_dim: int            # P
    d_state: int             # N
    conv_width: int = 4
    chunk: int = 256         # Q
    n_groups: int = 1        # B/C groups (like GQA for SSM)


def d_inner(cfg: Mamba2Config) -> int:
    return cfg.n_heads * cfg.head_dim


def init_mamba2(key, cfg: Mamba2Config, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 8)
    d, di, n, g = cfg.d_model, d_inner(cfg), cfg.d_state, cfg.n_groups
    p = {
        # fused input projection: [z (gate), x, B, C, dt]
        "in_z": dense_init(ks[0], (d, di), ("embed", "heads"), dtype),
        "in_x": dense_init(ks[1], (d, di), ("embed", "heads"), dtype),
        "in_B": dense_init(ks[2], (d, g * n), ("embed", "state"), dtype),
        "in_C": dense_init(ks[3], (d, g * n), ("embed", "state"), dtype),
        "in_dt": dense_init(ks[4], (d, cfg.n_heads), ("embed", "heads"),
                            dtype),
        "dt_bias": zeros_init((cfg.n_heads,), ("heads",), F32),
        "A_log": box(jnp.log(jnp.linspace(1.0, 16.0, cfg.n_heads,
                                          dtype=F32)), "heads"),
        "D": ones_init((cfg.n_heads,), ("heads",), F32),
        "conv_x": zeros_init((cfg.conv_width, di), ("conv", "heads"), dtype),
        "conv_B": zeros_init((cfg.conv_width, g * n), ("conv", "state"),
                             dtype),
        "conv_C": zeros_init((cfg.conv_width, g * n), ("conv", "state"),
                             dtype),
        "norm": init_rmsnorm(di, dtype),
        "out": dense_init(ks[5], (di, d), ("heads", "embed"), dtype),
    }
    return p


def _causal_conv(x, w, state=None):
    """Depthwise causal conv. x: (B,S,C), w: (W,C). state: (B,W-1,C) for
    decode. Returns (y, new_state)."""
    wdt = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], wdt - 1, x.shape[2]), x.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
            for i in range(wdt))
    new_state = xp[:, -(wdt - 1):, :] if wdt > 1 else pad
    return jax.nn.silu(y), new_state


def _ssd_chunked(xh, dt, A, Bm, Cm, cfg: Mamba2Config):
    """Chunked SSD. xh: (B,S,H,P); dt: (B,S,H) (post-softplus, f32);
    A: (H,) negative reals (f32); Bm/Cm: (B,S,G,N). Returns (y, last_state).
    """
    b, s, h, pp = xh.shape
    g, n = Bm.shape[2], Bm.shape[3]
    q = min(cfg.chunk, s)
    if s % q:
        q = s
    c = s // q
    rep = h // g

    # reshape to chunks
    xc = xh.reshape(b, c, q, h, pp)
    dtc = dt.reshape(b, c, q, h)
    Bc = Bm.reshape(b, c, q, g, n)
    Cc = Cm.reshape(b, c, q, g, n)

    a_dt = A[None, None, None, :] * dtc                     # (B,C,Q,H) <= 0
    seg = jnp.cumsum(a_dt, axis=2)                          # within-chunk
    total = seg[:, :, -1, :]                                # (B,C,H)

    # expand B/C groups to heads once: head hh uses group hh // rep
    Bh = jnp.repeat(Bc.astype(F32), rep, axis=3)             # (B,C,Q,H,N)
    Ch = jnp.repeat(Cc.astype(F32), rep, axis=3)             # (B,C,Q,H,N)

    # ---- intra-chunk (quadratic, MXU): y_intra[t] =
    #   C_t . sum_{u<=t} exp(seg_t - seg_u) dt_u B_u x_u
    # L[t,u] = exp(seg_t - seg_u) for u <= t else 0.
    # Mask BEFORE the exp: the u > t half has seg_t - seg_u >= 0 and can
    # overflow; exp(inf)*0 would re-enter as NaN through the VJP of where.
    diff = seg[:, :, :, None, :] - seg[:, :, None, :, :]         # (B,C,Q,Q,H)
    causal = jnp.tril(jnp.ones((q, q), bool))
    diff = jnp.where(causal[None, None, :, :, None], diff, -1e30)
    L = jnp.exp(diff)
    scores = jnp.einsum("bcthn,bcuhn->bctuh", Ch, Bh,
                        preferred_element_type=F32)          # (B,C,Qt,Qu,H)
    M = scores * L * dtc[:, :, None, :, :]
    y_intra = jnp.einsum("bctuh,bcuhp->bcthp", M,
                         xc.astype(F32), preferred_element_type=F32)

    # ---- chunk summaries: state_c = sum_u exp(total - seg_u) dt_u B_u x_u
    decay_out = jnp.exp(total[:, :, None, :] - seg)          # (B,C,Q,H)
    BdtX = jnp.einsum("bcqhn,bcqh,bcqhp->bchnp", Bh,
                      dtc * decay_out, xc.astype(F32),
                      preferred_element_type=F32)            # (B,C,H,N,P)

    # ---- inter-chunk associative scan over (log-decay, state) pairs:
    # combining segments multiplies decays (adds logs) and carries
    # state_right + state_left * decay_right.
    def combine(left, right):
        dl, sl = left
        dr, sr = right
        return dl + dr, sr + sl * jnp.exp(dr)[..., None, None]

    cum_decay, cum_state = jax.lax.associative_scan(
        combine, (total, BdtX), axis=1)
    # state entering chunk i = cum_state[i-1]
    zero_state = jnp.zeros_like(cum_state[:, :1])
    prev_state = jnp.concatenate([zero_state, cum_state[:, :-1]], axis=1)

    # ---- inter-chunk contribution: y_inter[t] = C_t exp(seg_t) prev_state
    decay_in = jnp.exp(seg)                                  # (B,C,Q,H)
    y_inter = jnp.einsum("bcqhn,bchnp,bcqh->bcqhp", Ch, prev_state,
                         decay_in, preferred_element_type=F32)

    y = (y_intra + y_inter).reshape(b, s, h, pp)
    last_state = cum_state[:, -1]                            # (B,H,N,P)
    return y, last_state


def mamba2(p, x, cfg: Mamba2Config, use_pallas: bool = False,
           return_cache: bool = False):
    """Train/prefill forward. x: (B,S,D) -> (B,S,D).
    return_cache: also return the decode cache (final SSM state + conv
    tails) for prefill-then-decode serving."""
    b, s, d = x.shape
    z = jnp.einsum("bsd,de->bse", x, p["in_z"])
    xh = jnp.einsum("bsd,de->bse", x, p["in_x"])
    Bm = jnp.einsum("bsd,de->bse", x, p["in_B"])
    Cm = jnp.einsum("bsd,de->bse", x, p["in_C"])
    dt = jnp.einsum("bsd,dh->bsh", x, p["in_dt"],
                    preferred_element_type=F32)
    xh, cx = _causal_conv(xh, p["conv_x"])
    Bm, cb = _causal_conv(Bm, p["conv_B"])
    Cm, cc = _causal_conv(Cm, p["conv_C"])
    xh = logical_constraint(xh, ("batch", "seq", "heads"))

    h, pp, n, g = cfg.n_heads, cfg.head_dim, cfg.d_state, cfg.n_groups
    xh = xh.reshape(b, s, h, pp)
    Bm = Bm.reshape(b, s, g, n)
    Cm = Cm.reshape(b, s, g, n)
    dt = jax.nn.softplus(dt + p["dt_bias"][None, None, :])
    A = -jnp.exp(p["A_log"])

    y, last_state = _ssd_chunked(xh, dt, A, Bm, Cm, cfg)
    y = y + xh.astype(F32) * p["D"][None, None, :, None]
    y = y.reshape(b, s, h * pp).astype(x.dtype)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z.astype(F32)).astype(x.dtype))
    out = jnp.einsum("bse,ed->bsd", y, p["out"],
                     preferred_element_type=F32).astype(x.dtype)
    out = logical_constraint(out, ("batch", "seq", "embed"))
    if return_cache:
        return out, {"state": last_state, "conv_x": cx, "conv_B": cb,
                     "conv_C": cc}
    return out


# --------------------------------------------------------------------------
# Decode (recurrent O(1) step)
# --------------------------------------------------------------------------

def init_mamba_cache(batch: int, cfg: Mamba2Config, dtype=jnp.bfloat16,
                     abstract: bool = False):
    h, pp, n, g = cfg.n_heads, cfg.head_dim, cfg.d_state, cfg.n_groups
    di = h * pp
    shapes = {
        "state": ((batch, h, n, pp), F32),
        "conv_x": ((batch, cfg.conv_width - 1, di), dtype),
        "conv_B": ((batch, cfg.conv_width - 1, g * n), dtype),
        "conv_C": ((batch, cfg.conv_width - 1, g * n), dtype),
    }
    if abstract:
        return {k: jax.ShapeDtypeStruct(sh, dt) for k, (sh, dt)
                in shapes.items()}
    return {k: jnp.zeros(sh, dt) for k, (sh, dt) in shapes.items()}


def mamba2_decode(p, x, cfg: Mamba2Config, cache: Dict[str, Any]):
    """One-token step. x: (B,1,D); cache holds SSM state + conv tails."""
    b = x.shape[0]
    h, pp, n, g = cfg.n_heads, cfg.head_dim, cfg.d_state, cfg.n_groups
    z = jnp.einsum("bsd,de->bse", x, p["in_z"])
    xh = jnp.einsum("bsd,de->bse", x, p["in_x"])
    Bm = jnp.einsum("bsd,de->bse", x, p["in_B"])
    Cm = jnp.einsum("bsd,de->bse", x, p["in_C"])
    dt = jnp.einsum("bsd,dh->bsh", x, p["in_dt"],
                    preferred_element_type=F32)
    xh, cx = _causal_conv(xh, p["conv_x"], cache["conv_x"])
    Bm, cb = _causal_conv(Bm, p["conv_B"], cache["conv_B"])
    Cm, cc = _causal_conv(Cm, p["conv_C"], cache["conv_C"])

    xh = xh.reshape(b, h, pp)
    xh = logical_constraint(xh, ("batch", "heads", None))
    Bm = Bm.reshape(b, g, n)
    Cm = Cm.reshape(b, g, n)
    dt = jax.nn.softplus(dt[:, 0] + p["dt_bias"][None, :])    # (B,H)
    dt = logical_constraint(dt, ("batch", "heads"))
    A = -jnp.exp(p["A_log"])                                  # (H,)
    rep = h // g
    Bh = jnp.repeat(Bm, rep, axis=1).astype(F32)              # (B,H,N)
    Ch = jnp.repeat(Cm, rep, axis=1).astype(F32)
    # keep the head axis model-sharded through the state update — without
    # these constraints XLA loses `heads` on the (B,H,N)/(B,H) repeats and
    # all-gathers the (B,H,N,P) state per layer per token (§Perf pair 2)
    Bh = logical_constraint(Bh, ("batch", "heads", None))
    Ch = logical_constraint(Ch, ("batch", "heads", None))

    decay = jnp.exp(A[None, :] * dt)                          # (B,H)
    upd = jnp.einsum("bhn,bh,bhp->bhnp", Bh, dt, xh.astype(F32))
    upd = logical_constraint(upd, ("batch", "heads", None, None))
    state = cache["state"] * decay[..., None, None] + upd
    state = logical_constraint(state, ("batch", "heads", None, None))
    y = jnp.einsum("bhn,bhnp->bhp", Ch, state)                # (B,H,P)
    y = y + xh.astype(F32) * p["D"][None, :, None]
    y = y.reshape(b, 1, h * pp).astype(x.dtype)
    y = rmsnorm(p["norm"], y * jax.nn.silu(z.astype(F32)).astype(x.dtype))
    out = jnp.einsum("bse,ed->bsd", y, p["out"],
                     preferred_element_type=F32).astype(x.dtype)
    new_cache = {"state": state, "conv_x": cx, "conv_B": cb, "conv_C": cc}
    return logical_constraint(out, ("batch", "seq", "embed")), new_cache
