"""Mixture-of-Experts layer: top-k router + capacity-bounded dispatch.

Dispatch design (TPU/SPMD-native):
  * tokens are processed in ``groups`` independent dispatch groups that
    line up with the data-parallel mesh axis — routing never crosses a
    data shard (the all-to-all happens only across the expert/model axis),
    exactly the communication pattern of expert parallelism.
  * within a group, the position of each (token, choice) inside its
    expert's capacity buffer comes from a stable argsort over expert ids
    (O(T log T)) — NOT a cumulative one-hot sum: a (T*k, E) cumsum lowers
    to a quadratic-cost reduce-window that both bloats real HBM traffic
    and poisons HLO cost analysis.
  * capacity-dropped tokens fall into a sentinel row; the combine gathers
    each choice's slot and weights by the renormalized router probs.

Expert weights carry the logical axis "experts" -> `model` mesh axis
(expert parallelism = the paper's feature partition applied to the expert
dimension). Router: softmax -> top-k -> renormalize over the selected k
(granite/llama4 convention). Switch-style load-balance aux loss returned
for the train objective.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from .common import dense_init, logical_constraint
from ..kernels import ops as kops

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    d_model: int
    d_ff: int                 # per-expert hidden
    n_experts: int
    top_k: int
    capacity_factor: float = 1.25
    activation: str = "swiglu"
    groups: int = 1           # dispatch groups (= data-parallel degree)


def init_moe(key, cfg: MoEConfig, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 4)
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    p = {
        "router": dense_init(ks[0], (d, e), ("embed", "experts"), dtype,
                             scale=d ** -0.5),
        "wo": dense_init(ks[3], (e, f, d), ("experts", "expert_mlp",
                                            "embed"), dtype),
    }
    if cfg.activation == "swiglu":
        p["wi_gate"] = dense_init(ks[1], (e, d, f),
                                  ("experts", "embed", "expert_mlp"), dtype)
        p["wi_up"] = dense_init(ks[2], (e, d, f),
                                ("experts", "embed", "expert_mlp"), dtype)
    else:
        p["wi"] = dense_init(ks[1], (e, d, f),
                             ("experts", "embed", "expert_mlp"), dtype)
    return p


def _capacity(tokens: int, cfg: MoEConfig) -> int:
    cap = int(tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(8, -(-cap // 8) * 8)  # multiple of 8, at least 8


def _dispatch_group(xt, top_e, cap: int, e: int, k: int):
    """One dispatch group. xt: (T,D); top_e: (T,k) expert ids.
    Returns (buckets (E,cap,D), slot (T*k,), keep (T*k,))."""
    t, d = xt.shape
    flat_e = top_e.reshape(-1)                                # (T*k,)
    # stable sort by expert id; position within expert = sorted rank -
    # expert segment start (first-come-first-served in token order).
    order = jnp.argsort(flat_e, stable=True)
    counts = jnp.zeros((e,), jnp.int32).at[flat_e].add(1)
    starts = jnp.cumsum(counts) - counts                      # (E,) cheap
    pos_sorted = jnp.arange(t * k, dtype=jnp.int32) - starts[flat_e[order]]
    pos = jnp.zeros((t * k,), jnp.int32).at[order].set(pos_sorted)
    keep = pos < cap
    slot = flat_e * cap + jnp.where(keep, pos, 0)
    # gather tokens into buckets (+1 sentinel row absorbs drops)
    src = jnp.repeat(jnp.arange(t), k)
    buckets = jnp.zeros((e * cap + 1, d), xt.dtype)
    buckets = buckets.at[jnp.where(keep, slot, e * cap)].add(
        jnp.where(keep[:, None], xt[src], 0).astype(xt.dtype))
    return buckets[:-1].reshape(e, cap, d), slot, keep


def moe(p, x, cfg: MoEConfig, use_pallas: bool = False
        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B,S,D) -> (y, aux_loss)."""
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    g = cfg.groups if t % cfg.groups == 0 else 1
    tg = t // g
    cap = _capacity(tg, cfg)

    xt = x.reshape(g, tg, d)
    xt = logical_constraint(xt, ("batch", None, "embed"))

    router_logits = jnp.einsum("gtd,de->gte", xt, p["router"],
                               preferred_element_type=F32)
    probs = jax.nn.softmax(router_logits, axis=-1)            # (G,T,E) f32
    top_w, top_e = jax.lax.top_k(probs, k)                    # (G,T,k)
    top_w = top_w / jnp.maximum(jnp.sum(top_w, -1, keepdims=True), 1e-9)

    # -- load-balance aux (Switch): E * sum_e f_e * P_e, averaged over groups
    occupancy = jax.vmap(
        lambda te: jnp.zeros((e,), F32).at[te.reshape(-1)].add(1.0)
    )(top_e) / (tg * k)
    aux = e * jnp.mean(jnp.sum(occupancy * jnp.mean(probs, axis=1), -1))

    buckets, slot, keep = jax.vmap(
        lambda xg, teg: _dispatch_group(xg, teg, cap, e, k))(xt, top_e)
    # buckets: (G, E, cap, D) — experts sharded on model, groups on data
    buckets = logical_constraint(buckets, ("batch", "experts", None,
                                           "embed"))

    # -- expert FFN (batched over groups x experts)
    if cfg.activation == "swiglu":
        h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", buckets, p["wi_gate"],
                                   preferred_element_type=F32)) * \
            jnp.einsum("gecd,edf->gecf", buckets, p["wi_up"],
                       preferred_element_type=F32)
    else:
        h = jax.nn.gelu(jnp.einsum("gecd,edf->gecf", buckets, p["wi"],
                                   preferred_element_type=F32))
    h = logical_constraint(h.astype(x.dtype),
                           ("batch", "experts", None, "expert_mlp"))
    eo = jnp.einsum("gecf,efd->gecd", h, p["wo"],
                    preferred_element_type=F32).astype(x.dtype)
    eo = eo.reshape(g, e * cap, d)

    # -- combine: each (token, choice) reads its slot
    gathered = jax.vmap(lambda eog, sg, kg:
                        jnp.where(kg[:, None], eog[sg], 0))(eo, slot, keep)
    per_tok = gathered.reshape(g * tg, k, d)
    w_flat = top_w.reshape(g * tg, k).astype(per_tok.dtype)
    if use_pallas:
        y = kops.moe_combine(per_tok, w_flat)
    else:
        y = jnp.einsum("tkd,tk->td", per_tok, w_flat)
    y = y.reshape(b, s, d)
    return logical_constraint(y, ("batch", "seq", "embed")), aux
