"""Transformer building blocks — pure JAX, shape-polymorphic, shardable.

Conventions
-----------
* activations: (batch B, seq S, embed D); attention heads H, kv heads Hk,
  head dim Dh, GQA group G = H // Hk.
* params are plain-array pytrees (unboxed); init_* functions return Boxed
  trees carrying logical axis names (see common.py).
* attention is computed in query chunks (pure-JAX flash-style) so the
  S x T score tensor never materializes for long sequences; sliding-window
  layers additionally slice the KV to the window, making local layers
  O(S * W) instead of O(S^2).
* every dot product accumulates in f32 (preferred_element_type) and
  softmax runs in f32 — bf16 params are safe.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from .common import dense_init, logical_constraint, ones_init, zeros_init

F32 = jnp.float32


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype=jnp.bfloat16):
    return {"scale": ones_init((d,), ("embed",), dtype)}


def rmsnorm(p, x, eps: float = 1e-6):
    xf = x.astype(F32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(F32)).astype(x.dtype)


def init_layernorm(d: int, dtype=jnp.bfloat16):
    return {"scale": ones_init((d,), ("embed",), dtype),
            "bias": zeros_init((d,), ("embed",), dtype)}


def layernorm(p, x, eps: float = 1e-5):
    xf = x.astype(F32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(F32) + p["bias"].astype(F32)).astype(x.dtype)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------

def rope(x, positions, base: float = 10000.0):
    """x: (..., S, H, Dh) rotated by position; positions: (..., S)."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = base ** (-jnp.arange(0, half, dtype=F32) / half)
    angles = positions[..., None].astype(F32) * freqs          # (..., S, half)
    angles = angles[..., None, :]                              # add head dim
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half].astype(F32), x[..., half:].astype(F32)
    return jnp.concatenate([x1 * cos - x2 * sin,
                            x2 * cos + x1 * sin], axis=-1).astype(x.dtype)


# --------------------------------------------------------------------------
# Attention
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    qkv_bias: bool = False
    rope_base: float = 10000.0
    window: Optional[int] = None      # sliding-window size (local layers)
    causal: bool = True               # False: encoder (bidirectional)
    q_chunk: int = 1024               # flash-style query block


def init_attention(key, cfg: AttnConfig, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 4)
    d, h, hk, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = {
        "wq": dense_init(ks[0], (d, h, dh), ("embed", "heads", "head_dim"),
                         dtype),
        "wk": dense_init(ks[1], (d, hk, dh), ("embed", "kv_heads",
                                              "head_dim"), dtype),
        "wv": dense_init(ks[2], (d, hk, dh), ("embed", "kv_heads",
                                              "head_dim"), dtype),
        "wo": dense_init(ks[3], (h, dh, d), ("heads", "head_dim", "embed"),
                         dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = zeros_init((h, dh), ("heads", "head_dim"), dtype)
        p["bk"] = zeros_init((hk, dh), ("kv_heads", "head_dim"), dtype)
        p["bv"] = zeros_init((hk, dh), ("kv_heads", "head_dim"), dtype)
    return p


def _qkv(p, x, cfg: AttnConfig, positions, use_rope=True,
         q_only: bool = False):
    """Projections; q_only skips K/V (cross-attention supplies its own)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if cfg.qkv_bias:
        q = q + p["bq"]
    if use_rope:
        q = rope(q, positions, cfg.rope_base)
    q = logical_constraint(q, ("batch", "seq", "heads", "head_dim"))
    if q_only:
        return q, None, None
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        k, v = k + p["bk"], v + p["bv"]
    if use_rope:
        k = rope(k, positions, cfg.rope_base)
    k = logical_constraint(k, ("batch", "seq", "kv_heads", "head_dim"))
    v = logical_constraint(v, ("batch", "seq", "kv_heads", "head_dim"))
    return q, k, v


_F8_DTYPES = (jnp.float8_e4m3fn, jnp.float8_e5m2)


def _sdpa(q, k, v, mask_bias):
    """q: (B,Sq,Hk,G,Dh); k/v: (B,T,Hk,Dh); mask_bias: (Sq,T) or None.

    f8 KV caches are consumed DIRECTLY (q is quantized to the cache dtype
    and the MXU accumulates in f32) — dequantizing the cache up front
    would materialize the full bf16 cache and erase the memory win.
    """
    scale = q.shape[-1] ** -0.5
    out_dtype = q.dtype
    if k.dtype in _F8_DTYPES:
        q = (q.astype(F32) * scale).astype(k.dtype)
        logits = jnp.einsum("bqhgd,bthd->bhgqt", q, k,
                            preferred_element_type=F32)
    else:
        logits = jnp.einsum("bqhgd,bthd->bhgqt", q, k,
                            preferred_element_type=F32) * scale
    if mask_bias is not None:
        logits = logits + mask_bias
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqt,bthd->bqhgd", probs.astype(v.dtype), v,
                     preferred_element_type=F32)
    return out.astype(out_dtype)


def _mask_bias(q_pos, k_pos, causal: bool, window: Optional[int],
               prefix_len: Optional[Any] = None):
    """Additive f32 bias (Sq, T). q_pos/k_pos: int vectors of positions."""
    neg = jnp.asarray(-1e30, F32)
    ok = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        c = k_pos[None, :] <= q_pos[:, None]
        if prefix_len is not None:   # prefix-LM: bidirectional prefix
            c = c | (k_pos[None, :] < prefix_len)
        ok &= c
    if window is not None:
        ok &= k_pos[None, :] > (q_pos[:, None] - window)
    return jnp.where(ok, 0.0, neg)


def attention_train(p, x, cfg: AttnConfig, positions=None,
                    prefix_len=None, kv_override=None,
                    return_kv: bool = False):
    """Full-sequence attention, query-chunked. x: (B,S,D) -> (B,S,D).

    kv_override: (k, v, k_positions) for cross-attention (enc-dec).
    return_kv: also return the (post-RoPE) k, v for cache priming.
    """
    b, s, d = x.shape
    hk = cfg.n_kv_heads
    g = cfg.n_heads // hk
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q, k, v = _qkv(p, x, cfg, positions, use_rope=cfg.rope_base > 0,
                   q_only=kv_override is not None)
    if kv_override is not None:
        k, v, k_pos = kv_override
    else:
        k_pos = jnp.arange(s)
    q = q.reshape(b, s, hk, g, cfg.head_dim)

    chunk = min(cfg.q_chunk, s)
    if s % chunk:
        chunk = s  # fallback: ragged seq, single block
    n_chunks = s // chunk

    def one_chunk(ci):
        q_c = jax.lax.dynamic_slice_in_dim(q, ci * chunk, chunk, axis=1)
        qp = ci * chunk + jnp.arange(chunk)
        if cfg.window is not None and kv_override is None:
            # local layer: only the last `window + chunk` keys can be seen
            span = min(cfg.window + chunk, k.shape[1])
            start = jnp.clip(ci * chunk + chunk - span, 0,
                             k.shape[1] - span)
            k_c = jax.lax.dynamic_slice_in_dim(k, start, span, axis=1)
            v_c = jax.lax.dynamic_slice_in_dim(v, start, span, axis=1)
            kp = start + jnp.arange(span)
        else:
            k_c, v_c, kp = k, v, k_pos
        bias = _mask_bias(qp, kp, cfg.causal and kv_override is None,
                          cfg.window, prefix_len)
        return _sdpa(q_c, k_c, v_c, bias)

    if n_chunks == 1:
        out = one_chunk(0)
    else:
        # static python loop (not lax.map): keeps the per-chunk memory
        # bound AND keeps every chunk visible to HLO cost analysis (a
        # while-loop body would be cost-counted once — see DESIGN.md).
        outs = [one_chunk(ci) for ci in range(n_chunks)]
        out = jnp.concatenate(outs, axis=1)
    out = out.reshape(b, s, cfg.n_heads, cfg.head_dim)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"],
                   preferred_element_type=F32).astype(x.dtype)
    y = logical_constraint(y, ("batch", "seq", "embed"))
    if return_kv:
        return y, (k, v)
    return y


def prime_attn_cache(k, v, cfg: AttnConfig, max_seq: int,
                     dtype=jnp.bfloat16):
    """Build a decode cache from prefill k/v (B,S,Hk,Dh).

    Full-attention layers: slots 0..S-1 hold positions 0..S-1 directly.
    Windowed layers use the ring layout: position p lives at slot
    p mod W, so the last W entries are rolled by S mod W.
    """
    b, s = k.shape[0], k.shape[1]
    T = min(max_seq, cfg.window) if cfg.window is not None else max_seq
    if cfg.window is not None and s >= T:
        k_r = jnp.roll(k[:, -T:], s % T, axis=1)
        v_r = jnp.roll(v[:, -T:], s % T, axis=1)
    else:
        pad = T - s
        k_r = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_r = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    return {"k": k_r.astype(dtype), "v": v_r.astype(dtype),
            "index": jnp.asarray(s, jnp.int32)}


def attention_decode(p, x, cfg: AttnConfig, cache: Dict[str, Any],
                     kv_override=None, use_pallas: bool = False):
    """One-token decode. x: (B,1,D); cache: {k,v: (B,T,Hk,Dh), index: ()}.

    Returns (y, new_cache). The KV cache is ring-buffer-sized T =
    min(window, max_seq) for sliding-window layers.
    """
    b = x.shape[0]
    hk, g = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
    idx = cache["index"]
    positions = jnp.broadcast_to(idx[None], (b, 1)) \
        if idx.ndim else jnp.full((b, 1), idx)
    q, k_new, v_new = _qkv(p, x, cfg, positions,
                           use_rope=cfg.rope_base > 0,
                           q_only=kv_override is not None)
    if kv_override is not None:
        k, v, k_pos = kv_override          # cross-attention: static cache
        new_cache = cache
        bias = None
    else:
        T = cache["k"].shape[1]
        slot = jnp.mod(idx, T)             # ring buffer for windowed layers
        k = _cache_update(cache["k"], k_new, slot)
        v = _cache_update(cache["v"], v_new, slot)
        new_cache = {"k": k, "v": v, "index": idx + 1}
        if k.dtype != x.dtype and k.dtype not in _F8_DTYPES:
            # non-f8 quantized cache: dequant fallback (f8 stays packed
            # and is consumed directly by _sdpa)
            k = k.astype(x.dtype)
            v = v.astype(x.dtype)
        # positions held in the ring: slot s holds absolute pos p with
        # p mod T == s and p <= idx; invalid (future/unwritten) slots masked
        slots = jnp.arange(T)
        abs_pos = idx - jnp.mod(idx - slots, T)
        valid = abs_pos >= 0
        if cfg.window is not None:
            valid &= abs_pos > idx - cfg.window
        bias = jnp.where(valid, 0.0, -1e30).astype(F32)[None, :]  # (1, T)
        k_pos = abs_pos
    q = q.reshape(b, 1, hk, g, cfg.head_dim)
    if use_pallas and kv_override is None and k.dtype not in _F8_DTYPES:
        # streaming flash-decode kernel: one VMEM pass over the KV cache
        from ..kernels.flash_decode import flash_decode as _fdec
        bias_b = jnp.broadcast_to(bias, (b, k.shape[1])) \
            if bias is not None else jnp.zeros((b, k.shape[1]), F32)
        out = _fdec(q[:, 0], k, v, bias_b)[:, None]   # (B,1,Hk,G,Dh)
    else:
        out = _sdpa(q, k, v, bias)
    out = out.reshape(b, 1, cfg.n_heads, cfg.head_dim)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"],
                   preferred_element_type=F32).astype(x.dtype)
    return logical_constraint(y, ("batch", "seq", "embed")), new_cache


def _cache_update(buf, new, slot):
    return jax.lax.dynamic_update_slice_in_dim(
        buf, new.astype(buf.dtype), slot, axis=1)


def init_attn_cache(batch: int, cfg: AttnConfig, max_seq: int,
                    dtype=jnp.bfloat16, abstract: bool = False):
    T = min(max_seq, cfg.window) if cfg.window is not None else max_seq
    shape = (batch, T, cfg.n_kv_heads, cfg.head_dim)
    if abstract:
        arr = jax.ShapeDtypeStruct(shape, dtype)
        idx = jax.ShapeDtypeStruct((), jnp.int32)
        return {"k": arr, "v": arr, "index": idx}
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "index": jnp.zeros((), jnp.int32)}


# --------------------------------------------------------------------------
# MLP
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MLPConfig:
    d_model: int
    d_ff: int
    activation: str = "swiglu"        # swiglu | gelu


def init_mlp(key, cfg: MLPConfig, dtype=jnp.bfloat16):
    ks = jax.random.split(key, 3)
    d, f = cfg.d_model, cfg.d_ff
    p = {"wo": dense_init(ks[2], (f, d), ("mlp", "embed"), dtype)}
    if cfg.activation == "swiglu":
        p["wi_gate"] = dense_init(ks[0], (d, f), ("embed", "mlp"), dtype)
        p["wi_up"] = dense_init(ks[1], (d, f), ("embed", "mlp"), dtype)
    else:
        p["wi"] = dense_init(ks[0], (d, f), ("embed", "mlp"), dtype)
    return p


def mlp(p, x, cfg: MLPConfig):
    if cfg.activation == "swiglu":
        h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["wi_gate"],
                                   preferred_element_type=F32)) \
            * jnp.einsum("bsd,df->bsf", x, p["wi_up"],
                         preferred_element_type=F32)
    else:
        h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["wi"],
                                   preferred_element_type=F32))
    h = logical_constraint(h.astype(x.dtype), ("batch", "seq", "mlp"))
    y = jnp.einsum("bsf,fd->bsd", h, p["wo"], preferred_element_type=F32)
    return logical_constraint(y.astype(x.dtype), ("batch", "seq", "embed"))


# --------------------------------------------------------------------------
# Embedding / unembedding / loss
# --------------------------------------------------------------------------

def init_embedding(key, vocab: int, d: int, dtype=jnp.bfloat16):
    k1, k2 = jax.random.split(key)
    return {
        # input table rows gathered -> shard embed dim (FSDP overlay), not
        # vocab (a vocab-sharded gather would all-gather the table).
        "in_table": dense_init(k1, (vocab, d), ("vocab_in", "embed"), dtype,
                               scale=1.0),
        "out_table": dense_init(k2, (d, vocab), ("embed", "vocab"), dtype),
    }


def embed(p, tokens):
    y = jnp.take(p["in_table"], tokens, axis=0)
    return logical_constraint(y, ("batch", "seq", "embed"))


def logits(p, x):
    y = jnp.einsum("bsd,dv->bsv", x, p["out_table"],
                   preferred_element_type=F32)
    return logical_constraint(y, ("batch", "seq", "vocab"))


def chunked_ce_loss(p, x, labels, chunk: int = 512):
    """Mean cross-entropy without materializing (B,S,V) at once."""
    b, s, d = x.shape
    chunk = min(chunk, s)
    if s % chunk:
        chunk = s
    n_chunks = s // chunk

    total = jnp.zeros((), F32)
    # static python loop for the same cost-analysis reason as attention
    for ci in range(n_chunks):
        x_c = jax.lax.dynamic_slice_in_dim(x, ci * chunk, chunk, axis=1)
        l_c = jax.lax.dynamic_slice_in_dim(labels, ci * chunk, chunk, axis=1)
        lg = logits(p, x_c)                                   # (B,C,V) f32
        lse = jax.nn.logsumexp(lg, axis=-1)
        gold = jnp.take_along_axis(lg, l_c[..., None], axis=-1)[..., 0]
        total = total + jnp.sum(lse - gold)
    return total / (b * s)
