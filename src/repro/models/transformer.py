"""Decoder-only model assembly: layer patterns, scan-over-repeats, remat.

An architecture is a repeating PATTERN of blocks (plus an optional
unscanned remainder), e.g.
    dense LM        : pattern [attn+mlp] x L
    gemma3          : pattern [local x5, global x1] x 10  + 2 remainder
    jamba           : pattern [attn, mamba x7] with moe on odd positions
    mamba2          : pattern [mamba] x 48 (no FFN)
Params for each pattern position are stacked over repeats (leading R dim)
and the forward pass is a single ``lax.scan`` over R — HLO size stays
O(pattern), not O(layers), which is what makes the 72-layer 398B dry-run
compile tractable.

Blocks are pre-norm residual:  x += mixer(norm(x));  x += ffn(norm(x)).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import Boxed, dense_init
from . import layers as L
from .layers import AttnConfig, MLPConfig
from .moe import MoEConfig, init_moe, moe
from .mamba2 import (Mamba2Config, init_mamba2, init_mamba_cache, mamba2,
                     mamba2_decode)

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    kind: str                    # "attn" | "mamba"
    ffn: str = "dense"           # "dense" | "moe" | "none"
    window: Optional[int] = None  # sliding window (attn only)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    d_model: int
    n_layers: int
    pattern: Tuple[LayerSpec, ...]
    attn: Optional[AttnConfig] = None
    mlp: Optional[MLPConfig] = None
    moe: Optional[MoEConfig] = None
    mamba: Optional[Mamba2Config] = None
    norm: str = "rmsnorm"                # rmsnorm | layernorm
    prefix_lm: bool = False              # paligemma-style prefix attention
    n_prefix: int = 0                    # prefix length (e.g. image patches)
    scale_embed: bool = False            # gemma convention
    learned_pos: int = 0                 # >0: learned abs positions (whisper)
    dtype: Any = jnp.bfloat16
    moe_aux_weight: float = 0.01
    remat: str = "full"                  # none | dots | full
    use_pallas: bool = False
    scan_unroll: int = 1                 # lax.scan unroll (dry-run costing)
    cache_dtype: Any = None              # KV-cache dtype override (e.g.
                                         # f8_e4m3 quantized serving cache)
    citation: str = ""

    @property
    def repeats(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def remainder(self) -> Tuple[LayerSpec, ...]:
        return self.pattern[: self.n_layers % len(self.pattern)]


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def _norm_init(cfg: ModelConfig):
    return (L.init_rmsnorm(cfg.d_model, cfg.dtype) if cfg.norm == "rmsnorm"
            else L.init_layernorm(cfg.d_model, cfg.dtype))


def _norm_apply(cfg: ModelConfig, p, x):
    return (L.rmsnorm(p, x) if cfg.norm == "rmsnorm"
            else L.layernorm(p, x))


def _attn_cfg(cfg: ModelConfig, spec: LayerSpec) -> AttnConfig:
    return dataclasses.replace(cfg.attn, window=spec.window)


def init_block(key, cfg: ModelConfig, spec: LayerSpec):
    ks = jax.random.split(key, 4)
    p: Dict[str, Any] = {"norm_mix": _norm_init(cfg)}
    if spec.kind == "attn":
        p["attn"] = L.init_attention(ks[0], _attn_cfg(cfg, spec), cfg.dtype)
    else:
        p["mamba"] = init_mamba2(ks[0], cfg.mamba, cfg.dtype)
    if spec.ffn != "none":
        p["norm_ffn"] = _norm_init(cfg)
        if spec.ffn == "moe":
            p["moe"] = init_moe(ks[1], cfg.moe, cfg.dtype)
        else:
            p["mlp"] = L.init_mlp(ks[1], cfg.mlp, cfg.dtype)
    return p


def init_params(key, cfg: ModelConfig):
    """Returns a Boxed tree:
       {embed, blocks: [per-pattern-position stacked over repeats],
        rem_blocks: [...], final_norm}"""
    k_emb, k_blocks, k_rem, k_fin = jax.random.split(key, 4)
    params: Dict[str, Any] = {
        "embed": L.init_embedding(k_emb, cfg.vocab, cfg.d_model, cfg.dtype),
        "final_norm": _norm_init(cfg),
    }
    if cfg.learned_pos:
        params["pos_embed"] = dense_init(
            jax.random.fold_in(k_emb, 7), (cfg.learned_pos, cfg.d_model),
            ("cache_seq", "embed"), cfg.dtype, scale=0.02)

    r = cfg.repeats
    blocks = []
    for pos, spec in enumerate(cfg.pattern):
        keys = jax.random.split(jax.random.fold_in(k_blocks, pos), r)
        stacked = jax.vmap(lambda k: init_block(k, cfg, spec))(keys)
        # vmap stacks leaves; prepend "layers" to the logical axes
        stacked = jax.tree_util.tree_map(
            lambda b: Boxed(b.value, ("layers",) + b.logical),
            stacked, is_leaf=lambda x: isinstance(x, Boxed))
        blocks.append(stacked)
    params["blocks"] = blocks
    params["rem_blocks"] = [
        init_block(jax.random.fold_in(k_rem, i), cfg, spec)
        for i, spec in enumerate(cfg.remainder)]
    return params


# --------------------------------------------------------------------------
# forward (train / prefill)
# --------------------------------------------------------------------------

def _apply_block(cfg: ModelConfig, spec: LayerSpec, p, x, prefix_len,
                 aux_acc):
    h = _norm_apply(cfg, p["norm_mix"], x)
    if spec.kind == "attn":
        h = L.attention_train(p["attn"], h, _attn_cfg(cfg, spec),
                              prefix_len=prefix_len)
    else:
        h = mamba2(p["mamba"], h, cfg.mamba, cfg.use_pallas)
    x = x + h
    if spec.ffn != "none":
        h = _norm_apply(cfg, p["norm_ffn"], x)
        if spec.ffn == "moe":
            h, aux = moe(p["moe"], h, cfg.moe, cfg.use_pallas)
            aux_acc = aux_acc + aux
        else:
            h = L.mlp(p["mlp"], h, cfg.mlp)
        x = x + h
    return x, aux_acc


def _remat_policy(cfg: ModelConfig):
    if cfg.remat == "none":
        return None
    if cfg.remat == "dots":
        return jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims
    return jax.checkpoint_policies.nothing_saveable


def forward(params, cfg: ModelConfig, tokens, prefix_embeds=None):
    """tokens: (B,S) int32; prefix_embeds: (B,P,D) (VLM/audio stub).
    Returns (hidden (B,S',D), moe_aux) where S' = P + S."""
    x = L.embed(params["embed"], tokens)
    if cfg.scale_embed:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    if cfg.learned_pos:
        s = x.shape[1]
        x = x + params["pos_embed"][:s][None]
    prefix_len = cfg.n_prefix if cfg.prefix_lm else None

    def body_fn(x, block_slice):
        aux = jnp.zeros((), F32)
        for pos, spec in enumerate(cfg.pattern):
            x, aux = _apply_block(cfg, spec, block_slice[pos], x,
                                  prefix_len, aux)
        return x, aux

    policy = _remat_policy(cfg)
    if policy is not None:
        body_fn = jax.checkpoint(body_fn, policy=policy)

    def scan_body(carry, block_slice):
        return body_fn(carry, block_slice)

    if cfg.repeats > 0:
        x, auxs = jax.lax.scan(scan_body, x, params["blocks"],
                               unroll=cfg.scan_unroll)
        aux_total = jnp.sum(auxs)
    else:
        aux_total = jnp.zeros((), F32)
    for p_blk, spec in zip(params["rem_blocks"], cfg.remainder):
        x, aux_total = _apply_block(cfg, spec, p_blk, x, prefix_len,
                                    aux_total)
    x = _norm_apply(cfg, params["final_norm"], x)
    return x, aux_total


def lm_loss(params, cfg: ModelConfig, tokens, labels, prefix_embeds=None):
    """Next-token CE over the token positions (prefix positions excluded)."""
    hidden, aux = forward(params, cfg, tokens, prefix_embeds)
    if prefix_embeds is not None:
        hidden = hidden[:, prefix_embeds.shape[1]:, :]
    ce = L.chunked_ce_loss(params["embed"], hidden, labels)
    return ce + cfg.moe_aux_weight * aux, {"ce": ce, "moe_aux": aux}


# --------------------------------------------------------------------------
# prefill (forward that also primes the decode cache)
# --------------------------------------------------------------------------

def _apply_block_prefill(cfg, spec, p, x, prefix_len, aux_acc, max_seq):
    kv_dtype = cfg.cache_dtype if cfg.cache_dtype is not None else cfg.dtype
    h = _norm_apply(cfg, p["norm_mix"], x)
    if spec.kind == "attn":
        acfg = _attn_cfg(cfg, spec)
        h, (k, v) = L.attention_train(p["attn"], h, acfg,
                                      prefix_len=prefix_len,
                                      return_kv=True)
        cache = L.prime_attn_cache(k, v, acfg, max_seq, kv_dtype)
    else:
        h, cache = mamba2(p["mamba"], h, cfg.mamba, cfg.use_pallas,
                          return_cache=True)
    x = x + h
    if spec.ffn != "none":
        h = _norm_apply(cfg, p["norm_ffn"], x)
        if spec.ffn == "moe":
            h, aux = moe(p["moe"], h, cfg.moe, cfg.use_pallas)
            aux_acc = aux_acc + aux
        else:
            h = L.mlp(p["mlp"], h, cfg.mlp)
        x = x + h
    return x, aux_acc, cache


def prefill(params, cfg: ModelConfig, tokens, prefix_embeds=None,
            max_seq: int = 0):
    """Forward pass that also PRIMES the decode cache (prefill-then-decode
    serving flow). Returns (last-token logits, cache)."""
    x = L.embed(params["embed"], tokens)
    if cfg.scale_embed:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    if cfg.learned_pos:
        x = x + params["pos_embed"][:x.shape[1]][None]
    s_total = x.shape[1]
    max_seq = max_seq or s_total
    assert max_seq >= s_total, "cache shorter than the prompt"
    prefix_len = cfg.n_prefix if cfg.prefix_lm else None

    def scan_body(carry, block_slice):
        x = carry
        aux = jnp.zeros((), F32)
        caches = []
        for pos, spec in enumerate(cfg.pattern):
            x, aux, c = _apply_block_prefill(cfg, spec, block_slice[pos],
                                             x, prefix_len, aux, max_seq)
            caches.append(c)
        return x, caches

    if cfg.repeats > 0:
        x, blocks_cache = jax.lax.scan(scan_body, x, params["blocks"],
                                       unroll=cfg.scan_unroll)
    else:
        blocks_cache = []
    rem_cache = []
    aux = jnp.zeros((), F32)
    for p_blk, spec in zip(params["rem_blocks"], cfg.remainder):
        x, aux, c = _apply_block_prefill(cfg, spec, p_blk, x, prefix_len,
                                         aux, max_seq)
        rem_cache.append(c)
    x = _norm_apply(cfg, params["final_norm"], x)
    lg = L.logits(params["embed"], x[:, -1:, :])
    return lg, {"blocks": blocks_cache, "rem_blocks": rem_cache}


# --------------------------------------------------------------------------
# decode (one token against a cache)
# --------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               abstract: bool = False):
    """Cache pytree mirroring the block structure; stacked over repeats."""
    kv_dtype = cfg.cache_dtype if cfg.cache_dtype is not None else cfg.dtype

    def one(spec: LayerSpec):
        if spec.kind == "attn":
            return L.init_attn_cache(batch, _attn_cfg(cfg, spec), max_seq,
                                     kv_dtype, abstract=abstract)
        return init_mamba_cache(batch, cfg.mamba, cfg.dtype,
                                abstract=abstract)

    def stack(tree, r):
        if abstract:
            return jax.tree_util.tree_map(
                lambda s: jax.ShapeDtypeStruct((r,) + s.shape, s.dtype), tree)
        return jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (r,) + a.shape), tree)

    return {
        "blocks": [stack(one(spec), cfg.repeats) for spec in cfg.pattern],
        "rem_blocks": [one(spec) for spec in cfg.remainder],
    }


def _apply_block_decode(cfg: ModelConfig, spec: LayerSpec, p, x, cache):
    h = _norm_apply(cfg, p["norm_mix"], x)
    if spec.kind == "attn":
        h, cache = L.attention_decode(p["attn"], h, _attn_cfg(cfg, spec),
                                      cache, use_pallas=cfg.use_pallas)
    else:
        h, cache = mamba2_decode(p["mamba"], h, cfg.mamba, cache)
    x = x + h
    if spec.ffn != "none":
        h = _norm_apply(cfg, p["norm_ffn"], x)
        if spec.ffn == "moe":
            h, _ = moe(p["moe"], h, cfg.moe, cfg.use_pallas)
        else:
            h = L.mlp(p["mlp"], h, cfg.mlp)
        x = x + h
    return x, cache


def decode_step(params, cfg: ModelConfig, token, cache):
    """token: (B,1) int32. Returns (logits (B,1,V), new_cache)."""
    x = L.embed(params["embed"], token)
    if cfg.scale_embed:
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
    if cfg.learned_pos:
        # position from the first attn cache index
        idx = _first_attn_index(cfg, cache)
        x = x + params["pos_embed"][idx][None, None]

    def scan_body(carry, inp):
        x = carry
        block_slice, cache_slice = inp
        new_cache = []
        for pos, spec in enumerate(cfg.pattern):
            x, c = _apply_block_decode(cfg, spec, block_slice[pos],
                                       x, cache_slice[pos])
            new_cache.append(c)
        return x, new_cache

    if cfg.repeats > 0:
        x, new_blocks = jax.lax.scan(scan_body, x,
                                     (params["blocks"], cache["blocks"]),
                                     unroll=cfg.scan_unroll)
    else:
        new_blocks = cache["blocks"]
    new_rem = []
    for p_blk, spec, c in zip(params["rem_blocks"], cfg.remainder,
                              cache["rem_blocks"]):
        x, c = _apply_block_decode(cfg, spec, p_blk, x, c)
        new_rem.append(c)
    x = _norm_apply(cfg, params["final_norm"], x)
    lg = L.logits(params["embed"], x)
    return lg, {"blocks": new_blocks, "rem_blocks": new_rem}


def _first_attn_index(cfg: ModelConfig, cache):
    for pos, spec in enumerate(cfg.pattern):
        if spec.kind == "attn":
            return cache["blocks"][pos]["index"][0]
    for pos, spec in enumerate(cfg.remainder):
        if spec.kind == "attn":
            return cache["rem_blocks"][pos]["index"]
    return jnp.zeros((), jnp.int32)
