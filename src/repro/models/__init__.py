"""Model stack: pure-JAX assigned architectures with logical-axis sharding.

layers (GQA/RoPE/SWA attention, MLP), moe (expert parallel), mamba2 (SSD),
transformer (decoder-only + prefix-LM + prefill/decode serving),
encdec (Whisper-style). See DESIGN.md §3.
"""
