"""Encoder-decoder assembly (Whisper-style audio backbone).

Per the assignment carve-out, the modality frontend (mel-spectrogram +
conv feature extractor) is a STUB: ``input_specs`` provides precomputed
frame embeddings (B, T_frames, D) — this module implements the
transformer that consumes them:

  encoder: [self-attn (bidirectional) + GELU MLP] x N, learned positions
  decoder: [causal self-attn + cross-attn + GELU MLP] x N, learned
           positions, KV cache decode

Whisper-large-v3: 32 enc + 32 dec layers, d_model 1280, 20 heads,
d_ff 5120, vocab 51866 [arXiv:2212.04356].
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from .common import Boxed, dense_init
from . import layers as L
from .layers import AttnConfig, MLPConfig

F32 = jnp.float32


@dataclasses.dataclass(frozen=True)
class EncDecConfig:
    name: str
    vocab: int
    d_model: int
    n_enc_layers: int
    n_dec_layers: int
    attn: AttnConfig                 # self-attn (decoder: causal=True)
    mlp: MLPConfig
    n_frames: int = 1500             # encoder positions (whisper audio ctx)
    max_target: int = 448            # decoder learned positions
    dtype: Any = jnp.bfloat16
    remat: str = "full"
    use_pallas: bool = False
    scan_unroll: int = 1             # lax.scan unroll (dry-run costing)
    citation: str = ""


def _enc_attn(cfg: EncDecConfig) -> AttnConfig:
    return dataclasses.replace(cfg.attn, causal=False, rope_base=0.0)


def _dec_attn(cfg: EncDecConfig) -> AttnConfig:
    return dataclasses.replace(cfg.attn, causal=True, rope_base=0.0)


def init_enc_block(key, cfg: EncDecConfig):
    k1, k2 = jax.random.split(key)
    return {
        "norm_attn": L.init_layernorm(cfg.d_model, cfg.dtype),
        "attn": L.init_attention(k1, _enc_attn(cfg), cfg.dtype),
        "norm_mlp": L.init_layernorm(cfg.d_model, cfg.dtype),
        "mlp": L.init_mlp(k2, cfg.mlp, cfg.dtype),
    }


def init_dec_block(key, cfg: EncDecConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "norm_self": L.init_layernorm(cfg.d_model, cfg.dtype),
        "self_attn": L.init_attention(k1, _dec_attn(cfg), cfg.dtype),
        "norm_cross": L.init_layernorm(cfg.d_model, cfg.dtype),
        "cross_attn": L.init_attention(k2, _dec_attn(cfg), cfg.dtype),
        "norm_mlp": L.init_layernorm(cfg.d_model, cfg.dtype),
        "mlp": L.init_mlp(k3, cfg.mlp, cfg.dtype),
    }


def init_params(key, cfg: EncDecConfig):
    k_emb, k_enc, k_dec, kp1, kp2 = jax.random.split(key, 5)
    enc_keys = jax.random.split(k_enc, cfg.n_enc_layers)
    dec_keys = jax.random.split(k_dec, cfg.n_dec_layers)

    def _stack(init_fn, keys):
        stacked = jax.vmap(init_fn)(keys)
        return jax.tree_util.tree_map(
            lambda b: Boxed(b.value, ("layers",) + b.logical), stacked,
            is_leaf=lambda x: isinstance(x, Boxed))

    return {
        "embed": L.init_embedding(k_emb, cfg.vocab, cfg.d_model, cfg.dtype),
        "enc_pos": dense_init(kp1, (cfg.n_frames, cfg.d_model),
                              ("frames", "embed"), cfg.dtype, scale=0.02),
        "dec_pos": dense_init(kp2, (cfg.max_target, cfg.d_model),
                              ("cache_seq", "embed"), cfg.dtype, scale=0.02),
        "enc_blocks": _stack(lambda k: init_enc_block(k, cfg), enc_keys),
        "dec_blocks": _stack(lambda k: init_dec_block(k, cfg), dec_keys),
        "enc_norm": L.init_layernorm(cfg.d_model, cfg.dtype),
        "dec_norm": L.init_layernorm(cfg.d_model, cfg.dtype),
    }


# --------------------------------------------------------------------------

def encode(params, cfg: EncDecConfig, frames):
    """frames: (B, T, D) stubbed conv features -> encoder states."""
    x = frames.astype(cfg.dtype) + params["enc_pos"][:frames.shape[1]][None]

    def body(x, blk):
        h = L.layernorm(blk["norm_attn"], x)
        x = x + L.attention_train(blk["attn"], h, _enc_attn(cfg))
        h = L.layernorm(blk["norm_mlp"], x)
        x = x + L.mlp(blk["mlp"], h, cfg.mlp)
        return x, None

    if cfg.remat != "none":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["enc_blocks"],
                        unroll=cfg.scan_unroll)
    return L.layernorm(params["enc_norm"], x)


def decode_train(params, cfg: EncDecConfig, tokens, enc_states):
    """Teacher-forced decoder. tokens: (B,S) -> hidden (B,S,D)."""
    x = L.embed(params["embed"], tokens)
    # positions clamp to the learned table (longform decode beyond the
    # 448-position whisper table — adaptation noted in DESIGN.md)
    pos_idx = jnp.minimum(jnp.arange(tokens.shape[1]),
                          params["dec_pos"].shape[0] - 1)
    x = x + params["dec_pos"][pos_idx][None]
    t_enc = enc_states.shape[1]
    k_pos = jnp.arange(t_enc)

    def body(x, blk):
        h = L.layernorm(blk["norm_self"], x)
        x = x + L.attention_train(blk["self_attn"], h, _dec_attn(cfg))
        h = L.layernorm(blk["norm_cross"], x)
        # cross-attention: kv from encoder states (projected per layer)
        kc = jnp.einsum("btd,dhk->bthk", enc_states, blk["cross_attn"]["wk"])
        vc = jnp.einsum("btd,dhk->bthk", enc_states, blk["cross_attn"]["wv"])
        x = x + L.attention_train(blk["cross_attn"], h, _dec_attn(cfg),
                                  kv_override=(kc, vc, k_pos))
        h = L.layernorm(blk["norm_mlp"], x)
        x = x + L.mlp(blk["mlp"], h, cfg.mlp)
        return x, None

    if cfg.remat != "none":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["dec_blocks"],
                        unroll=cfg.scan_unroll)
    return L.layernorm(params["dec_norm"], x)


def loss(params, cfg: EncDecConfig, frames, tokens, labels):
    enc_states = encode(params, cfg, frames)
    hidden = decode_train(params, cfg, tokens, enc_states)
    ce = L.chunked_ce_loss(params["embed"], hidden, labels)
    return ce, {"ce": ce}


# --------------------------------------------------------------------------
# decode step (serving)
# --------------------------------------------------------------------------

def init_cache(cfg: EncDecConfig, batch: int, max_seq: int,
               abstract: bool = False):
    """Self-attn KV caches (stacked over layers) + precomputed cross KV."""
    self_c = L.init_attn_cache(batch, _dec_attn(cfg), max_seq, cfg.dtype,
                               abstract=abstract)
    r = cfg.n_dec_layers
    if abstract:
        stacked = jax.tree_util.tree_map(
            lambda s: jax.ShapeDtypeStruct((r,) + s.shape, s.dtype), self_c)
        cross = jax.ShapeDtypeStruct(
            (r, batch, cfg.n_frames, cfg.attn.n_kv_heads,
             cfg.attn.head_dim), cfg.dtype)
    else:
        stacked = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (r,) + a.shape), self_c)
        cross = jnp.zeros((r, batch, cfg.n_frames, cfg.attn.n_kv_heads,
                           cfg.attn.head_dim), cfg.dtype)
    return {"self": stacked, "cross_k": cross, "cross_v": cross}


def decode_step(params, cfg: EncDecConfig, token, cache):
    """One decoder token against cached self-KV and cross-KV."""
    idx = cache["self"]["index"][0]
    x = L.embed(params["embed"], token)
    x = x + params["dec_pos"][jnp.minimum(idx, cfg.max_target - 1)][None,
                                                                    None]
    t_enc = cache["cross_k"].shape[2]
    k_pos = jnp.arange(t_enc)

    def body(x, inp):
        blk, self_c, ck, cv = inp
        h = L.layernorm(blk["norm_self"], x)
        a, new_self = L.attention_decode(blk["self_attn"], h,
                                         _dec_attn(cfg), self_c)
        x = x + a
        h = L.layernorm(blk["norm_cross"], x)
        a, _ = L.attention_decode(blk["cross_attn"], h, _dec_attn(cfg),
                                  {"index": self_c["index"]},
                                  kv_override=(ck, cv, k_pos))
        x = x + a
        h = L.layernorm(blk["norm_mlp"], x)
        x = x + L.mlp(blk["mlp"], h, cfg.mlp)
        return x, new_self

    x, new_self = jax.lax.scan(
        body, x, (params["dec_blocks"], cache["self"], cache["cross_k"],
                  cache["cross_v"]), unroll=cfg.scan_unroll)
    x = L.layernorm(params["dec_norm"], x)
    lg = L.logits(params["embed"], x)
    new_cache = {"self": new_self, "cross_k": cache["cross_k"],
                 "cross_v": cache["cross_v"]}
    return lg, new_cache
