"""Schedule conformance: the jaxpr's messages == the ledger's messages.

Three layers, each proved exactly (no tolerances — a wire schedule is
discrete data):

1. **Step conformance** — for every traced segment step, the messages
   recovered from the jaxpr (``extract.extract_messages``) must match
   the records the trace captured into the scratch ledger one-for-one:
   count, order, kind, tag, shape, dtype, wire arithmetic, provisional
   bits, and round offset within the step.  Each message must also
   anchor to a real reduction/collective equation — a ledger record with
   no graph ops behind it is phantom traffic.
2. **Replay conformance** — the static schedule, expanded over the
   program's segments (repeating each step ``count`` times, advancing
   round indices, re-pricing scheduled channels per round), must equal
   the trace-once ``CommLedger.replay_schedule`` stream record-for-
   record, round-mark-for-round-mark.  This is the replay every scan
   engine and ``execute_batch`` group uses — so proving it against the
   jaxpr proves the meter for every compiled run.
3. **Dynamic conformance** (optional, ``execute=True``) — the same
   static expansion must equal the ledger of an actually executed run
   (the eager python engine for local plans — a fully independent
   meter — and the expanded ``shard_map`` driver ledger for sharded
   plans).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence, Tuple

from ..core.comm import CommLedger, sanitize_scope_tag
from .extract import StaticMessage, TracedStep, extract_messages
from .findings import Finding

# comparison key: one tuple per message/record; `rnd` appended by the
# expansion layers
_FIELDS = ("kind", "tag", "shape", "dtype", "bits", "wire", "direction")


def _rec_key(rec) -> tuple:
    return (rec.kind, sanitize_scope_tag(rec.tag), tuple(rec.shape),
            rec.dtype, int(rec.bits),
            tuple(rec.wire) if rec.wire is not None else None,
            rec.direction)


def _msg_key(msg: StaticMessage) -> tuple:
    return (msg.kind, msg.tag, msg.shape, msg.dtype, int(msg.bits),
            msg.wire, msg.direction)


def round_offsets(n_records: int, marks: Sequence[int]) -> List[int]:
    """Round index of each record position, from the round-boundary
    marks (marks[k] == record position right after round k+1 ended, so
    record i belongs to round ``#{m : m <= i}``)."""
    out = []
    j = 0
    ms = sorted(marks)
    for i in range(n_records):
        while j < len(ms) and ms[j] <= i:
            j += 1
        out.append(j)
    return out


_LOCAL_ANCHORS = {"reduce_all": ("reduce_sum", "add_any"),
                  "all_to_all_broadcast": ()}
_SHARDED_ANCHORS = {"reduce_all": ("psum",),
                    "all_to_all_broadcast": ("all_gather",)}


def _check_messages(msgs: List[StaticMessage], problems: List[str],
                    records: Sequence[Any], marks: Sequence[int],
                    *, placement: str, where: str,
                    rel_round_base: Optional[int] = None,
                    span_starts: Optional[Dict[int, int]] = None,
                    ) -> List[Finding]:
    """Static messages vs captured records, one-for-one.

    ``rel_round_base``: when set, message round fields are step-relative
    offsets rebased so the first record sits at round
    ``rel_round_base`` (local step traces).  ``span_starts`` (sharded
    scheduled traces): maps a record index to the index of the first
    record of its scan span — messages inside a span carry rounds
    relative to the span's start round.
    """
    fs: List[Finding] = []
    for p in problems:
        fs.append(Finding("sched-scope", "error", f"{where}: {p}"))
    if len(msgs) != len(records):
        fs.append(Finding(
            "sched-count", "error",
            f"{where}: the jaxpr carries {len(msgs)} wire message(s) "
            f"but the captured schedule has {len(records)} record(s)"))
        return fs
    if not msgs:
        return fs
    base_idx = msgs[0].idx
    offs = round_offsets(len(records), marks)
    anchors = _SHARDED_ANCHORS if placement == "sharded" \
        else _LOCAL_ANCHORS
    for i, (msg, rec) in enumerate(zip(msgs, records)):
        if msg.idx != base_idx + i:
            fs.append(Finding(
                "sched-index", "error",
                f"{where}: message record indices are not contiguous "
                f"(expected {base_idx + i}, found {msg.idx}) — a record "
                f"was captured without a traced message or vice versa",
                path=msg.path))
            return fs
        mk, rk = _msg_key(msg), _rec_key(rec)
        if mk != rk:
            diffs = [f"{name}: jaxpr={mv!r} ledger={rv!r}"
                     for name, mv, rv in zip(_FIELDS, mk, rk) if mv != rv]
            fs.append(Finding(
                "sched-field", "error",
                f"{where}: message {i} ({rec.tag!r}) disagrees with its "
                f"ledger record on " + "; ".join(diffs),
                path=msg.path))
        # round position
        if rel_round_base is not None:
            want = offs[i]
            got = msg.rnd - msgs[0].rnd + offs[0]
        elif span_starts is not None and msg.idx in span_starts:
            start = span_starts[msg.idx]
            want = offs[i]
            got = msg.rnd + offs[start]
        else:
            want = offs[i]
            got = msg.rnd
        if got != want:
            fs.append(Finding(
                "sched-round", "error",
                f"{where}: message {i} ({rec.tag!r}) sits in round "
                f"{got} of the jaxpr but round {want} of the captured "
                f"schedule", path=msg.path))
        need = anchors.get(msg.kind, ())
        if need and not any(p in msg.prims for p in need):
            fs.append(Finding(
                "sched-anchor", "error",
                f"{where}: message {i} ({rec.tag!r}, kind {msg.kind}) "
                f"anchors to no {' / '.join(need)} equation — the scope "
                f"contains only {sorted(set(msg.prims))}",
                path=msg.path))
        elif not msg.prims:
            fs.append(Finding(
                "sched-anchor", "error",
                f"{where}: message {i} ({rec.tag!r}) owns no equations "
                f"at all", path=msg.path))
    return fs


# --------------------------------------------------------------------------
# Local plans: step traces -> expansion -> replay / executed run
# --------------------------------------------------------------------------

def _step_for_segment(steps: List[TracedStep],
                      s: int) -> TracedStep:
    for ts in steps:
        if s in ts.segments:
            return ts
    raise ValueError(f"no traced step covers segment {s}")


def ledger_stream(led: CommLedger) -> List[tuple]:
    """(fields…, round) per record — the exact comparison stream."""
    offs = round_offsets(len(led.records), led.round_marks)
    return [_rec_key(r) + (offs[i],)
            for i, r in enumerate(led.records)]


def static_expand_local(steps: List[TracedStep], program,
                        chan) -> Tuple[List[tuple], int]:
    """Expand the per-step static schedule over the program's segments,
    re-pricing scheduled channels from each repeat's global round —
    implemented from the jaxpr-extracted messages alone, independently
    of ``CommLedger.replay_schedule``."""
    scheduled = getattr(chan, "scheduled", False)
    stream: List[tuple] = []
    base = 0
    for s, seg in enumerate(program.segments):
        ts = _step_for_segment(steps, s)
        msgs, _ = extract_messages(ts.closed.jaxpr)
        offs = round_offsets(len(ts.records), ts.marks)
        rels = [msg.rnd - msgs[0].rnd + offs[0] for msg in msgs] \
            if msgs else []
        rps = ts.rounds_per_step
        for k in range(int(seg.count)):
            for msg, rel in zip(msgs, rels):
                rnd = base + k * rps + rel
                bits = msg.bits
                if scheduled and msg.wire is not None:
                    per, nmsg = msg.wire
                    bits = nmsg * chan.wire_bits(per, msg.itemsize,
                                                 rnd=rnd)
                stream.append((msg.kind, msg.tag, msg.shape, msg.dtype,
                               int(bits), msg.wire, msg.direction, rnd))
        base += rps * int(seg.count)
    return stream, base


def replay_expand_local(steps: List[TracedStep], program,
                        chan) -> CommLedger:
    """The trace-once replay every scan engine / batch group performs."""
    sched_chan = chan if getattr(chan, "scheduled", False) else None
    led = CommLedger()
    for s, seg in enumerate(program.segments):
        ts = _step_for_segment(steps, s)
        led.replay_schedule(ts.records, ts.rounds_per_step, ts.marks,
                            int(seg.count), channel=sched_chan,
                            faults=None)
    return led


def _compare_streams(static: List[tuple], dynamic: List[tuple],
                     code: str, where: str,
                     total_rounds: Tuple[int, int]) -> List[Finding]:
    fs: List[Finding] = []
    if len(static) != len(dynamic):
        fs.append(Finding(
            code, "error",
            f"{where}: static expansion has {len(static)} record(s), "
            f"the replayed/executed ledger {len(dynamic)}"))
        return fs
    names = _FIELDS + ("round",)
    for i, (a, b) in enumerate(zip(static, dynamic)):
        if a != b:
            diffs = [f"{n}: static={x!r} dynamic={y!r}"
                     for n, x, y in zip(names, a, b) if x != y]
            fs.append(Finding(
                code, "error",
                f"{where}: record {i} ({b[1]!r}) diverges — "
                + "; ".join(diffs)))
            if len(fs) >= 5:
                fs.append(Finding(code, "error",
                                  f"{where}: … further diffs suppressed"))
                return fs
    if total_rounds[0] != total_rounds[1]:
        fs.append(Finding(
            code, "error",
            f"{where}: static expansion spans {total_rounds[0]} "
            f"round(s), the ledger {total_rounds[1]}"))
    return fs


def verify_local_schedule(steps: List[TracedStep], program, chan,
                          executed_ledger: Optional[CommLedger] = None,
                          ) -> Tuple[List[Finding], Dict[str, int]]:
    """Layers 1+2 (and 3 when ``executed_ledger`` is given) for a local
    plan's traced steps.  Returns (findings, schedule stats)."""
    findings: List[Finding] = []
    for ts in steps:
        msgs, problems = extract_messages(ts.closed.jaxpr)
        where = f"segment(s) {ts.segments}"
        findings += _check_messages(
            msgs, problems, ts.records, ts.marks, placement="local",
            where=where, rel_round_base=0)
    if any(f.severity == "error" for f in findings):
        return findings, {}
    static, rounds_s = static_expand_local(steps, program, chan)
    replay = replay_expand_local(steps, program, chan)
    findings += _compare_streams(
        static, ledger_stream(replay), "sched-replay",
        "trace-once replay", (rounds_s, replay.rounds))
    if executed_ledger is not None:
        findings += _compare_streams(
            static, ledger_stream(executed_ledger), "sched-dynamic",
            "executed run", (rounds_s, executed_ledger.algo_rounds))
    stats = {"messages": len(static), "rounds": rounds_s,
             "total_bits": int(sum(rec[4] for rec in static))}
    return findings, stats


# --------------------------------------------------------------------------
# Sharded plans: one traced shard_map program + scan spans
# --------------------------------------------------------------------------

def static_expand_sharded(msgs: List[StaticMessage],
                          trace_marks: Sequence[int],
                          spans: Sequence[Tuple[int, int, int, int]],
                          chan) -> Tuple[List[tuple], int]:
    """Expand the trace-time static schedule the way the sharded driver
    expands its ledger: records outside scan spans copy once; each
    span's records repeat ``count`` times with advancing rounds and
    per-round scheduled re-pricing."""
    scheduled = getattr(chan, "scheduled", False)
    offs = round_offsets(len(msgs), trace_marks)

    def emit(stream, msg, rnd):
        bits = msg.bits
        if scheduled and msg.wire is not None:
            per, nmsg = msg.wire
            bits = nmsg * chan.wire_bits(per, msg.itemsize, rnd=rnd)
        stream.append((msg.kind, msg.tag, msg.shape, msg.dtype,
                       int(bits), msg.wire, msg.direction, rnd))

    stream: List[tuple] = []
    rounds_total = 0
    prev_end = 0
    for start, end, r_traced, count in spans:
        for i in range(prev_end, start):
            emit(stream, msgs[i],
                 rounds_total + offs[i] - offs[prev_end])
        if start > prev_end:
            rounds_total += offs[start] - offs[prev_end]
        span = msgs[start:end]
        for k in range(count):
            for i, msg in enumerate(span):
                rel = offs[start + i] - (offs[start]
                                         if start < len(offs) else 0)
                emit(stream, msg, rounds_total + rel)
            rounds_total += r_traced
        prev_end = end
    for i in range(prev_end, len(msgs)):
        emit(stream, msgs[i], rounds_total + offs[i] - offs[prev_end])
    return stream, rounds_total


def verify_sharded_schedule(closed, led: CommLedger,
                            spans: Sequence[Tuple[int, int, int, int]],
                            chan,
                            executed_ledger: Optional[CommLedger] = None,
                            ) -> Tuple[List[Finding], Dict[str, int]]:
    """Static messages of the traced ``shard_map`` program vs its
    trace-time ledger, then the span expansion vs the executed run."""
    msgs, problems = extract_messages(closed.jaxpr)
    scheduled = getattr(chan, "scheduled", False)
    span_starts: Optional[Dict[int, int]] = None
    if scheduled:
        span_starts = {}
        for start, end, _, _ in spans:
            for i in range(start, end):
                span_starts[i] = start
    findings = _check_messages(
        msgs, problems, led.records, led.round_marks,
        placement="sharded", where="sharded trace",
        span_starts=span_starts)
    if any(f.severity == "error" for f in findings):
        return findings, {}
    static, rounds_s = static_expand_sharded(
        msgs, led.round_marks, spans, chan)
    stats = {"messages": len(static), "rounds": rounds_s,
             "total_bits": int(sum(rec[4] for rec in static))}
    if executed_ledger is not None:
        findings += _compare_streams(
            static, ledger_stream(executed_ledger), "sched-dynamic",
            "executed sharded run", (rounds_s, executed_ledger.rounds))
    return findings, stats


__all__ = [
    "ledger_stream", "replay_expand_local", "round_offsets",
    "static_expand_local", "static_expand_sharded",
    "verify_local_schedule", "verify_sharded_schedule",
]
