"""``repro.analysis`` — static verification of traced round programs.

Three analyses over the jaxprs the engines already trace, none of which
runs a single algorithm round:

1. **Schedule conformance** (``schedule``) — every wire message a
   communicator prices is scope-annotated in the graph; the static
   schedule recovered from the jaxpr must equal the trace-once
   ``CommLedger`` capture, its replay expansion, and (optionally) an
   executed run's ledger, exactly.
2. **Algorithm-class certification** (``lineage``) — input-lineage
   proof that local compute reads only the machine's own feature block
   and that nothing crosses machines outside communicator primitives,
   plus Theorem 4's scalar-payload restriction for incremental inner
   rounds.
3. **Compile-hazard lints** (``lints``) — in-step RNG, group-splitting
   structure instabilities, weak-literal hazards.

Entry points: ``ExecutionPlan.audit()`` / ``plan(spec,
verify="static")`` for one cell, ``audit_registry()`` (the
``python -m repro.analysis`` CLI) for the whole registry plus the
mutation fixtures that prove the verifier rejects out-of-class
programs.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

from ..core.channel import parse_channel
from ..core.comm import CommLedger
from .extract import extract_messages, trace_steps
from .findings import (AuditReport, CellAudit, Finding, FixtureResult,
                       summarize)
from .lineage import (ClassCertifier, certify_sharded_class,
                      thm4_payload_findings)
from .lints import lint_group_stability, lint_rng, lint_weak_literals
from .schedule import (verify_local_schedule, verify_sharded_schedule)

# the audited channel axis: a fixed lossless wire, a fixed quantized
# wire, and a two-stage schedule (exercises round-indexed re-pricing)
AUDIT_CHANNELS: Tuple[str, ...] = ("identity", "int8",
                                   "sched:int8@0,fp16@5")
AUDIT_PLACEMENTS: Tuple[str, ...] = ("local", "sharded")

# audit instances pin m distinct from every other dimension (m=3 vs
# d=12, d_max=4, n=12) so "axis of size m" identifies the machine axis
AUDIT_INSTANCES: Dict[str, Tuple[str, dict, dict]] = {
    # algorithm -> (instance kind, params, hyper-varied params for the
    # group-stability lint)
    "dgd": ("thm2_chain", dict(d=12, m=3, kappa=16.0),
            dict(d=12, m=3, kappa=24.0)),
    "dagd": ("thm2_chain", dict(d=12, m=3, kappa=16.0),
             dict(d=12, m=3, kappa=24.0)),
    "prox_dagd": ("thm2_chain", dict(d=12, m=3, kappa=16.0),
                  dict(d=12, m=3, kappa=24.0)),
    "bcd": ("thm2_chain", dict(d=12, m=3, kappa=16.0),
            dict(d=12, m=3, kappa=24.0)),
    "disco_f": ("thm2_chain", dict(d=12, m=3, kappa=16.0),
                dict(d=12, m=3, kappa=24.0)),
    "dsvrg": ("thm4_separable", dict(n=12, m=3, kappa=16.0),
              dict(n=12, m=3, kappa=24.0)),
}
AUDIT_ROUNDS = 8


def _ambiguous_m(dist, steps) -> bool:
    """True when some traced shape carries the machine count at a
    non-leading position — the shape convention can no longer identify
    the machine axis and class certification would be guesswork."""
    m = dist.part.m
    for ts in steps:
        jaxpr = ts.closed.jaxpr
        for v in list(jaxpr.constvars) + list(jaxpr.invars):
            shp = tuple(getattr(v.aval, "shape", ()))
            if m in shp[1:]:
                return True
    return False


def audit_plan(pl, execute: bool = False) -> CellAudit:
    """Statically audit one ``ExecutionPlan``: schedule conformance,
    class certification, and the per-cell lints.  ``execute=True`` adds
    the dynamic cross-check against an actually executed run (the eager
    python engine locally; the expanded shard_map driver sharded)."""
    from ..api.plan import PlanError  # noqa: F401  (shared error type)

    cell = CellAudit(
        algorithm=pl.algo.name if pl.algo else "",
        placement=pl.placement, channel=pl.channel,
        backend=pl.backend, engine=pl.engine,
        instance=pl.spec.instance or "")
    if pl.resolution_only:
        cell.skipped = "resolution-only plan (no instance/algorithm)"
        return cell
    if pl.faults != "none":
        cell.skipped = (f"fault injection ({pl.faults!r}) is a dynamic "
                        f"axis; static audit requires faults='none'")
        return cell
    coords = dict(algorithm=cell.algorithm, placement=cell.placement,
                  channel=cell.channel)
    chan = parse_channel(pl.wire_channel())
    if cell.placement == "sharded":
        _audit_sharded(pl, cell, chan, coords, execute)
    else:
        _audit_local(pl, cell, chan, coords, execute)
    return cell


def _stamp(findings, coords):
    return [Finding(**{**f.to_dict(), **{k: v for k, v in coords.items()
                                         if not getattr(f, k)}})
            for f in findings]


def _audit_local(pl, cell: CellAudit, chan, coords,
                 execute: bool) -> None:
    from ..core.engine import run_program

    dist, program, _ = pl._cell()
    steps = trace_steps(dist, program)
    executed_led: Optional[CommLedger] = None
    if execute:
        # the eager python engine meters every call as it happens — a
        # fully independent dynamic meter to hold the statics against
        dist.comm.ledger = executed_led = CommLedger()
        run_program(dist, program, engine="python", measure=None)
        cell.executed = True
    fs, stats = verify_local_schedule(steps, program, chan,
                                      executed_ledger=executed_led)
    cell.findings += _stamp(fs, coords)
    cell.messages = stats.get("messages", 0)
    cell.rounds = stats.get("rounds", 0)
    cell.total_bits = stats.get("total_bits", 0)
    if _ambiguous_m(dist, steps):
        cell.findings.append(Finding(
            "class-unknown", "warning",
            f"machine count m={dist.part.m} collides with another traced "
            f"dimension; the shape convention cannot identify the "
            f"machine axis, so class certification was skipped — "
            f"audit on an instance with distinct m", **coords))
    else:
        cert = ClassCertifier(dist.part.m, **coords)
        for ts in steps:
            cert.certify_step(ts)
        cell.findings += cert.findings
    if pl.algo is not None and pl.algo.incremental:
        cell.findings += thm4_payload_findings(
            steps, program, algorithm=cell.algorithm,
            channel=cell.channel)
    cell.findings += lint_rng(steps, algorithm=cell.algorithm,
                              channel=cell.channel)
    cell.findings += lint_weak_literals(steps,
                                        algorithm=cell.algorithm,
                                        channel=cell.channel)


def _audit_sharded(pl, cell: CellAudit, chan, coords,
                   execute: bool) -> None:
    from ..core.runtime import _run_sharded

    b = pl.bundle
    kwargs = pl.algo_kwargs()
    closed, led, spans = _run_sharded(
        b.prob, None, rounds=pl.spec.rounds, ledger=CommLedger(),
        backend=pl.backend, engine="scan",
        program_builder=lambda d_, r: pl.algo.program(d_, r, **kwargs),
        channel=pl.wire_channel(), trace_only=True)
    executed_led: Optional[CommLedger] = None
    if execute:
        _, executed_led = _run_sharded(
            b.prob, None, rounds=pl.spec.rounds, ledger=CommLedger(),
            backend=pl.backend, engine="scan",
            program_builder=lambda d_, r: pl.algo.program(d_, r,
                                                          **kwargs),
            channel=pl.wire_channel())
        cell.executed = True
    fs, stats = verify_sharded_schedule(closed, led, spans, chan,
                                        executed_ledger=executed_led)
    cell.findings += _stamp(fs, coords)
    cell.messages = stats.get("messages", 0)
    cell.rounds = stats.get("rounds", 0)
    cell.total_bits = stats.get("total_bits", 0)
    cell.findings += certify_sharded_class(
        closed, algorithm=cell.algorithm, channel=cell.channel)


def _group_stability_findings(algo_name: str) -> list:
    """Trace the algorithm under two hyper settings; identical
    structure text is what lets ``execute_batch`` group a sweep."""
    from ..api import RunSpec
    from ..api.plan import plan

    kind, pa, pb = AUDIT_INSTANCES[algo_name]
    structs = []
    for params in (pa, pb):
        spec = RunSpec(instance=kind, instance_params=params,
                       algorithm=algo_name, rounds=AUDIT_ROUNDS,
                       placement="local", engine="scan",
                       backend="einsum", channel="identity",
                       measure="none")
        pl = plan(spec)
        dist, program, _ = pl._cell()
        structs.append([ts.structure
                        for ts in trace_steps(dist, program)])
        pl.release()
    return lint_group_stability(structs[0], structs[1],
                                algorithm=algo_name,
                                channel="identity")


def audit_registry(channels: Sequence[str] = AUDIT_CHANNELS,
                   placements: Sequence[str] = AUDIT_PLACEMENTS,
                   rounds: int = AUDIT_ROUNDS,
                   execute: bool = False,
                   fixtures: bool = True,
                   quick: bool = False) -> AuditReport:
    """The registry-wide audit the CLI and the CI leg run: every
    registered algorithm × placement × channel, plus the group-
    stability lint and the mutation fixtures."""
    import jax

    from ..api import RunSpec
    from ..api.plan import PlanError, plan
    from ..experiments.registry import ALGORITHM_REGISTRY
    from .fixtures import run_fixtures

    if quick:
        channels = tuple(channels[:1]) + tuple(
            c for c in channels if c.startswith("sched:"))[:1]
        execute = False
    report = AuditReport(meta={
        "jax": jax.__version__,
        "rounds": rounds,
        "channels": list(channels),
        "placements": list(placements),
        "executed": bool(execute),
    })
    bundles: dict = {}
    for algo_name in sorted(ALGORITHM_REGISTRY):
        kind, params, _ = AUDIT_INSTANCES.get(
            algo_name, ("thm2_chain", dict(d=12, m=3, kappa=16.0),
                        None))
        for placement in placements:
            for channel in channels:
                spec = RunSpec(instance=kind, instance_params=params,
                               algorithm=algo_name, rounds=rounds,
                               placement=placement, engine="scan",
                               backend="einsum", channel=channel,
                               measure="none")
                bkey = (kind, tuple(sorted(params.items())))
                try:
                    pl = plan(spec, bundle=bundles.get(bkey))
                    bundles.setdefault(bkey, pl.bundle)
                    cell = audit_plan(pl, execute=execute)
                    pl.release()
                except PlanError as e:
                    cell = CellAudit(algorithm=algo_name,
                                     placement=placement,
                                     channel=channel, instance=kind,
                                     skipped=str(e))
                report.cells.append(cell)
        if not quick:
            try:
                stab = _group_stability_findings(algo_name)
            except PlanError as e:
                stab = [Finding("lint-group-split", "warning",
                                f"group-stability lint skipped: {e}",
                                algorithm=algo_name)]
            if stab:
                # attach to the algorithm's local/identity cell
                for cell in report.cells:
                    if cell.algorithm == algo_name \
                            and cell.placement == "local" \
                            and not cell.skipped:
                        cell.findings += stab
                        break
    if fixtures:
        report.fixtures = run_fixtures()
    return report


__all__ = [
    "AUDIT_CHANNELS", "AUDIT_INSTANCES", "AUDIT_PLACEMENTS",
    "AuditReport", "CellAudit", "ClassCertifier", "Finding",
    "FixtureResult", "audit_plan", "audit_registry",
    "certify_sharded_class", "extract_messages", "lint_group_stability",
    "lint_rng", "lint_weak_literals", "summarize",
    "thm4_payload_findings", "trace_steps", "verify_local_schedule",
    "verify_sharded_schedule",
]
