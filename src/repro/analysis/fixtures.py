"""Mutation fixtures: programs the verifier MUST reject.

A static verifier that has never rejected anything proves nothing.  Each
fixture here takes a correct round program and plants one specific
out-of-class behaviour — a feature-block read across machines, a cross-
machine combination outside the communicator, an incremental inner round
shipping a vector, a priced message with no graph ops behind it — then
runs the very audit pipeline ``ExecutionPlan.audit()`` uses and asserts
the expected typed finding fires.  The CI ``analysis`` leg runs these on
every push; a verifier change that silently stops rejecting any of them
fails the build.
"""
from __future__ import annotations

from typing import List

import jax.numpy as jnp

from ..core.channel import parse_channel
from ..core.engine import RoundProgram, Segment
from .extract import trace_steps
from .findings import Finding, FixtureResult
from .lineage import ClassCertifier, thm4_payload_findings
from .schedule import verify_local_schedule


def _fixture_dist():
    """A small audit-sized LocalDistERM (m=3 distinct from d=12)."""
    from ..core.runtime import LocalDistERM
    from ..experiments.instances import build_thm2_chain

    b = build_thm2_chain(d=12, m=3, kappa=16.0)
    return LocalDistERM(b.prob, b.part, backend="einsum",
                        channel="identity")


def _audit_program(dist, program, incremental: bool = False
                   ) -> List[Finding]:
    """The same local audit pipeline ``audit_plan`` runs, over a raw
    (dist, program) pair — schedule conformance + class certification
    (+ the Theorem-4 payload restriction for incremental programs)."""
    steps = trace_steps(dist, program)
    chan = parse_channel("identity")
    findings, _ = verify_local_schedule(steps, program, chan)
    cert = ClassCertifier(dist.part.m)
    for ts in steps:
        cert.certify_step(ts)
    findings = list(findings) + list(cert.findings)
    if incremental:
        findings += thm4_payload_findings(steps, program)
    return findings


def _gd_program(dist, rounds: int, mutate=None) -> RoundProgram:
    """The dgd skeleton every fixture perturbs: one ReduceAll of z = Aw
    and one scalar ReduceAll per round."""
    eta = jnp.float32(0.05)

    def step(d_, w, _x):
        z = d_.response(w)
        g = d_.pgrad(w, z)
        w_new = w - eta * g
        if mutate is not None:
            w_new = mutate(d_, w, w_new)
        d_.end_round()
        return w_new, w_new

    return RoundProgram(init=dist.zeros_like_w(),
                        segments=[Segment(step, rounds, name="gd")],
                        final=lambda w: w)


# --------------------------------------------------------------------------
# The fixtures
# --------------------------------------------------------------------------

def fixture_leaky_dgd() -> FixtureResult:
    """Machine 0's feature block read by everyone, outside the
    communicator: ``w[0]`` collapses the machine axis to one machine's
    slice.  Expected: ``class-leak`` naming the slicing equation."""
    dist = _fixture_dist()

    def mutate(d_, w, w_new):
        # every machine nudges its iterate by machine 0's first block
        # coordinate — data that never crossed the wire
        return w_new + 0.0 * jnp.sum(w[0])

    program = _gd_program(dist, rounds=3, mutate=mutate)
    findings = _audit_program(dist, program)
    expect = ["class-leak"]
    return FixtureResult(
        name="leaky-dgd", expect_codes=expect,
        rejected=any(f.code in expect and f.severity == "error"
                     for f in findings),
        findings=findings)


def fixture_oob_dgd() -> FixtureResult:
    """A cross-machine sum computed outside the communicator: the
    semantic effect of a ReduceAll with no wire pricing.  Expected:
    ``class-oob`` naming the machine-axis reduce equation."""
    dist = _fixture_dist()

    def mutate(d_, w, w_new):
        # sums across the machine axis without dist.comm — free lunch
        ghost = jnp.sum(w, axis=0)
        return w_new + 0.0 * ghost[None, :]

    program = _gd_program(dist, rounds=3, mutate=mutate)
    findings = _audit_program(dist, program)
    expect = ["class-oob"]
    return FixtureResult(
        name="oob-dgd", expect_codes=expect,
        rejected=any(f.code in expect and f.severity == "error"
                     for f in findings),
        findings=findings)


def fixture_chatty_dsvrg() -> FixtureResult:
    """An 'incremental' program whose inner (count > 1) segment ships a
    full vector per round, violating Theorem 4's O(1)-per-round payload
    model.  Expected: ``thm4-payload``."""
    dist = _fixture_dist()
    eta = jnp.float32(0.05)

    def snapshot(d_, w, _x):
        z = d_.response(w)              # one full-vector round: allowed
        g = d_.pgrad(w, z)
        d_.end_round()
        return w - eta * g, w

    def inner(d_, w, _x):
        z = d_.response(w)              # full vector EVERY inner round
        g = d_.pgrad(w, z)
        d_.end_round()
        return w - eta * g, w

    program = RoundProgram(
        init=dist.zeros_like_w(),
        segments=[Segment(snapshot, 1, name="snapshot"),
                  Segment(inner, 4, name="inner")],
        final=lambda w: w)
    findings = _audit_program(dist, program, incremental=True)
    expect = ["thm4-payload"]
    return FixtureResult(
        name="chatty-dsvrg", expect_codes=expect,
        rejected=any(f.code in expect and f.severity == "error"
                     for f in findings),
        findings=findings)


def fixture_phantom_dgd() -> FixtureResult:
    """A ledger record priced with no message behind it: the step books
    a ReduceAll straight into the ledger without transmitting anything.
    The static schedule (recovered from the jaxpr) is one message short
    of the captured one.  Expected: ``sched-count``."""
    dist = _fixture_dist()

    def mutate(d_, w, w_new):
        # books wire traffic the graph never performs
        d_.comm.ledger.record("reduce_all", int(w.shape[1]),
                              tag="phantom", dtype="float32",
                              shape=(int(w.shape[1]),))
        return w_new

    program = _gd_program(dist, rounds=3, mutate=mutate)
    findings = _audit_program(dist, program)
    expect = ["sched-count"]
    return FixtureResult(
        name="phantom-dgd", expect_codes=expect,
        rejected=any(f.code in expect and f.severity == "error"
                     for f in findings),
        findings=findings)


FIXTURES = (fixture_leaky_dgd, fixture_oob_dgd, fixture_chatty_dsvrg,
            fixture_phantom_dgd)


def run_fixtures() -> List[FixtureResult]:
    return [fx() for fx in FIXTURES]


__all__ = ["FIXTURES", "run_fixtures", "fixture_chatty_dsvrg",
           "fixture_leaky_dgd", "fixture_oob_dgd",
           "fixture_phantom_dgd"]
