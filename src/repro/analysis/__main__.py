"""``python -m repro.analysis`` — audit the whole registry statically.

Traces every registered algorithm under every audited placement and
channel, proves the three static properties (schedule conformance,
algorithm-class certification, compile-hazard lints), runs the mutation
fixtures, and writes ``docs/results/static-audit.{json,md}``.  Exits
non-zero unless every cell verifies and every fixture is rejected —
the CI ``analysis`` leg gates on exactly this.

  python -m repro.analysis                 # full static audit + report
  python -m repro.analysis --execute       # + dynamic executed-run cross-check
  python -m repro.analysis --quick         # trimmed channel axis, no fixtures
  python -m repro.analysis --no-report     # verdict only, write nothing
"""
from __future__ import annotations

import argparse
import pathlib
import sys

from . import AUDIT_CHANNELS, AUDIT_PLACEMENTS, AUDIT_ROUNDS, \
    audit_registry


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static audit of every registered algorithm's "
                    "communication schedule, class membership, and "
                    "compile hazards")
    ap.add_argument("--execute", action="store_true",
                    help="additionally cross-check each static schedule "
                         "against an executed run's ledger")
    ap.add_argument("--quick", action="store_true",
                    help="trim the channel axis and skip fixtures/"
                         "group-stability (fast sanity pass)")
    ap.add_argument("--rounds", type=int, default=AUDIT_ROUNDS,
                    help=f"round budget per audited cell "
                         f"(default {AUDIT_ROUNDS})")
    ap.add_argument("--channel", action="append", dest="channels",
                    metavar="NAME",
                    help="audit only this channel (repeatable; default: "
                         f"{', '.join(AUDIT_CHANNELS)})")
    ap.add_argument("--placement", action="append", dest="placements",
                    choices=list(AUDIT_PLACEMENTS),
                    help="audit only this placement (repeatable)")
    ap.add_argument("--out", type=pathlib.Path, default=None,
                    help="results directory (default docs/results)")
    ap.add_argument("--no-report", action="store_true",
                    help="print the verdict but write no files")
    args = ap.parse_args(argv)

    report = audit_registry(
        channels=tuple(args.channels or AUDIT_CHANNELS),
        placements=tuple(args.placements or AUDIT_PLACEMENTS),
        rounds=args.rounds, execute=args.execute,
        fixtures=not args.quick, quick=args.quick)

    audited = [c for c in report.cells if not c.skipped]
    skipped = [c for c in report.cells if c.skipped]
    print(f"audited {len(audited)} cell(s) "
          f"({len(skipped)} skipped), "
          f"{len(report.fixtures)} fixture(s)")
    for f in report.errors():
        print(f"  ERROR {f}", file=sys.stderr)
    for fx in report.fixtures:
        if not fx.rejected:
            print(f"  ERROR fixture {fx.name!r} was NOT rejected "
                  f"(expected {fx.expect_codes})", file=sys.stderr)

    if not args.no_report:
        from ..experiments.report import default_results_dir, \
            refresh_index
        out = args.out or default_results_dir()
        out.mkdir(parents=True, exist_ok=True)
        (out / "static-audit.json").write_text(report.to_json() + "\n")
        (out / "static-audit.md").write_text(report.to_markdown())
        refresh_index(out)
        print(f"wrote {out / 'static-audit.json'}")
        print(f"wrote {out / 'static-audit.md'}")

    print(f"verdict: {'PASS' if report.ok else 'FAIL'}")
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
