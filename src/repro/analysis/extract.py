"""Jaxpr walking and static message extraction.

The communicators wrap every wire message's graph ops in a
``jax.named_scope`` token encoding the ledger record they priced
(``core.comm.comm_scope_name``).  The token rides each traced equation's
``source_info.name_stack`` — through ``scan``, ``shard_map``, ``cond``
and friends — without perturbing the jaxpr text or the compiled
computation.  This module recovers the *static* message schedule from a
traced program: walk every equation (recursing into sub-jaxprs), group
the equations claimed by each comm token, and parse the token back into
a ``StaticMessage``.  ``repro.analysis.schedule`` then proves this
static schedule equal to the trace-once ``CommLedger`` capture and its
replay/expansion.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Iterator, List, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from ..core.comm import CommLedger, parse_comm_scope

# --------------------------------------------------------------------------
# Generic jaxpr traversal
# --------------------------------------------------------------------------


def _sub_jaxprs(eqn) -> Iterator[Tuple[str, Any]]:
    """(param path fragment, jaxpr) for every sub-jaxpr of an equation —
    ``scan``/``while``/``cond`` bodies, ``pjit``/``shard_map`` callees,
    custom-derivative wrappers."""
    for key, val in eqn.params.items():
        items = val if isinstance(val, (list, tuple)) else (val,)
        many = isinstance(val, (list, tuple))
        for j, item in enumerate(items):
            sub = None
            if isinstance(item, jax.core.ClosedJaxpr):
                sub = item.jaxpr
            elif isinstance(item, jax.core.Jaxpr):
                sub = item
            if sub is not None:
                yield (f"{key}[{j}]" if many else key), sub


def iter_eqns(jaxpr, path: str = "") -> Iterator[Tuple[Any, str]]:
    """Depth-first (eqn, path) over a jaxpr and all its sub-jaxprs."""
    for i, eqn in enumerate(jaxpr.eqns):
        p = f"{path}eqns[{i}]"
        yield eqn, p
        for frag, sub in _sub_jaxprs(eqn):
            yield from iter_eqns(sub, f"{p}.{frag}.")


def comm_token(eqn) -> Optional[str]:
    """The comm scope token on an equation's name stack, or None.
    Messages never nest, so at most one token appears; the innermost
    wins if an exotic caller ever nests them."""
    stack = str(eqn.source_info.name_stack)
    tok = None
    for seg in stack.split("/"):
        if seg.startswith("comm["):
            tok = seg
    return tok


def format_eqn(eqn, width: int = 160) -> str:
    """A finding-sized rendering of one equation."""
    text = " ".join(str(eqn).split())
    return text if len(text) <= width else text[:width - 1] + "…"


# --------------------------------------------------------------------------
# Static messages
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StaticMessage:
    """One wire message recovered from the jaxpr alone."""

    idx: int                     # ledger record index at trace time
    rnd: int                     # round (step offset or absolute trace
                                 # round — see comm_scope_name)
    kind: str
    direction: str
    shape: Tuple[int, ...]
    dtype: str
    bits: int
    wire: Optional[Tuple[int, int]]
    tag: str
    path: str                    # first anchoring equation's path
    prims: Tuple[str, ...]       # primitive names inside the scope

    @property
    def itemsize(self) -> int:
        return int(np.dtype(self.dtype).itemsize)


def extract_messages(jaxpr) -> Tuple[List[StaticMessage], List[str]]:
    """All wire messages in a traced program, in record order, plus a
    list of problems (malformed tokens, duplicated record indices) the
    schedule verifier reports as ``sched-scope``/``sched-index``."""
    by_token: Dict[str, Dict[str, Any]] = {}
    problems: List[str] = []
    for eqn, path in iter_eqns(jaxpr):
        tok = comm_token(eqn)
        if tok is None:
            continue
        slot = by_token.get(tok)
        if slot is None:
            meta = parse_comm_scope(tok)
            if meta is None:
                problems.append(f"malformed comm scope token {tok!r} "
                                f"at {path}")
                by_token[tok] = {"meta": None}
                continue
            by_token[tok] = slot = {"meta": meta, "path": path,
                                    "prims": []}
        if slot["meta"] is None:
            continue
        slot["prims"].append(eqn.primitive.name)
    msgs: List[StaticMessage] = []
    seen_idx: Dict[int, str] = {}
    for tok, slot in by_token.items():
        meta = slot["meta"]
        if meta is None:
            continue
        idx = int(meta["idx"])
        if idx in seen_idx:
            problems.append(
                f"two comm scopes claim record index {idx}: "
                f"{seen_idx[idx]!r} and {tok!r} — mixed traces?")
            continue
        seen_idx[idx] = tok
        msgs.append(StaticMessage(
            idx=idx, rnd=int(meta["rnd"]), kind=str(meta["kind"]),
            direction=str(meta["direction"]),
            shape=tuple(meta["shape"]), dtype=str(meta["dtype"]),
            bits=int(meta["bits"]), wire=meta["wire"],
            tag=str(meta["tag"]), path=str(slot["path"]),
            prims=tuple(slot["prims"])))
    msgs.sort(key=lambda msg: msg.idx)
    return msgs, problems


# --------------------------------------------------------------------------
# Step tracing (shared by plan audits and mutation fixtures)
# --------------------------------------------------------------------------

@dataclasses.dataclass
class TracedStep:
    """One traced segment step: the jaxpr, its hoisted consts, and the
    schedule the trace captured into the scratch ledger."""

    closed: Any                              # ClosedJaxpr
    consts: List[Any]
    structure: str
    records: List[Any]                       # captured CommRecords
    rounds_per_step: int
    marks: List[int]                         # record-stream round marks
    segments: List[int]                      # program segment indices
    counts: List[int]                        # scan length per segment


def trace_steps(dist, program) -> List[TracedStep]:
    """Trace every distinct segment step of a local ``RoundProgram``
    into (jaxpr, consts, captured schedule) — the same ``make_jaxpr``
    split ``repro.api.batch.prepare_cell`` performs, shared here so
    mutation fixtures (raw ``dist`` + program, no ``ExecutionPlan``)
    go through the identical trace path the batch engine uses."""
    from ..api.batch import _convert, _segment_xs

    scheduled = getattr(getattr(dist.comm, "channel", None),
                        "scheduled", False)
    real = dist.comm.ledger
    dist.comm.ledger = scratch = CommLedger()
    dist.comm._tracing = True
    out: List[TracedStep] = []
    try:
        carry = program.init
        by_step: Dict[tuple, TracedStep] = {}
        for s, seg in enumerate(program.segments):
            xs = _segment_xs(seg)
            key = (id(seg.step), xs.dtype.str, xs.shape[1:])
            if key not in by_step:
                n0, r0 = len(scratch.records), scratch.rounds
                m0 = len(scratch.round_marks)
                if scheduled:
                    def traced(c, rx, _step=seg.step):
                        rk, x = rx
                        dist.comm.begin_round(rk)
                        try:
                            return _step(dist, c, x)
                        finally:
                            dist.comm.reset_round()
                    conv = _convert(traced, carry,
                                    (jnp.int32(0), jnp.asarray(xs[0])))
                else:
                    conv = _convert(lambda c, x: seg.step(dist, c, x),
                                    carry, jnp.asarray(xs[0]))
                ts = TracedStep(
                    closed=conv.closed, consts=list(conv.consts),
                    structure=conv.structure,
                    records=list(scratch.records[n0:]),
                    rounds_per_step=scratch.rounds - r0,
                    marks=[m - n0 for m in scratch.round_marks[m0:]],
                    segments=[], counts=[])
                by_step[key] = ts
                out.append(ts)
            by_step[key].segments.append(s)
            by_step[key].counts.append(int(seg.count))
    finally:
        dist.comm.ledger = real
        dist.comm._tracing = False
    return out


__all__ = [
    "StaticMessage", "TracedStep", "comm_token", "extract_messages",
    "format_eqn", "iter_eqns", "trace_steps",
]
