"""Input-lineage certification of the paper's algorithm class.

The lower bounds only bind algorithms in a restricted class: each
machine's local computation may read only its own feature block, and
every cross-machine combination must flow through the communicator
primitives (Arjevani–Shamir's formalization; Theorem 4 adds a per-round
payload restriction for incremental methods).  Under the local
placement a per-machine value is an array whose leading *machine axis*
has size ``m``; the communicators are the only code allowed to collapse
that axis.  This module runs an abstract interpretation over a traced
step jaxpr tracking, for every intermediate value, **which of its axes
are machine axes**:

* combining values along a machine axis (``reduce_sum`` over it, a
  ``dot_general`` contracting it, a cumulative/sort op along it)
  outside a comm scope is an out-of-band transfer (``class-oob``);
* slicing/gathering a machine axis down to a subset outside a comm
  scope reads another machine's partition (``class-leak``);
* a primitive whose machine-axis flow the interpreter cannot model is
  ``class-unknown`` — certification refuses to guess.

Inside a communicator's scope (``core.comm`` wraps every wire message
in a named scope) the same operations are precisely what a metered
message performs, so they are exempt and their results demote to
machine-independent ("global") values.

The audit instance pins ``m`` distinct from every other dimension
(``m=3`` against ``d=12``/``d_max=4``/``n=12``), so "an axis of size
m" identifies the machine axis unambiguously.
"""
from __future__ import annotations

from typing import Any, Dict, FrozenSet, List, Tuple

import jax

from .extract import TracedStep, comm_token, format_eqn, iter_eqns
from .findings import Finding

Dims = FrozenSet[int]
_EMPTY: Dims = frozenset()

# shape-preserving / elementwise primitives: output machine dims are the
# union of the (rank-aligned) operand machine dims
_ELEMENTWISE = {
    "add", "sub", "mul", "div", "rem", "pow", "atan2", "max", "min",
    "and", "or", "xor", "not", "neg", "sign", "floor", "ceil", "round",
    "abs", "exp", "exp2", "log", "log1p", "expm1", "sqrt", "rsqrt",
    "cbrt", "sin", "cos", "tan", "asin", "acos", "atan", "sinh", "cosh",
    "tanh", "asinh", "acosh", "atanh", "erf", "erfc", "erf_inv",
    "logistic", "integer_pow", "is_finite", "eq", "ne", "lt", "le",
    "gt", "ge", "select_n", "clamp", "nextafter", "square",
    "shift_left", "shift_right_logical", "shift_right_arithmetic",
    "population_count", "clz", "real", "imag", "conj",
}

# unary layout-preserving: out dims == in dims
_PASSTHROUGH = {
    "convert_element_type", "copy", "stop_gradient", "device_put",
    "reduce_precision", "rev",
}

_REDUCES = {"reduce_sum", "reduce_prod", "reduce_max", "reduce_min",
            "reduce_and", "reduce_or", "reduce_xor",
            "argmax", "argmin"}

# ordered/cumulative ops: along the machine axis they mix machines
_AXIS_OPS = {"cumsum", "cumprod", "cummax", "cummin", "cumlogsumexp",
             "sort"}

# explicit cross-machine collectives (legal only inside comm scopes)
_COLLECTIVES = {"psum", "pmax", "pmin", "all_gather", "all_to_all",
                "ppermute", "psum_scatter", "pbroadcast", "axis_index",
                "reduce_scatter"}

_GLOBAL_SOURCES = {"iota", "rng_bit_generator", "threefry2x32",
                   "random_seed", "random_wrap", "random_bits",
                   "random_fold_in", "random_split"}

_CALL_JAXPR_PARAMS = ("jaxpr", "call_jaxpr", "fun_jaxpr")


class _Unmodeled(Exception):
    pass


def _rank(v) -> int:
    return len(getattr(v.aval, "shape", ()))


def _shape(v) -> Tuple[int, ...]:
    return tuple(getattr(v.aval, "shape", ()))


class _Env:
    """Var -> machine-dim set (Literals are always global)."""

    def __init__(self) -> None:
        self._d: Dict[Any, Dims] = {}

    def read(self, v) -> Dims:
        if isinstance(v, jax.core.Literal):
            return _EMPTY
        return self._d.get(v, _EMPTY)

    def write(self, v, dims: Dims) -> bool:
        old = self._d.get(v)
        if old == dims:
            return False
        # joining states across fixpoint passes: union
        self._d[v] = dims if old is None else (old | dims)
        return True


def _union_elementwise(env: _Env, eqn) -> Dims:
    out_rank = _rank(eqn.outvars[0])
    dims: Dims = _EMPTY
    for v in eqn.invars:
        d = env.read(v)
        if not d:
            continue
        if _rank(v) != out_rank:
            raise _Unmodeled("rank-mismatched machine operand in "
                             "elementwise op")
        dims = dims | d
    return dims


def _dot_general(env: _Env, eqn, in_scope: bool) -> Tuple[Dims, str]:
    """Returns (out machine dims, violation kind or '')."""
    (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
    lhs, rhs = eqn.invars
    ld, rd = env.read(lhs), env.read(rhs)
    if any(a in ld for a in lc) or any(a in rd for a in rc):
        if not in_scope:
            return _EMPTY, "contract"
        return _EMPTY, ""
    # output layout: batch dims, then lhs free, then rhs free
    out: set = set()
    for pos, (a, _) in enumerate(zip(lb, rb)):
        if a in ld or rb[pos] in rd:
            out.add(pos)
    nb = len(lb)
    lfree = [a for a in range(_rank(lhs)) if a not in lc and a not in lb]
    rfree = [a for a in range(_rank(rhs)) if a not in rc and a not in rb]
    for i, a in enumerate(lfree):
        if a in ld:
            out.add(nb + i)
    for i, a in enumerate(rfree):
        if a in rd:
            out.add(nb + len(lfree) + i)
    return frozenset(out), ""


def _remap_removed(dims: Dims, removed) -> Dims:
    rm = sorted(removed)
    out = set()
    for a in dims:
        if a in rm:
            continue
        out.add(a - sum(1 for r in rm if r < a))
    return frozenset(out)


def _reshape_dims(dims: Dims, shp_in, shp_out, m: int) -> Dims:
    """A machine dim survives a reshape iff an output axis of size m
    sits at the same flattened offset with the same surrounding
    products; otherwise the reshape merged machine data — unmodeled."""
    out = set()
    for a in dims:
        pre = 1
        for s in shp_in[:a]:
            pre *= s
        hit = None
        acc = 1
        for j, s in enumerate(shp_out):
            if acc == pre and s == m:
                hit = j
                break
            acc *= s
        if hit is None:
            raise _Unmodeled("reshape folds a machine axis into "
                             "neighboring dimensions")
        out.add(hit)
    return frozenset(out)


def _gather_dims(env: _Env, eqn, m: int) -> Tuple[Dims, str]:
    operand = eqn.invars[0]
    od = env.read(operand)
    if not od:
        return _EMPTY, ""
    dn = eqn.params["dimension_numbers"]
    slice_sizes = eqn.params["slice_sizes"]
    collapsed = set(dn.collapsed_slice_dims)
    batching = set(getattr(dn, "operand_batching_dims", ()))
    offset_dims = tuple(dn.offset_dims)
    # operand dims that survive into the output as offset dims, in order
    kept = [a for a in range(_rank(operand))
            if a not in collapsed and a not in batching]
    out = set()
    for a in sorted(od):
        if a in collapsed or slice_sizes[a] < m:
            return _EMPTY, "slice"
        if a in batching:
            raise _Unmodeled("gather batches over a machine axis")
        out.add(offset_dims[kept.index(a)])
    return frozenset(out), ""


def _call_jaxprs(eqn):
    for name in _CALL_JAXPR_PARAMS:
        if name in eqn.params:
            cj = eqn.params[name]
            return cj.jaxpr if hasattr(cj, "jaxpr") else cj
    return None


class ClassCertifier:
    """One abstract-interpretation pass over a traced step."""

    def __init__(self, m: int, algorithm: str = "",
                 placement: str = "local", channel: str = ""):
        self.m = m
        self.coords = dict(algorithm=algorithm, placement=placement,
                           channel=channel)
        self.findings: List[Finding] = []

    def _flag(self, code: str, msg: str, eqn, path: str) -> None:
        self.findings.append(Finding(
            code, "error", msg, eqn=format_eqn(eqn), path=path,
            **self.coords))

    # ---- the transfer function ------------------------------------------
    def _apply(self, env: _Env, eqn, path: str,
               ambient: bool = False) -> bool:
        prim = eqn.primitive.name
        # sub-jaxpr equations (cond branches, scan bodies) carry a name
        # stack relative to their caller, so a scope on the calling
        # equation covers everything nested under it (``ambient``)
        in_scope = ambient or comm_token(eqn) is not None
        changed = False

        def write_all(dims: Dims) -> None:
            nonlocal changed
            for ov in eqn.outvars:
                changed |= env.write(ov, dims)

        in_dims = [env.read(v) for v in eqn.invars]
        any_machine = any(in_dims)

        if prim in _COLLECTIVES:
            if not in_scope:
                self._flag("class-oob",
                           f"collective '{prim}' outside a communicator "
                           f"scope — cross-machine information flow the "
                           f"ledger never priced", eqn, path)
            write_all(_EMPTY)
            return changed
        if not any_machine:
            # machine data neither read nor fabricated (sources are
            # global): outputs are global; still recurse into sub-jaxprs
            # to catch scoped violations of nested machine values
            sub = _call_jaxprs(eqn)
            if sub is None and prim not in ("scan", "while", "cond"):
                write_all(_EMPTY)
                return changed

        try:
            if prim in _ELEMENTWISE:
                write_all(_union_elementwise(env, eqn))
            elif prim in _PASSTHROUGH:
                write_all(in_dims[0])
            elif prim in _GLOBAL_SOURCES:
                write_all(_EMPTY)
            elif prim in _REDUCES:
                axes = eqn.params.get("axes", ())
                dims = in_dims[0]
                hit = [a for a in axes if a in dims]
                if hit and not in_scope:
                    self._flag("class-oob",
                               f"'{prim}' collapses machine axis "
                               f"{hit[0]} outside a communicator scope",
                               eqn, path)
                write_all(_remap_removed(dims - frozenset(axes),
                                         axes))
            elif prim in _AXIS_OPS:
                ax = eqn.params.get("axis",
                                    eqn.params.get("dimension", None))
                dims = in_dims[0]
                if ax is not None and ax in dims and not in_scope:
                    self._flag("class-oob",
                               f"'{prim}' mixes values along machine "
                               f"axis {ax} outside a communicator "
                               f"scope", eqn, path)
                write_all(dims)
            elif prim == "dot_general":
                dims, viol = _dot_general(env, eqn, in_scope)
                if viol:
                    self._flag("class-oob",
                               "dot_general contracts a machine axis "
                               "outside a communicator scope", eqn,
                               path)
                write_all(dims)
            elif prim == "broadcast_in_dim":
                bd = eqn.params["broadcast_dimensions"]
                write_all(frozenset(bd[a] for a in in_dims[0]))
            elif prim == "reshape":
                write_all(_reshape_dims(in_dims[0], _shape(eqn.invars[0]),
                                        _shape(eqn.outvars[0]), self.m))
            elif prim == "transpose":
                perm = eqn.params["permutation"]
                write_all(frozenset(perm.index(a) for a in in_dims[0]))
            elif prim == "squeeze":
                write_all(_remap_removed(in_dims[0],
                                         eqn.params["dimensions"]))
            elif prim == "slice":
                dims = in_dims[0]
                starts = eqn.params["start_indices"]
                limits = eqn.params["limit_indices"]
                strides = eqn.params["strides"] or \
                    (1,) * len(starts)
                for a in sorted(dims):
                    kept = len(range(starts[a], limits[a], strides[a]))
                    if kept < self.m and not in_scope:
                        self._flag(
                            "class-leak",
                            f"slice keeps {kept} of {self.m} machines "
                            f"on axis {a} — local compute reading "
                            f"another machine's feature block", eqn,
                            path)
                write_all(dims)
            elif prim == "dynamic_slice":
                dims = in_dims[0]
                sizes = eqn.params["slice_sizes"]
                for a in sorted(dims):
                    if sizes[a] < self.m and not in_scope:
                        self._flag(
                            "class-leak",
                            f"dynamic_slice keeps {sizes[a]} of "
                            f"{self.m} machines on axis {a} — local "
                            f"compute reading another machine's "
                            f"feature block", eqn, path)
                write_all(dims)
            elif prim == "dynamic_update_slice":
                write_all(in_dims[0] | (in_dims[1]
                                        if _rank(eqn.invars[1])
                                        == _rank(eqn.invars[0])
                                        else _EMPTY))
            elif prim == "gather":
                dims, viol = _gather_dims(env, eqn, self.m)
                if viol and not in_scope:
                    self._flag("class-leak",
                               "gather selects a machine-axis subset — "
                               "local compute reading another "
                               "machine's feature block", eqn, path)
                write_all(dims)
            elif prim == "concatenate":
                ax = eqn.params["dimension"]
                dims: Dims = _EMPTY
                for d in in_dims:
                    if ax in d:
                        raise _Unmodeled("concatenate along a machine "
                                         "axis")
                    dims = dims | d
                write_all(dims)
            elif prim == "pad":
                dims = in_dims[0]
                cfg = eqn.params["padding_config"]
                for a in dims:
                    lo, hi, interior = cfg[a]
                    if lo or hi or interior:
                        raise _Unmodeled("pad alters a machine axis")
                write_all(dims)
            elif prim == "optimization_barrier":
                for iv, ov in zip(eqn.invars, eqn.outvars):
                    changed |= env.write(ov, env.read(iv))
            elif prim == "while":
                cn = eqn.params["cond_nconsts"]
                bn = eqn.params["body_nconsts"]
                changed |= self._fixpoint_call(
                    env, eqn, eqn.params["body_jaxpr"].jaxpr,
                    list(eqn.invars[cn:]), list(eqn.outvars), path,
                    skip_in=bn, ambient=in_scope)
                cond_j = eqn.params["cond_jaxpr"].jaxpr
                cond_dims = ([env.read(v) for v in eqn.invars[:cn]]
                             + [env.read(v)
                                for v in eqn.invars[cn + bn:]])
                self._run(_Env(), cond_j, path + ".cond.", cond_dims,
                          ambient=in_scope)
            elif prim == "cond":
                branches = eqn.params["branches"]
                op_dims = in_dims[1:]
                out_dims = [_EMPTY] * len(eqn.outvars)
                for bi, br in enumerate(branches):
                    sub = br.jaxpr if hasattr(br, "jaxpr") else br
                    outs = self._run(_Env(), sub,
                                     f"{path}.branches[{bi}].", op_dims,
                                     ambient=in_scope)
                    out_dims = [a | b for a, b in zip(out_dims, outs)]
                for ov, d in zip(eqn.outvars, out_dims):
                    changed |= env.write(ov, d)
            elif prim == "scan":
                changed |= self._scan(env, eqn, path, in_scope)
            else:
                sub = _call_jaxprs(eqn)
                if sub is not None:
                    outs = self._run(_Env(), sub, f"{path}.{prim}.",
                                     in_dims, ambient=in_scope)
                    for ov, d in zip(eqn.outvars, outs):
                        changed |= env.write(ov, d)
                elif any_machine:
                    raise _Unmodeled(f"no machine-axis rule for "
                                     f"primitive '{prim}'")
                else:
                    write_all(_EMPTY)
        except _Unmodeled as e:
            if in_scope:
                # inside a communicator scope the ops ARE the metered
                # message transform (e.g. the int8 quantizer's bitcast);
                # the whole scope is priced, so its values demote to
                # global rather than blocking certification
                write_all(_EMPTY)
            else:
                self._flag("class-unknown",
                           f"cannot certify past this equation: {e}",
                           eqn, path)
                write_all(_EMPTY)
        return changed

    def _scan(self, env: _Env, eqn, path: str,
              ambient: bool = False) -> bool:
        body = eqn.params["jaxpr"].jaxpr
        nc = eqn.params["num_consts"]
        ncar = eqn.params["num_carry"]
        in_dims = []
        for i, v in enumerate(eqn.invars):
            d = env.read(v)
            if i >= nc + ncar:
                if 0 in d:
                    raise _Unmodeled("scan iterates over a machine "
                                     "axis")
                d = frozenset(a - 1 for a in d)
            in_dims.append(d)
        # fixpoint over the carry
        for _ in range(4):
            outs = self._run(_Env(), body, f"{path}.body.", in_dims,
                             quiet=True, ambient=ambient)
            new_carry = [a | b for a, b in
                         zip(in_dims[nc:nc + ncar], outs[:ncar])]
            if new_carry == in_dims[nc:nc + ncar]:
                break
            in_dims[nc:nc + ncar] = new_carry
        outs = self._run(_Env(), body, f"{path}.body.", in_dims,
                         ambient=ambient)
        changed = False
        for i, ov in enumerate(eqn.outvars):
            if i < ncar:
                d = outs[i]
            else:
                d = frozenset(a + 1 for a in outs[i])
            changed |= env.write(ov, d)
        return changed

    def _fixpoint_call(self, env: _Env, eqn, body, invars, outvars,
                       path: str, skip_in: int,
                       ambient: bool = False) -> bool:
        in_dims = [env.read(v) for v in invars]
        for _ in range(4):
            outs = self._run(_Env(), body, f"{path}.body.", in_dims,
                             quiet=True, ambient=ambient)
            new_state = [a | b for a, b in
                         zip(in_dims[skip_in:], outs)]
            if new_state == in_dims[skip_in:]:
                break
            in_dims[skip_in:] = new_state
        outs = self._run(_Env(), body, f"{path}.body.", in_dims,
                         ambient=ambient)
        changed = False
        for ov, d in zip(outvars, outs):
            changed |= env.write(ov, d)
        return changed

    def _run(self, env: _Env, jaxpr, path: str,
             in_dims: List[Dims], quiet: bool = False,
             ambient: bool = False) -> List[Dims]:
        if quiet:
            saved = self.findings
            self.findings = []
        for v, d in zip(jaxpr.invars, in_dims):
            env.write(v, d)
        for i, eqn in enumerate(jaxpr.eqns):
            self._apply(env, eqn, f"{path}eqns[{i}]", ambient=ambient)
        outs = [env.read(v) for v in jaxpr.outvars]
        if quiet:
            self.findings = saved
        return outs

    # ---- entry point ----------------------------------------------------
    def certify_step(self, ts: TracedStep) -> List[Finding]:
        """Certify one traced step: consts/carry/xs classified by the
        audit-instance shape convention (leading axis of size m is the
        machine axis), then propagate."""
        jaxpr = ts.closed.jaxpr
        env = _Env()
        for cv, c in zip(jaxpr.constvars, ts.consts):
            shp = tuple(getattr(c, "shape", ()))
            env.write(cv, frozenset({0}) if shp and shp[0] == self.m
                      else _EMPTY)
        in_dims = []
        for v in jaxpr.invars:
            shp = _shape(v)
            in_dims.append(frozenset({0})
                           if shp and shp[0] == self.m else _EMPTY)
        n0 = len(self.findings)
        for v, d in zip(jaxpr.invars, in_dims):
            env.write(v, d)
        for i, eqn in enumerate(jaxpr.eqns):
            self._apply(env, eqn, f"eqns[{i}]")
        return self.findings[n0:]


def certify_sharded_class(closed, algorithm: str = "",
                          channel: str = "") -> List[Finding]:
    """Under the sharded placement machines are mesh shards, so the
    class boundary is syntactic: every collective primitive must sit
    inside a communicator scope."""
    out: List[Finding] = []
    for eqn, path in iter_eqns(closed.jaxpr):
        if eqn.primitive.name in _COLLECTIVES \
                and comm_token(eqn) is None:
            out.append(Finding(
                "class-oob", "error",
                f"collective '{eqn.primitive.name}' outside a "
                f"communicator scope — cross-machine information flow "
                f"the ledger never priced", eqn=format_eqn(eqn),
                path=path, algorithm=algorithm, placement="sharded",
                channel=channel))
    return out


def thm4_payload_findings(steps: List[TracedStep], program,
                          algorithm: str = "",
                          channel: str = "") -> List[Finding]:
    """Theorem 4's restriction on incremental algorithms: repeated
    (inner, count > 1) segments may ship only O(1) scalars per round —
    a vector payload in an inner round breaks the bound's premise."""
    out: List[Finding] = []
    for s, seg in enumerate(program.segments):
        if int(seg.count) <= 1:
            continue   # snapshot/full rounds may carry R^n payloads
        for ts in steps:
            if s not in ts.segments:
                continue
            for rec in ts.records:
                if tuple(rec.shape) != ():
                    out.append(Finding(
                        "thm4-payload", "error",
                        f"incremental inner segment {s} (count "
                        f"{seg.count}) ships a {rec.dtype}"
                        f"{tuple(rec.shape)} payload ({rec.tag!r}); "
                        f"Theorem 4 prices inner rounds as O(1) "
                        f"scalars", algorithm=algorithm,
                        placement="local", channel=channel))
            break
    return out


__all__ = ["ClassCertifier", "certify_sharded_class",
           "thm4_payload_findings"]
