"""Compile-hazard and determinism lints over traced step programs.

These are the failure modes that don't corrupt a single run but corrupt
*fleets* of runs:

* ``lint-rng`` — an RNG primitive inside a step jaxpr.  Stochastic
  choices must be pre-drawn into the scanned ``xs`` (as DSVRG's sampled
  row indices are): in-step RNG would make the trace-once schedule a
  sample rather than a certificate, and replaying the compiled step
  twice would disagree with the eager engine.
* ``lint-group-split`` — the same algorithm, traced on two instances
  that differ only in hyper-parameter *values*, must produce identical
  structure text; ``execute_batch`` groups on that text, so a baked-in
  python float silently splits what should be one compiled group into
  one compile per cell.  The diff names the first diverging jaxpr line.
* ``lint-weak-literal`` — weak-typed float literals in the structure
  (reported as context: each is a value that *would* split groups the
  moment it varies per cell; the algorithm builders wrap their hypers
  in ``jnp.float32`` to hoist them into consts for exactly this
  reason).
"""
from __future__ import annotations

from typing import List, Tuple

import jax

from .extract import TracedStep, format_eqn, iter_eqns
from .findings import Finding

_RNG_PRIMS = {
    "threefry2x32", "rng_bit_generator", "random_seed", "random_wrap",
    "random_bits", "random_fold_in", "random_split", "random_gamma",
}


def lint_rng(steps: List[TracedStep], algorithm: str = "",
             channel: str = "") -> List[Finding]:
    out: List[Finding] = []
    for ts in steps:
        for eqn, path in iter_eqns(ts.closed.jaxpr):
            if eqn.primitive.name in _RNG_PRIMS:
                out.append(Finding(
                    "lint-rng", "error",
                    f"RNG primitive '{eqn.primitive.name}' inside the "
                    f"step for segment(s) {ts.segments}; stochastic "
                    f"choices must be pre-drawn into the scanned xs so "
                    f"the traced schedule is a certificate, not a "
                    f"sample", eqn=format_eqn(eqn), path=path,
                    algorithm=algorithm, channel=channel))
    return out


def lint_weak_literals(steps: List[TracedStep], algorithm: str = "",
                       channel: str = "") -> List[Finding]:
    out: List[Finding] = []
    for ts in steps:
        seen = set()
        for eqn, path in iter_eqns(ts.closed.jaxpr):
            for v in eqn.invars:
                if not isinstance(v, jax.core.Literal):
                    continue
                aval = v.aval
                if getattr(aval, "weak_type", False) \
                        and getattr(aval, "dtype", None) is not None \
                        and aval.dtype.kind == "f":
                    key = (float(v.val), path)
                    if key in seen:
                        continue
                    seen.add(key)
                    out.append(Finding(
                        "lint-weak-literal", "info",
                        f"weak-typed float literal {float(v.val)!r} "
                        f"baked into the structure of segment(s) "
                        f"{ts.segments}; if this value ever varies per "
                        f"cell it will split execute_batch groups",
                        eqn=format_eqn(eqn), path=path,
                        algorithm=algorithm, channel=channel))
    return out


def _first_diff(a: str, b: str) -> Tuple[int, str, str]:
    la, lb = a.splitlines(), b.splitlines()
    for i, (x, y) in enumerate(zip(la, lb)):
        if x != y:
            return i + 1, x.strip(), y.strip()
    return min(len(la), len(lb)) + 1, "<end>", "<end>"


def lint_group_stability(structures_a: List[str],
                         structures_b: List[str],
                         algorithm: str = "",
                         channel: str = "") -> List[Finding]:
    """Structure texts of the same algorithm traced under two
    hyper-parameter settings: any textual difference is a group split
    (the hyper leaked into the jaxpr instead of hoisting into a
    const)."""
    out: List[Finding] = []
    if len(structures_a) != len(structures_b):
        out.append(Finding(
            "lint-group-split", "error",
            f"hyper-parameter change altered the SEGMENT structure "
            f"({len(structures_a)} vs {len(structures_b)} distinct "
            f"steps)", algorithm=algorithm, channel=channel))
        return out
    for si, (sa, sb) in enumerate(zip(structures_a, structures_b)):
        if sa == sb:
            continue
        line, xa, xb = _first_diff(sa, sb)
        out.append(Finding(
            "lint-group-split", "error",
            f"step {si}: structure text diverges at jaxpr line {line} "
            f"under a pure hyper-parameter change — execute_batch "
            f"would compile this group once per cell.  "
            f"first diff: {xa!r} vs {xb!r}",
            algorithm=algorithm, channel=channel))
    return out


__all__ = ["lint_group_stability", "lint_rng", "lint_weak_literals"]
