"""Typed findings and the audit report schema.

Every analysis in ``repro.analysis`` reports through one vocabulary: a
``Finding`` names the check that fired (``code``), how bad it is
(``severity``), where it fired (cell coordinates plus — when the check
anchors to a traced operation — the offending jaxpr equation and its
path), and what went wrong (``message``).  ``CellAudit`` collects one
audited (algorithm, placement, channel) cell; ``AuditReport`` is the
registry-wide result the CLI serializes to ``docs/results/
static-audit.{json,md}``.  The schema round-trips through plain dicts
(``to_dict``/``from_dict``) so served or archived audits can be
re-loaded and re-gated without re-tracing anything.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, List

SEVERITIES = ("error", "warning", "info")

# the closed vocabulary of checks; gating logic and tests match on these
CODES = (
    # schedule conformance
    "sched-count",      # static message count != captured record count
    "sched-field",      # kind/shape/dtype/bits/wire/direction/tag mismatch
    "sched-round",      # message sits in the wrong round
    "sched-anchor",     # scope carries no anchoring reduce/collective op
    "sched-index",      # scope record indices non-contiguous / duplicated
    "sched-scope",      # malformed or orphaned comm scope token
    "sched-replay",     # static expansion != trace-once ledger replay
    "sched-dynamic",    # static expansion != an executed run's ledger
    # algorithm-class certification
    "class-leak",       # machine-axis slice/gather outside a comm scope
    "class-oob",        # cross-machine combination outside a comm scope
    "class-unknown",    # propagation hit an unmodeled primitive (unsound
                        # to certify past it)
    "thm4-payload",     # incremental inner round ships a non-scalar
    # compile-hazard / determinism lints
    "lint-rng",         # RNG primitive inside a step jaxpr
    "lint-group-split", # same algorithm, different hypers -> different
                        # structure text (execute_batch group split)
    "lint-weak-literal",# weak-typed float literal baked into structure
)


@dataclasses.dataclass(frozen=True)
class Finding:
    """One typed analysis finding."""

    code: str
    severity: str
    message: str
    algorithm: str = ""
    placement: str = ""
    channel: str = ""
    # the offending jaxpr equation (pretty-printed, truncated) and its
    # path inside the traced program, e.g. "segment[1].eqns[7]"
    eqn: str = ""
    path: str = ""

    def __post_init__(self):
        if self.code not in CODES:
            raise ValueError(f"unknown finding code {self.code!r}")
        if self.severity not in SEVERITIES:
            raise ValueError(f"unknown severity {self.severity!r}")

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "Finding":
        return cls(**d)

    def __str__(self) -> str:
        where = ""
        if self.path:
            where = f" at {self.path}"
            if self.eqn:
                where += f" ({self.eqn})"
        return f"[{self.code}/{self.severity}] {self.message}{where}"


@dataclasses.dataclass
class CellAudit:
    """One audited (algorithm, placement, channel) cell."""

    algorithm: str
    placement: str
    channel: str
    backend: str = ""
    engine: str = ""
    instance: str = ""
    # static schedule stats (from the verified expansion)
    messages: int = 0          # wire messages per full run
    rounds: int = 0
    total_bits: int = 0
    findings: List[Finding] = dataclasses.field(default_factory=list)
    # non-empty when the combination is not applicable (e.g. a
    # local-only algorithm under the sharded placement) — skipped cells
    # carry the plan-time rejection and do not count as verified
    skipped: str = ""
    executed: bool = False     # dynamic (executed-run) cross-check ran

    @property
    def ok(self) -> bool:
        return not self.skipped and not any(
            f.severity == "error" for f in self.findings)

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["findings"] = [f.to_dict() for f in self.findings]
        d["ok"] = self.ok
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "CellAudit":
        d = dict(d)
        d.pop("ok", None)
        d["findings"] = [Finding.from_dict(f) for f in d.get("findings", [])]
        return cls(**d)


@dataclasses.dataclass
class FixtureResult:
    """One mutation fixture: a deliberately out-of-class program that the
    verifier must reject with the expected finding code."""

    name: str
    expect_codes: List[str]
    rejected: bool
    findings: List[Finding] = dataclasses.field(default_factory=list)

    def to_dict(self) -> Dict[str, Any]:
        d = dataclasses.asdict(self)
        d["findings"] = [f.to_dict() for f in self.findings]
        return d

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "FixtureResult":
        d = dict(d)
        d["findings"] = [Finding.from_dict(f) for f in d.get("findings", [])]
        return cls(**d)


@dataclasses.dataclass
class AuditReport:
    """The registry-wide static audit."""

    cells: List[CellAudit] = dataclasses.field(default_factory=list)
    fixtures: List[FixtureResult] = dataclasses.field(default_factory=list)
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def ok(self) -> bool:
        return (all(c.ok or c.skipped for c in self.cells)
                and all(f.rejected for f in self.fixtures))

    def errors(self) -> List[Finding]:
        return [f for c in self.cells for f in c.findings
                if f.severity == "error"]

    def to_dict(self) -> Dict[str, Any]:
        return {
            "schema": "repro.analysis/static-audit/v1",
            "meta": self.meta,
            "ok": self.ok,
            "cells": [c.to_dict() for c in self.cells],
            "fixtures": [f.to_dict() for f in self.fixtures],
        }

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "AuditReport":
        return cls(
            cells=[CellAudit.from_dict(c) for c in d.get("cells", [])],
            fixtures=[FixtureResult.from_dict(f)
                      for f in d.get("fixtures", [])],
            meta=dict(d.get("meta", {})),
        )

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), indent=2, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "AuditReport":
        return cls.from_dict(json.loads(text))

    # ---- markdown rendering ---------------------------------------------
    def to_markdown(self) -> str:
        lines: List[str] = []
        lines.append("# Static communication audit")
        lines.append("")
        verdict = "PASS" if self.ok else "FAIL"
        lines.append(f"**Verdict: {verdict}** — every row below is "
                     "proved from the traced jaxpr alone; `dynamic` "
                     "marks rows additionally cross-checked against an "
                     "executed run's ledger.")
        lines.append("")
        if self.meta:
            for k in sorted(self.meta):
                lines.append(f"- {k}: `{self.meta[k]}`")
            lines.append("")
        lines.append("## Schedule conformance × class certification")
        lines.append("")
        lines.append("| algorithm | placement | channel | messages | "
                     "rounds | wire bits | dynamic | status |")
        lines.append("|---|---|---|---:|---:|---:|:-:|---|")
        for c in self.cells:
            if c.skipped:
                status = f"skipped: {c.skipped}"
                stats = ("—", "—", "—")
            else:
                nerr = sum(1 for f in c.findings if f.severity == "error")
                status = "ok" if not nerr else f"{nerr} error(s)"
                stats = (str(c.messages), str(c.rounds), str(c.total_bits))
            lines.append(
                f"| {c.algorithm} | {c.placement} | `{c.channel}` | "
                f"{stats[0]} | {stats[1]} | {stats[2]} | "
                f"{'yes' if c.executed else 'no'} | {status} |")
        lines.append("")
        flagged = [(c, f) for c in self.cells for f in c.findings
                   if f.severity != "info"]
        if flagged:
            lines.append("## Findings")
            lines.append("")
            for c, f in flagged:
                lines.append(f"- `{c.algorithm}/{c.placement}/"
                             f"{c.channel}`: {f}")
            lines.append("")
        if self.fixtures:
            lines.append("## Mutation fixtures (must be rejected)")
            lines.append("")
            lines.append("| fixture | expected finding | rejected | "
                         "fired |")
            lines.append("|---|---|---|---|")
            for fx in self.fixtures:
                fired = ", ".join(sorted({f.code for f in fx.findings})) \
                    or "—"
                lines.append(
                    f"| {fx.name} | {', '.join(fx.expect_codes)} | "
                    f"{'yes' if fx.rejected else 'NO'} | {fired} |")
            lines.append("")
        return "\n".join(lines) + "\n"


def summarize(findings: List[Finding], limit: int = 3) -> str:
    """A one-line digest for exception messages."""
    errs = [f for f in findings if f.severity == "error"]
    head = "; ".join(str(f) for f in errs[:limit])
    more = len(errs) - limit
    return head + (f"; … {more} more" if more > 0 else "")


__all__ = [
    "AuditReport", "CellAudit", "Finding", "FixtureResult", "CODES",
    "SEVERITIES", "summarize",
]
