"""Core — the paper's contribution, operational.

Feature-partitioned distributed convex optimization: the algorithm family
of Definition 1 (and its incremental variant), the hard instances and
closed-form lower bounds of Theorems 2-4, the feasible-set certifier for
Lemma 5 / Corollary 6, and the metered communication model.
"""
from .partition import FeaturePartition, even_partition
from .erm import (ERMProblem, GLMLoss, LOSSES, logistic_loss,
                  make_random_erm, squared_hinge_loss, squared_loss)
from .hard_instance import (ChainInstance, SeparableInstance, chain_matrix,
                            smooth_convex_lower_bound_rounds, tridiag_bands,
                            tridiag_matvec)
from .bounds import (BoundReport, agd_smooth_upper_bound, agd_upper_bound,
                     gd_upper_bound, thm2_strongly_convex, thm3_smooth_convex,
                     thm4_incremental)
from .channel import CHANNELS, Channel, parse_channel
from .comm import (CollectiveAudit, CommLedger, CommRecord,
                   LocalCommunicator, ShardMapCommunicator,
                   collective_bytes_from_hlo, inject_crash_recovery)
from .faults import (FaultRecoveryError, FaultSpec, NO_FAULTS, parse_faults)
from .feasible_set import SpanOracle

__all__ = [
    "FeaturePartition", "even_partition",
    "ERMProblem", "GLMLoss", "LOSSES", "logistic_loss", "make_random_erm",
    "squared_hinge_loss", "squared_loss",
    "ChainInstance", "SeparableInstance", "chain_matrix",
    "smooth_convex_lower_bound_rounds", "tridiag_bands", "tridiag_matvec",
    "BoundReport", "agd_smooth_upper_bound", "agd_upper_bound",
    "gd_upper_bound", "thm2_strongly_convex", "thm3_smooth_convex",
    "thm4_incremental",
    "CHANNELS", "Channel", "parse_channel",
    "FaultRecoveryError", "FaultSpec", "NO_FAULTS", "parse_faults",
    "CollectiveAudit", "CommLedger", "CommRecord", "LocalCommunicator",
    "ShardMapCommunicator", "collective_bytes_from_hlo",
    "inject_crash_recovery",
    "SpanOracle",
]
