"""Seeded, declarative fault model for the communicator boundary.

The paper prices every message that crosses the machine boundary; a real
deployment also pays for the messages that cross it *twice* because the
first copy was dropped or corrupted, and for the rounds a crashed machine
spends replaying from its last snapshot.  This module is the declarative
half of that story: a :class:`FaultSpec` names a deterministic schedule of
injected faults, and the communicator/engine layers consult it to decide
*which* message fails, *how*, and *what the recovery costs*.

Design rules (mirroring ``core/channel.py``):

- A fault spec is named by a canonical string (the ``faults=`` RunSpec
  axis).  ``"none"`` is the inactive spec; everything else is
  ``inject:key=value,...``.
- Every fault decision is a pure function of ``(seed, message index)`` or
  ``(seed, algorithm round)`` — never of payload values, wall clock, or
  engine.  The python engine (which injects eagerly, corrupting real
  arrays) and the scan engine (which injects during ledger replay)
  therefore price the *identical* recovery stream bit for bit.
- Recovery is value-transparent: a faulted message is retransmitted until
  a clean copy arrives, so delivered payloads — and hence all computed
  results — are bit-identical to the fault-free run.  Only the ledger
  (extra ``retransmit=True`` records, extra recovery rounds) differs.

Grammar::

    none
    inject:seed=<int>[,drop=<p>][,flip=<p>][,straggle=<p>x<rounds>]
                     [,crash=<round>][,snap=<every>][,resend=<max>]

- ``drop=p``      each wire message is dropped (timeout -> NACK -> resend)
                  independently with probability ``p`` per attempt.
- ``flip=p``      each wire message has one bit flipped in transit with
                  probability ``p`` per attempt (checksum -> NACK -> resend).
- ``straggle=pxr`` after each algorithm round, with probability ``p`` the
                  slowest machine straggles for ``r`` extra (empty) rounds.
- ``crash=k``     the center crashes after completing algorithm round ``k``
                  (1-based) and replays rounds since its last snapshot.
- ``snap=s``      snapshot cadence for crash recovery (default 1).
- ``resend=n``    max resend attempts per message before giving up
                  (default 4); exceeding it raises FaultRecoveryError.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple, Union

import numpy as np

__all__ = [
    "FaultSpec",
    "NO_FAULTS",
    "parse_faults",
    "FaultRecoveryError",
    "checksum",
    "corrupt",
    "NACK_BITS",
]

# A NACK is a single 32-bit control scalar sent center->worker to request a
# resend.  Checksums ride in the (unpriced) message header, exactly like
# the shape/dtype metadata the ledger already treats as free; the NACK and
# the resent payload are the only *priced* recovery traffic, which is what
# makes ``total_bits == clean_bits + retransmit_bits`` exact.
NACK_BITS = 32


class FaultRecoveryError(RuntimeError):
    """Recovery budget exceeded (message unrecoverable within ``resend=``)."""


def _mix(*keys: int) -> int:
    """splitmix64-style avalanche over a tuple of integer keys.

    Pure python, 64-bit wraparound; deterministic across platforms and
    engines (never traced, never dependent on payload values).
    """
    h = 0x9E3779B97F4A7C15
    for k in keys:
        h = (h ^ (int(k) & 0xFFFFFFFFFFFFFFFF)) * 0xBF58476D1CE4E5B9 % (1 << 64)
        h ^= h >> 27
        h = h * 0x94D049BB133111EB % (1 << 64)
        h ^= h >> 31
    return h


def _uniform(*keys: int) -> float:
    """Deterministic uniform in [0, 1) from integer keys (53-bit mantissa)."""
    return (_mix(*keys) >> 11) / float(1 << 53)


# Domain-separation tags so drop/flip/straggle draws never alias.
_DOM_DROP = 0xD809
_DOM_FLIP = 0xF11D
_DOM_STRAGGLE = 0x57A6
_DOM_SITE = 0x517E


def checksum(arr) -> int:
    """XOR-fold checksum over the raw bytes of ``arr`` (uint32 words).

    A single flipped bit always changes exactly one bit of the fold, so
    every single-bit corruption this module injects is detected.
    """
    a = np.ascontiguousarray(np.asarray(arr))
    buf = a.view(np.uint8).reshape(-1)
    pad = (-buf.size) % 4
    if pad:
        buf = np.concatenate([buf, np.zeros(pad, dtype=np.uint8)])
    words = buf.view(np.uint32)
    return int(np.bitwise_xor.reduce(words)) if words.size else 0


def corrupt(arr, seed: int, msg: int, attempt: int) -> np.ndarray:
    """Return a copy of ``arr`` with one deterministic bit flipped in transit."""
    a = np.ascontiguousarray(np.asarray(arr)).copy()
    flat = a.view(np.uint8).reshape(-1)
    if flat.size == 0:
        return a
    h = _mix(seed, _DOM_SITE, msg, attempt)
    byte = h % flat.size
    bit = (h >> 17) % 8
    flat[byte] ^= np.uint8(1 << bit)
    return a


def _parse_prob(key: str, val: str) -> float:
    try:
        p = float(val)
    except ValueError:
        raise ValueError(f"bad probability {val!r} for {key}=") from None
    if not 0.0 <= p <= 1.0:
        raise ValueError(f"probability {key}={p:g} outside [0, 1]")
    return p


def _parse_int(key: str, val: str, lo: int) -> int:
    try:
        n = int(val)
    except ValueError:
        raise ValueError(f"bad integer {val!r} for {key}=") from None
    if n < lo:
        raise ValueError(f"{key}={n} must be >= {lo}")
    return n


@dataclass(frozen=True)
class FaultSpec:
    """A canonical, seeded fault schedule (the ``faults=`` RunSpec axis)."""

    seed: int = 0
    drop: float = 0.0
    flip: float = 0.0
    straggle: float = 0.0
    straggle_rounds: int = 1
    crash_round: Optional[int] = None
    snapshot_every: int = 1
    max_resend: int = 4

    # ------------------------------------------------------------------
    # identity
    # ------------------------------------------------------------------
    @property
    def active(self) -> bool:
        return bool(self.drop or self.flip or self.straggle or self.crash_round)

    @property
    def name(self) -> str:
        """Canonical string form (parse -> name is idempotent)."""
        if not self.active:
            return "none"
        parts = [f"seed={self.seed}"]
        if self.drop:
            parts.append(f"drop={self.drop:g}")
        if self.flip:
            parts.append(f"flip={self.flip:g}")
        if self.straggle:
            parts.append(f"straggle={self.straggle:g}x{self.straggle_rounds}")
        if self.crash_round is not None:
            parts.append(f"crash={self.crash_round}")
            if self.snapshot_every != 1:
                parts.append(f"snap={self.snapshot_every}")
        if self.max_resend != 4:
            parts.append(f"resend={self.max_resend}")
        return "inject:" + ",".join(parts)

    # ------------------------------------------------------------------
    # per-message decisions (keyed on the wire-message index)
    # ------------------------------------------------------------------
    def attempts(self, msg: int) -> Tuple[str, ...]:
        """Failure kinds for the failed attempts of wire message ``msg``.

        Returns e.g. ``("drop", "flip")`` meaning attempt 0 was dropped,
        attempt 1 corrupted, attempt 2 clean — so two NACK+resend pairs
        are priced.  Raises :class:`FaultRecoveryError` if the message
        fails more than ``max_resend`` times.
        """
        out: List[str] = []
        for a in range(self.max_resend + 1):
            if self.drop and _uniform(self.seed, _DOM_DROP, msg, a) < self.drop:
                out.append("drop")
            elif self.flip and _uniform(self.seed, _DOM_FLIP, msg, a) < self.flip:
                out.append("flip")
            else:
                return tuple(out)
        raise FaultRecoveryError(
            f"message {msg} unrecoverable: {self.max_resend + 1} consecutive "
            f"faulted attempts under {self.name!r}"
        )

    def straggle_delay(self, algo_round: int) -> int:
        """Extra (empty) rounds injected after 0-based algorithm round."""
        if not self.straggle:
            return 0
        if _uniform(self.seed, _DOM_STRAGGLE, algo_round) < self.straggle:
            return self.straggle_rounds
        return 0

    # ------------------------------------------------------------------
    # crash bookkeeping
    # ------------------------------------------------------------------
    def snapshot_round(self) -> int:
        """Last snapshotted algorithm round before the crash (may be 0)."""
        if self.crash_round is None:
            return 0
        return ((self.crash_round - 1) // self.snapshot_every) * self.snapshot_every

    def crash_span(self, total_rounds: int) -> Tuple[int, int]:
        """(snapshot round s, crash round k): rounds s+1..k are replayed.

        Returns ``(0, 0)`` when no crash fires within ``total_rounds``.
        """
        k = self.crash_round
        if k is None or k > total_rounds:
            return (0, 0)
        return (self.snapshot_round(), k)

    def declared_recovery_rounds(self, total_rounds: int) -> int:
        """The recovery budget: extra wire rounds the schedule will inject.

        Deterministic (data-independent), so it can be *declared* before a
        run and certified ``==`` measured afterwards: straggle delays over
        every algorithm round plus the crash replay span.
        """
        extra = sum(self.straggle_delay(r) for r in range(total_rounds))
        s, k = self.crash_span(total_rounds)
        return extra + (k - s)

    def __str__(self) -> str:  # pragma: no cover - convenience
        return self.name


NO_FAULTS = FaultSpec()


def parse_faults(spec: Union[str, FaultSpec, None]) -> FaultSpec:
    """Parse a ``faults=`` axis value into a :class:`FaultSpec`.

    Accepts an existing FaultSpec (pass-through), ``None``/``"none"`` (no
    faults), or the ``inject:...`` grammar above.  Raises ``ValueError``
    naming the offending segment, in the ``parse_channel`` style.
    """
    if isinstance(spec, FaultSpec):
        return spec
    if spec is None:
        return NO_FAULTS
    name = spec.strip()
    if name in ("", "none"):
        return NO_FAULTS
    if not name.startswith("inject:"):
        raise ValueError(
            f"faults {name!r}: expected 'none' or 'inject:key=value,...'"
        )
    kw = {}
    seen = set()
    for seg in name[len("inject:"):].split(","):
        seg = seg.strip()
        if not seg:
            raise ValueError(f"faults {name!r}: empty segment")
        if "=" not in seg:
            raise ValueError(f"faults {name!r}: bad segment {seg!r}: missing '='")
        key, _, val = seg.partition("=")
        key = key.strip()
        val = val.strip()
        if key in seen:
            raise ValueError(f"faults {name!r}: duplicate key {key!r}")
        seen.add(key)
        try:
            if key == "seed":
                kw["seed"] = _parse_int(key, val, 0)
            elif key == "drop":
                kw["drop"] = _parse_prob(key, val)
            elif key == "flip":
                kw["flip"] = _parse_prob(key, val)
            elif key == "straggle":
                p, _, r = val.partition("x")
                kw["straggle"] = _parse_prob(key, p)
                kw["straggle_rounds"] = _parse_int(key, r, 1) if r else 1
            elif key == "crash":
                kw["crash_round"] = _parse_int(key, val, 1)
            elif key == "snap":
                kw["snapshot_every"] = _parse_int(key, val, 1)
            elif key == "resend":
                kw["max_resend"] = _parse_int(key, val, 1)
            else:
                raise ValueError(f"unknown key {key!r}")
        except ValueError as e:
            raise ValueError(f"faults {name!r}: bad segment {seg!r}: {e}") from None
    if "snapshot_every" in kw and "crash_round" not in kw:
        raise ValueError(f"faults {name!r}: snap= requires crash=")
    f = FaultSpec(**kw)
    if f.drop >= 1.0 or f.flip >= 1.0:
        raise ValueError(
            f"faults {name!r}: drop/flip probability 1.0 is unrecoverable"
        )
    return f
