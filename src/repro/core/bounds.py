"""Closed-form communication-round lower bounds (the paper's Theorems 2-4).

These are the paper's *results*, packaged as callables so benchmarks and
tests can overlay measured algorithm round counts against them. Each bound
returns the number of communication rounds required to reach an
eps-suboptimal point for the corresponding hard instance.
"""
from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class BoundReport:
    theorem: str
    rounds: float
    params: dict


def thm2_strongly_convex(kappa: float, lam: float, norm_w_star: float,
                         eps: float) -> BoundReport:
    """Omega( sqrt(kappa) log( lam |w*| / eps ) )  — with the proof's
    constants:  k >= (sqrt(kappa)-1)/4 * log( lam |w*|^2 / ((sqrt(kappa)+1) eps) ).
    """
    rk = math.sqrt(kappa)
    arg = lam * norm_w_star ** 2 / ((rk + 1.0) * eps)
    rounds = 0.0 if arg <= 1.0 else (rk - 1.0) / 4.0 * math.log(arg)
    return BoundReport("thm2", max(0.0, rounds),
                       dict(kappa=kappa, lam=lam, norm_w_star=norm_w_star,
                            eps=eps))


def thm3_smooth_convex(L: float, norm_w_star: float, eps: float) -> BoundReport:
    """Omega( sqrt(L/eps) |w*| )  (Nesterov 2.1.7 constant: the proof
    replaces [13, Lemma 2.1.3] with the paper's Corollary 6)."""
    rounds = math.sqrt(3.0 * L * norm_w_star ** 2 / (32.0 * eps)) - 1.0
    return BoundReport("thm3", max(0.0, rounds),
                       dict(L=L, norm_w_star=norm_w_star, eps=eps))


def thm4_incremental(n: int, kappa: float, lam: float, norm_w_star: float,
                     eps: float) -> BoundReport:
    """Omega( (sqrt(n kappa) + n) log( lam |w*| / eps ) ) — from the proof's
    display:  E|w^(k)-w*|^2 >= 1/2 exp(-4 k sqrt(kappa) /
    (n (sqrt(kappa)+1)^2 - 4 sqrt(kappa))) |w*|^2, then strong convexity."""
    rk = math.sqrt(kappa)
    arg = lam * norm_w_star ** 2 / (4.0 * eps)
    if arg <= 1.0:
        return BoundReport("thm4", 0.0, dict(n=n, kappa=kappa, lam=lam,
                                             norm_w_star=norm_w_star, eps=eps))
    coef = (n * (rk + 1.0) ** 2 - 4.0 * rk) / (4.0 * rk)
    rounds = coef * math.log(arg)
    return BoundReport("thm4", max(0.0, rounds),
                       dict(n=n, kappa=kappa, lam=lam,
                            norm_w_star=norm_w_star, eps=eps))


# ---- matching upper bounds (for tightness overlays) -----------------------

def agd_upper_bound(kappa: float, lam: float, norm_w0_star: float,
                    eps: float) -> float:
    """Rounds for distributed Nesterov AGD on a lam-strongly-convex,
    L=kappa*lam-smooth f:  f(x_k)-f* <= L |x0-x*|^2 exp(-k/sqrt(kappa))."""
    L = kappa * lam
    arg = L * norm_w0_star ** 2 / eps
    return 0.0 if arg <= 1.0 else math.sqrt(kappa) * math.log(arg)


def agd_smooth_upper_bound(L: float, norm_w0_star: float, eps: float) -> float:
    """Rounds for AGD on smooth convex f: f(x_k)-f* <= 2 L |x0-x*|^2/(k+1)^2."""
    return max(0.0, math.sqrt(2.0 * L * norm_w0_star ** 2 / eps) - 1.0)


def gd_upper_bound(kappa: float, lam: float, norm_w0_star: float,
                   eps: float) -> float:
    """Plain GD: O(kappa log(...)) — the gap vs thm2 shows why acceleration
    is needed to MATCH the lower bound."""
    L = kappa * lam
    arg = L * norm_w0_star ** 2 / (2.0 * eps)
    return 0.0 if arg <= 1.0 else kappa * math.log(arg)
