"""Feature partition of the coordinate index set [d] across m machines.

Implements the paper's Definition 1 data layout: [d] is split into m
disjoint, contiguous coordinate sets S_1..S_m with sum(d_i) = d, and the
data matrix A in R^{n x d} is partitioned column-wise A = [A_1 .. A_m]
with machine j storing A_j = A[:, S_j].

On the TPU mesh, "machine j" is the j-th slice of the `model` mesh axis;
this module provides both the abstract index bookkeeping (used by the
feasible-set certifier and the single-host reference algorithms) and the
padding helpers needed to lay a ragged partition out as a dense
(m, n, d_max) array for shard_map.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class FeaturePartition:
    """Partition of [d] into m contiguous blocks of sizes ``block_sizes``."""

    d: int
    block_sizes: Tuple[int, ...]

    def __post_init__(self):
        if sum(self.block_sizes) != self.d:
            raise ValueError(
                f"block sizes {self.block_sizes} do not sum to d={self.d}")
        if any(b <= 0 for b in self.block_sizes):
            raise ValueError("all blocks must be non-empty")

    @property
    def m(self) -> int:
        return len(self.block_sizes)

    @property
    def offsets(self) -> Tuple[int, ...]:
        """Start offset of each block S_j."""
        return tuple(int(x) for x in np.concatenate(
            [[0], np.cumsum(self.block_sizes)[:-1]]))

    @property
    def d_max(self) -> int:
        return max(self.block_sizes)

    def coords(self, j: int) -> range:
        """The coordinate set S_j (0-based, contiguous)."""
        off = self.offsets[j]
        return range(off, off + self.block_sizes[j])

    def owner(self, coord: int) -> int:
        """Machine owning a given coordinate."""
        if not 0 <= coord < self.d:
            raise ValueError(f"coordinate {coord} out of range [0,{self.d})")
        return int(np.searchsorted(np.cumsum(self.block_sizes), coord,
                                   side="right"))

    # ---- splitting / assembling vectors --------------------------------
    def split_vector(self, w) -> List[jnp.ndarray]:
        """w in R^d  ->  [w^[1], ..., w^[m]]."""
        out, off = [], 0
        for b in self.block_sizes:
            out.append(w[off:off + b])
            off += b
        return out

    def concat_blocks(self, blocks: Sequence[jnp.ndarray]) -> jnp.ndarray:
        return jnp.concatenate(list(blocks), axis=-1)

    def split_columns(self, A) -> List[jnp.ndarray]:
        """A in R^{n x d}  ->  [A_1, ..., A_m] with A_j = A[:, S_j]."""
        out, off = [], 0
        for b in self.block_sizes:
            out.append(A[:, off:off + b])
            off += b
        return out

    # ---- dense padded layout for shard_map -----------------------------
    def pad_blocks(self, blocks: Sequence[jnp.ndarray]) -> jnp.ndarray:
        """Stack ragged per-machine blocks into (m, ..., d_max), zero padded.

        Zero padding is semantically safe for every operation the
        algorithms perform: A_j w_j ignores zero columns, and partial
        gradients of padded coordinates are discarded on unpad.
        """
        dm = self.d_max
        padded = []
        for blk in blocks:
            pad = dm - blk.shape[-1]
            widths = [(0, 0)] * (blk.ndim - 1) + [(0, pad)]
            padded.append(jnp.pad(blk, widths))
        return jnp.stack(padded)

    def unpad_blocks(self, stacked) -> List[jnp.ndarray]:
        return [stacked[j][..., :b] for j, b in enumerate(self.block_sizes)]

    def mask(self) -> jnp.ndarray:
        """(m, d_max) 1/0 mask of valid coordinates."""
        dm = self.d_max
        rows = [jnp.concatenate([jnp.ones((b,)), jnp.zeros((dm - b,))])
                for b in self.block_sizes]
        return jnp.stack(rows)


def even_partition(d: int, m: int) -> FeaturePartition:
    """Split [d] into m near-equal contiguous blocks (paper's layout)."""
    base, rem = divmod(d, m)
    if base == 0:
        raise ValueError(f"cannot split d={d} into m={m} non-empty blocks")
    sizes = tuple(base + (1 if j < rem else 0) for j in range(m))
    return FeaturePartition(d=d, block_sizes=sizes)
