"""Communication model + metering.

The paper restricts each round to:
  * computation phase: a constant number of Reduce/ReduceAll ops of an R^n
    vector (or scalars),
  * communication phase: each machine j broadcasts O(1) vectors in R^{d_j}
    (an all-to-all broadcast == one ReduceAll of an R^d vector).

Two communicator backends implement this model:

  * ``LocalCommunicator`` — m simulated machines on the host; per-machine
    state is stacked on a leading axis. Used by the reference algorithms,
    the feasible-set certifier, and the CPU benchmarks.
  * ``ShardMapCommunicator`` — the same interface bound to ``jax.lax``
    collectives over a named mesh axis, for use inside ``shard_map``.
    "Machine j" is mesh slice j of the `model` axis.

Every call is recorded in a ``CommLedger`` as a typed message —
direction, shape, dtype, payload bytes, and *wire bits* — so benchmarks
can report rounds, op counts, bytes, and bit totals, and assert the
paper's per-round budget (O(n + d) bits/round) is respected by each
algorithm.  ``end_round()`` additionally marks the record-stream
position of every round boundary (``round_marks``), so per-round and
rounds-prefix bit totals are exact even for algorithms with non-uniform
round structure.

Both communicators accept a ``channel`` (``core.channel``): a lossy
transform (fp16/bf16 cast, int8 stochastic-rounding quantization, top-k
sparsification) applied to every per-machine vector upload before the
reduction, with the transformed payload's wire bits recorded in the
ledger.  The default identity channel leaves both the computation graph
and the legacy ``(kind, elems, bytes, tag)`` record stream bit-identical
to a channel-free build; scalar reductions always bypass the channel.

Both communicators also accept a ``faults`` spec (``core.faults``): a
seeded schedule of injected wire faults (message drops, bit flips,
straggler rounds, one crash-restart).  Faults are *value-transparent* —
every faulted message is detected (checksum / timeout), NACKed, and
retransmitted until a clean copy arrives, so delivered payloads and all
computed results stay bit-identical to the fault-free run.  What changes
is the ledger: each failed attempt appends a 32-bit NACK plus a resend
copy of the record, both ``retransmit=True``, and straggler / crash
recovery appends extra rounds counted in ``recovery_rounds``.  Fault
granularity is the ledger record (a record's ``wire`` message bundle
fails and resends atomically), so ``total_bits == clean bits + exactly
the injected retransmission bits`` holds by construction.

Also here: ``collective_bytes_from_hlo`` — the dry-run HLO auditor that sums
payload bytes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops in a lowered/compiled module (used by the roofline).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from .channel import AnyChannel, parse_channel
from .faults import FaultSpec, checksum as _fault_checksum, corrupt as _fault_corrupt, parse_faults


# --------------------------------------------------------------------------
# Ledger
# --------------------------------------------------------------------------

@dataclasses.dataclass
class CommRecord:
    """One metered message.  The first four fields are the legacy stream
    the conformance suites pin bit-identical across backends / engines /
    batching; the typed tail (direction, shape, dtype, bits) is the
    message-level accounting added for bit budgets.  ``bytes`` is always
    the *source* payload (elems x itemsize); ``bits`` is what the payload
    occupies on the wire after the channel transform (== bytes x 8 under
    the identity channel)."""

    kind: str          # reduce_all | reduce | broadcast | all_to_all_broadcast
    elems: int         # payload element count (per machine contribution)
    bytes: int
    tag: str = ""
    direction: str = "worker->center"   # | "worker->all"
    shape: Optional[Tuple[int, ...]] = None   # () is a scalar; None derives
    dtype: str = "float32"
    bits: int = 0
    # wire geometry (per-message source elems, message count) for records
    # the channel prices — lets replay re-price a scheduled channel from
    # the record's round offset.  None == channel-exempt (scalars).
    # Deliberately NOT part of typed_stream(): it is pricing provenance,
    # not a wire observable.
    wire: Optional[Tuple[int, int]] = None
    # recovery traffic: True for NACKs, resends of faulted messages, and
    # crash-replay records.  Part of typed_stream() (it is a wire
    # observable: the receiver sees the duplicate), so total_bits splits
    # exactly into clean_bits() + retransmit_bits().
    retransmit: bool = False

    def __post_init__(self):
        if self.shape is None:
            self.shape = (self.elems,)
        if not self.bits:
            self.bits = self.bytes * 8


@dataclasses.dataclass
class CommLedger:
    records: List[CommRecord] = dataclasses.field(default_factory=list)
    rounds: int = 0
    # record-stream position of each round boundary: round_marks[k] ==
    # len(records) right after round k+1 ended.  Lets per-round / first-K
    # bit totals stay exact for non-uniform round structures.
    round_marks: List[int] = dataclasses.field(default_factory=list)
    _round_open: bool = False
    # wire rounds spent on recovery (straggler idles + crash replay);
    # algo_rounds == rounds - recovery_rounds is the algorithm's own
    # round count, which keys scheduled-channel stages and fault draws.
    recovery_rounds: int = 0
    # index of the next non-retransmit wire message — the per-message key
    # of the fault schedule.  Advanced identically by eager metering and
    # by replay, so both engines draw the same faults for the same
    # message.
    wire_msgs: int = 0
    # while True, every record is flagged retransmit (crash-replay
    # re-execution) and no fresh faults are drawn.
    mark_retransmit: bool = False

    @property
    def algo_rounds(self) -> int:
        return self.rounds - self.recovery_rounds

    def record(self, kind: str, elems: int, itemsize: int = 4, tag: str = "",
               *, shape: Optional[Tuple[int, ...]] = None,
               dtype: str = "float32", direction: str = "worker->center",
               bits: Optional[int] = None,
               wire: Optional[Tuple[int, int]] = None,
               retransmit: bool = False):
        nbytes = int(elems) * itemsize
        retransmit = bool(retransmit or self.mark_retransmit)
        self.records.append(CommRecord(
            kind, int(elems), nbytes, tag,
            direction=direction,
            shape=tuple(shape) if shape is not None else (int(elems),),
            dtype=dtype,
            bits=int(bits) if bits is not None else nbytes * 8,
            wire=tuple(wire) if wire is not None else None,
            retransmit=retransmit))
        if wire is not None and not retransmit:
            self.wire_msgs += 1
        self._round_open = True

    def end_round(self, recovery: bool = False):
        self.rounds += 1
        if recovery:
            self.recovery_rounds += 1
        self.round_marks.append(len(self.records))
        self._round_open = False

    def idle_round(self):
        """An empty recovery round (straggler delay): the wire stays open
        but carries nothing — wire rounds advance, the algorithm's don't."""
        self.end_round(recovery=True)

    def append_recovery(self, rec: CommRecord):
        """Price one failed delivery of ``rec``: a 32-bit NACK
        (center->worker resend request) plus a resend copy of the full
        record.  The checksum itself rides in the unpriced message header
        (like shape/dtype metadata), so this pair is *exactly* the
        injected retransmission traffic."""
        self.records.append(CommRecord(
            "nack", 1, 4, rec.tag, direction="center->worker", shape=(),
            retransmit=True))
        self.records.append(dataclasses.replace(rec, retransmit=True))
        self._round_open = True

    def end_round_faulted(self, faults: FaultSpec):
        """End an algorithm round, then inject the fault schedule's
        straggler delay for it (deterministic in the 0-based algo round)."""
        r = self.algo_rounds
        self.end_round()
        for _ in range(faults.straggle_delay(r)):
            self.idle_round()

    def replay_schedule(self, records: Sequence[CommRecord], rounds: int,
                        marks: Sequence[int], count: int,
                        channel: Optional[AnyChannel] = None,
                        faults: Optional[FaultSpec] = None):
        """Append a captured per-step schedule ``count`` times: the
        record objects are shared (replay is metering, not mutation), the
        round counter advances by ``rounds`` per repeat, and the step's
        round-boundary marks are rebased onto this ledger's stream.  The
        scan engine and ``execute_batch`` route their trace-once
        schedules through here so the replayed stream — marks included —
        is bit-identical to the per-call python-engine stream.

        Under a *scheduled* ``channel`` the captured records carry
        provisional prices (tracing sees a symbolic round index), so each
        repeat re-prices its channel-metered records from the record's
        round offset within the step — wire bits per round stay exact
        without re-tracing.  Fixed channels keep the shared-object fast
        path (prices are round-invariant by construction).

        With an active ``faults`` spec the replay walks the schedule
        record by record, drawing the same per-message fault decisions
        the eager python engine draws live, and appending the identical
        NACK/resend records and straggler idle rounds — so the faulted
        trace-once stream is bit-identical to the faulted per-call
        stream."""
        if faults is not None and faults.active:
            self._replay_faulted(records, rounds, marks, count, channel,
                                 faults)
            return
        if channel is not None and getattr(channel, "scheduled", False):
            for _ in range(count):
                base = len(self.records)
                self.records.extend(
                    repriced_records(records, marks, self.rounds, channel))
                self.round_marks.extend(base + m for m in marks)
                self.rounds += rounds
            return
        for _ in range(count):
            base = len(self.records)
            self.records.extend(records)
            self.round_marks.extend(base + m for m in marks)
        self.rounds += rounds * count

    def _replay_faulted(self, records: Sequence[CommRecord], rounds: int,
                        marks: Sequence[int], count: int,
                        channel: Optional[AnyChannel],
                        faults: FaultSpec):
        scheduled = channel is not None and getattr(channel, "scheduled",
                                                    False)
        for _ in range(count):
            recs = (repriced_records(records, marks, self.algo_rounds,
                                     channel) if scheduled else records)
            mi = 0
            for j, rec in enumerate(recs):
                while mi < len(marks) and marks[mi] <= j:
                    self.end_round_faulted(faults)
                    mi += 1
                self.records.append(rec)
                if rec.wire is not None and not rec.retransmit:
                    msg = self.wire_msgs
                    self.wire_msgs += 1
                    for _kind in faults.attempts(msg):
                        self.append_recovery(rec)
            while mi < len(marks):
                self.end_round_faulted(faults)
                mi += 1
            for _ in range(rounds - len(marks)):
                self.end_round_faulted(faults)

    # ---- summaries -----------------------------------------------------
    def typed_stream(self) -> List[Tuple]:
        """The full typed record stream — legacy tuple plus the
        bit-accounting tail — as hashable tuples.  The conformance
        surfaces (tests, ``benchmarks/comm_bits``) compare THIS, so a
        future field lands in every one of them at once."""
        return [(r.kind, r.elems, r.bytes, r.bits, r.tag, tuple(r.shape),
                 r.dtype, r.direction, r.retransmit) for r in self.records]

    def total_bytes(self) -> int:
        return sum(r.bytes for r in self.records)

    def total_bits(self) -> int:
        return sum(r.bits for r in self.records)

    def retransmit_bits(self) -> int:
        """Wire bits of recovery traffic (NACKs + resends + crash replay)."""
        return sum(r.bits for r in self.records if r.retransmit)

    def clean_bits(self) -> int:
        """Wire bits net of recovery — bit-identical to a fault-free run."""
        return sum(r.bits for r in self.records if not r.retransmit)

    def retransmissions(self) -> int:
        """Number of resent payload messages (NACKs not counted)."""
        return sum(1 for r in self.records
                   if r.retransmit and r.kind != "nack")

    def op_counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for r in self.records:
            out[r.kind] = out.get(r.kind, 0) + 1
        return out

    def bytes_per_round(self) -> float:
        return self.total_bytes() / max(1, self.rounds)

    def bits_per_round(self) -> float:
        return self.total_bits() / max(1, self.rounds)

    def bits_through_round(self, k: int) -> int:
        """Wire bits of the first ``k`` rounds, exact via ``round_marks``
        (proportional fallback if a caller bypassed the marked paths)."""
        if k >= self.rounds:
            return self.total_bits()
        if k <= 0:
            return 0
        if len(self.round_marks) == self.rounds:
            return sum(r.bits for r in self.records[:self.round_marks[k - 1]])
        return int(round(self.total_bits() * k / max(1, self.rounds)))

    def assert_budget(self, n: int, d: int, const: int = 8,
                      itemsize: int = 4):
        """Assert the paper's per-round budget: <= const ReduceAll of R^n
        plus const broadcast of R^{d} total, i.e. O(n+d) elements/round."""
        budget = const * (n + d) * itemsize
        per_round = self.bytes_per_round()
        if per_round > budget:
            raise AssertionError(
                f"communication budget violated: {per_round:.0f} B/round "
                f"> {budget} B/round (n={n}, d={d}, const={const})")


def repriced_records(records: Sequence[CommRecord], marks: Sequence[int],
                     base_round: int, channel: AnyChannel
                     ) -> List[CommRecord]:
    """Copies of a captured step's ``records`` with every channel-priced
    payload (``wire`` set) re-priced for a repeat whose first round is
    global round ``base_round``.  Record ``j``'s round offset within the
    step is the number of marks at or before it — the same invariant
    ``round_marks`` encodes (``marks[k] == #records once round k+1
    ended``).  Channel-exempt records (scalars) are shared unchanged."""
    out: List[CommRecord] = []
    mi, offset = 0, 0
    for j, rec in enumerate(records):
        while mi < len(marks) and marks[mi] <= j:
            offset += 1
            mi += 1
        if rec.wire is None:
            out.append(rec)
            continue
        per_elems, nmsg = rec.wire
        itemsize = rec.bytes // max(1, rec.elems)
        bits = nmsg * channel.wire_bits(per_elems, itemsize,
                                        rnd=base_round + offset)
        out.append(dataclasses.replace(rec, bits=int(bits))
                   if int(bits) != rec.bits else rec)
    return out


def inject_crash_recovery(ledger: CommLedger, faults: FaultSpec) -> int:
    """Post-pass for the trace-once engines: splice the crash-replay
    records into a replayed ledger exactly where the live python engine
    records them.

    The fault model crashes the center after it completes algorithm round
    ``k``, losing everything since its last snapshot (round ``s``); rounds
    ``s+1..k`` are re-executed from the restored snapshot.  The python
    engine does this live (``engine._run_python`` restores the carry via
    ``repro.checkpoint`` and re-runs the steps under
    ``ledger.mark_retransmit``); the scan/batch engines replay a captured
    schedule, so the same traffic is spliced in here: copies of rounds
    ``s+1..k``'s non-retransmit records, flagged ``retransmit=True``,
    inserted right after round ``k`` (and after its straggler idles, which
    the live path emits inside the round's ``end_round``).  Original
    per-record bits are kept — the live path pins the original round index
    for scheduled-channel pricing, so both streams price the replay at the
    round it re-executes.  Returns the number of replayed rounds."""
    s, k = faults.crash_span(ledger.algo_rounds)
    if k == 0:
        return 0

    def end_mark_index(r: int) -> int:
        """round_marks index of 1-based algo round ``r``'s end (straggle
        idles own their own marks, recomputed from the seeded schedule)."""
        w = 0
        for j in range(r - 1):
            w += 1 + faults.straggle_delay(j)
        return w

    def end_pos(r: int) -> int:
        return 0 if r == 0 else ledger.round_marks[end_mark_index(r)]

    insert_at = end_pos(k)
    copied: List[CommRecord] = []
    copy_marks: List[int] = []
    for r in range(s + 1, k + 1):
        copied.extend(dataclasses.replace(rc, retransmit=True)
                      for rc in ledger.records[end_pos(r - 1):end_pos(r)]
                      if not rc.retransmit)
        copy_marks.append(insert_at + len(copied))
    # marks splice point: after round k's own mark and its straggle idles
    splice = end_mark_index(k) + 1 + faults.straggle_delay(k - 1)
    n = len(copied)
    ledger.records[insert_at:insert_at] = copied
    ledger.round_marks = (ledger.round_marks[:splice] + copy_marks +
                          [m + n for m in ledger.round_marks[splice:]])
    ledger.rounds += k - s
    ledger.recovery_rounds += k - s
    return k - s


# --------------------------------------------------------------------------
# Communicators
# --------------------------------------------------------------------------

# --------------------------------------------------------------------------
# Wire scopes — the static-analysis anchor
# --------------------------------------------------------------------------
#
# Every wire message a communicator emits is wrapped in a
# ``jax.named_scope`` whose name encodes the ledger record it just
# priced.  ``jax.named_scope`` rides the tracer name stack: it lands in
# each traced equation's ``source_info.name_stack`` (surviving into
# ``scan``/``shard_map`` sub-jaxprs) WITHOUT touching the jaxpr's
# pretty-printed text, the compiled computation, or any numeric value —
# so ``execute_batch`` structure grouping and every bit-identity gate
# are unaffected.  ``repro.analysis`` walks the jaxpr and parses these
# tokens back into the *static* message schedule, which it then proves
# equal to the trace-once ledger replay.

_SCOPE_SAFE_RE = re.compile(r"[^A-Za-z0-9_.+-]")

_DIRECTION_CODES = {
    "worker->center": "w2c",
    "worker->all": "w2a",
    "center->worker": "c2w",
}
_DIRECTION_NAMES = {v: k for k, v in _DIRECTION_CODES.items()}

COMM_SCOPE_RE = re.compile(
    r"comm\[i=(?P<idx>\d+);r=(?P<rnd>\d+);k=(?P<kind>[a-z_]+);"
    r"d=(?P<direction>[A-Za-z0-9_.+-]*);s=(?P<shape>[0-9x]*);"
    r"t=(?P<dtype>[A-Za-z0-9_]*);b=(?P<bits>\d+);"
    r"w=(?P<wire>(?:\d+\.\d+)|-);g=(?P<tag>[A-Za-z0-9_.+-]*)\]")


def sanitize_scope_tag(tag: str) -> str:
    """Ledger tags (``"z=Aw"``, ``"|w|^2"``) may use characters a scope
    name cannot carry; both the emitter and the verifier canonicalize
    through this before comparing."""
    return _SCOPE_SAFE_RE.sub("-", tag)


def comm_scope_name(rec: CommRecord, idx: int, rnd: int) -> str:
    """Scope token for ledger record ``rec`` at position ``idx``,
    emitted in round ``rnd`` (an offset within the traced step when the
    engine pinned a round base, else the ledger's absolute counter)."""
    shape = "x".join(str(int(s)) for s in rec.shape)
    wire = "-" if rec.wire is None else f"{rec.wire[0]}.{rec.wire[1]}"
    d = _DIRECTION_CODES.get(rec.direction, sanitize_scope_tag(rec.direction))
    return (f"comm[i={idx};r={rnd};k={rec.kind};d={d};s={shape};"
            f"t={rec.dtype};b={rec.bits};w={wire};g={sanitize_scope_tag(rec.tag)}]")


def parse_comm_scope(token: str) -> Optional[Dict[str, object]]:
    """Inverse of ``comm_scope_name``; ``None`` if ``token`` is not a
    comm scope.  ``shape`` comes back as a tuple, ``wire`` as
    ``(per_elems, nmsg)`` or ``None``, ``direction`` decoded."""
    m = COMM_SCOPE_RE.fullmatch(token)
    if m is None:
        return None
    shape_s = m.group("shape")
    wire_s = m.group("wire")
    d = m.group("direction")
    return {
        "idx": int(m.group("idx")),
        "rnd": int(m.group("rnd")),
        "kind": m.group("kind"),
        "direction": _DIRECTION_NAMES.get(d, d),
        "shape": tuple(int(s) for s in shape_s.split("x")) if shape_s else (),
        "dtype": m.group("dtype"),
        "bits": int(m.group("bits")),
        "wire": (None if wire_s == "-"
                 else tuple(int(p) for p in wire_s.split("."))),
        "tag": m.group("tag"),
    }


class _ChannelWireMixin:
    """Channel plumbing shared by both communicators: parsing/rejection,
    round-index tracking for scheduled channels, and wire pricing.

    Round identity: under the python engine nobody calls
    ``begin_round`` — the ledger's concrete round counter IS the round
    index (it advances at every ``end_round``, so it is exact even
    intra-step).  The scan engines thread the round index as scanned
    ``xs`` and pin it with ``begin_round`` before each step;
    ``end_round`` then advances a local offset for multi-round steps.  A
    *traced* index prices provisionally (stage 0); the ledger replay
    re-prices from round offsets, so the trace-once stream still carries
    exact per-round wire bits.
    """

    def _init_channel(self, channel):
        self.channel: AnyChannel = parse_channel(channel)
        if getattr(self.channel, "kind", "") == "gap":
            raise ValueError(
                f"channel {self.channel.name!r} is a gap-adaptive "
                f"specification; resolve it to a schedule before "
                f"constructing a communicator (repro.api.plan resolves "
                f"gap channels via an identity probe run)")
        self._round_base = None
        self._round_offset = 0

    def _init_faults(self, faults):
        self.faults: FaultSpec = parse_faults(faults)
        # True while an engine captures a schedule (jax.eval_shape /
        # make_jaxpr): fault injection must not pollute the captured
        # stream — the ledger replay injects it instead.
        self._tracing = False

    def begin_round(self, rnd):
        """Pin the round index of subsequent messages (scan engines pass
        the scanned — possibly traced — index here)."""
        self._round_base = rnd
        self._round_offset = 0

    def reset_round(self):
        """Drop a pinned round index (call after a traced run so a stale
        tracer never leaks into eager metering)."""
        self._round_base = None
        self._round_offset = 0

    def _round_index(self):
        """The round the next message belongs to: concrete under the
        python engine (ledger counter), possibly traced under scan.
        Channel schedules are indexed by *algorithm* round, so recovery
        rounds (straggler idles, crash replay) never shift the stage."""
        if self._round_base is None:
            return self.ledger.algo_rounds
        return self._round_base + self._round_offset

    def _wire_scope(self, payload=None):
        """``jax.named_scope`` for the graph ops realizing the wire
        message the ledger just recorded (call right after
        ``ledger.record``).  The name encodes the record so the static
        verifier can recover the message schedule from the jaxpr alone;
        the round field is the concrete offset within the traced step
        when ``begin_round`` pinned a (possibly traced) base, else the
        ledger's concrete round counter."""
        led = self.ledger
        rec = led.records[-1]
        idx = len(led.records) - 1
        rnd = (self._round_offset if self._round_base is not None
               else led.algo_rounds)
        return jax.named_scope(comm_scope_name(rec, idx, rnd))

    def _price(self, per_elems: int, itemsize: int, nmsg: int = 1) -> int:
        """Wire bits for ``nmsg`` channel-transformed messages of
        ``per_elems`` elements at the current round (stage 0 provisional
        when the round index is traced — replay re-prices)."""
        ch = self.channel
        if getattr(ch, "scheduled", False):
            rnd = self._round_index()
            if not isinstance(rnd, (int, np.integer)):
                rnd = None
            return nmsg * ch.wire_bits(per_elems, itemsize, rnd=rnd)
        return nmsg * ch.wire_bits(per_elems, itemsize)

    def _apply_channel(self, x):
        """The per-message transform at the current round."""
        if getattr(self.channel, "scheduled", False):
            rnd = self._round_index()
            return self.channel.apply(x, rnd)
        return self.channel.apply(x)

    def end_round(self):
        if self._round_base is not None:
            self._round_offset += 1
        led = self.ledger
        if led.mark_retransmit:
            # crash-replay re-execution: a recovery round, no fresh faults
            led.end_round(recovery=True)
            return
        f = getattr(self, "faults", None)
        if f is not None and f.active and not self._tracing:
            led.end_round_faulted(f)
            return
        led.end_round()

    def _inject_faults(self, payload):
        """The eager detect-and-retransmit dance for the wire message the
        ledger just recorded.  Draws the seeded fault schedule for this
        message index; for each failed attempt, genuinely corrupts the
        concrete payload in transit (bit flip), verifies the XOR-fold
        checksum catches it, and prices the NACK + resend.  The delivered
        payload is always the clean copy, so computed values stay
        bit-identical to the fault-free run.  No-op while tracing (the
        ledger replay injects the identical records instead) or during
        crash-replay re-execution (a replayed message is recovery
        traffic, not a fresh draw)."""
        led = self.ledger
        f = getattr(self, "faults", None)
        if (f is None or not f.active or led.mark_retransmit
                or self._tracing or isinstance(payload, jax.core.Tracer)):
            return
        rec = led.records[-1]
        if rec.wire is None or rec.retransmit:
            return
        msg = led.wire_msgs - 1   # record() just advanced it
        events = f.attempts(msg)
        if not events:
            return
        clean = np.asarray(payload)
        for a, kind in enumerate(events):
            if kind == "flip":
                sent = _fault_corrupt(clean, f.seed, msg, a)
                if _fault_checksum(sent) == _fault_checksum(clean):
                    raise AssertionError(
                        "checksum failed to detect an injected bit flip")
            led.append_recovery(rec)


class LocalCommunicator(_ChannelWireMixin):
    """Simulates m machines on host. Per-machine values are stacked on a
    leading axis of size m. Used by reference algorithms and tests.

    ``channel`` (name or ``core.channel`` object) is applied per machine
    to every vector upload before the reduction; the identity default
    skips the transform entirely, so channel-free semantics — compute
    graph and ledger stream alike — are untouched."""

    def __init__(self, m: int, ledger: Optional[CommLedger] = None,
                 channel=None, faults=None):
        self.m = m
        self.ledger = ledger if ledger is not None else CommLedger()
        self._init_channel(channel)
        self._init_faults(faults)

    def _transmit(self, x_stacked):
        """The lossy worker->center wire, per machine (leading axis)."""
        if self.channel.lossless:
            return x_stacked
        return jax.vmap(self._apply_channel)(x_stacked)

    def reduce_all(self, x_stacked, tag: str = "",
                   pretransformed: bool = False) -> jnp.ndarray:
        """ReduceAll: each machine holds x_j (stacked (m, ...)); returns the
        sum, conceptually available on every machine.

        ``pretransformed`` declares that the caller already applied this
        round's channel transform to every per-machine payload (the fused
        round-step kernel emits the upload vector through the in-kernel
        channel stage) — the record, its wire pricing, and fault
        injection are byte-identical to the untransformed path; only the
        redundant second transform is skipped."""
        x_stacked = jnp.asarray(x_stacked)
        # per-machine payload metadata from the aval, NOT from slicing
        # x_stacked[0]: a traced slice would plant a dead machine-axis
        # gather in every step jaxpr, which the static class certifier
        # (repro.analysis) must treat as reading another machine's block
        per_shape = tuple(x_stacked.shape[1:])
        per_size = int(np.prod(per_shape, dtype=np.int64)) if per_shape else 1
        itemsize = x_stacked.dtype.itemsize
        self.ledger.record("reduce_all", per_size, itemsize, tag,
                           shape=per_shape,
                           dtype=str(x_stacked.dtype),
                           direction="worker->center",
                           bits=self._price(per_size, itemsize),
                           wire=(per_size, 1))
        self._inject_faults(x_stacked)
        with self._wire_scope():
            xfer = x_stacked if pretransformed else self._transmit(x_stacked)
            return jnp.sum(xfer, axis=0)

    def reduce_scalar(self, x_stacked, tag: str = "") -> jnp.ndarray:
        # scalars carry control quantities: never channel-transformed
        self.ledger.record("reduce_all", 1, 4, tag, shape=(),
                           direction="worker->center")
        with self._wire_scope():
            return jnp.sum(x_stacked, axis=0)

    def all_to_all_broadcast(self, blocks_stacked, tag: str = ""):
        """Each machine broadcasts its R^{d_j} block; every machine ends up
        with all blocks. Locally this is the identity on the stacked array;
        the ledger charges sum_j d_j = d elements (wire bits: m per-machine
        messages through the channel)."""
        blocks_stacked = jnp.asarray(blocks_stacked)
        itemsize = blocks_stacked.dtype.itemsize
        per_elems = int(np.prod(blocks_stacked.shape[1:], dtype=np.int64)) \
            if blocks_stacked.ndim > 1 else 1
        m = blocks_stacked.shape[0]
        self.ledger.record("all_to_all_broadcast", blocks_stacked.size,
                           itemsize, tag,
                           shape=tuple(blocks_stacked.shape),
                           dtype=str(blocks_stacked.dtype),
                           direction="worker->all",
                           bits=self._price(per_elems, itemsize, m),
                           wire=(per_elems, m))
        self._inject_faults(blocks_stacked)
        with self._wire_scope():
            out = self._transmit(blocks_stacked)
            if self.channel.lossless:
                # a lossless local broadcast is the identity — it traces
                # to zero equations, leaving the scope (and the message)
                # invisible to the static verifier.  An optimization
                # barrier is a semantic no-op that still owns an
                # equation, anchoring the scope in the jaxpr.
                out = lax.optimization_barrier(out)
            return out


class ShardMapCommunicator(_ChannelWireMixin):
    """The same interface bound to lax collectives over mesh axis ``axis``.

    Use inside ``shard_map``: per-machine arrays are the *local* shards (no
    stacking axis). Ledger recording happens at trace time — callers run one
    traced step per round (or multiply a one-round ledger by round count).
    The channel is applied to the local shard (one message) before the
    collective, mirroring the Local path's per-machine transform.
    """

    def __init__(self, axis: str, ledger: Optional[CommLedger] = None,
                 channel=None, faults=None):
        self.axis = axis
        self.ledger = ledger if ledger is not None else CommLedger()
        self._init_channel(channel)
        if parse_faults(faults).active:
            raise ValueError(
                "fault injection requires the local placement (the "
                "detect/retransmit dance runs on concrete host arrays); "
                "run faulted specs with placement='local'")
        self._init_faults(None)

    def _transmit(self, x_local):
        if self.channel.lossless:
            return x_local
        return self._apply_channel(x_local)

    def reduce_all(self, x_local, tag: str = "") -> jnp.ndarray:
        itemsize = x_local.dtype.itemsize
        self.ledger.record("reduce_all", x_local.size, itemsize, tag,
                           shape=tuple(x_local.shape),
                           dtype=str(x_local.dtype),
                           direction="worker->center",
                           bits=self._price(x_local.size, itemsize),
                           wire=(x_local.size, 1))
        with self._wire_scope():
            return lax.psum(self._transmit(x_local), self.axis)

    def reduce_scalar(self, x_local, tag: str = "") -> jnp.ndarray:
        self.ledger.record("reduce_all", 1, 4, tag, shape=(),
                           direction="worker->center")
        with self._wire_scope():
            return lax.psum(x_local, self.axis)

    def all_to_all_broadcast(self, block_local, tag: str = "") -> jnp.ndarray:
        """all_gather of the local R^{d_j} block -> (m, d_j) on every shard."""
        itemsize = block_local.dtype.itemsize
        self.ledger.record("all_to_all_broadcast", block_local.size,
                           itemsize, tag,
                           shape=tuple(block_local.shape),
                           dtype=str(block_local.dtype),
                           direction="worker->all",
                           bits=self._price(block_local.size, itemsize),
                           wire=(block_local.size, 1))
        with self._wire_scope():
            return lax.all_gather(self._transmit(block_local), self.axis)


# --------------------------------------------------------------------------
# HLO collective audit (used by the dry-run roofline)
# --------------------------------------------------------------------------

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")

_COLLECTIVE_OPS = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_REPLICA_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")
# e.g. replica_groups=[16,16]<=[256]T(1,0) (iota format)
_IOTA_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    nb = _DTYPE_BYTES[dtype]
    if not dims:
        return nb
    n = 1
    for tok in dims.split(","):
        tok = tok.strip()
        if tok:
            n *= int(tok)
    return n * nb


def _group_size(line: str) -> int:
    m = _REPLICA_GROUPS_RE.search(line)
    if m:
        toks = [t for t in m.group(1).split(",") if t.strip()]
        return max(1, len(toks))
    m = _IOTA_GROUPS_RE.search(line)
    if m:
        # replica_groups=[num_groups, group_size]<=[total]
        return max(1, int(m.group(2)))
    return 1


@dataclasses.dataclass
class CollectiveAudit:
    bytes_by_op: Dict[str, int]
    count_by_op: Dict[str, int]

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_op.values())


def collective_bytes_from_hlo(hlo_text: str) -> CollectiveAudit:
    """Sum payload bytes of collective ops in an HLO module text.

    Methodology (documented for the roofline):
      * all-reduce / all-to-all / collective-permute: result bytes
        (operand and result payloads coincide).
      * all-gather: result bytes (the fully-gathered tensor ~= bytes that
        cross links per participating device, ring all-gather moves
        (k-1)/k of it — we charge the full tensor, slightly conservative).
      * reduce-scatter: result bytes x group size (operand payload).
      * async pairs: the ``-start`` op is counted, the ``-done`` is skipped.
    """
    bytes_by_op: Dict[str, int] = {}
    count_by_op: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if "=" not in stripped:
            continue
        rhs = stripped.split("=", 1)[1]
        opname = None
        for op in _COLLECTIVE_OPS:
            # match `op(`, `op-start(` but not `op-done(`
            if re.search(rf"\b{op}(-start)?\(", rhs):
                opname = op
                break
        if opname is None:
            continue
        # result shapes: everything before the op call on the rhs
        head = rhs.split(opname)[0]
        shapes = _SHAPE_RE.findall(head)
        if not shapes:
            continue
        nbytes = sum(_shape_bytes(dt, dims) for dt, dims in shapes)
        if opname == "reduce-scatter":
            nbytes *= _group_size(stripped)
        bytes_by_op[opname] = bytes_by_op.get(opname, 0) + nbytes
        count_by_op[opname] = count_by_op.get(opname, 0) + 1
    return CollectiveAudit(bytes_by_op, count_by_op)


def collective_bytes_from_lowered(lowered) -> CollectiveAudit:
    """Audit a ``jax.stages.Lowered`` computation (e.g. the sharded
    driver's ``lower_only=True`` product): compile it and sum the
    collective payloads of the optimized HLO module.  Compilation beats
    auditing the pre-optimization text — it is what actually runs, after
    fusion, async splitting, and collective combining."""
    try:
        text = lowered.compile().as_text()
    except Exception:
        # some backends cannot render compiled HLO; the pre-optimization
        # lowering still names every collective
        text = lowered.as_text(dialect="hlo")
    return collective_bytes_from_hlo(text)
