"""Empirical risk minimization objectives in the feature-partitioned model.

The paper's ERM form (Eq. 1):  f(w) = (1/n) sum_i phi(w, A_i:) [+ lam/2 |w|^2]

The key structural fact the whole paper leans on: for GLM-type losses
(squared, logistic, squared hinge) every machine can compute its partial
gradient

    f'_j(w) = (1/n) A_j^T ell'(z) + lam w_j,      z = A w = sum_j A_j w_j

from the *shared* R^n vector z, and z is exactly ONE ReduceAll of an R^n
vector per round (each machine contributes its local z_j = A_j w_j).
Similarly Hessian-vector products (f''(w) v)^[j] = (1/n) A_j^T (ell''(z) *
(A v)) + lam v_j need the same single ReduceAll — this is what makes
DISCO-F communication-cheap on these losses.

Losses are expressed by per-sample scalar functions of the margin/response
so the same machinery serves all of them.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class GLMLoss:
    """A GLM loss  (1/n) sum_i ell(z_i, y_i) + lam/2 |w|^2,  z = A w."""

    name: str
    value: Callable  # (z, y) -> per-sample loss vector
    grad: Callable   # (z, y) -> d ell / d z          (R^n)
    hess: Callable   # (z, y) -> d^2 ell / d z^2      (R^n, diagonal)
    smoothness: float  # max of ell'' (per-sample curvature bound)

    def full_value(self, z, y, w, lam):
        n = z.shape[0]
        return jnp.sum(self.value(z, y)) / n + 0.5 * lam * jnp.vdot(w, w)


def squared_loss() -> GLMLoss:
    return GLMLoss(
        name="squared",
        value=lambda z, y: 0.5 * (z - y) ** 2,
        grad=lambda z, y: z - y,
        hess=lambda z, y: jnp.ones_like(z),
        smoothness=1.0,
    )


def logistic_loss() -> GLMLoss:
    # y in {-1, +1}; ell = log(1 + exp(-y z))
    def _val(z, y):
        return jnp.logaddexp(0.0, -y * z)

    def _grad(z, y):
        return -y * jax.nn.sigmoid(-y * z)

    def _hess(z, y):
        s = jax.nn.sigmoid(-y * z)
        return s * (1.0 - s)

    return GLMLoss("logistic", _val, _grad, _hess, smoothness=0.25)


def squared_hinge_loss() -> GLMLoss:
    # y in {-1, +1}; ell = max(0, 1 - y z)^2 / 2
    def _val(z, y):
        return 0.5 * jnp.maximum(0.0, 1.0 - y * z) ** 2

    def _grad(z, y):
        return -y * jnp.maximum(0.0, 1.0 - y * z)

    def _hess(z, y):
        return (1.0 - y * z > 0).astype(z.dtype)

    return GLMLoss("squared_hinge", _val, _grad, _hess, smoothness=1.0)


LOSSES = {
    "squared": squared_loss,
    "logistic": logistic_loss,
    "squared_hinge": squared_hinge_loss,
}


@dataclasses.dataclass(frozen=True)
class ERMProblem:
    """A concrete ERM instance: data (A, y), loss, ridge lam."""

    A: jnp.ndarray           # (n, d)
    y: jnp.ndarray           # (n,)
    loss: GLMLoss
    lam: float = 0.0

    @property
    def n(self) -> int:
        return self.A.shape[0]

    @property
    def d(self) -> int:
        return self.A.shape[1]

    # ---- whole-vector oracle (reference; no partitioning) --------------
    def value(self, w) -> jnp.ndarray:
        z = self.A @ w
        return self.loss.full_value(z, self.y, w, self.lam)

    def gradient(self, w) -> jnp.ndarray:
        z = self.A @ w
        return self.A.T @ self.loss.grad(z, self.y) / self.n + self.lam * w

    def hvp(self, w, v) -> jnp.ndarray:
        """Hessian-vector product at w."""
        z = self.A @ w
        h = self.loss.hess(z, self.y)
        return self.A.T @ (h * (self.A @ v)) / self.n + self.lam * v

    def smoothness_bound(self) -> float:
        """L <= ell''_max * sigma_max(A)^2 / n + lam."""
        smax = jnp.linalg.norm(self.A, ord=2)
        return float(self.loss.smoothness * smax ** 2 / self.n + self.lam)

    # ---- feature-partitioned oracles (machine-local pieces) ------------
    # These are the per-machine computations; the single ReduceAll that
    # forms z (or Av) is done by the caller (runtime / shard_map body).
    def local_response(self, A_j, w_j) -> jnp.ndarray:
        """z_j = A_j w_j  — machine j's summand of the ReduceAll."""
        return A_j @ w_j

    def partial_gradient(self, A_j, w_j, z) -> jnp.ndarray:
        """f'_j(w) given the reduced z = Aw."""
        return A_j.T @ self.loss.grad(z, self.y) / self.n + self.lam * w_j

    def partial_hvp(self, A_j, v_j, z, av) -> jnp.ndarray:
        """(f''(w) v)^[j] given reduced z = Aw and av = Av."""
        h = self.loss.hess(z, self.y)
        return A_j.T @ (h * av) / self.n + self.lam * v_j


def make_random_erm(n: int, d: int, loss: str = "squared", lam: float = 1e-2,
                    seed: int = 0, cond: Optional[float] = None) -> ERMProblem:
    """Synthetic ERM instance. If ``cond`` is set, shape A's spectrum to
    roughly that condition number (for controlled kappa experiments)."""
    key = jax.random.PRNGKey(seed)
    ka, kw, kn = jax.random.split(key, 3)
    A = jax.random.normal(ka, (n, d)) / jnp.sqrt(d)
    if cond is not None:
        u, s, vt = jnp.linalg.svd(A, full_matrices=False)
        k = s.shape[0]
        s_new = jnp.geomspace(1.0, 1.0 / jnp.sqrt(cond), k)
        A = (u * s_new) @ vt
    w_true = jax.random.normal(kw, (d,))
    z = A @ w_true
    lf = LOSSES[loss]()
    if loss == "squared":
        y = z + 0.01 * jax.random.normal(kn, (n,))
    else:
        y = jnp.sign(z + 0.01 * jax.random.normal(kn, (n,)))
        y = jnp.where(y == 0, 1.0, y)
    return ERMProblem(A=A, y=y, loss=lf, lam=lam)
