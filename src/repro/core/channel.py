"""Lossy channel transforms for worker->center messages.

The paper's lower bounds are stated in communication *rounds*; the
bit-complexity refinements (Arjevani & Shamir 2015; Ghadiri et al. 2024)
ask what each round *costs on the wire*.  This module models that axis:
a ``Channel`` is a transform applied to every vector payload a machine
uploads (the per-machine ``ReduceAll`` contribution, the per-machine
block of an all-to-all broadcast) plus the arithmetic for the bits that
payload occupies after the transform.  The communicators in
``core.comm`` apply the transform before reducing and record the wire
bits in the ``CommLedger``, so the certification harness can meter
bit budgets next to round counts.

Fixed channels:

  * ``identity``   — the exact f32 wire; 32 bits/element.  The default,
                     and the one every existing certification runs under:
                     with it the computation graph and the ledger's
                     legacy ``(kind, elems, bytes, tag)`` stream are
                     bit-identical to a channel-free build.  ``fp32`` is
                     an accepted alias (schedules read better with it).
  * ``fp16``/``bf16`` — deterministic nearest-even cast to half /
                     bfloat16 and back; 16 bits/element.
  * ``int8``       — per-message symmetric quantization to the int8 grid
                     with *stochastic rounding* (unbiased given uniform
                     rounding offsets); 8 bits/element + one f32 scale
                     per message.  The rounding offsets are derived from
                     an integer hash of the payload's own float bits, so
                     the transform is a pure traceable function (scan-
                     and ``vmap``-safe, no RNG key threading through the
                     round engine) while still varying per round as the
                     iterate moves.
  * ``topk``/``topk:<rho>`` — magnitude top-k sparsification keeping a
                     ``rho`` fraction of entries (default 0.1); each
                     survivor costs its f32 value plus a 32-bit index.

Adaptive channels (the bits-to-eps frontier axis):

  * ``sched:<ch>@<round>,...`` — precision as a pure function of the
    round index: ``sched:fp32@0,int8@5,topk:0.25@20`` sends exact f32
    for rounds 0-4, int8 for rounds 5-19, top-k from round 20 on.  The
    first stage must start at round 0 and starts must strictly increase.
    Because the stage is a function of the round index alone, the scan
    engines thread the index as scanned ``xs`` and the trace-once ledger
    replay re-prices each record from its ``round_marks`` offset — per
    round wire bits stay exact without re-tracing.  A one-entry schedule
    (``sched:int8@0``) is bit-identical to the fixed channel on every
    path (transform, pricing, graph).
  * ``gap:<ch0>,<ch>@<thr>,...`` — gap-adaptive *specification*:
    ``gap:int8,fp16@1e-3,identity@1e-5`` starts at int8 and refines to
    the next stage the round after the measured suboptimality gap
    crosses each (strictly decreasing) threshold.  A ``GapChannel`` is
    resolved — against an identity probe run's gap series — into a
    concrete ``ScheduledChannel`` before execution (``repro.api`` does
    this at plan time); communicators reject the unresolved spec.

Scalar reductions (``reduce_scalar``) bypass every channel: they carry
the model's control quantities (step sizes, CG inner products) whose
corruption would change *which algorithm runs*, not how much it pays —
exactly as bit-complexity treatments keep O(log) control bits exact.
That bypass is what makes the incremental family a bits hard instance:
its rounds are scalar-dominated, so no precision schedule can lower the
certified floor (see ``benchmarks/bits_frontier.py``).  Likewise the
center->worker return of a ReduceAll is exact; the metered payload is
the per-machine upload, matching the ledger's per-machine ``elems``
convention.
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Optional, Sequence, Tuple, Union

import numpy as np

import jax.numpy as jnp
from jax import lax


# Canonical channel kinds; mirrored in repro.api._resolve (the single
# capability resolver) — tests/test_channel.py pins equality.
CHANNELS = ("identity", "fp16", "bf16", "int8", "topk", "sched", "gap")

DEFAULT_TOPK_RHO = 0.1
INDEX_BITS = 32     # per-survivor coordinate index on a top-k wire
SCALE_BITS = 32     # per-message f32 scale on the int8 wire


def _hash_uniform(x: jnp.ndarray) -> jnp.ndarray:
    """Per-element uniforms in [0, 1) from an integer hash of the f32
    payload bits (xorshift-multiply avalanche).  Deterministic and
    traceable — the stochastic-rounding offsets need no RNG key, so the
    transform composes with ``vmap``/``scan``/``eval_shape`` unchanged —
    yet decorrelated from the value's magnitude and fresh every round
    (the hash input is the moving iterate's own bits)."""
    bits = lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
    h = bits ^ jnp.uint32(0x9E3779B9)
    h = (h ^ (h >> 16)) * jnp.uint32(0x45D9F3B)
    h = (h ^ (h >> 16)) * jnp.uint32(0x45D9F3B)
    h = h ^ (h >> 16)
    # keep 24 bits so the uniform is exact in f32
    return (h >> 8).astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))


def stochastic_round(y: jnp.ndarray, u: jnp.ndarray) -> jnp.ndarray:
    """``floor(y + u)`` — unbiased for ``u ~ U[0, 1)``:
    ``E_u[floor(y + u)] = y`` exactly.  Split out so the unbiasedness
    property is testable with explicit uniforms (the channel feeds it
    hash-derived ones)."""
    return jnp.floor(y + u)


@dataclasses.dataclass(frozen=True)
class Channel:
    """One fixed wire model: a payload transform + its bit arithmetic.

    ``apply`` maps ONE message (a single machine's payload, any shape)
    to what the receiver decodes; callers ``vmap`` it over a stacked
    machine axis.  ``wire_bits`` prices one message of ``elems``
    elements at source ``itemsize`` bytes/element (``rnd`` is accepted
    and ignored so fixed and scheduled channels share one call shape).
    """

    name: str                   # canonical, e.g. "int8", "topk:0.25"
    kind: str                   # member of CHANNELS
    rho: float = 1.0            # topk keep fraction

    @property
    def lossless(self) -> bool:
        return self.kind == "identity"

    @property
    def scheduled(self) -> bool:
        return False

    def stage_at(self, rnd: int) -> "Channel":
        return self

    # ---- payload transform ----------------------------------------------
    def apply(self, x: jnp.ndarray, rnd=None) -> jnp.ndarray:
        if self.kind == "identity":
            return x
        if self.kind == "fp16":
            return x.astype(jnp.float16).astype(x.dtype)
        if self.kind == "bf16":
            return x.astype(jnp.bfloat16).astype(x.dtype)
        if self.kind == "int8":
            return self._int8(x)
        return self._topk(x)

    def _int8(self, x: jnp.ndarray) -> jnp.ndarray:
        scale = jnp.max(jnp.abs(x)) / jnp.asarray(127.0, x.dtype)
        safe = jnp.where(scale > 0, scale, jnp.ones_like(scale))
        q = stochastic_round(x / safe, _hash_uniform(x))
        q = jnp.clip(q, -127.0, 127.0)
        return jnp.where(scale > 0, q * safe, jnp.zeros_like(x))

    def _topk(self, x: jnp.ndarray) -> jnp.ndarray:
        flat = x.reshape(-1)
        k = self.topk_k(flat.shape[0])
        _, idx = lax.top_k(jnp.abs(flat), k)
        out = jnp.zeros_like(flat).at[idx].set(flat[idx])
        return out.reshape(x.shape)

    # ---- wire arithmetic -------------------------------------------------
    def topk_k(self, elems: int) -> int:
        return max(1, min(int(elems), math.ceil(self.rho * int(elems))))

    def wire_bits(self, elems: int, itemsize: int = 4, rnd=None) -> int:
        """Bits one message of ``elems`` source elements occupies on the
        wire under this channel."""
        elems = int(elems)
        if self.kind == "identity":
            return elems * itemsize * 8
        if self.kind in ("fp16", "bf16"):
            return elems * 16
        if self.kind == "int8":
            return elems * 8 + SCALE_BITS
        return self.topk_k(elems) * (itemsize * 8 + INDEX_BITS)


@dataclasses.dataclass(frozen=True)
class ScheduledChannel:
    """Round-indexed precision schedule: stage ``i`` (a fixed
    ``Channel``) is active for rounds ``starts[i] <= k < starts[i+1]``.

    The stage is a pure function of the round index, so the transform is
    traceable two ways: concrete round -> static dispatch to the active
    stage (python engine, capture-time); traced round -> one
    ``lax.switch`` over the stage table (scan engines thread the round
    index as scanned ``xs``).  Pricing is never traced: communicators
    stamp each record with its payload geometry and the ledger replay
    re-prices from the record's round offset, so per-round wire bits
    stay exact under trace-once scheduling.
    """

    name: str                                   # canonical "sched:..."
    stages: Tuple[Tuple[int, Channel], ...]     # ((start_round, stage), ...)
    kind: str = "sched"
    rho: float = 1.0

    @property
    def lossless(self) -> bool:
        # A schedule is invisible to the graph only if EVERY stage is.
        return all(st.lossless for _, st in self.stages)

    @property
    def scheduled(self) -> bool:
        # One-entry schedules take every fixed-channel fast path: no
        # round threading, no re-pricing — bit-identical to the constant
        # channel by construction (only the canonical name differs).
        return len(self.stages) > 1

    def stage_at(self, rnd: int) -> Channel:
        active = self.stages[0][1]
        for start, stage in self.stages:
            if int(rnd) >= start:
                active = stage
            else:
                break
        return active

    # ---- payload transform ----------------------------------------------
    def apply(self, x: jnp.ndarray, rnd=None) -> jnp.ndarray:
        if not self.scheduled:
            return self.stages[0][1].apply(x)
        if rnd is None:
            raise ValueError(f"channel {self.name!r} needs the round "
                             f"index to pick a stage; pass apply(x, rnd)")
        if isinstance(rnd, (int, np.integer)):
            return self.stage_at(int(rnd)).apply(x)
        # traced round index: one switch over the (static) stage table
        starts = jnp.asarray([s for s, _ in self.stages[1:]],
                             dtype=jnp.int32)
        idx = jnp.sum(jnp.asarray(rnd, jnp.int32) >= starts)
        branches = [lambda v, _st=stage: _st.apply(v)
                    for _, stage in self.stages]
        return lax.switch(idx, branches, x)

    # ---- wire arithmetic -------------------------------------------------
    def wire_bits(self, elems: int, itemsize: int = 4, rnd=None) -> int:
        """Bits one message of ``elems`` elements occupies at round
        ``rnd`` (round 0's stage when ``rnd`` is None — callers that
        price provisionally during tracing are re-priced at replay)."""
        return self.stage_at(0 if rnd is None else int(rnd)).wire_bits(
            elems, itemsize)


@dataclasses.dataclass(frozen=True)
class GapChannel:
    """Gap-adaptive channel *specification* — not yet a wire model.

    Stage 0 is threshold-free; stage ``i > 0`` activates the round after
    the measured suboptimality gap first drops to ``thresholds[i]``
    (strictly decreasing).  ``resolve(gaps)`` turns the spec into a
    concrete ``ScheduledChannel`` against a measured gap series (the
    plan layer runs an identity probe to get one); executing the
    unresolved spec is an error, which keeps the communicators and
    engines free of any data-dependent control flow.
    """

    name: str                                             # canonical "gap:..."
    stages: Tuple[Tuple[Optional[float], Channel], ...]   # ((thr, stage), ...)
    kind: str = "gap"
    rho: float = 1.0

    @property
    def lossless(self) -> bool:
        return all(st.lossless for _, st in self.stages)

    @property
    def scheduled(self) -> bool:
        return True

    def _unresolved(self):
        return ValueError(
            f"channel {self.name!r} is a gap-adaptive specification; "
            f"resolve it against a measured gap series first "
            f"(repro.api.plan does this via an identity probe run)")

    def apply(self, x, rnd=None):
        raise self._unresolved()

    def wire_bits(self, elems, itemsize=4, rnd=None):
        raise self._unresolved()

    def resolve(self, gaps: Sequence[float]) -> ScheduledChannel:
        """Pin stage switch rounds against a gap trajectory: stage ``i``
        starts the round AFTER the first round whose gap <= threshold
        (the controller reacts to what it has measured).  Unreached
        thresholds drop their stage; if two thresholds are crossed at
        the same round the finer (later) stage wins."""
        g = np.asarray(list(gaps), dtype=float)
        starts = [(0, self.stages[0][1])]
        for thr, stage in self.stages[1:]:
            hit = np.nonzero(g <= thr)[0]
            if hit.size == 0:
                continue
            start = int(hit[0]) + 1
            if start <= starts[-1][0]:
                starts[-1] = (starts[-1][0], stage)
            else:
                starts.append((start, stage))
        return make_schedule(starts)


def make_schedule(stages: Sequence[Tuple[int, Channel]]) -> ScheduledChannel:
    """Build a ``ScheduledChannel`` with its canonical name from
    ``(start_round, stage)`` pairs (starts strictly increasing from 0)."""
    stages = tuple((int(s), st) for s, st in stages)
    name = "sched:" + ",".join(f"{st.name}@{s}" for s, st in stages)
    return ScheduledChannel(name=name, stages=stages)


_IDENTITY = Channel(name="identity", kind="identity")

_TOPK_RE = re.compile(r"topk(?::([^,@]*))?\Z")

AnyChannel = Union[Channel, ScheduledChannel, GapChannel]


def _parse_fixed(name: str) -> Channel:
    """Parse one fixed (non-composite) channel name.  Errors name the
    offending token; composite parsers add the segment context."""
    if name in ("", "identity", "fp32"):
        # fp32 is an alias: schedules like "sched:fp32@0,int8@5" read as
        # the paper's "full precision early" — canonicalized to identity.
        return _IDENTITY
    if name == "fp16":
        return Channel(name="fp16", kind="fp16")
    if name == "bf16":
        return Channel(name="bf16", kind="bf16")
    if name == "int8":
        return Channel(name="int8", kind="int8")
    m = _TOPK_RE.match(name)
    if m:
        if m.group(1) is None:
            rho = DEFAULT_TOPK_RHO
        elif not m.group(1).strip():
            # "topk:" used to fall through to the generic unknown-channel
            # error, which named the whole token instead of the real
            # problem (an empty keep fraction after the colon).
            raise ValueError(
                f"empty topk keep fraction in {name!r}: write "
                f"'topk' for the default ({DEFAULT_TOPK_RHO:g}) or "
                f"'topk:<rho>' with 0 < rho <= 1")
        else:
            try:
                rho = float(m.group(1))
            except ValueError:
                raise ValueError(
                    f"bad topk keep fraction {m.group(1)!r} in "
                    f"{name!r}: not a number") from None
        if not 0.0 < rho <= 1.0:
            raise ValueError(f"topk keep fraction must be in (0, 1]; "
                             f"got {rho:g} in {name!r}")
        return Channel(name=f"topk:{rho:g}", kind="topk", rho=rho)
    hint = ""
    if "@" in name:
        # a bare "int8@5" is almost always a schedule stage that lost
        # its "sched:" prefix; the generic message sent users to the
        # fixed-channel list, which cannot explain the '@'.
        hint = (f"; a '<channel>@<round>' stage only makes sense inside "
                f"a schedule — did you mean 'sched:{name}'?")
    raise ValueError(f"unknown channel {name!r}; expected one of "
                     f"{CHANNELS} (topk also takes 'topk:<rho>'){hint}")


def _parse_sched(name: str) -> ScheduledChannel:
    body = name[len("sched:"):]
    if not body.strip():
        raise ValueError(f"channel {name!r}: empty schedule; expected "
                         f"'sched:<channel>@<start round>,...'")
    stages = []
    for seg in body.split(","):
        seg = seg.strip()
        if not seg:
            raise ValueError(f"channel {name!r}: empty segment "
                             f"(doubled or trailing comma)")
        ch_name, sep, start_s = seg.rpartition("@")
        if not sep:
            raise ValueError(
                f"channel {name!r}: bad segment {seg!r}: missing "
                f"'@<start round>' (every schedule stage needs one)")
        try:
            start = int(start_s)
        except ValueError:
            raise ValueError(
                f"channel {name!r}: bad segment {seg!r}: start round "
                f"{start_s!r} is not an integer") from None
        if start < 0:
            raise ValueError(f"channel {name!r}: bad segment {seg!r}: "
                             f"start round must be >= 0")
        if not ch_name.strip():
            raise ValueError(f"channel {name!r}: bad segment {seg!r}: "
                             f"missing channel name before '@'")
        try:
            stage = _parse_fixed(ch_name.strip())
        except ValueError as e:
            raise ValueError(
                f"channel {name!r}: bad segment {seg!r}: {e}") from None
        stages.append((start, stage))
    if stages[0][0] != 0:
        raise ValueError(f"channel {name!r}: first stage must start at "
                         f"round 0 (got @{stages[0][0]})")
    for (a, _), (b, _) in zip(stages, stages[1:]):
        if b <= a:
            raise ValueError(f"channel {name!r}: stage starts must be "
                             f"strictly increasing (got @{a} then @{b})")
    return make_schedule(stages)


def _parse_gap(name: str) -> GapChannel:
    body = name[len("gap:"):]
    segs = [s.strip() for s in body.split(",")] if body.strip() else []
    if len(segs) < 2:
        raise ValueError(
            f"channel {name!r}: a gap channel needs a starting stage "
            f"plus at least one '<channel>@<gap threshold>' refinement, "
            f"e.g. 'gap:int8,fp16@1e-3,identity@1e-5'")
    for seg in segs:
        if not seg:
            raise ValueError(f"channel {name!r}: empty segment "
                             f"(doubled or trailing comma)")
    if "@" in segs[0]:
        raise ValueError(
            f"channel {name!r}: bad segment {segs[0]!r}: the first "
            f"(coarsest) stage takes no threshold — it is active from "
            f"round 0")
    try:
        stages = [(None, _parse_fixed(segs[0]))]
    except ValueError as e:
        raise ValueError(
            f"channel {name!r}: bad segment {segs[0]!r}: {e}") from None
    for seg in segs[1:]:
        ch_name, sep, thr_s = seg.rpartition("@")
        if not sep:
            raise ValueError(
                f"channel {name!r}: bad segment {seg!r}: missing "
                f"'@<gap threshold>'")
        try:
            thr = float(thr_s)
        except ValueError:
            raise ValueError(
                f"channel {name!r}: bad segment {seg!r}: threshold "
                f"{thr_s!r} is not a number") from None
        if not (thr > 0 and math.isfinite(thr)):
            raise ValueError(f"channel {name!r}: bad segment {seg!r}: "
                             f"threshold must be finite and > 0")
        if not ch_name.strip():
            raise ValueError(f"channel {name!r}: bad segment {seg!r}: "
                             f"missing channel name before '@'")
        prev = stages[-1][0]
        if prev is not None and thr >= prev:
            raise ValueError(
                f"channel {name!r}: bad segment {seg!r}: thresholds "
                f"must strictly decrease (got {prev:g} then {thr:g})")
        try:
            stage = _parse_fixed(ch_name.strip())
        except ValueError as e:
            raise ValueError(
                f"channel {name!r}: bad segment {seg!r}: {e}") from None
        stages.append((thr, stage))
    canonical = "gap:" + stages[0][1].name + "".join(
        f",{st.name}@{thr:g}" for thr, st in stages[1:])
    return GapChannel(name=canonical, stages=tuple(stages))


def parse_channel(channel: Union[None, str, AnyChannel]) -> AnyChannel:
    """Resolve a channel *name* to a channel object.

    Accepts ``None`` (identity), a channel instance (passed through),
    the canonical fixed kinds (plus the ``fp32`` alias for identity and
    ``topk:<rho>`` with ``0 < rho <= 1``), round schedules
    (``sched:<ch>@<round>,...``) and gap-adaptive specs
    (``gap:<ch0>,<ch>@<thr>,...``).  Raises ``ValueError`` naming the
    offending segment on anything malformed — callers in ``repro.api``
    surface that as a plan-time error, and the ``REPRO_CHANNEL`` env
    path hits the same messages.
    """
    if channel is None:
        return _IDENTITY
    if isinstance(channel, (Channel, ScheduledChannel, GapChannel)):
        return channel
    name = str(channel).strip()
    if name.startswith("sched:"):
        return _parse_sched(name)
    if name.startswith("gap:"):
        return _parse_gap(name)
    if name in ("sched", "gap"):
        raise ValueError(
            f"channel {name!r} needs stages: 'sched:<ch>@<round>,...' "
            f"or 'gap:<ch0>,<ch>@<thr>,...'")
    return _parse_fixed(name)
