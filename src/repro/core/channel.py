"""Lossy channel transforms for worker->center messages.

The paper's lower bounds are stated in communication *rounds*; the
bit-complexity refinements (Arjevani & Shamir 2015; Ghadiri et al. 2024)
ask what each round *costs on the wire*.  This module models that axis:
a ``Channel`` is a transform applied to every vector payload a machine
uploads (the per-machine ``ReduceAll`` contribution, the per-machine
block of an all-to-all broadcast) plus the arithmetic for the bits that
payload occupies after the transform.  The communicators in
``core.comm`` apply the transform before reducing and record the wire
bits in the ``CommLedger``, so the certification harness can meter
bit budgets next to round counts.

Channels:

  * ``identity``   — the exact f32 wire; 32 bits/element.  The default,
                     and the one every existing certification runs under:
                     with it the computation graph and the ledger's
                     legacy ``(kind, elems, bytes, tag)`` stream are
                     bit-identical to a channel-free build.
  * ``fp16``/``bf16`` — deterministic nearest-even cast to half /
                     bfloat16 and back; 16 bits/element.
  * ``int8``       — per-message symmetric quantization to the int8 grid
                     with *stochastic rounding* (unbiased given uniform
                     rounding offsets); 8 bits/element + one f32 scale
                     per message.  The rounding offsets are derived from
                     an integer hash of the payload's own float bits, so
                     the transform is a pure traceable function (scan-
                     and ``vmap``-safe, no RNG key threading through the
                     round engine) while still varying per round as the
                     iterate moves.
  * ``topk``/``topk:<rho>`` — magnitude top-k sparsification keeping a
                     ``rho`` fraction of entries (default 0.1); each
                     survivor costs its f32 value plus a 32-bit index.

Scalar reductions (``reduce_scalar``) bypass the channel: they carry the
model's control quantities (step sizes, CG inner products) whose
corruption would change *which algorithm runs*, not how much it pays —
exactly as bit-complexity treatments keep O(log) control bits exact.
Likewise the center->worker return of a ReduceAll is exact; the metered
payload is the per-machine upload, matching the ledger's per-machine
``elems`` convention.
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Optional, Union

import jax
import jax.numpy as jnp
from jax import lax


# Canonical channel kinds; mirrored in repro.api._resolve (the single
# capability resolver) — tests/test_channel.py pins equality.
CHANNELS = ("identity", "fp16", "bf16", "int8", "topk")

DEFAULT_TOPK_RHO = 0.1
INDEX_BITS = 32     # per-survivor coordinate index on a top-k wire
SCALE_BITS = 32     # per-message f32 scale on the int8 wire


def _hash_uniform(x: jnp.ndarray) -> jnp.ndarray:
    """Per-element uniforms in [0, 1) from an integer hash of the f32
    payload bits (xorshift-multiply avalanche).  Deterministic and
    traceable — the stochastic-rounding offsets need no RNG key, so the
    transform composes with ``vmap``/``scan``/``eval_shape`` unchanged —
    yet decorrelated from the value's magnitude and fresh every round
    (the hash input is the moving iterate's own bits)."""
    bits = lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
    h = bits ^ jnp.uint32(0x9E3779B9)
    h = (h ^ (h >> 16)) * jnp.uint32(0x45D9F3B)
    h = (h ^ (h >> 16)) * jnp.uint32(0x45D9F3B)
    h = h ^ (h >> 16)
    # keep 24 bits so the uniform is exact in f32
    return (h >> 8).astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))


def stochastic_round(y: jnp.ndarray, u: jnp.ndarray) -> jnp.ndarray:
    """``floor(y + u)`` — unbiased for ``u ~ U[0, 1)``:
    ``E_u[floor(y + u)] = y`` exactly.  Split out so the unbiasedness
    property is testable with explicit uniforms (the channel feeds it
    hash-derived ones)."""
    return jnp.floor(y + u)


@dataclasses.dataclass(frozen=True)
class Channel:
    """One wire model: a payload transform + its bit arithmetic.

    ``apply`` maps ONE message (a single machine's payload, any shape)
    to what the receiver decodes; callers ``vmap`` it over a stacked
    machine axis.  ``wire_bits`` prices one message of ``elems``
    elements at source ``itemsize`` bytes/element.
    """

    name: str                   # canonical, e.g. "int8", "topk:0.25"
    kind: str                   # member of CHANNELS
    rho: float = 1.0            # topk keep fraction

    @property
    def lossless(self) -> bool:
        return self.kind == "identity"

    # ---- payload transform ----------------------------------------------
    def apply(self, x: jnp.ndarray) -> jnp.ndarray:
        if self.kind == "identity":
            return x
        if self.kind == "fp16":
            return x.astype(jnp.float16).astype(x.dtype)
        if self.kind == "bf16":
            return x.astype(jnp.bfloat16).astype(x.dtype)
        if self.kind == "int8":
            return self._int8(x)
        return self._topk(x)

    def _int8(self, x: jnp.ndarray) -> jnp.ndarray:
        scale = jnp.max(jnp.abs(x)) / jnp.asarray(127.0, x.dtype)
        safe = jnp.where(scale > 0, scale, jnp.ones_like(scale))
        q = stochastic_round(x / safe, _hash_uniform(x))
        q = jnp.clip(q, -127.0, 127.0)
        return jnp.where(scale > 0, q * safe, jnp.zeros_like(x))

    def _topk(self, x: jnp.ndarray) -> jnp.ndarray:
        flat = x.reshape(-1)
        k = self.topk_k(flat.shape[0])
        _, idx = lax.top_k(jnp.abs(flat), k)
        out = jnp.zeros_like(flat).at[idx].set(flat[idx])
        return out.reshape(x.shape)

    # ---- wire arithmetic -------------------------------------------------
    def topk_k(self, elems: int) -> int:
        return max(1, min(int(elems), math.ceil(self.rho * int(elems))))

    def wire_bits(self, elems: int, itemsize: int = 4) -> int:
        """Bits one message of ``elems`` source elements occupies on the
        wire under this channel."""
        elems = int(elems)
        if self.kind == "identity":
            return elems * itemsize * 8
        if self.kind in ("fp16", "bf16"):
            return elems * 16
        if self.kind == "int8":
            return elems * 8 + SCALE_BITS
        return self.topk_k(elems) * (itemsize * 8 + INDEX_BITS)


_IDENTITY = Channel(name="identity", kind="identity")

_TOPK_RE = re.compile(r"topk(?::([0-9.]+))?\Z")


def parse_channel(channel: Union[None, str, Channel]) -> Channel:
    """Resolve a channel *name* to a ``Channel``.

    Accepts ``None`` (identity), a ``Channel`` (passed through), the
    canonical kind names, and the parameterized form ``topk:<rho>`` with
    ``0 < rho <= 1``.  Raises ``ValueError`` on anything else — callers
    in ``repro.api`` surface that as a plan-time error.
    """
    if channel is None:
        return _IDENTITY
    if isinstance(channel, Channel):
        return channel
    name = str(channel).strip()
    if name in ("", "identity"):
        return _IDENTITY
    if name == "fp16":
        return Channel(name="fp16", kind="fp16")
    if name == "bf16":
        return Channel(name="bf16", kind="bf16")
    if name == "int8":
        return Channel(name="int8", kind="int8")
    m = _TOPK_RE.match(name)
    if m:
        rho = float(m.group(1)) if m.group(1) else DEFAULT_TOPK_RHO
        if not 0.0 < rho <= 1.0:
            raise ValueError(f"topk keep fraction must be in (0, 1]; "
                             f"got {rho}")
        return Channel(name=f"topk:{rho:g}", kind="topk", rho=rho)
    raise ValueError(f"unknown channel {name!r}; expected one of "
                     f"{CHANNELS} (topk also takes 'topk:<rho>')")
