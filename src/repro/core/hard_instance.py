"""The paper's hard instances (Section 5).

Theorem 2/3 instance — Nesterov's "chain" quadratic adapted by the paper:

    f(w) = lam (kappa-1)/4 * [ 1/2 w^T A w  - <e_1, w> ]  +  lam/2 |w|^2

with A tridiagonal (2 on the diagonal, -1 off-diagonal, and the bottom-right
entry (sqrt(kappa)+3)/(sqrt(kappa)+1)).  Its minimizer is w*(i) = q^i with
q = (sqrt(kappa)-1)/(sqrt(kappa)+1), and information can propagate at most
ONE coordinate per communication round (Lemma 5) — which yields the
Omega(sqrt(kappa) log(lam |w*| / eps)) round bound.

Theorem 4 instance — the separable block-diagonal version: machine j owns
phi_j, a sum of n/m independent copies of the chain function on its own
coordinates; incremental algorithms touch one component per step.

Everything here is constructive and exact: we expose f, grad f, the
tridiagonal Hessian (as a LinearOperator-ish callable and as an ERM data
matrix), the closed-form w*, and the closed-form error floor of the proof,
so tests/benchmarks can compare measured algorithm progress against the
theory to machine precision.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np
import jax
import jax.numpy as jnp


def chain_matrix(d: int, kappa: float) -> np.ndarray:
    """The tridiagonal matrix A of Eq. (7) (dense, for reference/tests)."""
    A = np.zeros((d, d))
    idx = np.arange(d)
    A[idx, idx] = 2.0
    A[idx[:-1], idx[:-1] + 1] = -1.0
    A[idx[:-1] + 1, idx[:-1]] = -1.0
    rk = np.sqrt(kappa)
    A[d - 1, d - 1] = (rk + 3.0) / (rk + 1.0)
    return A


def tridiag_bands(d: int, kappa: float) -> Tuple[np.ndarray, np.ndarray]:
    """(diag, offdiag) bands of the chain matrix — O(d) storage."""
    diag = np.full((d,), 2.0)
    rk = np.sqrt(kappa)
    diag[d - 1] = (rk + 3.0) / (rk + 1.0)
    off = np.full((d - 1,), -1.0)
    return diag, off


def tridiag_matvec(diag, off, v):
    """Banded tridiagonal matvec in pure jnp (oracle for the Pallas kernel)."""
    out = diag * v
    out = out.at[:-1].add(off * v[1:])
    out = out.at[1:].add(off * v[:-1])
    return out


@dataclasses.dataclass(frozen=True)
class ChainInstance:
    """The Theorem-2 hard function, with exact minimizer and error floor."""

    d: int
    kappa: float
    lam: float = 1.0

    @property
    def L(self) -> float:
        return self.kappa * self.lam

    @property
    def q(self) -> float:
        rk = float(np.sqrt(self.kappa))
        return (rk - 1.0) / (rk + 1.0)

    # ---- function oracles ----------------------------------------------
    def _bands(self):
        diag, off = tridiag_bands(self.d, self.kappa)
        return jnp.asarray(diag), jnp.asarray(off)

    def value(self, w) -> jnp.ndarray:
        diag, off = self._bands()
        aw = tridiag_matvec(diag, off, w)
        c = self.lam * (self.kappa - 1.0) / 4.0
        return c * (0.5 * jnp.vdot(w, aw) - w[0]) + 0.5 * self.lam * jnp.vdot(w, w)

    def gradient(self, w) -> jnp.ndarray:
        diag, off = self._bands()
        aw = tridiag_matvec(diag, off, w)
        c = self.lam * (self.kappa - 1.0) / 4.0
        e1 = jnp.zeros_like(w).at[0].set(1.0)
        return c * (aw - e1) + self.lam * w

    def hvp(self, v) -> jnp.ndarray:
        diag, off = self._bands()
        c = self.lam * (self.kappa - 1.0) / 4.0
        return c * tridiag_matvec(diag, off, v) + self.lam * v

    # ---- exact solution & proof quantities ------------------------------
    def w_star(self) -> jnp.ndarray:
        """w*(i) = q^i  (1-based i; exact up to the boundary-condition
        truncation the paper itself uses, exponentially small in d)."""
        i = jnp.arange(1, self.d + 1, dtype=jnp.float64
                       if jax.config.read("jax_enable_x64") else jnp.float32)
        return self.q ** i

    def f_star(self) -> jnp.ndarray:
        return self.value(self.w_star())

    def error_floor(self, k: int) -> float:
        """Paper's floor:
        f(w^(k)) - f* >= lam/(sqrt(kappa)+1) * exp(-4k/(sqrt(kappa)+1)) * |w*|^2,
        valid while k <= d (Corollary 6 regime)."""
        rk = float(np.sqrt(self.kappa))
        wstar = self.w_star()
        nrm2 = float(jnp.vdot(wstar, wstar))
        return self.lam / (rk + 1.0) * float(np.exp(-4.0 * k / (rk + 1.0))) * nrm2

    def lower_bound_rounds(self, eps: float) -> float:
        """Rounds needed per Theorem 2's final display:
        k >= (sqrt(kappa)-1)/4 * log( lam |w*|^2 / ((sqrt(kappa)+1) eps) )."""
        rk = float(np.sqrt(self.kappa))
        wstar = self.w_star()
        nrm2 = float(jnp.vdot(wstar, wstar))
        arg = self.lam * nrm2 / ((rk + 1.0) * eps)
        if arg <= 1.0:
            return 0.0
        return (rk - 1.0) / 4.0 * float(np.log(arg))

    # ---- ERM embedding ---------------------------------------------------
    def as_erm_data(self) -> Tuple[np.ndarray, np.ndarray, float]:
        """Express f as a ridge-regularized least-squares ERM:
            f(w) = 1/2 |B w|^2 - c e_1^T w + lam/2 |w|^2 + const
        with B = sqrt(c) A^{1/2} (dense; for modest d used in experiments).
        Returns (B, y, lam) such that  1/n sum 0.5 (B w - y)_i^2 * n + ...
        matches f up to an additive constant. Used to drive the generic
        feature-partitioned ERM algorithms on the hard instance."""
        c = self.lam * (self.kappa - 1.0) / 4.0
        A = chain_matrix(self.d, self.kappa)
        evals, evecs = np.linalg.eigh(A)
        evals = np.clip(evals, 0.0, None)
        B = (evecs * np.sqrt(np.clip(c * evals, 0, None))) @ evecs.T  # (d,d)
        # 1/2 w^T (cA) w - c e1^T w  =  1/2 |B w - y|^2 - 1/2 |y|^2
        # provided  B^T y = c e1  (B is symmetric PSD here, so solve B y = c e1).
        rhs = np.zeros(self.d)
        rhs[0] = c
        y = np.linalg.lstsq(B.T, rhs, rcond=None)[0]
        return B, y, self.lam


@dataclasses.dataclass(frozen=True)
class SeparableInstance:
    """Theorem-4 hard function: f(w) = (1/m) sum_j phi_j(w_j), each phi_j a
    sum of n/m independent chain components on machine j's coordinates."""

    m: int
    n: int                      # total number of components (paper's n)
    d_per_component: int
    kappa: float
    lam: float = 1.0

    def __post_init__(self):
        if self.n % self.m != 0:
            raise ValueError("n must be divisible by m")

    @property
    def components_per_machine(self) -> int:
        return self.n // self.m

    @property
    def d(self) -> int:
        return self.m * self.components_per_machine * self.d_per_component

    def component(self) -> ChainInstance:
        return ChainInstance(d=self.d_per_component, kappa=self.kappa,
                             lam=self.lam)

    def w_star(self) -> jnp.ndarray:
        blk = self.component().w_star()
        return jnp.tile(blk, self.m * self.components_per_machine)

    def value(self, w) -> jnp.ndarray:
        comp = self.component()
        blocks = w.reshape(self.m * self.components_per_machine,
                           self.d_per_component)
        vals = jax.vmap(comp.value)(blocks)
        return jnp.sum(vals) / self.m

    def gradient(self, w) -> jnp.ndarray:
        comp = self.component()
        blocks = w.reshape(self.m * self.components_per_machine,
                           self.d_per_component)
        grads = jax.vmap(comp.gradient)(blocks)
        return grads.reshape(-1) / self.m

    def lower_bound_rounds(self, eps: float) -> float:
        """Theorem 4:  Omega((sqrt(n kappa) + n) log(lam |w*| / eps))."""
        wstar = self.w_star()
        nrm2 = float(jnp.vdot(wstar, wstar))
        arg = self.lam * nrm2 / (2.0 * eps)
        if arg <= 1.0:
            return 0.0
        rk = float(np.sqrt(self.kappa))
        # k >= (n (rk+1)^2 - 4 rk) / (4 rk) * log(...) ~ (n sqrt(kappa) + n)/4
        denom = 4.0 * rk
        coef = (self.n * (rk + 1.0) ** 2 - 4.0 * rk) / denom
        return max(0.0, coef * float(np.log(arg)) / 2.0)


def smooth_convex_lower_bound_rounds(L: float, norm_w_star: float,
                                     eps: float) -> float:
    """Theorem 3:  Omega( sqrt(L/eps) |w*| )  (constant from Nesterov 2.1.7:
    k >= sqrt( 3 L |w*|^2 / (32 eps) ) - 1 )."""
    return max(0.0, float(np.sqrt(3.0 * L * norm_w_star ** 2 / (32.0 * eps))) - 1.0)
