"""Distributed runtime: one algorithm codebase, two execution backends.

Algorithms (``core.algorithms``) are written against the ``DistERM``
interface, which exposes exactly the oracles the paper's Definition 1
allows, with every cross-machine interaction going through a metered
communicator:

    response(w)        z = A w            — ONE ReduceAll of an R^n vector
    pgrad(w, z)        f'_j(w) per block  — local
    phvp(v, z, av)     (f''(w) v)^[j]     — local given reduced Av
    dot(u, v)          <u, v> global      — ONE ReduceAll of a scalar
    end_round()        round boundary

Backends:
  * ``LocalDistERM`` — m simulated machines; per-machine blocks stacked on a
    leading axis (m, ...). Reference semantics, used by tests/benchmarks.
  * ``ShardedDistERM`` — identical math with machine j = slice j of a mesh
    axis; constructed *inside* a ``shard_map`` body. ``_run_sharded``
    places column-sharded data on a real mesh and drives any algorithm
    through it (front-ended by ``repro.api``'s sharded placement).

The two backends are required to produce bit-comparable iterates (up to
reduction order), which ``tests/test_runtime_parity.py`` asserts.

Orthogonal to the execution backend is the **oracle backend**: how the
per-machine GEMVs inside ``response``/``pgrad``/``phvp`` are computed.
Each backend is an ``OracleBackend`` strategy object (resolved once per
run by ``repro.api._resolve``, never re-dispatched per call):

  * ``"einsum"`` — plain ``jnp`` contractions (XLA decides the schedule);
    the CPU default and the reference semantics.
  * ``"kernel"`` — the MXU-tiled Pallas kernels in ``repro.kernels``
    (``feature_matvec``/``feature_rmatvec``/``feature_hvp``), ``vmap``-ed
    over the stacked machine axis in local mode and applied directly to
    the local shard inside ``shard_map``.
  * ``"fused"`` — the kernel path with epilogue-fused oracles
    (``fused_pgrad``/``fused_phvp``: the ``/n + lam v`` + mask epilogue
    folded into the contraction's last block) plus the whole-round
    ``round_step()`` capability: program builders that recognise their
    round as response -> pgrad -> block-local update hand the update to
    ``LocalDistERM.fused_round_step`` and, when the cell qualifies
    (local placement, in-kernel channel, single-tile A_j block), run the
    entire round as ONE Pallas kernel per machine with the wire channel
    applied in the same pass that emits the upload
    (``kernels/fused_round.py``); otherwise they fall back to the
    composed oracles.  The TPU default under ``auto``.

The paper meters communication *rounds*, never local FLOPs, so the oracle
backend MUST be invisible to the ``CommLedger`` — the conformance suite
(``tests/test_ledger_invariance.py``) pins that invariant (for ``fused``
it pins full bit-identity of streams, verdicts and iterates against
``kernel`` wherever the whole-round kernel engages).

A third orthogonal axis is the **round engine** (``core.engine``): whether
an algorithm's rounds run as a per-call Python loop (``"python"``) or as
one ``lax.scan``-compiled XLA program (``"scan"``).  ``_run_sharded``
accepts a step-form ``RoundProgram`` builder to compile the whole
multi-round run inside the ``shard_map`` body; the ledger is expanded
from the trace-once schedule to the same per-call stream the python loop
produces.

All three axes are front-ended by ``repro.api``: a ``RunSpec`` names
placement/backend/engine declaratively, ``plan`` resolves the ``auto``
choices through the single capability resolver, and the resulting
``ExecutionPlan`` drives the machinery here.  The per-call knobs on the
runtime classes remain for direct use; the PR-4 ``run_sharded`` kwargs
shim is retired (it raises, naming the ``RunSpec`` replacement).
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from .comm import CommLedger, LocalCommunicator, ShardMapCommunicator
from .erm import ERMProblem, GLMLoss
from .partition import FeaturePartition
from ..kernels import ops as kops


# --------------------------------------------------------------------------
# Oracle-backend dispatch
# --------------------------------------------------------------------------

# Canonical list lives in repro.api._resolve (the single resolver);
# mirrored here because this module cannot import repro.api at load time
# (repro.api.plan imports this module). tests/test_api.py pins equality.
ORACLE_BACKENDS = ("einsum", "kernel", "fused")


def resolve_oracle_backend(backend: Optional[str] = None) -> str:
    """Resolve an oracle-backend choice to a member of
    ``ORACLE_BACKENDS``.

    Delegates to the single capability resolver in ``repro.api``
    (env var consulted at call time, then the platform: kernels compile
    for TPU, interpret-mode elsewhere).  Kept under its historical name
    so direct ``LocalDistERM``/``ShardedDistERM`` construction still
    resolves; planned runs (``repro.api.plan``) arrive here with the
    choice already concrete.
    """
    # call-time import: loading repro.api at module-load time would cycle
    # (api.plan imports this module). Note this pulls the whole facade
    # package on first use, not just the leaf _resolve module — safe,
    # because by call time every module in that chain is importable.
    from ..api import _resolve
    return _resolve.resolve_oracle_backend(backend)


def _cached_loss_term(cache: dict, loss: "GLMLoss", which: str, z, y):
    """Per-round memo of ``loss.grad(z, y)`` / ``loss.hess(z, y)``.

    Keyed on the *identity* of the (possibly traced) response vector ``z``
    — within a round every oracle call sees the same ``z`` object, so
    e.g. repeated ``phvp`` calls in a CG loop reuse one Hessian-diagonal
    evaluation. ``end_round()`` clears the cache, so nothing ever leaks
    across a round boundary (or across traces: a tracer's identity dies
    with its trace, and the cache dies with the round)."""
    if cache.get("z") is not z:
        cache.clear()
        cache["z"] = z
    if which not in cache:
        fn = loss.grad if which == "grad" else loss.hess
        cache[which] = fn(z, y)
    return cache[which]


class OracleBackend:
    """Strategy protocol for the oracle compute path.

    One instance per backend name, resolved ONCE per run (``repro.api``
    resolves the name at plan time; the runtimes bind the implementation
    object at construction) — no per-call string dispatch.  Local-
    placement hooks receive the ``LocalDistERM`` and stacked ``(m, ...)``
    blocks; shard hooks receive the ``ShardedDistERM`` and machine-local
    arrays inside the ``shard_map`` body.  ``pgrad_local``/``phvp_local``
    return the FULL partial gradient / HVP (data term, ``/n``,
    ``lam``-term, block mask) so a backend may fuse the epilogue into
    its kernels.

    ``round_step`` is the whole-round capability: given an algorithm's
    block-local ``update(x, y, g, coeff) -> (x_new, y_new)`` it returns
    a fused one-kernel round step for the cell, or ``None`` when the
    backend (or the cell's channel/shape) cannot rotate the round —
    callers must then compose the round from the oracles above.
    """

    name: str = ""

    # ---- local placement: blocks stacked on a leading (m, ...) axis ----
    def response_local(self, dist, w_stk):
        raise NotImplementedError

    def pgrad_local(self, dist, w_stk, lgrad):
        raise NotImplementedError

    def phvp_local(self, dist, v_stk, h, av):
        raise NotImplementedError

    # ---- sharded placement: machine-local arrays inside shard_map ----
    def response_shard(self, dist, w_loc):
        raise NotImplementedError

    def pgrad_shard(self, dist, w_loc, lgrad):
        raise NotImplementedError

    def phvp_shard(self, dist, v_loc, h, av):
        raise NotImplementedError

    # ---- whole-round capability ----
    def round_step(self, dist, update):
        return None


class EinsumBackend(OracleBackend):
    """Plain jnp contractions — XLA schedules them; reference semantics."""

    name = "einsum"

    def response_local(self, dist, w_stk):
        return jnp.einsum("mnd,md->mn", dist.A_stk, w_stk)

    def pgrad_local(self, dist, w_stk, lgrad):
        g = jnp.einsum("mnd,n->md", dist.A_stk, lgrad) / dist.n
        return (g + dist.lam * w_stk) * dist.mask

    def phvp_local(self, dist, v_stk, h, av):
        out = jnp.einsum("mnd,n->md", dist.A_stk, h * av) / dist.n
        return (out + dist.lam * v_stk) * dist.mask

    def response_shard(self, dist, w_loc):
        return dist.A_loc @ w_loc

    def pgrad_shard(self, dist, w_loc, lgrad):
        g = dist.A_loc.T @ lgrad
        return g / dist.n + dist.lam * w_loc

    def phvp_shard(self, dist, v_loc, h, av):
        out = dist.A_loc.T @ (h * av)
        return out / dist.n + dist.lam * v_loc


class KernelBackend(OracleBackend):
    """The MXU-tiled Pallas GEMV kernels, composed with jnp epilogues."""

    name = "kernel"

    def response_local(self, dist, w_stk):
        return jax.vmap(kops.feature_matvec)(dist.A_stk, w_stk)

    def pgrad_local(self, dist, w_stk, lgrad):
        g = jax.vmap(kops.feature_rmatvec,
                     in_axes=(0, None))(dist.A_stk, lgrad) / dist.n
        return (g + dist.lam * w_stk) * dist.mask

    def phvp_local(self, dist, v_stk, h, av):
        out = jax.vmap(kops.feature_hvp,
                       in_axes=(0, None, None))(dist.A_stk, h, av) \
            / dist.n
        return (out + dist.lam * v_stk) * dist.mask

    def response_shard(self, dist, w_loc):
        return kops.feature_matvec(dist.A_loc, w_loc)

    def pgrad_shard(self, dist, w_loc, lgrad):
        g = kops.feature_rmatvec(dist.A_loc, lgrad)
        return g / dist.n + dist.lam * w_loc

    def phvp_shard(self, dist, v_loc, h, av):
        out = kops.feature_hvp(dist.A_loc, h, av)
        return out / dist.n + dist.lam * v_loc


class FusedBackend(KernelBackend):
    """Kernel path + epilogue fusion + the whole-round capability.

    Composed oracles route through ``fused_pgrad``/``fused_phvp`` (the
    gradient epilogue folded into the contraction's last block — one
    A-read per oracle; this is what DISCO-F's CG hits every inner
    iteration, where the round's scalar reduces make a whole-round
    rotation impossible).  Sharded placement inherits the kernel
    oracles unchanged: inside ``shard_map`` the fused backend is the
    kernel backend, by construction bit-identical.  ``round_step``
    builds the one-kernel-per-machine round of
    ``kernels.fused_round.make_round_step`` when the cell qualifies.
    """

    name = "fused"

    def pgrad_local(self, dist, w_stk, lgrad):
        return jax.vmap(
            functools.partial(kops.fused_pgrad, n=dist.n, lam=dist.lam),
            in_axes=(0, None, 0, 0))(dist.A_stk, lgrad, w_stk, dist.mask)

    def phvp_local(self, dist, v_stk, h, av):
        return jax.vmap(
            functools.partial(kops.fused_phvp, n=dist.n, lam=dist.lam),
            in_axes=(0, None, None, 0, 0))(dist.A_stk, h, av, v_stk,
                                           dist.mask)

    def round_step(self, dist, update):
        from ..kernels import fused_round
        chan = dist.comm.channel
        if fused_round.channel_stages(chan) is None:
            return None     # topk (or unresolved) stages stay composed
        if not fused_round.round_step_fits(dist.n, dist.part.d_max):
            return None     # A_j block exceeds one VMEM tile
        return fused_round.make_round_step(
            dist.A_stk, dist.mask, dist.y, dist.loss,
            n=dist.n, lam=dist.lam, update=update, channel=chan)


BACKEND_IMPLS = {
    "einsum": EinsumBackend(),
    "kernel": KernelBackend(),
    "fused": FusedBackend(),
}


class LocalDistERM:
    """m machines simulated on host; blocks stacked: A (m,n,dmax), w (m,dmax).

    ``backend`` selects the oracle compute path ("einsum" | "kernel" |
    "fused" | "auto"/None for the platform default); the resolved name
    binds an ``OracleBackend`` strategy object once, at construction.
    """

    def __init__(self, prob: ERMProblem, part: FeaturePartition,
                 ledger: Optional[CommLedger] = None,
                 backend: Optional[str] = None,
                 channel=None, faults=None):
        self.prob = prob
        self.part = part
        self.comm = LocalCommunicator(part.m, ledger, channel=channel,
                                      faults=faults)
        self.backend = resolve_oracle_backend(backend)
        self.backend_impl: OracleBackend = BACKEND_IMPLS[self.backend]
        self.A_stk = part.pad_blocks(part.split_columns(prob.A))  # (m,n,dmax)
        self.mask = part.mask()                                   # (m,dmax)
        self.n = prob.n
        self.lam = prob.lam
        self.loss: GLMLoss = prob.loss
        self.y = prob.y
        self._round_cache: dict = {}

    # ---- paper oracles --------------------------------------------------
    def zeros_like_w(self):
        return jnp.zeros((self.part.m, self.part.d_max))

    def response(self, w_stk, tag="z=Aw"):
        """z = sum_j A_j w_j : one ReduceAll of an R^n vector."""
        local = self.backend_impl.response_local(self, w_stk)
        return self.comm.reduce_all(local, tag=tag)

    def reduce_response(self, zloc_stk, tag="z=Aw"):
        """Reduce per-machine response summands a fused round-step
        already computed AND channel-transformed in-kernel: the same
        metered ReduceAll as ``response`` (record, pricing, faults all
        byte-identical), minus the redundant second wire transform."""
        return self.comm.reduce_all(zloc_stk, tag=tag, pretransformed=True)

    def pgrad(self, w_stk, z):
        """f'_j(w) for every j, stacked — local compute only."""
        lgrad = self._loss_term("grad", z)                    # (n,)
        return self.backend_impl.pgrad_local(self, w_stk, lgrad)

    def phvp(self, v_stk, z, av):
        """(f''(w) v)^[j] stacked, given reduced z=Aw and av=Av — local."""
        h = self._loss_term("hess", z)
        return self.backend_impl.phvp_local(self, v_stk, h, av)

    def fused_round_step(self, update):
        """The backend's whole-round fused step for this cell (see
        ``OracleBackend.round_step``), or ``None`` — program builders
        call this and fall back to the composed oracles on ``None``."""
        return self.backend_impl.round_step(self, update)

    def _loss_term(self, which: str, z):
        return _cached_loss_term(self._round_cache, self.loss, which, z,
                                 self.y)

    def dot(self, u_stk, v_stk, tag="dot"):
        u_stk, v_stk = jnp.asarray(u_stk), jnp.asarray(v_stk)
        shape = (self.part.m, self.part.d_max)
        if u_stk.shape != shape or v_stk.shape != shape:
            raise ValueError(
                f"dot expects stacked blocks of shape {shape}; got "
                f"{u_stk.shape} and {v_stk.shape} — a wrong-rank input "
                f"would silently reduce over the wrong axes")
        # one masked contraction: padding coordinates never contribute,
        # even if a caller let nonzero values leak into the pad region
        local = jnp.einsum("md,md->m", u_stk * self.mask, v_stk)
        return self.comm.reduce_scalar(local, tag=tag)

    def value(self, w_stk, z):
        """f(w) given reduced z (needs one scalar reduce for |w|^2)."""
        sq = self.dot(w_stk, w_stk, tag="|w|^2")
        return jnp.sum(self.loss.value(z, self.y)) / self.n + 0.5 * self.lam * sq

    def end_round(self):
        self._round_cache.clear()
        self.comm.end_round()

    # ---- incremental-family oracles (Definition 3.2) ---------------------
    def sample_row(self, i: int):
        """Machine-local blocks of data row i: a_i^[j], stacked (m, dmax)."""
        return self.A_stk[:, i, :]

    def dot_row(self, a_i, w_stk, tag="a_i.w"):
        """Scalar a_i . w — one ReduceAll of a scalar."""
        local = jnp.einsum("md,md->m", a_i, w_stk)
        return self.comm.reduce_scalar(local, tag=tag)

    def row_grad(self, a_i, zi, i):
        """Component gradient blocks: a_i^[j] * l'(z_i, y_i) (no 1/n)."""
        return a_i * self.loss.grad(zi, self.y[i])

    # ---- conversions ----------------------------------------------------
    def gather_w(self, w_stk) -> jnp.ndarray:
        return self.part.concat_blocks(self.part.unpad_blocks(w_stk))

    def scatter_w(self, w) -> jnp.ndarray:
        return self.part.pad_blocks(self.part.split_vector(w))


class ShardedDistERM:
    """Same oracle surface inside a shard_map body.

    Local arrays: A_loc (n, d_loc), w_loc (d_loc,). All machines see the
    same y. Construct inside the shard_map body with the mesh axis name.
    """

    def __init__(self, A_loc, y, loss: GLMLoss, lam: float, n: int,
                 axis: str = "model", ledger: Optional[CommLedger] = None,
                 backend: Optional[str] = None,
                 channel=None):
        self.A_loc = A_loc
        self.y = y
        self.loss = loss
        self.lam = lam
        self.n = n
        self.comm = ShardMapCommunicator(axis, ledger, channel=channel)
        self.backend = resolve_oracle_backend(backend)
        self.backend_impl: OracleBackend = BACKEND_IMPLS[self.backend]
        self._round_cache: dict = {}

    def zeros_like_w(self):
        return jnp.zeros((self.A_loc.shape[1],))

    def response(self, w_loc, tag="z=Aw"):
        local = self.backend_impl.response_shard(self, w_loc)
        return self.comm.reduce_all(local, tag=tag)

    def pgrad(self, w_loc, z):
        lgrad = self._loss_term("grad", z)
        return self.backend_impl.pgrad_shard(self, w_loc, lgrad)

    def phvp(self, v_loc, z, av):
        h = self._loss_term("hess", z)
        return self.backend_impl.phvp_shard(self, v_loc, h, av)

    def _loss_term(self, which: str, z):
        return _cached_loss_term(self._round_cache, self.loss, which, z,
                                 self.y)

    def dot(self, u_loc, v_loc, tag="dot"):
        u_loc, v_loc = jnp.asarray(u_loc), jnp.asarray(v_loc)
        if u_loc.ndim != 1 or u_loc.shape != v_loc.shape:
            raise ValueError(
                f"dot expects machine-local blocks of matching 1-D shape; "
                f"got {u_loc.shape} and {v_loc.shape}")
        return self.comm.reduce_scalar(jnp.vdot(u_loc, v_loc), tag=tag)

    def value(self, w_loc, z):
        sq = self.dot(w_loc, w_loc, tag="|w|^2")
        return jnp.sum(self.loss.value(z, self.y)) / self.n + 0.5 * self.lam * sq

    def end_round(self):
        self._round_cache.clear()
        self.comm.end_round()

    # ---- incremental-family oracles --------------------------------------
    def sample_row(self, i: int):
        return self.A_loc[i, :]

    def dot_row(self, a_i_loc, w_loc, tag="a_i.w"):
        return self.comm.reduce_scalar(jnp.vdot(a_i_loc, w_loc), tag=tag)

    def row_grad(self, a_i_loc, zi, i):
        return a_i_loc * self.loss.grad(zi, self.y[i])


# --------------------------------------------------------------------------
# shard_map driver
# --------------------------------------------------------------------------

def run_sharded(*args, **kwargs):
    """Removed legacy entry point (deprecated in PR 4, retired now).

    Construct a ``repro.api.RunSpec(placement='sharded', ...)`` and
    execute it via ``repro.api.plan()``/``run()`` — the facade resolves
    ``backend``/``engine``/``channel`` through the single capability
    resolver and validates the combination before compiling.  Library
    internals (and non-registry ``algorithm_body`` callables) use the
    private ``_run_sharded`` driver directly.
    """
    raise TypeError(
        "run_sharded(...) with per-call kwargs was removed: construct a "
        "repro.api.RunSpec(placement='sharded') and execute it via "
        "repro.api.plan()/run(); library internals use "
        "repro.core.runtime._run_sharded")


def _run_sharded(prob: ERMProblem, algorithm_body: Optional[Callable],
                 rounds: int,
                 mesh: Optional[Mesh] = None, axis: str = "model",
                 ledger: Optional[CommLedger] = None,
                 backend: Optional[str] = None,
                 engine: str = "python",
                 program_builder: Optional[Callable] = None,
                 channel=None, trace_only: bool = False,
                 lower_only: bool = False):
    """Run an algorithm under shard_map with the data matrix column-sharded
    over ``axis``.  (Machinery behind ``repro.api``'s sharded placement;
    the retired public ``run_sharded`` wrapper raises, naming this
    driver and the ``RunSpec`` path.)

    Two driving modes, selected by ``engine``:

    * ``"python"`` (default) — ``algorithm_body(dist, rounds) -> w_loc``
      is traced as-is: the historical per-round Python loop unrolled into
      the jitted body. Ledger counts are trace-time (ops per traced
      call), i.e. the full per-round stream.
    * ``"scan"`` — ``program_builder(dist, rounds) -> RoundProgram``
      (step-form, see ``core.engine``) is compiled segment-by-segment
      with ``lax.scan`` inside the shard_map body, so the traced program
      is one scan per segment regardless of the round budget. Each
      segment's step traces ONCE; afterwards the ledger is expanded from
      the captured per-step schedule to the identical per-round stream
      the python mode records.

    ``backend`` picks the oracle compute path (see
    ``resolve_oracle_backend``). Returns the assembled global w (d,) and
    the per-round ledger.
    """
    from jax.experimental.shard_map import shard_map  # local import: jax>=0.4

    from .engine import resolve_engine

    engine = resolve_engine(engine)
    if engine == "scan" and program_builder is None:
        raise ValueError("engine='scan' requires a program_builder "
                         "(step-form RoundProgram factory)")
    if engine == "python" and algorithm_body is None:
        raise ValueError("engine='python' requires an algorithm_body")

    if mesh is None:
        devs = np.array(jax.devices())
        mesh = Mesh(devs, (axis,))
    m = mesh.shape[axis]
    d = prob.d
    if d % m:
        pad = m - d % m
        A = jnp.pad(prob.A, ((0, 0), (0, pad)))
    else:
        pad = 0
        A = prob.A
    led = ledger if ledger is not None else CommLedger()
    backend = resolve_oracle_backend(backend)
    from .channel import parse_channel
    chan = parse_channel(channel)
    scheduled = getattr(chan, "scheduled", False)
    pre_records, pre_rounds = len(led.records), led.rounds
    spans = []   # (start, end, rounds_traced, count) per scanned segment
    # run-time global round base of the NEXT scanned segment (python int:
    # each segment's rounds-per-step is concrete at trace time)
    run_base = [pre_rounds]

    def body(A_loc, y):
        dist = ShardedDistERM(A_loc, y, prob.loss, prob.lam, prob.n,
                              axis=axis, ledger=led, backend=backend,
                              channel=chan)
        if engine == "python":
            return algorithm_body(dist, rounds)
        program = program_builder(dist, rounds)
        carry = program.init
        for seg in program.segments:
            xs = (jnp.asarray(seg.xs) if seg.xs is not None
                  else jnp.arange(seg.count, dtype=jnp.int32))
            start, r0 = len(led.records), led.rounds

            def scan_body(c, x, _step=seg.step):
                c, _ = _step(dist, c, x)
                return c, None

            def sched_body(cr, x, _step=seg.step):
                # scheduled channel: thread the global round index as a
                # carried counter so the transform switches stages
                # mid-scan; the per-step advance is concrete at trace
                # time (the ledger meters eagerly while tracing).
                c, rk = cr
                dist.comm.begin_round(rk)
                r_in = led.rounds
                c, _ = _step(dist, c, x)
                dist.comm.reset_round()
                return (c, rk + (led.rounds - r_in)), None

            if scheduled:
                (carry, _), _ = lax.scan(
                    sched_body, (carry, jnp.int32(run_base[0])), xs)
            else:
                carry, _ = lax.scan(scan_body, carry, xs)
            r_traced = led.rounds - r0
            run_base[0] += r_traced * seg.count
            spans.append((start, len(led.records), r_traced, seg.count))
        return program.final(carry)

    # pallas_call has no shard_map replication rule, and lax.scan carries
    # mixing replicated (z, scalars) with sharded (w-block) values defeat
    # the replication typer; both paths opt out of the (purely
    # diagnostic) replication check.
    fn = shard_map(body, mesh=mesh,
                   in_specs=(P(None, axis), P(None)),
                   out_specs=P(axis),
                   check_rep=(backend not in ("kernel", "fused")
                              and engine != "scan"))
    if trace_only:
        # repro.analysis hook: trace the sharded program without running
        # it and hand back the jaxpr, the raw trace-time ledger (records
        # metered once per scanned segment, NOT expanded), and the spans
        # the expansion below would have consumed — the static verifier
        # performs its own expansion and proves it equal to the ledger
        # this function produces when actually run.
        closed = jax.make_jaxpr(fn)(A, prob.y)
        return closed, led, spans
    if lower_only:
        # HLO audit hook: the lowered (compilable, unexecuted) sharded
        # computation, for collective_bytes_from_hlo cross-checks of the
        # collectives XLA actually emits against the metered ledger.
        return jax.jit(fn).lower(A, prob.y), led, spans
    w = jax.jit(fn)(A, prob.y)
    if spans:
        # Expand the trace-once schedule: each segment's single traced
        # step stream repeats `count` times, reproducing the per-round
        # stream — round-boundary marks included — the python mode
        # records bit-identically.  Marks are record positions into the
        # trace-time stream; each region's marks are rebased onto the
        # expanded stream as the region is copied.
        records, marks = led.records, led.round_marks
        expanded = list(records[:pre_records])
        new_marks = [m for m in marks if m <= pre_records]
        rounds_total = pre_rounds
        prev_end = pre_records
        for start, end, r_traced, count in spans:
            # records (and any marks) traced outside the scans, if ever
            new_marks.extend(len(expanded) + (m - prev_end)
                             for m in marks if prev_end < m <= start)
            expanded.extend(records[prev_end:start])
            span_records = records[start:end]
            span_marks = [m - start for m in marks if start < m <= end]
            for _ in range(count):
                base = len(expanded)
                if scheduled:
                    # trace-time prices are provisional (the round index
                    # was a tracer): re-price each repeat from its
                    # global round base, as the scan-engine replay does.
                    from .comm import repriced_records
                    expanded.extend(repriced_records(
                        span_records, span_marks, rounds_total, chan))
                else:
                    expanded.extend(span_records)
                new_marks.extend(base + m for m in span_marks)
                rounds_total += r_traced
            prev_end = end
        new_marks.extend(len(expanded) + (m - prev_end)
                         for m in marks if m > prev_end)
        expanded.extend(records[prev_end:])
        led.records[:] = expanded
        led.round_marks[:] = new_marks
        led.rounds = rounds_total
    return (w[:d] if pad else w), led
