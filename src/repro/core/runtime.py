"""Distributed runtime: one algorithm codebase, two execution backends.

Algorithms (``core.algorithms``) are written against the ``DistERM``
interface, which exposes exactly the oracles the paper's Definition 1
allows, with every cross-machine interaction going through a metered
communicator:

    response(w)        z = A w            — ONE ReduceAll of an R^n vector
    pgrad(w, z)        f'_j(w) per block  — local
    phvp(v, z, av)     (f''(w) v)^[j]     — local given reduced Av
    dot(u, v)          <u, v> global      — ONE ReduceAll of a scalar
    end_round()        round boundary

Backends:
  * ``LocalDistERM`` — m simulated machines; per-machine blocks stacked on a
    leading axis (m, ...). Reference semantics, used by tests/benchmarks.
  * ``ShardedDistERM`` — identical math with machine j = slice j of a mesh
    axis; constructed *inside* a ``shard_map`` body. ``run_sharded`` places
    column-sharded data on a real mesh and drives any algorithm through it.

The two backends are required to produce bit-comparable iterates (up to
reduction order), which ``tests/test_runtime_parity.py`` asserts.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional, Tuple

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .comm import CommLedger, LocalCommunicator, ShardMapCommunicator
from .erm import ERMProblem, GLMLoss
from .partition import FeaturePartition, even_partition


class LocalDistERM:
    """m machines simulated on host; blocks stacked: A (m,n,dmax), w (m,dmax)."""

    def __init__(self, prob: ERMProblem, part: FeaturePartition,
                 ledger: Optional[CommLedger] = None):
        self.prob = prob
        self.part = part
        self.comm = LocalCommunicator(part.m, ledger)
        self.A_stk = part.pad_blocks(part.split_columns(prob.A))  # (m,n,dmax)
        self.mask = part.mask()                                   # (m,dmax)
        self.n = prob.n
        self.lam = prob.lam
        self.loss: GLMLoss = prob.loss
        self.y = prob.y

    # ---- paper oracles --------------------------------------------------
    def zeros_like_w(self):
        return jnp.zeros((self.part.m, self.part.d_max))

    def response(self, w_stk, tag="z=Aw"):
        """z = sum_j A_j w_j : one ReduceAll of an R^n vector."""
        local = jnp.einsum("mnd,md->mn", self.A_stk, w_stk)
        return self.comm.reduce_all(local, tag=tag)

    def pgrad(self, w_stk, z):
        """f'_j(w) for every j, stacked — local compute only."""
        lgrad = self.loss.grad(z, self.y)                     # (n,)
        g = jnp.einsum("mnd,n->md", self.A_stk, lgrad) / self.n
        return (g + self.lam * w_stk) * self.mask

    def phvp(self, v_stk, z, av):
        """(f''(w) v)^[j] stacked, given reduced z=Aw and av=Av — local."""
        h = self.loss.hess(z, self.y)
        out = jnp.einsum("mnd,n->md", self.A_stk, h * av) / self.n
        return (out + self.lam * v_stk) * self.mask

    def dot(self, u_stk, v_stk, tag="dot"):
        local = jnp.sum(u_stk * v_stk, axis=(-2, -1)) \
            if u_stk.ndim > 2 else jnp.einsum("md,md->m", u_stk, v_stk)
        return self.comm.reduce_scalar(local, tag=tag)

    def value(self, w_stk, z):
        """f(w) given reduced z (needs one scalar reduce for |w|^2)."""
        sq = self.dot(w_stk, w_stk, tag="|w|^2")
        return jnp.sum(self.loss.value(z, self.y)) / self.n + 0.5 * self.lam * sq

    def end_round(self):
        self.comm.end_round()

    # ---- incremental-family oracles (Definition 3.2) ---------------------
    def sample_row(self, i: int):
        """Machine-local blocks of data row i: a_i^[j], stacked (m, dmax)."""
        return self.A_stk[:, i, :]

    def dot_row(self, a_i, w_stk, tag="a_i.w"):
        """Scalar a_i . w — one ReduceAll of a scalar."""
        local = jnp.einsum("md,md->m", a_i, w_stk)
        return self.comm.reduce_scalar(local, tag=tag)

    def row_grad(self, a_i, zi, i):
        """Component gradient blocks: a_i^[j] * l'(z_i, y_i) (no 1/n)."""
        return a_i * self.loss.grad(zi, self.y[i])

    # ---- conversions ----------------------------------------------------
    def gather_w(self, w_stk) -> jnp.ndarray:
        return self.part.concat_blocks(self.part.unpad_blocks(w_stk))

    def scatter_w(self, w) -> jnp.ndarray:
        return self.part.pad_blocks(self.part.split_vector(w))


class ShardedDistERM:
    """Same oracle surface inside a shard_map body.

    Local arrays: A_loc (n, d_loc), w_loc (d_loc,). All machines see the
    same y. Construct inside the shard_map body with the mesh axis name.
    """

    def __init__(self, A_loc, y, loss: GLMLoss, lam: float, n: int,
                 axis: str = "model", ledger: Optional[CommLedger] = None):
        self.A_loc = A_loc
        self.y = y
        self.loss = loss
        self.lam = lam
        self.n = n
        self.comm = ShardMapCommunicator(axis, ledger)

    def zeros_like_w(self):
        return jnp.zeros((self.A_loc.shape[1],))

    def response(self, w_loc, tag="z=Aw"):
        return self.comm.reduce_all(self.A_loc @ w_loc, tag=tag)

    def pgrad(self, w_loc, z):
        return self.A_loc.T @ self.loss.grad(z, self.y) / self.n \
            + self.lam * w_loc

    def phvp(self, v_loc, z, av):
        h = self.loss.hess(z, self.y)
        return self.A_loc.T @ (h * av) / self.n + self.lam * v_loc

    def dot(self, u_loc, v_loc, tag="dot"):
        return self.comm.reduce_scalar(jnp.vdot(u_loc, v_loc), tag=tag)

    def value(self, w_loc, z):
        sq = self.dot(w_loc, w_loc, tag="|w|^2")
        return jnp.sum(self.loss.value(z, self.y)) / self.n + 0.5 * self.lam * sq

    def end_round(self):
        self.comm.end_round()

    # ---- incremental-family oracles --------------------------------------
    def sample_row(self, i: int):
        return self.A_loc[i, :]

    def dot_row(self, a_i_loc, w_loc, tag="a_i.w"):
        return self.comm.reduce_scalar(jnp.vdot(a_i_loc, w_loc), tag=tag)

    def row_grad(self, a_i_loc, zi, i):
        return a_i_loc * self.loss.grad(zi, self.y[i])


# --------------------------------------------------------------------------
# shard_map driver
# --------------------------------------------------------------------------

def run_sharded(prob: ERMProblem, algorithm_body: Callable, rounds: int,
                mesh: Optional[Mesh] = None, axis: str = "model",
                ledger: Optional[CommLedger] = None):
    """Run ``algorithm_body(dist, rounds) -> w_loc`` under shard_map with the
    data matrix column-sharded over ``axis``.

    ``algorithm_body`` receives a ``ShardedDistERM`` and a static round
    count and must return the machine-local block of the final iterate.
    Returns the assembled global w (d,) and the per-round ledger (counts are
    trace-time: ops per traced call).
    """
    from jax.experimental.shard_map import shard_map  # local import: jax>=0.4

    if mesh is None:
        devs = np.array(jax.devices())
        mesh = Mesh(devs, (axis,))
    m = mesh.shape[axis]
    d = prob.d
    if d % m:
        pad = m - d % m
        A = jnp.pad(prob.A, ((0, 0), (0, pad)))
    else:
        pad = 0
        A = prob.A
    led = ledger if ledger is not None else CommLedger()

    def body(A_loc, y):
        dist = ShardedDistERM(A_loc, y, prob.loss, prob.lam, prob.n,
                              axis=axis, ledger=led)
        return algorithm_body(dist, rounds)

    fn = shard_map(body, mesh=mesh,
                   in_specs=(P(None, axis), P(None)),
                   out_specs=P(axis))
    w = jax.jit(fn)(A, prob.y)
    return (w[:d] if pad else w), led
