"""DISCO-F — distributed inexact (damped) Newton, feature-partitioned
[Ma & Takac 2016, ref 9 in the paper].

Newton direction solved by distributed conjugate gradient. Under the
feature partition each CG iteration needs:
    Av   : one ReduceAll of an R^n vector (the same budget as a gradient)
    Hp_j : local  A_j^T (l''(z) * Av)/n + lam p_j
    2 scalar ReduceAll ops (alpha, beta line-search scalars)
i.e. one Definition-1 round per CG iteration. On quadratics a single
Newton system solved to accuracy eps gives the paper's quoted
O(sqrt(kappa) log(1/eps)) rounds — the second tightness witness, showing
second-order information does NOT beat the bound under linear-size
communication.

For non-quadratic GLM losses the standard damped outer loop is provided
(a constant number of outer steps, each an inner CG run).

Round structure is non-uniform — a Newton/gradient round followed by a
run of identical CG rounds — so the step-form program uses one segment
per phase with a carry that is uniform across both step kinds:
``(w0, z, u, r, p, rs)``.  The initial CG residual norm ``rs`` is folded
into the Newton round's step; the flat CommLedger record stream is
unchanged from the historical loop (only the position of a round
boundary relative to that one scalar reduce moves, which no meter
quantity — records, rounds, bytes/round — observes).
"""
from __future__ import annotations

import jax.numpy as jnp

from ..engine import RoundProgram, Segment, run_program


def disco_f_program(dist, rounds: int, L: float, lam: float = 0.0,
                    newton_steps: int = 1) -> RoundProgram:
    """``rounds`` is the TOTAL communication-round budget; it is split
    evenly across ``newton_steps`` inner CG runs (quadratics: 1 outer)."""
    inner = max(1, rounds // max(1, newton_steps) - 1)
    zero = dist.zeros_like_w()
    init = dict(w0=zero, z=jnp.zeros((dist.n,)), u=zero, r=zero, p=zero,
                rs=jnp.asarray(0.0))

    def step_newton(dist, carry, _):
        """One gradient round: refresh z, g at w = w0 - u and reset CG."""
        w = carry["w0"] - carry["u"]
        z = dist.response(w, tag="newton.z")
        g = dist.pgrad(w, z)
        rs = dist.dot(g, g, tag="cg.rs")
        dist.end_round()
        return dict(w0=w, z=z, u=jnp.zeros_like(w), r=g, p=g, rs=rs), w

    def step_cg(dist, carry, _):
        """One distributed CG iteration on  f''(w) u = g."""
        w0, z = carry["w0"], carry["z"]
        u, r, p, rs = carry["u"], carry["r"], carry["p"], carry["rs"]
        av = dist.response(p, tag="cg.Ap")     # R^n ReduceAll
        hp = dist.phvp(p, z, av)
        alpha = rs / jnp.maximum(dist.dot(p, hp, tag="cg.pHp"), 1e-30)
        u = u + alpha * p
        r = r - alpha * hp
        rs_new = dist.dot(r, r, tag="cg.rs")
        p_new = r + (rs_new / jnp.maximum(rs, 1e-30)) * p
        dist.end_round()
        return dict(w0=w0, z=z, u=u, r=r, p=p_new, rs=rs_new), w0 - u

    segments = []
    for _ in range(max(1, newton_steps)):
        segments.append(Segment(step_newton, 1, name="newton"))
        segments.append(Segment(step_cg, inner, name="cg"))
    return RoundProgram(init=init, segments=segments,
                        final=lambda c: c["w0"] - c["u"])


def disco_f(dist, rounds: int, L: float, lam: float = 0.0,
            newton_steps: int = 1, history: bool = False,
            engine: str = "python"):
    res = run_program(dist,
                      disco_f_program(dist, rounds, L=L, lam=lam,
                                      newton_steps=newton_steps),
                      engine=engine, history=history)
    return (res.w, {"iterates": res.iterates}) if history else res.w
