"""DISCO-F — distributed inexact (damped) Newton, feature-partitioned
[Ma & Takac 2016, ref 9 in the paper].

Newton direction solved by distributed conjugate gradient. Under the
feature partition each CG iteration needs:
    Av   : one ReduceAll of an R^n vector (the same budget as a gradient)
    Hp_j : local  A_j^T (l''(z) * Av)/n + lam p_j
    2 scalar ReduceAll ops (alpha, beta line-search scalars)
i.e. one Definition-1 round per CG iteration. On quadratics a single
Newton system solved to accuracy eps gives the paper's quoted
O(sqrt(kappa) log(1/eps)) rounds — the second tightness witness, showing
second-order information does NOT beat the bound under linear-size
communication.

For non-quadratic GLM losses the standard damped outer loop is provided
(a constant number of outer steps, each an inner CG run).
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp


def _cg(dist, z, g, iters: int, w0=None, iterates=None):
    """Distributed CG on  f''(w) u = g,  given reduced z = A w.
    If ``iterates`` is a list, the per-CG-round point w0 - u_k is appended
    (one entry per communication round, for rounds-to-eps accounting)."""
    u = dist.zeros_like_w()
    r = g                       # residual b - H u with u = 0
    p = r
    rs = dist.dot(r, r, tag="cg.rs")
    for _ in range(iters):
        av = dist.response(p, tag="cg.Ap")     # R^n ReduceAll
        hp = dist.phvp(p, z, av)
        alpha = rs / jnp.maximum(dist.dot(p, hp, tag="cg.pHp"), 1e-30)
        u = u + alpha * p
        r = r - alpha * hp
        rs_new = dist.dot(r, r, tag="cg.rs")
        p = r + (rs_new / jnp.maximum(rs, 1e-30)) * p
        rs = rs_new
        dist.end_round()
        if iterates is not None and w0 is not None:
            iterates.append(w0 - u)
    return u


def disco_f(dist, rounds: int, L: float, lam: float = 0.0,
            newton_steps: int = 1, history: bool = False):
    """``rounds`` is the TOTAL communication-round budget; it is split
    evenly across ``newton_steps`` inner CG runs (quadratics: 1 outer)."""
    w = dist.zeros_like_w()
    iterates = [] if history else None
    inner = max(1, rounds // max(1, newton_steps) - 1)
    for _ in range(newton_steps):
        z = dist.response(w, tag="newton.z")
        g = dist.pgrad(w, z)
        dist.end_round()
        if history:
            iterates.append(w)     # the round spent on the gradient
        u = _cg(dist, z, g, iters=inner, w0=w, iterates=iterates)
        w = w - u
    return (w, {"iterates": iterates}) if history else w
