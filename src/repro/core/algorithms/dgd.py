"""Distributed gradient descent under the feature partition.

Round structure (exactly Definition 1's budget):
  computation phase : z = ReduceAll_j(A_j w_j)   (one R^n ReduceAll)
                      g_j = A_j^T l'(z)/n + lam w_j  (local)
  update            : w_j <- w_j - eta g_j            (local, own block only)

No communication-phase broadcast is ever needed: the iterate never has to
be materialized on one machine. This is the communication advantage the
paper attributes to partition-on-feature algorithms.
"""
from __future__ import annotations

import jax.numpy as jnp

from ..engine import RoundProgram, Segment, run_program
from ._fused import fused_linear_program


def dgd_program(dist, rounds: int, L: float, lam: float = 0.0
                ) -> RoundProgram:
    # f64-computed, f32-wrapped: same value the weak-typed float gave the
    # f32 update, but a hoistable const so repro.api.execute_batch can
    # group cells that differ only in L (see dagd.py).
    eta = jnp.float32(2.0 / (L + lam) if lam > 0 else 1.0 / L)

    def update(x, y, g, coeff):
        w_new = y - eta * g
        return w_new, w_new

    fused = fused_linear_program(dist, rounds, update, name="gd")
    if fused is not None:
        return fused

    def step(dist, w, _):
        z = dist.response(w)
        g = dist.pgrad(w, z)
        w_new = w - eta * g
        dist.end_round()
        return w_new, w_new

    return RoundProgram(init=dist.zeros_like_w(),
                        segments=[Segment(step, rounds, name="gd")],
                        final=lambda w: w)


def dgd(dist, rounds: int, L: float, lam: float = 0.0,
        history: bool = False, engine: str = "python"):
    """Plain GD with the standard step 2/(L+lam) (=1/L if lam=0)."""
    res = run_program(dist, dgd_program(dist, rounds, L=L, lam=lam),
                      engine=engine, history=history)
    return (res.w, {"iterates": res.iterates}) if history else res.w
