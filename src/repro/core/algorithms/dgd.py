"""Distributed gradient descent under the feature partition.

Round structure (exactly Definition 1's budget):
  computation phase : z = ReduceAll_j(A_j w_j)   (one R^n ReduceAll)
                      g_j = A_j^T l'(z)/n + lam w_j  (local)
  update            : w_j <- w_j - eta g_j            (local, own block only)

No communication-phase broadcast is ever needed: the iterate never has to
be materialized on one machine. This is the communication advantage the
paper attributes to partition-on-feature algorithms.
"""
from __future__ import annotations

from typing import Optional


def dgd(dist, rounds: int, L: float, lam: float = 0.0,
        history: bool = False):
    """Plain GD with the standard step 2/(L+lam) (=1/L if lam=0)."""
    eta = 2.0 / (L + lam) if lam > 0 else 1.0 / L
    w = dist.zeros_like_w()
    iterates = []
    for _ in range(rounds):
        z = dist.response(w)
        g = dist.pgrad(w, z)
        w = w - eta * g
        dist.end_round()
        if history:
            iterates.append(w)
    return (w, {"iterates": iterates}) if history else w
