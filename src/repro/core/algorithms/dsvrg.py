"""Feature-partitioned SVRG — a member of the paper's incremental family
I^{lam,L} (Definition in Sec. 3.2).

Round structure: each stochastic step touches ONE component phi(w, A_l:)
(Eq. 3's g(w)) and needs the scalar a_l . w — under the feature partition
that is one ReduceAll of a SCALAR per step (machine j contributes
a_l[S_j] . w_j), so a stochastic step is a (cheap) communication round.
Snapshot full gradients cost one R^n ReduceAll.

SVRG round complexity O((n + kappa_max) log(1/eps)) does NOT meet the
Theorem-4 floor Omega((sqrt(n kappa) + n) log(1/eps)); the paper leaves
tightness open. benchmarks/thm4_incremental.py plots both.

Step form: sampling is data-independent, so the full index sequence is
pre-drawn (same ``RandomState`` order as the historical loop) and scanned
over as ``xs`` — one snapshot segment plus one stochastic segment per
epoch, with a carry ``(w, w_snap, z_snap, g_snap)`` that is uniform
across both step kinds.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from ..engine import RoundProgram, Segment, run_program


def dsvrg_program(dist, rounds: int, L_max: float, lam: float = 0.0,
                  epoch_len: int = 0, seed: int = 0, eta: float = 0.0
                  ) -> RoundProgram:
    n = dist.n
    epoch_len = epoch_len or 2 * n
    # f64-computed, f32-wrapped for const hoisting (see dagd.py)
    eta = jnp.float32(eta or 1.0 / (10.0 * L_max))
    lam_f = jnp.float32(lam)
    rng = np.random.RandomState(seed)
    zero = dist.zeros_like_w()
    init = dict(w=zero, w_snap=zero, z_snap=jnp.zeros((n,)), g_snap=zero)

    def step_snapshot(dist, carry, _):
        """One R^n ReduceAll + local full partial gradient; w unchanged
        (the snapshot consumes a round, so history index k == round k)."""
        w = carry["w"]
        z_snap = dist.response(w, tag="svrg.snapshot")
        g_snap = dist.pgrad(w, z_snap)   # includes lam*w term
        dist.end_round()
        return dict(w=w, w_snap=w, z_snap=z_snap, g_snap=g_snap), w

    def step_inner(dist, carry, i):
        """One stochastic step == one scalar-ReduceAll round."""
        w, w_snap = carry["w"], carry["w_snap"]
        z_snap, g_snap = carry["z_snap"], carry["g_snap"]
        a_i = dist.sample_row(i)                  # local block of row i
        zi = dist.dot_row(a_i, w, tag="svrg.aw")  # scalar reduce
        zi_snap = z_snap[i]
        gi = dist.row_grad(a_i, zi, i) + lam_f * w
        gi_snap = dist.row_grad(a_i, zi_snap, i) + lam_f * w_snap
        w_new = w - eta * (gi - gi_snap + g_snap)
        dist.end_round()
        return dict(w=w_new, w_snap=w_snap, z_snap=z_snap,
                    g_snap=g_snap), w_new

    segments, used = [], 0
    while used < rounds:
        segments.append(Segment(step_snapshot, 1, name="snapshot"))
        used += 1
        k = min(epoch_len, rounds - used)
        if k > 0:
            idx = np.asarray([rng.randint(n) for _ in range(k)],
                             dtype=np.int32)
            segments.append(Segment(step_inner, k, xs=idx, name="epoch"))
            used += k
    return RoundProgram(init=init, segments=segments,
                        final=lambda c: c["w"])


def dsvrg(dist, rounds: int, L_max: float, lam: float = 0.0,
          epoch_len: int = 0, seed: int = 0, history: bool = False,
          eta: float = 0.0, engine: str = "python"):
    """``L_max``: max per-component smoothness (max_i |a_i|^2 l''max + lam).
    ``rounds`` counts every stochastic step as a round (paper's metric).
    Requires the backend to expose per-sample rows: dist.sample_row(i).
    """
    res = run_program(dist,
                      dsvrg_program(dist, rounds, L_max=L_max, lam=lam,
                                    epoch_len=epoch_len, seed=seed,
                                    eta=eta),
                      engine=engine, history=history)
    return (res.w, {"iterates": res.iterates}) if history else res.w
