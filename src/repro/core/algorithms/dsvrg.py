"""Feature-partitioned SVRG — a member of the paper's incremental family
I^{lam,L} (Definition in Sec. 3.2).

Round structure: each stochastic step touches ONE component phi(w, A_l:)
(Eq. 3's g(w)) and needs the scalar a_l . w — under the feature partition
that is one ReduceAll of a SCALAR per step (machine j contributes
a_l[S_j] . w_j), so a stochastic step is a (cheap) communication round.
Snapshot full gradients cost one R^n ReduceAll.

SVRG round complexity O((n + kappa_max) log(1/eps)) does NOT meet the
Theorem-4 floor Omega((sqrt(n kappa) + n) log(1/eps)); the paper leaves
tightness open. benchmarks/thm4_incremental.py plots both.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp


def dsvrg(dist, rounds: int, L_max: float, lam: float = 0.0,
          epoch_len: int = 0, seed: int = 0, history: bool = False,
          eta: float = 0.0):
    """``L_max``: max per-component smoothness (max_i |a_i|^2 l''max + lam).
    ``rounds`` counts every stochastic step as a round (paper's metric).
    Requires the backend to expose per-sample rows: dist.sample_row(i).
    """
    n = dist.n
    epoch_len = epoch_len or 2 * n
    eta = eta or 1.0 / (10.0 * L_max)
    rng = np.random.RandomState(seed)

    w = dist.zeros_like_w()
    iterates = []
    used = 0
    while used < rounds:
        # --- snapshot: one R^n ReduceAll + local full partial gradient
        z_snap = dist.response(w, tag="svrg.snapshot")
        g_snap = dist.pgrad(w, z_snap)   # includes lam*w term
        w_snap = w
        dist.end_round()
        used += 1
        if history:
            # the snapshot consumes a round: record the (unchanged)
            # iterate so history index k == communication round k
            iterates.append(w)
        # --- inner loop: one scalar-ReduceAll round per stochastic step
        for _ in range(min(epoch_len, rounds - used)):
            i = int(rng.randint(n))
            a_i = dist.sample_row(i)              # local block of row i
            zi = dist.dot_row(a_i, w, tag="svrg.aw")        # scalar reduce
            zi_snap = z_snap[i]
            gi = dist.row_grad(a_i, zi, i) + lam * w
            gi_snap = dist.row_grad(a_i, zi_snap, i) + lam * w_snap
            w = w - eta * (gi - gi_snap + g_snap)
            dist.end_round()
            used += 1
            if history:
                iterates.append(w)
    return (w, {"iterates": iterates}) if history else w
