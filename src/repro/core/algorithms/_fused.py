"""Whole-round fused program rotation for linear first-order rounds.

Every first-order algorithm in the family F^{lam,L} runs the same round:
reduce the response, take the masked partial gradient, apply a
block-local update.  When the dist's oracle backend offers the
whole-round ``round_step`` capability (the ``fused`` backend,
``kernels/fused_round.py``), that round can run as ONE Pallas kernel per
machine — but only after a rotation: the composed step computes this
round's upload *inside* the round, while the fused kernel emits next
round's upload (already channel-transformed) in the same pass that read
A_j.  So the fused program's carry holds ``zloc`` — machine j's pending
upload — and each round is: reduce the carried uploads
(``pretransformed=True``: byte-identical record/pricing/faults, no
second transform), then one kernel.

Round 0's pending upload is A·0 = 0, and every in-kernel channel maps 0
to 0 (int8's scale is 0 -> zeros; the half casts are exact at 0), so the
zeros init reproduces the composed round-0 message bit-for-bit.  The
kernel applies channel stage ``rnd + 1`` to the upload it emits — the
stage the composed path would apply when that upload is actually sent.

The ledger cannot tell the difference by construction (metadata-only
records, identical tags/shapes/pricing); the iterates are bit-identical
to the composed ``kernel`` backend because the kernel's dots see the
same single-tile padded operands and the epilogue/update runs the same
f32 op order (``tests/test_ledger_invariance.py`` pins both).
"""
from __future__ import annotations

from typing import Optional

import numpy as np
import jax.numpy as jnp

from ..engine import RoundProgram, Segment


def fused_linear_program(dist, rounds: int, update,
                         xs: Optional[np.ndarray] = None,
                         name: str = "") -> Optional[RoundProgram]:
    """The fused RoundProgram for a response->pgrad->update round, or
    ``None`` when the dist's backend (or this cell's channel/shape)
    cannot rotate it — callers fall back to their composed program.

    ``update(x, y, g, coeff) -> (x_new, y_new)`` is the algorithm's
    block-local update (elementwise over the coordinate blocks; it is
    traced into the kernel body).  ``xs`` optionally supplies the
    per-round ``coeff`` input (e.g. FISTA momentum coefficients).
    """
    maker = getattr(dist, "fused_round_step", None)
    if maker is None:
        return None      # sharded placement (or a non-protocol dist)
    step_fn = maker(update)
    if step_fn is None:
        return None      # backend or cell does not support the rotation
    zero = dist.zeros_like_w()
    zloc0 = jnp.zeros((dist.part.m, dist.n))
    no_coeff = jnp.float32(0.0)

    def step(dist, carry, x):
        x_c, y_c, zloc = carry
        z = dist.reduce_response(zloc)
        coeff = x if xs is not None else no_coeff
        rnd = dist.comm._round_index()
        x_n, y_n, zloc_n = step_fn(z, x_c, y_c, coeff, rnd)
        dist.end_round()
        return (x_n, y_n, zloc_n), x_n

    return RoundProgram(init=(zero, zero, zloc0),
                        segments=[Segment(step, rounds, xs=xs, name=name)],
                        final=lambda c: c[0])
