"""Feature-partitioned distributed optimization algorithms.

Every algorithm here is a member of the paper's family F^{lam,L} (or
I^{lam,L} for DSVRG): machine j only ever updates its own coordinate
block, all cross-machine traffic is the allowed constant number of
ReduceAll ops per round, and every such op is metered by the CommLedger.

  dgd      — distributed gradient descent           O(kappa log(1/eps))
  dagd     — distributed Nesterov accelerated GD    O(sqrt(kappa) log(1/eps))
             == MATCHES the Theorem-2 lower bound (and Thm 3 when lam=0)
  bcd      — synchronous parallel block coordinate descent [Richtarik-Takac]
  disco_f  — DISCO-F: distributed inexact (damped) Newton via CG [Ma-Takac]
             == matches Thm 2 on quadratics
  dsvrg    — feature-partitioned SVRG (incremental family I^{lam,L})
  prox_dagd— FISTA for composite f + psi with separable psi: the prox is
             BLOCK-LOCAL under the feature partition (zero extra comm)

Each algorithm exists in two forms:

  * ``<name>(dist, rounds, ..., engine="python")`` — the historical
    callable (runs the step functions through the round engine; the
    python engine reproduces the per-call semantics exactly);
  * ``<name>_program(dist, rounds, ...) -> RoundProgram`` — the step
    form the scan engine compiles (``core.engine.run_program``).
"""
from .dgd import dgd, dgd_program
from .prox_dagd import (box_projection, prox_dagd, prox_dagd_program,
                        soft_threshold)
from .dagd import dagd, dagd_program
from .bcd import bcd, bcd_program
from .disco_f import disco_f, disco_f_program
from .dsvrg import dsvrg, dsvrg_program

ALGORITHMS = {
    "dgd": dgd,
    "prox_dagd": prox_dagd,
    "dagd": dagd,
    "bcd": bcd,
    "disco_f": disco_f,
    "dsvrg": dsvrg,
}

PROGRAMS = {
    "dgd": dgd_program,
    "prox_dagd": prox_dagd_program,
    "dagd": dagd_program,
    "bcd": bcd_program,
    "disco_f": disco_f_program,
    "dsvrg": dsvrg_program,
}

__all__ = ["dgd", "dagd", "bcd", "disco_f", "dsvrg",
           "prox_dagd", "soft_threshold", "box_projection",
           "dgd_program", "dagd_program", "bcd_program", "disco_f_program",
           "dsvrg_program", "prox_dagd_program",
           "ALGORITHMS", "PROGRAMS"]
