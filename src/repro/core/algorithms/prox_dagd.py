"""Proximal accelerated gradient (FISTA) under the feature partition.

Composite objectives  f(w) + psi(w)  with coordinate-separable psi (L1,
box constraints, elastic net) fit the paper's communication model for
free: the prox operator acts coordinate-wise, so machine j applies
prox_{psi} to ITS OWN block with zero additional communication — the
round cost stays exactly one R^n ReduceAll, and the Theorem-2/3 lower
bounds (which hold for the smooth part) are still matched order-wise by
this algorithm. This extends the framework beyond the paper's smooth
setting at no communication cost.
"""
from __future__ import annotations

import math
from typing import Callable

import jax.numpy as jnp


def soft_threshold(tau: float):
    """prox of tau*|w|_1 — elementwise, hence block-local."""
    def prox(w, step):
        t = tau * step
        return jnp.sign(w) * jnp.maximum(jnp.abs(w) - t, 0.0)
    return prox


def box_projection(lo: float, hi: float):
    def prox(w, step):
        return jnp.clip(w, lo, hi)
    return prox


def prox_dagd(dist, rounds: int, L: float, prox: Callable,
              lam: float = 0.0, history: bool = False):
    """FISTA (lam=0) / accelerated proximal gradient (lam>0) on
    f(w) + psi(w); ``prox(w_block, step)`` must be coordinate-separable.
    One R^n ReduceAll per round, like DAGD."""
    x = dist.zeros_like_w()
    y = dist.zeros_like_w()
    t = 1.0
    beta_sc = None
    if lam > 0:
        kappa = L / lam
        beta_sc = (math.sqrt(kappa) - 1.0) / (math.sqrt(kappa) + 1.0)
    iterates = []
    for _ in range(rounds):
        z = dist.response(y)
        g = dist.pgrad(y, z)
        x_new = prox(y - (1.0 / L) * g, 1.0 / L)   # block-local prox
        if beta_sc is not None:
            y = x_new + beta_sc * (x_new - x)
        else:
            t_new = 0.5 * (1.0 + math.sqrt(1.0 + 4.0 * t * t))
            y = x_new + ((t - 1.0) / t_new) * (x_new - x)
            t = t_new
        x = x_new
        dist.end_round()
        if history:
            iterates.append(x)
    return (x, {"iterates": iterates}) if history else x
