"""Proximal accelerated gradient (FISTA) under the feature partition.

Composite objectives  f(w) + psi(w)  with coordinate-separable psi (L1,
box constraints, elastic net) fit the paper's communication model for
free: the prox operator acts coordinate-wise, so machine j applies
prox_{psi} to ITS OWN block with zero additional communication — the
round cost stays exactly one R^n ReduceAll, and the Theorem-2/3 lower
bounds (which hold for the smooth part) are still matched order-wise by
this algorithm. This extends the framework beyond the paper's smooth
setting at no communication cost.
"""
from __future__ import annotations

import math
from typing import Callable

import jax.numpy as jnp

from ..engine import RoundProgram, Segment, run_program
from ._fused import fused_linear_program
from .dagd import fista_momentum_schedule


def soft_threshold(tau: float):
    """prox of tau*|w|_1 — elementwise, hence block-local."""
    def prox(w, step):
        t = tau * step
        return jnp.sign(w) * jnp.maximum(jnp.abs(w) - t, 0.0)
    return prox


def box_projection(lo: float, hi: float):
    def prox(w, step):
        return jnp.clip(w, lo, hi)
    return prox


def prox_dagd_program(dist, rounds: int, L: float, prox: Callable,
                      lam: float = 0.0) -> RoundProgram:
    # The gradient-step scalar is f32-wrapped for const hoisting (see
    # dagd.py); the prox keeps receiving the python-float step size so a
    # prox that pre-multiplies it (soft_threshold's tau * step) rounds
    # exactly once, as it always has.
    inv_L = 1.0 / L
    step_L = jnp.float32(inv_L)
    zero = dist.zeros_like_w()

    if lam > 0:
        kappa = L / lam
        beta = jnp.float32((math.sqrt(kappa) - 1.0)
                           / (math.sqrt(kappa) + 1.0))

        def update(x, y, g, coeff):
            x_new = prox(y - step_L * g, inv_L)  # block-local prox
            y_new = x_new + beta * (x_new - x)
            return x_new, y_new

        fused = fused_linear_program(dist, rounds, update, name="apg")
        if fused is not None:
            return fused

        def step(dist, carry, _):
            x, y = carry
            z = dist.response(y)
            g = dist.pgrad(y, z)
            x_new = prox(y - step_L * g, inv_L)  # block-local prox
            y_new = x_new + beta * (x_new - x)
            dist.end_round()
            return (x_new, y_new), x_new

        return RoundProgram(init=(zero, zero),
                            segments=[Segment(step, rounds, name="apg")],
                            final=lambda c: c[0])

    def update(x, y, g, coeff):
        x_new = prox(y - step_L * g, inv_L)      # block-local prox
        y_new = x_new + coeff * (x_new - x)
        return x_new, y_new

    fused = fused_linear_program(dist, rounds, update,
                                 xs=fista_momentum_schedule(rounds),
                                 name="fista")
    if fused is not None:
        return fused

    def step(dist, carry, coeff):
        x, y = carry
        z = dist.response(y)
        g = dist.pgrad(y, z)
        x_new = prox(y - step_L * g, inv_L)      # block-local prox
        y_new = x_new + coeff * (x_new - x)
        dist.end_round()
        return (x_new, y_new), x_new

    return RoundProgram(
        init=(zero, zero),
        segments=[Segment(step, rounds, xs=fista_momentum_schedule(rounds),
                          name="fista")],
        final=lambda c: c[0])


def prox_dagd(dist, rounds: int, L: float, prox: Callable,
              lam: float = 0.0, history: bool = False,
              engine: str = "python"):
    """FISTA (lam=0) / accelerated proximal gradient (lam>0) on
    f(w) + psi(w); ``prox(w_block, step)`` must be coordinate-separable.
    One R^n ReduceAll per round, like DAGD."""
    res = run_program(dist,
                      prox_dagd_program(dist, rounds, L=L, prox=prox,
                                        lam=lam),
                      engine=engine, history=history)
    return (res.w, {"iterates": res.iterates}) if history else res.w
