"""Synchronous parallel block coordinate descent (Richtarik-Takac style).

The classic partition-on-feature algorithm [14, 11 in the paper]: every
machine takes a gradient step on ITS OWN block with a block-wise step
size, all blocks updated simultaneously. The expected-separable-
overapproximation (ESO) safe factor ``beta`` (default m) guarantees
monotone descent for dense couplings; sparser data admits smaller beta.

Communication: one R^n ReduceAll per round (for z), like DGD. Its rate is
NOT accelerated — included as the practitioner's baseline the paper's
bound separates from.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax.numpy as jnp


def bcd(dist, rounds: int, block_L, beta: Optional[float] = None,
        m: Optional[int] = None, history: bool = False):
    """``block_L``: per-block Lipschitz bounds L_j, broadcastable against w
    (stacked (m, 1) in local mode, scalar per shard in sharded mode)."""
    if beta is None:
        if m is None:
            raise ValueError("need beta or m for the ESO factor")
        beta = float(m)
    w = dist.zeros_like_w()
    step = 1.0 / (beta * jnp.asarray(block_L))
    iterates = []
    for _ in range(rounds):
        z = dist.response(w)
        g = dist.pgrad(w, z)
        w = w - step * g
        dist.end_round()
        if history:
            iterates.append(w)
    return (w, {"iterates": iterates}) if history else w
