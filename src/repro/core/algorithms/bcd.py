"""Synchronous parallel block coordinate descent (Richtarik-Takac style).

The classic partition-on-feature algorithm [14, 11 in the paper]: every
machine takes a gradient step on ITS OWN block with a block-wise step
size, all blocks updated simultaneously. The expected-separable-
overapproximation (ESO) safe factor ``beta`` (default m) guarantees
monotone descent for dense couplings; sparser data admits smaller beta.

Communication: one R^n ReduceAll per round (for z), like DGD. Its rate is
NOT accelerated — included as the practitioner's baseline the paper's
bound separates from.
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from ..engine import RoundProgram, Segment, run_program


def bcd_program(dist, rounds: int, block_L, beta: Optional[float] = None,
                m: Optional[int] = None) -> RoundProgram:
    """``block_L``: per-block Lipschitz bounds L_j, broadcastable against w
    (stacked (m, 1) in local mode, scalar per shard in sharded mode)."""
    if beta is None:
        if m is None:
            raise ValueError("need beta or m for the ESO factor")
        beta = float(m)
    step_size = 1.0 / (beta * jnp.asarray(block_L))

    def step(dist, w, _):
        z = dist.response(w)
        g = dist.pgrad(w, z)
        w_new = w - step_size * g
        dist.end_round()
        return w_new, w_new

    return RoundProgram(init=dist.zeros_like_w(),
                        segments=[Segment(step, rounds, name="bcd")],
                        final=lambda w: w)


def bcd(dist, rounds: int, block_L, beta: Optional[float] = None,
        m: Optional[int] = None, history: bool = False,
        engine: str = "python"):
    res = run_program(dist,
                      bcd_program(dist, rounds, block_L=block_L, beta=beta,
                                  m=m),
                      engine=engine, history=history)
    return (res.w, {"iterates": res.iterates}) if history else res.w
