"""Distributed accelerated gradient descent (Nesterov) — the matching
upper bound for Theorems 2 and 3.

Each round does exactly ONE ReduceAll of an R^n vector (z = A y); the
momentum extrapolation is block-local. Hence the algorithm sits inside
F^{lam,L} with the minimal possible communication, and its round count

   strongly convex : O( sqrt(kappa) log(1/eps) )   [Nesterov 2.2.22]
   smooth convex   : O( sqrt(L/eps) |w*| )         [Nesterov 2.2.19]

matches the paper's lower bounds — the tightness witnesses.

Expressed in step form (``dagd_program``) for the round engine: the FISTA
``t_k`` recursion is data-independent, so the smooth-case momentum
coefficients are precomputed per round in float64 and fed to the step as
the scanned ``xs`` — both engines then run bit-identical f32 arithmetic.
"""
from __future__ import annotations

import math

import numpy as np
import jax.numpy as jnp

from ..engine import RoundProgram, Segment, run_program
from ._fused import fused_linear_program


def fista_momentum_schedule(rounds: int) -> np.ndarray:
    """The (t_k - 1)/t_{k+1} coefficient sequence, rounded to f32 exactly
    as the historical Python loop's weak-typed scalars were."""
    t, coeffs = 1.0, []
    for _ in range(rounds):
        t_new = 0.5 * (1.0 + math.sqrt(1.0 + 4.0 * t * t))
        coeffs.append((t - 1.0) / t_new)
        t = t_new
    return np.asarray(coeffs, dtype=np.float32)


def dagd_program(dist, rounds: int, L: float, lam: float = 0.0
                 ) -> RoundProgram:
    # Scalar hypers are computed in f64 exactly as before, then wrapped as
    # f32 arrays: the step arithmetic sees the same f32 values the
    # weak-typed python floats produced, but the scalars become hoistable
    # jaxpr consts, so repro.api.execute_batch can group cells that differ
    # only in their hyper-parameters (a python-float literal would bake a
    # per-cell constant into the traced program).
    inv_L = jnp.float32(1.0 / L)
    zero = dist.zeros_like_w()

    if lam > 0:
        kappa = L / lam
        beta = jnp.float32((math.sqrt(kappa) - 1.0)
                           / (math.sqrt(kappa) + 1.0))

        def update(x, y, g, coeff):
            x_new = y - inv_L * g
            y_new = x_new + beta * (x_new - x)
            return x_new, y_new

        fused = fused_linear_program(dist, rounds, update, name="agd")
        if fused is not None:
            return fused

        def step(dist, carry, _):
            x, y = carry
            z = dist.response(y)
            g = dist.pgrad(y, z)
            x_new = y - inv_L * g
            y_new = x_new + beta * (x_new - x)
            dist.end_round()
            return (x_new, y_new), x_new

        return RoundProgram(init=(zero, zero),
                            segments=[Segment(step, rounds, name="agd")],
                            final=lambda c: c[0])

    def update(x, y, g, coeff):
        x_new = y - inv_L * g
        y_new = x_new + coeff * (x_new - x)
        return x_new, y_new

    fused = fused_linear_program(dist, rounds, update,
                                 xs=fista_momentum_schedule(rounds),
                                 name="fista")
    if fused is not None:
        return fused

    def step(dist, carry, coeff):
        x, y = carry
        z = dist.response(y)
        g = dist.pgrad(y, z)
        x_new = y - inv_L * g
        y_new = x_new + coeff * (x_new - x)
        dist.end_round()
        return (x_new, y_new), x_new

    return RoundProgram(
        init=(zero, zero),
        segments=[Segment(step, rounds, xs=fista_momentum_schedule(rounds),
                          name="fista")],
        final=lambda c: c[0])


def dagd(dist, rounds: int, L: float, lam: float = 0.0,
         history: bool = False, engine: str = "python"):
    res = run_program(dist, dagd_program(dist, rounds, L=L, lam=lam),
                      engine=engine, history=history)
    return (res.w, {"iterates": res.iterates}) if history else res.w
