"""Distributed accelerated gradient descent (Nesterov) — the matching
upper bound for Theorems 2 and 3.

Each round does exactly ONE ReduceAll of an R^n vector (z = A y); the
momentum extrapolation is block-local. Hence the algorithm sits inside
F^{lam,L} with the minimal possible communication, and its round count

   strongly convex : O( sqrt(kappa) log(1/eps) )   [Nesterov 2.2.22]
   smooth convex   : O( sqrt(L/eps) |w*| )         [Nesterov 2.2.19]

matches the paper's lower bounds — the tightness witnesses.
"""
from __future__ import annotations

import math


def dagd(dist, rounds: int, L: float, lam: float = 0.0,
         history: bool = False):
    if lam > 0:
        return _dagd_strongly_convex(dist, rounds, L, lam, history)
    return _dagd_smooth(dist, rounds, L, history)


def _dagd_strongly_convex(dist, rounds, L, lam, history):
    kappa = L / lam
    beta = (math.sqrt(kappa) - 1.0) / (math.sqrt(kappa) + 1.0)
    x = dist.zeros_like_w()
    y = dist.zeros_like_w()
    iterates = []
    for _ in range(rounds):
        z = dist.response(y)
        g = dist.pgrad(y, z)
        x_new = y - (1.0 / L) * g
        y = x_new + beta * (x_new - x)
        x = x_new
        dist.end_round()
        if history:
            iterates.append(x)
    return (x, {"iterates": iterates}) if history else x


def _dagd_smooth(dist, rounds, L, history):
    x = dist.zeros_like_w()
    y = dist.zeros_like_w()
    t = 1.0
    iterates = []
    for _ in range(rounds):
        z = dist.response(y)
        g = dist.pgrad(y, z)
        x_new = y - (1.0 / L) * g
        t_new = 0.5 * (1.0 + math.sqrt(1.0 + 4.0 * t * t))
        y = x_new + ((t - 1.0) / t_new) * (x_new - x)
        x, t = x_new, t_new
        dist.end_round()
        if history:
            iterates.append(x)
    return (x, {"iterates": iterates}) if history else x
