"""Scan-compiled round engine: one XLA program per algorithm run.

The paper's object of study is communication *rounds* — thousands of them
per certification cell — and every algorithm in ``core.algorithms`` is a
fixed per-round recurrence.  Executing those recurrences as Python loops
costs one dispatch per op per round; compiling the whole multi-round run
into a single ``jax.lax.scan`` program is the standard JAX idiom for this
workload shape and removes both the dispatch overhead and the per-round
history materialization.

Algorithms are expressed as **round programs**:

  * a ``step(dist, carry, x) -> (carry, w_k)`` function — exactly one
    communication round: metered oracle calls, a block-local update, one
    ``dist.end_round()``, and the iterate ``w_k`` to measure this round;
  * an initial carry (a pytree of arrays, momentum scalars included);
  * ``Segment``s — a run is a sequence of (step, count[, xs]) segments so
    algorithms with non-uniform round structure (DISCO-F's Newton round
    followed by CG rounds, DSVRG's snapshot + stochastic epochs) stay
    expressible; per-round data-independent inputs (momentum coefficient
    schedules, pre-drawn sample indices) ride along as ``xs``.

Two engines execute a program:

  * ``"python"`` — one ``step`` call per round, eager dispatch.  This is
    the debugging / parity reference: it produces exactly the per-call
    oracle stream (and therefore exactly the ``CommLedger`` records) of
    the historical per-algorithm Python loops.
  * ``"scan"``  — each segment's step is traced ONCE, wrapped in
    ``lax.scan`` over the round count, and jitted, so an entire run is a
    handful of XLA programs regardless of the round budget.

**Trace-once ledger schedule.**  The ``CommLedger`` meters the paper's
communication model, and certifications must be bit-invariant to the
execution engine.  The scan engine therefore captures each step's op
stream once (an abstract ``jax.eval_shape`` trace against a scratch
ledger), silences the ledger during the compiled run, and replays the
captured schedule ``count`` times into the real ledger.  Because the
python engine runs the *same* step functions, the replayed stream is
bit-identical to the per-call stream — ``tests/test_ledger_invariance``
pins this.

**In-scan gap measurement.**  Passing ``measure`` (any traceable
``w_k -> scalar``, e.g. ``f(w_k) - f*``) folds suboptimality measurement
into the scan as a per-round scalar output: a run returns a ``(K,)``
gap series instead of a ``(K, m, d_max)`` iterate history.  ``measure``
must not call metered oracles — it is measurement, not communication
(the scan engine would bake its ops into the replayed schedule and the
python engine would meter them; either corrupts the certification).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, Optional

import numpy as np
import jax
import jax.numpy as jnp
from jax import lax

from .comm import CommLedger, inject_crash_recovery
from .faults import FaultRecoveryError


# Canonical list lives in repro.api._resolve (the single resolver);
# mirrored here because this module cannot import repro.api at load time
# (repro.api.plan imports modules that import this one). tests/test_api.py
# pins equality.
ENGINES = ("python", "scan")


def resolve_engine(engine: Optional[str] = None) -> str:
    """Resolve an engine choice to ``"python"`` or ``"scan"``.

    Delegates to the single capability resolver in ``repro.api`` (env
    var consulted at call time; ``scan`` is the production default on
    every platform, the python engine exists for debugging and parity).
    Planned runs (``repro.api.plan``) arrive at ``run_program`` with the
    choice already concrete.
    """
    # call-time import: loading repro.api at module-load time would cycle
    # (api.plan imports modules that import this one). Note this pulls
    # the whole facade package on first use, not just the leaf _resolve
    # module — safe, because by call time the chain is importable.
    from ..api import _resolve
    return _resolve.resolve_engine(engine)


@dataclasses.dataclass
class Segment:
    """``count`` identical rounds driven by one step function.

    ``step(dist, carry, x) -> (carry, w_k)`` must perform exactly one
    communication round (ending with ``dist.end_round()``) and must keep
    the carry pytree structure/shapes fixed across the segment.  ``xs``
    optionally supplies a per-round input of leading dimension ``count``
    (momentum coefficients, sample indices); when absent the step
    receives the round index within the segment.
    """

    step: Callable
    count: int
    xs: Optional[np.ndarray] = None
    name: str = ""

    def __post_init__(self):
        if self.count < 1:
            raise ValueError(f"segment {self.name!r}: count must be >= 1")
        if self.xs is not None and len(self.xs) != self.count:
            raise ValueError(
                f"segment {self.name!r}: xs leading dim "
                f"{len(self.xs)} != count {self.count}")


@dataclasses.dataclass
class RoundProgram:
    """An algorithm run: initial carry, round segments, final extractor."""

    init: Any                        # carry pytree
    segments: List[Segment]
    final: Callable                  # carry -> final iterate w

    @property
    def rounds(self) -> int:
        return sum(seg.count for seg in self.segments)


@dataclasses.dataclass
class EngineResult:
    w: Any                           # final iterate (stacked blocks / local)
    rounds: int
    gaps: Optional[np.ndarray] = None      # (K,) when measure was given
    iterates: Optional[list] = None        # per-round iterates (history)


class EngineSession:
    """Reusable jit + schedule caches for repeated runs of the same
    program against the same ``dist`` (e.g. benchmark repeats).  Keyed by
    step-function identity, so program builders must construct each
    distinct step once and share it across segments."""

    def __init__(self):
        self.runners = {}
        self.schedules = {}


def run_program(dist, program: RoundProgram, *, engine: Optional[str] = None,
                measure: Optional[Callable] = None, history: bool = False,
                session: Optional[EngineSession] = None) -> EngineResult:
    """Execute a round program against a ``DistERM`` backend.

    ``measure``: traceable ``w_k -> scalar`` folded into the run as a
    per-round output (the ``(K,)`` gap series).  ``history``: collect the
    raw per-round iterates instead (debugging / parity; materializes
    ``(K, m, d_max)``).  The two are mutually exclusive.
    """
    if measure is not None and history:
        raise ValueError("measure and history are mutually exclusive")
    engine = resolve_engine(engine)
    if engine == "python":
        return _run_python(dist, program, measure, history)
    return _run_scan(dist, program, measure, history,
                     session if session is not None else EngineSession())


# --------------------------------------------------------------------------
# python engine — the per-call reference
# --------------------------------------------------------------------------

def _engine_faults(dist):
    """The communicator's active fault schedule, if any."""
    f = getattr(getattr(dist, "comm", None), "faults", None)
    return f if f is not None and f.active else None


def _run_python(dist, program, measure, history) -> EngineResult:
    faults = _engine_faults(dist)
    crash_at = None
    snap = flat = None
    if faults is not None and faults.crash_round is not None \
            and faults.crash_round <= program.rounds:
        # live crash-restart: snapshot the carry on the declared cadence
        # through the real checkpoint store, so recovery replays the real
        # save/restore path (not an in-memory copy).
        from ..checkpoint import RoundSnapshotter
        crash_at = faults.crash_round
        snap = RoundSnapshotter()
        snap.save(0, program.init)
        flat = [(seg, k) for seg in program.segments
                for k in range(seg.count)]
    carry = program.init
    gaps, iterates, rounds = [], [], 0
    try:
        for seg in program.segments:
            for k in range(seg.count):
                x = seg.xs[k] if seg.xs is not None else k
                carry, w = seg.step(dist, carry, x)
                rounds += 1
                if crash_at is not None:
                    if rounds < crash_at \
                            and rounds % faults.snapshot_every == 0:
                        snap.save(rounds, carry)
                    elif rounds == crash_at:
                        carry = _recover_crash(dist, flat, faults, snap,
                                               carry)
                        crash_at = None
                if measure is not None:
                    gaps.append(measure(w))
                elif history:
                    iterates.append(w)
    finally:
        if snap is not None:
            snap.close()
    return EngineResult(
        w=program.final(carry), rounds=rounds,
        gaps=np.asarray(jnp.stack(gaps)) if measure is not None else None,
        iterates=iterates if history else None)


def _recover_crash(dist, flat, faults, snap, lost_carry):
    """Crash-restart after algorithm round ``k``: restore the round-``s``
    snapshot and re-execute rounds ``s+1..k`` for real, metered as
    recovery traffic (``mark_retransmit``: every record retransmit=True,
    no fresh fault draws, recovery rounds).  The channel round index is
    pinned to the round being re-executed so scheduled-channel pricing
    matches the original.  Self-healing is then *proved*: the recomputed
    carry must be bit-identical to the state the crash lost."""
    s, k = faults.crash_span(len(flat))
    carry = snap.restore(s, like=lost_carry)
    comm, led = dist.comm, dist.comm.ledger
    led.mark_retransmit = True
    try:
        for r in range(s, k):          # 0-based rounds s..k-1 == algo s+1..k
            seg, j = flat[r]
            comm.begin_round(r)
            x = seg.xs[j] if seg.xs is not None else j
            carry, _ = seg.step(dist, carry, x)
    finally:
        led.mark_retransmit = False
        comm.reset_round()
    for a, b in zip(jax.tree_util.tree_leaves(lost_carry),
                    jax.tree_util.tree_leaves(carry)):
        if not np.array_equal(np.asarray(a), np.asarray(b)):
            raise FaultRecoveryError(
                f"crash recovery diverged: replay of rounds {s + 1}..{k} "
                f"did not reproduce the pre-crash state")
    return carry


# --------------------------------------------------------------------------
# scan engine — trace once, run compiled
# --------------------------------------------------------------------------

def _segment_xs(seg: Segment) -> np.ndarray:
    if seg.xs is not None:
        return np.asarray(seg.xs)
    return np.arange(seg.count, dtype=np.int32)


def _capture_schedule(dist, seg: Segment, carry, xs: np.ndarray):
    """One abstract trace of the step against a scratch ledger: the
    per-round op schedule (records + rounds + round-boundary marks) this
    segment will replay."""
    real = dist.comm.ledger
    scratch = CommLedger()
    dist.comm.ledger = scratch
    dist.comm._tracing = True   # captured schedules stay fault-free;
    try:                        # the ledger replay injects the faults
        x_abs = jax.ShapeDtypeStruct(xs.shape[1:], xs.dtype)
        jax.eval_shape(lambda c, x: seg.step(dist, c, x), carry, x_abs)
    finally:
        dist.comm.ledger = real
        dist.comm._tracing = False
    return list(scratch.records), scratch.rounds, list(scratch.round_marks)


def scheduled_channel(dist):
    """The communicator's channel iff it is round-scheduled (the case
    where the scan engines must thread the round index), else None."""
    chan = getattr(getattr(dist, "comm", None), "channel", None)
    return chan if getattr(chan, "scheduled", False) else None


def _build_runner(dist, step: Callable, measure, history, scheduled: bool):
    collect_w = history and measure is None

    def body(carry, x):
        if scheduled:
            # xs carry (global round index, per-round input): pin the
            # index so the channel transform switches stages mid-scan.
            rk, x = x
            dist.comm.begin_round(rk)
        carry, w = step(dist, carry, x)
        if measure is not None:
            return carry, measure(w)
        return carry, (w if collect_w else None)

    return jax.jit(lambda carry, xs: lax.scan(body, carry, xs))


def _run_scan(dist, program, measure, history,
              session: EngineSession) -> EngineResult:
    ledger = dist.comm.ledger
    chan = scheduled_channel(dist)
    faults = _engine_faults(dist)
    carry = program.init
    outs, rounds = [], 0
    for seg in program.segments:
        xs = _segment_xs(seg)
        sched_key = (seg.step, xs.dtype.str, xs.shape[1:])
        if sched_key not in session.schedules:
            session.schedules[sched_key] = _capture_schedule(
                dist, seg, carry, xs)
        records, rounds_per_step, marks = session.schedules[sched_key]
        run_key = (seg.step, measure, history, chan is not None)
        runner = session.runners.get(run_key)
        if runner is None:
            runner = _build_runner(dist, seg.step, measure, history,
                                   chan is not None)
            session.runners[run_key] = runner
        xs_arg = jnp.asarray(xs)
        if chan is not None:
            # Global round index per scan step, precomputed as scanned
            # xs (the schedule is a pure function of the round index, so
            # this is data-independent): ledger.algo_rounds is exact
            # here — every prior segment has already been replayed, and
            # recovery rounds never shift the channel schedule.
            rid = ledger.algo_rounds + np.arange(
                seg.count, dtype=np.int32) * rounds_per_step
            xs_arg = (jnp.asarray(rid), xs_arg)
        # The compiled run records nothing: any trace-time metering goes
        # to a throwaway ledger (jit may or may not retrace — either way
        # the schedule replay below is the single source of truth).
        dist.comm.ledger = CommLedger()
        try:
            carry, out = runner(carry, xs_arg)
        finally:
            dist.comm.ledger = ledger
            if chan is not None:
                dist.comm.reset_round()
        if measure is not None or history:
            outs.append(out)
        ledger.replay_schedule(records, rounds_per_step, marks, seg.count,
                               channel=chan, faults=faults)
        rounds += seg.count
    if faults is not None:
        # splice the crash-replay traffic exactly where the live python
        # engine records it (drops/flips/stragglers were injected by the
        # replay above; values need no recovery — replay is metering, and
        # the fault model's recovery is value-transparent).
        inject_crash_recovery(ledger, faults)
    gaps = iterates = None
    if measure is not None:
        gaps = np.asarray(jnp.concatenate(outs)) if outs else np.zeros((0,))
    elif history:
        stacked = jnp.concatenate(outs, axis=0)
        iterates = [stacked[k] for k in range(stacked.shape[0])]
    return EngineResult(w=program.final(carry), rounds=rounds,
                        gaps=gaps, iterates=iterates)
