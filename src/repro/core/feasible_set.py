"""Feasible-set span-oracle simulator — certifies Lemma 5 / Corollary 6.

The paper's Definition 1 grows, per machine j and round k, a feasible set
W_j^(k) by closing under

    w_j in span{ u_j,  f'_j(u),  (f''_jj(u) + D) v_j,  f''_ji(u) v_i }

with u_j, v_j from the machine's CURRENT round set and u_i, v_i (i != j)
from OTHER machines' PREVIOUS round sets.  Lemma 5 says: on the chain hard
instance, if the union feasible set lives in E_{K,d} (first K coordinates)
then after one more round it lives in E_{K+1,d} — information moves at most
one coordinate per round no matter what the machines do.

This module makes that *checkable*: it tracks an explicit orthonormal basis
of each W_j^(k) and applies the span rules exhaustively for quadratic f
(where f'_j and f''_ji are affine/linear, so the reachable set IS a
subspace and a basis evolution is exact — the paper's hard functions are
quadratics).  Tests then assert:

  * support(W^(K)) ⊆ {1..K}   (Corollary 6)
  * the best point in W^(K) obeys the error floor of Theorem 2
  * greedy algorithms (GD/AGD/CD steps) never escape the certified subspace

Like the paper's proof, we use its WLOG normalization ("each machine only
adds ONE vector per round; the bound does not change asymptotically"):
one span-closure application per round, with u_j/v_j drawn from the frozen
previous-round sets. A constant number c of within-round additions only
rescales the round count by c.

For quadratic f(w) = 1/2 w^T H w - b^T w with H = c*A + lam*I:
    f'_j(u)      = H[S_j, :] u - b[S_j]                 (affine in u)
    f''_jj(u)    = H[S_j, S_j]                           (constant)
    f''_ji(u)v_i = H[S_j, S_i] v_i                       (linear in v_i)
The affine offset -b[S_j] means the span contains H[S_j,:]u and b[S_j]
directions once any u is present (u=0 is always in W_j^(0)={0}).
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from .partition import FeaturePartition


def _orth_basis(vectors: np.ndarray, tol: float = 1e-10) -> np.ndarray:
    """Orthonormal basis (columns) of the span of the given column stack."""
    if vectors.size == 0:
        return np.zeros((vectors.shape[0], 0))
    # SVD, not plain QR: Householder QR without pivoting gives unreliable
    # R-diagonals on rank-deficient stacks (interior zero pivots), which
    # silently truncated the span.
    u, s, _ = np.linalg.svd(vectors, full_matrices=False)
    keep = s > tol * max(1.0, s.max() if s.size else 1.0)
    return u[:, keep]


@dataclasses.dataclass
class SpanOracle:
    """Tracks per-machine feasible subspaces for a quadratic objective."""

    H: np.ndarray            # (d, d) Hessian
    b: np.ndarray            # (d,) linear term;  f'(w) = Hw - b
    part: FeaturePartition

    def __post_init__(self):
        d = self.part.d
        assert self.H.shape == (d, d) and self.b.shape == (d,)
        # basis[j]: (d_j, r_j) columns spanning W_j
        self.basis: List[np.ndarray] = [
            np.zeros((dj, 0)) for dj in self.part.block_sizes]
        self.round = 0

    # ---- helpers ---------------------------------------------------------
    def _block(self, j: int) -> slice:
        off = self.part.offsets[j]
        return slice(off, off + self.part.block_sizes[j])

    def union_support(self, tol: float = 1e-9) -> np.ndarray:
        """Sorted global coordinate indices on which ANY feasible vector can
        be nonzero."""
        sup = []
        for j in range(self.part.m):
            off = self.part.offsets[j]
            Bj = self.basis[j]
            if Bj.shape[1] == 0:
                continue
            rows = np.where(np.abs(Bj).max(axis=1) > tol)[0]
            sup.extend((rows + off).tolist())
        return np.array(sorted(set(sup)), dtype=int)

    def step(self):
        """Apply one round of the Definition-1 span closure (exhaustively,
        for the quadratic case)."""
        m = self.part.m
        prev = [B.copy() for B in self.basis]   # W^(k-1), frozen for i != j
        new_basis: List[np.ndarray] = []
        for j in range(m):
            sj = self._block(j)
            dj = self.part.block_sizes[j]
            cand = [prev[j]] if prev[j].shape[1] else []
            # u ranges over W_j^(k) x prod_{i!=j} W_i^(k-1); by linearity it
            # suffices to push each basis vector through separately, plus the
            # affine offset -b[S_j] (from u = 0, always feasible).
            cand.append(self.b[sj].reshape(dj, 1))
            # f'_j(u) and f''_jj u_j: H[S_j, S_i] @ basis_i for all i
            for i in range(m):
                src = prev[i]
                if src.shape[1] == 0:
                    continue
                si = self._block(i)
                blk = self.H[sj, si] @ src          # (d_j, r_i)
                cand.append(blk)
            # (f''_jj + D) v_j with D any diagonal: D v_j can hit any
            # coordinate-rescaling of v_j -> adds diag-closure of W_j.
            # For the chain instance W_j is coordinate-aligned so this is
            # already contained; we include elementwise products with basis
            # supports to stay exhaustive.
            if prev[j].shape[1]:
                sup = (np.abs(prev[j]).max(axis=1) > 1e-12).astype(float)
                cand.append(np.diag(sup) @ prev[j])
            stacked = np.concatenate([c for c in cand if c.shape[1] > 0],
                                     axis=1) if cand else np.zeros((dj, 0))
            new_basis.append(_orth_basis(stacked))
        self.basis = new_basis
        self.round += 1

    # ---- certification ---------------------------------------------------
    def certify_corollary6(self, rounds: int) -> bool:
        """Run ``rounds`` rounds; return True iff support(W^(K)) ⊆ [K] for
        every K along the way (the paper's E_{K,d} confinement)."""
        for k in range(1, rounds + 1):
            self.step()
            sup = self.union_support()
            if sup.size and sup.max() >= k:   # 0-based: coords 0..k-1 allowed
                return False
        return True

    def best_point(self, w_star: np.ndarray) -> np.ndarray:
        """Projection of w* onto the current feasible product-subspace —
        the best any algorithm in the family could output this round."""
        out = np.zeros_like(w_star)
        for j in range(self.part.m):
            sj = self._block(j)
            Bj = self.basis[j]
            if Bj.shape[1]:
                out[sj] = Bj @ (Bj.T @ w_star[sj])
        return out
