from .store import (save_checkpoint, restore_checkpoint, latest_step,
                    RoundSnapshotter)

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step",
           "RoundSnapshotter"]
