"""Checkpointing: flattened-pytree npz store with tree-structure manifest.

Sharding-aware in the sense that arrays are pulled to host per-leaf
(jax.device_get) and restored leaves are placed back through the caller's
shardings if provided. Single-file npz is appropriate for the example
scale; a production deployment would swap in tensorstore/OCDBT behind the
same three-function interface.
"""
from __future__ import annotations

import os
import re
import shutil
import tempfile
from typing import Any, Optional

import numpy as np
import jax


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_checkpoint(ckpt_dir: str, step: int, tree: Any) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    leaves, treedef = _flatten(tree)
    path = os.path.join(ckpt_dir, f"ckpt_{step:08d}.npz")
    arrays = {}
    for i, l in enumerate(leaves):
        a = np.asarray(jax.device_get(l))
        if a.dtype.name in ("bfloat16", "float16"):
            # numpy's npz has no bf16: store losslessly widened
            a = a.astype(np.float32)
        arrays[f"leaf_{i}"] = a
    np.savez(path, **arrays)
    with open(path + ".treedef", "w") as f:
        f.write(str(treedef))
    return path


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for fn in os.listdir(ckpt_dir)
             if (m := re.match(r"ckpt_(\d+)\.npz$", fn))]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, step: int, like: Any,
                       shardings: Any = None) -> Any:
    """Restore into the structure of ``like`` (treedef source of truth)."""
    path = os.path.join(ckpt_dir, f"ckpt_{step:08d}.npz")
    data = np.load(path)
    leaves, treedef = _flatten(like)
    import jax.numpy as jnp
    new_leaves = [jnp.asarray(data[f"leaf_{i}"]).astype(
        jnp.asarray(l).dtype) for i, l in enumerate(leaves)]
    tree = jax.tree_util.tree_unflatten(treedef, new_leaves)
    if shardings is not None:
        tree = jax.tree_util.tree_map(
            lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree


class RoundSnapshotter:
    """Round-level carry snapshots for crash recovery.

    The fault model (``core.faults``) crashes the center after round ``k``
    and replays from the last snapshot; the python round engine routes
    those snapshots through this store so recovery exercises the real
    save/restore path (f32 npz round-trips are bit-exact, which is what
    makes recovered state provably identical to the lost state).  Owns a
    temporary directory unless given one; ``close()`` removes an owned
    directory.
    """

    def __init__(self, ckpt_dir: Optional[str] = None):
        self._owned = ckpt_dir is None
        self.dir = ckpt_dir if ckpt_dir is not None else tempfile.mkdtemp(
            prefix="repro-snap-")

    def save(self, rnd: int, tree: Any) -> str:
        return save_checkpoint(self.dir, rnd, tree)

    def restore(self, rnd: int, like: Any) -> Any:
        return restore_checkpoint(self.dir, rnd, like)

    def close(self):
        if self._owned:
            shutil.rmtree(self.dir, ignore_errors=True)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
