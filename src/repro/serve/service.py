"""The certification service: queue -> coalesce -> compiled cache -> stream.

``CertificationService`` wires the admission queue, the continuous-
batching scheduler, and the compiled-program cache around the reusable
``repro.api`` batch machinery:

    submit(payload)  -> ticket        (validate, plan, trace the cell)
    step(now)        -> [envelope]    (execute every batch due at `now`)
    drain(now)       -> [envelope]    (flush everything still pending)

Grouped batches run through ``repro.api.execute_group`` with this
service's per-group-key runner cache, so the trace + XLA compile is paid
once per (group structure, batch width) and every later batch of that
shape is a cache hit.  Unbatchable plans (python engine, sharded
placement) execute on the sequential ``ExecutionPlan.execute`` path —
the service never changes what a spec computes, only when and with whom
it is compiled (the soak test and ``benchmarks/serve_throughput.py``
gate verdict + typed-ledger identity against direct execution).

Results stream back as ``ResultEnvelope``s — verdict per eps threshold
plus the ledger summary (rounds, payload bytes, wire bits).  Within a
client the stream preserves submission order: a client's spec that lands
in a slow group never overtakes its earlier submissions (per-client
reorder buffer, released by sequence number).

The service never reads a wall clock; every method takes ``now``.  Real
deployments pass ``time.monotonic()``, tests and benchmarks pass a
synthetic trace — the scheduling decisions are identical either way.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from .. import api
from .cache import ProgramCache
from .queue import PendingRun, SubmissionQueue
from .scheduler import Batch, CoalescingScheduler


@dataclasses.dataclass
class ResultEnvelope:
    """One served verdict.  ``result`` is the full in-process RunResult
    (tests and benchmarks compare its ledger/iterate against direct
    execution); ``to_dict()`` is the wire shape — summaries only."""

    ticket: str
    client_id: str
    seq: int
    spec: api.RunSpec
    batched: bool                     # ran in a coalesced group
    cache_hit: bool                   # compile-free (key + width seen)
    width: int                        # batch width it executed at
    arrival: float
    completed: float
    verdicts: List[dict]              # per eps: measured/bound/certified
    result: api.RunResult

    @property
    def latency(self) -> float:
        return self.completed - self.arrival

    def to_dict(self) -> dict:
        led = self.result.ledger
        return dict(
            status="ok", ticket=self.ticket, client_id=self.client_id,
            seq=self.seq, spec=self.spec.to_dict(), batched=self.batched,
            cache_hit=self.cache_hit, width=self.width,
            latency=round(self.latency, 6), verdicts=self.verdicts,
            budget_ok=self.result.budget_ok,
            ledger=dict(rounds=led.rounds,
                        total_bytes=led.total_bytes(),
                        total_bits=led.total_bits(),
                        bits_per_round=round(led.bits_per_round(), 2),
                        op_counts=led.op_counts()))


class CertificationService:
    def __init__(self, max_batch: int = 8, max_wait: float = 0.05,
                 cache_capacity: int = 32, max_depth: int = 1024):
        self.queue = SubmissionQueue(max_depth=max_depth)
        self.scheduler = CoalescingScheduler(max_batch=max_batch,
                                             max_wait=max_wait)
        self.cache = ProgramCache(capacity=cache_capacity)
        self.batches = 0
        self.fallbacks = 0
        self.completed = 0
        # per-client reorder buffers: release envelopes strictly in
        # submission (seq) order so a client's stream never reorders
        self._next_seq: Dict[str, int] = {}
        self._held: Dict[str, Dict[int, ResultEnvelope]] = {}

    # ---- intake ----------------------------------------------------------
    def submit(self, payload, client_id: str = "anon",
               now: float = 0.0) -> str:
        """Admit one RunSpec payload; returns its ticket.  Raises
        ``SpecError``/``PlanError`` (ValueError) on payloads that cannot
        run and ``QueueFullError`` under admission control — always
        before the spec reaches a batch."""
        run = self.queue.admit(payload, client_id=client_id, now=now)
        self.scheduler.add(run)
        return run.ticket

    @property
    def pending(self) -> int:
        return self.scheduler.pending

    # ---- execution -------------------------------------------------------
    def step(self, now: float) -> List[ResultEnvelope]:
        """Execute every batch due at ``now``; returns the envelopes
        released by the per-client reorder buffers (submission order
        within each client)."""
        return self._run_batches(self.scheduler.due(now), now)

    def drain(self, now: float) -> List[ResultEnvelope]:
        """Flush and execute everything still pending."""
        return self._run_batches(self.scheduler.due(now, flush=True), now)

    def _run_batches(self, batches: List[Batch],
                     now: float) -> List[ResultEnvelope]:
        released: List[ResultEnvelope] = []
        for batch in batches:
            if batch.grouped:
                entry, hit = self.cache.lookup(batch.key, batch.width)
                results = api.execute_group(
                    [r.cell for r in batch.runs],
                    runner_cache=entry.runners)
                self.batches += 1
            else:
                results = [r.plan.execute() for r in batch.runs]
                hit = False
                self.fallbacks += len(batch.runs)
            for run, result in zip(batch.runs, results):
                released.extend(self._complete(run, result, batch, hit,
                                               now))
        return released

    def _complete(self, run: PendingRun, result: api.RunResult,
                  batch: Batch, cache_hit: bool,
                  now: float) -> List[ResultEnvelope]:
        env = ResultEnvelope(
            ticket=run.ticket, client_id=run.client_id, seq=run.seq,
            spec=run.spec, batched=batch.grouped, cache_hit=cache_hit,
            width=batch.width, arrival=run.arrival, completed=now,
            verdicts=self._verdicts(run.plan, result), result=result)
        run.plan.release()            # drop the cell's data copies
        run.cell = None
        self.queue.complete()
        self.completed += 1
        # reorder-buffer release
        held = self._held.setdefault(run.client_id, {})
        held[run.seq] = env
        nxt = self._next_seq.get(run.client_id, 0)
        out: List[ResultEnvelope] = []
        while nxt in held:
            out.append(held.pop(nxt))
            nxt += 1
        self._next_seq[run.client_id] = nxt
        return out

    @staticmethod
    def _verdicts(pl: api.ExecutionPlan, result: api.RunResult) -> List[dict]:
        out = []
        for eps in pl.spec.eps:
            eps_abs = pl.eps_abs(eps)
            bound = pl.bound(eps_abs)
            out.append(dict(
                eps=eps, measured_rounds=result.measured_rounds(eps_abs),
                bound_rounds=bound.rounds if bound else None,
                certified=pl.certify(result, eps)))
        return out

    # ---- introspection ---------------------------------------------------
    def stats(self) -> dict:
        return dict(admitted=self.queue.admitted,
                    rejected=self.queue.rejected,
                    completed=self.completed,
                    pending=self.pending,
                    batches=self.batches,
                    fallbacks=self.fallbacks,
                    cache=self.cache.stats().to_dict())


def replay_trace(service: CertificationService, arrivals,
                 on_reject=None) -> List[ResultEnvelope]:
    """Drive a service through an arrival trace (objects with ``t``,
    ``client_id``, ``spec`` — see ``repro.serve.workload``) on the
    trace's own clock: step at each arrival time, then drain.  Fully
    deterministic for a fixed trace.  Rejections go to ``on_reject(
    arrival, error)`` when given, else re-raise."""
    envelopes: List[ResultEnvelope] = []
    last = 0.0
    for a in arrivals:
        envelopes.extend(service.step(a.t))
        last = a.t
        try:
            service.submit(a.spec, client_id=a.client_id, now=a.t)
        except (ValueError, RuntimeError) as e:
            if on_reject is None:
                raise
            on_reject(a, e)
    envelopes.extend(service.drain(last))
    return envelopes
