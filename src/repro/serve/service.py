"""The certification service: queue -> coalesce -> compiled cache -> stream.

``CertificationService`` wires the admission queue, the continuous-
batching scheduler, and the compiled-program cache around the reusable
``repro.api`` batch machinery:

    submit(payload)  -> ticket        (validate, plan, trace the cell)
    step(now)        -> [envelope]    (execute every batch due at `now`)
    drain(now)       -> [envelope]    (flush everything still pending)

Grouped batches run through ``repro.api.execute_group`` with this
service's per-group-key runner cache, so the trace + XLA compile is paid
once per (group structure, batch width) and every later batch of that
shape is a cache hit.  Unbatchable plans (python engine, sharded
placement) execute on the sequential ``ExecutionPlan.execute`` path —
the service never changes what a spec computes, only when and with whom
it is compiled (the soak test and ``benchmarks/serve_throughput.py``
gate verdict + typed-ledger identity against direct execution).

Results stream back as ``ResultEnvelope``s — verdict per eps threshold
plus the ledger summary (rounds, payload bytes, wire bits).  Within a
client the stream preserves submission order: a client's spec that lands
in a slow group never overtakes its earlier submissions (per-client
reorder buffer, released by sequence number).

**Resilience (PR 8).**  Execution failures never lose tickets and never
take sibling runs down with them.  A grouped batch that raises degrades
per-run down a ladder: re-run sequentially (``ExecutionPlan.execute``),
then retry with exponential backoff (``max_retries`` / ``retry_backoff``),
then re-plan on the python round engine, and only then emit a
**dead-letter envelope** (``status="error"`` with the failure cause) —
which still flows through the reorder buffer, so the client stream stays
gapless and ordered even under faults.  A group key that keeps failing
trips a circuit breaker in the program cache (later batches skip the
grouped compile entirely), and a spec that exhausts the whole ladder is
**quarantined**: later submissions of the same spec are rejected at the
door with ``QuarantinedError``.  Specs that wait longer than
``spec_timeout`` before executing are dead-lettered as timeouts.

The service never reads a wall clock; every method takes ``now``.  Real
deployments pass ``time.monotonic()``, tests and benchmarks pass a
synthetic trace — the scheduling decisions are identical either way.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Set, Tuple

from .. import api
from .cache import ProgramCache
from .queue import (PendingRun, QuarantinedError, SubmissionQueue,
                    parse_runspec)
from .scheduler import Batch, CoalescingScheduler


@dataclasses.dataclass
class ResultEnvelope:
    """One served verdict.  ``result`` is the full in-process RunResult
    (tests and benchmarks compare its ledger/iterate against direct
    execution); ``to_dict()`` is the wire shape — summaries only.

    Dead letters are envelopes too: ``status="error"`` with the failure
    cause in ``error`` and ``result=None``.  They occupy the run's slot
    in the per-client stream, so ordering/no-loss invariants hold for
    faulted and healthy runs alike."""

    ticket: str
    client_id: str
    seq: int
    spec: api.RunSpec
    batched: bool                     # ran in a coalesced group
    cache_hit: bool                   # compile-free (key + width seen)
    width: int                        # batch width it executed at
    arrival: float
    completed: float
    verdicts: List[dict]              # per eps: measured/bound/certified
    result: Optional[api.RunResult]
    status: str = "ok"                # "ok" | "error"
    error: Optional[str] = None       # failure cause for dead letters

    @property
    def latency(self) -> float:
        return self.completed - self.arrival

    def to_dict(self) -> dict:
        base = dict(
            status=self.status, ticket=self.ticket,
            client_id=self.client_id, seq=self.seq,
            spec=self.spec.to_dict(), batched=self.batched,
            cache_hit=self.cache_hit, width=self.width,
            latency=round(self.latency, 6))
        if self.status != "ok" or self.result is None:
            base["error"] = self.error
            return base
        led = self.result.ledger
        base.update(
            verdicts=self.verdicts,
            budget_ok=self.result.budget_ok,
            ledger=dict(rounds=led.rounds,
                        total_bytes=led.total_bytes(),
                        total_bits=led.total_bits(),
                        bits_per_round=round(led.bits_per_round(), 2),
                        op_counts=led.op_counts()))
        return base


class CertificationService:
    def __init__(self, max_batch: int = 8, max_wait: float = 0.05,
                 cache_capacity: int = 32, max_depth: int = 1024,
                 max_retries: int = 1, retry_backoff: float = 0.05,
                 spec_timeout: Optional[float] = None,
                 breaker_threshold: int = 3):
        self.queue = SubmissionQueue(max_depth=max_depth,
                                     retry_after=max_wait)
        self.scheduler = CoalescingScheduler(max_batch=max_batch,
                                             max_wait=max_wait)
        self.cache = ProgramCache(capacity=cache_capacity,
                                  breaker_threshold=breaker_threshold)
        self.max_retries = int(max_retries)
        self.retry_backoff = float(retry_backoff)
        self.spec_timeout = spec_timeout
        self.batches = 0
        self.fallbacks = 0
        self.completed = 0
        self.retries = 0
        self.dead_letters = 0
        self.breaker_skips = 0
        self.group_failures = 0
        self.engine_fallbacks = 0
        self.rejected_quarantined = 0
        # retry backlog: (due time, run) — singleton batches when due
        self._retry: List[Tuple[float, PendingRun]] = []
        # poison specs, keyed by canonical JSON: rejected at submit
        self._quarantined: Set[str] = set()
        # per-client reorder buffers: release envelopes strictly in
        # submission (seq) order so a client's stream never reorders
        self._next_seq: Dict[str, int] = {}
        self._held: Dict[str, Dict[int, ResultEnvelope]] = {}

    # ---- intake ----------------------------------------------------------
    def submit(self, payload, client_id: str = "anon",
               now: float = 0.0) -> str:
        """Admit one RunSpec payload; returns its ticket.  Raises
        ``SpecError``/``PlanError`` (ValueError) on payloads that cannot
        run, ``QuarantinedError`` for specs that previously exhausted the
        recovery ladder, and ``QueueFullError`` (with ``depth`` and
        ``retry_after`` hints) under admission control — always before
        the spec reaches a batch."""
        spec = parse_runspec(payload)
        if spec.to_json() in self._quarantined:
            self.queue.rejected += 1
            self.rejected_quarantined += 1
            raise QuarantinedError(
                "spec quarantined after repeated execution failures; "
                "resubmit after operator intervention")
        run = self.queue.admit(spec, client_id=client_id, now=now)
        self.scheduler.add(run)
        return run.ticket

    @property
    def pending(self) -> int:
        return self.scheduler.pending + len(self._retry)

    # ---- execution -------------------------------------------------------
    def step(self, now: float) -> List[ResultEnvelope]:
        """Execute every batch due at ``now`` plus every retry whose
        backoff expired; returns the envelopes released by the
        per-client reorder buffers (submission order within each
        client)."""
        return self._run_batches(
            self._due_retries(now) + self.scheduler.due(now), now)

    def drain(self, now: float) -> List[ResultEnvelope]:
        """Flush and execute everything still pending, including the
        retry backlog — a drained service holds no tickets."""
        released = self._run_batches(
            self._due_retries(now, flush=True)
            + self.scheduler.due(now, flush=True), now)
        while self._retry:            # failures during the flush re-arm
            released.extend(self._run_batches(
                self._due_retries(now, flush=True), now))
        return released

    def _due_retries(self, now: float, flush: bool = False) -> List[Batch]:
        due = [(t, r) for t, r in self._retry if flush or t <= now]
        if not due:
            return []
        self._retry = [(t, r) for t, r in self._retry
                       if not (flush or t <= now)]
        return [Batch(runs=[r]) for _, r in due]

    def _run_batches(self, batches: List[Batch],
                     now: float) -> List[ResultEnvelope]:
        released: List[ResultEnvelope] = []
        for batch in batches:
            if batch.grouped and self.cache.tripped(batch.key):
                # circuit breaker: this group shape keeps crashing the
                # compiled path — skip straight to sequential execution
                self.breaker_skips += len(batch.runs)
                for run in batch.runs:
                    released.extend(self._run_single(run, batch, now))
                continue
            if batch.grouped:
                entry, hit = self.cache.lookup(batch.key, batch.width)
                try:
                    results = api.execute_group(
                        [r.cell for r in batch.runs],
                        runner_cache=entry.runners)
                except Exception:     # degrade per-run, lose no tickets
                    self.group_failures += 1
                    self.cache.record_failure(batch.key)
                    for run in batch.runs:
                        released.extend(self._run_single(run, batch, now))
                    continue
                self.cache.record_success(batch.key)
                self.batches += 1
                for run, result in zip(batch.runs, results):
                    released.extend(self._complete(run, result, batch,
                                                   hit, now))
            else:
                for run in batch.runs:
                    released.extend(self._run_single(run, batch, now))
        return released

    def _run_single(self, run: PendingRun, batch: Batch,
                    now: float) -> List[ResultEnvelope]:
        """Sequential rung of the degradation ladder: execute one run
        alone; on failure retry with backoff, then re-plan on the python
        engine, then dead-letter + quarantine."""
        if run.cell is None and run.attempts == 0:
            self.fallbacks += 1       # unbatchable plan, healthy path
        if (self.spec_timeout is not None
                and now - run.arrival > self.spec_timeout):
            return self._dead_letter(
                run, now, f"timed out: waited {now - run.arrival:g}s "
                f"(spec_timeout={self.spec_timeout:g}s)")
        try:
            result = run.plan.execute()
        except Exception as e:        # noqa: BLE001 — ladder continues
            run.attempts += 1
            if run.attempts <= self.max_retries:
                delay = self.retry_backoff * (2 ** (run.attempts - 1))
                self._retry.append((now + delay, run))
                self.retries += 1
                return []
            result = self._python_fallback(run)
            if result is None:
                self._quarantined.add(run.spec.to_json())
                return self._dead_letter(
                    run, now, f"{type(e).__name__}: {e} "
                    f"(after {run.attempts} attempts + engine fallback)")
            self.engine_fallbacks += 1
        return self._complete(run, result, batch, False, now)

    def _python_fallback(self, run: PendingRun) -> Optional[api.RunResult]:
        """Last execution rung: re-plan the spec on the interpreted
        python round engine (no XLA compile in the loop).  Returns None
        when that also fails or the run already was on python."""
        if run.plan.engine == "python":
            return None
        try:
            fb = dataclasses.replace(run.spec, engine="python")
            return api.plan(fb).execute()
        except Exception:             # noqa: BLE001 — ladder exhausted
            return None

    def _complete(self, run: PendingRun, result: api.RunResult,
                  batch: Batch, cache_hit: bool,
                  now: float) -> List[ResultEnvelope]:
        env = ResultEnvelope(
            ticket=run.ticket, client_id=run.client_id, seq=run.seq,
            spec=run.spec, batched=batch.grouped, cache_hit=cache_hit,
            width=batch.width, arrival=run.arrival, completed=now,
            verdicts=self._verdicts(run.plan, result), result=result)
        return self._release(run, env)

    def _dead_letter(self, run: PendingRun, now: float,
                     cause: str) -> List[ResultEnvelope]:
        self.dead_letters += 1
        env = ResultEnvelope(
            ticket=run.ticket, client_id=run.client_id, seq=run.seq,
            spec=run.spec, batched=False, cache_hit=False, width=1,
            arrival=run.arrival, completed=now, verdicts=[],
            result=None, status="error", error=cause)
        return self._release(run, env)

    def _release(self, run: PendingRun,
                 env: ResultEnvelope) -> List[ResultEnvelope]:
        run.plan.release()            # drop the cell's data copies
        run.cell = None
        self.queue.complete()
        self.completed += 1
        # reorder-buffer release
        held = self._held.setdefault(run.client_id, {})
        held[run.seq] = env
        nxt = self._next_seq.get(run.client_id, 0)
        out: List[ResultEnvelope] = []
        while nxt in held:
            out.append(held.pop(nxt))
            nxt += 1
        self._next_seq[run.client_id] = nxt
        return out

    @staticmethod
    def _verdicts(pl: api.ExecutionPlan, result: api.RunResult) -> List[dict]:
        out = []
        for eps in pl.spec.eps:
            eps_abs = pl.eps_abs(eps)
            bound = pl.bound(eps_abs)
            out.append(dict(
                eps=eps, measured_rounds=result.measured_rounds(eps_abs),
                bound_rounds=bound.rounds if bound else None,
                certified=pl.certify(result, eps)))
        return out

    # ---- introspection ---------------------------------------------------
    def stats(self) -> dict:
        return dict(admitted=self.queue.admitted,
                    rejected=self.queue.rejected,
                    rejected_full=self.queue.rejected_full,
                    rejected_quarantined=self.rejected_quarantined,
                    quarantined=len(self._quarantined),
                    completed=self.completed,
                    pending=self.pending,
                    batches=self.batches,
                    fallbacks=self.fallbacks,
                    retries=self.retries,
                    group_failures=self.group_failures,
                    breaker_skips=self.breaker_skips,
                    engine_fallbacks=self.engine_fallbacks,
                    dead_letters=self.dead_letters,
                    cache=self.cache.stats().to_dict())


def replay_trace(service: CertificationService, arrivals,
                 on_reject=None) -> List[ResultEnvelope]:
    """Drive a service through an arrival trace (objects with ``t``,
    ``client_id``, ``spec`` — see ``repro.serve.workload``) on the
    trace's own clock: step at each arrival time, then drain.  Fully
    deterministic for a fixed trace.  Rejections go to ``on_reject(
    arrival, error)`` when given, else re-raise."""
    envelopes: List[ResultEnvelope] = []
    last = 0.0
    for a in arrivals:
        envelopes.extend(service.step(a.t))
        last = a.t
        try:
            service.submit(a.spec, client_id=a.client_id, now=a.t)
        except (ValueError, RuntimeError) as e:
            if on_reject is None:
                raise
            on_reject(a, e)
    envelopes.extend(service.drain(last))
    return envelopes
