"""CLI for the certification service.

    # synthetic heavy-traffic demo (seeded, deterministic trace)
    PYTHONPATH=src python -m repro.serve --demo 96

    # serve RunSpec JSONL from a file or stdin ("-"): one payload per
    # line, either a bare RunSpec object or {"client_id": ..., "spec": {...}}
    PYTHONPATH=src python -m repro.serve --input specs.jsonl

Envelopes stream to stdout as JSON lines as verdicts complete (per-
client submission order); rejected payloads become
``{"status": "rejected", ...}`` lines.  Service stats go to stderr.
Exit status is non-zero iff any payload was rejected.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Optional, Sequence

from .service import CertificationService
from .workload import Arrival, DEFAULT_STRUCTURES, synthetic_trace


def _read_arrivals(path: str, dt: float):
    fh = sys.stdin if path == "-" else open(path)
    arrivals = []
    try:
        for i, line in enumerate(fh):
            line = line.strip()
            if not line:
                continue
            client, payload = "anon", line
            try:
                doc = json.loads(line)
                if isinstance(doc, dict) and "spec" in doc:
                    client = str(doc.get("client_id", "anon"))
                    payload = doc["spec"]
                else:
                    payload = doc
            except json.JSONDecodeError:
                pass      # leave as raw text; admission reports it cleanly
            arrivals.append(Arrival(t=i * dt, client_id=client,
                                    spec=payload))
    finally:
        if fh is not sys.stdin:
            fh.close()
    return arrivals


def main(argv: Optional[Sequence[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.serve", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    src = parser.add_mutually_exclusive_group(required=True)
    src.add_argument("--demo", type=int, metavar="N",
                     help="serve a synthetic seeded trace of ~N specs")
    src.add_argument("--input", metavar="FILE",
                     help="RunSpec JSONL file ('-' for stdin)")
    parser.add_argument("--seed", type=int, default=0)
    parser.add_argument("--dt", type=float, default=1e-3,
                        help="trace inter-arrival time (injected clock)")
    parser.add_argument("--max-batch", type=int, default=8)
    parser.add_argument("--max-wait", type=float, default=0.05,
                        help="coalescing deadline on the injected clock")
    parser.add_argument("--cache-capacity", type=int, default=32)
    parser.add_argument("--max-depth", type=int, default=4096)
    args = parser.parse_args(argv)

    if args.demo is not None:
        per = max(1, -(-args.demo // len(DEFAULT_STRUCTURES)))
        arrivals = synthetic_trace(n_per_structure=per, seed=args.seed,
                                   dt=args.dt)
    else:
        arrivals = _read_arrivals(args.input, args.dt)

    service = CertificationService(max_batch=args.max_batch,
                                   max_wait=args.max_wait,
                                   cache_capacity=args.cache_capacity,
                                   max_depth=args.max_depth)
    rejected = 0

    def on_reject(arrival, err):
        nonlocal rejected
        rejected += 1
        print(json.dumps(dict(status="rejected",
                              client_id=arrival.client_id,
                              error=str(err))), flush=True)

    # Inline replay (rather than replay_trace) so envelopes stream to
    # stdout as their batches complete, not at end of trace.  Arrival
    # specs may be raw payloads (from --input); admission deserializes.
    def emit(envelopes):
        for env in envelopes:
            print(json.dumps(env.to_dict()), flush=True)

    last = 0.0
    for a in arrivals:
        emit(service.step(a.t))
        last = a.t
        try:
            service.submit(a.spec, client_id=a.client_id, now=a.t)
        except (ValueError, RuntimeError) as e:
            on_reject(a, e)
    emit(service.drain(last))

    print(f"[serve] {json.dumps(service.stats())}", file=sys.stderr)
    return 1 if rejected else 0


if __name__ == "__main__":
    sys.exit(main())
