"""Admission: RunSpec payloads -> validated, traced, ticketed work.

The queue is the service's front door.  ``admit`` takes whatever a
client sent — a JSON string, a decoded dict, or an already-constructed
``RunSpec`` — and either returns a ``PendingRun`` (ticket assigned,
plan validated, cell traced and ready to coalesce) or raises a clear
``ValueError`` subclass:

  * ``SpecError``      — malformed JSON, wrong-typed fields, a payload
    that is not a JSON object, or a spec the planner rejects
    (``repro.api.PlanError`` is re-raised as-is; it IS a ValueError).
  * ``QueueFullError`` — admission control: the number of admitted but
    not-yet-completed runs is capped so a traffic burst degrades into
    explicit rejections, not unbounded memory growth.

Rejection happens BEFORE any compute is paid for (plan-time validation,
PR 4) and before the run enters the scheduler, so a malformed spec can
never poison a coalesced batch.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, Optional, Union

from .. import api


class SpecError(ValueError):
    """A submission that cannot be turned into a runnable plan."""


class QuarantinedError(SpecError):
    """A spec rejected because earlier copies of it repeatedly failed to
    execute (poison-spec quarantine; see ``CertificationService``)."""


class QueueFullError(RuntimeError):
    """Admission control tripped: too many outstanding runs.

    Carries backpressure hints for the client: ``depth`` (current
    outstanding runs == the configured cap) and ``retry_after`` (the
    scheduler's coalescing deadline — by then at least one in-flight
    batch has been released, so capacity is the earliest plausible)."""

    def __init__(self, msg: str, *, depth: int = 0,
                 retry_after: float = 0.0):
        super().__init__(msg)
        self.depth = int(depth)
        self.retry_after = float(retry_after)


def parse_runspec(payload: Union[str, bytes, dict,
                                 api.RunSpec]) -> api.RunSpec:
    """Deserialize a submission payload into a RunSpec, wrapping every
    failure mode in a ``SpecError`` with the reason up front."""
    if isinstance(payload, api.RunSpec):
        return payload
    if isinstance(payload, (str, bytes)):
        try:
            payload = json.loads(payload)
        except json.JSONDecodeError as e:
            raise SpecError(f"malformed RunSpec JSON: {e}") from None
    if not isinstance(payload, dict):
        raise SpecError(f"a RunSpec payload must be a JSON object; got "
                        f"{type(payload).__name__}")
    try:
        return api.RunSpec.from_dict(payload)
    except ValueError as e:
        raise SpecError(str(e)) from None


@dataclasses.dataclass
class PendingRun:
    """One admitted spec: ticketed, planned, and (when batchable) traced
    into a ``repro.api.Cell`` ready for group coalescing.  ``cell`` is
    None for plans the batcher cannot take (python engine, sharded
    placement) — the service runs those on the sequential fallback
    path."""

    ticket: str
    client_id: str
    seq: int                          # per-client submission index
    spec: api.RunSpec
    plan: api.ExecutionPlan
    cell: Optional[api.Cell]
    arrival: float                    # injected clock, not wall time
    attempts: int = 0                 # failed execution attempts so far


class SubmissionQueue:
    """Ticket assignment + admission control + spec -> cell splitting.

    The queue does no scheduling — it turns payloads into ``PendingRun``s
    and tracks how many are outstanding (admitted minus completed).  The
    service hands each PendingRun to the coalescing scheduler and calls
    ``complete`` once its verdict is emitted.
    """

    def __init__(self, max_depth: int = 1024, retry_after: float = 0.05):
        self.max_depth = int(max_depth)
        self.retry_after = float(retry_after)
        self.outstanding = 0
        self.admitted = 0
        self.rejected = 0
        self.rejected_full = 0
        self._client_seq: Dict[str, int] = {}

    def admit(self, payload, client_id: str = "anon",
              now: float = 0.0) -> PendingRun:
        if self.outstanding >= self.max_depth:
            self.rejected += 1
            self.rejected_full += 1
            raise QueueFullError(
                f"submission queue full: {self.outstanding} outstanding "
                f"runs (max_depth={self.max_depth}); retry after "
                f"{self.retry_after:g}s",
                depth=self.outstanding, retry_after=self.retry_after)
        try:
            spec = parse_runspec(payload)
            pl = api.plan(spec)
            if pl.resolution_only:
                raise SpecError(
                    "resolution-only RunSpec (no instance/algorithm); "
                    "nothing to certify")
            cell = api.prepare_cell(pl)
        except ValueError:
            self.rejected += 1
            raise
        seq = self._client_seq.get(client_id, 0)
        self._client_seq[client_id] = seq + 1
        self.admitted += 1
        self.outstanding += 1
        return PendingRun(ticket=f"t{self.admitted:06d}",
                          client_id=client_id, seq=seq, spec=spec,
                          plan=pl, cell=cell, arrival=float(now))

    def complete(self, n: int = 1) -> None:
        self.outstanding = max(0, self.outstanding - n)
