"""Compiled-program cache: LRU over group keys, hit/miss accounted.

One entry per ``group_key`` holds the jitted group runners
(``repro.api.execute_group``'s ``runner_cache``) plus the set of batch
widths already compiled.  An execution is a **hit** iff the entry was
present AND the batch width was seen before — exactly the condition
under which no new XLA compile is paid (jit re-specializes per width;
the runner object itself is reused for free).  Counting it this way
keeps the published hit rate an honest proxy for "compiles avoided",
which is what ``benchmarks/serve_throughput.py`` gates.

Eviction is LRU over entries: touching a key moves it to the tail;
exceeding ``capacity`` drops the head (its runners and their compiled
executables become garbage; a later batch with that key re-traces and
recompiles, accounted as a miss).

The cache also hosts the **compile circuit breaker**: a per-key count of
consecutive grouped-execution failures.  Once a key fails
``breaker_threshold`` times in a row, ``tripped(key)`` flips true and the
service stops routing batches with that key through the grouped path —
every future run for it goes straight to the sequential ladder instead of
re-paying (and re-crashing in) the same XLA compile.  A single grouped
success for the key resets its count.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Dict, Set, Tuple


@dataclasses.dataclass
class CacheEntry:
    runners: dict = dataclasses.field(default_factory=dict)
    widths: Set[int] = dataclasses.field(default_factory=set)
    uses: int = 0


@dataclasses.dataclass
class CacheStats:
    hits: int
    misses: int
    evictions: int
    size: int
    capacity: int
    breaker_open: int = 0             # keys with the breaker tripped

    @property
    def executions(self) -> int:
        return self.hits + self.misses

    @property
    def hit_rate(self) -> float:
        return self.hits / max(1, self.executions)

    def to_dict(self) -> dict:
        return dict(hits=self.hits, misses=self.misses,
                    evictions=self.evictions, size=self.size,
                    capacity=self.capacity,
                    breaker_open=self.breaker_open,
                    hit_rate=round(self.hit_rate, 4))


class ProgramCache:
    def __init__(self, capacity: int = 32, breaker_threshold: int = 3):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1; got {capacity}")
        if breaker_threshold < 1:
            raise ValueError(f"breaker_threshold must be >= 1; got "
                             f"{breaker_threshold}")
        self.capacity = int(capacity)
        self.breaker_threshold = int(breaker_threshold)
        self._entries: "OrderedDict[tuple, CacheEntry]" = OrderedDict()
        self._failures: Dict[tuple, int] = {}
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def lookup(self, key: tuple, width: int) -> Tuple[CacheEntry, bool]:
        """The entry for ``key`` (created if absent, LRU-evicting) and
        whether this (key, width) execution is a compile-free hit."""
        entry = self._entries.get(key)
        hit = entry is not None and width in entry.widths
        if entry is None:
            entry = CacheEntry()
            self._entries[key] = entry
            while len(self._entries) > self.capacity:
                self._entries.popitem(last=False)
                self.evictions += 1
        self._entries.move_to_end(key)
        entry.widths.add(width)
        entry.uses += 1
        if hit:
            self.hits += 1
        else:
            self.misses += 1
        return entry, hit

    def record_failure(self, key: tuple) -> None:
        """One grouped-execution failure for ``key``.  Also drops the
        (possibly half-compiled, possibly poisoned) cache entry so a
        later retry starts from a clean trace."""
        self._failures[key] = self._failures.get(key, 0) + 1
        self._entries.pop(key, None)

    def record_success(self, key: tuple) -> None:
        self._failures.pop(key, None)

    def tripped(self, key: tuple) -> bool:
        return self._failures.get(key, 0) >= self.breaker_threshold

    @property
    def breaker_open(self) -> int:
        return sum(1 for n in self._failures.values()
                   if n >= self.breaker_threshold)

    def __len__(self) -> int:
        return len(self._entries)

    def stats(self) -> CacheStats:
        return CacheStats(hits=self.hits, misses=self.misses,
                          evictions=self.evictions,
                          size=len(self._entries),
                          capacity=self.capacity,
                          breaker_open=self.breaker_open)
