"""Continuous-batching coalescer: pending runs -> due batches.

Arriving ``PendingRun``s are pooled by their cell's ``group_key()`` —
the same key ``repro.api.execute_batch`` groups by (jaxpr structure x
backend x channel x rounds; placement/engine never reach the pool, see
``prepare_cell``).  A pooled group is released as a batch when either

  * it reaches ``max_batch`` width (count-based flush: under heavy
    traffic every batch is full and the compiled program is reused at a
    fixed width), or
  * its oldest member has waited ``max_wait`` (the coalescing deadline:
    a lone spec is never parked forever waiting for company), or
  * the caller drains (shutdown / end of trace).

Unbatchable runs (``cell is None``) bypass the pool entirely and come
back as immediately-due singleton batches on the sequential path.

Everything is driven by caller-supplied ``now`` values — the scheduler
never reads a wall clock — so a replayed arrival trace produces the
identical batch sequence every time (the soak test leans on this).
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import List, Optional

from .queue import PendingRun


@dataclasses.dataclass
class Batch:
    """One unit of execution: ``grouped`` batches share a group key and
    run through ``repro.api.execute_group``; sequential ones run their
    plan directly."""

    runs: List[PendingRun]
    key: Optional[tuple] = None       # group key; None => sequential

    @property
    def grouped(self) -> bool:
        return self.key is not None

    @property
    def width(self) -> int:
        return len(self.runs)


class CoalescingScheduler:
    def __init__(self, max_batch: int = 8, max_wait: float = 0.05):
        if max_batch < 1:
            raise ValueError(f"max_batch must be >= 1; got {max_batch}")
        self.max_batch = int(max_batch)
        self.max_wait = float(max_wait)
        # insertion-ordered so equal-deadline groups release in first-
        # arrival order — determinism, not fairness tuning
        self._pool: "OrderedDict[tuple, List[PendingRun]]" = OrderedDict()
        self._sequential: List[PendingRun] = []

    @property
    def pending(self) -> int:
        return (sum(len(v) for v in self._pool.values())
                + len(self._sequential))

    def add(self, run: PendingRun) -> None:
        if run.cell is None:
            self._sequential.append(run)
        else:
            self._pool.setdefault(run.cell.group_key(), []).append(run)

    def due(self, now: float, flush: bool = False) -> List[Batch]:
        """Release every batch that is ready at ``now`` (all of them,
        ``max_batch``-sized, when ``flush``).  Deterministic: release
        order is pool insertion order, members in arrival order."""
        batches: List[Batch] = [Batch(runs=[r], key=None)
                                for r in self._sequential]
        self._sequential = []
        for key in list(self._pool):
            waiting = self._pool[key]
            while len(waiting) >= self.max_batch:
                batches.append(Batch(runs=waiting[:self.max_batch],
                                     key=key))
                waiting = waiting[self.max_batch:]
            if waiting and (flush or
                            now - waiting[0].arrival >= self.max_wait):
                batches.append(Batch(runs=waiting, key=key))
                waiting = []
            if waiting:
                self._pool[key] = waiting
            else:
                del self._pool[key]
        return batches
